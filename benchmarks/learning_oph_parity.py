"""Fig.-4-style learning parity: OPH (rotation) vs k-pass minhash at
equal (k, b) -- the ROADMAP's "learning-path benchmark" item.

One Permutation Hashing does ~k x less hashing work; this benchmark shows
the thing that makes that a free lunch: a linear model trained on
rotation-densified OPH signatures reaches the same accuracy as one
trained on k-pass minwise signatures at the same (k, b).  Both paths run
through the streaming ``OnlineTrainer`` + ``SignatureCache`` subsystem,
so the rows also report the epoch-0 (hash) vs cached-replay load split.

Run:  PYTHONPATH=src python -m benchmarks.learning_oph_parity [--json OUT]
"""

from __future__ import annotations

import argparse
import json
import tempfile

import jax
import numpy as np

from benchmarks.common import Row, bench_dataset
from repro.data.pipeline import SignatureStream, batch_to_shards
from repro.kernels import batch_signatures
from repro.train import OnlineTrainer, SignatureCache, make_family

D_BITS = 16
K, B = 128, 8
EPOCHS = 15

SCHEMES = [
    ("minhash-2u", "2u", "rotation"),       # k-pass baseline
    ("oph-rotation", "oph", "rotation"),    # single-pass, densified
    ("oph-sentinel", "oph", "sentinel"),    # single-pass, zero-coded EMPTYs
]


def run() -> list[Row]:
    train, test = bench_dataset(n=512, D=2**D_BITS, avg_nnz=96, seed=7)
    shard_paths = batch_to_shards(train,
                                  tempfile.mkdtemp(prefix="repro_parity_"))

    results = {}
    for name, scheme, densify in SCHEMES:
        family = make_family(jax.random.PRNGKey(0), scheme, K, D_BITS,
                             densify=densify)
        sig_te = batch_signatures(test, family, b=B)
        cache = SignatureCache(SignatureStream(shard_paths, family, b=B,
                                               chunk_size=128))
        trainer = OnlineTrainer(k=K, b=B, average=True, lam=1e-4, eta0=0.5,
                                batch_size=16)
        _, stats, evals = trainer.fit(
            cache, EPOCHS,
            eval_fn=lambda t: t.evaluate(sig_te, test.labels))
        replay_load = [s.load_s for s in stats[1:]]
        results[name] = {
            "final_acc": round(evals[-1], 4),
            "best_acc": round(max(evals), 4),
            "hash_epoch_load_s": round(stats[0].load_s, 4),
            "cache_epoch_load_s": round(float(np.mean(replay_load)), 4),
            "cache_reduction_x": round(cache.stats.reduction(), 1),
        }

    base = results["minhash-2u"]["final_acc"]
    rows: list[Row] = []
    for name, r in results.items():
        rows.append((f"parity/{name}", 0.0, {
            **r, "gap_vs_minhash": round(abs(r["final_acc"] - base), 4)}))
    rows.append(("parity/summary", 0.0, {
        "k": K, "b": B,
        "oph_within_2pct": int(
            abs(results["oph-rotation"]["final_acc"] - base) <= 0.02),
        "cache_load_below_hash": int(all(
            r["cache_epoch_load_s"] < r["hash_epoch_load_s"]
            for r in results.values())),
    }))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="also write results as a JSON file (CI artifact)")
    args = ap.parse_args()
    rows = run()
    for name, _, derived in rows:
        print(name, derived)
    if args.json:
        payload = [{"name": name, "derived": derived}
                   for name, _, derived in rows]
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
