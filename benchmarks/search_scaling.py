"""Search scale-out benchmark: fused scan vs per-block loop, out-of-core
streaming, and the sharded-index router.

The PR-5 serving claims, measured end to end on synthetic corpora:

  * exact q/s of the fused in-jit scan (ONE traced computation per
    flush) vs the PR-4 per-block host loop, across corpus sizes --
    the dispatch-overhead story behind the paper's "bounded by data
    movement, not hashing" thesis (PAPER.md §1, §3),
  * a successful out-of-core run: corpus payload bytes strictly greater
    than the configured device window, block windows streamed off the
    mmap'd ``.idx`` through the double-buffered H2D pipeline,
  * router q/s vs shard count -- the sequential fan-out AND (when more
    than one device is visible) the mesh-parallel ``shard_map`` dispatch
    with round-robin shard placement -- each checked bit-identical to
    the single-index search.

``--json PATH`` writes the rows as a JSON artifact (uploaded by the
slow-tier CI job next to ``search_index.json``; the CI step forces 8
host devices via XLA_FLAGS so the mesh rows are populated).
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys
import tempfile
import time

# Force multiple host devices for the mesh-dispatch rows.  Must land
# before jax initialises; respect an explicit setting (CI) and never
# fight an already-imported jax (e.g. when run via a driver script).
if "jax" not in sys.modules:
    _flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_force_host_platform_device_count=8").strip()

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, fmt_rows, time_fn
from repro.data.pipeline import make_sharded_dataset
from repro.data.preprocess import preprocess_shards
from repro.data.synthetic import DatasetSpec
from repro.index import (IndexSearcher, build_index, build_sharded,
                         choose_band_config, load_index, load_sharded)
from repro.train.online import make_family

D_BITS = 16
K, B = 128, 8
N_QUERIES = 16
TOPK = 10
CORPUS_SIZES = (1024, 4096)
SHARD_COUNTS = (2, 4, 8)
CORPUS_BLOCK = 512
REPEATS = 3


def _median_qps(searcher, queries, *, mode: str = "exact") -> float:
    us = time_fn(lambda: searcher.search(queries, TOPK, mode=mode),
                 warmup=1, iters=REPEATS)
    return N_QUERIES / (us * 1e-6)


def _build_corpus(tmp: str, n: int):
    spec = DatasetSpec(f"scale_{n}", n=n, D=2**D_BITS, avg_nnz=64,
                       n_prototypes=8, overlap=0.8, seed=0)
    fam = make_family(jax.random.PRNGKey(0), "oph", K, D_BITS,
                      densify="rotation")
    raw = make_sharded_dataset(spec, os.path.join(tmp, f"raw{n}"),
                               n_shards=8)
    # chunk small enough that every corpus yields >= 8 .sig files, so
    # the largest SHARD_COUNTS row is buildable (file-granularity split)
    preprocess_shards(raw, os.path.join(tmp, f"sig{n}"), fam, b=B,
                      chunk_size=max(64, n // 16),
                      loader_kwargs={"lane_multiple": 8})
    return sorted(glob.glob(os.path.join(tmp, f"sig{n}", "*.sig")))


def run() -> list[Row]:
    rows: list[Row] = []
    cfg = choose_band_config(K, B, threshold=0.5)
    with tempfile.TemporaryDirectory(prefix="repro_search_scale_") as tmp:
        for n in CORPUS_SIZES:
            sig_paths = _build_corpus(tmp, n)
            idx_path = os.path.join(tmp, f"c{n}.idx")
            build_index(sig_paths, idx_path, cfg)
            index = load_index(idx_path)
            rng = np.random.default_rng(7)
            picks = rng.integers(0, index.n, N_QUERIES)
            queries = jnp.asarray(np.ascontiguousarray(
                index.words_host[picks]))

            fused = IndexSearcher(index, corpus_block=CORPUS_BLOCK)
            blockloop = IndexSearcher(index, corpus_block=CORPUS_BLOCK,
                                      exact_impl="blockloop")
            qps_fused = _median_qps(fused, queries)
            qps_block = _median_qps(blockloop, queries)
            speedup = qps_fused / qps_block
            ref = fused.search(queries, TOPK)
            r_block = blockloop.search(queries, TOPK)
            same = (np.array_equal(ref.indices, r_block.indices)
                    and np.array_equal(ref.scores, r_block.scores))
            rows.append((f"scaling/exact_fused_n{n}",
                         1e6 / qps_fused, {
                             "docs": n, "queries_per_s": round(qps_fused, 1),
                             "blocks": n // CORPUS_BLOCK}))
            rows.append((f"scaling/exact_blockloop_n{n}",
                         1e6 / qps_block, {
                             "docs": n, "queries_per_s": round(qps_block, 1)}))
            rows.append((f"scaling/fused_speedup_n{n}", 0.0, {
                "speedup": round(speedup, 3),
                "bit_identical": bool(same),
                "acceptance": "fused q/s >= per-block baseline",
                "ok": bool(speedup >= 1.0 and same)}))

            if n == CORPUS_SIZES[-1]:
                # out-of-core: device window strictly smaller than the
                # packed corpus forces the streamed mmap-window scan
                window = index.meta.payload_bytes // 4
                streamed = IndexSearcher(index, corpus_block=CORPUS_BLOCK,
                                         max_device_bytes=window)
                assert streamed.streamed
                qps_stream = _median_qps(streamed, queries)
                r_stream = streamed.search(queries, TOPK)
                same_stream = (np.array_equal(r_stream.indices, ref.indices)
                               and np.array_equal(r_stream.scores,
                                                  ref.scores))
                rows.append((f"scaling/exact_streamed_n{n}",
                             1e6 / qps_stream, {
                                 "docs": n,
                                 "queries_per_s": round(qps_stream, 1),
                                 "corpus_bytes": index.meta.payload_bytes,
                                 "device_window": window,
                                 "bit_identical": bool(same_stream),
                                 "acceptance": "corpus bytes > device "
                                               "window with identical "
                                               "results",
                                 "ok": bool(
                                     index.meta.payload_bytes > window
                                     and same_stream)}))

                n_dev = len(jax.devices())
                mesh = None
                if n_dev > 1:
                    from repro.launch.mesh import make_debug_mesh
                    mesh = make_debug_mesh(n_dev, axes=("data",))
                for n_shards in SHARD_COUNTS:
                    if n_shards > len(sig_paths):
                        # splits are at .sig-file granularity
                        continue
                    shard_dir = os.path.join(tmp, f"shards{n}_{n_shards}")
                    t0 = time.perf_counter()
                    build_sharded(sig_paths, shard_dir, cfg,
                                  n_shards=n_shards)
                    t_build = time.perf_counter() - t0
                    router = load_sharded(shard_dir,
                                          corpus_block=CORPUS_BLOCK)
                    qps_router = _median_qps(router, queries)
                    res = router.search(queries, TOPK)
                    identical = (np.array_equal(res.indices, ref.indices)
                                 and np.array_equal(res.scores, ref.scores))
                    rows.append((f"scaling/router_seq_s{n_shards}_n{n}",
                                 1e6 / qps_router, {
                                     "docs": n, "shards": n_shards,
                                     "dispatch": "sequential",
                                     "queries_per_s": round(qps_router, 1),
                                     "build_s": round(t_build, 2),
                                     "bit_identical": bool(identical),
                                     "acceptance": "merged top-k == "
                                                   "single-index top-k",
                                     "ok": bool(identical)}))
                    if mesh is None:
                        continue
                    mrouter = load_sharded(shard_dir, mesh=mesh,
                                           corpus_block=CORPUS_BLOCK)
                    qps_mesh = _median_qps(mrouter, queries)
                    mres = mrouter.search(queries, TOPK)
                    m_ident = (np.array_equal(mres.indices, ref.indices)
                               and np.array_equal(mres.scores, ref.scores))
                    rows.append((f"scaling/router_mesh_s{n_shards}_n{n}",
                                 1e6 / qps_mesh, {
                                     "docs": n, "shards": n_shards,
                                     "dispatch": "mesh", "devices": n_dev,
                                     "queries_per_s": round(qps_mesh, 1),
                                     "qps_vs_sequential": round(
                                         qps_mesh / qps_router, 3),
                                     "bit_identical": bool(m_ident),
                                     "acceptance": "shard_map top-k == "
                                                   "single-index top-k",
                                     "ok": bool(m_ident)}))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as a JSON artifact")
    args = ap.parse_args()
    rows = run()
    print(fmt_rows(rows))
    if args.json:
        doc = [{"name": name, "us_per_call": us, **derived}
               for name, us, derived in rows]
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2)


if __name__ == "__main__":
    main()
