"""Benchmark harness: one module per paper table/figure.

Usage:  PYTHONPATH=src python -m benchmarks.run [--only NAME[,NAME...]]
            [--repeat N] [--json PATH]
Prints ``name,us_per_call,derived`` CSV (one line per measurement).

``--only`` with a single token is a substring filter (legacy behaviour);
a comma-separated list selects exact module names and errors on unknown
ones (no more silently matching nothing on a typo).  ``--repeat N`` runs
each selected module N times and reports the per-row MEDIAN wall-clock
(plus min/max spread), so scaling numbers stop being single-sample
noise; ``--json PATH`` writes a ``{"rows": [...], "metrics": {...}}``
artifact -- ``rows`` is the measurement list, ``metrics`` maps each
module to the ``repro.obs`` registry snapshot taken right after it ran
(the registry is reset before each module, so snapshots don't bleed
across modules).
"""

from __future__ import annotations

import argparse
import json
import sys
import time
import traceback

from benchmarks.common import fmt_rows

MODULES = [
    ("preprocessing_cpu", "Table 2"),
    ("preprocessing_kernel", "Table 3 / Figs 1-3"),
    ("preprocessing_oph", "OPH vs §3 k-pass cost"),
    ("learning_hashfuncs", "Fig 4"),
    ("learning_oph_parity", "Fig 4-style OPH vs minhash parity"),
    ("vw_hashfuncs", "Fig 5"),
    ("learning_scaling", "Figs 6-9"),
    ("bbit_vs_vw", "Figs 10-12"),
    ("online_learning", "Figs 13-15, 19"),
    ("loading_time", "Figs 16, 18 / Table 4"),
    ("resemblance_mse", "Figs 20-22 / App. A"),
    ("signature_engine", "§6 / Table 2 wire format"),
    ("search_index", "§1 search workload (repro.index)"),
    ("search_scaling", "serving scale-out (fused scan, shards, "
                       "out-of-core)"),
    ("search_serving", "continuous-batching server (latency vs load, "
                       "live appends)"),
]


def _selector(only):
    """--only matcher: single token = substring, comma list = exact names."""
    if not only:
        return lambda name: True
    tokens = [t.strip() for t in only.split(",") if t.strip()]
    if len(tokens) > 1:
        known = {name for name, _ in MODULES}
        unknown = [t for t in tokens if t not in known]
        if unknown:
            raise SystemExit(f"--only: unknown module(s) {unknown}; "
                             f"available: {sorted(known)}")
        return lambda name: name in tokens
    return lambda name: tokens[0] in name


def _median_merge(runs):
    """Per-row median wall-clock over aligned repeat runs.

    Rows align by position and name (every module emits a deterministic
    row list); the derived dict comes from the median run, annotated
    with the repeat count and the min/max spread.
    """
    if len(runs) == 1:
        return runs[0]
    if any(len(r) != len(runs[0]) or
           [name for name, _, _ in r] != [name for name, _, _ in runs[0]]
           for r in runs[1:]):
        # misaligned rows (a module emitted differently across repeats):
        # fall back to the last run rather than mismatching medians
        return runs[-1]
    merged = []
    for j, (name, _, _) in enumerate(runs[0]):
        order = sorted(range(len(runs)), key=lambda i: runs[i][j][1])
        mid = order[len(order) // 2]
        us = runs[mid][j][1]
        derived = dict(runs[mid][j][2])
        derived.update(repeat=len(runs),
                       us_min=round(runs[order[0]][j][1], 3),
                       us_max=round(runs[order[-1]][j][1], 3))
        merged.append((name, us, derived))
    return merged


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter, or comma-separated exact "
                         "module names")
    ap.add_argument("--repeat", type=int, default=1, metavar="N",
                    help="run each selected module N times; report the "
                         "per-row median wall-clock")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the final rows as a JSON artifact")
    args = ap.parse_args()
    if args.repeat < 1:
        ap.error("--repeat must be >= 1")
    selected = _selector(args.only)

    from repro.obs.metrics import get_registry

    all_rows = []
    metrics = {}
    failures = []
    ran = 0
    for mod_name, paper_ref in MODULES:
        if not selected(mod_name):
            continue
        ran += 1
        t0 = time.perf_counter()
        get_registry().reset()
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
            rows = _median_merge([mod.run() for _ in range(args.repeat)])
            all_rows.extend(rows)
            snap = get_registry().snapshot()
            if snap:
                metrics[mod_name] = snap
            dt = time.perf_counter() - t0
            print(f"# {mod_name} ({paper_ref}): {len(rows)} rows "
                  f"in {dt:.1f}s"
                  + (f" ({args.repeat} repeats, median reported)"
                     if args.repeat > 1 else ""), file=sys.stderr)
        except Exception:
            failures.append(mod_name)
            print(f"# {mod_name} FAILED:", file=sys.stderr)
            traceback.print_exc()
    print(fmt_rows(all_rows))
    if args.json and not failures:
        doc = {"rows": [{"name": name, "us_per_call": us, **derived}
                        for name, us, derived in all_rows],
               "metrics": metrics}
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2)
    if not ran:
        # a substring --only matching nothing must not look like success
        print(f"# --only {args.only!r} selected no modules; available: "
              f"{sorted(name for name, _ in MODULES)}", file=sys.stderr)
        sys.exit(2)
    if failures:
        # a raising module is a harness failure, not a summary footnote:
        # CI must go red
        print(f"# FAILURES: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
