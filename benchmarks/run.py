"""Benchmark harness: one module per paper table/figure.

Usage:  PYTHONPATH=src python -m benchmarks.run [--only NAME]
Prints ``name,us_per_call,derived`` CSV (one line per measurement).
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks.common import fmt_rows

MODULES = [
    ("preprocessing_cpu", "Table 2"),
    ("preprocessing_kernel", "Table 3 / Figs 1-3"),
    ("preprocessing_oph", "OPH vs §3 k-pass cost"),
    ("learning_hashfuncs", "Fig 4"),
    ("learning_oph_parity", "Fig 4-style OPH vs minhash parity"),
    ("vw_hashfuncs", "Fig 5"),
    ("learning_scaling", "Figs 6-9"),
    ("bbit_vs_vw", "Figs 10-12"),
    ("online_learning", "Figs 13-15, 19"),
    ("loading_time", "Figs 16, 18 / Table 4"),
    ("resemblance_mse", "Figs 20-22 / App. A"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    all_rows = []
    failures = []
    for mod_name, paper_ref in MODULES:
        if args.only and args.only not in mod_name:
            continue
        t0 = time.perf_counter()
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
            rows = mod.run()
            all_rows.extend(rows)
            dt = time.perf_counter() - t0
            print(f"# {mod_name} ({paper_ref}): {len(rows)} rows "
                  f"in {dt:.1f}s", file=sys.stderr)
        except Exception:
            failures.append(mod_name)
            print(f"# {mod_name} FAILED:", file=sys.stderr)
            traceback.print_exc()
    print(fmt_rows(all_rows))
    if failures:
        print(f"# FAILURES: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
