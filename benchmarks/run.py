"""Benchmark harness: one module per paper table/figure.

Usage:  PYTHONPATH=src python -m benchmarks.run [--only NAME[,NAME...]]
Prints ``name,us_per_call,derived`` CSV (one line per measurement).

``--only`` with a single token is a substring filter (legacy behaviour);
a comma-separated list selects exact module names and errors on unknown
ones (no more silently matching nothing on a typo).
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks.common import fmt_rows

MODULES = [
    ("preprocessing_cpu", "Table 2"),
    ("preprocessing_kernel", "Table 3 / Figs 1-3"),
    ("preprocessing_oph", "OPH vs §3 k-pass cost"),
    ("learning_hashfuncs", "Fig 4"),
    ("learning_oph_parity", "Fig 4-style OPH vs minhash parity"),
    ("vw_hashfuncs", "Fig 5"),
    ("learning_scaling", "Figs 6-9"),
    ("bbit_vs_vw", "Figs 10-12"),
    ("online_learning", "Figs 13-15, 19"),
    ("loading_time", "Figs 16, 18 / Table 4"),
    ("resemblance_mse", "Figs 20-22 / App. A"),
    ("signature_engine", "§6 / Table 2 wire format"),
    ("search_index", "§1 search workload (repro.index)"),
]


def _selector(only):
    """--only matcher: single token = substring, comma list = exact names."""
    if not only:
        return lambda name: True
    tokens = [t.strip() for t in only.split(",") if t.strip()]
    if len(tokens) > 1:
        known = {name for name, _ in MODULES}
        unknown = [t for t in tokens if t not in known]
        if unknown:
            raise SystemExit(f"--only: unknown module(s) {unknown}; "
                             f"available: {sorted(known)}")
        return lambda name: name in tokens
    return lambda name: tokens[0] in name


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter, or comma-separated exact "
                         "module names")
    args = ap.parse_args()
    selected = _selector(args.only)

    all_rows = []
    failures = []
    ran = 0
    for mod_name, paper_ref in MODULES:
        if not selected(mod_name):
            continue
        ran += 1
        t0 = time.perf_counter()
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
            rows = mod.run()
            all_rows.extend(rows)
            dt = time.perf_counter() - t0
            print(f"# {mod_name} ({paper_ref}): {len(rows)} rows "
                  f"in {dt:.1f}s", file=sys.stderr)
        except Exception:
            failures.append(mod_name)
            print(f"# {mod_name} FAILED:", file=sys.stderr)
            traceback.print_exc()
    print(fmt_rows(all_rows))
    if not ran:
        # a substring --only matching nothing must not look like success
        print(f"# --only {args.only!r} selected no modules; available: "
              f"{sorted(name for name, _ in MODULES)}", file=sys.stderr)
        sys.exit(2)
    if failures:
        # a raising module is a harness failure, not a summary footnote:
        # CI must go red
        print(f"# FAILURES: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
