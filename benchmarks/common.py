"""Shared benchmark utilities: timing, CSV rows, small dataset cache."""

from __future__ import annotations

import time
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Row = Tuple[str, float, Dict]   # (name, us_per_call, derived)


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time per call in microseconds (blocking on outputs)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def fmt_rows(rows: List[Row]) -> str:
    lines = ["name,us_per_call,derived"]
    for name, us, derived in rows:
        dv = ";".join(f"{k}={v}" for k, v in derived.items())
        lines.append(f"{name},{us:.1f},{dv}")
    return "\n".join(lines)


_CACHE = {}


def bench_dataset(n: int = 512, D: int = 2**20, avg_nnz: int = 256,
                  seed: int = 0):
    """Small webspam-like dataset (cached across benchmark modules)."""
    key = (n, D, avg_nnz, seed)
    if key not in _CACHE:
        from repro.data.synthetic import DatasetSpec, generate
        spec = DatasetSpec("bench", n=n, D=D, avg_nnz=avg_nnz,
                           n_prototypes=4, overlap=0.7, seed=seed)
        _CACHE[key] = generate(spec)
    return _CACHE[key]


def train_svm_accuracy(sig_tr, y_tr, sig_te, y_te, k: int, b: int,
                       steps: int = 80, lr: float = 0.05) -> float:
    """Quick batch SVM on hashed features; returns test accuracy."""
    from repro.models.linear import LinearModel, accuracy, make_loss_fn
    from repro.optim import adamw, constant
    from repro.train import TrainState, make_train_step
    loss = make_loss_fn("svm", "hashed", b, C=1.0)
    opt = adamw(constant(lr))
    state = TrainState.create(LinearModel.create(k * (1 << b)), opt)
    step = jax.jit(make_train_step(lambda p, batch: loss(p, *batch), opt))
    for _ in range(steps):
        state, _ = step(state, (sig_tr, y_tr))
    return float(accuracy(state.params, sig_te, y_te,
                          feature_kind="hashed", b=b))


def train_dense_accuracy(x_tr, y_tr, x_te, y_te, steps: int = 80,
                         lr: float = 0.05, kind: str = "svm") -> float:
    from repro.models.linear import LinearModel, accuracy, make_loss_fn
    from repro.optim import adamw, constant
    from repro.train import TrainState, make_train_step
    loss = make_loss_fn(kind, "dense", 0, C=1.0)
    opt = adamw(constant(lr))
    state = TrainState.create(LinearModel.create(x_tr.shape[1]), opt)
    step = jax.jit(make_train_step(lambda p, batch: loss(p, *batch), opt))
    for _ in range(steps):
        state, _ = step(state, (x_tr, y_tr))
    return float(accuracy(state.params, x_te, y_te, feature_kind="dense"))
