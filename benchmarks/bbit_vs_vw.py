"""Paper Figures 10-12: b-bit minwise hashing vs VW at equal storage.

Claim: at the same per-example storage budget, b-bit minwise hashing is
substantially more accurate than VW (VW needs ~10-100x more storage for
parity); at equal k, 8-bit hashing also trains faster than VW's denser
vectors.
"""

from __future__ import annotations

import time

import jax

from benchmarks.common import (Row, bench_dataset, train_dense_accuracy,
                               train_svm_accuracy)
from repro.core import Hash2U, VWHasher, lowest_bits, minhash_signatures
from repro.core.bbit import storage_bits, vw_storage_bits

D_BITS = 22


def run() -> list[Row]:
    train, test = bench_dataset(n=512, D=2**D_BITS, avg_nnz=192, seed=5)
    rows: list[Row] = []
    b = 8
    for k in (32, 128):
        # b-bit minwise at k*b bits/example
        fam = Hash2U.create(jax.random.PRNGKey(k), k, D_BITS)
        s_tr = lowest_bits(minhash_signatures(train.indices, train.mask,
                                              fam), b)
        s_te = lowest_bits(minhash_signatures(test.indices, test.mask,
                                              fam), b)
        t0 = time.perf_counter()
        acc_bbit = train_svm_accuracy(s_tr, train.labels, s_te, test.labels,
                                      k, b)
        t_bbit = (time.perf_counter() - t0) * 1e6
        bits = storage_bits(k, b)

        # VW with the same number of hashed values (k bins) -- the paper's
        # equal-k comparison (VW stores counts, i.e. more bits per value)
        m_bits = max(2, (k - 1).bit_length())
        vw = VWHasher.create(jax.random.PRNGKey(k + 1), m_bits, mode="u2")
        x_tr, x_te = vw(train.indices, train.mask), vw(test.indices,
                                                       test.mask)
        t0 = time.perf_counter()
        acc_vw = train_dense_accuracy(x_tr, train.labels, x_te, test.labels)
        t_vw = (time.perf_counter() - t0) * 1e6
        rows.append((f"fig10_12/k{k}", 0.0, {
            "acc_bbit": round(acc_bbit, 4), "acc_vw": round(acc_vw, 4),
            "bbit_bits_per_ex": bits,
            "vw_bits_per_ex": vw_storage_bits(1 << m_bits),
            "train_us_bbit": round(t_bbit, 0),
            "train_us_vw": round(t_vw, 0)}))
    return rows
