"""Paper Figure 5: VW feature hashing -- full randomness vs 2U hashing.

Claim: test accuracies are essentially unaffected by replacing fully
random hash tables with the 2U scheme (for both SVM and logistic).
"""

from __future__ import annotations

import jax

from benchmarks.common import Row, bench_dataset, train_dense_accuracy
from repro.core import VWHasher

D_BITS = 18


def run() -> list[Row]:
    train, test = bench_dataset(n=512, D=2**D_BITS, avg_nnz=128)
    rows: list[Row] = []
    for m_bits in (8, 10, 12):
        accs = {}
        for kind in ("svm", "logistic"):
            for mode in ("full", "u2"):
                vw = VWHasher.create(jax.random.PRNGKey(m_bits), m_bits,
                                     mode=mode, D=2**D_BITS)
                x_tr = vw(train.indices, train.mask)
                x_te = vw(test.indices, test.mask)
                accs[f"{kind}_{mode}"] = round(train_dense_accuracy(
                    x_tr, train.labels, x_te, test.labels, kind=kind), 4)
        rows.append((f"fig5/m2e{m_bits}", 0.0, {
            **accs,
            "svm_gap": round(abs(accs["svm_full"] - accs["svm_u2"]), 4),
            "logistic_gap": round(abs(accs["logistic_full"]
                                      - accs["logistic_u2"]), 4)}))
    return rows
