"""Paper Appendix A, Figures 20-22: resemblance-estimation MSE with 2U
hashing vs the theoretical variance (Eq. 11 of [26]), across D.

Claim: for sparse data the empirical MSE matches theory already at
D=2^16; denser pairs (OF-AND) need D >= 2^20.  We sweep the Table-5 word
pairs (reconstructed with their exact f1, f2, R) over D in {2^16, 2^20}.
"""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import Row
from repro.core import (Hash2U, empirical_p_hat, estimate_resemblance,
                        lowest_bits, minhash_signatures,
                        theoretical_variance)
from repro.data import TABLE5_PAIRS, word_pair_sets
from repro.data.sparse import from_lists

K = 128
N_REP = 25
PAIRS = [p for p in TABLE5_PAIRS if p[0] in
         ("KONG-HONG", "OF-AND", "SAN-FRANCISCO", "A-TEST")]


def run() -> list[Row]:
    rows: list[Row] = []
    for name, f1, f2, R in PAIRS:
        for d_bits in (16, 20):
            D = 2 ** d_bits
            if f1 + f2 > D // 2:     # pair too dense for this universe
                continue
            s1, s2 = word_pair_sets(D, f1, f2, R, seed=13)
            true_r = (len(np.intersect1d(s1, s2))
                      / len(np.union1d(s1, s2)))
            batch = from_lists([s1, s2])
            for b in (1, 4):
                errs = []
                for rep in range(N_REP):
                    fam = Hash2U.create(jax.random.PRNGKey(rep * 7 + b),
                                        K, d_bits)
                    sig = lowest_bits(minhash_signatures(
                        batch.indices, batch.mask, fam), b)
                    p_hat = float(empirical_p_hat(sig[0], sig[1]))
                    errs.append(float(estimate_resemblance(
                        p_hat, f1, f2, D, b)) - true_r)
                mse = float(np.mean(np.square(errs)))
                var_th = float(theoretical_variance(true_r, f1, f2, D, b, K))
                rows.append((f"fig20_22/{name}_D2e{d_bits}_b{b}", 0.0, {
                    "mse": round(mse, 6), "theory": round(var_th, 6),
                    "ratio": round(mse / max(var_th, 1e-12), 2)}))
    return rows
