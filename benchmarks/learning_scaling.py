"""Paper Figures 6-9: accuracy and train time vs (k, b) on the rcv1-like
(large-D) dataset, SVM + logistic.

Paper claim: k=30, b=12 already >90%; k >= 300 reaches >95%; training
time grows mildly with k*2^b.
"""

from __future__ import annotations

import time

import jax

from benchmarks.common import Row, bench_dataset, train_svm_accuracy
from repro.core import Hash2U, lowest_bits, minhash_signatures

D_BITS = 26    # large-D regime (rcv1-like, far beyond permutation storage)


def run() -> list[Row]:
    train, test = bench_dataset(n=512, D=2**D_BITS, avg_nnz=256, seed=3)
    rows: list[Row] = []
    for k in (16, 64, 256):
        for b in (4, 8, 12):
            fam = Hash2U.create(jax.random.PRNGKey(k + b), k, D_BITS)
            s_tr = lowest_bits(
                minhash_signatures(train.indices, train.mask, fam), b)
            s_te = lowest_bits(
                minhash_signatures(test.indices, test.mask, fam), b)
            t0 = time.perf_counter()
            acc = train_svm_accuracy(s_tr, train.labels, s_te, test.labels,
                                     k, b)
            dt = (time.perf_counter() - t0) * 1e6
            rows.append((f"fig6_9/k{k}_b{b}", dt, {
                "acc": round(acc, 4), "model_dims": k * (1 << b)}))
    return rows
