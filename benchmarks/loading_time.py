"""Paper Figures 16, 18 + Table 4: per-epoch loading + training time,
original vs b-bit hashed data.

Claim (Table 4): training on the original data costs ~10x (webspam) /
~29x (rcv1) the hashed-data cost, and loading dominates -- the whole
point of using b-bit hashing for online learning.  We measure real disk
round-trips per epoch for both representations (binary format both, per
the paper's methodology note).
"""

from __future__ import annotations

import functools
import os
import tempfile
import time

import jax
import numpy as np

from benchmarks.common import Row, bench_dataset
from repro.core import Hash2U, lowest_bits, minhash_signatures
from repro.models.linear import sgd_svm_init, sgd_svm_step
from repro.train import online_epochs

D_BITS = 20
K, B = 128, 8


def run() -> list[Row]:
    train, _ = bench_dataset(n=512, D=2**D_BITS, avg_nnz=256, seed=9)
    fam = Hash2U.create(jax.random.PRNGKey(0), K, D_BITS)
    sig = np.asarray(lowest_bits(
        minhash_signatures(train.indices, train.mask, fam), B), np.uint8)
    labels = np.asarray(train.labels)

    tmp = tempfile.mkdtemp(prefix="repro_loading_")
    orig_path = os.path.join(tmp, "orig.npz")
    idx = np.asarray(train.indices)
    msk = np.asarray(train.mask)
    np.savez(orig_path, indices=idx, mask=msk, labels=labels)
    hash_path = os.path.join(tmp, "hashed.npz")
    np.savez(hash_path, sig=sig, labels=labels)

    size_orig = os.path.getsize(orig_path)
    size_hash = os.path.getsize(hash_path)

    step = jax.jit(functools.partial(sgd_svm_step, lam=1e-4, eta0=0.5, b=B))
    st = sgd_svm_init(K * (1 << B))

    def hashed_epoch_batches():
        with np.load(hash_path) as z:       # loaded from disk every epoch
            s, y = z["sig"], z["labels"]
        for i in range(0, len(y), 64):
            yield (jax.numpy.asarray(s[i:i + 64], jax.numpy.uint32),
                   jax.numpy.asarray(y[i:i + 64]))

    st, times_h, _ = online_epochs(
        lambda state, batch: step(state, batch[0], batch[1]),
        st, hashed_epoch_batches, 3)

    def epoch_load(path, keys):
        t0 = time.perf_counter()
        with np.load(path) as z:
            arrs = [np.array(z[k]) for k in keys]   # force full read
        return time.perf_counter() - t0

    load_orig_s = float(np.median(
        [epoch_load(orig_path, ("indices", "mask", "labels"))
         for _ in range(5)]))
    load_hash_s = float(np.median(
        [epoch_load(hash_path, ("sig", "labels")) for _ in range(5)]))

    return [
        ("table4/storage", 0.0, {
            "orig_bytes": size_orig, "hashed_bytes": size_hash,
            "reduction_x": round(size_orig / size_hash, 1)}),
        ("table4/loading", 0.0, {
            "orig_epoch_s": round(load_orig_s, 4),
            "hashed_epoch_s": round(load_hash_s, 4),
            "ratio": round(load_orig_s / max(load_hash_s, 1e-9), 1),
            "paper_webspam_ratio": 8.95, "paper_rcv1_ratio": 29.07}),
        ("fig16/train_s_per_epoch_hashed", 0.0, {
            "train_s": round(float(np.median([t.train_s for t in times_h])),
                             4)}),
    ]
