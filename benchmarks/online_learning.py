"""Paper Figures 13-15 + 19: online SGD/ASGD accuracy vs epochs, original
vs b-bit hashed data.

Claims: (i) ~20 epochs suffice on hashed data for near-final accuracy;
(ii) b >= 8, k >= 200 matches the original-data accuracy; (iii) ASGD
improves on SGD but still needs ~10-20 epochs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import Row, bench_dataset
from repro.core import Hash2U, lowest_bits, minhash_signatures
from repro.data.sparse import to_dense
from repro.models.linear import (accuracy, asgd_model, sgd_svm_init,
                                 sgd_svm_step)

D_BITS = 16
K, B = 128, 8


def run() -> list[Row]:
    train, test = bench_dataset(n=512, D=2**D_BITS, avg_nnz=96, seed=7)
    fam = Hash2U.create(jax.random.PRNGKey(0), K, D_BITS)
    s_tr = lowest_bits(minhash_signatures(train.indices, train.mask, fam), B)
    s_te = lowest_bits(minhash_signatures(test.indices, test.mask, fam), B)
    x_tr, x_te = to_dense(train, 2**D_BITS), to_dense(test, 2**D_BITS)

    rows: list[Row] = []
    lam, eta0, bs = 1e-4, 0.5, 16

    def epochs_curve(feature_kind, feats_tr, feats_te, average):
        st = sgd_svm_init(K * (1 << B) if feature_kind == "hashed"
                          else feats_tr.shape[1])
        step = jax.jit(functools.partial(
            sgd_svm_step, lam=lam, eta0=eta0, b=B,
            feature_kind=feature_kind, average=average))
        accs = []
        for ep in range(20):
            for i in range(0, feats_tr.shape[0], bs):
                st = step(st, feats_tr[i:i + bs], train.labels[i:i + bs])
            model = asgd_model(st) if average else st.model
            accs.append(float(accuracy(model, feats_te, test.labels,
                                       feature_kind=feature_kind, b=B)))
        return accs

    acc_orig = epochs_curve("dense", x_tr, x_te, False)
    acc_hash = epochs_curve("hashed", s_tr, s_te, False)
    acc_asgd = epochs_curve("hashed", s_tr, s_te, True)
    rows.append(("fig14/final_acc", 0.0, {
        "orig": round(acc_orig[-1], 4), "hashed": round(acc_hash[-1], 4),
        "gap": round(abs(acc_orig[-1] - acc_hash[-1]), 4)}))
    rows.append(("fig15/epochs_to_95pct_of_final", 0.0, {
        "hashed": _epochs_to(acc_hash), "orig": _epochs_to(acc_orig)}))
    rows.append(("fig19/asgd_vs_sgd", 0.0, {
        "sgd_ep5": round(acc_hash[4], 4), "asgd_ep5": round(acc_asgd[4], 4),
        "sgd_final": round(acc_hash[-1], 4),
        "asgd_final": round(acc_asgd[-1], 4)}))
    return rows


def _epochs_to(curve, frac=0.95):
    target = frac * max(curve)
    for i, a in enumerate(curve):
        if a >= target:
            return i + 1
    return len(curve)
