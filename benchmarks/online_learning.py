"""Paper Figures 13-15 + 19: online SGD/ASGD accuracy vs epochs, original
vs b-bit hashed data -- now driven by the fused ``repro.train.online``
subsystem (epoch 0 hashes and caches, epochs >= 1 replay packed shards).

Claims: (i) ~20 epochs suffice on hashed data for near-final accuracy;
(ii) b >= 8, k >= 200 matches the original-data accuracy; (iii) ASGD
improves on SGD but still needs ~10-20 epochs; plus the Table-4 point
that the cached hashed replay costs far less than the hashing epoch.
"""

from __future__ import annotations

import functools
import tempfile

import jax
import numpy as np

from benchmarks.common import Row, bench_dataset
from repro.data.pipeline import SignatureStream, batch_to_shards
from repro.data.sparse import to_dense
from repro.kernels import batch_signatures
from repro.models.linear import accuracy, sgd_svm_init, sgd_svm_step
from repro.train import OnlineTrainer, SignatureCache, make_family

D_BITS = 16
K, B = 128, 8
EPOCHS = 20


def run() -> list[Row]:
    train, test = bench_dataset(n=512, D=2**D_BITS, avg_nnz=96, seed=7)
    shard_paths = batch_to_shards(train,
                                  tempfile.mkdtemp(prefix="repro_online_"))
    family = make_family(jax.random.PRNGKey(0), "2u", K, D_BITS)
    sig_te = batch_signatures(test, family, b=B)
    x_tr, x_te = to_dense(train, 2**D_BITS), to_dense(test, 2**D_BITS)

    lam, eta0, bs = 1e-4, 0.5, 16

    # hashed curves via the streaming subsystem; one shared cache means the
    # second trainer replays from epoch 0 (only the first pays the hash).
    cache = SignatureCache(SignatureStream(shard_paths, family, b=B,
                                           chunk_size=128))
    curves = {}
    hash_stats = None
    for name, average in [("sgd", False), ("asgd", True)]:
        tr = OnlineTrainer(k=K, b=B, average=average, lam=lam, eta0=eta0,
                           batch_size=bs)
        _, stats, evals = tr.fit(
            cache, EPOCHS, eval_fn=lambda t: t.evaluate(sig_te, test.labels))
        curves[name] = evals
        if name == "sgd":           # the only run that pays the hash epoch
            hash_stats = stats

    # original-data baseline: dense features, same SGD update
    def dense_curve():
        st = sgd_svm_init(x_tr.shape[1])
        step = jax.jit(functools.partial(sgd_svm_step, lam=lam, eta0=eta0,
                                         b=B, feature_kind="dense",
                                         average=False))
        accs = []
        for _ in range(EPOCHS):
            for i in range(0, x_tr.shape[0], bs):
                st = step(st, x_tr[i:i + bs], train.labels[i:i + bs])
            accs.append(float(accuracy(st.model, x_te, test.labels,
                                       feature_kind="dense")))
        return accs

    acc_orig = dense_curve()
    acc_hash, acc_asgd = curves["sgd"], curves["asgd"]
    epoch0 = hash_stats[0]
    replays = hash_stats[1:]
    mean_replay_load = float(np.mean([s.load_s for s in replays]))

    return [
        ("fig14/final_acc", 0.0, {
            "orig": round(acc_orig[-1], 4), "hashed": round(acc_hash[-1], 4),
            "gap": round(abs(acc_orig[-1] - acc_hash[-1]), 4)}),
        ("fig15/epochs_to_95pct_of_final", 0.0, {
            "hashed": _epochs_to(acc_hash), "orig": _epochs_to(acc_orig)}),
        ("fig19/asgd_vs_sgd", 0.0, {
            "sgd_ep5": round(acc_hash[4], 4), "asgd_ep5": round(acc_asgd[4], 4),
            "sgd_final": round(acc_hash[-1], 4),
            "asgd_final": round(acc_asgd[-1], 4)}),
        ("fig16/epoch_seconds", 0.0, {
            "hash_epoch_load_s": round(epoch0.load_s, 4),
            "cache_epoch_load_s": round(mean_replay_load, 4),
            "load_speedup_x": round(epoch0.load_s
                                    / max(mean_replay_load, 1e-9), 1)}),
        ("table2/online_storage", 0.0, {
            "orig_bytes": cache.stats.bytes_original,
            "hashed_bytes": cache.stats.bytes_cached,
            "reduction_x": round(cache.stats.reduction(), 1)}),
    ]


def _epochs_to(curve, frac=0.95):
    target = frac * max(curve)
    for i, a in enumerate(curve):
        if a >= target:
            return i + 1
    return len(curve)
