"""Continuous-batching serving benchmark: the ``SearchServer`` under
open-loop Zipf/Poisson traffic -- multi-worker dispatch, admission
control, live appends, and a roofline gap per load level.

The serving-lane claims, measured end to end on a synthetic sharded
corpus:

  * p50/p99 end-to-end latency, queue-wait, achieved q/s, deadline-miss
    rate, shed rate, and per-worker occupancy at several offered loads
    (Poisson arrivals, Zipf-popular query ids) through the
    deadline-aware micro-batching dispatch loop,
  * the same load served by ONE dispatch worker vs a worker pool
    (``serving/multiworker_speedup``): overlapped flushes must beat the
    single thread at the same offered load, with results bit-identical
    either way (when >1 JAX device is present the router is placed on a
    ``("data",)`` mesh, so worker flushes land on the collective
    ``shard_map`` dispatch),
  * one deliberately unserveable load (``serving/overload_shed``)
    driving the bounded-queue ``shed-oldest`` admission policy: the
    server must shed instead of deadlocking, and every NON-shed request
    still meets its deadline,
  * an open-loop run while a concurrent appender thread grows the last
    shard via ``ShardedIndex.append`` and the server's per-flush
    ``refresh`` picks the growth up live,
  * micro-batched results checked bit-identical per query to a direct
    ``search`` call on the same searcher (single- AND multi-worker),
  * predicted vs measured bytes/flush for the exact hamming scan
    (``repro.roofline.search``): each load row carries the memory-bound
    prediction and the measured roofline gap, the autotuning lane's
    steering metric,
  * the cost of the observability layer itself
    (``serving/instrumentation_overhead``): the same closed-loop run
    with tracing+metrics enabled vs bare, median of 3 interleaved runs
    each -- the instrumented server must stay within 2% q/s of bare,
  * the cost of the fault-tolerance layer
    (``serving/resilience_overhead``): the same closed-loop fan-out
    with every shard client wrapped in ``ResilientShardClient`` vs the
    bare local clients, median of 3 interleaved -- the healthy path
    must stay within 3% q/s of bare,
  * degraded serving under injected chaos (``serving/chaos_*pct``):
    seeded ``ChaosShardClient`` faults (latency / OSError / hang /
    drop) at 0% / 10% / 25% per-dispatch fault rates through a
    partial-mode server -- reporting availability, achieved q/s, and
    mean coverage; every request must resolve.

``--json PATH`` writes the rows as a JSON artifact (uploaded by the
slow-tier AND the multidevice CI jobs next to ``search_scaling.json``).
``--chaos-json PATH`` writes just the resilience/chaos rows (the CI
chaos artifact).
``--metrics-port P`` serves the live ``repro.obs`` registry over HTTP
while the benchmark runs; ``--prom-out PATH`` saves the last good
Prometheus scrape (taken by a background scraper thread, i.e. a real
scrape under load, falling back to a direct registry dump);
``--trace-out PATH`` enables the global tracer and writes the
Perfetto-loadable trace-event JSON on exit.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys
import tempfile
import threading
import time

import jax
import numpy as np

from benchmarks.common import Row, fmt_rows
from repro.data.pipeline import make_sharded_dataset
from repro.data.preprocess import preprocess_shards
from repro.data.synthetic import DatasetSpec
from repro.index import build_sharded, choose_band_config, load_sharded
from repro.launch.server import RequestShed, SearchServer, ZipfianTraffic
from repro.roofline.search import exact_scan_cost, roofline_gap
from repro.train.online import make_family

D_BITS = 16
K, B = 128, 8
N_DOCS = 2048
N_SHARDS = 2
N_APPEND_SHARDS = 3
CORPUS_BLOCK = 512
TOPK = 10
MAX_BATCH = 8
MAX_DELAY_S = 0.002
RATES_QPS = (200.0, 2000.0)
N_REQUESTS = 192
MULTI_WORKERS = 4
OVERLOAD_QPS = 50_000.0          # >> capacity: forces the shedding path
OVERLOAD_QUEUE = 32
OVERLOAD_DEADLINE_S = 2.0
CHAOS_RATES = (0.0, 0.10, 0.25)  # injected per-dispatch fault rates
CHAOS_REQUESTS = 96
CHAOS_DEADLINE_S = 0.1           # per-attempt; an injected hang blows it
CHAOS_HANG_S = 0.4


def _build_sigs(tmp: str, name: str, n: int, seed: int) -> list:
    spec = DatasetSpec(name, n=n, D=2**D_BITS, avg_nnz=64,
                       n_prototypes=8, overlap=0.8, seed=seed)
    fam = make_family(jax.random.PRNGKey(0), "oph", K, D_BITS,
                      densify="rotation")
    raw = make_sharded_dataset(spec, os.path.join(tmp, f"raw_{name}"),
                               n_shards=4)
    preprocess_shards(raw, os.path.join(tmp, f"sig_{name}"), fam, b=B,
                      chunk_size=max(128, n // 4),
                      loader_kwargs={"lane_multiple": 8})
    return sorted(glob.glob(os.path.join(tmp, f"sig_{name}", "*.sig")))


def _row_reader(router):
    offsets = list(router.offsets) + [router.n]

    def words_of(i: int) -> np.ndarray:
        shard = int(np.searchsorted(offsets, i, side="right")) - 1
        return np.asarray(router.searchers[shard]
                          .index.words_host[i - int(offsets[shard])])
    return words_of


def _warmup(router, words_of) -> None:
    """Compile every query-batch shape a flush can produce (1..MAX_BATCH),
    so the timed open-loop runs measure serving, not tracing."""
    for nq in range(1, MAX_BATCH + 1):
        q = np.stack([words_of(i % router.n) for i in range(nq)])
        router.search(q, TOPK, mode="exact")


def _drive(router, words_of, n_docs: int, rate: float, m: int, seed: int,
           *, workers: int = 1, admission: str = "none",
           max_queue=None, deadline_s=None) -> dict:
    """One open-loop run: m Zipf queries at Poisson rate; returns the
    server's stats snapshot + achieved q/s (served requests over wall
    clock -- shed traffic does not count as served)."""
    traffic = ZipfianTraffic(n_docs, alpha=1.1, seed=seed)
    ids = traffic.ids(m)
    arrivals = traffic.arrival_offsets(m, rate)
    server = SearchServer(router, max_batch=MAX_BATCH,
                          max_delay_s=MAX_DELAY_S, topk=TOPK, mode="exact",
                          num_workers=workers, admission=admission,
                          max_queue=max_queue)
    with server:
        t_start = time.monotonic()
        handles = []
        for doc, at in zip(ids, arrivals):
            lag = at - (time.monotonic() - t_start)
            if lag > 0:
                time.sleep(lag)
            handles.append(server.submit(words_of(int(doc)),
                                         deadline_s=deadline_s))
        for h in handles:
            try:
                h.result(timeout=120.0)
            except RequestShed:
                pass                             # accounted in snap["shed"]
        elapsed = time.monotonic() - t_start
    snap = server.stats.snapshot()
    snap["achieved_qps"] = snap["requests"] / elapsed
    return snap


def _closed_loop_qps(router, words_of, n_docs: int, m: int,
                     tracer, registry) -> float:
    """Closed-loop throughput through one dispatch worker: submit m
    requests back to back, wait for all; q/s over wall clock.  The
    tracer/registry are injected so the instrumentation-overhead row can
    compare enabled vs disabled on otherwise identical servers."""
    server = SearchServer(router, max_batch=MAX_BATCH,
                          max_delay_s=MAX_DELAY_S, topk=TOPK, mode="exact",
                          num_workers=1, registry=registry, tracer=tracer)
    with server:
        t0 = time.monotonic()
        handles = [server.submit(words_of(i % n_docs)) for i in range(m)]
        for h in handles:
            h.result(timeout=120.0)
        return m / (time.monotonic() - t0)


def _router_closed_qps(router, words_of, m: int) -> float:
    """Closed-loop fan-out throughput straight through the router (no
    server): MAX_BATCH-query batches back to back, q/s over wall clock.
    Used to price the resilience wrapper on the healthy path."""
    n = router.n
    t0 = time.monotonic()
    done = 0
    while done < m:
        nq = min(MAX_BATCH, m - done)
        q = np.stack([words_of((done + j) % n) for j in range(nq)])
        router.search(q, TOPK, mode="exact")
        done += nq
    return m / (time.monotonic() - t0)


def _chaos_row(shard_dir: str, fault_frac: float, seed: int) -> dict:
    """One degraded-serving run: a partial-mode server over resilient +
    chaos-wrapped sequential clients at the given per-dispatch fault
    rate.  Returns availability / q/s / coverage accounting."""
    from repro.index import ChaosSchedule, ResiliencePolicy
    from repro.index import resilient_client_factory

    policy = ResiliencePolicy(deadline_s=CHAOS_DEADLINE_S, max_retries=1,
                              backoff_base_s=0.001, backoff_cap_s=0.01)
    chaos = None
    if fault_frac > 0.0:
        chaos = lambda i: ChaosSchedule(seed=seed + i,
                                        fault_rate=fault_frac,
                                        latency_s=0.002,
                                        hang_s=CHAOS_HANG_S)
    # warm the jit caches through a plain router first: a cold compile
    # takes seconds and would blow every per-attempt deadline below
    plain = load_sharded(shard_dir, dispatch="sequential",
                         corpus_block=CORPUS_BLOCK)
    _warmup(plain, _row_reader(plain))
    fac = resilient_client_factory(policy, chaos=chaos, seed=seed)
    router = load_sharded(shard_dir, dispatch="sequential",
                          corpus_block=CORPUS_BLOCK, client_factory=fac,
                          on_shard_failure="partial")
    words_of = _row_reader(router)
    n = router.n
    resolved = errors = 0
    coverages = []
    server = SearchServer(router, max_batch=MAX_BATCH,
                          max_delay_s=MAX_DELAY_S, topk=TOPK,
                          mode="exact", num_workers=2,
                          on_shard_failure="partial")
    with server:
        t0 = time.monotonic()
        handles = [server.submit(words_of(i % n))
                   for i in range(CHAOS_REQUESTS)]
        for h in handles:
            try:
                res = h.result(timeout=120.0)
                resolved += 1
                coverages.append(float(res.coverage))
            except Exception:
                errors += 1
        elapsed = time.monotonic() - t0
    snap = server.stats.snapshot()
    faults = sum(sum(1 for _, k in c.fault_log if k is not None)
                 for c in fac.chaos_clients)
    return {
        "fault_rate": fault_frac,
        "availability": round(resolved / CHAOS_REQUESTS, 4),
        "achieved_qps": round(resolved / elapsed, 1),
        "mean_coverage": round(float(np.mean(coverages)), 4)
        if coverages else 0.0,
        "requests": CHAOS_REQUESTS,
        "resolved": resolved,
        "errors": errors,
        "partial": snap["partial"],
        "worker_restarts": snap["worker_restarts"],
        "injected_faults": faults,
    }


def _load_fields(snap: dict, n_docs: int, words: int) -> dict:
    """The shared per-load row payload: latency/throughput, admission
    outcomes, per-worker occupancy, and the roofline comparison for the
    measured mean flush."""
    q = max(1, int(round(snap["mean_batch"])))
    cost = exact_scan_cost(n_docs, words, q, topk=TOPK)
    gap = roofline_gap(cost["bytes"], snap["flush_p50_ms"] / 1e3)
    return {
        "achieved_qps": round(snap["achieved_qps"], 1),
        "latency_p50_ms": round(snap["latency_p50_ms"], 3),
        "latency_p99_ms": round(snap["latency_p99_ms"], 3),
        "queue_wait_p50_ms": round(snap["queue_wait_p50_ms"], 3),
        "flush_p50_ms": round(snap["flush_p50_ms"], 3),
        "mean_batch": round(snap["mean_batch"], 2),
        "flush_full": snap["flush_full"],
        "flush_aged": snap["flush_aged"],
        "requests": snap["requests"],
        "workers": snap["workers"],
        "deadline_miss_rate": round(snap["deadline_miss_rate"], 4),
        "shed_rate": round(snap["shed_rate"], 4),
        "worker_occupancy": [round(o, 3)
                             for o in snap["worker_occupancy"]],
        "predicted_bytes_per_flush": int(cost["bytes"]),
        "roofline_predicted_flush_us": round(gap["predicted_s"] * 1e6, 3),
        "roofline_gap": round(gap["gap"], 1),
        "achieved_gbps": round(gap["achieved_gbps"], 3),
    }


def run() -> list[Row]:
    rows: list[Row] = []
    cfg = choose_band_config(K, B, threshold=0.5)
    with tempfile.TemporaryDirectory(prefix="repro_search_serving_") as tmp:
        sig_paths = _build_sigs(tmp, "corpus", N_DOCS, seed=0)
        extra_sigs = _build_sigs(tmp, "extra", N_DOCS // 4, seed=9)
        shard_dir = os.path.join(tmp, "shards")
        build_sharded(sig_paths, shard_dir, cfg, n_shards=N_SHARDS)
        mesh = None
        if len(jax.devices()) > 1:
            # multidevice CI tier: place shards on the mesh so every
            # worker flush runs the collective shard_map dispatch
            from repro.launch.mesh import make_debug_mesh
            mesh = make_debug_mesh(min(N_SHARDS, len(jax.devices())),
                                   axes=("data",))
        router = load_sharded(shard_dir, mesh=mesh,
                              corpus_block=CORPUS_BLOCK)
        words_of = _row_reader(router)
        n0 = router.n
        words = int(router.searchers[0].index.words_host.shape[1])
        _warmup(router, words_of)

        # -- micro-batched == direct (bit-identity), both worker counts --
        rng = np.random.default_rng(3)
        picks = rng.integers(0, n0, 16)
        direct = router.search(
            np.stack([words_of(int(i)) for i in picks]), TOPK, mode="exact")
        identical = {}
        for nw in (1, MULTI_WORKERS):
            with SearchServer(router, max_batch=MAX_BATCH,
                              max_delay_s=MAX_DELAY_S, topk=TOPK,
                              mode="exact", num_workers=nw) as srv:
                served = [srv.submit(words_of(int(i))) for i in picks]
                served = [h.result(timeout=120.0) for h in served]
            identical[nw] = all(
                np.array_equal(res.indices[0], direct.indices[j])
                and np.array_equal(res.scores[0], direct.scores[j])
                for j, res in enumerate(served))
        rows.append(("serving/bit_identical", 0.0, {
            "queries": len(picks), "workers_checked": [1, MULTI_WORKERS],
            "acceptance": "micro-batched results == direct search(), "
                          "single- and multi-worker",
            "ok": bool(identical[1] and identical[MULTI_WORKERS])}))

        # -- instrumentation overhead: tracing must stay off the hot path
        from repro.obs.metrics import MetricsRegistry
        from repro.obs.trace import Tracer
        m_over = 256
        _closed_loop_qps(router, words_of, n0, m_over,
                         Tracer(enabled=False), MetricsRegistry())  # warm
        bare, instr = [], []
        for _ in range(3):                  # interleave to share drift
            bare.append(_closed_loop_qps(
                router, words_of, n0, m_over,
                Tracer(enabled=False), MetricsRegistry()))
            instr.append(_closed_loop_qps(
                router, words_of, n0, m_over,
                Tracer(enabled=True), MetricsRegistry()))
        bare_qps, instr_qps = sorted(bare)[1], sorted(instr)[1]
        overhead = 1.0 - instr_qps / bare_qps
        rows.append(("serving/instrumentation_overhead", 0.0, {
            "bare_qps": round(bare_qps, 1),
            "instrumented_qps": round(instr_qps, 1),
            "overhead_frac": round(overhead, 4),
            "requests_per_run": m_over, "runs_each": 3,
            "acceptance": "full tracing + metrics registry cost < 2% "
                          "q/s vs a bare server (median of 3)",
            "ok": bool(overhead < 0.02)}))

        # -- latency/throughput vs offered load, 1 vs N workers ----------
        qps_by_workers = {}
        for rate in RATES_QPS:
            for nw in (1, MULTI_WORKERS):
                snap = _drive(router, words_of, n0, rate, N_REQUESTS,
                              seed=5, workers=nw)
                qps_by_workers[(rate, nw)] = snap["achieved_qps"]
                suffix = "" if nw == 1 else f"_w{nw}"
                rows.append((f"serving/load_{int(rate)}qps{suffix}",
                             snap["latency_p50_ms"] * 1e3,
                             {"offered_qps": rate,
                              **_load_fields(snap, n0, words)}))

        # -- multi-worker speedup at the saturating load -----------------
        rate = max(RATES_QPS)
        single = qps_by_workers[(rate, 1)]
        multi = qps_by_workers[(rate, MULTI_WORKERS)]
        rows.append(("serving/multiworker_speedup", 0.0, {
            "offered_qps": rate,
            "single_worker_qps": round(single, 1),
            "multi_worker_qps": round(multi, 1),
            "workers": MULTI_WORKERS,
            "cpu_cores": os.cpu_count(),     # <2 cores can't overlap
            "speedup": round(multi / single, 3),
            "acceptance": "worker pool outserves one dispatch thread at "
                          "the same offered load, bit-identically",
            "ok": bool(multi > single and identical[MULTI_WORKERS])}))

        # -- overload: bounded queue + shed-oldest must shed, not stall --
        snap = _drive(router, words_of, n0, OVERLOAD_QPS, N_REQUESTS,
                      seed=8, workers=MULTI_WORKERS,
                      admission="shed-oldest", max_queue=OVERLOAD_QUEUE,
                      deadline_s=OVERLOAD_DEADLINE_S)
        rows.append(("serving/overload_shed",
                     snap["latency_p50_ms"] * 1e3, {
                         "offered_qps": OVERLOAD_QPS,
                         "max_queue": OVERLOAD_QUEUE,
                         "deadline_budget_ms": OVERLOAD_DEADLINE_S * 1e3,
                         **_load_fields(snap, n0, words),
                         "shed": snap["shed"],
                         "deadline_misses": snap["deadline_misses"],
                         "acceptance": "overload sheds per policy; every "
                                       "non-shed request meets its "
                                       "deadline; nothing deadlocks",
                         "ok": bool(snap["shed"] > 0
                                    and snap["requests"] + snap["shed"]
                                    == N_REQUESTS
                                    and snap["deadline_misses"] == 0)}))

        # -- serving while a concurrent appender grows the index ---------
        stop = threading.Event()
        appended = []

        def appender():
            for sig in extra_sigs[:N_APPEND_SHARDS]:
                if stop.is_set():
                    return
                router.append([sig])
                appended.append(router.n)
                time.sleep(0.02)

        t = threading.Thread(target=appender)
        t.start()
        try:
            snap = _drive(router, words_of, n0, RATES_QPS[0],
                          N_REQUESTS, seed=6)
        finally:
            stop.set()
            t.join()
        router.refresh()
        grew = router.n > n0
        rows.append(("serving/with_live_appends",
                     snap["latency_p50_ms"] * 1e3, {
                         "offered_qps": RATES_QPS[0],
                         "achieved_qps": round(snap["achieved_qps"], 1),
                         "latency_p50_ms": round(snap["latency_p50_ms"], 3),
                         "latency_p99_ms": round(snap["latency_p99_ms"], 3),
                         "docs_before": n0, "docs_after": router.n,
                         "appends": len(appended),
                         "requests": snap["requests"],
                         "errors": snap["errors"],
                         "acceptance": "all requests served while the "
                                       "corpus grows under the reader",
                         "ok": bool(grew and snap["errors"] == 0
                                    and snap["requests"] == N_REQUESTS)}))

        # -- resilience wrapper price on the healthy path ----------------
        from repro.index import ResiliencePolicy, resilient_client_factory
        bare_r = load_sharded(shard_dir, dispatch="sequential",
                              corpus_block=CORPUS_BLOCK)
        res_r = load_sharded(
            shard_dir, dispatch="sequential", corpus_block=CORPUS_BLOCK,
            client_factory=resilient_client_factory(ResiliencePolicy()))
        wb, wr = _row_reader(bare_r), _row_reader(res_r)
        _warmup(bare_r, wb)
        _warmup(res_r, wr)
        picks = np.random.default_rng(12).integers(0, bare_r.n, 8)
        q = np.stack([wb(int(i)) for i in picks])
        a, b = bare_r.search(q, TOPK), res_r.search(q, TOPK)
        same = bool(np.array_equal(a.indices, b.indices)
                    and np.array_equal(a.scores, b.scores))
        m_res = 256
        bare_q, res_q = [], []
        for _ in range(3):                  # interleave to share drift
            bare_q.append(_router_closed_qps(bare_r, wb, m_res))
            res_q.append(_router_closed_qps(res_r, wr, m_res))
        bq, rq = sorted(bare_q)[1], sorted(res_q)[1]
        overhead = 1.0 - rq / bq
        rows.append(("serving/resilience_overhead", 0.0, {
            "bare_qps": round(bq, 1),
            "resilient_qps": round(rq, 1),
            "overhead_frac": round(overhead, 4),
            "bit_identical": same,
            "requests_per_run": m_res, "runs_each": 3,
            "acceptance": "healthy-path ResilientShardClient fan-out "
                          "bit-identical and within 3% q/s of bare "
                          "local clients (median of 3)",
            "ok": bool(same and overhead < 0.03)}))

        # -- degraded serving under injected chaos -----------------------
        # (keep these LAST: the prom scrape retained at exit must still
        # see the live partial-mode servers' serve_* collectors)
        for j, frac in enumerate(CHAOS_RATES):
            fields = _chaos_row(shard_dir, frac, seed=17 + 31 * j)
            ok = fields["resolved"] == CHAOS_REQUESTS
            if frac == 0.0:
                ok = (ok and fields["errors"] == 0
                      and fields["mean_coverage"] == 1.0)
            rows.append((f"serving/chaos_{int(round(frac * 100))}pct",
                         0.0, {
                             **fields,
                             "acceptance": "every request resolves under "
                                           "seeded injected faults; "
                                           "partial-mode coverage "
                                           "accounted",
                             "ok": bool(ok)}))
    return rows


class _Scraper(threading.Thread):
    """Background thread that keeps re-scraping /metrics while the
    benchmark runs, keeping the LAST GOOD body -- so ``--prom-out`` is a
    real scrape taken under serving load, not a post-mortem dump."""

    def __init__(self, url: str, period_s: float = 0.25):
        super().__init__(daemon=True)
        self.url = url
        self.period_s = period_s
        self.last: str = ""
        self.scrapes = 0
        # NB: not named _stop -- that would shadow threading.Thread._stop
        self._halt = threading.Event()

    def run(self) -> None:
        import urllib.request
        while not self._halt.is_set():
            try:
                with urllib.request.urlopen(self.url, timeout=5.0) as r:
                    self.last = r.read().decode("utf-8")
                    self.scrapes += 1
            except OSError:
                pass                       # keep the previous good scrape
            self._halt.wait(self.period_s)

    def stop(self) -> None:
        self._halt.set()
        self.join(timeout=10.0)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as a JSON artifact")
    ap.add_argument("--chaos-json", default=None, metavar="PATH",
                    help="write just the resilience/chaos rows (the CI "
                         "chaos artifact)")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve live Prometheus metrics on this port "
                         "while the benchmark runs (0 = ephemeral)")
    ap.add_argument("--prom-out", default=None, metavar="PATH",
                    help="write the last good /metrics scrape here "
                         "(implies a background scraper when "
                         "--metrics-port is up)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="enable request tracing; write trace-event "
                         "JSON here on exit")
    args = ap.parse_args()

    from repro.obs.metrics import get_registry
    from repro.obs.trace import get_tracer

    exporter = scraper = None
    if args.metrics_port is not None:
        from repro.obs.export import start_http_exporter
        exporter = start_http_exporter(port=args.metrics_port)
        print(f"# metrics: {exporter.url}/metrics", file=sys.stderr)
        if args.prom_out:
            scraper = _Scraper(exporter.url + "/metrics")
            scraper.start()
    if args.trace_out:
        get_tracer().reset(enabled=True)
    try:
        rows = run()
    finally:
        if scraper is not None:
            scraper.stop()
        if args.prom_out:
            text = scraper.last if (scraper and scraper.last) \
                else get_registry().prometheus_text()
            with open(args.prom_out, "w") as f:
                f.write(text)
            print(f"# prom-out: {args.prom_out} "
                  f"({scraper.scrapes if scraper else 0} live scrapes)",
                  file=sys.stderr)
        if args.trace_out:
            n_ev = get_tracer().export(args.trace_out)
            print(f"# trace-out: {args.trace_out} ({n_ev} events)",
                  file=sys.stderr)
        if exporter is not None:
            exporter.close()
    print(fmt_rows(rows))
    if args.json:
        doc = [{"name": name, "us_per_call": us, **derived}
               for name, us, derived in rows]
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2)
    if args.chaos_json:
        doc = [{"name": name, "us_per_call": us, **derived}
               for name, us, derived in rows
               if name.startswith(("serving/chaos_",
                                   "serving/resilience_overhead"))]
        with open(args.chaos_json, "w") as f:
            json.dump(doc, f, indent=2)


if __name__ == "__main__":
    main()
