"""Paper Figure 4: SVM accuracy -- permutations vs 2U vs 4U across (k, b).

Paper claim: for k >= ~200, b >= 4 the three hashing schemes are
indistinguishable; 4U slightly better than 2U only at b=1 / tiny k.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Row, bench_dataset, train_svm_accuracy
from repro.core import (Hash2U, Hash4U, PermutationFamily, lowest_bits,
                        minhash_signatures)

D_BITS = 18


def run() -> list[Row]:
    train, test = bench_dataset(n=512, D=2**D_BITS, avg_nnz=128)
    rows: list[Row] = []
    key = jax.random.PRNGKey(1)
    for k in (32, 128):
        for b in (1, 4, 8):
            accs = {}
            for name, fam in [
                ("perm", PermutationFamily.create(key, k, 2**D_BITS)),
                ("2u", Hash2U.create(key, k, D_BITS)),
                ("4u", Hash4U.create(key, k, D_BITS)),
            ]:
                s_tr = lowest_bits(
                    minhash_signatures(train.indices, train.mask, fam), b)
                s_te = lowest_bits(
                    minhash_signatures(test.indices, test.mask, fam), b)
                accs[name] = train_svm_accuracy(
                    s_tr, train.labels, s_te, test.labels, k, b)
            spread = max(accs.values()) - min(accs.values())
            rows.append((f"fig4/k{k}_b{b}", 0.0, {
                **{f"acc_{n}": round(a, 4) for n, a in accs.items()},
                "spread": round(spread, 4)}))
    return rows
