"""SignatureEngine wire-format benchmark: host-transfer bytes + pack cost.

The §6/Table-2 systems claim, measured on the engine: signatures leave
the device as packed words (k*b bits per example, (b+1)-bit codes for
sentinel OPH), so the host transfer, the cache shards and every replay
epoch pay the paper's bit budget instead of k uint32 lanes.  Reports

  * packed vs unpacked kernel wall time (the pack overhead),
  * host-transfer bytes per example for both paths,
  * replayed ``.sig`` cache payload for sentinel-OPH b=8 against the
    uint32-shard baseline -- the acceptance bound is (b+1)/32.

``--json PATH`` additionally writes the rows as a JSON artifact (the
slow-tier CI job uploads it).
"""

from __future__ import annotations

import argparse
import json
import tempfile

import jax
import numpy as np

from benchmarks.common import Row, bench_dataset, fmt_rows, time_fn
from repro.data.pipeline import SignatureStream, batch_to_shards
from repro.kernels import SignatureEngine
from repro.train import SignatureCache, make_family

D_BITS = 16
K, B = 128, 8
N = 512


def _engine_rows(family, name: str) -> list[Row]:
    train, _ = bench_dataset(n=N, D=2**D_BITS, avg_nnz=96, seed=11)
    unpacked = SignatureEngine(family, b=B)
    packed = SignatureEngine(family, b=B, packed=True)
    t_unpacked = time_fn(lambda: unpacked.signatures(train))
    t_packed = time_fn(lambda: packed.packed_signatures(train).data)
    sig = unpacked.signatures(train)
    wire = packed.packed_signatures(train)
    n = sig.shape[0]
    bytes_unpacked = int(np.asarray(sig).nbytes)
    bytes_packed = wire.nbytes
    return [
        (f"engine/{name}/pack_overhead", t_packed, {
            "unpacked_us": round(t_unpacked, 1),
            "overhead_pct": round(100.0 * (t_packed - t_unpacked)
                                  / max(t_unpacked, 1e-9), 1)}),
        (f"engine/{name}/host_bytes_per_example", 0.0, {
            "unpacked": bytes_unpacked // n,
            "packed": bytes_packed // n,
            "reduction_x": round(bytes_unpacked / max(bytes_packed, 1), 2),
            "code_bits": wire.code_bits}),
    ]


def _cache_rows() -> list[Row]:
    """Replayed sentinel-OPH b=8 cache payload vs the uint32 baseline."""
    train, _ = bench_dataset(n=N, D=2**D_BITS, avg_nnz=96, seed=11)
    with tempfile.TemporaryDirectory(prefix="repro_engine_bench_") as raw_dir:
        shard_paths = batch_to_shards(train, raw_dir)
        fam = make_family(jax.random.PRNGKey(0), "oph", K, D_BITS,
                          densify="sentinel")
        with SignatureCache(SignatureStream(shard_paths, fam, b=B,
                                            chunk_size=128,
                                            packed=True)) as cache:
            for _ in cache:                  # epoch 0: hash + write .sig
                pass
            replayed = 0
            for sig, _ in cache:             # epoch 1: replayed wire bytes
                replayed += sig.nbytes
            n = cache.stats.examples
            baseline = n * K * 4             # uint32 shard payload
            ratio = cache.stats.bytes_payload / baseline
            return [("engine/cache_sentinel_b8/replay_bytes", 0.0, {
                "payload_bytes": cache.stats.bytes_payload,
                "replayed_bytes": replayed,
                "uint32_baseline_bytes": baseline,
                "ratio": round(ratio, 4),
                "bound": round((B + 1) / 32, 4),
                "within_bound": ratio <= (B + 1) / 32,
                "file_bytes": cache.stats.bytes_cached,
                "raw_bytes": cache.stats.bytes_original})]


def run() -> list[Row]:
    key = jax.random.PRNGKey(0)
    rows = []
    rows += _engine_rows(make_family(key, "oph", K, D_BITS,
                                     densify="sentinel"), "oph_sentinel")
    rows += _engine_rows(make_family(key, "2u", K, D_BITS), "minhash_2u")
    rows += _cache_rows()
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as a JSON artifact")
    args = ap.parse_args()
    rows = run()
    print(fmt_rows(rows))
    if args.json:
        doc = [{"name": name, "us_per_call": us, **derived}
               for name, us, derived in rows]
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2)


if __name__ == "__main__":
    main()
