"""One Permutation Hashing vs k-pass minwise hashing preprocessing.

The paper's §3 cost model: minwise preprocessing evaluates k hash
functions per nonzero (k ~ 500).  OPH (Li-Owen-Zhang, NIPS 2012)
evaluates ONE function per nonzero and splits the hashed universe into k
bins, so hash-evaluation counts drop by exactly k at equal signature
length.  This module reports, per (k, scheme):

  * the analytic hash-evaluation count (the §3 cost model; platform
    independent, this is the >= k x reduction the OPH subsystem exists
    for),
  * the kernel-level count (the Pallas OPH kernel re-evaluates its one
    function once per BLK_K lane block, i.e. ceil(k/512) times -- still
    ~k x below minhash's k),
  * interpret-mode wall time of both kernels for the relative trend
    (absolute speedups need a real TPU; interpret mode mostly measures
    the emulator).

Estimator quality at equal k is covered by tests/test_oph.py and the
resemblance_mse module; this module is pure preprocessing cost.
"""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import Row, bench_dataset, time_fn
from repro.core.hashing import Hash2U
from repro.core.oph import OPH, hash_evaluations
from repro.kernels import minhash2u, oph2u

S = 20
N, AVG_NNZ = 64, 256


def run() -> list[Row]:
    train, _ = bench_dataset(n=N, D=2**S, avg_nnz=AVG_NNZ)
    counts = np.asarray(train.mask.sum(axis=1), np.int32)
    d_idx = jax.device_put(train.indices)
    d_cnt = jax.device_put(counts)
    nnz_total = int(counts.sum())
    rows: list[Row] = []

    for k in (128, 512):
        key = jax.random.PRNGKey(k)
        fam = Hash2U.create(key, k, S)
        oph = OPH.create(key, k, S, "2u", "rotation")

        t_min = time_fn(lambda: minhash2u(d_idx, d_cnt, fam.a1, fam.a2,
                                          s=S, b=8))
        t_oph = time_fn(lambda: oph2u(d_idx, d_cnt, oph.base.a1, oph.base.a2,
                                      s=S, k=k, densify="rotation", b=8))

        evals_min = hash_evaluations(N, AVG_NNZ, k, "minhash")
        evals_oph = hash_evaluations(N, AVG_NNZ, k, "oph")
        # the kernel evaluates its ONE function once per BLK_K lane block;
        # derive the pass count from the wrapper's actual block choice
        from repro.kernels.engine import _oph_lanes
        k_lanes, blk_k = _oph_lanes(k, 0)
        kernel_passes = k_lanes // blk_k
        rows.append((f"oph/k_{k}", t_oph, {
            "minhash_us": round(t_min, 1),
            "hash_evals_minhash": int(evals_min),
            "hash_evals_oph": int(evals_oph),
            "reduction_x": round(evals_min / evals_oph, 1),
            "kernel_evals_oph": nnz_total * kernel_passes,
            "kernel_reduction_x": round(nnz_total * k
                                        / (nnz_total * kernel_passes), 1),
        }))

    # coefficient storage (the paper's Issue 3, taken to its extreme:
    # OPH stores ONE function's coefficients regardless of k)
    from repro.core.hashing import family_storage_bytes
    fam = Hash2U.create(jax.random.PRNGKey(0), 512, S)
    oph = OPH.create(jax.random.PRNGKey(0), 512, S, "2u")
    rows.append(("oph/storage", 0.0, {
        "minhash_coeff_bytes": family_storage_bytes(fam),
        "oph_coeff_bytes": family_storage_bytes(oph),
    }))
    return rows
