"""Paper Table 2: CPU preprocessing cost -- loading vs Permu / 2U /
4U(Mod) / 4U(Bit) minhash signature computation.

Paper numbers (webspam, k=500, seconds): load 970, Permu 6100, 2U 4100,
4U-Mod 44000, 4U-Bit 14000 -- i.e. preprocessing >> loading, and BitMod
cuts 4U by ~3x.  We reproduce the *ratios* on a scaled synthetic set with
the pure-jnp reference implementations (the "CPU" rows of the paper).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, bench_dataset, time_fn
from repro.core.hashing import Hash2U, Hash4U, PermutationFamily
from repro.core.minhash import minhash_signatures
from repro.data.pipeline import make_sharded_dataset, ChunkedLoader
from repro.data.synthetic import DatasetSpec

K = 128
S = 20


def run() -> list[Row]:
    train, _ = bench_dataset(n=256, D=2**S, avg_nnz=256)
    key = jax.random.PRNGKey(0)
    rows: list[Row] = []

    # data loading time (binary shards)
    spec = DatasetSpec("t2", n=256, D=2**S, avg_nnz=256, seed=1)
    paths = make_sharded_dataset(spec, n_shards=2)
    t0 = time.perf_counter()
    loader = ChunkedLoader(paths, chunk_size=128)
    n_rows = sum(c.n for c in loader)
    t_load_us = (time.perf_counter() - t0) * 1e6
    rows.append(("table2/loading", t_load_us, {"rows": n_rows}))

    fams = {
        "permu": PermutationFamily.create(key, K, 2**S),
        "2u": Hash2U.create(key, K, S),
        "4u_bit": Hash4U.create(key, K, S, use_bitmod=True),
        "4u_mod": Hash4U.create(key, K, S, use_bitmod=False),
    }
    times = {}
    for name, fam in fams.items():
        fn = jax.jit(lambda idx, msk, f=fam: minhash_signatures(idx, msk, f))
        us = time_fn(fn, train.indices, train.mask)
        times[name] = us
        rows.append((f"table2/{name}", us, {"k": K}))

    rows.append(("table2/ratios", 0.0, {
        "prep_over_load_2u": round(times["2u"] / max(t_load_us, 1), 2),
        "mod_over_bit_4u": round(times["4u_mod"] / times["4u_bit"], 2),
        "paper_mod_over_bit": round(44000 / 14000, 2),
    }))
    return rows
