"""Paper Table 3 + Figures 1-3: accelerator (Pallas kernel) preprocessing
with the chunk-size sweep and the 3-phase breakdown.

The paper's GPU pipeline: (i) CPU->GPU transfer, (ii) kernel, (iii)
GPU->CPU transfer, swept over chunk sizes 1..50K; conclusion: cost is
flat for chunk >= ~100, and transfer is ~2 orders below compute.  Here the
phases are host->device put, the minhash kernel (Pallas; interpret mode on
CPU, so *relative* phase structure not absolute speedup is the
deliverable), and device->host get of the (n, k) signatures (b-bit packed,
so phase (iii) moves k*b bits/example as in the paper).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, bench_dataset
from repro.core.hashing import Hash2U
from repro.core.bbit import pack_signatures
from repro.kernels import minhash2u

K, S, B = 128, 20, 8


def run() -> list[Row]:
    train, _ = bench_dataset(n=512, D=2**S, avg_nnz=256)
    fam = Hash2U.create(jax.random.PRNGKey(0), K, S)
    idx_np = np.asarray(train.indices)
    counts_np = np.asarray(train.mask.sum(axis=1), np.int32)
    rows: list[Row] = []

    for chunk in (32, 128, 512):
        t_in = t_kernel = t_out = 0.0
        sigs = []
        for lo in range(0, train.n, chunk):
            hi = min(lo + chunk, train.n)
            t0 = time.perf_counter()
            d_idx = jax.device_put(idx_np[lo:hi])
            d_cnt = jax.device_put(counts_np[lo:hi])
            jax.block_until_ready((d_idx, d_cnt))
            t1 = time.perf_counter()
            sig = minhash2u(d_idx, d_cnt, fam.a1, fam.a2, s=S, b=B)
            packed = pack_signatures(sig, B)
            jax.block_until_ready(packed)
            t2 = time.perf_counter()
            host = np.asarray(packed)
            t3 = time.perf_counter()
            t_in += t1 - t0
            t_kernel += t2 - t1
            t_out += t3 - t2
            sigs.append(host)
        total_us = (t_in + t_kernel + t_out) * 1e6
        rows.append((f"table3/chunk_{chunk}", total_us, {
            "phase_in_us": round(t_in * 1e6, 1),
            "phase_kernel_us": round(t_kernel * 1e6, 1),
            "phase_out_us": round(t_out * 1e6, 1),
            "bytes_out_per_example": sigs[0].shape[1] * 4,
        }))

    # determinism across chunk sizes (paper: results chunk-invariant)
    a = np.concatenate(sigs)
    rows.append(("table3/chunk_invariance", 0.0, {"checksum": int(a.sum())}))
    return rows
