"""Similarity-search index benchmark: build throughput, query rates, recall.

The retrieval workload (``repro.index``) measured end to end on a
synthetic corpus:

  * index build throughput (``.sig`` shards -> ``.idx``, docs/s),
  * queries/s for the exact kernel brute-force path vs the banded
    LSH-candidates + kernel-rerank path (batched admission),
  * recall@10 of the LSH path against the exact top-10, with the
    S-curve-predicted band configuration
    (``repro.index.banding.choose_band_config``),
  * mean candidate fraction (the selectivity the banding buys).

``--json PATH`` writes the rows as a JSON artifact (uploaded by the
slow-tier CI job next to ``signature_engine.json``).
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import tempfile
import time

import jax
import numpy as np

from benchmarks.common import Row, fmt_rows
from repro.data.pipeline import make_sharded_dataset
from repro.data.preprocess import preprocess_shards
from repro.data.synthetic import DatasetSpec
from repro.index import (IndexSearcher, build_index, choose_band_config,
                         load_index)
from repro.train.online import make_family

D_BITS = 16
K, B = 128, 8
N_DOCS = 1024
N_QUERIES = 32
TOPK = 10
THRESHOLD = 0.5


def _recall_at_k(lsh_idx: np.ndarray, exact_idx: np.ndarray) -> float:
    """Mean |top-k(lsh) ∩ top-k(exact)| / k over the query batch."""
    hits = [len(set(l.tolist()) & set(e.tolist())) / exact_idx.shape[1]
            for l, e in zip(lsh_idx, exact_idx)]
    return float(np.mean(hits))


def run() -> list[Row]:
    spec = DatasetSpec("search_index", n=N_DOCS, D=2**D_BITS, avg_nnz=64,
                       n_prototypes=8, overlap=0.8, seed=0)
    fam = make_family(jax.random.PRNGKey(0), "oph", K, D_BITS,
                      densify="rotation")
    rows: list[Row] = []
    with tempfile.TemporaryDirectory(prefix="repro_search_bench_") as tmp:
        raw = make_sharded_dataset(spec, os.path.join(tmp, "raw"),
                                   n_shards=4)
        preprocess_shards(raw, os.path.join(tmp, "sig"), fam, b=B,
                          chunk_size=256, loader_kwargs={"lane_multiple": 8})
        sig_paths = sorted(glob.glob(os.path.join(tmp, "sig", "*.sig")))
        cfg = choose_band_config(K, B, threshold=THRESHOLD)

        t0 = time.perf_counter()
        meta = build_index(sig_paths, os.path.join(tmp, "c.idx"), cfg)
        t_build = time.perf_counter() - t0
        rows.append(("index/build", t_build * 1e6, {
            "docs": meta.n, "docs_per_s": round(meta.n / t_build, 1),
            "n_bands": cfg.n_bands, "rows_per_band": cfg.rows_per_band,
            "payload_bytes": meta.payload_bytes}))

        index = load_index(os.path.join(tmp, "c.idx"))
        searcher = IndexSearcher(index, corpus_block=512)
        rng = np.random.default_rng(7)
        picks = rng.integers(0, meta.n, N_QUERIES)
        queries = np.ascontiguousarray(index.words_host[picks])

        results = {}
        for mode in ("exact", "lsh"):
            searcher.search(queries, TOPK, mode=mode)     # compile once
            t0 = time.perf_counter()
            results[mode] = searcher.search(queries, TOPK, mode=mode)
            dt = time.perf_counter() - t0
            derived = {"queries_per_s": round(N_QUERIES / dt, 1),
                       "topk": TOPK}
            if mode == "lsh":
                derived["mean_candidates"] = round(
                    float(np.mean(results[mode].n_candidates)), 1)
                derived["candidate_frac"] = round(
                    float(np.mean(results[mode].n_candidates)) / meta.n, 4)
            rows.append((f"index/query_{mode}", dt / N_QUERIES * 1e6,
                         derived))

        recall = _recall_at_k(results["lsh"].indices,
                              results["exact"].indices)
        rows.append(("index/recall_at_10", 0.0, {
            "recall": round(recall, 4),
            "threshold": THRESHOLD,
            "acceptance": "recall >= 0.9",
            "ok": recall >= 0.9}))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as a JSON artifact")
    args = ap.parse_args()
    rows = run()
    print(fmt_rows(rows))
    if args.json:
        doc = [{"name": name, "us_per_call": us, **derived}
               for name, us, derived in rows]
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2)


if __name__ == "__main__":
    main()
