"""Appendix A: resemblance estimation with 2U hashing vs theory.

Rebuilds the Table-5 word pairs (exact f1, f2, R), estimates R with
b-bit minwise hashing under 2U hash functions, and compares the empirical
MSE against the theoretical variance (Eq. 11 of [26]).

Run:  PYTHONPATH=src python examples/resemblance.py
"""

import jax
import numpy as np

from repro.core import (Hash2U, empirical_p_hat, estimate_resemblance,
                        lowest_bits, minhash_signatures,
                        theoretical_variance)
from repro.data import TABLE5_PAIRS, word_pair_sets
from repro.data.sparse import from_lists

K, D_BITS, REPS = 256, 18, 20


def main():
    D = 1 << D_BITS
    print(f"D=2^{D_BITS}, k={K}, {REPS} repetitions, 2U hashing")
    print(f"{'pair':<18}{'R':>7}{'b':>3}{'R_hat':>8}{'MSE':>10}"
          f"{'theory':>10}{'ratio':>7}")
    for name, f1, f2, R in TABLE5_PAIRS:
        if f1 + f2 > D // 2:
            continue
        s1, s2 = word_pair_sets(D, f1, f2, R, seed=1)
        true_r = len(np.intersect1d(s1, s2)) / len(np.union1d(s1, s2))
        batch = from_lists([s1, s2])
        for b in (1, 2, 4):
            errs, last = [], 0.0
            for rep in range(REPS):
                fam = Hash2U.create(jax.random.PRNGKey(rep * 31 + b), K,
                                    D_BITS)
                sig = lowest_bits(minhash_signatures(
                    batch.indices, batch.mask, fam), b)
                p_hat = float(empirical_p_hat(sig[0], sig[1]))
                last = float(estimate_resemblance(p_hat, f1, f2, D, b))
                errs.append(last - true_r)
            mse = float(np.mean(np.square(errs)))
            th = float(theoretical_variance(true_r, f1, f2, D, b, K))
            print(f"{name:<18}{true_r:7.3f}{b:3d}{last:8.3f}{mse:10.6f}"
                  f"{th:10.6f}{mse / max(th, 1e-12):7.2f}")


if __name__ == "__main__":
    main()
