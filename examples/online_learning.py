"""Online learning (paper §6): streaming SGD / ASGD over many epochs.

Epoch 0 streams raw shards through the single-pass OPH kernel (signatures
go straight to the SGD step, no host round-trip) while writing b-bit
packed signature shards; epochs >= 1 replay that cache -- the paper's
point that b-bit hashing shrinks the per-epoch loading cost that
dominates online learning.

Run:  PYTHONPATH=src python examples/online_learning.py
Docs: docs/online_learning.md walks through this loop stage by stage.
"""

import jax

from repro.data import TINY, generate
from repro.data.pipeline import SignatureStream, make_sharded_dataset
from repro.kernels import batch_signatures
from repro.models.linear import accuracy
from repro.train import OnlineTrainer, SignatureCache, make_family

K, B, D_BITS = 128, 8, 16
SCHEME, DENSIFY = "oph", "rotation"   # try "2u" / "4u" / ("oph", "sentinel")
EPOCHS = 10


def main():
    shard_paths = make_sharded_dataset(TINY, n_shards=4)
    family = make_family(jax.random.PRNGKey(0), SCHEME, K, D_BITS,
                         densify=DENSIFY)
    # packed=True: chunks are PackedSignatures wire words (k*b bits per
    # example); the unpack happens inside the jitted SGD step.
    stream = SignatureStream(shard_paths, family, b=B, chunk_size=64,
                             packed=True)

    _, test = generate(TINY)
    sig_te = batch_signatures(test, family, b=B)

    # context managers: the trainer closes the cache, the cache deletes
    # its temp shard dir (no per-run leaks)
    with SignatureCache(stream) as cache, \
            OnlineTrainer(k=K, b=B, kind="svm", average=True,
                          lam=1e-4, eta0=0.5, batch_size=16,
                          avg_start=100.0) as trainer:
        _, stats, evals = trainer.fit(
            cache, EPOCHS,
            eval_fn=lambda tr: tr.evaluate(sig_te, test.labels))

        print(f"scheme={SCHEME} densify={DENSIFY} k={K} b={B}")
        print(f"on-disk: original={cache.stats.bytes_original:,} B  "
              f"hashed={cache.stats.bytes_cached:,} B  "
              f"(reduction {cache.stats.reduction():.1f}x, "
              f"payload {cache.stats.bytes_payload:,} B = "
              f"k*{cache.code_bits} bits/example)")
        for es, acc in zip(stats, evals):
            print(f"epoch {es.epoch:2d} [{es.source:5s}]: "
                  f"load={es.load_s * 1e3:7.1f} ms  "
                  f"train={es.train_s * 1e3:7.1f} ms  "
                  f"read={es.bytes_read:>8,} B  test_acc={acc:.4f}")
        sgd_acc = float(accuracy(trainer.state.model, sig_te, test.labels,
                                 feature_kind="hashed", b=B))
        print(f"final: SGD acc={sgd_acc:.4f}  ASGD acc={evals[-1]:.4f}")


if __name__ == "__main__":
    main()
