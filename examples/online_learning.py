"""Online learning (paper §6): SGD / ASGD over many epochs, loading data
from disk every epoch -- demonstrating that b-bit hashing's size
reduction cuts the dominant cost (loading).

Run:  PYTHONPATH=src python examples/online_learning.py
"""

import functools
import os
import tempfile

import jax
import numpy as np

from repro.core import Hash2U, lowest_bits, minhash_signatures
from repro.data import TINY, generate
from repro.models.linear import (accuracy, asgd_model, sgd_svm_init,
                                 sgd_svm_step)
from repro.train import online_epochs

K, B, D_BITS = 128, 8, 16
EPOCHS = 15


def main():
    train, test = generate(TINY)
    fam = Hash2U.create(jax.random.PRNGKey(0), K, D_BITS)
    sig_tr = np.asarray(lowest_bits(
        minhash_signatures(train.indices, train.mask, fam), B), np.uint8)
    sig_te = lowest_bits(
        minhash_signatures(test.indices, test.mask, fam), B)

    tmp = tempfile.mkdtemp(prefix="repro_online_")
    orig = os.path.join(tmp, "orig.npz")
    np.savez(orig, idx=np.asarray(train.indices),
             msk=np.asarray(train.mask), y=np.asarray(train.labels))
    hashed = os.path.join(tmp, "hashed.npz")
    np.savez(hashed, sig=sig_tr, y=np.asarray(train.labels))
    ro, rh = os.path.getsize(orig), os.path.getsize(hashed)
    print(f"on-disk: original={ro:,} B  hashed={rh:,} B  "
          f"(reduction {ro / rh:.1f}x)")

    step = jax.jit(functools.partial(sgd_svm_step, lam=1e-4, eta0=0.5, b=B,
                                     average=True))

    def epoch_batches():
        with np.load(hashed) as z:          # real disk read, every epoch
            s, y = z["sig"], z["y"]
        for i in range(0, len(y), 16):
            yield (jax.numpy.asarray(s[i:i + 16], jax.numpy.uint32),
                   jax.numpy.asarray(y[i:i + 16]))

    state = sgd_svm_init(K * (1 << B), avg_start=100.0)
    state, times, evals = online_epochs(
        lambda st, batch: step(st, batch[0], batch[1]), state,
        epoch_batches, EPOCHS,
        eval_fn=lambda st: accuracy(st.model, sig_te, test.labels,
                                    feature_kind="hashed", b=B))
    for ep, (t, acc) in enumerate(zip(times, evals), 1):
        print(f"epoch {ep:2d}: load={t.load_s * 1e3:7.1f} ms  "
              f"train={t.train_s * 1e3:7.1f} ms  test_acc={acc:.4f}")
    asgd_acc = accuracy(asgd_model(state), sig_te, test.labels,
                        feature_kind="hashed", b=B)
    print(f"final: SGD acc={evals[-1]:.4f}  ASGD acc={float(asgd_acc):.4f}")


if __name__ == "__main__":
    main()
