"""End-to-end driver: distributed training of a recsys model whose user
feature-set goes through the paper's b-bit minhash frontend.

Trains AutoInt (reduced config) for a few hundred steps on synthetic CTR
data with the production Trainer: data-parallel mesh over the local
devices, checkpoint/resume, straggler heartbeat.  The hashed frontend is
the paper's Eq.(5) construction embedded as a signature embedding-bag.

Run:  PYTHONPATH=src python examples/distributed_recsys.py [--steps 300]
"""

import argparse
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.launch.steps import build_cell, init_inputs
from repro.models.recsys import recsys_loss, serve_scores
from repro.optim import adamw, warmup_cosine
from repro.sharding.rules import set_mesh
from repro.train import TrainState, Trainer, make_train_step


def make_batch(key, cfg, batch_size):
    """Synthetic CTR batch with a learnable signal: the label depends on
    (field ids + the sparse behavior set) so both paths must be used."""
    ks = jax.random.split(key, 4)
    field_ids = jax.random.randint(ks[0], (batch_size, cfg.n_fields), 0,
                                   cfg.vocab, dtype=jnp.int32)
    set_ids = jax.random.randint(ks[1], (batch_size, cfg.set_nnz), 0,
                                 1 << cfg.minhash_s, dtype=jnp.int32)
    set_counts = jax.random.randint(ks[2], (batch_size,), 8, cfg.set_nnz,
                                    dtype=jnp.int32)
    signal = (field_ids[:, 0] % 2).astype(jnp.float32) * 2.0 \
        + (set_ids[:, 0] % 3).astype(jnp.float32) - 2.0
    labels = (jax.nn.sigmoid(signal)
              > jax.random.uniform(ks[3], (batch_size,))).astype(jnp.float32)
    return {"field_ids": field_ids, "set_ids": set_ids,
            "set_counts": set_counts, "labels": labels}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=256)
    args = ap.parse_args()

    cfg = get_arch("autoint").smoke
    from repro.models.recsys import init_recsys_params
    params = init_recsys_params(cfg, jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(l.shape))
                   for l in jax.tree_util.tree_leaves(params))
    print(f"autoint (reduced): {n_params:,} params, "
          f"minhash frontend k={cfg.minhash_k} b={cfg.minhash_b}")

    opt = adamw(warmup_cosine(3e-3, 20, args.steps))
    state = TrainState.create(params, opt)
    step = make_train_step(lambda p, b: recsys_loss(p, b, cfg), opt)

    keys = jax.random.split(jax.random.PRNGKey(1), args.steps)
    batches = lambda: (make_batch(k, cfg, args.batch) for k in keys)

    with tempfile.TemporaryDirectory() as ckpt_dir:
        tr = Trainer(step, ckpt_dir=ckpt_dir, ckpt_every=100)
        state = tr.fit(state, batches, args.steps)
        losses = [m["loss"] for m in tr.metrics_log]
        print(f"loss: step1={losses[0]:.4f}  "
              f"step{len(losses)}={losses[-1]:.4f}")
        assert losses[-1] < losses[0], "training did not reduce the loss"

    # quick eval: scores should separate the label signal
    test = make_batch(jax.random.PRNGKey(99), cfg, 2048)
    scores = serve_scores(state.params, test, cfg)
    pred = (scores > 0.5).astype(jnp.float32)
    acc = float(jnp.mean((pred == test["labels"]).astype(jnp.float32)))
    print(f"holdout accuracy: {acc:.4f}")
    print(f"straggler heartbeat: {tr.heartbeat.stragglers} slow steps "
          f"of {len(tr.heartbeat.history)}")


if __name__ == "__main__":
    main()
