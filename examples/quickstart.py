"""Quickstart: the paper's pipeline in 60 lines.

Generates sparse binary data, computes k b-bit minwise signatures under
three hash families (full permutations / 2U / 4U -- the paper's §4
comparison), trains a linear SVM on the implicit Eq.(5) expansion, and
prints the test accuracies, which should be essentially identical.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax

from repro.core import (Hash2U, Hash4U, PermutationFamily,
                        family_storage_bytes, lowest_bits,
                        minhash_signatures)
from repro.data import TINY, generate
from repro.models.linear import LinearModel, accuracy, make_loss_fn
from repro.optim import adamw, constant
from repro.train import TrainState, Trainer, make_train_step

K, B, D_BITS = 128, 8, 16


def signatures(batch, fam):
    return lowest_bits(minhash_signatures(batch.indices, batch.mask, fam), B)


def main():
    train, test = generate(TINY)
    print(f"data: n_train={train.n} n_test={test.n} D=2^{D_BITS} "
          f"k={K} b={B}")
    key = jax.random.PRNGKey(0)
    families = {
        "permutations": PermutationFamily.create(key, K, 1 << D_BITS),
        "2U": Hash2U.create(key, K, D_BITS),
        "4U": Hash4U.create(key, K, D_BITS),
    }
    for name, fam in families.items():
        sig_tr, sig_te = signatures(train, fam), signatures(test, fam)
        loss = make_loss_fn("svm", "hashed", B, C=1.0)
        opt = adamw(constant(0.05))
        state = TrainState.create(LinearModel.create(K * (1 << B)), opt)
        step = make_train_step(lambda p, batch: loss(p, *batch), opt)
        state = Trainer(step).fit(
            state, lambda: iter([(sig_tr, train.labels)] * 120), 120)
        acc = accuracy(state.params, sig_te, test.labels,
                       feature_kind="hashed", b=B)
        print(f"{name:14s} acc={float(acc):.4f}  "
              f"hash-family storage={family_storage_bytes(fam):>12,} B")
    print("\n(2U/4U match full permutations at a millionth of the storage "
          "-- the paper's Issue-3 result.)")


if __name__ == "__main__":
    main()
