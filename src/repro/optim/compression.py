"""Gradient compression for data-parallel all-reduce.

Two schemes, both usable inside a ``shard_map`` gradient-sync wrapper:

  * int8 symmetric quantization with stochastic rounding: the all-reduce
    moves 1 byte/element instead of 4 (plus one scalar scale per tensor,
    agreed via a ``pmax``),
  * top-k sparsification with error feedback (memory carries the residual
    to the next step, preserving convergence).

On a real pod these cut the DP-gradient collective term by 4x / (dim/k)x;
the roofline analysis in EXPERIMENTS.md quantifies this on the compiled
HLO.  The implementations are exact-arithmetic-checked in tests.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_int8(g: jax.Array, key: jax.Array,
                  scale: jax.Array | None = None
                  ) -> Tuple[jax.Array, jax.Array]:
    """Symmetric int8 quantization with stochastic rounding.

    Returns (q int8, scale f32) with g ~= q * scale / 127.
    """
    g32 = g.astype(jnp.float32)
    if scale is None:
        scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12)
    x = g32 / scale * 127.0
    lo = jnp.floor(x)
    frac = x - lo
    rnd = (jax.random.uniform(key, g.shape) < frac).astype(jnp.float32)
    q = jnp.clip(lo + rnd, -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale / 127.0


def compressed_psum_int8(g: jax.Array, key: jax.Array, axis_name: str
                         ) -> jax.Array:
    """Data-parallel mean of gradients with int8 wire format.

    Inside shard_map: agree on a shared scale (pmax), quantize locally,
    all-reduce the int32 sums (1B/elem on the wire pre-accumulation),
    dequantize once.
    """
    g32 = g.astype(jnp.float32)
    local_scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12)
    scale = jax.lax.pmax(local_scale, axis_name)
    q, _ = quantize_int8(g32, key, scale)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return total.astype(jnp.float32) * scale / 127.0 / n


def make_compressed_allreduce(mesh, axis_name: str = "dp", spec=None):
    """Build the shard_map-wrapped int8 mean-allreduce.

    Returns ``f(g, key) -> mean(g)`` ready to ``jax.jit``; uses the
    ``repro.compat.shard_map`` shim so the same call works across jax
    versions (``jax.shard_map`` vs ``jax.experimental.shard_map``).
    """
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    spec = P() if spec is None else spec

    def f(g, key):
        return compressed_psum_int8(g, key, axis_name)

    return shard_map(f, mesh=mesh, in_specs=(spec, P()), out_specs=spec)


def topk_compress(g: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
    """Keep the k largest-magnitude entries. Returns (values, flat indices)."""
    flat = g.reshape(-1).astype(jnp.float32)
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    return flat[idx], idx


def topk_decompress(values: jax.Array, idx: jax.Array, shape) -> jax.Array:
    size = 1
    for s in shape:
        size *= s
    return jnp.zeros((size,), jnp.float32).at[idx].set(values).reshape(shape)


def topk_error_feedback(g: jax.Array, residual: jax.Array, k: int
                        ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Error-feedback top-k: compress (g + residual), carry the rest.

    Returns (values, idx, new_residual, transmitted_dense) -- the dense form
    is what a psum would reduce; callers all-reduce (values, idx) pairs via
    all_gather in practice.
    """
    corrected = g.astype(jnp.float32) + residual
    vals, idx = topk_compress(corrected, k)
    transmitted = topk_decompress(vals, idx, g.shape)
    new_residual = corrected - transmitted
    return vals, idx, new_residual, transmitted
