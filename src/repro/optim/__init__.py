from repro.optim.base import (Optimizer, add_decayed_weights, apply_updates,
                              chain, clip_by_global_norm, scale,
                              scale_by_schedule)
from repro.optim.optimizers import adafactor, adamw, sgd
from repro.optim.schedules import constant, inverse_time, warmup_cosine
from repro.optim.compression import (compressed_psum_int8, dequantize_int8,
                                     quantize_int8, topk_compress,
                                     topk_decompress, topk_error_feedback)

__all__ = [
    "Optimizer", "add_decayed_weights", "apply_updates", "chain",
    "clip_by_global_norm", "scale", "scale_by_schedule", "adafactor",
    "adamw", "sgd", "constant", "inverse_time", "warmup_cosine",
    "compressed_psum_int8", "dequantize_int8", "quantize_int8",
    "topk_compress", "topk_decompress", "topk_error_feedback",
]
