"""Concrete optimizers: SGD(+momentum), AdamW, Adafactor.

Adafactor (Shazeer & Stern) is the memory lever that lets the 123B/671B
dry-run configs fit 16 GB/chip: second moments are factored into row/col
statistics (O(n+m) instead of O(nm)) and first-moment momentum is kept in
bf16.
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.optim.base import Optimizer


def sgd(lr: Callable[[jax.Array], jax.Array] | float,
        momentum: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        mu = (jax.tree_util.tree_map(jnp.zeros_like, params)
              if momentum else ())
        return {"count": jnp.zeros((), jnp.int32), "mu": mu}

    def update(grads, state, params):
        count = state["count"]
        step = lr_fn(count)
        if momentum:
            mu = jax.tree_util.tree_map(
                lambda m, g: momentum * m + g, state["mu"], grads)
            upd = jax.tree_util.tree_map(lambda m: -step * m, mu)
            return upd, {"count": count + 1, "mu": mu}
        upd = jax.tree_util.tree_map(lambda g: -step * g, grads)
        return upd, {"count": count + 1, "mu": ()}

    return Optimizer(init, update)


def adamw(lr: Callable[[jax.Array], jax.Array] | float, b1: float = 0.9,
          b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"count": jnp.zeros((), jnp.int32),
                "m": jax.tree_util.tree_map(zeros32, params),
                "v": jax.tree_util.tree_map(zeros32, params)}

    def update(grads, state, params):
        count = state["count"] + 1
        cf = count.astype(jnp.float32)
        m = jax.tree_util.tree_map(
            lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
            state["m"], grads)
        v = jax.tree_util.tree_map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"], grads)
        mh = 1.0 - b1 ** cf
        vh = 1.0 - b2 ** cf
        step = lr_fn(state["count"])

        def upd(m_, v_, p):
            u = (m_ / mh) / (jnp.sqrt(v_ / vh) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (-step * u).astype(p.dtype)

        updates = jax.tree_util.tree_map(upd, m, v, params)
        return updates, {"count": count, "m": m, "v": v}

    return Optimizer(init, update)


def adafactor_fused(lr: Callable[[jax.Array], jax.Array] | float,
                    momentum: Optional[float] = None,
                    momentum_dtype=jnp.bfloat16,
                    decay: float = 0.8, eps: float = 1e-30,
                    clip_threshold: float = 1.0, scan_min_leading: int = 8):
    """Adafactor whose update is fused with the parameter apply and
    *scanned over the layer-stack axis* for big leaves.

    Motivation (100B+ models on 16 GB chips): a whole-tree update
    materializes fp32 gradient/precondition copies of every layer-stacked
    tensor simultaneously (~2x params in fp32).  Scanning over axis 0 of
    each (L, ...) leaf keeps only one layer-slice of fp32 temporaries live
    (factored stats are per-slice exact; update clipping becomes per-slice,
    a standard variation).  Returns (init, update_apply) where
    ``update_apply(grads, state, params) -> (new_params, new_state)``.
    """
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def v_for(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        state = {"count": jnp.zeros((), jnp.int32),
                 "v": jax.tree_util.tree_map(v_for, params)}
        if momentum is not None:
            state["m"] = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, momentum_dtype), params)
        return state

    def update_apply(grads, state, params):
        count = state["count"] + 1
        beta2 = 1.0 - count.astype(jnp.float32) ** (-decay)
        step = lr_fn(state["count"])

        def slice_update(g, p, vr, vc, m):
            # barrier: stops XLA hoisting the fp32 convert of the (loop-
            # invariant) stacked grads/params out of the scan, which would
            # materialize whole-stack fp32 copies (2x params in fp32).
            g, p = jax.lax.optimization_barrier((g, p))
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + eps
            if vr is not None:
                vr = beta2 * vr + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * vc + (1 - beta2) * jnp.mean(g2, axis=-2)
                denom_r = vr / jnp.maximum(
                    jnp.mean(vr, axis=-1, keepdims=True), eps)
                precond = g32 / (jnp.sqrt(denom_r)[..., None]
                                 * jnp.sqrt(vc)[..., None, :] + eps)
            else:
                vc = beta2 * vc + (1 - beta2) * g2     # vc doubles as v
                precond = g32 / (jnp.sqrt(vc) + eps)
            rms = jnp.sqrt(jnp.mean(jnp.square(precond)) + 1e-30)
            precond = precond / jnp.maximum(1.0, rms / clip_threshold)
            if m is not None:
                m = (momentum * m.astype(jnp.float32)
                     + (1 - momentum) * precond).astype(momentum_dtype)
                upd = m.astype(jnp.float32)
            else:
                upd = precond
            new_p = (p.astype(jnp.float32) - step * upd).astype(p.dtype)
            return new_p, vr, vc, m

        def leaf(g, p, v, m):
            vr = v.get("vr")
            vc = v.get("vc", v.get("v"))
            if p.ndim >= 3 and p.shape[0] >= scan_min_leading:
                def body(_, xs):
                    g_s, p_s, vr_s, vc_s, m_s = xs
                    out = slice_update(g_s, p_s, vr_s, vc_s, m_s)
                    return None, out
                xs = (g, p, vr, vc, m)
                _, (new_p, nvr, nvc, nm) = jax.lax.scan(body, None, xs)
            else:
                new_p, nvr, nvc, nm = slice_update(g, p, vr, vc, m)
            nv = ({"vr": nvr, "vc": nvc} if "vr" in v else {"v": nvc})
            return new_p, nv, nm

        flat_g, tdef = jax.tree_util.tree_flatten(grads)
        flat_p = tdef.flatten_up_to(params)
        flat_v = tdef.flatten_up_to(state["v"])
        flat_m = (tdef.flatten_up_to(state["m"]) if momentum is not None
                  else [None] * len(flat_g))
        outs = [leaf(g, p, v, m)
                for g, p, v, m in zip(flat_g, flat_p, flat_v, flat_m)]
        new_params = tdef.unflatten([o[0] for o in outs])
        new_state = {"count": count,
                     "v": tdef.unflatten([o[1] for o in outs])}
        if momentum is not None:
            new_state["m"] = tdef.unflatten([o[2] for o in outs])
        return new_params, new_state

    return Optimizer(init, update_apply)


def adafactor(lr: Callable[[jax.Array], jax.Array] | float,
              momentum: Optional[float] = 0.9,
              momentum_dtype=jnp.bfloat16,
              decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0) -> Optimizer:
    """Factored-second-moment optimizer for very large models."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def v_for(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        state = {"count": jnp.zeros((), jnp.int32),
                 "v": jax.tree_util.tree_map(v_for, params,
                                             is_leaf=lambda x: isinstance(x, jax.Array))}
        if momentum is not None:
            state["m"] = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, momentum_dtype), params)
        return state

    def update(grads, state, params):
        count = state["count"] + 1
        beta2 = 1.0 - count.astype(jnp.float32) ** (-decay)
        step = lr_fn(state["count"])

        def upd_one(g, p, v):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + eps
            if _factored(p):
                vr = beta2 * v["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * v["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                denom_r = vr / jnp.maximum(
                    jnp.mean(vr, axis=-1, keepdims=True), eps)
                precond = g32 / (jnp.sqrt(denom_r)[..., None]
                                 * jnp.sqrt(vc)[..., None, :] + eps)
                new_v = {"vr": vr, "vc": vc}
            else:
                vv = beta2 * v["v"] + (1 - beta2) * g2
                precond = g32 / (jnp.sqrt(vv) + eps)
                new_v = {"v": vv}
            # update clipping (RMS <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(jnp.square(precond)) + 1e-30)
            precond = precond / jnp.maximum(1.0, rms / clip_threshold)
            return precond, new_v

        flat_g, tdef = jax.tree_util.tree_flatten(grads)
        flat_p = tdef.flatten_up_to(params)
        flat_v = tdef.flatten_up_to(state["v"])
        outs = [upd_one(g, p, v) for g, p, v in zip(flat_g, flat_p, flat_v)]
        precs = tdef.unflatten([o[0] for o in outs])
        new_v = tdef.unflatten([o[1] for o in outs])

        new_state = {"count": count, "v": new_v}
        if momentum is not None:
            m = jax.tree_util.tree_map(
                lambda m_, u: (momentum * m_.astype(jnp.float32)
                               + (1 - momentum) * u).astype(momentum_dtype),
                state["m"], precs)
            new_state["m"] = m
            updates = jax.tree_util.tree_map(
                lambda m_, p: (-step * m_.astype(jnp.float32)).astype(p.dtype),
                m, params)
        else:
            updates = jax.tree_util.tree_map(
                lambda u, p: (-step * u).astype(p.dtype), precs, params)
        return updates, new_state

    return Optimizer(init, update)
