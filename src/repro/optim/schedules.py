"""Learning-rate schedules."""

from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda count: jnp.asarray(lr, jnp.float32)


def inverse_time(eta0: float, lam: float):
    """Bottou's SGD schedule: eta_t = eta0 / (1 + lam * eta0 * t)."""
    return lambda count: eta0 / (1.0 + lam * eta0 * count.astype(jnp.float32))


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    def fn(count):
        c = count.astype(jnp.float32)
        # (c+1): step 0 must have a nonzero LR
        warm = peak_lr * jnp.minimum(1.0, (c + 1.0) / max(warmup_steps, 1))
        progress = jnp.clip((c - warmup_steps) /
                            max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * progress))
        return jnp.where(c < warmup_steps, warm, peak_lr * cos)

    return fn
