"""Minimal optimizer framework (optax-like, self-contained).

An optimizer is a pair of pure functions:

    init(params) -> state
    update(grads, state, params) -> (updates, state)

``apply_updates`` adds updates to params.  All optimizers are pytree-
polymorphic and jit/pjit-safe; states shard like their params, so FSDP
sharding of parameters automatically shards optimizer state (ZeRO).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Tuple[Any, Any]]


def apply_updates(params, updates):
    return jax.tree_util.tree_map(lambda p, u: (p + u).astype(p.dtype),
                                  params, updates)


def chain(*transforms: Optimizer) -> Optimizer:
    """Compose gradient transformations left-to-right."""

    def init(params):
        return tuple(t.init(params) for t in transforms)

    def update(grads, state, params):
        new_states = []
        for t, s in zip(transforms, state):
            grads, ns = t.update(grads, s, params)
            new_states.append(ns)
        return grads, tuple(new_states)

    return Optimizer(init, update)


def scale(factor: float) -> Optimizer:
    return Optimizer(lambda p: (),
                     lambda g, s, p: (jax.tree_util.tree_map(
                         lambda x: x * factor, g), s))


def scale_by_schedule(schedule: Callable[[jax.Array], jax.Array]) -> Optimizer:
    def init(params):
        return jnp.zeros((), jnp.int32)

    def update(grads, count, params):
        lr = schedule(count)
        return (jax.tree_util.tree_map(lambda g: -lr * g, grads), count + 1)

    return Optimizer(init, update)


def clip_by_global_norm(max_norm: float) -> Optimizer:
    def update(grads, state, params):
        leaves = jax.tree_util.tree_leaves(grads)
        norm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                            for g in leaves))
        factor = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
        return (jax.tree_util.tree_map(lambda g: g * factor, grads), state)

    return Optimizer(lambda p: (), update)


def add_decayed_weights(weight_decay: float) -> Optimizer:
    def update(grads, state, params):
        return (jax.tree_util.tree_map(
            lambda g, p: g + weight_decay * p.astype(g.dtype), grads, params),
            state)

    return Optimizer(lambda p: (), update)
