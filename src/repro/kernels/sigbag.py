"""Pallas TPU kernel for the signature embedding-bag (Eq. 5 forward).

The paper's learning construction expands k b-bit signatures into a
``2^b * k`` one-hot vector and feeds it to a linear model (Eq. 5).  The
inner product with the weight vector is

    f(x) = sum_j  W[j, z_j]            (W reshaped to (k, 2^b, d))

i.e., a k-way embedding-bag over per-slot tables.  With d = 1 this *is*
the paper's linear SVM / logistic forward; with d > 1 it is the hashed
embedding frontend used by the recsys architectures.

TPU design: the per-slot gather is expressed as a one-hot (BLK_N, 2^b)
times (2^b, d) matmul so it runs on the MXU (the canonical TPU small-vocab
gather).  Grid = (n/BLK_N, k): the j axis accumulates into the output
block (revisited), so the kernel streams one (2^b, d) table slice through
VMEM per step instead of holding all k*2^b rows.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sigbag_kernel(tok_ref, table_ref, out_ref, *, two_b: int):
    # out_ref is a float32 accumulator regardless of table dtype (the
    # standard MXU practice: bf16 operands, fp32 accumulation).
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    tok = tok_ref[...][:, 0]                              # (BLK_N,) int32
    onehot = (tok[:, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (tok.shape[0], two_b), 1)
              ).astype(table_ref.dtype)                   # (BLK_N, 2^b)
    tbl = table_ref[...][0]                               # (2^b, d)
    out_ref[...] += jnp.dot(onehot, tbl,
                            preferred_element_type=jnp.float32)


def sigbag_pallas(tokens: jax.Array, table: jax.Array, *, blk_n: int = 128,
                  interpret: bool = True) -> jax.Array:
    """Sum-of-rows lookup: out[i] = sum_j table[j, tokens[i, j]].

    Args:
      tokens: (n, k) int32 b-bit signature values in [0, 2^b).
      table:  (k, 2^b, d) float weights.

    Returns:
      (n, d) float.
    """
    n, k = tokens.shape
    k_t, two_b, d = table.shape
    if k_t != k:
        raise ValueError(f"table k={k_t} != tokens k={k}")
    if n % blk_n:
        raise ValueError(f"n={n} must tile by blk_n={blk_n}")
    grid = (n // blk_n, k)
    kern = functools.partial(_sigbag_kernel, two_b=two_b)
    params = {}
    if not interpret:
        try:
            from jax.experimental.pallas import tpu as pltpu
            for name in ("CompilerParams", "TPUCompilerParams"):
                cls = getattr(pltpu, name, None)
                if cls is not None:
                    params["compiler_params"] = cls(
                        dimension_semantics=("parallel", "arbitrary"))
                    break
        except ImportError:
            pass
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk_n, 1), lambda i, j: (i, j)),
            pl.BlockSpec((1, two_b, d), lambda i, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((blk_n, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=interpret,
        **params,
    )(tokens.astype(jnp.int32), table).astype(table.dtype)
