"""Pallas TPU kernels for minwise-hash signature computation.

This is the TPU adaptation of the paper's §3 GPU preprocessing kernel.

Mapping of the paper's GPU design onto TPU v5e:

  paper (CUDA, Tesla C2050)            this kernel (Pallas, TPU)
  -----------------------------------  -----------------------------------
  chunk of 10K sets copied to GPU mem  (BLK_N, BLK_T) index tiles DMA'd
                                       HBM -> VMEM via BlockSpec
  SIMD threads over (element, hash j)  VPU lanes over a (BLK_N, BLK_T,
                                       BLK_K) tile; k is the 128-lane axis
  per-set running minima in registers  running-min accumulator in the
                                       revisited output block (grid's
                                       innermost "arbitrary" dim iterates
                                       nnz chunks)
  avoid % via 2^32 overflow (Eq. 10)   identical uint32 wraparound +
                                       multiply-shift
  avoid % via BitMod, p = 2^31-1       identical shift/mask/cond-subtract,
                                       with the 64-bit intermediate emulated
                                       by 16-bit-limb long multiplication
                                       (TPU has no 64-bit integer unit)

Grid = (n/BLK_N, k/BLK_K, nnz/BLK_T); the last axis accumulates, so the
output (n, k) block is revisited -- the standard Pallas reduction pattern
("parallel", "parallel", "arbitrary").

Padding is communicated via per-row nonzero counts: lane t of row i is
valid iff ``t < counts[i]``; invalid lanes hash to 0xFFFFFFFF so they never
win the min.  If ``b > 0`` the lowest-b-bit extraction (the *b-bit* step)
is fused into the final grid iteration; with ``pack=True`` that same final
step additionally bit-packs the (BLK_N, BLK_K) b-bit tile into
(BLK_N, BLK_K*b/32) uint32 words (``repro.kernels.pack.pack_block``), so
signatures leave the kernel in the paper's k*b-bit wire format.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.hashing import add64, mod_mersenne31, umul32_wide
from repro.kernels.pack import pack_block

_U32 = jnp.uint32
# numpy scalar (not a traced jax array) so kernels don't capture constants
_PAD = np.uint32(0xFFFFFFFF)


# ---------------------------------------------------------------------------
# Kernel bodies
# ---------------------------------------------------------------------------

def _minhash2u_kernel(counts_ref, idx_ref, a1_ref, a2_ref, out_ref,
                      *packed_refs, s: int, b: int, blk_t: int, variant: str,
                      pack: bool = False):
    t_step = pl.program_id(2)
    n_t = pl.num_programs(2)

    @pl.when(t_step == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, _PAD)

    idx = idx_ref[...]                                    # (BLK_N, BLK_T) i32
    counts = counts_ref[...]                              # (BLK_N, 1) i32
    col = jax.lax.broadcasted_iota(jnp.int32, idx.shape, 1) + t_step * blk_t
    valid = col < counts                                  # (BLK_N, BLK_T)

    a1 = a1_ref[...]                                      # (1, BLK_K) u32
    a2 = a2_ref[...]
    # (BLK_N, BLK_T, BLK_K): the SIMD tile. uint32 mul wraps mod 2^32.
    h = a1[0][None, None, :] + a2[0][None, None, :] * idx.astype(_U32)[..., None]
    if s < 32:
        if variant == "high":
            h = h >> _U32(32 - s)
        else:
            h = h & _U32((1 << s) - 1)
    h = jnp.where(valid[..., None], h, _PAD)
    blk_min = jnp.min(h, axis=1)                          # (BLK_N, BLK_K)
    out_ref[...] = jnp.minimum(out_ref[...], blk_min)

    if b > 0:
        @pl.when(t_step == n_t - 1)
        def _extract_bbits():
            z = out_ref[...] & _U32((1 << b) - 1)
            out_ref[...] = z
            if pack:
                packed_refs[0][...] = pack_block(z, b)


def _minhash4u_kernel(counts_ref, idx_ref, a_ref, out_ref, *packed_refs,
                      s: int, b: int, blk_t: int, pack: bool = False):
    t_step = pl.program_id(2)
    n_t = pl.num_programs(2)

    @pl.when(t_step == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, _PAD)

    idx = idx_ref[...]
    counts = counts_ref[...]
    col = jax.lax.broadcasted_iota(jnp.int32, idx.shape, 1) + t_step * blk_t
    valid = col < counts

    a = a_ref[...]                                        # (4, BLK_K) u32
    t = idx.astype(_U32)[..., None]                       # (BLK_N, BLK_T, 1)
    # Horner: acc = ((a4 t + a3) t + a2) t + a1, each step mod p via BitMod.
    acc = jnp.broadcast_to(a[3][None, None, :], t.shape[:2] + (a.shape[1],))
    for i in (2, 1, 0):
        hi, lo = umul32_wide(acc, t)                      # acc*t < 2^62
        hi, lo = add64(hi, lo, jnp.broadcast_to(a[i][None, None, :], lo.shape))
        acc = mod_mersenne31(hi, lo)
    if s < 31:
        acc = acc & _U32((1 << s) - 1)
    h = jnp.where(valid[..., None], acc, _PAD)
    blk_min = jnp.min(h, axis=1)
    out_ref[...] = jnp.minimum(out_ref[...], blk_min)

    if b > 0:
        @pl.when(t_step == n_t - 1)
        def _extract_bbits():
            z = out_ref[...] & _U32((1 << b) - 1)
            out_ref[...] = z
            if pack:
                packed_refs[0][...] = pack_block(z, b)


# ---------------------------------------------------------------------------
# pallas_call builders
# ---------------------------------------------------------------------------

def _common_grid_specs(n, nnz, k, blk_n, blk_t, blk_k):
    if n % blk_n or nnz % blk_t or k % blk_k:
        raise ValueError(
            f"shapes must tile: n={n}%{blk_n}, nnz={nnz}%{blk_t}, k={k}%{blk_k}")
    grid = (n // blk_n, k // blk_k, nnz // blk_t)
    counts_spec = pl.BlockSpec((blk_n, 1), lambda i, j, t: (i, 0))
    idx_spec = pl.BlockSpec((blk_n, blk_t), lambda i, j, t: (i, t))
    out_spec = pl.BlockSpec((blk_n, blk_k), lambda i, j, t: (i, j))
    return grid, counts_spec, idx_spec, out_spec


def _compiler_params(interpret: bool):
    if interpret:
        return {}
    try:  # TPU-only: declare the reduction dim non-parallel
        from jax.experimental.pallas import tpu as pltpu
        for name in ("CompilerParams", "TPUCompilerParams"):
            cls = getattr(pltpu, name, None)
            if cls is not None:
                return {"compiler_params": cls(
                    dimension_semantics=("parallel", "parallel", "arbitrary"))}
    except ImportError:
        pass
    return {}


def _pack_out(n, k, b, blk_n, blk_k, out_spec, pack):
    """(out_specs, out_shapes) with the optional packed-words output."""
    out_specs = [out_spec]
    out_shapes = [jax.ShapeDtypeStruct((n, k), jnp.uint32)]
    if pack:
        if b <= 0 or 32 % b or (blk_k * b) % 32:
            raise ValueError(f"fused pack needs b | 32 and blk_k*b % 32 == 0, "
                             f"got b={b}, blk_k={blk_k}")
        out_specs.append(
            pl.BlockSpec((blk_n, blk_k * b // 32), lambda i, j, t: (i, j)))
        out_shapes.append(jax.ShapeDtypeStruct((n, k * b // 32), jnp.uint32))
    return out_specs, out_shapes


def minhash2u_pallas(indices: jax.Array, counts: jax.Array, a1: jax.Array,
                     a2: jax.Array, *, s: int, b: int = 0,
                     blk_n: int = 8, blk_t: int = 128, blk_k: int = 128,
                     variant: str = "high", pack: bool = False,
                     interpret: bool = True):
    """2U minhash signatures: (n, nnz) indices -> (n, k) uint32 minima.

    Args:
      indices: (n, max_nnz) int32, padded.
      counts:  (n, 1) int32 valid-lane counts per row.
      a1, a2:  (k,) uint32 multiply-shift coefficients (a2 odd).
      s:       D = 2^s.
      b:       if > 0, fuse lowest-b-bit extraction into the last step.
      pack:    also emit the bit-packed (n, k*b/32) words from the final
               grid step; returns ``(sig, packed)``.
    """
    n, nnz = indices.shape
    k = a1.shape[0]
    grid, counts_spec, idx_spec, out_spec = _common_grid_specs(
        n, nnz, k, blk_n, blk_t, blk_k)
    coeff_spec = pl.BlockSpec((1, blk_k), lambda i, j, t: (0, j))
    out_specs, out_shapes = _pack_out(n, k, b, blk_n, blk_k, out_spec, pack)
    kern = functools.partial(_minhash2u_kernel, s=s, b=b, blk_t=blk_t,
                             variant=variant, pack=pack)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[counts_spec, idx_spec, coeff_spec, coeff_spec],
        out_specs=out_specs if pack else out_specs[0],
        out_shape=out_shapes if pack else out_shapes[0],
        interpret=interpret,
        **_compiler_params(interpret),
    )(counts, indices, a1[None, :], a2[None, :])
    return out


def minhash4u_pallas(indices: jax.Array, counts: jax.Array, a: jax.Array, *,
                     s: int, b: int = 0, blk_n: int = 8, blk_t: int = 128,
                     blk_k: int = 128, pack: bool = False,
                     interpret: bool = True):
    """4U minhash signatures with in-kernel Mersenne BitMod (§3.4)."""
    n, nnz = indices.shape
    k = a.shape[1]
    grid, counts_spec, idx_spec, out_spec = _common_grid_specs(
        n, nnz, k, blk_n, blk_t, blk_k)
    coeff_spec = pl.BlockSpec((4, blk_k), lambda i, j, t: (0, j))
    out_specs, out_shapes = _pack_out(n, k, b, blk_n, blk_k, out_spec, pack)
    kern = functools.partial(_minhash4u_kernel, s=s, b=b, blk_t=blk_t,
                             pack=pack)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[counts_spec, idx_spec, coeff_spec],
        out_specs=out_specs if pack else out_specs[0],
        out_shape=out_shapes if pack else out_shapes[0],
        interpret=interpret,
        **_compiler_params(interpret),
    )(counts, indices, a)
    return out
