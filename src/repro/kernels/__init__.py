"""Pallas TPU kernels for the paper's compute hot-spot: minhash preprocessing.

  minhash.py  -- 2U / 4U minwise-hash signature kernels (the §3 GPU kernel,
                 re-derived for TPU: VMEM tiling, VPU lanes over hash
                 functions, running-min accumulation, in-kernel BitMod).
  oph.py      -- One Permutation Hashing kernels: the same running-min
                 reduction, but ONE hash evaluation per nonzero feeds all
                 k bins (k x less hash work than minhash.py).
  sigbag.py   -- Eq.(5) signature embedding-bag as one-hot MXU matmuls.
  ops.py      -- jitted public wrappers (padding, block choice, dispatch,
                 OPH densification epilogue).
  ref.py      -- pure-jnp oracles for allclose validation.
"""

from repro.kernels.ops import (batch_signatures, minhash2u, minhash4u,
                               oph2u, oph4u, sigbag)

__all__ = ["batch_signatures", "minhash2u", "minhash4u", "oph2u", "oph4u",
           "sigbag"]
