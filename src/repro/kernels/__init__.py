"""Pallas TPU kernels for the paper's compute hot-spot: minhash preprocessing.

  minhash.py  -- 2U / 4U minwise-hash signature kernels (the §3 GPU kernel,
                 re-derived for TPU: VMEM tiling, VPU lanes over hash
                 functions, running-min accumulation, in-kernel BitMod,
                 fused b-bit extraction + word packing in the final step).
  oph.py      -- One Permutation Hashing kernels: the same running-min
                 reduction, but ONE hash evaluation per nonzero feeds all
                 k bins (k x less hash work than minhash.py); fused
                 (b+1)-bit sentinel coding for the packed wire format.
  sigbag.py   -- Eq.(5) signature embedding-bag as one-hot MXU matmuls.
  hamming.py  -- packed-signature match counting for retrieval: b-bit
                 codes extracted in-register from the wire words,
                 sentinel-EMPTY aware (the repro.index scoring hot path).
  pack.py     -- the packed b-bit wire format (PackSpec, device pack /
                 unpack epilogues, in-kernel pack_block).
  engine.py   -- SignaturePlan / SignatureEngine: backend registry
                 (interpret / tpu / gpu / ref), JSON block-size tuning
                 table, padding/tiling, scheme dispatch, PackedSignatures.
  ops.py      -- legacy re-exports of the public wrappers.
  ref.py      -- pure-jnp oracles for allclose validation.

Only this package calls ``*_pallas`` builders; everything downstream goes
through the engine (or the legacy wrappers it backs).
"""

from repro.kernels.engine import (BACKENDS, HAMMING_BLOCKS, Backend,
                                  PackedSignatures, SignatureEngine,
                                  SignaturePlan, TuningTable,
                                  batch_signatures, default_tuning_table,
                                  minhash2u, minhash4u, oph2u, oph4u,
                                  register_backend, resolve_backend, sigbag)
from repro.kernels.hamming import packed_match
from repro.kernels.pack import PackSpec

__all__ = [
    "BACKENDS", "Backend", "HAMMING_BLOCKS", "PackSpec", "PackedSignatures",
    "SignatureEngine", "SignaturePlan", "TuningTable", "batch_signatures",
    "default_tuning_table", "minhash2u", "minhash4u", "oph2u", "oph4u",
    "packed_match", "register_backend", "resolve_backend", "sigbag",
]
