"""Pallas TPU kernel for packed-signature match counting (retrieval).

The search workload (paper §1's dedup/crawling pipeline; Li-Owen-Zhang,
arXiv:1208.1259, "...for Efficient Search and Learning") scores a batch
of query signatures against a corpus block: for every (query, doc) pair,
how many of the k b-bit codes agree?  That count is the collision
fraction P̂_b behind the Theorem-1 resemblance estimate, so this kernel
is the entire scoring hot path of ``repro.index``.

Both operands arrive in the packed wire format (``kernels/pack.py``:
k codes of ``code_bits`` each, little-endian bitstream in uint32 words
-- (b+1)-bit codes with EMPTY = 2^b for sentinel OPH).  The kernel never
round-trips through an unpacked (n, k) matrix in HBM: each grid step
DMA's a word tile, extracts its codes in-register, and accumulates match
counts into the revisited (BLK_Q, BLK_N) output block.

Grid = (Q/BLK_Q, N/BLK_N, k_pad/BLK_K) with the last axis accumulating
(the same "parallel, parallel, arbitrary" reduction pattern as the
signature kernels).  BLK_K must be a multiple of 32 so every code block
starts on a word boundary and its words form a clean BlockSpec tile of
BLK_K*code_bits/32 lanes.

For sentinel OPH the kernel also counts jointly-EMPTY positions, so the
caller can apply the Li-Owen-Zhang normalization
N_match / (k - N_jointly_empty) without ever unpacking.

Backend selection / block sizes come from the ``SignatureEngine``
registry (``repro.kernels.engine``): the public wrapper ``packed_match``
resolves a Backend (interpret / tpu run this kernel; gpu / ref run the
``kernels/ref.py`` oracle) and looks up ``TuningTable`` entries under
scheme ``"hamming"`` keyed on the packed word count.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.bbit import packed_words
from repro.kernels.minhash import _compiler_params
from repro.kernels.pack import PackSpec

_U32 = jnp.uint32


def _extract_codes(words, code_bits: int, blk_k: int):
    """(rows, BW) word tile -> (rows, BLK_K) uint32 codes, in-register.

    The tile starts on a word boundary (BLK_K % 32 == 0 guarantees every
    code block does), so local code i occupies bits
    [i*code_bits, (i+1)*code_bits) of the tile's bitstream.  Same
    two-shift word-straddle arithmetic as ``repro.core.bbit.unpack_codes``
    (no undefined shift-by-32), traced here inside the kernel.
    """
    bw = words.shape[-1]
    i = jnp.arange(blk_k, dtype=jnp.uint32)
    bit0 = i * _U32(code_bits)
    wlo = (bit0 >> 5).astype(jnp.int32)
    sh = bit0 & _U32(31)
    lo = jnp.take(words, wlo, axis=1) >> sh
    hi = (jnp.take(words, jnp.minimum(wlo + 1, bw - 1), axis=1)
          << (_U32(31) - sh)) << _U32(1)
    out = lo | hi
    if code_bits < 32:
        out = out & _U32((1 << code_bits) - 1)
    return out


def _hamming_kernel(q_ref, c_ref, match_ref, *empty_refs, k: int,
                    code_bits: int, blk_k: int, sentinel: bool):
    t_step = pl.program_id(2)
    n_t = pl.num_programs(2)

    @pl.when(t_step == 0)
    def _init():
        match_ref[...] = jnp.zeros_like(match_ref)
        if sentinel:
            empty_refs[0][...] = jnp.zeros_like(empty_refs[0])

    qc = _extract_codes(q_ref[...], code_bits, blk_k)      # (BLK_Q, BLK_K)
    cc = _extract_codes(c_ref[...], code_bits, blk_k)      # (BLK_N, BLK_K)
    # global code index: padding codes past k never count
    valid = (jax.lax.broadcasted_iota(jnp.int32, (1, 1, blk_k), 2)
             + t_step * blk_k) < k
    eq = (qc[:, None, :] == cc[None, :, :]) & valid
    if sentinel:
        ec = _U32(1 << (code_bits - 1))                    # EMPTY = 2^b
        both = ((qc == ec)[:, None, :] & (cc == ec)[None, :, :]) & valid
        eq = eq & ~both
        empty_refs[0][...] = (empty_refs[0][...]
                              + jnp.sum(both.astype(jnp.int32), axis=2))
    match_ref[...] = match_ref[...] + jnp.sum(eq.astype(jnp.int32), axis=2)


def packed_match_pallas(qwords: jax.Array, cwords: jax.Array, *, k: int,
                        code_bits: int, sentinel: bool = False,
                        blk_q: int = 8, blk_n: int = 128, blk_k: int = 128,
                        interpret: bool = True):
    """Match counts between packed query and corpus signatures.

    Args:
      qwords: (Q, W) uint32 packed query signatures.
      cwords: (N, W) uint32 packed corpus signatures (same wire format).
      k, code_bits, sentinel: the wire format (``PackSpec``).
      blk_q, blk_n: output tile; blk_k: codes per reduction step
        (must be a multiple of 32 so word tiles align).

    Q, N and W must tile (pad in the caller: zero words decode to code 0
    but the in-kernel ``valid`` mask keeps codes past k out of every
    count; padded *rows* produce garbage counts the caller slices off).

    Returns (Q, N) int32 match counts; for ``sentinel=True`` a tuple
    ``(matches, both_empty)`` where matches already excludes jointly-EMPTY
    positions (the Li-Owen-Zhang numerator) and both_empty counts them
    (the denominator correction).
    """
    if blk_k % 32:
        raise ValueError(f"blk_k must be a multiple of 32 so code blocks "
                         f"align to word boundaries, got {blk_k}")
    q, w = qwords.shape
    n, wc = cwords.shape
    if wc != w:
        raise ValueError(f"query words {w} != corpus words {wc}")
    bw = blk_k * code_bits // 32
    if q % blk_q or n % blk_n or w % bw:
        raise ValueError(f"shapes must tile: Q={q}%{blk_q}, N={n}%{blk_n}, "
                         f"W={w}%{bw} (= blk_k*code_bits/32)")
    grid = (q // blk_q, n // blk_n, w // bw)
    q_spec = pl.BlockSpec((blk_q, bw), lambda i, j, t: (i, t))
    c_spec = pl.BlockSpec((blk_n, bw), lambda i, j, t: (j, t))
    out_spec = pl.BlockSpec((blk_q, blk_n), lambda i, j, t: (i, j))
    out_shape = jax.ShapeDtypeStruct((q, n), jnp.int32)
    kern = functools.partial(_hamming_kernel, k=k, code_bits=code_bits,
                             blk_k=blk_k, sentinel=sentinel)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[q_spec, c_spec],
        out_specs=[out_spec, out_spec] if sentinel else out_spec,
        out_shape=[out_shape, out_shape] if sentinel else out_shape,
        interpret=interpret,
        **_compiler_params(interpret),
    )(qwords, cwords)
    return out


@functools.partial(jax.jit, static_argnames=("k", "code_bits", "sentinel",
                                             "backend", "blk_q", "blk_n",
                                             "blk_k"))
def _packed_match_run(qwords, cwords, *, k, code_bits, sentinel, backend,
                      blk_q, blk_n, blk_k):
    from repro.kernels import ref as kref
    from repro.kernels.engine import BACKENDS, _pad_axis
    q, n = qwords.shape[0], cwords.shape[0]
    be = BACKENDS[backend]
    if not be.use_pallas:
        return kref.packed_match_ref(qwords, cwords, k=k,
                                     code_bits=code_bits, sentinel=sentinel)
    bw = blk_k * code_bits // 32
    qp = _pad_axis(_pad_axis(qwords, blk_q, 0), bw, 1)
    cp = _pad_axis(_pad_axis(cwords, blk_n, 0), bw, 1)
    out = packed_match_pallas(qp, cp, k=k, code_bits=code_bits,
                              sentinel=sentinel, blk_q=blk_q, blk_n=blk_n,
                              blk_k=blk_k, interpret=be.interpret)
    if sentinel:
        return out[0][:q, :n], out[1][:q, :n]
    return out[:q, :n]


def packed_match(qwords: jax.Array, cwords: jax.Array, spec: PackSpec, *,
                 backend: Optional[str] = None, blocks: Optional[dict] = None,
                 tuning=None) -> Union[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Match counts between packed signature batches (the query hot path).

    ``spec`` is the shared wire format; ``backend`` resolves through the
    ``SignatureEngine`` registry ("auto" per hardware; interpret/tpu run
    the Pallas kernel, gpu/ref the jnp oracle).  Block sizes come from
    explicit ``blocks`` > ``TuningTable`` entry (scheme ``"hamming"``,
    keyed on the packed word count) > ``HAMMING_BLOCKS`` defaults.

    Returns (Q, N) int32 matches, or ``(matches, both_empty)`` for
    sentinel wires (see ``packed_match_pallas``).
    """
    from repro.kernels.engine import (HAMMING_BLOCKS, default_tuning_table,
                                      resolve_backend)
    words = packed_words(spec.k, spec.code_bits)
    if qwords.shape[-1] != words or cwords.shape[-1] != words:
        raise ValueError(
            f"packed operands have {qwords.shape[-1]}/{cwords.shape[-1]} "
            f"words, spec (k={spec.k}, code_bits={spec.code_bits}) "
            f"needs {words}")
    be = resolve_backend(backend)
    if not blocks:
        table = tuning or default_tuning_table()
        blocks = (table.lookup(be.name, "hamming", spec.k, words)
                  or dict(HAMMING_BLOCKS))
    return _packed_match_run(qwords, cwords, k=spec.k,
                             code_bits=spec.code_bits, sentinel=spec.sentinel,
                             backend=be.name, blk_q=blocks["blk_q"],
                             blk_n=blocks["blk_n"], blk_k=blocks["blk_k"])
