"""Pure-jnp oracles for every Pallas kernel in this package.

Each function computes the same value as its kernel with no Pallas
machinery; kernel tests sweep shapes/dtypes and assert exact (integer) or
allclose (float) agreement in interpret mode.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.hashing import hash2u_apply, hash4u_apply

_PAD = jnp.uint32(0xFFFFFFFF)


def minhash2u_ref(indices: jax.Array, counts: jax.Array, a1: jax.Array,
                  a2: jax.Array, *, s: int, b: int = 0,
                  variant: str = "high") -> jax.Array:
    """(n, nnz) x (k,) -> (n, k) uint32 minima (optionally b-bit masked)."""
    col = jnp.arange(indices.shape[1])[None, :]
    valid = col < counts                                     # (n, nnz)
    h = hash2u_apply(indices[..., None], a1, a2, s, variant)  # (n, nnz, k)
    h = jnp.where(valid[..., None], h, _PAD)
    out = jnp.min(h, axis=1)
    if b > 0:
        out = out & jnp.uint32((1 << b) - 1)
    return out


def minhash4u_ref(indices: jax.Array, counts: jax.Array, a: jax.Array, *,
                  s: int, b: int = 0) -> jax.Array:
    col = jnp.arange(indices.shape[1])[None, :]
    valid = col < counts
    h = hash4u_apply(indices[..., None], a[0], a[1], a[2], a[3], s, True)
    h = jnp.where(valid[..., None], h, _PAD)
    out = jnp.min(h, axis=1)
    if b > 0:
        out = out & jnp.uint32((1 << b) - 1)
    return out


def _oph_binned_min_ref(h: jax.Array, counts: jax.Array, *, s: int,
                        bin_bits: int, k_lanes: int) -> jax.Array:
    """Shared OPH oracle: hash values -> (n, k_lanes) sentinel bin minima."""
    from repro.core.oph import split_hash
    n, nnz = h.shape
    col = jnp.arange(nnz)[None, :]
    valid = col < counts                                     # (n, nnz)
    bins, offs = split_hash(h, s, bin_bits)
    offs = jnp.where(valid, offs, _PAD)
    bins = jnp.where(valid, bins, 0).astype(jnp.int32)
    return jnp.full((n, k_lanes), _PAD).at[
        jnp.arange(n)[:, None], bins].min(offs)


def oph2u_ref(indices: jax.Array, counts: jax.Array, a1: jax.Array,
              a2: jax.Array, *, s: int, bin_bits: int, k_lanes: int,
              variant: str = "high") -> jax.Array:
    """Oracle for ``oph2u_pallas``: raw sentinel-coded bin minima."""
    h = hash2u_apply(indices[..., None], a1, a2, s, variant)[..., 0]
    return _oph_binned_min_ref(h, counts, s=s, bin_bits=bin_bits,
                               k_lanes=k_lanes)


def oph4u_ref(indices: jax.Array, counts: jax.Array, a: jax.Array, *,
              s: int, bin_bits: int, k_lanes: int) -> jax.Array:
    """Oracle for ``oph4u_pallas``: raw sentinel-coded bin minima."""
    h = hash4u_apply(indices[..., None], a[0], a[1], a[2], a[3], s,
                     True)[..., 0]
    return _oph_binned_min_ref(h, counts, s=s, bin_bits=bin_bits,
                               k_lanes=k_lanes)


def sigbag_ref(tokens: jax.Array, table: jax.Array) -> jax.Array:
    """out[i] = sum_j table[j, tokens[i, j]] (fp32 accumulation)."""
    k = tokens.shape[1]
    # gather per slot then sum: (n, k, d) -> (n, d)
    gathered = jnp.take_along_axis(
        table[None],                                   # (1, k, 2^b, d)
        tokens[:, :, None, None].astype(jnp.int32),    # (n, k, 1, 1)
        axis=2,
    )[:, :, 0, :]
    return jnp.sum(gathered.astype(jnp.float32), axis=1).astype(table.dtype)


def packed_match_ref(qwords: jax.Array, cwords: jax.Array, *, k: int,
                     code_bits: int, sentinel: bool = False):
    """Oracle for ``packed_match_pallas``: unpack (on device) + compare.

    Returns (Q, N) int32 match counts; sentinel wires additionally return
    the jointly-EMPTY counts: ``(matches, both_empty)`` with matches
    excluding jointly-EMPTY positions (Li-Owen-Zhang numerator).
    """
    from repro.core.bbit import unpack_codes
    qc = unpack_codes(qwords, code_bits, k)            # (Q, k)
    cc = unpack_codes(cwords, code_bits, k)            # (N, k)
    eq = qc[:, None, :] == cc[None, :, :]
    if sentinel:
        ec = jnp.uint32(1 << (code_bits - 1))
        both = (qc == ec)[:, None, :] & (cc == ec)[None, :, :]
        matches = jnp.sum((eq & ~both).astype(jnp.int32), axis=2)
        return matches, jnp.sum(both.astype(jnp.int32), axis=2)
    return jnp.sum(eq.astype(jnp.int32), axis=2)
