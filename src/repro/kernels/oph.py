"""Pallas TPU kernels for One Permutation Hashing signatures.

Same (parallel, parallel, arbitrary) running-min reduction as
``kernels/minhash.py`` -- grid (n/BLK_N, k/BLK_K, nnz/BLK_T), the last
axis accumulating into a revisited (BLK_N, BLK_K) output block -- but the
hash work per nonzero collapses from k evaluations to ONE: a single 2U/4U
function is evaluated on the (BLK_N, BLK_T) index tile, split into
(bin, offset) bit-fields, and the offset competes only in its bin's lane
(a lane-iota compare instead of k - 1 extra hash evaluations).

Hash evaluations per nonzero = ceil(k / BLK_K): with the default BLK_K
covering all k bins at once (k <= 512 fits one block column), that is
literally one pass, versus k passes for the minhash kernels -- the
paper's §3 preprocessing cost divided by k.

Empty bins come out as the 0xFFFFFFFF sentinel; densification (and b-bit
extraction, which must not destroy the sentinel before densification
reads it) happens in the thin jnp epilogue in ``kernels/engine.py``,
shared bit-for-bit with the ``core/oph.py`` reference.  For the packed
*sentinel* wire format, ``code_b > 0`` moves that b-bit step into the
kernel's final grid iteration: genuine minima are masked to b bits and
EMPTY becomes the (b+1)-bit code 2^b (``repro.kernels.pack.PackSpec``),
so the epilogue only has to bitstream-pack the codes.

Paper mapping:
  * §3.2-§3.3 (the GPU chunk kernel, re-derived for TPU): grid layout,
    VMEM tiling, running-min accumulation over the nnz axis,
  * Eq. (10) / §3.4: the in-kernel 2U multiply-shift (``_oph2u_kernel``)
    and 4U Horner + Mersenne ``BitMod`` (``_oph4u_kernel``) -- identical
    arithmetic to ``kernels/minhash.py``, evaluated ONCE per nonzero,
  * arXiv:1208.1259 §3: the bin/offset bit-split (``_binned_min``), high
    bits select the bin, low bits compete in the running min.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.hashing import add64, mod_mersenne31, umul32_wide
from repro.kernels.minhash import _common_grid_specs, _compiler_params

_U32 = jnp.uint32
_EMPTY = np.uint32(0xFFFFFFFF)


def _binned_min(h, valid, out_ref, *, s: int, bin_bits: int, blk_k: int):
    """Shared epilogue: split hash -> (bin, offset), min into bin lanes.

    h: (BLK_N, BLK_T) uint32 hash values in [0, 2^s); lanes where
    ``valid`` is False never win.  Updates the running-min out block.
    """
    j_step = pl.program_id(1)
    off_bits = s - bin_bits
    if bin_bits > 0:
        bins = (h >> _U32(off_bits)).astype(jnp.int32)
    else:
        bins = jnp.zeros(h.shape, jnp.int32)
    offs = h & _U32((1 << off_bits) - 1)
    # lane j of this block owns global bin j_step * BLK_K + j
    jb = (jax.lax.broadcasted_iota(jnp.int32, h.shape + (blk_k,), 2)
          + j_step * blk_k)
    match = (bins[..., None] == jb) & valid[..., None]
    v = jnp.where(match, offs[..., None], _EMPTY)     # (BLK_N, BLK_T, BLK_K)
    out_ref[...] = jnp.minimum(out_ref[...], jnp.min(v, axis=1))


def _sentinel_codes(out_ref, code_b: int):
    """Final-step epilogue: b-bit values + EMPTY -> (b+1)-bit codes."""
    t_step = pl.program_id(2)
    n_t = pl.num_programs(2)

    @pl.when(t_step == n_t - 1)
    def _codes():
        v = out_ref[...]
        out_ref[...] = jnp.where(v == _EMPTY, _U32(1 << code_b),
                                 v & _U32((1 << code_b) - 1))


def _oph2u_kernel(counts_ref, idx_ref, a1_ref, a2_ref, out_ref, *,
                  s: int, bin_bits: int, blk_t: int, blk_k: int,
                  variant: str, code_b: int = 0):
    t_step = pl.program_id(2)

    @pl.when(t_step == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, _EMPTY)

    idx = idx_ref[...]                                    # (BLK_N, BLK_T) i32
    counts = counts_ref[...]                              # (BLK_N, 1) i32
    col = jax.lax.broadcasted_iota(jnp.int32, idx.shape, 1) + t_step * blk_t
    valid = col < counts

    # ONE multiply-shift evaluation for the whole tile (scalar coefficients)
    a1 = a1_ref[0, 0]
    a2 = a2_ref[0, 0]
    h = a1 + a2 * idx.astype(_U32)                        # wraps mod 2^32
    if s < 32:
        if variant == "high":
            h = h >> _U32(32 - s)
        else:
            h = h & _U32((1 << s) - 1)
    _binned_min(h, valid, out_ref, s=s, bin_bits=bin_bits, blk_k=blk_k)
    if code_b > 0:
        _sentinel_codes(out_ref, code_b)


def _oph4u_kernel(counts_ref, idx_ref, a_ref, out_ref, *,
                  s: int, bin_bits: int, blk_t: int, blk_k: int,
                  code_b: int = 0):
    t_step = pl.program_id(2)

    @pl.when(t_step == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, _EMPTY)

    idx = idx_ref[...]
    counts = counts_ref[...]
    col = jax.lax.broadcasted_iota(jnp.int32, idx.shape, 1) + t_step * blk_t
    valid = col < counts

    # ONE Horner chain (scalar coefficients) with in-kernel Mersenne BitMod
    a = a_ref[...]                                        # (4, 1) u32
    t = idx.astype(_U32)                                  # (BLK_N, BLK_T)
    acc = jnp.full(t.shape, a[3, 0], _U32)
    for i in (2, 1, 0):
        hi, lo = umul32_wide(acc, t)                      # acc * t < 2^62
        hi, lo = add64(hi, lo, jnp.full(lo.shape, a[i, 0], _U32))
        acc = mod_mersenne31(hi, lo)
    if s < 31:
        acc = acc & _U32((1 << s) - 1)
    _binned_min(acc, valid, out_ref, s=s, bin_bits=bin_bits, blk_k=blk_k)
    if code_b > 0:
        _sentinel_codes(out_ref, code_b)


def oph2u_pallas(indices: jax.Array, counts: jax.Array, a1: jax.Array,
                 a2: jax.Array, *, s: int, bin_bits: int,
                 blk_n: int = 8, blk_t: int = 128, blk_k: int = 128,
                 variant: str = "high", code_b: int = 0,
                 interpret: bool = True) -> jax.Array:
    """2U OPH: (n, nnz) indices -> (n, k_lanes) sentinel-coded bin minima.

    Args:
      indices:  (n, max_nnz) int32, padded; n, nnz, k_lanes must tile.
      counts:   (n, 1) int32 valid-lane counts per row.
      a1, a2:   (1,) uint32 -- the ONE multiply-shift function (a2 odd).
      s:        D = 2^s.
      bin_bits: log2(number of real bins); lanes >= 2^bin_bits never match
                and come out EMPTY (callers slice them off).
      code_b:   if > 0, the final grid step emits (code_b+1)-bit sentinel
                codes (EMPTY -> 2^code_b) instead of raw minima -- the
                packed-wire-format epilogue fused into the kernel.
    """
    n, nnz = indices.shape
    k_lanes = blk_k * max(1, (1 << bin_bits) // blk_k)
    grid, counts_spec, idx_spec, out_spec = _common_grid_specs(
        n, nnz, k_lanes, blk_n, blk_t, blk_k)
    coeff_spec = pl.BlockSpec((1, 1), lambda i, j, t: (0, 0))
    kern = functools.partial(_oph2u_kernel, s=s, bin_bits=bin_bits,
                             blk_t=blk_t, blk_k=blk_k, variant=variant,
                             code_b=code_b)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[counts_spec, idx_spec, coeff_spec, coeff_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((n, k_lanes), jnp.uint32),
        interpret=interpret,
        **_compiler_params(interpret),
    )(counts, indices, a1.reshape(1, 1), a2.reshape(1, 1))


def oph4u_pallas(indices: jax.Array, counts: jax.Array, a: jax.Array, *,
                 s: int, bin_bits: int, blk_n: int = 8, blk_t: int = 128,
                 blk_k: int = 128, code_b: int = 0,
                 interpret: bool = True) -> jax.Array:
    """4U OPH with in-kernel Mersenne BitMod; a: (4, 1) uint32."""
    n, nnz = indices.shape
    k_lanes = blk_k * max(1, (1 << bin_bits) // blk_k)
    grid, counts_spec, idx_spec, out_spec = _common_grid_specs(
        n, nnz, k_lanes, blk_n, blk_t, blk_k)
    coeff_spec = pl.BlockSpec((4, 1), lambda i, j, t: (0, 0))
    kern = functools.partial(_oph4u_kernel, s=s, bin_bits=bin_bits,
                             blk_t=blk_t, blk_k=blk_k, code_b=code_b)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[counts_spec, idx_spec, coeff_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((n, k_lanes), jnp.uint32),
        interpret=interpret,
        **_compiler_params(interpret),
    )(counts, indices, a.reshape(4, 1))
