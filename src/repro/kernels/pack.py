"""Packed b-bit wire format for signatures: spec + device-side epilogues.

The paper's §6/Table-2 systems claim is that b-bit hashing shrinks what
*moves*: k·b bits per example on the wire and on disk, not k uint32
lanes.  This module defines that wire format once so the kernels, the
engine, the cache shards and the learning layer all agree:

  * ``PackSpec``        -- (k, b, sentinel) -> code width and word count.
                           Plain signatures pack b-bit codes; sentinel
                           OPH packs (b+1)-bit codes with EMPTY stored as
                           the value 2^b (no aliasing with genuine b-bit
                           values, no unpacked escape hatch).
  * ``encode_sentinel`` / ``decode_sentinel`` -- EMPTY <-> 2^b mapping.
  * ``pack_device`` / ``unpack_device`` -- jnp pack/unpack epilogues,
    meant to be traced *inside* the same jit as the kernel (pack) or the
    SGD step (unpack) so only packed words ever cross the host boundary.
  * ``pack_block`` -- the in-kernel packing epilogue: packs a
    (BLK_N, BLK_K) b-bit tile into (BLK_N, BLK_K*b/32) words in the
    kernel's final grid step (used by ``kernels/minhash.py`` when the
    signature length is lane-aligned).

Bit layout (shared with ``repro.core.bbit.pack_codes``): code j occupies
bits [j*code_bits, (j+1)*code_bits) of the row's little-endian bitstream.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.bbit import (pack_codes, pack_signatures, packed_words,
                             unpack_codes)
from repro.core.oph import EMPTY


@dataclasses.dataclass(frozen=True)
class PackSpec:
    """Static description of one packed-signature wire format."""

    k: int                 # signature length (values per example)
    b: int                 # b-bit width of genuine values
    sentinel: bool = False  # True: OPH sentinel scheme, EMPTY coded as 2^b

    def __post_init__(self):
        if not 1 <= self.b <= 16:
            raise ValueError(f"packed wire format needs 1 <= b <= 16, "
                             f"got b={self.b}")

    @property
    def code_bits(self) -> int:
        return self.b + 1 if self.sentinel else self.b

    @property
    def words(self) -> int:
        return packed_words(self.k, self.code_bits)

    @property
    def empty_code(self) -> int:
        return 1 << self.b

    def bytes_per_example(self) -> int:
        return 4 * self.words


def encode_sentinel(sig: jax.Array, b: int) -> jax.Array:
    """b-bit values with EMPTY markers -> (b+1)-bit codes (EMPTY = 2^b)."""
    mask_b = jnp.uint32((1 << b) - 1)
    return jnp.where(sig == EMPTY, jnp.uint32(1 << b),
                     sig.astype(jnp.uint32) & mask_b)


def decode_sentinel(codes: jax.Array, b: int) -> jax.Array:
    """(b+1)-bit codes -> b-bit values with EMPTY restored."""
    return jnp.where(codes == jnp.uint32(1 << b), EMPTY,
                     codes.astype(jnp.uint32))


def pack_device(sig: jax.Array, spec: PackSpec) -> jax.Array:
    """(n, k) signature values -> (n, spec.words) uint32 words.

    ``sig`` carries b-bit values (sentinel schemes: b-bit values + EMPTY
    markers).  Trace this inside the kernel wrapper's jit so the packed
    words are what leaves the device.
    """
    if sig.shape[-1] != spec.k:
        raise ValueError(f"sig has k={sig.shape[-1]}, spec has k={spec.k}")
    codes = encode_sentinel(sig, spec.b) if spec.sentinel else sig
    return pack_codes(codes, spec.code_bits)


def unpack_device(packed: jax.Array, spec: PackSpec) -> jax.Array:
    """(n, spec.words) uint32 words -> (n, k) values, EMPTY restored."""
    codes = unpack_codes(packed, spec.code_bits, spec.k)
    return decode_sentinel(codes, spec.b) if spec.sentinel else codes


def can_pack_in_kernel(k_pad: int, k: int, b: int, blk_k: int) -> bool:
    """True when the kernel's final grid step can emit packed words
    directly: lane-aligned codes (b | 32), no sliced padding lanes, and
    whole words per k-block."""
    return (0 < b <= 16 and 32 % b == 0 and k_pad == k
            and (blk_k * b) % 32 == 0)


def pack_block(tile: jax.Array, b: int) -> jax.Array:
    """In-kernel epilogue: (BLK_N, BLK_K) b-bit tile -> packed words.

    Requires b | 32 and BLK_K*b % 32 == 0 (``can_pack_in_kernel``), under
    which the lane-aligned ``repro.core.bbit.pack_signatures`` layout
    coincides bit-for-bit with the ``pack_codes`` bitstream, so host-side
    unpacking is one shared code path regardless of where the packing
    ran.  (Plain reshape/shift/sum -- traces fine inside Pallas.)
    """
    return pack_signatures(tile, b)
