"""Unified SignatureEngine: backend-aware kernel dispatch + packed wire.

This module is the ONE seam between hashing schemes and hardware:

  * ``SignaturePlan``  -- a frozen description of a signature computation:
    scheme x family x (k, s, b, densify) x block sizes x backend x wire
    format.  Everything static; the arrays live in the hash family.
  * ``Backend`` / ``BACKENDS`` -- the execution registry.  ``interpret``
    runs the Pallas kernels in interpret mode (CPU / CI), ``tpu`` runs
    them compiled, ``gpu`` is the pallas-triton entry that falls back to
    the jnp reference until the triton lowering lands, ``ref`` forces the
    pure-jnp oracles.  ``auto`` resolves per ``jax.default_backend()``.
    This replaces the scattered ``interpret=not _on_tpu()`` flags.
  * ``TuningTable``    -- JSON-persisted block-size table keyed on
    (backend, scheme, k, nnz-bucket), the hook for the ROADMAP TPU/GPU tuning
    items; ships with seed defaults in ``tuning_table.json``.
  * ``SignatureEngine`` -- owns padding/tiling and scheme dispatch
    (a registry keyed on (scheme, family) -- no isinstance chains), and
    emits either unpacked (n, k) signatures or the packed wire format.
  * ``PackedSignatures`` -- the wire format itself: k*b bits per example
    ((b+1)-bit codes for sentinel OPH, EMPTY stored as 2^b), produced
    inside the kernel jit so only packed words cross the host boundary.

``repro.kernels.ops`` re-exports the legacy wrappers (``minhash2u``,
``oph2u``, ``batch_signatures``, ...) from here; no module outside
``repro/kernels/`` touches a ``*_pallas`` builder directly.
"""

from __future__ import annotations

import dataclasses
import functools
import json
import os
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.bbit import pack_codes
from repro.core.hashing import Hash2U, Hash4U, PermutationFamily
from repro.core.oph import OPH, densify_and_bbit, oph_signatures
from repro.data.sparse import SparseBatch
from repro.kernels import ref as kref
from repro.kernels.minhash import minhash2u_pallas, minhash4u_pallas
from repro.kernels.oph import oph2u_pallas, oph4u_pallas
from repro.kernels.pack import (PackSpec, can_pack_in_kernel, encode_sentinel,
                                pack_device, unpack_device)
from repro.kernels.sigbag import sigbag_pallas


# ---------------------------------------------------------------------------
# Backend registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Backend:
    """One way to execute the signature kernels.

    ``use_pallas=False`` routes to the pure-jnp oracles in
    ``kernels/ref.py`` (bit-exact by the kernel test suite); otherwise
    ``interpret`` selects Pallas interpret vs compiled mode.
    """

    name: str
    use_pallas: bool
    interpret: bool
    notes: str = ""


BACKENDS: Dict[str, Backend] = {}


def register_backend(backend: Backend) -> Backend:
    BACKENDS[backend.name] = backend
    return backend


register_backend(Backend("interpret", True, True,
                         "Pallas interpret mode (CPU hosts, CI)"))
register_backend(Backend("tpu", True, False,
                         "compiled Pallas TPU (Mosaic)"))
register_backend(Backend("gpu", False, False,
                         "pallas-triton lowering pending (ROADMAP); "
                         "falls back to the jnp reference"))
register_backend(Backend("ref", False, False,
                         "pure-jnp oracles (kernels/ref.py)"))


def resolve_backend(name: Optional[str] = None) -> Backend:
    """Map a backend name (or None/"auto") to a registered Backend."""
    if name is None or name == "auto":
        plat = jax.default_backend()
        name = plat if plat in ("tpu", "gpu") else "interpret"
    try:
        return BACKENDS[name]
    except KeyError:
        raise ValueError(f"unknown backend {name!r}; registered: "
                         f"{sorted(BACKENDS)}") from None


# ---------------------------------------------------------------------------
# Block-size tuning table
# ---------------------------------------------------------------------------

MINHASH_BLOCKS = {"blk_n": 8, "blk_t": 128, "blk_k": 128}
OPH_BLOCKS = {"blk_n": 8, "blk_t": 128, "blk_k": 0}     # blk_k 0 = all-lane
# retrieval scoring (kernels/hamming.py): query x corpus output tile +
# codes per reduction step; table entries keyed on the packed word count
HAMMING_BLOCKS = {"blk_q": 8, "blk_n": 128, "blk_k": 128}


def nnz_bucket(nnz: int) -> int:
    """Bucket a padded nnz width to the next power of two (>= 128)."""
    return max(128, 1 << max(0, int(nnz) - 1).bit_length())


class TuningTable:
    """JSON-persisted block-size choices keyed on
    (backend, scheme, k, nnz-bucket).

    The seam for the ROADMAP "tune (BLK_N, BLK_T, BLK_K) on real TPU"
    item: a profiling run records winners with ``record`` + ``save``, and
    every engine on that host picks them up via ``lookup``.  Unknown keys
    fall back to the per-scheme defaults, so the table is always
    optional.  The scheme is part of the key because block conventions
    differ (``blk_k=0`` means "all bins in one lane block" for OPH but
    is invalid for minhash).  The retrieval kernel registers as scheme
    ``"hamming"`` with (blk_q, blk_n, blk_k) blocks keyed on the packed
    word count instead of nnz (``repro.kernels.hamming.packed_match``).
    """

    def __init__(self, entries: Optional[dict] = None,
                 path: Optional[str] = None):
        self.entries = dict(entries or {})
        self.path = path

    @staticmethod
    def key(backend: str, scheme: str, k: int, bucket: int) -> str:
        return f"{backend}/{scheme}/k={k}/nnz<={bucket}"

    def lookup(self, backend: str, scheme: str, k: int,
               nnz: int) -> Optional[dict]:
        return self.entries.get(
            self.key(backend, scheme, k, nnz_bucket(nnz)))

    def record(self, backend: str, scheme: str, k: int, nnz: int,
               blocks: dict) -> None:
        self.entries[self.key(backend, scheme, k, nnz_bucket(nnz))] = \
            dict(blocks)

    def save(self, path: Optional[str] = None) -> str:
        path = path or self.path
        if not path:
            raise ValueError("no path given and table has none")
        with open(path, "w") as f:
            json.dump({"version": 1, "entries": self.entries}, f, indent=2,
                      sort_keys=True)
        self.path = path
        return path

    @staticmethod
    def load(path: str) -> "TuningTable":
        with open(path) as f:
            doc = json.load(f)
        return TuningTable(doc.get("entries", {}), path=path)


_DEFAULT_TABLE: Optional[TuningTable] = None


def default_tuning_table() -> TuningTable:
    """The process-wide table: ``$REPRO_TUNING_TABLE`` if set, else the
    packaged ``tuning_table.json`` seed defaults."""
    global _DEFAULT_TABLE
    if _DEFAULT_TABLE is None:
        path = os.environ.get("REPRO_TUNING_TABLE") or os.path.join(
            os.path.dirname(__file__), "tuning_table.json")
        _DEFAULT_TABLE = (TuningTable.load(path) if os.path.exists(path)
                          else TuningTable())
    return _DEFAULT_TABLE


# ---------------------------------------------------------------------------
# Wire format
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PackedSignatures:
    """Bit-packed signatures: (n, words) uint32, k*code_bits bits/example.

    The device-to-host / disk / SGD wire format.  ``sentinel=True`` means
    (b+1)-bit codes with EMPTY stored as 2^b; ``unpack`` restores the
    exact (n, k) uint32 signatures (EMPTY marker included).  Registered
    as a pytree (data leaf + static meta) so it can cross jit boundaries.
    """

    data: jax.Array          # (n, words) uint32
    k: int
    b: int
    sentinel: bool = False

    @property
    def spec(self) -> PackSpec:
        return PackSpec(self.k, self.b, self.sentinel)

    @property
    def code_bits(self) -> int:
        return self.spec.code_bits

    @property
    def n(self) -> int:
        return self.data.shape[0]

    @property
    def nbytes(self) -> int:
        return int(self.data.size) * 4

    def unpack(self) -> jax.Array:
        """(n, k) uint32 signatures, EMPTY restored for sentinel codes."""
        return unpack_device(self.data, self.spec)

    def __getitem__(self, idx) -> "PackedSignatures":
        return PackedSignatures(self.data[idx], self.k, self.b, self.sentinel)

    def __len__(self) -> int:
        return self.n


jax.tree_util.register_pytree_node(
    PackedSignatures,
    lambda p: ((p.data,), (p.k, p.b, p.sentinel)),
    lambda meta, children: PackedSignatures(children[0], *meta))


# ---------------------------------------------------------------------------
# Plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SignaturePlan:
    """Static description of one signature computation (no arrays)."""

    scheme: str                  # "minhash" | "oph"
    family: str                  # "2u" | "4u" | "perm"
    k: int
    s: int
    b: int = 0
    densify: Optional[str] = None   # OPH only
    variant: str = "high"           # 2U only
    backend: str = "interpret"      # resolved Backend name
    blk_n: int = 8
    blk_t: int = 128
    blk_k: int = 128                # OPH: 0 = all bins in one lane block
    packed: bool = False

    @property
    def sentinel(self) -> bool:
        return self.densify == "sentinel"

    @property
    def pack_spec(self) -> PackSpec:
        return PackSpec(self.k, self.b, self.sentinel)


def _family_statics(family) -> dict:
    """The single isinstance seam: hash-family object -> plan statics."""
    if isinstance(family, OPH):
        base = family.base
        if isinstance(base, Hash2U):
            fam = "2u"
        elif isinstance(base, Hash4U):
            fam = "4u"
        elif isinstance(base, PermutationFamily):
            fam = "perm"
        else:
            raise TypeError(f"unsupported OPH base {type(base)}")
        return dict(scheme="oph", family=fam, k=family.k, s=family.s,
                    densify=family.densify,
                    variant=getattr(base, "variant", "high"))
    if isinstance(family, Hash2U):
        return dict(scheme="minhash", family="2u", k=family.k, s=family.s,
                    variant=family.variant)
    if isinstance(family, Hash4U):
        return dict(scheme="minhash", family="4u", k=family.k, s=family.s)
    raise TypeError(
        f"SignatureEngine supports 2U/4U/OPH families, got {type(family)}")


# ---------------------------------------------------------------------------
# Padding helpers + jitted runners (the only callers of *_pallas builders)
# ---------------------------------------------------------------------------

def _pad_axis(x, mult, axis, value=0):
    size = x.shape[axis]
    target = ((size + mult - 1) // mult) * mult
    if target == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - size)
    return jnp.pad(x, pads, constant_values=value)


@functools.partial(jax.jit, static_argnames=("s", "b", "variant", "backend",
                                             "blk_n", "blk_t", "blk_k",
                                             "packed"))
def _minhash2u_run(indices, counts, a1, a2, *, s, b, variant, backend,
                   blk_n, blk_t, blk_k, packed=False):
    n, _ = indices.shape
    k = a1.shape[0]
    counts = counts.reshape(-1, 1).astype(jnp.int32)
    be = BACKENDS[backend]
    if not be.use_pallas:
        out = kref.minhash2u_ref(indices, counts, a1, a2, s=s, b=b,
                                 variant=variant)
        return pack_device(out, PackSpec(k, b)) if packed else out
    idx = _pad_axis(_pad_axis(indices, blk_t, 1), blk_n, 0)
    cts = _pad_axis(counts, blk_n, 0)
    a1p = _pad_axis(a1, blk_k, 0)
    a2p = _pad_axis(a2, blk_k, 0, value=1)
    if packed and can_pack_in_kernel(a1p.shape[0], k, b, blk_k):
        _, words = minhash2u_pallas(idx, cts, a1p, a2p, s=s, b=b, blk_n=blk_n,
                                    blk_t=blk_t, blk_k=blk_k, variant=variant,
                                    pack=True, interpret=be.interpret)
        return words[:n]
    out = minhash2u_pallas(idx, cts, a1p, a2p, s=s, b=b, blk_n=blk_n,
                           blk_t=blk_t, blk_k=blk_k, variant=variant,
                           interpret=be.interpret)[:n, :k]
    return pack_device(out, PackSpec(k, b)) if packed else out


@functools.partial(jax.jit, static_argnames=("s", "b", "backend", "blk_n",
                                             "blk_t", "blk_k", "packed"))
def _minhash4u_run(indices, counts, a, *, s, b, backend, blk_n, blk_t, blk_k,
                   packed=False):
    n, _ = indices.shape
    k = a.shape[1]
    counts = counts.reshape(-1, 1).astype(jnp.int32)
    be = BACKENDS[backend]
    if not be.use_pallas:
        out = kref.minhash4u_ref(indices, counts, a, s=s, b=b)
        return pack_device(out, PackSpec(k, b)) if packed else out
    idx = _pad_axis(_pad_axis(indices, blk_t, 1), blk_n, 0)
    cts = _pad_axis(counts, blk_n, 0)
    ap = _pad_axis(a, blk_k, 1, value=1)
    if packed and can_pack_in_kernel(ap.shape[1], k, b, blk_k):
        _, words = minhash4u_pallas(idx, cts, ap, s=s, b=b, blk_n=blk_n,
                                    blk_t=blk_t, blk_k=blk_k, pack=True,
                                    interpret=be.interpret)
        return words[:n]
    out = minhash4u_pallas(idx, cts, ap, s=s, b=b, blk_n=blk_n, blk_t=blk_t,
                           blk_k=blk_k, interpret=be.interpret)[:n, :k]
    return pack_device(out, PackSpec(k, b)) if packed else out


def _oph_lanes(k: int, blk_k: int):
    """(k_lanes, blk_k) for an OPH call: k padded to a full lane block."""
    if k < 1 or k & (k - 1):
        raise ValueError(f"OPH bin count k must be a power of two, got {k}")
    k_lanes = max(k, 128)
    if blk_k <= 0:
        blk_k = min(k_lanes, 512)             # all bins in one pass for k<=512
    return max(k_lanes, blk_k), blk_k


@functools.partial(jax.jit, static_argnames=("s", "bin_bits", "variant",
                                             "backend", "k_lanes", "blk_n",
                                             "blk_t", "blk_k", "code_b"))
def _oph2u_raw(indices, counts, a1, a2, *, s, bin_bits, variant, backend,
               k_lanes, blk_n, blk_t, blk_k, code_b=0):
    be = BACKENDS[backend]
    if not be.use_pallas:
        raw = kref.oph2u_ref(indices, counts, a1, a2, s=s, bin_bits=bin_bits,
                             k_lanes=k_lanes, variant=variant)
        return encode_sentinel(raw, code_b) if code_b > 0 else raw
    idx = _pad_axis(_pad_axis(indices, blk_t, 1), blk_n, 0)
    cts = _pad_axis(counts, blk_n, 0)
    return oph2u_pallas(idx, cts, a1, a2, s=s, bin_bits=bin_bits, blk_n=blk_n,
                        blk_t=blk_t, blk_k=blk_k, variant=variant,
                        code_b=code_b, interpret=be.interpret)


@functools.partial(jax.jit, static_argnames=("s", "bin_bits", "backend",
                                             "k_lanes", "blk_n", "blk_t",
                                             "blk_k", "code_b"))
def _oph4u_raw(indices, counts, a, *, s, bin_bits, backend, k_lanes,
               blk_n, blk_t, blk_k, code_b=0):
    be = BACKENDS[backend]
    if not be.use_pallas:
        raw = kref.oph4u_ref(indices, counts, a, s=s, bin_bits=bin_bits,
                             k_lanes=k_lanes)
        return encode_sentinel(raw, code_b) if code_b > 0 else raw
    idx = _pad_axis(_pad_axis(indices, blk_t, 1), blk_n, 0)
    cts = _pad_axis(counts, blk_n, 0)
    return oph4u_pallas(idx, cts, a, s=s, bin_bits=bin_bits, blk_n=blk_n,
                        blk_t=blk_t, blk_k=blk_k, code_b=code_b,
                        interpret=be.interpret)


@functools.partial(jax.jit, static_argnames=("k", "s", "bin_bits", "densify",
                                             "b", "packed", "coded"))
def _oph_epilogue_jit(raw, *, k, s, bin_bits, densify, b, packed=False,
                      coded=False):
    """Slice lane padding, densify, extract b bits, optionally pack.

    Shares ``repro.core.oph.densify_and_bbit`` with the jnp reference so
    the kernel path is bit-exact against it.  ``coded=True`` means the
    kernel already emitted (b+1)-bit sentinel codes (fused epilogue) and
    only the bitstream pack remains.
    """
    sig = raw[:, :k]
    spec = PackSpec(k, b, sentinel=(densify == "sentinel")) if packed else None
    if coded:
        return pack_codes(sig, spec.code_bits)
    sig = densify_and_bbit(sig, 1 << (s - bin_bits), densify, b)
    if packed:
        return pack_device(sig, spec)
    return sig


# ---------------------------------------------------------------------------
# Legacy-compatible jitted wrappers (public API, re-exported by ops.py)
# ---------------------------------------------------------------------------

def _legacy_backend(use_pallas: bool, backend: Optional[str]) -> str:
    return "ref" if not use_pallas else resolve_backend(backend).name


def minhash2u(indices: jax.Array, counts: jax.Array, a1: jax.Array,
              a2: jax.Array, *, s: int, b: int = 0, variant: str = "high",
              use_pallas: bool = True, backend: Optional[str] = None,
              blk_n: int = 8, blk_t: int = 128, blk_k: int = 128) -> jax.Array:
    """Batched 2U minhash signatures. counts: (n,) or (n,1) int32."""
    return _minhash2u_run(indices, counts, a1, a2, s=s, b=b, variant=variant,
                          backend=_legacy_backend(use_pallas, backend),
                          blk_n=blk_n, blk_t=blk_t, blk_k=blk_k)


def minhash4u(indices: jax.Array, counts: jax.Array, a: jax.Array, *, s: int,
              b: int = 0, use_pallas: bool = True,
              backend: Optional[str] = None, blk_n: int = 8, blk_t: int = 128,
              blk_k: int = 128) -> jax.Array:
    """Batched 4U minhash signatures (Mersenne BitMod path)."""
    return _minhash4u_run(indices, counts, a, s=s, b=b,
                          backend=_legacy_backend(use_pallas, backend),
                          blk_n=blk_n, blk_t=blk_t, blk_k=blk_k)


def oph2u(indices: jax.Array, counts: jax.Array, a1: jax.Array,
          a2: jax.Array, *, s: int, k: int, densify: str = "rotation",
          b: int = 0, variant: str = "high", use_pallas: bool = True,
          backend: Optional[str] = None, blk_n: int = 8, blk_t: int = 128,
          blk_k: int = 0) -> jax.Array:
    """Batched 2U OPH signatures: ONE hash pass -> (n, k) bin minima.

    Two jit stages: the Pallas raw-bin stage is independent of
    (densify, b), so sweeping those (tests, b-grids) reuses its compiled
    executable and only the cheap epilogue recompiles.
    """
    n, _ = indices.shape
    counts = counts.reshape(-1, 1).astype(jnp.int32)
    bin_bits = k.bit_length() - 1
    k_lanes, blk_k = _oph_lanes(k, blk_k)
    raw = _oph2u_raw(indices, counts, a1, a2, s=s, bin_bits=bin_bits,
                     variant=variant,
                     backend=_legacy_backend(use_pallas, backend),
                     k_lanes=k_lanes, blk_n=blk_n, blk_t=blk_t, blk_k=blk_k)
    return _oph_epilogue_jit(raw, k=k, s=s, bin_bits=bin_bits,
                             densify=densify, b=b)[:n]


def oph4u(indices: jax.Array, counts: jax.Array, a: jax.Array, *, s: int,
          k: int, densify: str = "rotation", b: int = 0,
          use_pallas: bool = True, backend: Optional[str] = None,
          blk_n: int = 8, blk_t: int = 128, blk_k: int = 0) -> jax.Array:
    """Batched 4U OPH signatures (Mersenne BitMod path); see ``oph2u``."""
    n, _ = indices.shape
    counts = counts.reshape(-1, 1).astype(jnp.int32)
    bin_bits = k.bit_length() - 1
    k_lanes, blk_k = _oph_lanes(k, blk_k)
    raw = _oph4u_raw(indices, counts, a, s=s, bin_bits=bin_bits,
                     backend=_legacy_backend(use_pallas, backend),
                     k_lanes=k_lanes, blk_n=blk_n, blk_t=blk_t, blk_k=blk_k)
    return _oph_epilogue_jit(raw, k=k, s=s, bin_bits=bin_bits,
                             densify=densify, b=b)[:n]


@functools.partial(jax.jit, static_argnames=("backend", "blk_n"))
def _sigbag_run(tokens, table, *, backend, blk_n):
    be = BACKENDS[backend]
    if not be.use_pallas:
        return kref.sigbag_ref(tokens, table)
    n = tokens.shape[0]
    tok = _pad_axis(tokens, blk_n, 0)
    out = sigbag_pallas(tok, table, blk_n=blk_n, interpret=be.interpret)
    return out[:n]


def sigbag(tokens: jax.Array, table: jax.Array, *, use_pallas: bool = True,
           backend: Optional[str] = None, blk_n: int = 128) -> jax.Array:
    """Signature embedding-bag: out[i] = sum_j table[j, tokens[i, j]]."""
    return _sigbag_run(tokens, table,
                       backend=_legacy_backend(use_pallas, backend),
                       blk_n=blk_n)


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------

class SignatureEngine:
    """Backend-aware signature computation for one hash family.

    Owns padding/tiling, block-size choice (explicit ``blocks`` >
    ``TuningTable`` entry > per-scheme defaults) and scheme dispatch via
    the ``(scheme, family)`` runner registry.  ``signatures`` returns the
    legacy (n, k) uint32 layout; ``packed_signatures`` returns the
    ``PackedSignatures`` wire format, packed inside the kernel jit (fused
    into the kernel's final grid step where alignment allows).
    """

    def __init__(self, family, *, b: int = 0, backend: Optional[str] = None,
                 packed: bool = False, blocks: Optional[dict] = None,
                 tuning: Optional[TuningTable] = None):
        self.family_obj = family
        self.statics = _family_statics(family)
        self.b = b
        self.packed = packed
        self.backend = resolve_backend(backend).name
        self._blocks = dict(blocks) if blocks else None
        self._tuning = tuning
        if packed:
            PackSpec(self.statics["k"], b,
                     self.statics.get("densify") == "sentinel")  # validate b
        key = (self.statics["scheme"], self.statics["family"])
        if key not in _RUNNERS:
            raise TypeError(f"no runner for scheme/family {key}")
        self._runner = _RUNNERS[key]

    # -- plan / blocks --------------------------------------------------
    def blocks_for(self, nnz: int) -> dict:
        if self._blocks:
            return self._blocks
        table = self._tuning or default_tuning_table()
        hit = table.lookup(self.backend, self.statics["scheme"],
                           self.statics["k"], nnz)
        if hit:
            return hit
        return dict(MINHASH_BLOCKS if self.statics["scheme"] == "minhash"
                    else OPH_BLOCKS)

    def plan_for(self, nnz: int) -> SignaturePlan:
        blocks = self.blocks_for(nnz)
        return SignaturePlan(backend=self.backend, b=self.b,
                             packed=self.packed, **self.statics, **blocks)

    # -- execution ------------------------------------------------------
    def signatures(self, batch: SparseBatch) -> jax.Array:
        """(n, k) uint32 signatures (b-bit masked when plan.b > 0)."""
        return self._runner(self, batch, self.plan_for(batch.indices.shape[1]),
                            packed=False)

    def packed_signatures(self, batch: SparseBatch) -> PackedSignatures:
        """The packed wire format: k*code_bits bits per example."""
        plan = self.plan_for(batch.indices.shape[1])
        words = self._runner(self, batch, plan, packed=True)
        return PackedSignatures(words, plan.k, plan.b, plan.sentinel)

    def __call__(self, batch: SparseBatch):
        return self.packed_signatures(batch) if self.packed \
            else self.signatures(batch)


def _counts(batch: SparseBatch) -> jax.Array:
    return jnp.sum(batch.mask.astype(jnp.int32), axis=1)


def _run_minhash_2u(eng, batch, plan, *, packed):
    fam = eng.family_obj
    return _minhash2u_run(batch.indices, _counts(batch), fam.a1, fam.a2,
                          s=plan.s, b=plan.b, variant=plan.variant,
                          backend=plan.backend, blk_n=plan.blk_n,
                          blk_t=plan.blk_t, blk_k=plan.blk_k, packed=packed)


def _run_minhash_4u(eng, batch, plan, *, packed):
    fam = eng.family_obj
    return _minhash4u_run(batch.indices, _counts(batch), fam.a, s=plan.s,
                          b=plan.b, backend=plan.backend, blk_n=plan.blk_n,
                          blk_t=plan.blk_t, blk_k=plan.blk_k, packed=packed)


def _run_oph(eng, batch, plan, *, packed, raw_fn, coeff_args):
    n = batch.indices.shape[0]
    counts = _counts(batch).reshape(-1, 1).astype(jnp.int32)
    bin_bits = plan.k.bit_length() - 1
    k_lanes, blk_k = _oph_lanes(plan.k, plan.blk_k)
    # packed sentinel: the kernel's fused final-step epilogue emits the
    # (b+1)-bit codes; everything else uses the raw-minima stage (shared
    # across densify/b sweeps) + the jnp epilogue.
    coded = packed and plan.sentinel
    raw = raw_fn(batch.indices, counts, *coeff_args, s=plan.s,
                 bin_bits=bin_bits, backend=plan.backend, k_lanes=k_lanes,
                 blk_n=plan.blk_n, blk_t=plan.blk_t, blk_k=blk_k,
                 code_b=plan.b if coded else 0)
    return _oph_epilogue_jit(raw, k=plan.k, s=plan.s, bin_bits=bin_bits,
                             densify=plan.densify, b=plan.b, packed=packed,
                             coded=coded)[:n]


def _run_oph_2u(eng, batch, plan, *, packed):
    base = eng.family_obj.base
    return _run_oph(eng, batch, plan, packed=packed,
                    raw_fn=functools.partial(_oph2u_raw, variant=plan.variant),
                    coeff_args=(base.a1, base.a2))


def _run_oph_4u(eng, batch, plan, *, packed):
    base = eng.family_obj.base
    return _run_oph(eng, batch, plan, packed=packed, raw_fn=_oph4u_raw,
                    coeff_args=(base.a,))


def _run_oph_perm(eng, batch, plan, *, packed):
    # permutation base: gold-standard jnp reference (tests/small D only)
    sig = oph_signatures(batch.indices, batch.mask, eng.family_obj, b=plan.b)
    return pack_device(sig, plan.pack_spec) if packed else sig


_RUNNERS = {
    ("minhash", "2u"): _run_minhash_2u,
    ("minhash", "4u"): _run_minhash_4u,
    ("oph", "2u"): _run_oph_2u,
    ("oph", "4u"): _run_oph_4u,
    ("oph", "perm"): _run_oph_perm,
}


# ---------------------------------------------------------------------------
# Batch entry point (legacy signature, engine-backed)
# ---------------------------------------------------------------------------

def batch_signatures(batch: SparseBatch, family, *, b: int = 0,
                     use_pallas: bool = True, backend: Optional[str] = None,
                     packed: bool = False):
    """Signatures for a SparseBatch via the SignatureEngine.

    ``family`` selects the scheme (Hash2U/Hash4U k-pass minwise, or an
    ``repro.core.oph.OPH`` scheme); ``backend`` selects execution
    ("auto" resolves per hardware); ``packed=True`` returns the
    ``PackedSignatures`` wire format instead of (n, k) uint32.
    """
    eng = SignatureEngine(family, b=b, packed=packed,
                          backend=_legacy_backend(use_pallas, backend))
    return eng(batch)


def _time_candidates(candidates, run_one, iters: int):
    """Shared tuning loop: compile once, time ``iters`` runs, keep the
    fastest candidate block dict."""
    import time
    candidates = list(candidates)
    if not candidates:
        raise ValueError("tune() needs at least one candidate block dict")
    best, best_t = None, float("inf")
    for blocks in candidates:
        run_one(blocks)                          # compile once
        t0 = time.perf_counter()
        for _ in range(iters):
            run_one(blocks)
        dt = (time.perf_counter() - t0) / iters
        if dt < best_t:
            best, best_t = dict(blocks), dt
    return best


def tune(engine, batch, candidates, iters: int = 3,
         table: Optional[TuningTable] = None,
         backend: Optional[str] = None) -> dict:
    """Time candidate block dicts and record the winner in the tuning
    table (the ROADMAP TPU/GPU tuning loop).

    Two schemes:
      * ``engine`` is a ``SignatureEngine`` and ``batch`` a
        ``SparseBatch`` -- tunes the signature kernels (minhash/oph).
      * ``engine`` is a ``PackSpec`` and ``batch`` a
        ``(qwords, cwords)`` pair of packed operands -- tunes the
        retrieval kernel (``repro.kernels.hamming.packed_match``),
        recording under scheme ``"hamming"`` keyed on the packed word
        count; ``backend`` resolves through the registry ("auto" per
        hardware).
    """
    if isinstance(engine, PackSpec):
        from repro.kernels.hamming import packed_match
        qwords, cwords = batch
        be = resolve_backend(backend).name

        def run_one(blocks):
            out = packed_match(qwords, cwords, engine, backend=be,
                               blocks=blocks)
            jax.block_until_ready(out[0] if isinstance(out, tuple) else out)

        best = _time_candidates(candidates, run_one, iters)
        tab = table or default_tuning_table()
        tab.record(be, "hamming", engine.k, engine.words, best)
        return best

    def run_one(blocks):
        probe = SignatureEngine(engine.family_obj, b=engine.b,
                                backend=engine.backend, packed=engine.packed,
                                blocks=blocks)
        out = probe(batch)
        jax.block_until_ready(out.data if isinstance(out, PackedSignatures)
                              else out)

    best = _time_candidates(candidates, run_one, iters)
    tab = table or engine._tuning or default_tuning_table()
    tab.record(engine.backend, engine.statics["scheme"],
               engine.statics["k"], batch.indices.shape[1], best)
    return best
