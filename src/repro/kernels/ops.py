"""Legacy public wrappers, re-exported from ``repro.kernels.engine``.

Historically this module held the jitted padding/dispatch wrappers and an
isinstance chain in ``batch_signatures``.  That machinery now lives in
``repro.kernels.engine`` (``SignaturePlan`` / ``SignatureEngine``: one
seam for backend choice, block-size tuning and the packed wire format);
this module remains so existing imports keep working.  New code should
import from ``repro.kernels`` or ``repro.kernels.engine`` directly.
"""

from __future__ import annotations

from repro.kernels.engine import (batch_signatures, minhash2u, minhash4u,
                                  oph2u, oph4u, sigbag)

__all__ = ["batch_signatures", "minhash2u", "minhash4u", "oph2u", "oph4u",
           "sigbag"]
