"""Jitted public wrappers around the Pallas kernels.

These pad inputs up to tile boundaries, pick block shapes, dispatch to the
Pallas kernel (interpret mode on CPU, compiled on TPU), and slice the
result back.  Downstream code (preprocessing pipeline, recsys hashed
frontends, benchmarks) calls these, never `pl.pallas_call` directly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.data.sparse import SparseBatch
from repro.kernels.minhash import minhash2u_pallas, minhash4u_pallas
from repro.kernels.sigbag import sigbag_pallas
from repro.kernels import ref as kref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_axis(x, mult, axis, value=0):
    size = x.shape[axis]
    target = ((size + mult - 1) // mult) * mult
    if target == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - size)
    return jnp.pad(x, pads, constant_values=value)


@functools.partial(jax.jit, static_argnames=("s", "b", "variant", "use_pallas",
                                             "blk_n", "blk_t", "blk_k"))
def minhash2u(indices: jax.Array, counts: jax.Array, a1: jax.Array,
              a2: jax.Array, *, s: int, b: int = 0, variant: str = "high",
              use_pallas: bool = True, blk_n: int = 8, blk_t: int = 128,
              blk_k: int = 128) -> jax.Array:
    """Batched 2U minhash signatures. counts: (n,) or (n,1) int32."""
    n, _ = indices.shape
    k = a1.shape[0]
    counts = counts.reshape(-1, 1).astype(jnp.int32)
    if not use_pallas:
        return kref.minhash2u_ref(indices, counts, a1, a2, s=s, b=b,
                                  variant=variant)
    idx = _pad_axis(_pad_axis(indices, blk_t, 1), blk_n, 0)
    cts = _pad_axis(counts, blk_n, 0)
    a1p = _pad_axis(a1, blk_k, 0)
    a2p = _pad_axis(a2, blk_k, 0, value=1)
    out = minhash2u_pallas(idx, cts, a1p, a2p, s=s, b=b, blk_n=blk_n,
                           blk_t=blk_t, blk_k=blk_k, variant=variant,
                           interpret=not _on_tpu())
    return out[:n, :k]


@functools.partial(jax.jit, static_argnames=("s", "b", "use_pallas", "blk_n",
                                             "blk_t", "blk_k"))
def minhash4u(indices: jax.Array, counts: jax.Array, a: jax.Array, *, s: int,
              b: int = 0, use_pallas: bool = True, blk_n: int = 8,
              blk_t: int = 128, blk_k: int = 128) -> jax.Array:
    """Batched 4U minhash signatures (Mersenne BitMod path)."""
    n, _ = indices.shape
    k = a.shape[1]
    counts = counts.reshape(-1, 1).astype(jnp.int32)
    if not use_pallas:
        return kref.minhash4u_ref(indices, counts, a, s=s, b=b)
    idx = _pad_axis(_pad_axis(indices, blk_t, 1), blk_n, 0)
    cts = _pad_axis(counts, blk_n, 0)
    ap = _pad_axis(a, blk_k, 1, value=1)
    out = minhash4u_pallas(idx, cts, ap, s=s, b=b, blk_n=blk_n, blk_t=blk_t,
                           blk_k=blk_k, interpret=not _on_tpu())
    return out[:n, :k]


@functools.partial(jax.jit, static_argnames=("use_pallas", "blk_n"))
def sigbag(tokens: jax.Array, table: jax.Array, *, use_pallas: bool = True,
           blk_n: int = 128) -> jax.Array:
    """Signature embedding-bag: out[i] = sum_j table[j, tokens[i, j]]."""
    if not use_pallas:
        return kref.sigbag_ref(tokens, table)
    n = tokens.shape[0]
    tok = _pad_axis(tokens, blk_n, 0)
    out = sigbag_pallas(tok, table, blk_n=blk_n, interpret=not _on_tpu())
    return out[:n]


def batch_signatures(batch: SparseBatch, family, *, b: int = 0,
                     use_pallas: bool = True) -> jax.Array:
    """Signatures for a SparseBatch under a Hash2U/Hash4U family."""
    from repro.core.hashing import Hash2U, Hash4U
    counts = jnp.sum(batch.mask.astype(jnp.int32), axis=1)
    if isinstance(family, Hash2U):
        return minhash2u(batch.indices, counts, family.a1, family.a2,
                         s=family.s, b=b, variant=family.variant,
                         use_pallas=use_pallas)
    if isinstance(family, Hash4U):
        return minhash4u(batch.indices, counts, family.a, s=family.s, b=b,
                         use_pallas=use_pallas)
    raise TypeError(f"Pallas path supports 2U/4U families, got {type(family)}")
