"""Jitted public wrappers around the Pallas kernels.

These pad inputs up to tile boundaries, pick block shapes, dispatch to the
Pallas kernel (interpret mode on CPU, compiled on TPU), and slice the
result back.  Downstream code (preprocessing pipeline, recsys hashed
frontends, benchmarks) calls these, never `pl.pallas_call` directly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.oph import EMPTY, OPH, densify_rotation
from repro.data.sparse import SparseBatch
from repro.kernels.minhash import minhash2u_pallas, minhash4u_pallas
from repro.kernels.oph import oph2u_pallas, oph4u_pallas
from repro.kernels.sigbag import sigbag_pallas
from repro.kernels import ref as kref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_axis(x, mult, axis, value=0):
    size = x.shape[axis]
    target = ((size + mult - 1) // mult) * mult
    if target == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - size)
    return jnp.pad(x, pads, constant_values=value)


@functools.partial(jax.jit, static_argnames=("s", "b", "variant", "use_pallas",
                                             "blk_n", "blk_t", "blk_k"))
def minhash2u(indices: jax.Array, counts: jax.Array, a1: jax.Array,
              a2: jax.Array, *, s: int, b: int = 0, variant: str = "high",
              use_pallas: bool = True, blk_n: int = 8, blk_t: int = 128,
              blk_k: int = 128) -> jax.Array:
    """Batched 2U minhash signatures. counts: (n,) or (n,1) int32."""
    n, _ = indices.shape
    k = a1.shape[0]
    counts = counts.reshape(-1, 1).astype(jnp.int32)
    if not use_pallas:
        return kref.minhash2u_ref(indices, counts, a1, a2, s=s, b=b,
                                  variant=variant)
    idx = _pad_axis(_pad_axis(indices, blk_t, 1), blk_n, 0)
    cts = _pad_axis(counts, blk_n, 0)
    a1p = _pad_axis(a1, blk_k, 0)
    a2p = _pad_axis(a2, blk_k, 0, value=1)
    out = minhash2u_pallas(idx, cts, a1p, a2p, s=s, b=b, blk_n=blk_n,
                           blk_t=blk_t, blk_k=blk_k, variant=variant,
                           interpret=not _on_tpu())
    return out[:n, :k]


@functools.partial(jax.jit, static_argnames=("s", "b", "use_pallas", "blk_n",
                                             "blk_t", "blk_k"))
def minhash4u(indices: jax.Array, counts: jax.Array, a: jax.Array, *, s: int,
              b: int = 0, use_pallas: bool = True, blk_n: int = 8,
              blk_t: int = 128, blk_k: int = 128) -> jax.Array:
    """Batched 4U minhash signatures (Mersenne BitMod path)."""
    n, _ = indices.shape
    k = a.shape[1]
    counts = counts.reshape(-1, 1).astype(jnp.int32)
    if not use_pallas:
        return kref.minhash4u_ref(indices, counts, a, s=s, b=b)
    idx = _pad_axis(_pad_axis(indices, blk_t, 1), blk_n, 0)
    cts = _pad_axis(counts, blk_n, 0)
    ap = _pad_axis(a, blk_k, 1, value=1)
    out = minhash4u_pallas(idx, cts, ap, s=s, b=b, blk_n=blk_n, blk_t=blk_t,
                           blk_k=blk_k, interpret=not _on_tpu())
    return out[:n, :k]


def _oph_lanes(k: int, blk_k: int) -> tuple[int, int]:
    """(k_lanes, blk_k) for an OPH call: k padded to a full lane block."""
    if k < 1 or k & (k - 1):
        raise ValueError(f"OPH bin count k must be a power of two, got {k}")
    k_lanes = max(k, 128)
    if blk_k <= 0:
        blk_k = min(k_lanes, 512)             # all bins in one pass for k<=512
    return max(k_lanes, blk_k), blk_k


def _oph_epilogue(raw: jax.Array, n: int, k: int, s: int, bin_bits: int,
                  densify: str, b: int) -> jax.Array:
    """Slice lane padding, densify, extract b bits.

    Shared verbatim with the semantics of ``core.oph.oph_signatures`` so
    the kernel path is bit-exact against the reference: sentinel keeps
    EMPTY through the b-bit mask; rotation masks everything (its only
    EMPTYs are all-empty rows, which fold to the all-ones code).
    """
    sig = raw[:n, :k]
    if densify == "rotation":
        sig = densify_rotation(sig, 1 << (s - bin_bits))
    if b > 0:
        mask_b = jnp.uint32((1 << b) - 1)
        if densify == "rotation":
            sig = sig & mask_b
        else:
            sig = jnp.where(sig != EMPTY, sig & mask_b, sig)
    return sig


@functools.partial(jax.jit, static_argnames=("s", "bin_bits", "variant",
                                             "use_pallas", "k_lanes", "blk_n",
                                             "blk_t", "blk_k"))
def _oph2u_raw(indices, counts, a1, a2, *, s, bin_bits, variant, use_pallas,
               k_lanes, blk_n, blk_t, blk_k):
    if not use_pallas:
        return kref.oph2u_ref(indices, counts, a1, a2, s=s, bin_bits=bin_bits,
                              k_lanes=k_lanes, variant=variant)
    idx = _pad_axis(_pad_axis(indices, blk_t, 1), blk_n, 0)
    cts = _pad_axis(counts, blk_n, 0)
    return oph2u_pallas(idx, cts, a1, a2, s=s, bin_bits=bin_bits, blk_n=blk_n,
                        blk_t=blk_t, blk_k=blk_k, variant=variant,
                        interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("s", "bin_bits", "use_pallas",
                                             "k_lanes", "blk_n", "blk_t",
                                             "blk_k"))
def _oph4u_raw(indices, counts, a, *, s, bin_bits, use_pallas, k_lanes,
               blk_n, blk_t, blk_k):
    if not use_pallas:
        return kref.oph4u_ref(indices, counts, a, s=s, bin_bits=bin_bits,
                              k_lanes=k_lanes)
    idx = _pad_axis(_pad_axis(indices, blk_t, 1), blk_n, 0)
    cts = _pad_axis(counts, blk_n, 0)
    return oph4u_pallas(idx, cts, a, s=s, bin_bits=bin_bits, blk_n=blk_n,
                        blk_t=blk_t, blk_k=blk_k, interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("k", "s", "bin_bits", "densify",
                                             "b"))
def _oph_epilogue_jit(raw, *, k, s, bin_bits, densify, b):
    n = raw.shape[0]
    return _oph_epilogue(raw, n, k, s, bin_bits, densify, b)


def oph2u(indices: jax.Array, counts: jax.Array, a1: jax.Array,
          a2: jax.Array, *, s: int, k: int, densify: str = "rotation",
          b: int = 0, variant: str = "high", use_pallas: bool = True,
          blk_n: int = 8, blk_t: int = 128, blk_k: int = 0) -> jax.Array:
    """Batched 2U OPH signatures: ONE hash pass -> (n, k) bin minima.

    Two jit stages: the Pallas raw-bin stage is independent of
    (densify, b), so sweeping those (tests, b-grids) reuses its compiled
    executable and only the cheap epilogue recompiles.
    """
    n, _ = indices.shape
    counts = counts.reshape(-1, 1).astype(jnp.int32)
    bin_bits = k.bit_length() - 1
    k_lanes, blk_k = _oph_lanes(k, blk_k)
    raw = _oph2u_raw(indices, counts, a1, a2, s=s, bin_bits=bin_bits,
                     variant=variant, use_pallas=use_pallas, k_lanes=k_lanes,
                     blk_n=blk_n, blk_t=blk_t, blk_k=blk_k)
    return _oph_epilogue_jit(raw, k=k, s=s, bin_bits=bin_bits,
                             densify=densify, b=b)[:n]


def oph4u(indices: jax.Array, counts: jax.Array, a: jax.Array, *, s: int,
          k: int, densify: str = "rotation", b: int = 0,
          use_pallas: bool = True, blk_n: int = 8, blk_t: int = 128,
          blk_k: int = 0) -> jax.Array:
    """Batched 4U OPH signatures (Mersenne BitMod path); see ``oph2u``."""
    n, _ = indices.shape
    counts = counts.reshape(-1, 1).astype(jnp.int32)
    bin_bits = k.bit_length() - 1
    k_lanes, blk_k = _oph_lanes(k, blk_k)
    raw = _oph4u_raw(indices, counts, a, s=s, bin_bits=bin_bits,
                     use_pallas=use_pallas, k_lanes=k_lanes, blk_n=blk_n,
                     blk_t=blk_t, blk_k=blk_k)
    return _oph_epilogue_jit(raw, k=k, s=s, bin_bits=bin_bits,
                             densify=densify, b=b)[:n]


@functools.partial(jax.jit, static_argnames=("use_pallas", "blk_n"))
def sigbag(tokens: jax.Array, table: jax.Array, *, use_pallas: bool = True,
           blk_n: int = 128) -> jax.Array:
    """Signature embedding-bag: out[i] = sum_j table[j, tokens[i, j]]."""
    if not use_pallas:
        return kref.sigbag_ref(tokens, table)
    n = tokens.shape[0]
    tok = _pad_axis(tokens, blk_n, 0)
    out = sigbag_pallas(tok, table, blk_n=blk_n, interpret=not _on_tpu())
    return out[:n]


def batch_signatures(batch: SparseBatch, family, *, b: int = 0,
                     use_pallas: bool = True) -> jax.Array:
    """Signatures for a SparseBatch.

    ``family`` selects the scheme: a Hash2U/Hash4U family runs the k-pass
    minwise kernels; an ``repro.core.oph.OPH`` scheme runs the
    single-pass binned kernels (k x fewer hash evaluations).
    """
    from repro.core.hashing import Hash2U, Hash4U
    counts = jnp.sum(batch.mask.astype(jnp.int32), axis=1)
    if isinstance(family, OPH):
        base = family.base
        if isinstance(base, Hash2U):
            return oph2u(batch.indices, counts, base.a1, base.a2,
                         s=family.s, k=family.k, densify=family.densify,
                         b=b, variant=base.variant, use_pallas=use_pallas)
        if isinstance(base, Hash4U):
            return oph4u(batch.indices, counts, base.a, s=family.s,
                         k=family.k, densify=family.densify, b=b,
                         use_pallas=use_pallas)
        # permutation base: gold-standard jnp reference (tests/small D only)
        from repro.core.oph import oph_signatures
        return oph_signatures(batch.indices, batch.mask, family, b=b)
    if isinstance(family, Hash2U):
        return minhash2u(batch.indices, counts, family.a1, family.a2,
                         s=family.s, b=b, variant=family.variant,
                         use_pallas=use_pallas)
    if isinstance(family, Hash4U):
        return minhash4u(batch.indices, counts, family.a, s=family.s, b=b,
                         use_pallas=use_pallas)
    raise TypeError(f"Pallas path supports 2U/4U/OPH families, got {type(family)}")
