"""Shared neural-net building blocks (pure functional, dict params)."""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp


def normal_init(key, shape, scale: float = 0.02, dtype=jnp.float32):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dtype) * weight


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array,
               eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps)).astype(dtype) * weight + bias


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def mlp(x: jax.Array, weights: Sequence[jax.Array],
        biases: Sequence[jax.Array], act=jax.nn.relu,
        final_act: bool = False) -> jax.Array:
    for i, (w, b) in enumerate(zip(weights, biases)):
        x = x @ w + b
        if i < len(weights) - 1 or final_act:
            x = act(x)
    return x


def init_mlp(key, dims: Sequence[int], dtype=jnp.float32):
    ws, bs = [], []
    keys = jax.random.split(key, len(dims) - 1)
    for i, kk in enumerate(keys):
        fan_in = dims[i]
        ws.append(normal_init(kk, (dims[i], dims[i + 1]),
                              scale=fan_in ** -0.5, dtype=dtype))
        bs.append(jnp.zeros((dims[i + 1],), dtype))
    return {"w": ws, "b": bs}


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0
               ) -> jax.Array:
    """Rotary embedding on the last dim. x: (..., S, H, hd), positions: (S,)
    or broadcastable to x's sequence axis (-3)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs     # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]                           # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    return jnp.concatenate([out1, out2], axis=-1).astype(x.dtype)
