"""GatedGCN (Bresson & Laurent, arXiv:1711.07553 / benchmark config
arXiv:2003.00982) with edge gates, via segment_sum message passing.

JAX has no CSR SpMM; message passing is built from first principles:
per-edge messages + ``jax.ops.segment_sum`` scatter into destination
nodes.  That scatter IS the system's GNN kernel (see kernel_taxonomy §GNN).

Layer (residual, batch-norm-free variant with RMS norm for TPU):
    e'_ij = A h_i + B h_j + C e_ij
    eta_ij = sigmoid(e'_ij) / (sum_j' sigmoid(e'_ij') + eps)
    h'_i  = h_i + ReLU(norm(U h_i + sum_j eta_ij * (V h_j)))
    e_ij  <- e_ij + ReLU(norm(e'_ij))

Supports full-batch graphs (cora / ogbn-products scale) and sampled
minibatch subgraphs from the fanout neighbor sampler below.  Edges are
padded to a fixed count with a validity mask (TPU static shapes).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import normal_init, rms_norm
from repro.sharding.rules import constrain


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    arch_id: str
    n_layers: int
    d_hidden: int
    d_in: int
    n_classes: int
    aggregator: str = "gated"
    readout: str = "node"        # "node" | "graph" (mean-pool per graph id)
    param_dtype: object = jnp.float32
    remat: bool = False


def init_gnn_params(cfg: GNNConfig, key: jax.Array):
    dtype = cfg.param_dtype
    k_in, k_e, k_layers, k_out = jax.random.split(key, 4)
    d = cfg.d_hidden

    def layer_init(k):
        ks = jax.random.split(k, 5)
        s = d ** -0.5
        return {"A": normal_init(ks[0], (d, d), s, dtype),
                "B": normal_init(ks[1], (d, d), s, dtype),
                "C": normal_init(ks[2], (d, d), s, dtype),
                "U": normal_init(ks[3], (d, d), s, dtype),
                "V": normal_init(ks[4], (d, d), s, dtype),
                "ln_h": jnp.ones((d,), dtype),
                "ln_e": jnp.ones((d,), dtype)}

    return {
        "embed_in": normal_init(k_in, (cfg.d_in, d), cfg.d_in ** -0.5, dtype),
        "embed_edge": normal_init(k_e, (1, d), 1.0, dtype),
        "layers": jax.vmap(layer_init)(jax.random.split(k_layers, cfg.n_layers)),
        "out": normal_init(k_out, (d, cfg.n_classes), d ** -0.5, dtype),
    }


def gnn_param_shapes(cfg: GNNConfig):
    return jax.eval_shape(partial(init_gnn_params, cfg), jax.random.PRNGKey(0))


def gatedgcn_layer(p, h, e, src, dst, edge_mask, n_nodes: int):
    """One GatedGCN layer. h: (N, d); e: (E, d); src/dst: (E,) int32."""
    h_src = jnp.take(h, src, axis=0)
    h_dst = jnp.take(h, dst, axis=0)
    e_new = h_dst @ p["A"] + h_src @ p["B"] + e @ p["C"]      # (E, d)
    gate = jax.nn.sigmoid(e_new) * edge_mask[:, None]
    gate_sum = jax.ops.segment_sum(gate, dst, num_segments=n_nodes)
    eta = gate / (jnp.take(gate_sum, dst, axis=0) + 1e-6)     # (E, d)
    msg = eta * (h_src @ p["V"]) * edge_mask[:, None]
    agg = jax.ops.segment_sum(msg, dst, num_segments=n_nodes) # (N, d)
    h = h + jax.nn.relu(rms_norm(h @ p["U"] + agg, p["ln_h"]))
    e = e + jax.nn.relu(rms_norm(e_new, p["ln_e"]))
    return h, e


def gnn_forward(params, batch: dict, cfg: GNNConfig) -> jax.Array:
    """batch: node_feats (N, d_in), edge_index (2, E) int32,
    edge_mask (E,) float, [node_mask (N,)].  Returns logits (N, classes)."""
    feats = constrain(batch["node_feats"], None, None)
    h = feats @ params["embed_in"]
    E = batch["edge_index"].shape[1]
    e = jnp.broadcast_to(params["embed_edge"], (E, cfg.d_hidden))
    # edges are row-parallel: shard over EVERY mesh axis (256/512-way)
    e = constrain(e, "all", None)
    src, dst = batch["edge_index"][0], batch["edge_index"][1]
    src = constrain(src, "all")
    dst = constrain(dst, "all")
    edge_mask = batch["edge_mask"].astype(h.dtype)
    n_nodes = h.shape[0]

    layer = gatedgcn_layer
    if cfg.remat:
        layer = jax.checkpoint(layer, static_argnums=(6,))

    def body(carry, p):
        h, e = carry
        # Perf iteration (EXPERIMENTS.md §Perf/gatedgcn): node tensors
        # sharded over the data axes (replicating them makes every chip
        # run the full N*d^2 matmuls and psum whole node tables per
        # layer); edge tensors sharded over all axes (their per-layer
        # stash dominated HBM at ogbn-products scale).
        h = constrain(h, "batch", None)
        e = constrain(e, "all", None)
        h, e = layer(p, h, e, src, dst, edge_mask, n_nodes)
        return (constrain(h, "batch", None), constrain(e, "all", None)), None

    (h, e), _ = jax.lax.scan(body, (h, e), params["layers"])
    if cfg.readout == "graph":
        # mean-pool nodes into per-graph embeddings (batched small graphs)
        gids = batch["graph_ids"]
        n_graphs = batch["labels"].shape[0]
        nm = batch["node_mask"].astype(h.dtype)
        sums = jax.ops.segment_sum(h * nm[:, None], gids,
                                   num_segments=n_graphs)
        cnt = jax.ops.segment_sum(nm, gids, num_segments=n_graphs)
        h = sums / jnp.maximum(cnt, 1.0)[:, None]
    return h @ params["out"]


def gnn_loss(params, batch: dict, cfg: GNNConfig) -> jax.Array:
    """Cross-entropy: masked node classification, or per-graph readout."""
    logits = gnn_forward(params, batch, cfg).astype(jnp.float32)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    if cfg.readout == "graph":
        return jnp.mean(nll)
    mask = batch["node_mask"].astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# ---------------------------------------------------------------------------
# Fanout neighbor sampler (GraphSAGE-style, for minibatch_lg)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CSRGraph:
    """Compressed neighbor lists on device."""
    indptr: jax.Array     # (N+1,) int32
    indices: jax.Array    # (nnz,) int32


def neighbor_sample(key: jax.Array, graph: CSRGraph, seeds: jax.Array,
                    fanouts: Tuple[int, ...]) -> dict:
    """Layer-wise uniform fanout sampling (with replacement).

    Returns a fixed-shape padded subgraph:
      nodes   (n_sub,) int32 -- [seeds, hop-1 samples, hop-2 samples, ...]
      edge_index (2, n_edges) int32 indices into `nodes`
      edge_mask  (n_edges,) bool (False for padded/self-loop fill)
    Sampling with replacement keeps shapes static (real systems do the
    same for TPU); duplicate edges are legitimate SAGE-style samples.
    """
    frontier = seeds
    all_nodes = [seeds]
    srcs, dsts, masks = [], [], []
    offset = 0
    for hop, fanout in enumerate(fanouts):
        key, sub = jax.random.split(key)
        deg = jnp.take(graph.indptr, frontier + 1) - jnp.take(graph.indptr,
                                                              frontier)
        r = jax.random.randint(sub, (frontier.shape[0], fanout), 0, 1 << 30)
        pick = r % jnp.maximum(deg[:, None], 1)
        nbr = jnp.take(graph.indices,
                       jnp.take(graph.indptr, frontier)[:, None] + pick,
                       mode="clip")                       # (F, fanout)
        valid = (deg > 0)[:, None] & jnp.ones_like(pick, bool)
        new_offset = offset + frontier.shape[0]
        # edges: sampled neighbor (src) -> frontier node (dst)
        src_local = new_offset + jnp.arange(frontier.shape[0] * fanout)
        dst_local = jnp.repeat(offset + jnp.arange(frontier.shape[0]), fanout)
        srcs.append(src_local)
        dsts.append(dst_local)
        masks.append(valid.reshape(-1))
        all_nodes.append(nbr.reshape(-1))
        frontier = nbr.reshape(-1)
        offset = new_offset
    nodes = jnp.concatenate(all_nodes)
    return {
        "nodes": nodes,
        "edge_index": jnp.stack([jnp.concatenate(srcs),
                                 jnp.concatenate(dsts)]).astype(jnp.int32),
        "edge_mask": jnp.concatenate(masks),
    }


def subgraph_sizes(n_seeds: int, fanouts: Tuple[int, ...]) -> Tuple[int, int]:
    """(n_sub_nodes, n_sub_edges) for the fixed-shape sampled subgraph."""
    n_nodes, n_edges, frontier = n_seeds, 0, n_seeds
    for f in fanouts:
        n_edges += frontier * f
        frontier *= f
        n_nodes += frontier
    return n_nodes, n_edges
