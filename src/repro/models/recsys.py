"""RecSys architectures: Wide&Deep, AutoInt, DIN, MIND -- on top of a
from-scratch EmbeddingBag (jnp.take + segment-sum; JAX has no native one),
with the paper's b-bit minwise hashing as an optional *hashed frontend*.

Hashed frontend (the paper's technique as a first-class feature): each
example carries a large sparse binary set (user behavior / n-gram
features).  Instead of a 10^9-row embedding table, the set is minhashed
into k b-bit signatures (repro.core / repro.kernels) and embedded by the
Eq.(5) signature embedding-bag: sum_j Table[j, z_j] with Table of shape
(k, 2^b, d).  This reduces the embedding storage from O(D d) to
O(k 2^b d) and the lookup from O(nnz) to O(k) -- precisely the paper's
data-reduction argument transplanted from linear models to embeddings.

All ID inputs are single-valued per field (standard Criteo-style layout);
the multi-hot path goes through the hashed frontend.  Embedding tables are
row-sharded over the ``model`` mesh axis.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bbit import expand_tokens
from repro.kernels import ref as kref
from repro.models.layers import init_mlp, mlp, normal_init, rms_norm
from repro.sharding.rules import constrain


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    arch_id: str
    interaction: str             # "concat" | "self-attn" | "target-attn" | "multi-interest"
    n_fields: int                # single-valued categorical fields
    vocab: int                   # rows per field table
    embed_dim: int
    mlp_dims: Tuple[int, ...] = ()
    # AutoInt
    n_attn_layers: int = 0
    n_attn_heads: int = 0
    d_attn: int = 0
    # DIN / MIND (behavior-sequence models)
    seq_len: int = 0
    attn_mlp_dims: Tuple[int, ...] = ()
    n_interests: int = 0
    capsule_iters: int = 0
    item_vocab: int = 0
    # paper integration: minhash-hashed set-valued feature
    use_minhash_frontend: bool = False
    minhash_k: int = 64
    minhash_b: int = 8
    minhash_s: int = 24          # original set universe D = 2^s
    set_nnz: int = 128           # padded nnz of the raw sparse set
    param_dtype: Any = jnp.float32


@functools.lru_cache(maxsize=None)
def _minhash_coeffs(arch_id: str, k: int) -> Tuple[np.ndarray, np.ndarray]:
    """Deterministic non-trainable 2U coefficients (buffers, not params)."""
    rng = np.random.default_rng(abs(hash((arch_id, k))) % (2**31))
    a1 = rng.integers(0, 2**32, k, dtype=np.uint32)
    a2 = (rng.integers(0, 2**32, k, dtype=np.uint32) | 1).astype(np.uint32)
    return a1, a2


# ---------------------------------------------------------------------------
# EmbeddingBag (built from scratch: JAX has no nn.EmbeddingBag)
# ---------------------------------------------------------------------------

def embedding_lookup(table: jax.Array, ids: jax.Array) -> jax.Array:
    """Single-hot per-field lookup. table: (F, V, d); ids: (B, F) -> (B, F, d)."""
    F = table.shape[0]
    out = jnp.take_along_axis(
        jnp.moveaxis(table, 0, 0)[None],           # (1, F, V, d)
        ids[:, :, None, None].astype(jnp.int32), axis=2)[:, :, 0, :]
    return out


def embedding_bag(table: jax.Array, ids: jax.Array, mask: jax.Array,
                  combiner: str = "sum") -> jax.Array:
    """Multi-hot bag over one table. table: (V, d); ids/mask: (B, L) -> (B, d)."""
    gathered = jnp.take(table, ids.astype(jnp.int32), axis=0)   # (B, L, d)
    gathered = gathered * mask[..., None].astype(gathered.dtype)
    out = jnp.sum(gathered, axis=1)
    if combiner == "mean":
        out = out / jnp.maximum(jnp.sum(mask, axis=1, keepdims=True), 1.0)
    return out


def minhash_frontend(params: dict, set_ids: jax.Array, set_counts: jax.Array,
                     cfg: RecsysConfig) -> jax.Array:
    """Sparse set -> k b-bit signatures -> signature embedding-bag (B, d).

    Inside the training graph this is the pure-jnp reference path (the
    Pallas kernel serves the preprocessing pipeline); both compute
    identical values (tests assert so).
    """
    a1, a2 = _minhash_coeffs(cfg.arch_id, cfg.minhash_k)
    sig = kref.minhash2u_ref(set_ids, set_counts.reshape(-1, 1),
                             jnp.asarray(a1), jnp.asarray(a2),
                             s=cfg.minhash_s, b=cfg.minhash_b)
    return kref.sigbag_ref(sig.astype(jnp.int32), params["minhash_table"])


# ---------------------------------------------------------------------------
# Parameter init per architecture
# ---------------------------------------------------------------------------

def init_recsys_params(cfg: RecsysConfig, key: jax.Array):
    dtype = cfg.param_dtype
    d = cfg.embed_dim
    ks = iter(jax.random.split(key, 16))
    p: Dict[str, Any] = {}
    if cfg.n_fields:
        p["tables"] = normal_init(next(ks), (cfg.n_fields, cfg.vocab, d),
                                  0.01, dtype)
    if cfg.interaction == "concat":           # wide & deep
        p["wide"] = normal_init(next(ks), (cfg.n_fields, cfg.vocab, 1),
                                0.01, dtype)
        p["deep"] = init_mlp(next(ks),
                             (cfg.n_fields * d + (d if cfg.use_minhash_frontend else 0),)
                             + cfg.mlp_dims + (1,), dtype)
    elif cfg.interaction == "self-attn":      # autoint
        n_f = cfg.n_fields + (1 if cfg.use_minhash_frontend else 0)
        layers = []
        d_in = d
        for _ in range(cfg.n_attn_layers):
            kq, kk, kv, kr = jax.random.split(next(ks), 4)
            h = cfg.n_attn_heads
            da = cfg.d_attn
            layers.append({
                "wq": normal_init(kq, (d_in, h * da), d_in ** -0.5, dtype),
                "wk": normal_init(kk, (d_in, h * da), d_in ** -0.5, dtype),
                "wv": normal_init(kv, (d_in, h * da), d_in ** -0.5, dtype),
                "wres": normal_init(kr, (d_in, h * da), d_in ** -0.5, dtype),
            })
            d_in = cfg.n_attn_heads * cfg.d_attn
        p["attn_layers"] = layers
        p["head"] = init_mlp(next(ks), (n_f * d_in, 1), dtype)
    elif cfg.interaction == "target-attn":    # din
        p["item_table"] = normal_init(next(ks), (cfg.item_vocab, d), 0.01,
                                      dtype)
        p["attn_mlp"] = init_mlp(next(ks), (4 * d,) + cfg.attn_mlp_dims + (1,),
                                 dtype)
        d_extra = d if cfg.use_minhash_frontend else 0
        p["head"] = init_mlp(next(ks), (3 * d + d_extra,) + cfg.mlp_dims + (1,),
                             dtype)
    elif cfg.interaction == "multi-interest":  # mind
        p["item_table"] = normal_init(next(ks), (cfg.item_vocab, d), 0.01,
                                      dtype)
        p["S"] = normal_init(next(ks), (d, d), d ** -0.5, dtype)
        p["head"] = init_mlp(next(ks), (d, d), dtype)
    else:
        raise ValueError(cfg.interaction)
    if cfg.use_minhash_frontend:
        p["minhash_table"] = normal_init(
            next(ks), (cfg.minhash_k, 1 << cfg.minhash_b, d), 0.01, dtype)
    return p


def recsys_param_shapes(cfg: RecsysConfig):
    return jax.eval_shape(functools.partial(init_recsys_params, cfg),
                          jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _squash(x: jax.Array, axis: int = -1) -> jax.Array:
    n2 = jnp.sum(jnp.square(x), axis=axis, keepdims=True)
    return (n2 / (1.0 + n2)) * x / jnp.sqrt(n2 + 1e-9)


def recsys_logits(params, batch: dict, cfg: RecsysConfig) -> jax.Array:
    """batch keys by arch:
      all:          [set_ids (B, nnz), set_counts (B,)] if minhash frontend
      concat/self-attn: field_ids (B, F)
      target-attn/multi-interest: hist_ids (B, L), hist_mask (B, L),
                                  target_id (B,)
    Returns (B,) logits.
    """
    extra = None
    if cfg.use_minhash_frontend:
        extra = minhash_frontend(params, batch["set_ids"],
                                 batch["set_counts"], cfg)      # (B, d)

    if cfg.interaction == "concat":
        ids = constrain(batch["field_ids"], "batch", None)
        emb = embedding_lookup(params["tables"], ids)            # (B, F, d)
        emb = constrain(emb, "batch", None, None)
        wide = jnp.sum(embedding_lookup(params["wide"], ids)[..., 0], axis=1)
        deep_in = emb.reshape(emb.shape[0], -1)
        if extra is not None:
            deep_in = jnp.concatenate([deep_in, extra], axis=-1)
        deep = mlp(deep_in, params["deep"]["w"], params["deep"]["b"])[:, 0]
        return wide + deep

    if cfg.interaction == "self-attn":
        ids = constrain(batch["field_ids"], "batch", None)
        x = embedding_lookup(params["tables"], ids)              # (B, F, d)
        if extra is not None:
            x = jnp.concatenate([x, extra[:, None, :]], axis=1)
        x = constrain(x, "batch", None, None)
        h, da = cfg.n_attn_heads, cfg.d_attn
        for lp in params["attn_layers"]:
            B, F, d_in = x.shape
            q = (x @ lp["wq"]).reshape(B, F, h, da)
            k = (x @ lp["wk"]).reshape(B, F, h, da)
            v = (x @ lp["wv"]).reshape(B, F, h, da)
            s = jnp.einsum("bfhd,bghd->bhfg", q, k) / jnp.sqrt(float(da))
            a = jax.nn.softmax(s, axis=-1)
            o = jnp.einsum("bhfg,bghd->bfhd", a, v).reshape(B, F, h * da)
            x = jax.nn.relu(o + x @ lp["wres"])
        flat = x.reshape(x.shape[0], -1)
        return mlp(flat, params["head"]["w"], params["head"]["b"])[:, 0]

    if cfg.interaction == "target-attn":
        hist = embedding_bag_seq(params["item_table"], batch["hist_ids"])
        tgt = jnp.take(params["item_table"], batch["target_id"], axis=0)
        hist = constrain(hist, "batch", None, None)
        B, L, d = hist.shape
        t = jnp.broadcast_to(tgt[:, None, :], (B, L, d))
        att_in = jnp.concatenate([hist, t, hist - t, hist * t], axis=-1)
        scores = mlp(att_in, params["attn_mlp"]["w"],
                     params["attn_mlp"]["b"])[..., 0]            # (B, L)
        scores = jnp.where(batch["hist_mask"] > 0, scores, -1e9)
        # DIN uses unnormalized sigmoid gates; softmax variant is standard too
        w = jax.nn.softmax(scores, axis=-1)
        user = jnp.einsum("bl,bld->bd", w, hist)
        head_in = [user, tgt, user * tgt]
        if extra is not None:
            head_in.append(extra)
        return mlp(jnp.concatenate(head_in, axis=-1), params["head"]["w"],
                   params["head"]["b"])[:, 0]

    if cfg.interaction == "multi-interest":
        hist = embedding_bag_seq(params["item_table"], batch["hist_ids"])
        tgt = jnp.take(params["item_table"], batch["target_id"], axis=0)
        hist = constrain(hist, "batch", None, None)
        B, L, d = hist.shape
        K = cfg.n_interests
        hS = hist @ params["S"]                                   # (B, L, d)
        blog = jnp.zeros((B, L, K), jnp.float32)
        mask = batch["hist_mask"].astype(jnp.float32)
        interests = None
        for _ in range(cfg.capsule_iters):
            w = jax.nn.softmax(blog, axis=-1) * mask[..., None]
            z = jnp.einsum("blk,bld->bkd", w, hS)
            interests = _squash(z)
            blog = blog + jnp.einsum("bld,bkd->blk", hS, interests)
        interests = mlp(interests, params["head"]["w"], params["head"]["b"],
                        act=jax.nn.relu, final_act=False)
        la = jax.nn.softmax(
            jnp.einsum("bkd,bd->bk", interests, tgt) * 2.0, axis=-1)
        user = jnp.einsum("bk,bkd->bd", la, interests)
        return jnp.einsum("bd,bd->b", user, tgt)

    raise ValueError(cfg.interaction)


def embedding_bag_seq(table: jax.Array, ids: jax.Array) -> jax.Array:
    """(V, d) x (B, L) -> (B, L, d) gather (the per-step bag)."""
    return jnp.take(table, ids.astype(jnp.int32), axis=0)


def recsys_loss(params, batch: dict, cfg: RecsysConfig) -> jax.Array:
    """Binary logistic loss on {0, 1} labels."""
    logits = recsys_logits(params, batch, cfg).astype(jnp.float32)
    y = batch["labels"].astype(jnp.float32)
    return jnp.mean(jax.nn.softplus(-logits) + (1.0 - y) * logits)


def serve_scores(params, batch: dict, cfg: RecsysConfig) -> jax.Array:
    """Online/offline scoring: sigmoid(logits)."""
    return jax.nn.sigmoid(recsys_logits(params, batch, cfg))


def retrieval_scores(params, batch: dict, cfg: RecsysConfig,
                     n_candidates: int) -> jax.Array:
    """Score one query context against n_candidates items (retrieval_cand).

    Sequence models (din/mind) compute the user representation once and
    score all candidates; field models (autoint/wide-deep) broadcast the
    user fields across the candidate axis (batched full scoring).
    Returns (n_candidates,) scores.
    """
    if cfg.interaction in ("target-attn", "multi-interest"):
        cand = jnp.arange(n_candidates, dtype=jnp.int32) % cfg.item_vocab
        rep = {k: jnp.repeat(v, n_candidates, axis=0)
               for k, v in batch.items() if k != "target_id"}
        rep["target_id"] = cand
        return recsys_logits(params, rep, cfg)
    cand = jnp.arange(n_candidates, dtype=jnp.int32) % cfg.vocab
    rep = {k: jnp.repeat(v, n_candidates, axis=0) for k, v in batch.items()}
    rep["field_ids"] = rep["field_ids"].at[:, -1].set(cand)
    return recsys_logits(params, rep, cfg)
