"""Mixture-of-experts FFN with sort-based capacity dispatch.

Top-k routing -> stable-sort tokens by expert -> scatter into per-expert
capacity buffers -> batched expert einsum on the MXU -> weighted combine.
O(T*k) bookkeeping, no (T, E, C) one-hot tensor.  Experts are sharded over
the ``model`` mesh axis (expert parallelism); token buffers move between
data- and expert-sharded layouts, which XLA lowers to all-to-all style
collectives under GSPMD.

Follows DeepSeek-MoE structure: ``n_shared`` always-on shared experts plus
``n_experts`` routed experts with ``top_k`` routing and optional
sigmoid+bias (aux-loss-free) or softmax routing.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.compat import shard_map
from repro.models.layers import swiglu
from repro.sharding.rules import constrain


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                    # per-expert hidden
    n_shared: int = 0
    capacity_factor: float = 1.25
    router: str = "softmax"      # "softmax" | "sigmoid" (aux-loss-free)


def init_moe_params(key, d_model: int, cfg: MoEConfig, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 5)
    E, f = cfg.n_experts, cfg.d_ff
    scale = d_model ** -0.5
    p = {
        "router": (jax.random.normal(ks[0], (d_model, E)) * scale
                   ).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (E, d_model, f)) * scale).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (E, d_model, f)) * scale).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (E, f, d_model)) * (f ** -0.5)
                   ).astype(dtype),
    }
    if cfg.n_shared:
        fs = cfg.d_ff * cfg.n_shared
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": (jax.random.normal(k1, (d_model, fs)) * scale).astype(dtype),
            "w_up": (jax.random.normal(k2, (d_model, fs)) * scale).astype(dtype),
            "w_down": (jax.random.normal(k3, (fs, d_model)) * (fs ** -0.5)
                       ).astype(dtype),
        }
    return p


def moe_ffn(params: dict, x: jax.Array, cfg: MoEConfig) -> jax.Array:
    """x: (T, d_model) -> (T, d_model).

    Under an active mesh this dispatches to the expert-parallel shard_map
    implementation (``moe_ffn_ep``); the plain-GSPMD path below is the
    single-device / no-mesh reference.  (GSPMD cannot shard the
    data-dependent dispatch gather -- at deepseek-v3 scale the (T*k, d)
    gather is 28 GiB/chip -- so EP is structural, not a tuning choice.)
    """
    from repro.sharding.rules import current_mesh
    mesh = current_mesh()
    if mesh is not None and "model" in mesh.axis_names:
        return moe_ffn_ep(params, x, cfg, mesh)
    return _moe_ffn_dense(params, x, cfg)


def _moe_ffn_dense(params: dict, x: jax.Array, cfg: MoEConfig) -> jax.Array:
    """Reference path (no mesh): sort-based capacity dispatch in plain jnp."""
    T, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = _capacity(T, cfg)

    logits = (x.astype(jnp.float32) @ params["router"])      # (T, E)
    if cfg.router == "sigmoid":
        scores = jax.nn.sigmoid(logits)
    else:
        scores = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(scores, k)                    # (T, k)
    topv = topv / jnp.maximum(jnp.sum(topv, axis=-1, keepdims=True), 1e-9)

    flat_e = topi.reshape(-1)                                # (T*k,)
    flat_w = topv.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(T), k)

    order = jnp.argsort(flat_e, stable=True)
    e_sorted = flat_e[order]
    tok_sorted = flat_tok[order]
    w_sorted = flat_w[order]
    starts = jnp.searchsorted(e_sorted, jnp.arange(E))       # (E,)
    pos_in_e = jnp.arange(T * k) - starts[e_sorted]
    keep = pos_in_e < C
    dest = jnp.where(keep, e_sorted * C + pos_in_e, E * C)   # OOB -> dropped

    buf = jnp.zeros((E * C, d), x.dtype).at[dest].set(
        x[tok_sorted], mode="drop").reshape(E, C, d)
    buf = constrain(buf, "model", None, None)     # expert-parallel buffers

    h = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["w_gate"]))
         * jnp.einsum("ecd,edf->ecf", buf, params["w_up"]))
    h = constrain(h, "model", None, None)
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
    out_buf = constrain(out_buf, "model", None, None)

    gathered = out_buf.reshape(E * C, d)[jnp.where(keep, dest, 0)]
    gathered = gathered * (keep[:, None] & True) * w_sorted[:, None].astype(x.dtype)
    out = jnp.zeros((T, d), x.dtype).at[tok_sorted].add(gathered)

    if "shared" in params:
        sp = params["shared"]
        out = out + swiglu(x, sp["w_gate"], sp["w_up"], sp["w_down"])
    return out


# ---------------------------------------------------------------------------
# Expert-parallel shard_map implementation
# ---------------------------------------------------------------------------

def ep_layout(mesh, E: int):
    """Expert-parallel group: as many mesh axes as E divides into.

    256-expert models span ("model", "data") = the whole 256-chip pod
    (1 expert/chip, full (d, f) weights, NO weight gathering -- the §Perf
    deepseek-v3 iteration); 16-expert models span ("model",) with d_ff
    FSDP'd over the remaining axes and gathered just-in-time.
    Returns (ep_axes, ffn_shard_axes, complement_token_axes).
    """
    ep_axes = []
    size = 1
    for name in ("model", "data"):
        if name in mesh.axis_names and E % (size * mesh.shape[name]) == 0:
            ep_axes.append(name)
            size *= mesh.shape[name]
    ep_axes = tuple(ep_axes)
    ffn_axes = tuple(n for n in ("data", "pod")
                     if n in mesh.axis_names and n not in ep_axes)
    tok_rest = tuple(n for n in ("pod", "data")
                     if n in mesh.axis_names and n not in ep_axes)
    return ep_axes, ffn_axes, tok_rest


def moe_ffn_ep(params: dict, x: jax.Array, cfg: MoEConfig, mesh) -> jax.Array:
    """Expert parallelism via shard_map with token all-to-all dispatch.

    Experts sharded over the EP group (see ep_layout); remaining d_ff
    sharding is FSDP'd and gathered just-in-time.  Fast path (token count
    divides the whole mesh): tokens sharded over every axis, dispatched to
    expert owners by all_to_all over the EP group and combined on the way
    back -- per-chip traffic ~ 2 * T_loc * top_k * d bytes/layer instead
    of re-gathering expert weights every pass.  Fallback (small/indivisible
    token counts, e.g. decode): tokens sharded over the complement axes,
    each chip computes its local experts' contributions, one psum over the
    EP group combines.
    """
    from jax.sharding import PartitionSpec as P
    T, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    ep_axes, ffn_axes, tok_rest = ep_layout(mesh, E)
    n_ep = 1
    for n in ep_axes:
        n_ep *= mesh.shape[n]
    n_all = 1
    for n in mesh.axis_names:
        n_all *= mesh.shape[n]
    E_loc = E // n_ep

    wg_spec = P(ep_axes, None, ffn_axes if ffn_axes else None)
    wd_spec = P(ep_axes, ffn_axes if ffn_axes else None, None)

    # Enter shard_map in the activations' NATIVE layout -- tokens over the
    # batch axes, d over "model" -- and convert inside with an explicit
    # all_to_all.  Feeding GSPMD a token-sharded in_spec instead makes it
    # reshard at the boundary by FULL REPLICATION of the (T, d) fp32
    # cotangent (~3.5 GB/layer at deepseek-v3 scale).
    tp = mesh.shape.get("model", 1)
    batch_axes = tuple(n for n in ("pod", "data") if n in mesh.axis_names)
    dp_b = 1
    for n in batch_axes:
        dp_b *= mesh.shape[n]
    d_loc = d // tp if d % tp == 0 else d
    d_spec = "model" if d % tp == 0 else None

    a2a = (T % n_all == 0) and (T // n_all > 0) and d % tp == 0
    if a2a:
        tok_spec = P(batch_axes if batch_axes else None, d_spec)
        T_loc = T // n_all
    else:
        n_rest = 1
        for n in tok_rest:
            n_rest *= mesh.shape[n]
        if tok_rest and T % n_rest == 0:
            tok_spec = P(tok_rest, d_spec)
            T_loc = T // n_rest
        else:
            tok_spec = P(None, d_spec)
            T_loc = T
    C = _capacity_local(T_loc, cfg)

    def _route(x_loc, router_w):
        logits = x_loc.astype(jnp.float32) @ router_w        # (T_loc, E)
        scores = (jax.nn.sigmoid(logits) if cfg.router == "sigmoid"
                  else jax.nn.softmax(logits, axis=-1))
        topv, topi = jax.lax.top_k(scores, k)                # (T_loc, k)
        topv = topv / jnp.maximum(jnp.sum(topv, -1, keepdims=True), 1e-9)
        return topv, topi

    def _dispatch(x_loc, ids, weights, n_buckets, bucket_cap):
        """Sort-based capacity dispatch of (T_loc*k) copies into
        (n_buckets, bucket_cap) slots. ids == n_buckets marks invalid."""
        order = jnp.argsort(ids, stable=True)
        ids_s = ids[order]
        tok_s = (jnp.repeat(jnp.arange(T_loc), k))[order]
        w_s = weights[order]
        starts = jnp.searchsorted(ids_s, jnp.arange(n_buckets))
        pos = jnp.arange(T_loc * k) - starts[ids_s]
        n_slots = n_buckets * bucket_cap
        sl = slice(0, min(n_slots, T_loc * k))
        ids_s, tok_s, w_s, pos = ids_s[sl], tok_s[sl], w_s[sl], pos[sl]
        keep = (ids_s < n_buckets) & (pos < bucket_cap)
        dest = jnp.where(keep, ids_s * bucket_cap + pos, n_slots)
        buf = jnp.zeros((n_slots, d), x_loc.dtype).at[dest].set(
            x_loc[tok_s], mode="drop")
        return buf, dest, tok_s, w_s, keep

    def _experts(buf_e, w_gate, w_up, w_down):
        h = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf_e, w_gate))
             * jnp.einsum("ecd,edf->ecf", buf_e, w_up))
        return jnp.einsum("ecf,efd->ecd", h, w_down)

    def _gather_ffn(w_gate, w_up, w_down):
        if ffn_axes:
            w_gate = jax.lax.all_gather(w_gate, ffn_axes, axis=2, tiled=True)
            w_up = jax.lax.all_gather(w_up, ffn_axes, axis=2, tiled=True)
            w_down = jax.lax.all_gather(w_down, ffn_axes, axis=1, tiled=True)
        return w_gate, w_up, w_down

    def block_a2a(x_in, router_w, w_gate, w_up, w_down):
        # (T_b, d/tp) -> (T_b/tp, d): tokens split over "model", d-slices
        # reassembled -- the sequence-parallel -> EP layout switch
        if d_spec is not None and tp > 1:
            x_loc = jax.lax.all_to_all(x_in, "model", split_axis=0,
                                       concat_axis=1, tiled=True)
        else:
            x_loc = x_in
        w_gate, w_up, w_down = _gather_ffn(w_gate, w_up, w_down)
        topv, topi = _route(x_loc, router_w)
        # bucket id = global expert id; owner rank = e // E_loc
        buf, dest, tok_s, w_s, keep = _dispatch(
            x_loc, topi.reshape(-1), topv.reshape(-1), E, C)
        send = buf.reshape(n_ep, E_loc * C, d)
        recv = jax.lax.all_to_all(send, ep_axes, split_axis=0,
                                  concat_axis=0, tiled=True)
        # recv: (n_ep, E_loc*C, d) -- source-major; regroup per expert
        xs = recv.reshape(n_ep, E_loc, C, d).transpose(1, 0, 2, 3) \
            .reshape(E_loc, n_ep * C, d)
        ys = _experts(xs, w_gate, w_up, w_down)
        back = ys.reshape(E_loc, n_ep, C, d).transpose(1, 0, 2, 3) \
            .reshape(n_ep, E_loc * C, d)
        got = jax.lax.all_to_all(back, ep_axes, split_axis=0,
                                 concat_axis=0, tiled=True)
        out_flat = got.reshape(E * C, d)
        contrib = out_flat[jnp.where(keep, dest, 0)] \
            * (keep[:, None] & True) * w_s[:, None].astype(x_loc.dtype)
        y = jnp.zeros((T_loc, d), x_loc.dtype).at[tok_s].add(contrib)
        if d_spec is not None and tp > 1:   # back to (T_b, d/tp)
            y = jax.lax.all_to_all(y, "model", split_axis=1,
                                   concat_axis=0, tiled=True)
        return y

    def block_psum(x_in, router_w, w_gate, w_up, w_down):
        if d_spec is not None and tp > 1:
            x_loc = jax.lax.all_gather(x_in, "model", axis=1, tiled=True)
        else:
            x_loc = x_in
        w_gate, w_up, w_down = _gather_ffn(w_gate, w_up, w_down)
        rank = jnp.int32(0)
        mult = 1
        for n in reversed(ep_axes):
            rank = rank + jax.lax.axis_index(n) * mult
            mult *= mesh.shape[n]
        topv, topi = _route(x_loc, router_w)
        e_local = topi.reshape(-1) - rank * E_loc
        valid = (e_local >= 0) & (e_local < E_loc)
        ids = jnp.where(valid, e_local, E_loc)
        buf, dest, tok_s, w_s, keep = _dispatch(
            x_loc, ids, topv.reshape(-1), E_loc, C)
        ys = _experts(buf.reshape(E_loc, C, d), w_gate, w_up, w_down)
        out_flat = ys.reshape(E_loc * C, d)
        contrib = out_flat[jnp.where(keep, dest, 0)] \
            * (keep[:, None] & True) * w_s[:, None].astype(x_loc.dtype)
        y_loc = jnp.zeros((T_loc, d), x_loc.dtype).at[tok_s].add(contrib)
        y_loc = jax.lax.psum(y_loc, ep_axes)
        if d_spec is not None and tp > 1:   # hand back my d-slice
            j = jax.lax.axis_index("model")
            y_loc = jax.lax.dynamic_slice_in_dim(y_loc, j * d_loc, d_loc, 1)
        return y_loc

    y = shard_map(
        block_a2a if a2a else block_psum, mesh=mesh,
        in_specs=(tok_spec, P(), wg_spec, wg_spec, wd_spec),
        out_specs=tok_spec, check_vma=False,
    )(x, params["router"], params["w_gate"], params["w_up"],
      params["w_down"])

    if "shared" in params:
        sp = params["shared"]
        from repro.sharding.rules import constrain
        hs = jax.nn.silu(x @ sp["w_gate"]) * (x @ sp["w_up"])
        hs = constrain(hs, "batch", "model")
        y = y + hs @ sp["w_down"]
    return y


def _capacity_local(T_loc: int, cfg: MoEConfig) -> int:
    c = int(T_loc * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(4, ((c + 3) // 4) * 4)


def moe_load_balance_loss(logits: jax.Array, topi: jax.Array, E: int
                          ) -> jax.Array:
    """Switch-style aux loss: E * sum_e f_e * p_e."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    p_e = jnp.mean(probs, axis=0)
    f_e = jnp.mean(jax.nn.one_hot(topi[..., 0], E), axis=0)
    return E * jnp.sum(p_e * f_e)


def _capacity(T: int, cfg: MoEConfig) -> int:
    c = int(T * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(8, ((c + 7) // 8) * 8)
