"""Attention variants: GQA (full/causal), chunked-local (llama4-style),
MLA (DeepSeek multi-head latent), plus single-token decode paths.

Training attention is *blockwise* (flash-style online softmax over KV
blocks via ``lax.scan``) so score matrices never materialize beyond
``(B, heads, q_blk, kv_blk)`` -- mandatory for the 32k-prefill dry-run
cells to fit HBM.  The mask (causal / chunked-local) is computed from
indices on the fly, never materialized at (S, S).
"""

from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _block_mask(q_idx: jax.Array, k_idx: jax.Array, window) -> jax.Array:
    """(q_blk, kv_blk) validity. window: 0/None = causal full;
    w > 0 = causal within chunk floor(idx/w) (llama4 chunked-local)."""
    causal = k_idx[None, :] <= q_idx[:, None]
    if window is None:
        return causal
    w = jnp.asarray(window, jnp.int32)
    same_chunk = (k_idx[None, :] // jnp.maximum(w, 1)) == (
        q_idx[:, None] // jnp.maximum(w, 1))
    return jnp.where(w > 0, causal & same_chunk, causal)


def blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        window=None, q_offset: int = 0,
                        blk_q: int = 1024, blk_kv: int = 1024) -> jax.Array:
    """Causal (optionally chunked-local) attention with online softmax.

    q: (B, Sq, Hq, hd);  k, v: (B, Skv, Hkv, hd) with Hq % Hkv == 0 (GQA).
    window may be a traced scalar (0 = full causal) so heterogeneous layer
    stacks can be scanned with a per-layer window value.
    Returns (B, Sq, Hq, hd).
    """
    B, Sq, Hq, hd = q.shape
    _, Skv, Hkv, _ = k.shape
    hd_v = v.shape[-1]                 # MLA: d_v may differ from d_qk
    G = Hq // Hkv
    scale = hd ** -0.5

    blk_q = min(blk_q, Sq)
    blk_kv = min(blk_kv, Skv)
    nq, nkv = Sq // blk_q, Skv // blk_kv
    assert Sq % blk_q == 0 and Skv % blk_kv == 0

    # (B, nq, blk_q, Hkv, G, hd) -> scan over nq outer, nkv inner
    qb = q.reshape(B, nq, blk_q, Hkv, G, hd)
    kb = k.reshape(B, nkv, blk_kv, Hkv, hd)
    vb = v.reshape(B, nkv, blk_kv, Hkv, hd_v)

    def q_block(carry, qi):
        q_i = qb[:, qi]                                # (B, bq, Hkv, G, hd)
        q_idx = q_offset + qi * blk_q + jnp.arange(blk_q)

        def kv_block(state, ki):
            m, l, acc = state
            k_j = kb[:, ki]                            # (B, bk, Hkv, hd)
            v_j = vb[:, ki]
            k_idx = ki * blk_kv + jnp.arange(blk_kv)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", q_i, k_j,
                           preferred_element_type=jnp.float32) * scale
            mask = _block_mask(q_idx, k_idx, window)   # (bq, bk)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(v_j.dtype), v_j,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, blk_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, blk_q), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, blk_q, hd_v), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_block, (m0, l0, a0),
                                      jnp.arange(nkv))
        out = acc / jnp.maximum(l, 1e-30)[..., None]   # (B, Hkv, G, bq, hd)
        out = jnp.moveaxis(out, 3, 1)                  # (B, bq, Hkv, G, hd)
        return carry, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_block, None, jnp.arange(nq))
    # outs: (nq, B, blk_q, Hkv, G, hd_v)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Sq, Hq, hd_v)
    return out


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     pos: jax.Array, *, window=None) -> jax.Array:
    """One-token attention over a KV cache.

    q: (B, Hq, hd); caches: (B, L, Hkv, hd); pos: () int32 -- number of
    valid cache entries (the new token's K/V already written at pos-1).
    ``window`` (traced scalar ok): > 0 restricts attention to the current
    length-``window`` chunk (llama4 chunked-local); 0/None = full causal.
    Returns (B, Hq, hd).
    """
    B, L, Hkv, hd = k_cache.shape
    Hq = q.shape[1]
    G = Hq // Hkv
    scale = hd ** -0.5
    qg = q.reshape(B, Hkv, G, hd)
    s = jnp.einsum("bhgd,blhd->bhgl", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    idx = jnp.arange(L)
    valid = idx < pos
    if window is not None:
        w = jnp.maximum(jnp.asarray(window, jnp.int32), 1)
        in_chunk = (idx // w) == ((pos - 1) // w)
        valid = valid & jnp.where(jnp.asarray(window, jnp.int32) > 0,
                                  in_chunk, True)
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgl,blhd->bhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Hq, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, DeepSeek-V2/V3)
# ---------------------------------------------------------------------------

def mla_prefill(x: jax.Array, p: dict, *, n_heads: int, d_nope: int,
                d_rope: int, d_v: int, positions: jax.Array,
                rope_theta: float, blk: int = 1024) -> jax.Array:
    """MLA forward for training/prefill (decompressed K/V).

    Params p: wdq (d, q_lora), wuq (q_lora, H*(d_nope+d_rope)),
              wdkv (d, kv_lora), wukv (kv_lora, H*(d_nope+d_v)),
              wkr (d, d_rope), q_norm (q_lora,), kv_norm (kv_lora,),
              wo (H*d_v, d).
    """
    from repro.models.layers import apply_rope, rms_norm
    B, S, D = x.shape
    H = n_heads
    cq = rms_norm(x @ p["wdq"], p["q_norm"])               # (B,S,q_lora)
    q = (cq @ p["wuq"]).reshape(B, S, H, d_nope + d_rope)
    q_nope, q_rope = q[..., :d_nope], q[..., d_nope:]
    q_rope = apply_rope(q_rope, positions, rope_theta)

    ckv = rms_norm(x @ p["wdkv"], p["kv_norm"])            # (B,S,kv_lora)
    kv = (ckv @ p["wukv"]).reshape(B, S, H, d_nope + d_v)
    k_nope, v = kv[..., :d_nope], kv[..., d_nope:]
    k_rope = apply_rope((x @ p["wkr"])[:, :, None, :], positions,
                        rope_theta)                        # (B,S,1,d_rope)

    qc = jnp.concatenate([q_nope, q_rope], axis=-1)
    kc = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, S, H, d_rope))], axis=-1)
    out = blockwise_attention(qc, kc, v, blk_q=blk, blk_kv=blk)
    return out.reshape(B, S, H * d_v) @ p["wo"]


def mla_decode(x: jax.Array, p: dict, ckv_cache: jax.Array,
               kr_cache: jax.Array, pos: jax.Array, *, n_heads: int,
               d_nope: int, d_rope: int, d_v: int, rope_theta: float
               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Absorbed-weight MLA decode: attention runs in the compressed space.

    The cache stores only (kv_lora + d_rope) per token (MLA's raison
    d'etre).  W_uk is absorbed into the query, W_uv into the output:
        score_h = (q_nope_h W_uk_h) . c_kv + q_rope_h . k_rope
        out_h   = (sum_t a_t c_kv_t) W_uv_h
    x: (B, D) one token. caches: (B, L, kv_lora), (B, L, d_rope).
    Returns (attn_out (B, D), new ckv_cache, new kr_cache).
    """
    from repro.models.layers import apply_rope, rms_norm
    B, D = x.shape
    H = n_heads
    L = ckv_cache.shape[1]
    kv_lora = ckv_cache.shape[2]

    cq = rms_norm(x @ p["wdq"], p["q_norm"])
    q = (cq @ p["wuq"]).reshape(B, H, d_nope + d_rope)
    q_nope, q_rope = q[..., :d_nope], q[..., d_nope:]
    q_rope = apply_rope(q_rope[:, None], (pos - 1)[None],
                        rope_theta)[:, 0]                   # (B,H,d_rope)

    ckv_new = rms_norm(x @ p["wdkv"], p["kv_norm"])         # (B, kv_lora)
    kr_new = apply_rope((x @ p["wkr"])[:, None, None, :], (pos - 1)[None],
                        rope_theta)[:, 0, 0]                # (B, d_rope)
    ckv_cache = jax.lax.dynamic_update_slice_in_dim(
        ckv_cache, ckv_new[:, None], pos - 1, axis=1)
    kr_cache = jax.lax.dynamic_update_slice_in_dim(
        kr_cache, kr_new[:, None], pos - 1, axis=1)

    # absorb W_uk: wukv is (kv_lora, H*(d_nope+d_v)); split per head
    wukv = p["wukv"].reshape(kv_lora, H, d_nope + d_v)
    w_uk = wukv[:, :, :d_nope]                              # (kv_lora, H, d_nope)
    w_uv = wukv[:, :, d_nope:]                              # (kv_lora, H, d_v)
    q_c = jnp.einsum("bhn,chn->bhc", q_nope, w_uk)          # (B, H, kv_lora)

    scale = (d_nope + d_rope) ** -0.5
    s = (jnp.einsum("bhc,blc->bhl", q_c, ckv_cache,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bhr,blr->bhl", q_rope, kr_cache,
                      preferred_element_type=jnp.float32)) * scale
    valid = jnp.arange(L) < pos
    s = jnp.where(valid[None, None], s, NEG_INF)
    a = jax.nn.softmax(s, axis=-1)
    o_c = jnp.einsum("bhl,blc->bhc", a.astype(ckv_cache.dtype), ckv_cache,
                     preferred_element_type=jnp.float32)    # (B, H, kv_lora)
    o = jnp.einsum("bhc,chv->bhv", o_c.astype(x.dtype), w_uv)  # (B, H, d_v)
    out = o.reshape(B, H * d_v) @ p["wo"]
    return out, ckv_cache, kr_cache
