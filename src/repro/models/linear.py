"""Linear SVM and logistic regression on b-bit-hashed features.

The paper's learning layer (§1.2, §5, §6):

  * L2-regularized linear SVM (Eq. 6) and logistic regression (Eq. 7),
  * operating on the Eq.(5) expansion of k b-bit signatures -- implemented
    *implicitly*: the weight vector w lives in (k * 2^b,) and the forward
    pass is the signature embedding-bag ``sum_j w[j * 2^b + z_j]``
    (``repro.kernels.sigbag`` with d = 1), never materializing one-hots,
  * also usable on dense features (VW-hashed vectors, original data) for
    the paper's baselines,
  * and directly on the *packed* wire format (``feature_kind="packed"``):
    mini-batches arrive as (n, words) uint32 -- k*b bits per example, the
    §6/Table-2 budget -- and the bitstream unpack happens *inside* the
    jitted margin/gradient, so the packed words are all that ever moves.
    Sentinel OPH codes (value 2^b) come out of the unpack as invalid
    tokens and are zero-coded like EMPTY.

Paper mapping:
  * Eq. (5): ``hashed_margin`` / the implicit expansion via
    ``repro.core.bbit.expand_tokens``,
  * Eq. (6)-(7): ``svm_objective`` / ``logistic_objective``,
  * §6, Eq. (11)-(12): ``sgd_svm_step`` (Bottou schedule), §6.3 ASGD via
    ``average=True`` + ``asgd_model``,
  * arXiv:1208.1259 (One Permutation Hashing): sentinel-densified OPH
    signatures carry EMPTY bins; both the margin and the gradient
    *zero-code* them (an empty bin contributes nothing to Eq. 5), so
    ``densify="sentinel"`` trains without densification.

Feature scaling: as in [27], each expanded vector has exactly k ones, so
we scale by 1/sqrt(k) to unit-norm the features (keeps C comparable
across k).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.bbit import expand_tokens, unpack_codes


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class LinearModel:
    w: jax.Array                     # (dim,) float32
    bias: jax.Array                  # () float32

    @staticmethod
    def create(dim: int, dtype=jnp.float32) -> "LinearModel":
        return LinearModel(w=jnp.zeros((dim,), dtype), bias=jnp.zeros((), dtype))


def packed_to_values(packed: jax.Array, *, k: int, b: int,
                     sentinel: bool = False) -> jax.Array:
    """Wire-format words -> (n, k) signature values, traced inside jit.

    Sentinel schemes carry (b+1)-bit codes; the EMPTY code 2^b is already
    >= 2^b, so ``_valid_tokens`` zero-codes it with no extra mapping.
    """
    return unpack_codes(packed, b + 1 if sentinel else b, k)


def _as_hashed(feats: jax.Array, feature_kind: str, b: int,
               k: Optional[int], sentinel: bool):
    """Normalize 'packed' features to b-bit values; pass 'hashed' through."""
    if feature_kind != "packed":
        return feats, feature_kind
    if k is None:
        raise ValueError("feature_kind='packed' needs k= (signature length)")
    return packed_to_values(feats, k=k, b=b, sentinel=sentinel), "hashed"


def _valid_tokens(sig_b: jax.Array, b: int) -> tuple[jax.Array, jax.Array]:
    """(tokens, validity) for Eq.(5): EMPTY bins (>= 2^b, OPH sentinel
    densification) are zero-coded -- token 0 with validity False."""
    if b >= 32:
        valid = jnp.ones(sig_b.shape, bool)
    else:
        valid = sig_b.astype(jnp.uint32) < jnp.uint32(1 << b)
    tok = expand_tokens(jnp.where(valid, sig_b, 0).astype(sig_b.dtype), b)
    return tok, valid


def hashed_margin(model: LinearModel, sig_b: jax.Array, b: int) -> jax.Array:
    """w . phi(x) for the implicit Eq.(5) expansion; (n,) scores."""
    k = sig_b.shape[-1]
    tok, valid = _valid_tokens(sig_b, b)               # (n, k)
    scale = 1.0 / jnp.sqrt(jnp.asarray(k, jnp.float32))
    return jnp.sum(jnp.where(valid, model.w[tok], 0.0), axis=-1) * scale \
        + model.bias


def dense_margin(model: LinearModel, x: jax.Array) -> jax.Array:
    return x @ model.w + model.bias


def svm_objective(margins: jax.Array, y: jax.Array, w: jax.Array,
                  C: float) -> jax.Array:
    """Eq. (6): (1/2)||w||^2 + C sum max(1 - y m, 0) (sum over batch)."""
    hinge = jnp.maximum(1.0 - y * margins, 0.0)
    return 0.5 * jnp.sum(w * w) + C * jnp.sum(hinge)

def logistic_objective(margins: jax.Array, y: jax.Array, w: jax.Array,
                       C: float) -> jax.Array:
    """Eq. (7): (1/2)||w||^2 + C sum log(1 + exp(-y m))."""
    # log1p(exp(-z)) computed stably via softplus(-z)
    return 0.5 * jnp.sum(w * w) + C * jnp.sum(jax.nn.softplus(-y * margins))


def make_loss_fn(kind: str, feature_kind: str, b: int, C: float, *,
                 k: Optional[int] = None, sentinel: bool = False
                 ) -> Callable[[LinearModel, jax.Array, jax.Array], jax.Array]:
    """Loss(model, features, y). feature_kind: 'hashed'|'packed'|'dense'."""
    obj = svm_objective if kind == "svm" else logistic_objective

    def loss(model: LinearModel, feats: jax.Array, y: jax.Array) -> jax.Array:
        feats, fkind = _as_hashed(feats, feature_kind, b, k, sentinel)
        m = (hashed_margin(model, feats, b) if fkind == "hashed"
             else dense_margin(model, feats))
        # normalize the data term by batch size so C matches the paper's
        # per-example weighting under mini-batching
        n = y.shape[0]
        return obj(m, y, model.w, C) / n

    return loss


def accuracy(model: LinearModel, feats: jax.Array, y: jax.Array, *,
             feature_kind: str, b: int = 0, k: Optional[int] = None,
             sentinel: bool = False) -> jax.Array:
    feats, fkind = _as_hashed(feats, feature_kind, b, k, sentinel)
    m = (hashed_margin(model, feats, b) if fkind == "hashed"
         else dense_margin(model, feats))
    return jnp.mean((jnp.sign(m) == y).astype(jnp.float32))


# ---------------------------------------------------------------------------
# Bottou-style online SGD SVM (§6, Eq. 11-12)
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SGDState:
    model: LinearModel
    t: jax.Array                     # step counter (float for the lr schedule)
    avg_w: jax.Array                 # ASGD running average
    avg_bias: jax.Array
    avg_start: float                 # step at which averaging starts


def sgd_svm_init(dim: int, avg_start: float = 0.0) -> SGDState:
    m = LinearModel.create(dim)
    return SGDState(model=m, t=jnp.zeros(()), avg_w=jnp.zeros_like(m.w),
                    avg_bias=jnp.zeros(()), avg_start=avg_start)


def sgd_svm_step(state: SGDState, feats: jax.Array, y: jax.Array, *,
                 lam: float, eta0: float, b: int, feature_kind: str = "hashed",
                 kind: str = "svm", average: bool = False,
                 k: Optional[int] = None, sentinel: bool = False) -> SGDState:
    """One mini-batch SGD update with Bottou's 1/(1 + lam*eta0*t) schedule.

    Implements Eq. (12): w <- w - eta_t * (lam w - [margin violators] y x),
    with the per-example gradient averaged over the mini-batch (batch size 1
    reproduces the paper exactly).  ``average=True`` maintains the ASGD
    (Wei Xu / Bottou averaged-SGD, §6.3) iterate average.
    ``feature_kind="packed"`` takes the k*b-bit wire words and unpacks
    them here, inside the jitted step (``k=`` required).
    """
    feats, feature_kind = _as_hashed(feats, feature_kind, b, k, sentinel)
    model = state.model
    eta = eta0 / (1.0 + lam * eta0 * state.t)

    def data_grad(mod: LinearModel) -> Tuple[jax.Array, jax.Array]:
        if feature_kind == "hashed":
            m = hashed_margin(mod, feats, b)
        else:
            m = dense_margin(mod, feats)
        if kind == "svm":
            coef = jnp.where(y * m < 1.0, -y, 0.0)          # dL/dm
        else:
            coef = -y * jax.nn.sigmoid(-y * m)
        coef = coef / y.shape[0]
        if feature_kind == "hashed":
            k = feats.shape[-1]
            tok, valid = _valid_tokens(feats, b)
            scale = 1.0 / jnp.sqrt(jnp.asarray(k, jnp.float32))
            gw = jnp.zeros_like(mod.w).at[tok].add(
                jnp.where(valid,
                          jnp.broadcast_to(coef[:, None] * scale, tok.shape),
                          0.0))
        else:
            gw = feats.T @ coef
        return gw, jnp.sum(coef)

    gw, gb = data_grad(model)
    new_w = model.w - eta * (lam * model.w + gw)
    new_b = model.bias - eta * gb
    new_t = state.t + 1.0

    if average:
        # polynomial-decay averaging from avg_start onwards
        mu = 1.0 / jnp.maximum(1.0, new_t - state.avg_start)
        take = (new_t > state.avg_start).astype(jnp.float32)
        avg_w = state.avg_w + take * mu * (new_w - state.avg_w)
        avg_b = state.avg_bias + take * mu * (new_b - state.avg_bias)
    else:
        avg_w, avg_b = state.avg_w, state.avg_bias

    return SGDState(model=LinearModel(w=new_w, bias=new_b), t=new_t,
                    avg_w=avg_w, avg_bias=avg_b, avg_start=state.avg_start)


def asgd_model(state: SGDState) -> LinearModel:
    """The averaged iterate (falls back to the last iterate pre-averaging)."""
    started = state.t > state.avg_start
    w = jnp.where(started, state.avg_w, state.model.w)
    bias = jnp.where(started, state.avg_bias, state.model.bias)
    return LinearModel(w=w, bias=bias)


def calibrate_eta0(loss_at_eta: Callable[[float], float],
                   etas=(2.0 ** p for p in range(-8, 4))) -> float:
    """Bottou-style eta0 calibration on a small data subset: pick the eta
    with the lowest one-pass loss."""
    best, best_loss = None, float("inf")
    for eta in etas:
        l = float(loss_at_eta(float(eta)))
        if l < best_loss:
            best, best_loss = float(eta), l
    return best
