"""Decoder-only LM family: dense GQA, chunked-local (llama4-style), MLA,
and MoE variants -- pure-functional JAX with scan-over-layers + remat.

Covers the five assigned LM architectures.  Two entry points:

  * ``train_loss(params, batch, cfg)``     -- next-token CE (chunked,
    vocab-parallel: full fp32 logits are never materialized),
  * ``serve_step(params, cache, tokens, pos, cfg)`` -- one decode step
    over a KV cache (GQA cache or compressed MLA cache).

Sharding is expressed through ``repro.sharding.rules.constrain`` with
logical axes, so the same code runs unsharded in smoke tests and on the
(pod, data, model) production mesh in the dry-run.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import (blockwise_attention, decode_attention,
                                    mla_decode, mla_prefill)
from repro.models.layers import apply_rope, normal_init, rms_norm, swiglu
from repro.models.moe import MoEConfig, init_moe_params, moe_ffn
from repro.sharding.rules import constrain


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    arch_id: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    attention: str = "gqa"            # "gqa" | "mla"
    local_window: int = 0             # >0: chunked-local attention window
    global_every: int = 4             # every Nth layer stays global
    rope_theta: float = 10000.0
    n_dense_layers: int = 0           # leading dense-FFN layers (MoE archs)
    d_ff_dense: int = 0
    moe: Optional[MoEConfig] = None
    # MLA dims (attention == "mla")
    q_lora: int = 1536
    kv_lora: int = 512
    qk_nope: int = 128
    qk_rope: int = 64
    v_head: int = 128
    param_dtype: Any = jnp.bfloat16
    remat: bool = True
    ce_chunk: int = 2048
    attn_blk: int = 1024
    microbatch: int = 1          # gradient-accumulation splits per step

    @property
    def is_moe(self) -> bool:
        return self.moe is not None

    def supports_long_context(self) -> bool:
        """Sub-quadratic prefill (chunked-local attention)?"""
        return self.local_window > 0


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def _init_attn(key, cfg: TransformerConfig, dtype):
    d, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim
    s = d ** -0.5
    if cfg.attention == "mla":
        ks = jax.random.split(key, 6)
        return {
            "wdq": normal_init(ks[0], (d, cfg.q_lora), s, dtype),
            "wuq": normal_init(ks[1], (cfg.q_lora, H * (cfg.qk_nope + cfg.qk_rope)),
                               cfg.q_lora ** -0.5, dtype),
            "wdkv": normal_init(ks[2], (d, cfg.kv_lora), s, dtype),
            "wukv": normal_init(ks[3], (cfg.kv_lora, H * (cfg.qk_nope + cfg.v_head)),
                                cfg.kv_lora ** -0.5, dtype),
            "wkr": normal_init(ks[4], (d, cfg.qk_rope), s, dtype),
            "wo": normal_init(ks[5], (H * cfg.v_head, d),
                              (H * cfg.v_head) ** -0.5, dtype),
            "q_norm": jnp.ones((cfg.q_lora,), dtype),
            "kv_norm": jnp.ones((cfg.kv_lora,), dtype),
        }
    ks = jax.random.split(key, 4)
    return {
        "wq": normal_init(ks[0], (d, H * hd), s, dtype),
        "wk": normal_init(ks[1], (d, Hkv * hd), s, dtype),
        "wv": normal_init(ks[2], (d, Hkv * hd), s, dtype),
        "wo": normal_init(ks[3], (H * hd, d), (H * hd) ** -0.5, dtype),
    }


def _init_dense_ffn(key, d: int, f: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w_gate": normal_init(k1, (d, f), d ** -0.5, dtype),
            "w_up": normal_init(k2, (d, f), d ** -0.5, dtype),
            "w_down": normal_init(k3, (f, d), f ** -0.5, dtype)}


def _init_layer(key, cfg: TransformerConfig, moe_layer: bool, dtype):
    k1, k2 = jax.random.split(key)
    ffn = (init_moe_params(k2, cfg.d_model, cfg.moe, dtype) if moe_layer
           else _init_dense_ffn(k2, cfg.d_model,
                                cfg.d_ff_dense or cfg.d_ff, dtype))
    return {"attn": _init_attn(k1, cfg, dtype), "ffn": ffn,
            "ln1": jnp.ones((cfg.d_model,), dtype),
            "ln2": jnp.ones((cfg.d_model,), dtype)}


def init_params(cfg: TransformerConfig, key: jax.Array):
    dtype = cfg.param_dtype
    k_embed, k_out, k_dense, k_layers = jax.random.split(key, 4)
    n_scan = cfg.n_layers - cfg.n_dense_layers
    params = {
        "embed": normal_init(k_embed, (cfg.vocab, cfg.d_model), 0.02, dtype),
        "out": normal_init(k_out, (cfg.d_model, cfg.vocab),
                           cfg.d_model ** -0.5, dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "layers": jax.vmap(
            lambda k: _init_layer(k, cfg, cfg.is_moe, dtype))(
                jax.random.split(k_layers, n_scan)),
    }
    if cfg.n_dense_layers:
        params["dense_layers"] = jax.vmap(
            lambda k: _init_layer(k, cfg, False, dtype))(
                jax.random.split(k_dense, cfg.n_dense_layers))
    return params


def param_shapes(cfg: TransformerConfig):
    """Shape-only init (no allocation) for the dry-run."""
    return jax.eval_shape(partial(init_params, cfg), jax.random.PRNGKey(0))


def count_params(cfg: TransformerConfig) -> int:
    import math
    shapes = param_shapes(cfg)
    return sum(math.prod(l.shape) for l in jax.tree_util.tree_leaves(shapes))


def count_active_params(cfg: TransformerConfig) -> int:
    """Active params per token (MoE: top_k of n_experts routed)."""
    total = count_params(cfg)
    if not cfg.is_moe:
        return total
    E, k = cfg.moe.n_experts, cfg.moe.top_k
    n_moe_layers = cfg.n_layers - cfg.n_dense_layers
    per_expert = 3 * cfg.d_model * cfg.moe.d_ff
    inactive = n_moe_layers * (E - k) * per_expert
    return total - inactive


# ---------------------------------------------------------------------------
# Forward (training / prefill)
# ---------------------------------------------------------------------------

def _layer_window(cfg: TransformerConfig, idx: jax.Array) -> jax.Array:
    """Per-layer attention window: 0 = full causal."""
    if cfg.local_window <= 0:
        return jnp.int32(0)
    is_global = (idx % cfg.global_every) == (cfg.global_every - 1)
    return jnp.where(is_global, jnp.int32(0), jnp.int32(cfg.local_window))


def _attn_block(p, x, cfg: TransformerConfig, positions, window):
    B, S, D = x.shape
    if cfg.attention == "mla":
        return mla_prefill(x, p, n_heads=cfg.n_heads, d_nope=cfg.qk_nope,
                           d_rope=cfg.qk_rope, d_v=cfg.v_head,
                           positions=positions, rope_theta=cfg.rope_theta,
                           blk=cfg.attn_blk)
    # constrain on the fused head dim (always divisible), reshape after
    q = constrain(x @ p["wq"], "batch", None, "model").reshape(
        B, S, cfg.n_heads, cfg.head_dim)
    k = constrain(x @ p["wk"], "batch", None, "model").reshape(
        B, S, cfg.n_kv, cfg.head_dim)
    v = constrain(x @ p["wv"], "batch", None, "model").reshape(
        B, S, cfg.n_kv, cfg.head_dim)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    out = blockwise_attention(q, k, v, window=window,
                              blk_q=cfg.attn_blk, blk_kv=cfg.attn_blk)
    return out.reshape(B, S, cfg.n_heads * cfg.head_dim) @ p["wo"]


def _ffn_block(p, x, cfg: TransformerConfig, moe_layer: bool):
    B, S, D = x.shape
    if moe_layer:
        out = moe_ffn(p, x.reshape(B * S, D), cfg.moe).reshape(B, S, D)
    else:
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
        h = constrain(h, "batch", None, "model")
        out = h @ p["w_down"]
    return out


def _make_layer_fn(cfg: TransformerConfig, moe_layer: bool, positions):
    def layer(x, p, idx):
        window = _layer_window(cfg, idx)
        # layer-boundary activations sharded on d_model over "model": the
        # remat stash (the dominant HBM consumer) shards tp-ways; GSPMD
        # all-gathers transiently inside the layer where needed.
        x = constrain(x, "batch", None, "model")
        x = x + _attn_block(p["attn"], rms_norm(x, p["ln1"]), cfg,
                            positions, window)
        x = x + _ffn_block(p["ffn"], rms_norm(x, p["ln2"]), cfg, moe_layer)
        return constrain(x, "batch", None, "model")

    if cfg.remat:
        layer = jax.checkpoint(layer)
    return layer


def forward(params, tokens: jax.Array, cfg: TransformerConfig) -> jax.Array:
    """tokens (B, S) -> final hidden states (B, S, D)."""
    B, S = tokens.shape
    positions = jnp.arange(S)
    # gather output deliberately unsharded on d: constraining it on
    # "model" makes XLA's vocab-partitioned-gather emit an invalid
    # dynamic-slice (partitioner bug); the first layer reshards to the
    # d-over-model layout one op later.
    x = constrain(jnp.take(params["embed"], tokens, axis=0),
                  "batch", None, None)

    if cfg.n_dense_layers:
        dense_fn = _make_layer_fn(cfg, False, positions)

        def dense_body(x, inp):
            p, idx = inp
            return dense_fn(x, p, idx), None

        x, _ = jax.lax.scan(dense_body, x,
                            (params["dense_layers"],
                             jnp.arange(cfg.n_dense_layers)))

    layer_fn = _make_layer_fn(cfg, cfg.is_moe, positions)

    def body(x, inp):
        p, idx = inp
        return layer_fn(x, p, idx), None

    n_scan = cfg.n_layers - cfg.n_dense_layers
    x, _ = jax.lax.scan(body, x, (params["layers"],
                                  jnp.arange(cfg.n_dense_layers,
                                             cfg.n_layers)))
    return rms_norm(x, params["final_norm"])


def chunked_ce_loss(x: jax.Array, w_out: jax.Array, labels: jax.Array,
                    chunk: int) -> jax.Array:
    """Vocab-parallel cross-entropy over sequence chunks.

    Never materializes (B, S, V) fp32 logits: each (B, chunk, V) slice is
    produced (vocab sharded on "model"), reduced, and discarded.
    """
    B, S, D = x.shape
    chunk = min(chunk, S)
    n_chunks = S // chunk
    assert S % chunk == 0

    @jax.checkpoint   # recompute chunk logits in bwd: no f32 logits stash
    def chunk_loss(x, labels, i):
        xs = jax.lax.dynamic_slice_in_dim(x, i * chunk, chunk, axis=1)
        ys = jax.lax.dynamic_slice_in_dim(labels, i * chunk, chunk, axis=1)
        logits = constrain(xs @ w_out, "batch", None, "model")
        logits = logits.astype(jnp.float32)
        m = jnp.max(logits, axis=-1, keepdims=True)
        lse = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
        correct = jnp.take_along_axis(logits, ys[..., None], axis=-1)[..., 0]
        return jnp.sum(lse - correct)

    def body(acc, i):
        return acc + chunk_loss(x, labels, i), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                            jnp.arange(n_chunks))
    return total / (B * S)


def train_loss(params, batch: dict, cfg: TransformerConfig) -> jax.Array:
    """batch: {"tokens": (B, S) int32, "labels": (B, S) int32}."""
    x = forward(params, batch["tokens"], cfg)
    return chunked_ce_loss(x, params["out"], batch["labels"], cfg.ce_chunk)


# ---------------------------------------------------------------------------
# Decode (serving)
# ---------------------------------------------------------------------------

def init_cache(cfg: TransformerConfig, batch: int, max_len: int,
               dtype=None):
    """KV cache pytree (all layers, stacked for scan)."""
    dtype = dtype or cfg.param_dtype
    n_scan = cfg.n_layers - cfg.n_dense_layers
    if cfg.attention == "mla":
        def mk(n):
            return {"ckv": jnp.zeros((n, batch, max_len, cfg.kv_lora), dtype),
                    "kr": jnp.zeros((n, batch, max_len, cfg.qk_rope), dtype)}
    else:
        def mk(n):
            shape = (n, batch, max_len, cfg.n_kv, cfg.head_dim)
            return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    cache = {"layers": mk(n_scan)}
    if cfg.n_dense_layers:
        cache["dense_layers"] = mk(cfg.n_dense_layers)
    return cache


def cache_shapes(cfg: TransformerConfig, batch: int, max_len: int,
                 dtype=None):
    return jax.eval_shape(partial(init_cache, cfg, batch, max_len, dtype))


def _decode_attn(p, x, cache_l, pos, cfg: TransformerConfig, window):
    """x: (B, D); cache_l: this layer's cache dict (no layer axis)."""
    B, D = x.shape
    if cfg.attention == "mla":
        out, ckv, kr = mla_decode(x, p, cache_l["ckv"], cache_l["kr"], pos,
                                  n_heads=cfg.n_heads, d_nope=cfg.qk_nope,
                                  d_rope=cfg.qk_rope, d_v=cfg.v_head,
                                  rope_theta=cfg.rope_theta)
        return out, {"ckv": ckv, "kr": kr}
    q = (x @ p["wq"]).reshape(B, cfg.n_heads, cfg.head_dim)
    k = (x @ p["wk"]).reshape(B, cfg.n_kv, cfg.head_dim)
    v = (x @ p["wv"]).reshape(B, cfg.n_kv, cfg.head_dim)
    pos_ids = (pos - 1)[None]
    q = apply_rope(q[:, None], pos_ids, cfg.rope_theta)[:, 0]
    k = apply_rope(k[:, None], pos_ids, cfg.rope_theta)[:, 0]
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        cache_l["k"], k[:, None], pos - 1, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        cache_l["v"], v[:, None], pos - 1, axis=1)
    out = decode_attention(q, k_cache, v_cache, pos,
                           window=window if cfg.local_window > 0 else None)
    out = out.reshape(B, cfg.n_heads * cfg.head_dim) @ p["wo"]
    return out, {"k": k_cache, "v": v_cache}


def _decode_stack(params_stack, cache_stack, x, pos, cfg, moe_layer,
                  idx_offset):
    def body(x, inp):
        p, cache_l, idx = inp
        window = _layer_window(cfg, idx)
        h = rms_norm(x, p["ln1"])
        attn_out, new_cache = _decode_attn(p["attn"], h, cache_l, pos, cfg,
                                           window)
        x = x + attn_out
        h = rms_norm(x, p["ln2"])
        if moe_layer:
            x = x + moe_ffn(p["ffn"], h, cfg.moe)
        else:
            x = x + swiglu(h, p["ffn"]["w_gate"], p["ffn"]["w_up"],
                           p["ffn"]["w_down"])
        return x, new_cache

    n = jax.tree_util.tree_leaves(params_stack)[0].shape[0]
    x, new_caches = jax.lax.scan(
        body, x, (params_stack, cache_stack, idx_offset + jnp.arange(n)))
    return x, new_caches


def serve_step(params, cache, tokens: jax.Array, pos: jax.Array,
               cfg: TransformerConfig) -> Tuple[jax.Array, dict]:
    """One greedy decode step.

    tokens: (B,) int32 current tokens; pos: () int32 -- sequence position
    of the *new* token + 1 (i.e. cache entries [0, pos) are valid after
    this step).  Returns (next_tokens (B,), new cache).
    """
    x = constrain(jnp.take(params["embed"], tokens, axis=0), "batch", None)
    new_cache = {}
    if cfg.n_dense_layers:
        x, nc = _decode_stack(params["dense_layers"], cache["dense_layers"],
                              x, pos, cfg, False, 0)
        new_cache["dense_layers"] = nc
    x, nc = _decode_stack(params["layers"], cache["layers"], x, pos, cfg,
                          cfg.is_moe, cfg.n_dense_layers)
    new_cache["layers"] = nc
    x = rms_norm(x, params["final_norm"])
    logits = constrain(x @ params["out"], "batch", "model")
    next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return next_tokens, new_cache
