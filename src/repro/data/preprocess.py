"""Distributed preprocessing driver: raw shards -> packed ``.sig`` shards.

This is the paper's §3 production pipeline as a service: stream raw sparse
shards through the signature engine in chunks, write bit-packed ``.sig``
signature shards (k*b bits per example -- the Table-2/§6 wire accounting,
sentinel OPH included via (b+1)-bit codes), and account the three phases
(load / kernel / store) exactly as Figures 1-3 split them.  Multiple
workers own disjoint shard slices (the ChunkedLoader's straggler
machinery applies); the ``backend`` argument picks execution through the
``repro.kernels.SignatureEngine`` registry (compiled on TPU, interpret on
CPU hosts, jnp fallback on GPU until the triton lowering lands).
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Optional, Sequence

import jax
import numpy as np

from repro.core.hashing import Hash2U, Hash4U
from repro.core.oph import OPH
from repro.data.pipeline import ChunkedLoader
from repro.data.sigshard import read_sig_shard, write_sig_shard
from repro.kernels import SignatureEngine


@dataclasses.dataclass
class PreprocessStats:
    examples: int = 0
    load_s: float = 0.0
    kernel_s: float = 0.0
    store_s: float = 0.0
    bytes_in: int = 0
    bytes_out: int = 0

    def reduction(self) -> float:
        return self.bytes_in / max(self.bytes_out, 1)


def preprocess_shards(shard_paths: Sequence[str], out_dir: str, family, *,
                      b: int = 8, chunk_size: int = 10_000,
                      n_workers: int = 1, backend: Optional[str] = None,
                      loader_kwargs: Optional[dict] = None
                      ) -> PreprocessStats:
    """Run the full preprocessing pipeline; returns phase accounting.

    family: Hash2U / Hash4U (k-pass minwise hashing) or an ``OPH`` scheme
    over a 2U/4U base (single-pass one-permutation hashing, ~k x fewer
    hash evaluations).  The permutation path is deliberately not offered
    here -- the paper's Issue 3: no permutation matrices at scale.  All
    densification modes pack: rotation/optimal signatures pack as b-bit
    codes; sentinel signatures pack as (b+1)-bit codes with EMPTY stored
    as 2^b, so even the estimator-facing sentinel scheme ships the
    paper's per-example bit budget.
    """
    if isinstance(family, OPH):
        if not isinstance(family.base, (Hash2U, Hash4U)):
            raise TypeError("production OPH preprocessing uses 2U/4U bases")
    elif not isinstance(family, (Hash2U, Hash4U)):
        raise TypeError("production preprocessing uses 2U/4U/OPH families")
    engine = SignatureEngine(family, b=b, packed=True, backend=backend)
    os.makedirs(out_dir, exist_ok=True)
    stats = PreprocessStats()
    loader = ChunkedLoader(shard_paths, chunk_size=chunk_size,
                           n_workers=n_workers, **(loader_kwargs or {}))
    t_mark = time.perf_counter()
    for idx, chunk in enumerate(loader):
        t_loaded = time.perf_counter()
        stats.load_s += t_loaded - t_mark
        stats.examples += chunk.n
        stats.bytes_in += chunk.nbytes()

        packed = engine.packed_signatures(chunk)     # packed on device
        jax.block_until_ready(packed.data)
        t_kernel = time.perf_counter()
        stats.kernel_s += t_kernel - t_loaded

        out_path = os.path.join(out_dir, f"sig_{idx:05d}.sig")
        labels = (np.asarray(chunk.labels) if chunk.labels is not None
                  else np.zeros((chunk.n,), np.float32))
        write_sig_shard(out_path, np.asarray(packed.data), labels,
                        k=packed.k, b=packed.b, code_bits=packed.code_bits,
                        sentinel=packed.sentinel)
        stats.bytes_out += os.path.getsize(out_path)
        t_mark = time.perf_counter()
        stats.store_s += t_mark - t_kernel
    return stats


def read_signature_shard(path: str):
    """Load a ``.sig`` shard back: (packed uint32 (n, words), labels, k, b).

    Kept for compatibility with the old npz reader's 4-tuple, whose
    documented pairing is ``unpack_signatures(words, b, k)`` -- that is
    only correct for plain b-bit layouts, so this reader refuses
    sentinel/(b+1)-bit shards instead of silently returning words a
    legacy caller would misdecode.  Use
    ``repro.data.sigshard.read_sig_shard`` for full metadata and any
    layout.
    """
    words, labels, meta = read_sig_shard(path)
    if meta.sentinel or meta.code_bits != meta.b:
        raise ValueError(
            f"{path}: {meta.code_bits}-bit"
            f"{' sentinel' if meta.sentinel else ''} codes cannot be "
            "decoded through the legacy (words, labels, k, b) contract; "
            "use repro.data.sigshard.read_sig_shard")
    return words, labels, meta.k, meta.b
