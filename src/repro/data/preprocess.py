"""Distributed preprocessing driver: raw shards -> b-bit signature shards.

This is the paper's §3 production pipeline as a service: stream raw sparse
shards through the Pallas minhash kernel in chunks, write packed b-bit
signature shards, and account the three phases (load / kernel / store)
exactly as Figures 1-3 split them.  Multiple workers own disjoint shard
slices (the ChunkedLoader's straggler machinery applies); on a TPU host
the kernel phase runs on-device, here in interpret mode.
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import List, Optional, Sequence

import jax
import numpy as np

from repro.core.bbit import pack_signatures
from repro.core.hashing import Hash2U, Hash4U
from repro.core.oph import OPH
from repro.data.pipeline import ChunkedLoader
from repro.kernels import batch_signatures


@dataclasses.dataclass
class PreprocessStats:
    examples: int = 0
    load_s: float = 0.0
    kernel_s: float = 0.0
    store_s: float = 0.0
    bytes_in: int = 0
    bytes_out: int = 0

    def reduction(self) -> float:
        return self.bytes_in / max(self.bytes_out, 1)


def preprocess_shards(shard_paths: Sequence[str], out_dir: str, family, *,
                      b: int = 8, chunk_size: int = 10_000,
                      n_workers: int = 1,
                      loader_kwargs: Optional[dict] = None
                      ) -> PreprocessStats:
    """Run the full preprocessing pipeline; returns phase accounting.

    family: Hash2U / Hash4U (k-pass minwise hashing) or an ``OPH`` scheme
    over a 2U/4U base (single-pass one-permutation hashing, ~k x fewer
    hash evaluations).  The permutation path is deliberately not offered
    here -- the paper's Issue 3: no permutation matrices at scale.  OPH
    must use ``densify="rotation"``: sentinel-coded empty bins cannot be
    bit-packed without aliasing a genuine b-bit value.  (Under rotation,
    empty input *sets* fold to the all-ones b-bit code -- the same
    defined value the minhash path assigns them -- so packing is always
    well-defined.)
    """
    if isinstance(family, OPH):
        if not isinstance(family.base, (Hash2U, Hash4U)):
            raise TypeError("production OPH preprocessing uses 2U/4U bases")
        if family.densify != "rotation":
            raise ValueError(
                "preprocess_shards needs densify='rotation' (sentinel-coded "
                "signatures cannot be b-bit packed unambiguously)")
    elif not isinstance(family, (Hash2U, Hash4U)):
        raise TypeError("production preprocessing uses 2U/4U/OPH families")
    os.makedirs(out_dir, exist_ok=True)
    stats = PreprocessStats()
    loader = ChunkedLoader(shard_paths, chunk_size=chunk_size,
                           n_workers=n_workers, **(loader_kwargs or {}))
    t_mark = time.perf_counter()
    for idx, chunk in enumerate(loader):
        t_loaded = time.perf_counter()
        stats.load_s += t_loaded - t_mark
        stats.examples += chunk.n
        stats.bytes_in += chunk.nbytes()

        sig = batch_signatures(chunk, family, b=b)       # Pallas kernel
        packed = pack_signatures(sig, b)
        jax.block_until_ready(packed)
        t_kernel = time.perf_counter()
        stats.kernel_s += t_kernel - t_loaded

        out_path = os.path.join(out_dir, f"sig_{idx:05d}.npz")
        host = np.asarray(packed)
        np.savez(out_path, packed=host,
                 labels=np.asarray(chunk.labels)
                 if chunk.labels is not None else np.zeros((chunk.n,)),
                 k=np.int32(family.k), b=np.int32(b))
        stats.bytes_out += os.path.getsize(out_path)
        t_mark = time.perf_counter()
        stats.store_s += t_mark - t_kernel
    return stats


def read_signature_shard(path: str):
    """Load a signature shard back: (packed uint32 (n, words), labels,
    k, b)."""
    with np.load(path) as z:
        return z["packed"], z["labels"], int(z["k"]), int(z["b"])
