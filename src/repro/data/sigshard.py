"""Raw mmap-able ``.sig`` signature-shard format (header + payload).

Replaces the per-chunk ``.npz`` shards (and the ad-hoc encode/decode that
rode along) for packed b-bit signatures.  The paper's accounting (§6,
Table 2) is k*b bits per example; this format stores exactly that plus a
fixed 64-byte header and the float32 labels, with the payload 64-byte
aligned so it can be ``np.memmap``'d straight off disk -- no zip/npz
decode on the replay path.

Layout (little-endian):

    0   magic   b"RSIG"
    4   u32     version (1)
    8   u32     n            examples
    12  u32     k            values per example
    16  u32     b            b-bit width of genuine values
    20  u32     code_bits    b, or b+1 for sentinel schemes
    24  u32     words        uint32 words per example
    28  u32     flags        bit 0: sentinel (EMPTY coded as 2^b)
    32  ..64    reserved (zero)
    64  f32[n]  labels
    pad to 64-byte boundary
    u32[n * words]  row-major packed payload

Codes follow ``repro.core.bbit.pack_codes``: value j occupies bits
[j*code_bits, (j+1)*code_bits) of its row's bitstream.
"""

from __future__ import annotations

import dataclasses
import os
import struct

import numpy as np

MAGIC = b"RSIG"
VERSION = 1
HEADER_BYTES = 64
_ALIGN = 64
_FLAG_SENTINEL = 1


@dataclasses.dataclass(frozen=True)
class SigShardMeta:
    """Decoded ``.sig`` header."""

    n: int
    k: int
    b: int
    code_bits: int
    words: int
    sentinel: bool

    @property
    def payload_bytes(self) -> int:
        """Signature payload only -- the paper's wire accounting."""
        return 4 * self.n * self.words

    @property
    def payload_offset(self) -> int:
        labels_end = HEADER_BYTES + 4 * self.n
        return ((labels_end + _ALIGN - 1) // _ALIGN) * _ALIGN


def _write_payload(f, words: np.ndarray) -> None:
    """Payload write hook (monkeypatched by the mid-write-crash test)."""
    f.write(words.tobytes())


def write_sig_shard(path: str, words: np.ndarray, labels: np.ndarray, *,
                    k: int, b: int, code_bits: int,
                    sentinel: bool = False) -> SigShardMeta:
    """Write one packed shard; ``words`` is (n, words_per_row) uint32.

    The write is atomic: bytes land in a same-directory temp file that is
    ``os.replace``'d over ``path`` only once complete, so a concurrent
    reader (or a TTL sweep in a shared ``SignatureCache`` dir) can never
    observe a truncated shard -- a crash mid-write leaves no ``path`` at
    all, and the temp file is unlinked on failure.
    """
    words = np.ascontiguousarray(words, dtype=np.uint32)
    labels = np.ascontiguousarray(labels, dtype=np.float32)
    n, wpr = words.shape
    if labels.shape != (n,):
        raise ValueError(f"labels shape {labels.shape} != ({n},)")
    meta = SigShardMeta(n=n, k=k, b=b, code_bits=code_bits, words=wpr,
                        sentinel=sentinel)
    header = MAGIC + struct.pack(
        "<7I", VERSION, n, k, b, code_bits, wpr,
        _FLAG_SENTINEL if sentinel else 0)
    header = header.ljust(HEADER_BYTES, b"\0")
    tmp = f"{path}.tmp.{os.getpid()}"
    try:
        with open(tmp, "wb") as f:
            f.write(header)
            f.write(labels.tobytes())
            f.write(b"\0" * (meta.payload_offset - HEADER_BYTES - 4 * n))
            _write_payload(f, words)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.remove(tmp)
        except OSError:
            pass
        raise
    return meta


def read_sig_meta(path: str) -> SigShardMeta:
    with open(path, "rb") as f:
        head = f.read(HEADER_BYTES)
    if len(head) < HEADER_BYTES or head[:4] != MAGIC:
        raise ValueError(f"{path}: not a .sig shard (bad magic)")
    version, n, k, b, code_bits, words, flags = struct.unpack(
        "<7I", head[4:32])
    if version != VERSION:
        raise ValueError(f"{path}: unsupported .sig version {version} "
                         f"(this build reads version {VERSION})")
    return SigShardMeta(n=n, k=k, b=b, code_bits=code_bits, words=words,
                        sentinel=bool(flags & _FLAG_SENTINEL))


def read_sig_shard(path: str, *, mmap: bool = False):
    """Read a shard back: ``(words, labels, meta)``.

    ``mmap=True`` maps the payload straight off disk (zero-copy until the
    device transfer); the plain path reads with ``np.fromfile``.
    """
    meta = read_sig_meta(path)
    if mmap:
        labels = np.array(np.memmap(path, np.float32, "r",
                                    offset=HEADER_BYTES, shape=(meta.n,)))
        words = np.memmap(path, np.uint32, "r", offset=meta.payload_offset,
                          shape=(meta.n, meta.words))
        return words, labels, meta
    with open(path, "rb") as f:
        f.seek(HEADER_BYTES)
        labels = np.fromfile(f, np.float32, meta.n)
        f.seek(meta.payload_offset)
        words = np.fromfile(f, np.uint32, meta.n * meta.words)
    if labels.size != meta.n or words.size != meta.n * meta.words:
        raise OSError(f"{path}: truncated .sig shard")
    return words.reshape(meta.n, meta.words), labels, meta
