"""Cross-process lock files for shared on-disk state.

One tiny primitive, ``FileLock``, used everywhere two processes (or two
threads of a serving stack) can touch the same directory:

  * ``repro.index`` -- ``append_index`` / ``ShardedIndex.append`` take
    the index lock so an appender never races another appender; readers
    never need the lock because every mutation lands via atomic
    ``tmp + os.replace`` (an open mmap keeps the old inode alive, so a
    concurrent reader sees either the pre- or the post-append file,
    never a torn one).
  * ``repro.train.online`` -- two trainers sharing one ``SignatureCache``
    directory serialize their populate passes on the cache lock, so the
    TTL sweep of one never interleaves with the shard writes of the
    other.

The lock is the classic ``O_CREAT | O_EXCL`` create-wins protocol: the
lock file's existence IS the lock, its content (pid + timestamp) is
diagnostics only.  ``stale_s`` lets a waiter break a lock whose mtime
has not moved for that long -- the crash-recovery story for a holder
that died without ``release`` (removal is best-effort and racy only
between *breakers*, who then re-contend on ``O_EXCL``).
"""

from __future__ import annotations

import os
import time


class LockTimeout(TimeoutError):
    """Raised when ``FileLock.acquire`` exceeds its ``timeout_s``."""


class FileLock:
    """An ``O_CREAT | O_EXCL`` lock file; reentrant within one instance.

    Use as a context manager::

        with FileLock(os.path.join(d, ".lock")):
            ...mutate d...

    ``timeout_s`` bounds the acquire wait (``LockTimeout`` on expiry);
    ``stale_s`` (optional) treats a lock file untouched for that many
    seconds as abandoned and breaks it.
    """

    def __init__(self, path: str, *, timeout_s: float = 30.0,
                 poll_s: float = 0.01, stale_s: float | None = None):
        self.path = path
        self.timeout_s = timeout_s
        self.poll_s = poll_s
        self.stale_s = stale_s
        self._depth = 0

    @property
    def held(self) -> bool:
        return self._depth > 0

    def _try_break_stale(self) -> None:
        if self.stale_s is None:
            return
        try:
            if time.time() - os.path.getmtime(self.path) > self.stale_s:
                os.remove(self.path)      # racy only vs other breakers;
        except OSError:                   # everyone re-contends on O_EXCL
            pass

    def acquire(self) -> "FileLock":
        if self._depth:                   # reentrant within this instance
            self._depth += 1
            return self
        deadline = time.monotonic() + self.timeout_s
        while True:
            try:
                fd = os.open(self.path,
                             os.O_CREAT | os.O_EXCL | os.O_WRONLY)
            except FileExistsError:
                self._try_break_stale()
                if time.monotonic() >= deadline:
                    raise LockTimeout(
                        f"could not acquire {self.path} within "
                        f"{self.timeout_s}s (holder: "
                        f"{self._holder_info()!r})")
                time.sleep(self.poll_s)
                continue
            with os.fdopen(fd, "w") as f:
                f.write(f"{os.getpid()} {time.time():.3f}\n")
            self._depth = 1
            return self

    def _holder_info(self) -> str:
        try:
            with open(self.path) as f:
                return f.read().strip()
        except OSError:
            return "?"

    def release(self) -> None:
        if not self._depth:
            raise RuntimeError(f"release of unheld lock {self.path}")
        self._depth -= 1
        if self._depth == 0:
            try:
                os.remove(self.path)
            except OSError:
                pass

    def __enter__(self) -> "FileLock":
        return self.acquire()

    def __exit__(self, *exc) -> None:
        self.release()
