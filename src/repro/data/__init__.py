from repro.data.sparse import SparseBatch, from_lists, to_dense, slice_batch
from repro.data.synthetic import (DatasetSpec, RCV1_LIKE, TABLE5_PAIRS, TINY,
                                  WEBSPAM_LIKE, generate, word_pair_sets)
from repro.data.pipeline import (ChunkedLoader, LoaderStats, SignatureStream,
                                 batch_to_shards, make_sharded_dataset,
                                 write_shards)

__all__ = [
    "SparseBatch", "from_lists", "to_dense", "slice_batch", "DatasetSpec",
    "RCV1_LIKE", "TABLE5_PAIRS", "TINY", "WEBSPAM_LIKE", "generate",
    "word_pair_sets", "ChunkedLoader", "LoaderStats", "SignatureStream",
    "batch_to_shards", "make_sharded_dataset", "write_shards",
]
