"""Chunked streaming data pipeline (the paper's batch-of-10K-sets loop).

Responsibilities:
  * on-disk shard format(s): LibSVM-style text and binary .npz -- the paper
    notes binary loading is ~5x faster than text (§3.7 Table 2 caption, §6.1);
    both are implemented so benchmarks can reproduce that ratio,
  * chunked iteration: yield SparseBatch chunks of ``chunk_size`` sets,
  * double-buffered background prefetch (overlap load with compute),
  * worker shard assignment + straggler mitigation: a shard read that
    exceeds its deadline is retried and, on repeated failure, reassigned to
    the next healthy worker (bookkeeping mirrors what a real multi-host
    data service does; on one host the "workers" are reader threads),
  * load-time accounting consumed by the online-learning benchmarks.

The prefetch (``prefetch_iter``) and retry (``read_with_retries``)
machinery is shared with the signature-cache replay path in
``repro.train.online``, so hashed-shard epochs get the same straggler
story as raw-shard epochs.
"""

from __future__ import annotations

import dataclasses
import os
import queue
import random
import tempfile
import threading
import time
from typing import Iterator, List, Optional, Sequence

import numpy as np

from repro.data.sparse import SparseBatch, from_lists


# ---------------------------------------------------------------------------
# Shard I/O
# ---------------------------------------------------------------------------

def write_shard_libsvm(path: str, sets: Sequence[np.ndarray], labels: np.ndarray) -> None:
    """LibSVM text: ``<label> <idx>:1 <idx>:1 ...`` (binary features)."""
    with open(path, "w") as f:
        for s, y in zip(sets, labels):
            feats = " ".join(f"{int(t)}:1" for t in s)
            f.write(f"{int(y)} {feats}\n")


def read_shard_libsvm(path: str):
    sets, labels = [], []
    with open(path) as f:
        for line in f:
            parts = line.split()
            labels.append(float(parts[0]))
            sets.append(np.array([int(p.split(":")[0]) for p in parts[1:]],
                                 np.int64))
    return sets, np.asarray(labels, np.float32)


def write_shard_binary(path: str, sets: Sequence[np.ndarray], labels: np.ndarray) -> None:
    """Binary .npz: concatenated indices + row offsets (true CSR)."""
    lens = np.array([len(s) for s in sets], np.int64)
    offsets = np.concatenate([[0], np.cumsum(lens)])
    flat = (np.concatenate(sets) if len(sets) else np.zeros((0,), np.int64))
    np.savez(path, indices=flat.astype(np.int64), offsets=offsets,
             labels=np.asarray(labels, np.float32))


def read_shard_binary(path: str):
    with np.load(path) as z:
        flat, offsets, labels = z["indices"], z["offsets"], z["labels"]
    sets = [flat[offsets[i]:offsets[i + 1]] for i in range(len(labels))]
    return sets, labels


def write_shards(batch_sets: Sequence[np.ndarray], labels: np.ndarray,
                 out_dir: str, n_shards: int, fmt: str = "binary") -> List[str]:
    os.makedirs(out_dir, exist_ok=True)
    paths = []
    per = (len(batch_sets) + n_shards - 1) // n_shards
    for i in range(n_shards):
        lo, hi = i * per, min((i + 1) * per, len(batch_sets))
        suffix = "npz" if fmt == "binary" else "txt"
        path = os.path.join(out_dir, f"shard_{i:05d}.{suffix}")
        writer = write_shard_binary if fmt == "binary" else write_shard_libsvm
        writer(path, batch_sets[lo:hi], labels[lo:hi])
        paths.append(path)
    return paths


# ---------------------------------------------------------------------------
# Streaming loader with prefetch + straggler handling
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LoaderStats:
    load_seconds: float = 0.0
    chunks: int = 0
    bytes_read: int = 0
    straggler_retries: int = 0
    shard_reassignments: int = 0
    io_errors: int = 0


# LoaderStats field -> (metric name, help); every field is monotone, so
# they all export as counters through ``loader_collector``
_LOADER_METRICS = {
    "load_seconds": ("data_loader_seconds_total",
                     "wall clock spent reading shards"),
    "chunks": ("data_loader_chunks_total", "chunks yielded"),
    "bytes_read": ("data_loader_bytes_read_total", "shard bytes read"),
    "straggler_retries": ("data_loader_straggler_retries_total",
                          "reads retried for exceeding the deadline"),
    "shard_reassignments": ("data_loader_shard_reassignments_total",
                            "slow reads kept after exhausted retries"),
    "io_errors": ("data_loader_io_errors_total",
                  "OSErrors absorbed by the retry loop"),
}


def loader_collector(role: str):
    """Registry collector factory over one ``LoaderStats`` holder.

    ``role`` labels which pipeline the stats belong to (``"load"`` = raw
    shard reads, ``"replay"`` = cached signature-shard replay); several
    live loaders with the same role sum into one process total.  Used as
    ``get_registry().register_object(stats, loader_collector("load"))``.
    """
    from repro.obs.metrics import Sample
    labels = (("role", role),)

    def collect(stats: LoaderStats):
        for field, (name, help) in _LOADER_METRICS.items():
            yield Sample(name, "counter", help, labels,
                         float(getattr(stats, field)))
    return collect


# process-wide jitter source for I/O retry backoff (callers needing
# determinism inject their own seeded ``random.Random``)
_default_backoff_rng = random.Random()


def read_with_retries(reader, path: str, stats: LoaderStats, *,
                      deadline: float, max_retries: int,
                      backoff_base_s: float = 0.05,
                      backoff_cap_s: float = 1.0,
                      rng=None, sleep=time.sleep):
    """Straggler/IO-aware shard read, shared by ``ChunkedLoader`` and the
    signature-cache replay path (``repro.train.online.SignatureCache``).

    Every attempt is accounted: an ``OSError`` bumps ``stats.io_errors``
    and is retried after an exponential backoff with jitter -- attempt
    ``i`` sleeps ``min(backoff_cap_s, backoff_base_s * 2**i)`` scaled by
    a uniform [0.5, 1.0) jitter factor, so a flapping filesystem is not
    hammered in a tight loop and concurrent readers decorrelate.  A read
    slower than ``deadline`` bumps ``stats.straggler_retries`` and
    retries *immediately* (slow is not broken; the last slow attempt is
    kept and counted as a ``shard_reassignment``).  If all
    ``max_retries + 1`` attempts raise, the last ``OSError`` propagates
    after the final attempt with no trailing sleep -- there is no silent
    unaccounted re-read.  ``rng`` (a ``random.Random``) and ``sleep``
    are injectable so tests can pin the exact sleep schedule with a
    fake clock.
    """
    if rng is None:
        rng = _default_backoff_rng
    last_err: Optional[OSError] = None
    for attempt in range(max_retries + 1):
        t0 = time.perf_counter()
        try:
            out = reader(path)
        except OSError as e:
            stats.io_errors += 1
            last_err = e
            if attempt < max_retries:
                delay = min(backoff_cap_s, backoff_base_s * (2.0 ** attempt))
                sleep(delay * (0.5 + 0.5 * rng.random()))
            continue
        dt = time.perf_counter() - t0
        if dt > deadline:
            if attempt < max_retries:
                # too slow: count as straggler, retry (a real service
                # would hedge the read against a replica)
                stats.straggler_retries += 1
                continue
            # retries exhausted: shard is handed to the next worker
            stats.shard_reassignments += 1
        stats.load_seconds += dt
        stats.bytes_read += os.path.getsize(path)
        return out
    assert last_err is not None
    raise last_err


def prefetch_iter(make_iter, prefetch: int):
    """Double-buffered background prefetch over any chunk iterator.

    Runs ``make_iter()`` in a daemon thread, keeping up to ``prefetch``
    items ahead of the consumer (overlap load with compute).  Exceptions
    in the producer propagate to the consumer; abandoning the consumer
    mid-iteration (generator close) stops the producer thread instead of
    leaving it blocked on a full queue.  ``prefetch <= 0`` iterates
    inline.
    """
    if prefetch <= 0:
        yield from make_iter()
        return
    q: "queue.Queue" = queue.Queue(maxsize=prefetch)
    sentinel = object()
    stop = threading.Event()
    err: List[BaseException] = []

    def put(item) -> bool:
        while not stop.is_set():
            try:
                q.put(item, timeout=0.1)
                return True
            except queue.Full:
                continue
        return False

    def producer():
        try:
            for item in make_iter():
                if not put(item):
                    return
        except BaseException as e:   # propagate into consumer
            err.append(e)
        finally:
            put(sentinel)

    t = threading.Thread(target=producer, daemon=True,
                         name="prefetch-producer")
    t.start()
    try:
        while True:
            item = q.get()
            if item is sentinel:
                break
            yield item
        t.join()
        if err:
            raise err[0]
    finally:
        # also runs on generator close (abandoned consumer): joining here
        # guarantees the producer no longer touches shared loader stats
        stop.set()
        t.join()


def device_put_iter(make_host_iter, prefetch: int = 2):
    """Double-buffered host->device upload pipeline.

    Wraps ``prefetch_iter`` with a ``jax.device_put`` applied in the
    producer thread, so the H2D copy of item i+1 overlaps the consumer's
    compute on item i (jax transfers are asynchronous; the producer only
    *enqueues* them).  Items are arbitrary pytrees of numpy arrays /
    scalars -- the out-of-core index scan streams
    ``(window_offset, window_words)`` pairs through this.
    """
    import jax

    def produce():
        for item in make_host_iter():
            yield jax.tree_util.tree_map(jax.device_put, item)

    yield from prefetch_iter(produce, prefetch)


class ChunkedLoader:
    """Iterate SparseBatch chunks over a list of shard files.

    ``n_workers`` reader threads each own a disjoint round-robin slice of
    shards.  A read exceeding ``straggler_deadline_s`` is retried
    (``max_retries``); persistent failure reassigns the shard to the next
    worker -- the multi-host straggler story, modeled faithfully enough to
    test the control logic.
    """

    def __init__(self, shard_paths: Sequence[str], chunk_size: int = 10_000,
                 fmt: str = "binary", max_nnz: Optional[int] = None,
                 prefetch: int = 2, n_workers: int = 1,
                 straggler_deadline_s: float = 30.0, max_retries: int = 2,
                 io_backoff_base_s: float = 0.05,
                 io_backoff_cap_s: float = 1.0,
                 lane_multiple: int = 128):
        self.shard_paths = list(shard_paths)
        self.chunk_size = chunk_size
        self.fmt = fmt
        self.max_nnz = max_nnz
        self.prefetch = prefetch
        self.n_workers = n_workers
        self.deadline = straggler_deadline_s
        self.max_retries = max_retries
        self.io_backoff_base_s = io_backoff_base_s
        self.io_backoff_cap_s = io_backoff_cap_s
        self.lane_multiple = lane_multiple
        self.stats = LoaderStats()
        from repro.obs.metrics import get_registry
        get_registry().register_object(self.stats, loader_collector("load"))
        # examples per shard index, recorded as shards are read; lets a
        # consumer resume mid-stream (``resume_point`` + ``iter_from``)
        self.shard_examples: dict = {}
        self._reader = read_shard_binary if fmt == "binary" else read_shard_libsvm

    # -- straggler-aware shard read ------------------------------------
    def _read_shard(self, path: str, worker: int):
        return read_with_retries(self._reader, path, self.stats,
                                 deadline=self.deadline,
                                 max_retries=self.max_retries,
                                 backoff_base_s=self.io_backoff_base_s,
                                 backoff_cap_s=self.io_backoff_cap_s)

    def _chunk_iter(self, start_shard: int = 0,
                    skip_examples: int = 0) -> Iterator[SparseBatch]:
        pending_sets: List[np.ndarray] = []
        pending_labels: List[float] = []
        # consume via a moving cursor instead of re-slicing the remainder
        # per chunk (pending = pending[chunk:] re-copied O(n) per yielded
        # chunk -- O(n^2) for many small chunks per shard); the buffers
        # compact once per shard, so each element moves at most twice
        start = 0
        skip = skip_examples
        for i in range(start_shard, len(self.shard_paths)):
            worker = i % self.n_workers
            sets, labels = self._read_shard(self.shard_paths[i], worker)
            self.shard_examples[i] = len(sets)
            if skip:
                take = min(skip, len(sets))
                sets, labels = sets[take:], labels[take:]
                skip -= take
            pending_sets.extend(sets)
            pending_labels.extend(labels.tolist())
            while len(pending_sets) - start >= self.chunk_size:
                stop = start + self.chunk_size
                yield self._make_batch(pending_sets[start:stop],
                                       pending_labels[start:stop])
                start = stop
            if start:
                del pending_sets[:start], pending_labels[:start]
                start = 0
        if pending_sets:
            yield self._make_batch(pending_sets, pending_labels)

    def _make_batch(self, sets, labels) -> SparseBatch:
        self.stats.chunks += 1
        return from_lists(sets, np.asarray(labels, np.float32),
                          max_nnz=self.max_nnz, lane_multiple=self.lane_multiple)

    def resume_point(self, example_offset: int):
        """Map a stream example offset -> (shard index, in-shard skip).

        Needs per-shard example counts, i.e. a completed prior pass
        (``shard_examples``).  This is how the signature cache starts a
        budget-truncated replay at the first *uncached* chunk instead of
        re-reading the cached prefix's raw shards.
        """
        cum = 0
        for i in range(len(self.shard_paths)):
            n_i = self.shard_examples.get(i)
            if n_i is None:
                raise ValueError(
                    f"resume_point({example_offset}) needs shard {i}'s "
                    "example count; complete a full pass first")
            if cum + n_i > example_offset:
                return i, example_offset - cum
            cum += n_i
        return len(self.shard_paths), 0

    def iter_from(self, start_shard: int = 0,
                  skip_examples: int = 0) -> Iterator[SparseBatch]:
        """Iterate chunks starting at ``start_shard``, dropping the first
        ``skip_examples`` examples (same prefetch machinery as iteration
        from the top).  Chunk boundaries line up with a full pass when
        (start_shard, skip_examples) came from ``resume_point`` of a
        chunk-aligned offset."""
        yield from prefetch_iter(
            lambda: self._chunk_iter(start_shard, skip_examples),
            self.prefetch)

    def __iter__(self) -> Iterator[SparseBatch]:
        yield from self.iter_from()


class SignatureStream:
    """Stream (signatures, labels) chunks: loader -> hash kernel -> b bits.

    The online-learning front half of the §3 pipeline with pluggable
    hashing scheme: ``family`` is a Hash2U/Hash4U (k-pass minwise
    hashing) or a ``repro.core.oph.OPH`` scheme (single-pass
    one-permutation hashing), executed through the
    ``repro.kernels.SignatureEngine`` (``backend`` selects interpret /
    compiled TPU / gpu-fallback execution).  With ``packed=True`` chunks
    are ``PackedSignatures`` -- the k*b-bit wire format, packed inside
    the kernel jit, so only packed words cross the host boundary.
    ``stats`` aggregates load/kernel accounting like ``preprocess_shards``
    does for the batch path.
    """

    def __init__(self, shard_paths: Sequence[str], family, *, b: int = 8,
                 chunk_size: int = 10_000, use_pallas: bool = True,
                 backend: Optional[str] = None, packed: bool = False,
                 loader_kwargs: Optional[dict] = None):
        from repro.kernels import SignatureEngine
        self.loader = ChunkedLoader(shard_paths, chunk_size=chunk_size,
                                    **(loader_kwargs or {}))
        self.family = family
        self.b = b
        self.use_pallas = use_pallas
        self.packed = packed
        self.engine = SignatureEngine(
            family, b=b, packed=packed,
            backend="ref" if not use_pallas else backend)
        self.kernel_seconds = 0.0
        self.examples = 0

    @property
    def cumulative_stats(self) -> dict:
        """Monotone counters for per-epoch delta accounting (the protocol
        ``repro.train.online.OnlineTrainer`` reads from any chunk source)."""
        return {"kernel_s": self.kernel_seconds,
                "bytes_read": self.loader.stats.bytes_read,
                "source": "hash"}

    def hash_chunk(self, chunk: SparseBatch):
        """Hash one SparseBatch chunk (with kernel-time accounting)."""
        import jax
        t0 = time.perf_counter()
        sig = self.engine(chunk)
        jax.block_until_ready(sig.data if self.packed else sig)
        self.kernel_seconds += time.perf_counter() - t0
        self.examples += chunk.n
        return sig, chunk.labels

    def __iter__(self):
        for chunk in self.loader:
            yield self.hash_chunk(chunk)


def batch_to_shards(batch: SparseBatch, out_dir: str, n_shards: int = 4,
                    fmt: str = "binary") -> List[str]:
    """Write a SparseBatch back out as raw disk shards; returns paths."""
    idx = np.asarray(batch.indices)
    msk = np.asarray(batch.mask)
    sets = [idx[i][msk[i]].astype(np.int64) for i in range(batch.n)]
    return write_shards(sets, np.asarray(batch.labels), out_dir, n_shards, fmt)


def make_sharded_dataset(spec, tmpdir: Optional[str] = None, n_shards: int = 4,
                         fmt: str = "binary", n: Optional[int] = None) -> List[str]:
    """Generate a synthetic dataset and write it as shards; returns paths."""
    from repro.data.synthetic import generate
    train, _ = generate(spec, n=n)
    out_dir = tmpdir or tempfile.mkdtemp(prefix=f"repro_{spec.name}_")
    return batch_to_shards(train, out_dir, n_shards, fmt)
