"""Synthetic sparse binary datasets with webspam-/rcv1-like statistics.

The paper's datasets (webspam: n=350K, D=16.6M, ~3728 nnz; rcv1-expanded:
n=781K, D=1.01e9, ~12062 nnz) are not available offline, so generators
here produce classification data with matched (n, D, nnz) at configurable
scale, plus a *class-conditional resemblance structure* so that
resemblance-kernel methods (= b-bit minwise hashing + linear model) are
informative: each class owns a set of "topic" prototypes; an example
samples one prototype and perturbs it, so same-class examples have high
resemblance and cross-class examples low resemblance.

Also provides the Appendix-A word-pair sets (two sets with a prescribed
exact resemblance R) used for estimator-MSE experiments.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro.data.sparse import SparseBatch, from_lists


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    n: int
    D: int
    avg_nnz: int
    n_classes: int = 2
    n_prototypes: int = 8        # topics per class
    overlap: float = 0.7         # fraction of an example copied from its prototype
    seed: int = 0


WEBSPAM_LIKE = DatasetSpec("webspam_like", n=4096, D=2**24, avg_nnz=512,
                           n_prototypes=6, overlap=0.7, seed=7)
RCV1_LIKE = DatasetSpec("rcv1_like", n=4096, D=2**30, avg_nnz=1024,
                        n_prototypes=8, overlap=0.65, seed=11)
TINY = DatasetSpec("tiny", n=256, D=2**16, avg_nnz=64, n_prototypes=3, seed=3)


def generate(spec: DatasetSpec, n: int | None = None) -> Tuple[SparseBatch, SparseBatch]:
    """Generate (train, test) SparseBatches with labels in {-1, +1}."""
    n = n or spec.n
    rng = np.random.default_rng(spec.seed)
    protos = []
    for c in range(spec.n_classes):
        for _ in range(spec.n_prototypes):
            size = max(8, int(spec.avg_nnz))
            protos.append((c, rng.choice(spec.D, size=size, replace=False)))

    def make(n_rows, seed_off):
        r = np.random.default_rng(spec.seed + seed_off)
        sets, labels = [], []
        for i in range(n_rows):
            c, proto = protos[r.integers(len(protos))]
            keep = r.random(len(proto)) < spec.overlap
            kept = proto[keep]
            n_new = max(1, int(len(proto) * (1.0 - spec.overlap)))
            fresh = r.integers(0, spec.D, size=n_new)
            s = np.unique(np.concatenate([kept, fresh])).astype(np.int64)
            sets.append(s)
            labels.append(1.0 if c == 1 else -1.0)
        return from_lists(sets, np.asarray(labels, np.float32))

    n_train = int(n * 0.8)
    return make(n_train, 1), make(n - n_train, 2)


def word_pair_sets(D: int, f1: int, f2: int, R: float, seed: int = 0
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """Two sets over [0, D) with |S1|=f1, |S2|=f2 and resemblance ~= R.

    Solves |S1 ∩ S2| = a from R = a / (f1 + f2 - a) -> a = R(f1+f2)/(1+R).
    Mirrors the Appendix-A word-pair data (Table 5).
    """
    a = int(round(R * (f1 + f2) / (1.0 + R)))
    a = min(a, f1, f2)
    rng = np.random.default_rng(seed)
    universe = rng.choice(D, size=f1 + f2 - a, replace=False)
    shared = universe[:a]
    only1 = universe[a:f1]
    only2 = universe[f1:f1 + f2 - a]
    s1 = np.sort(np.concatenate([shared, only1]))
    s2 = np.sort(np.concatenate([shared, only2]))
    return s1.astype(np.int64), s2.astype(np.int64)


# Appendix-A Table 5 word pairs: (name, f1, f2, R)
TABLE5_PAIRS = [
    ("KONG-HONG", 948, 940, 0.925),
    ("RIGHTS-RESERVED", 12234, 11272, 0.877),
    ("OF-AND", 37339, 36289, 0.771),
    ("GAMBIA-KIRIBATI", 206, 186, 0.712),
    ("SAN-FRANCISCO", 3194, 1651, 0.476),
    ("CREDIT-CARD", 2999, 2697, 0.285),
    ("TIME-JOB", 37339, 36289, 0.128),
    ("LOW-PAY", 2936, 2828, 0.112),
    ("A-TEST", 39063, 2278, 0.052),
]
