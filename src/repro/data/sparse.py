"""Padded-CSR sparse batch layout.

Binary feature *sets* are stored as ``indices (n, max_nnz) int32`` plus a
validity ``mask (n, max_nnz) bool``.  This is the TPU-friendly ragged
layout: fixed shape, 128-lane alignable, maskable.  It is the on-device
analogue of the paper's "chunks of 10K sets" (each chunk is one
SparseBatch).
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SparseBatch:
    """A batch of binary sets in padded-CSR form."""

    indices: jax.Array          # (n, max_nnz) int32, ids in [0, D)
    mask: jax.Array             # (n, max_nnz) bool
    labels: Optional[jax.Array] = None   # (n,) float32 in {-1, +1} or None

    @property
    def n(self) -> int:
        return self.indices.shape[0]

    @property
    def max_nnz(self) -> int:
        return self.indices.shape[1]

    def nnz_per_row(self) -> jax.Array:
        return jnp.sum(self.mask.astype(jnp.int32), axis=1)

    def nbytes(self) -> int:
        b = self.indices.size * 4 + self.mask.size
        if self.labels is not None:
            b += self.labels.size * 4
        return b


def pad_to_multiple(x: np.ndarray, multiple: int, axis: int, value=0) -> np.ndarray:
    size = x.shape[axis]
    target = ((size + multiple - 1) // multiple) * multiple
    if target == size:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, target - size)
    return np.pad(x, pad, constant_values=value)


def from_lists(sets: Sequence[np.ndarray], labels: Optional[np.ndarray] = None,
               max_nnz: Optional[int] = None, lane_multiple: int = 128) -> SparseBatch:
    """Build a SparseBatch from a list of index arrays (CPU-side)."""
    n = len(sets)
    if max_nnz is None:
        max_nnz = max((len(s) for s in sets), default=1) or 1
    max_nnz = ((max_nnz + lane_multiple - 1) // lane_multiple) * lane_multiple
    idx = np.zeros((n, max_nnz), np.int32)
    msk = np.zeros((n, max_nnz), bool)
    for i, s in enumerate(sets):
        m = min(len(s), max_nnz)
        idx[i, :m] = np.asarray(s[:m], np.int32)
        msk[i, :m] = True
    lab = None if labels is None else jnp.asarray(labels, jnp.float32)
    return SparseBatch(indices=jnp.asarray(idx), mask=jnp.asarray(msk), labels=lab)


def to_dense(batch: SparseBatch, D: int) -> jax.Array:
    """Dense 0/1 matrix (n, D).  Tests/small-D only."""
    n, nnz = batch.indices.shape
    row = jnp.broadcast_to(jnp.arange(n)[:, None], (n, nnz))
    flat = row * D + batch.indices
    vals = batch.mask.astype(jnp.float32).reshape(-1)
    out = jnp.zeros((n * D,), jnp.float32).at[flat.reshape(-1)].add(vals, mode="drop")
    return jnp.minimum(out.reshape(n, D), 1.0)


def slice_batch(batch: SparseBatch, start: int, size: int) -> SparseBatch:
    return SparseBatch(
        indices=jax.lax.dynamic_slice_in_dim(batch.indices, start, size, 0),
        mask=jax.lax.dynamic_slice_in_dim(batch.mask, start, size, 0),
        labels=None if batch.labels is None
        else jax.lax.dynamic_slice_in_dim(batch.labels, start, size, 0),
    )
