"""Loopback-TCP shard transport: the real ``ShardClient`` seam.

``ShardService`` puts one shard's ``IndexSearcher`` behind a socket
server speaking length-prefixed binary frames; ``SocketShardClient``
is the matching ``ShardClient`` -- plug-compatible with
``LocalShardClient`` via ``ShardedIndex(client_factory=...)`` and
bit-identical to it (the wire carries the exact numpy buffers a local
dispatch would return).

Wire format (all integers little-endian):

    frame   := magic(4) | payload_len(u32) | payload
    payload := header_len(u32) | header(JSON, utf-8) | array bytes...

The JSON header carries ``kind`` plus scalar fields, and an ``arrays``
list of ``[name, dtype, shape]`` entries describing the raw buffers
concatenated after it (C order, in list order).  Requests are
``hello`` (returns the shard's doc count -- backs ``client.n``) and
``search`` (qwords / optional query_sizes / optional qkeys + topk +
mode, answered with a ``result`` frame holding the ``SearchResult``
buffers, or an ``error`` frame).  Anything malformed -- bad magic,
truncated frame, undecodable header, short buffers -- raises
``TransportError`` client-side (an ``OSError``, so retry policies
treat it like any other I/O fault) and is answered/ignored
server-side without killing the service.

Each ``dispatch`` uses its own connection: concurrent server workers
share ``ShardClient`` instances, and per-dispatch sockets make
timeouts, cancellation, and injected connection drops independent
per in-flight query.
"""

from __future__ import annotations

import json
import socket
import struct
import threading
from typing import Callable, Optional, Tuple

import numpy as np

from repro.index.query import SearchResult
from repro.index.router import ShardClient

__all__ = ["ShardService", "SocketShardClient", "TransportError",
           "loopback_client_factory"]

_MAGIC = b"bSHr"
_HDR = struct.Struct("<4sI")
_MAX_FRAME = 1 << 30


class TransportError(OSError):
    """A torn, truncated, or corrupt transport frame (retryable)."""


# -- framing ------------------------------------------------------------

def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise TransportError(
                f"connection closed mid-frame ({len(buf)}/{n} bytes)")
        buf += chunk
    return bytes(buf)


def _pack_msg(header: dict, arrays=()) -> bytes:
    """header dict + named numpy buffers -> one wire frame."""
    meta = []
    bufs = []
    for name, arr in arrays:
        arr = np.ascontiguousarray(arr)
        meta.append([name, arr.dtype.str, list(arr.shape)])
        bufs.append(arr.tobytes())
    header = dict(header, arrays=meta)
    hdr = json.dumps(header).encode("utf-8")
    payload = struct.pack("<I", len(hdr)) + hdr + b"".join(bufs)
    return _HDR.pack(_MAGIC, len(payload)) + payload


def _send_msg(sock: socket.socket, header: dict, arrays=()) -> None:
    sock.sendall(_pack_msg(header, arrays))


def _recv_msg(sock: socket.socket) -> Tuple[dict, dict]:
    """Read one frame -> (header, {name: ndarray}).  TransportError on
    bad magic / truncation / corrupt header / short buffers."""
    magic, n = _HDR.unpack(_recv_exact(sock, _HDR.size))
    if magic != _MAGIC:
        raise TransportError(f"bad frame magic {magic!r}")
    if n > _MAX_FRAME:
        raise TransportError(f"frame length {n} exceeds limit")
    payload = _recv_exact(sock, n)
    if len(payload) < 4:
        raise TransportError("frame too short for header length")
    (hlen,) = struct.unpack_from("<I", payload)
    if 4 + hlen > len(payload):
        raise TransportError("header length exceeds frame")
    try:
        header = json.loads(payload[4:4 + hlen].decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise TransportError(f"corrupt frame header: {e}") from e
    if not isinstance(header, dict):
        raise TransportError("frame header is not an object")
    arrays = {}
    off = 4 + hlen
    for entry in header.get("arrays", ()):
        try:
            name, dtype, shape = entry
            nbytes = int(np.dtype(dtype).itemsize * int(np.prod(shape)))
        except (TypeError, ValueError) as e:
            raise TransportError(f"corrupt array descriptor: {e}") from e
        if off + nbytes > len(payload):
            raise TransportError(
                f"array {name!r} truncated ({len(payload) - off}/{nbytes} "
                "bytes)")
        arrays[name] = np.frombuffer(
            payload, dtype, count=int(np.prod(shape)),
            offset=off).reshape(shape)
        off += nbytes
    return header, arrays


# -- server -------------------------------------------------------------

class ShardService:
    """One shard's searcher behind a loopback-TCP frame server.

    Per-connection handler threads; a malformed request gets an
    ``error`` frame (when the stream is still framed) or drops the
    connection, and the service keeps serving.  ``close()`` stops the
    accept loop and closes the listener.
    """

    def __init__(self, searcher, *, host: str = "127.0.0.1",
                 port: int = 0):
        self.searcher = searcher
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(64)
        self.address: Tuple[str, int] = self._sock.getsockname()
        self._closed = False
        self._thread = threading.Thread(
            target=self._accept_loop, daemon=True,
            name=f"shard-service-{self.address[1]}")
        self._thread.start()

    def _accept_loop(self) -> None:
        while not self._closed:
            try:
                conn, _ = self._sock.accept()
            except OSError:
                return                      # listener closed
            threading.Thread(target=self._serve_conn, args=(conn,),
                             daemon=True).start()

    def _serve_conn(self, conn: socket.socket) -> None:
        with conn:
            while True:
                try:
                    header, arrays = _recv_msg(conn)
                except TransportError as e:
                    # Malformed stream: best-effort error frame, then
                    # drop the connection (framing is unrecoverable).
                    if str(e).startswith("connection closed mid-frame (0/"):
                        return              # clean EOF between frames
                    try:
                        _send_msg(conn, {"kind": "error",
                                         "error": str(e)})
                    except OSError:
                        pass
                    return
                except OSError:
                    return
                try:
                    reply, bufs = self._handle(header, arrays)
                except Exception as e:      # searcher-side failure
                    reply, bufs = {"kind": "error",
                                   "error": f"{type(e).__name__}: {e}"}, ()
                try:
                    _send_msg(conn, reply, bufs)
                except OSError:
                    return

    def _handle(self, header: dict, arrays: dict):
        kind = header.get("kind")
        if kind == "hello":
            return {"kind": "hello_ok", "n": int(self.searcher.index.n)}, ()
        if kind != "search":
            raise ValueError(f"unknown request kind {kind!r}")
        if "qwords" not in arrays:
            raise ValueError("search request missing qwords")
        res = self.searcher.dispatch(
            arrays["qwords"], int(header["topk"]),
            mode=header.get("mode", "exact"),
            query_sizes=arrays.get("query_sizes"),
            _qkeys=arrays.get("qkeys"))()
        out = [("indices", np.asarray(res.indices)),
               ("scores", np.asarray(res.scores))]
        if res.n_candidates is not None:
            out.append(("n_candidates", np.asarray(res.n_candidates)))
        return {"kind": "result"}, out

    def close(self) -> None:
        self._closed = True
        try:
            self._sock.close()
        except OSError:
            pass


# -- client -------------------------------------------------------------

class SocketShardClient(ShardClient):
    """``ShardClient`` over a ``ShardService`` address.

    ``dispatch`` writes the request on a fresh connection immediately
    and returns a harvest closure that blocks on the reply -- the
    server computes while the caller fans out to other shards, same
    overlap the local client gets from ``IndexSearcher.dispatch``.
    ``timeout_s`` bounds every socket op (connect/send/recv); an
    expired timeout surfaces as ``socket.timeout`` (a ``TimeoutError``
    / ``OSError``), never a hang.
    """

    def __init__(self, address: Tuple[str, int], *,
                 timeout_s: Optional[float] = 30.0):
        self.address = (address[0], int(address[1]))
        self.timeout_s = timeout_s
        self._n: Optional[int] = None

    def _connect(self) -> socket.socket:
        sock = socket.create_connection(self.address,
                                        timeout=self.timeout_s)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        return sock

    def _roundtrip(self, header: dict, arrays=()) -> Tuple[dict, dict]:
        with self._connect() as sock:
            _send_msg(sock, header, arrays)
            reply, bufs = _recv_msg(sock)
        if reply.get("kind") == "error":
            raise RemoteShardError(reply.get("error", "unknown shard error"))
        return reply, bufs

    @property
    def n(self) -> int:
        if self._n is None:
            reply, _ = self._roundtrip({"kind": "hello"})
            if reply.get("kind") != "hello_ok":
                raise TransportError(
                    f"unexpected hello reply {reply.get('kind')!r}")
            self._n = int(reply["n"])
        return self._n

    def dispatch(self, qwords, topk: int, *, mode: str = "exact",
                 query_sizes=None,
                 qkeys=None) -> Callable[[], SearchResult]:
        arrays = [("qwords", np.asarray(qwords))]
        if query_sizes is not None:
            arrays.append(("query_sizes", np.asarray(query_sizes)))
        if qkeys is not None:
            arrays.append(("qkeys", np.asarray(qkeys)))
        sock = self._connect()
        try:
            _send_msg(sock, {"kind": "search", "topk": int(topk),
                             "mode": mode}, arrays)
        except BaseException:
            sock.close()
            raise

        def harvest() -> SearchResult:
            try:
                reply, bufs = _recv_msg(sock)
            finally:
                sock.close()
            if reply.get("kind") == "error":
                raise RemoteShardError(
                    reply.get("error", "unknown shard error"))
            if reply.get("kind") != "result":
                raise TransportError(
                    f"unexpected reply kind {reply.get('kind')!r}")
            if "indices" not in bufs or "scores" not in bufs:
                raise TransportError("result frame missing buffers")
            return SearchResult(bufs["indices"], bufs["scores"],
                                bufs.get("n_candidates"))
        return harvest


class RemoteShardError(RuntimeError):
    """The shard executed the request and failed (not a wire fault, so
    resilience policies do not retry it by default)."""


def loopback_client_factory(*, timeout_s: Optional[float] = 30.0):
    """A ``client_factory=`` that spins up one ``ShardService`` per
    shard searcher and returns ``SocketShardClient``s to them.

    The factory object keeps ``.services`` / ``.clients`` lists and a
    ``.close()`` that tears all services down (tests/benchmarks own
    the lifecycle; services are daemon threads either way).
    """
    def factory(searcher) -> SocketShardClient:
        svc = ShardService(searcher)
        client = SocketShardClient(svc.address, timeout_s=timeout_s)
        factory.services.append(svc)
        factory.clients.append(client)
        return client

    factory.services = []
    factory.clients = []
    factory.close = lambda: [svc.close() for svc in factory.services]
    return factory
