"""repro.index: sharded b-bit similarity search over packed signatures.

The retrieval workload (paper §1's crawling/dedup framing; Li-Owen-Zhang
arXiv:1208.1259 "One Permutation Hashing for Efficient Search and
Learning") served from the same packed ``.sig`` wire format the
preprocessing and learning stacks already produce:

  banding.py -- the LSH banding math: band-key packing (device-side,
                straight from packed words), the S-curve, and the
                ``choose_band_config`` tuner.
  builder.py -- ``build_index``: ``.sig`` shards -> one raw mmap-able
                ``.idx`` file (banded bucket tables + packed signature
                payload), with zero host-side unpacking; ``load_index``
                -> ``SigIndex`` (mmap'd tables + device-resident packed
                corpus matrix); ``build_sharded`` -> S contiguous-range
                shards + manifest; ``append_index`` -> incremental
                growth without a rebuild.
  query.py   -- ``IndexSearcher``: exact top-k as ONE fused traced
                computation (in-jit ``fori_loop`` over corpus blocks
                carrying the running top-k; out-of-core corpora stream
                mmap windows through a double-buffered H2D pipeline) and
                LSH candidate generation + kernel rerank, behind one
                API, with batched query admission.
  router.py  -- ``ShardedIndex``: fan a query batch across shard
                searchers (sequential async dispatch, or ONE
                ``shard_map`` computation over the mesh's "data"-axis
                devices with round-robin shard placement), merge
                per-shard top-k bit-identically to a single-index
                search; ``ShardClient`` RPC seam; ``load_sharded`` +
                incremental ``append`` with budgeted spill into new
                shards; ``on_shard_failure="partial"`` serves the
                surviving shards with exact ``coverage`` accounting.
  transport.py -- the real ``ShardClient`` wire: ``ShardService``
                (per-shard loopback-TCP frame server) +
                ``SocketShardClient``, bit-identical to the local
                client.
  resilience.py -- ``ResilientShardClient`` (per-dispatch deadlines,
                jittered retries, hedged dispatch, circuit breaker)
                and the seeded ``ChaosShardClient`` fault injector.

The scoring hot path is ``repro.kernels.hamming.packed_match`` -- a
Pallas kernel registered in the SignatureEngine backend registry
(scheme ``"hamming"``), so it inherits interpret/tpu/ref execution and
TuningTable block sizes.
"""

from repro.index.banding import (BandingConfig, band_keys_from_codes,
                                 band_keys_packed, choose_band_config,
                                 s_curve)
from repro.index.builder import (IndexMeta, SigIndex, append_index,
                                 build_band_tables, build_index,
                                 build_sharded, load_index,
                                 merge_band_tables, read_index_meta)
from repro.index.query import IndexSearcher, SearchResult, resemblance_scores
from repro.index.resilience import (ChaosSchedule, ChaosShardClient,
                                    CircuitOpenError, ResiliencePolicy,
                                    ResilientShardClient,
                                    ShardDispatchTimeout,
                                    resilient_client_factory)
from repro.index.router import (LocalShardClient, ShardClient, ShardedIndex,
                                load_sharded, merge_topk)
from repro.index.transport import (ShardService, SocketShardClient,
                                   TransportError, loopback_client_factory)

__all__ = [
    "BandingConfig", "ChaosSchedule", "ChaosShardClient", "CircuitOpenError",
    "IndexMeta", "IndexSearcher", "LocalShardClient", "ResiliencePolicy",
    "ResilientShardClient", "SearchResult", "ShardClient",
    "ShardDispatchTimeout", "ShardService", "ShardedIndex", "SigIndex",
    "SocketShardClient", "TransportError", "append_index",
    "band_keys_from_codes", "band_keys_packed", "build_band_tables",
    "build_index", "build_sharded", "choose_band_config", "load_index",
    "load_sharded", "loopback_client_factory", "merge_band_tables",
    "merge_topk", "read_index_meta", "resemblance_scores",
    "resilient_client_factory", "s_curve",
]
