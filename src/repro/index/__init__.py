"""repro.index: sharded b-bit similarity search over packed signatures.

The retrieval workload (paper §1's crawling/dedup framing; Li-Owen-Zhang
arXiv:1208.1259 "One Permutation Hashing for Efficient Search and
Learning") served from the same packed ``.sig`` wire format the
preprocessing and learning stacks already produce:

  banding.py -- the LSH banding math: band-key packing (device-side,
                straight from packed words), the S-curve, and the
                ``choose_band_config`` tuner.
  builder.py -- ``build_index``: ``.sig`` shards -> one raw mmap-able
                ``.idx`` file (banded bucket tables + packed signature
                payload), with zero host-side unpacking; ``load_index``
                -> ``SigIndex`` (mmap'd tables + device-resident packed
                corpus matrix).
  query.py   -- ``IndexSearcher``: exact top-k (packed-Hamming kernel
                brute force over corpus blocks + Theorem-1 rerank) and
                LSH candidate generation + kernel rerank, behind one
                API, with batched query admission.

The scoring hot path is ``repro.kernels.hamming.packed_match`` -- a
Pallas kernel registered in the SignatureEngine backend registry
(scheme ``"hamming"``), so it inherits interpret/tpu/ref execution and
TuningTable block sizes.
"""

from repro.index.banding import (BandingConfig, band_keys_from_codes,
                                 band_keys_packed, choose_band_config,
                                 s_curve)
from repro.index.builder import (IndexMeta, SigIndex, build_band_tables,
                                 build_index, load_index, read_index_meta)
from repro.index.query import IndexSearcher, SearchResult, resemblance_scores

__all__ = [
    "BandingConfig", "IndexMeta", "IndexSearcher", "SearchResult",
    "SigIndex", "band_keys_from_codes", "band_keys_packed",
    "build_band_tables", "build_index", "choose_band_config", "load_index",
    "read_index_meta", "resemblance_scores", "s_curve",
]
