"""Sharded-index router: fan a query batch across ``.idx`` shards and
merge per-shard top-k bit-identically to a single-index search.

``build_sharded`` (``repro.index.builder``) splits a corpus into S
contiguous-doc-range shards; this module serves them as one logical
index:

  * ``ShardedIndex``  -- per-shard ``IndexSearcher``s + the global doc-id
    offsets.  ``search`` fans the query batch out (every shard's fused
    exact scan / LSH rerank dispatches before any result is harvested --
    jax's async dispatch overlaps the shards on one device and is the
    seam for per-shard devices/hosts later), then ``merge_topk`` folds
    the per-shard results.
  * ``merge_topk``    -- stable merge of per-shard (scores, local ids):
    scores are computed by the same kernel path on every shard, shards
    are concatenated in ascending-global-id order, and ties break to the
    earliest position -- exactly ``lax.top_k``'s tie rule over the whole
    corpus, so the merged top-k (ids AND scores) is bit-identical to a
    single-index search over the same documents.
  * ``load_sharded``  -- read ``manifest.json`` + shards from a
    ``build_sharded`` output directory.

Incremental growth: ``ShardedIndex.append`` extends the LAST shard via
``repro.index.builder.append_index`` (later shards would shift global
ids), updates the manifest, and reloads only that shard -- a crawler can
grow the corpus without a full rebuild.
"""

from __future__ import annotations

import json
import os
from typing import Optional, Sequence, Union

import jax
import numpy as np

from repro.index.banding import band_keys_packed
from repro.index.builder import (MANIFEST_NAME, SigIndex, append_index,
                                 load_index, write_manifest)
from repro.index.query import (IndexSearcher, SearchResult, _BatchedAdmission,
                               _query_words)
from repro.kernels import PackedSignatures


def merge_topk(results: Sequence[SearchResult], offsets: Sequence[int],
               topk: int) -> SearchResult:
    """Fold per-shard top-k (local ids) into global top-k.

    Shard results arrive sorted by descending score with ascending local
    ids inside every tie run; concatenating them in shard order makes
    position order == ascending global id inside every tie run, so a
    *stable* sort by descending score reproduces ``lax.top_k``'s
    lowest-id tie-breaking over the concatenated corpus bit-exactly.
    """
    if not results:
        raise ValueError("merge_topk needs at least one shard result")
    cat_s = np.concatenate([r.scores for r in results], axis=1)
    cat_i = np.concatenate(
        [np.where(r.indices >= 0, r.indices + off, np.int64(-1))
         for r, off in zip(results, offsets)], axis=1)
    order = np.argsort(-cat_s, axis=1, kind="stable")[:, :topk]
    out_s = np.take_along_axis(cat_s, order, axis=1)
    out_i = np.take_along_axis(cat_i, order, axis=1)
    pad = topk - out_s.shape[1]
    if pad > 0:
        out_s = np.pad(out_s, ((0, 0), (0, pad)),
                       constant_values=-np.inf)
        out_i = np.pad(out_i, ((0, 0), (0, pad)), constant_values=-1)
    n_cand = None
    if all(r.n_candidates is not None for r in results):
        n_cand = np.sum([r.n_candidates for r in results], axis=0)
    return SearchResult(out_i, out_s.astype(np.float32), n_cand)


class ShardedIndex(_BatchedAdmission):
    """One logical index over S ``.idx`` shards with contiguous doc ranges.

    Mirrors the ``IndexSearcher`` serving API (``search`` plus the
    shared ``submit``/``flush`` batched admission) and returns *global*
    doc ids.  ``searcher_kwargs`` flow to every per-shard
    ``IndexSearcher`` (backend, corpus_block, max_device_bytes, ... --
    an out-of-core device window applies per shard).
    """

    def __init__(self, indexes: Sequence[SigIndex], *,
                 paths: Optional[Sequence[str]] = None,
                 manifest_dir: Optional[str] = None,
                 **searcher_kwargs):
        if not indexes:
            raise ValueError("ShardedIndex needs at least one shard")
        spec0 = indexes[0].spec
        for i, idx in enumerate(indexes[1:], 1):
            if idx.spec != spec0 or idx.banding != indexes[0].banding:
                raise ValueError(
                    f"shard {i} wire/banding {idx.spec}/{idx.banding} != "
                    f"shard 0 {spec0}/{indexes[0].banding}")
        self._searcher_kwargs = dict(searcher_kwargs)
        self.searchers = [IndexSearcher(idx, **searcher_kwargs)
                          for idx in indexes]
        self.paths = list(paths) if paths else None
        self.manifest_dir = manifest_dir
        self.offsets = np.cumsum([0] + [idx.n for idx in indexes])[:-1]
        self._admission_init()

    @property
    def n(self) -> int:
        return int(sum(s.index.n for s in self.searchers))

    @property
    def n_shards(self) -> int:
        return len(self.searchers)

    @property
    def spec(self):
        return self.searchers[0].index.spec

    def search(self, queries: Union[PackedSignatures, jax.Array, np.ndarray],
               topk: int = 10, *, mode: str = "exact",
               query_sizes: Optional[np.ndarray] = None) -> SearchResult:
        """Global top-k: fan out to every shard searcher, merge.

        Every shard's device work dispatches (``IndexSearcher.dispatch``)
        before any shard's result is harvested to host arrays, so shard
        i+1's candidate generation / scan launch overlaps shard i's
        device work; band keys for the LSH path are computed once for
        the batch and shared across shards.
        """
        qwords = _query_words(queries, self.spec)
        qkeys = None
        if mode == "lsh":
            idx0 = self.searchers[0].index
            qkeys = np.asarray(band_keys_packed(qwords, idx0.spec,
                                                idx0.banding))
        pending = [s.dispatch(qwords, topk, mode=mode,
                              query_sizes=query_sizes, _qkeys=qkeys)
                   for s in self.searchers]
        return merge_topk([p() for p in pending], self.offsets, topk)

    # -- incremental growth ----------------------------------------------
    def append(self, sig_paths: Sequence[str], *,
               set_sizes: Optional[np.ndarray] = None):
        """Append new documents to the LAST shard (``append_index``) and
        reload it; global ids of existing documents are unchanged.
        Requires shard paths (construct via ``load_sharded``)."""
        if not self.paths:
            raise ValueError("append needs shard paths; load this index "
                             "via load_sharded()")
        last = self.paths[-1]
        meta = append_index(last, sig_paths, set_sizes=set_sizes)
        self.searchers[-1] = IndexSearcher(load_index(last),
                                           **self._searcher_kwargs)
        if self.manifest_dir:
            write_manifest(self.manifest_dir, self.paths,
                           [s.index.n for s in self.searchers])
        return meta


def load_sharded(shard_dir: str, *, mmap: bool = True,
                 **searcher_kwargs) -> ShardedIndex:
    """Load a ``build_sharded`` output directory into a ``ShardedIndex``.

    ``searcher_kwargs`` flow to every per-shard ``IndexSearcher``
    (``backend=``, ``corpus_block=``, ``max_device_bytes=``, ...).
    """
    man_path = os.path.join(shard_dir, MANIFEST_NAME)
    with open(man_path) as f:
        manifest = json.load(f)
    if manifest.get("version") != 1:
        raise ValueError(f"{man_path}: unsupported manifest version "
                         f"{manifest.get('version')}")
    paths = [os.path.join(shard_dir, name) for name in manifest["shards"]]
    indexes = [load_index(p, mmap=mmap) for p in paths]
    sharded = ShardedIndex(indexes, paths=paths, manifest_dir=shard_dir,
                           **searcher_kwargs)
    if sharded.n != manifest["n"]:
        raise ValueError(f"{man_path}: manifest n={manifest['n']} != "
                         f"loaded {sharded.n}")
    return sharded
