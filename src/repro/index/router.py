"""Sharded-index router: fan a query batch across ``.idx`` shards and
merge per-shard top-k bit-identically to a single-index search.

``build_sharded`` (``repro.index.builder``) splits a corpus into S
contiguous-doc-range shards; this module serves them as one logical
index:

  * ``ShardedIndex``  -- per-shard ``IndexSearcher``s + the global doc-id
    offsets.  ``search`` fans the query batch out (every shard's fused
    exact scan / LSH rerank dispatches before any result is harvested --
    jax's async dispatch overlaps the shards on one device and is the
    seam for per-shard devices/hosts later), then ``merge_topk`` folds
    the per-shard results.
  * ``merge_topk``    -- stable merge of per-shard (scores, local ids):
    scores are computed by the same kernel path on every shard, shards
    are concatenated in ascending-global-id order, and ties break to the
    earliest position -- exactly ``lax.top_k``'s tie rule over the whole
    corpus, so the merged top-k (ids AND scores) is bit-identical to a
    single-index search over the same documents.
  * ``load_sharded``  -- read ``manifest.json`` + shards from a
    ``build_sharded`` output directory.

Live growth under readers: ``ShardedIndex.append`` extends the LAST
shard via ``repro.index.builder.append_index`` (later shards would shift
global ids) under the directory's lock file (``sharded_lock``), rewrites
the manifest atomically with a bumped ``generation``, and swaps the
router's (searchers, offsets) state in one assignment -- a concurrently
running ``search``/``flush`` reads ONE consistent snapshot (taken once
at entry), so it returns results against either the pre- or the
post-append corpus, never a torn mix.  ``refresh`` is the reader side:
re-read the manifest (written atomically, so never torn) and reload only
the shards whose (name, doc count) changed -- how a serving process
picks up appends made by a crawler process
(``repro.launch.server.SearchServer`` calls it before every flush).
"""

from __future__ import annotations

import dataclasses
import os
import threading
from typing import Optional, Sequence, Tuple, Union

import jax
import numpy as np

from repro.index.banding import band_keys_packed
from repro.index.builder import (MANIFEST_NAME, SigIndex, append_index,
                                 load_index, read_manifest, sharded_lock,
                                 write_manifest)
from repro.index.query import (IndexSearcher, SearchResult, _BatchedAdmission,
                               _query_words)
from repro.kernels import PackedSignatures


def merge_topk(results: Sequence[SearchResult], offsets: Sequence[int],
               topk: int) -> SearchResult:
    """Fold per-shard top-k (local ids) into global top-k.

    Shard results arrive sorted by descending score with ascending local
    ids inside every tie run; concatenating them in shard order makes
    position order == ascending global id inside every tie run, so a
    *stable* sort by descending score reproduces ``lax.top_k``'s
    lowest-id tie-breaking over the concatenated corpus bit-exactly.
    """
    if not results:
        raise ValueError("merge_topk needs at least one shard result")
    cat_s = np.concatenate([r.scores for r in results], axis=1)
    cat_i = np.concatenate(
        [np.where(r.indices >= 0, r.indices + off, np.int64(-1))
         for r, off in zip(results, offsets)], axis=1)
    order = np.argsort(-cat_s, axis=1, kind="stable")[:, :topk]
    out_s = np.take_along_axis(cat_s, order, axis=1)
    out_i = np.take_along_axis(cat_i, order, axis=1)
    pad = topk - out_s.shape[1]
    if pad > 0:
        out_s = np.pad(out_s, ((0, 0), (0, pad)),
                       constant_values=-np.inf)
        out_i = np.pad(out_i, ((0, 0), (0, pad)), constant_values=-1)
    n_cand = None
    if all(r.n_candidates is not None for r in results):
        n_cand = np.sum([r.n_candidates for r in results], axis=0)
    return SearchResult(out_i, out_s.astype(np.float32), n_cand)


@dataclasses.dataclass(frozen=True)
class _RouterState:
    """One immutable, internally consistent view of the shard set.

    Mutations (``append``, ``refresh``) build a whole new state and swap
    it in with a single attribute assignment; every ``search`` snapshots
    ``self._state`` exactly once, so a racing mutation can never hand a
    query old offsets with new searchers (a torn view).
    """

    searchers: Tuple[IndexSearcher, ...]
    offsets: np.ndarray            # global doc-id offset per shard
    paths: Optional[Tuple[str, ...]]
    generation: int

    @property
    def n(self) -> int:
        return int(sum(s.index.n for s in self.searchers))


def _make_state(searchers: Sequence[IndexSearcher],
                paths: Optional[Sequence[str]],
                generation: int) -> _RouterState:
    offsets = np.cumsum([0] + [s.index.n for s in searchers])[:-1]
    return _RouterState(tuple(searchers), offsets,
                        tuple(paths) if paths else None, generation)


class ShardedIndex(_BatchedAdmission):
    """One logical index over S ``.idx`` shards with contiguous doc ranges.

    Mirrors the ``IndexSearcher`` serving API (``search`` plus the
    shared ``submit``/``flush`` batched admission) and returns *global*
    doc ids.  ``searcher_kwargs`` flow to every per-shard
    ``IndexSearcher`` (backend, corpus_block, max_device_bytes, ... --
    an out-of-core device window applies per shard).
    """

    def __init__(self, indexes: Sequence[SigIndex], *,
                 paths: Optional[Sequence[str]] = None,
                 manifest_dir: Optional[str] = None,
                 generation: int = 0,
                 **searcher_kwargs):
        if not indexes:
            raise ValueError("ShardedIndex needs at least one shard")
        spec0 = indexes[0].spec
        for i, idx in enumerate(indexes[1:], 1):
            if idx.spec != spec0 or idx.banding != indexes[0].banding:
                raise ValueError(
                    f"shard {i} wire/banding {idx.spec}/{idx.banding} != "
                    f"shard 0 {spec0}/{indexes[0].banding}")
        self._searcher_kwargs = dict(searcher_kwargs)
        self.manifest_dir = manifest_dir
        # Serializes state swaps so a refresh that read an older manifest
        # can never overwrite a concurrent append's newer state
        # (generations only move forward).
        self._swap_lock = threading.Lock()
        self._state = _make_state(
            [IndexSearcher(idx, **searcher_kwargs) for idx in indexes],
            paths, generation)
        self._admission_init()

    # -- snapshot accessors (each reads self._state exactly once) --------
    @property
    def searchers(self) -> Tuple[IndexSearcher, ...]:
        return self._state.searchers

    @property
    def offsets(self) -> np.ndarray:
        return self._state.offsets

    @property
    def paths(self) -> Optional[Tuple[str, ...]]:
        return self._state.paths

    @property
    def generation(self) -> int:
        """The manifest generation this router currently serves."""
        return self._state.generation

    @property
    def n(self) -> int:
        return self._state.n

    @property
    def n_shards(self) -> int:
        return len(self._state.searchers)

    @property
    def spec(self):
        return self._state.searchers[0].index.spec

    def search(self, queries: Union[PackedSignatures, jax.Array, np.ndarray],
               topk: int = 10, *, mode: str = "exact",
               query_sizes: Optional[np.ndarray] = None) -> SearchResult:
        """Global top-k: fan out to every shard searcher, merge.

        Every shard's device work dispatches (``IndexSearcher.dispatch``)
        before any shard's result is harvested to host arrays, so shard
        i+1's candidate generation / scan launch overlaps shard i's
        device work; band keys for the LSH path are computed once for
        the batch and shared across shards.  The shard set is snapshotted
        ONCE here, so a concurrent ``append``/``refresh`` never tears
        this call's view.
        """
        state = self._state
        qwords = _query_words(queries, state.searchers[0].index.spec)
        qkeys = None
        if mode == "lsh":
            idx0 = state.searchers[0].index
            qkeys = np.asarray(band_keys_packed(qwords, idx0.spec,
                                                idx0.banding))
        pending = [s.dispatch(qwords, topk, mode=mode,
                              query_sizes=query_sizes, _qkeys=qkeys)
                   for s in state.searchers]
        return merge_topk([p() for p in pending], state.offsets, topk)

    # -- live growth -----------------------------------------------------
    def append(self, sig_paths: Sequence[str], *,
               set_sizes: Optional[np.ndarray] = None):
        """Append new documents to the LAST shard (``append_index``),
        concurrently safe with readers.

        Holds the directory lock (so two appenders serialize), refreshes
        first (picking up appends other processes landed), rewrites the
        manifest atomically with a bumped generation, and swaps this
        router's state in one assignment.  Existing global ids are
        unchanged; a racing ``search`` sees the pre- or post-append
        corpus, never a mix.  Requires shard paths (construct via
        ``load_sharded``).
        """
        if not self.paths:
            raise ValueError("append needs shard paths; load this index "
                             "via load_sharded()")
        if not self.manifest_dir:
            raise ValueError("append needs a manifest dir; load this "
                             "index via load_sharded()")
        with sharded_lock(self.manifest_dir):
            self.refresh()
            state = self._state
            last = state.paths[-1]
            meta = append_index(last, sig_paths, set_sizes=set_sizes)
            grown = IndexSearcher(load_index(last), **self._searcher_kwargs)
            searchers = state.searchers[:-1] + (grown,)
            write_manifest(self.manifest_dir, state.paths,
                           [s.index.n for s in searchers],
                           generation=state.generation + 1)
            with self._swap_lock:
                self._state = _make_state(searchers, state.paths,
                                          state.generation + 1)
        return meta

    def refresh(self, *, max_attempts: int = 5) -> bool:
        """Re-read the manifest; reload shards another process changed.

        Returns True when the served state moved.  Only shards whose
        (name, doc count) differ from the current snapshot are reloaded;
        unchanged shards keep their device-resident corpus.  If a writer
        replaces a shard file between the manifest read and the shard
        load (the loaded count disagrees with the manifest), the whole
        read retries -- the swapped-in state is always internally
        consistent.
        """
        if not self.manifest_dir:
            return False
        for _ in range(max_attempts):
            manifest = read_manifest(self.manifest_dir)
            state = self._state
            if manifest["generation"] == state.generation:
                return False
            names = manifest["shards"]
            counts = [int(b) - int(a) for a, b in
                      zip(manifest["offsets"],
                          list(manifest["offsets"][1:]) + [manifest["n"]])]
            paths = [os.path.join(self.manifest_dir, nm) for nm in names]
            old = {}
            if state.paths:
                old = {(p, s.index.n): s
                       for p, s in zip(state.paths, state.searchers)}
            searchers = []
            consistent = True
            for path, count in zip(paths, counts):
                keep = old.get((path, count))
                if keep is not None:
                    searchers.append(keep)
                    continue
                loaded = IndexSearcher(load_index(path),
                                       **self._searcher_kwargs)
                if loaded.index.n != count:
                    consistent = False     # raced a writer; re-read
                    break
                searchers.append(loaded)
            if consistent:
                with self._swap_lock:
                    if manifest["generation"] <= self._state.generation:
                        return False   # a concurrent append moved further
                    self._state = _make_state(searchers, paths,
                                              manifest["generation"])
                return True
        raise RuntimeError(
            f"refresh({self.manifest_dir}) kept racing a writer: shard "
            f"doc counts never matched the manifest after "
            f"{max_attempts} attempts")


def load_sharded(shard_dir: str, *, mmap: bool = True,
                 **searcher_kwargs) -> ShardedIndex:
    """Load a ``build_sharded`` output directory into a ``ShardedIndex``.

    ``searcher_kwargs`` flow to every per-shard ``IndexSearcher``
    (``backend=``, ``corpus_block=``, ``max_device_bytes=``, ...).
    """
    manifest = read_manifest(shard_dir)
    man_path = os.path.join(shard_dir, MANIFEST_NAME)
    paths = [os.path.join(shard_dir, name) for name in manifest["shards"]]
    indexes = [load_index(p, mmap=mmap) for p in paths]
    sharded = ShardedIndex(indexes, paths=paths, manifest_dir=shard_dir,
                           generation=manifest["generation"],
                           **searcher_kwargs)
    if sharded.n != manifest["n"]:
        raise ValueError(f"{man_path}: manifest n={manifest['n']} != "
                         f"loaded {sharded.n}")
    return sharded
