"""Sharded-index router: fan a query batch across ``.idx`` shards and
merge per-shard top-k bit-identically to a single-index search.

``build_sharded`` (``repro.index.builder``) splits a corpus into S
contiguous-doc-range shards; this module serves them as one logical
index:

  * ``ShardedIndex``  -- per-shard ``IndexSearcher``s + the global doc-id
    offsets, reached through a transport-agnostic ``ShardClient`` seam.
    ``search`` fans the query batch out, then ``merge_topk`` folds the
    per-shard results.  Two fan-out dispatchers:

      - ``sequential``: every shard's fused exact scan / LSH rerank
        dispatches before any result is harvested -- jax's async
        dispatch overlaps the shards, on one device or (with a mesh)
        on each shard's placed device.
      - ``mesh``: the exact scan AND the LSH rerank each run as ONE
        ``shard_map``-dispatched computation per flush.  Shards are
        placed round-robin on the devices of the mesh's ``"data"`` axis
        (``repro.sharding.rules.place_shards``); the exact path scans
        each device's stacked shards with a per-device running top-k
        carried in-jit, the LSH path gathers each device's padded/
        masked candidate rows (host bucket probe per shard, band keys
        computed once per batch) and reranks them in one collective
        kernel launch; either way the per-device ``(best_s, best_i)``
        are gathered across the mesh and folded through the same
        ``merge_topk`` rule -- adding devices divides the scan, instead
        of adding per-shard latency.

  * ``merge_topk``    -- lexicographic (descending score, ascending
    global id) fold of per-shard (scores, local ids): exactly
    ``lax.top_k``'s tie rule over the whole corpus, so the merged top-k
    (ids AND scores) is bit-identical to a single-index search over the
    same documents, regardless of how the corpus was partitioned or in
    what order partial results arrive.
  * ``load_sharded``  -- read ``manifest.json`` + shards from a
    ``build_sharded`` output directory.

Live growth under readers: ``ShardedIndex.append`` extends the LAST
shard via ``repro.index.builder.append_index`` under the directory's
lock file (``sharded_lock``), rewrites the manifest atomically with a
bumped ``generation``, and swaps the router's state in one assignment --
a concurrently running ``search``/``flush`` reads ONE consistent
snapshot (taken once at entry), so it returns results against either the
pre- or the post-append corpus, never a torn mix.  With a
``max_shard_docs`` budget, an append that would push the last shard past
the budget *spills* into NEW tail shards instead (published atomically:
temp write + ``os.replace``, manifest last, so a crash mid-spill leaves
readers on the old generation with no torn shard visible).  ``refresh``
is the reader side: re-read the manifest (written atomically, so never
torn) and reload only the shards whose (name, doc count) changed --
spilled shards pick up their round-robin device placement here, and
unchanged shards keep their device-resident corpus
(``repro.launch.server.SearchServer`` calls it before every flush).
"""

from __future__ import annotations

import dataclasses
import os
import threading
from typing import Callable, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.data.sigshard import read_sig_meta
from repro.index.banding import band_keys_packed
from repro.index.builder import (MANIFEST_NAME, SigIndex, append_index,
                                 build_index, load_index, read_manifest,
                                 sharded_lock, write_manifest)
from repro.index.query import (IndexSearcher, SearchResult, _BatchedAdmission,
                               _query_words, exact_scan_ids, lsh_rerank_ids)
from repro.kernels import PackedSignatures
from repro.obs.metrics import Sample, get_registry
from repro.obs.trace import get_tracer
from repro.sharding.rules import data_axis_devices, place_shards


def _router_samples(router: "ShardedIndex"):
    """Registry collector over one live ``ShardedIndex`` (weakref'd):
    the per-instance mesh-dispatch ints (kept per-instance -- tests pin
    them) roll up into process counters, plus the served manifest
    generation / corpus size as gauges."""
    state = router._state
    yield Sample("index_mesh_dispatches_total", "counter",
                 "shard_map collective dispatches taken",
                 (("mode", "exact"),), float(router.mesh_exact_dispatches))
    yield Sample("index_mesh_dispatches_total", "counter",
                 "shard_map collective dispatches taken",
                 (("mode", "lsh"),), float(router.mesh_lsh_dispatches))
    yield Sample("index_generation", "gauge",
                 "manifest generation currently served", (),
                 float(state.generation))
    yield Sample("index_docs", "gauge", "documents served", (),
                 float(state.n))
    yield Sample("index_shards", "gauge", "shards served", (),
                 float(len(state.searchers)))


def merge_topk(results: Sequence[SearchResult], offsets: Sequence[int],
               topk: int) -> SearchResult:
    """Fold per-shard top-k (local ids) into global top-k.

    Scores are computed by the same kernel path on every shard, so
    sorting the concatenated candidates lexicographically by
    (descending score, ascending global id) reproduces ``lax.top_k``
    over the unpartitioned corpus bit-exactly -- ids AND scores.  The
    rule is a pure function of (score, global id), which makes the merge
    independent of shard order and contiguity: the sequential fan-out
    (ascending contiguous ranges) and the mesh fan-out's gathered
    per-device partials (round-robin interleaved ranges) share this one
    code path.  Padding entries (id -1) carry -inf scores and sort last.
    """
    if not results:
        raise ValueError("merge_topk needs at least one shard result")
    cat_s = np.concatenate([r.scores for r in results], axis=1)
    cat_i = np.concatenate(
        [np.where(r.indices >= 0, r.indices + off, np.int64(-1))
         for r, off in zip(results, offsets)], axis=1)
    order = np.lexsort((cat_i, -cat_s), axis=1)[:, :topk]
    out_s = np.take_along_axis(cat_s, order, axis=1)
    out_i = np.take_along_axis(cat_i, order, axis=1)
    pad = topk - out_s.shape[1]
    if pad > 0:
        out_s = np.pad(out_s, ((0, 0), (0, pad)),
                       constant_values=-np.inf)
        out_i = np.pad(out_i, ((0, 0), (0, pad)), constant_values=-1)
    n_cand = None
    if all(r.n_candidates is not None for r in results):
        n_cand = np.sum([r.n_candidates for r in results], axis=0)
    return SearchResult(out_i, out_s.astype(np.float32), n_cand)


# ---------------------------------------------------------------------------
# The RPC seam
# ---------------------------------------------------------------------------

class ShardClient:
    """Transport seam between the router and one shard's searcher.

    ``ShardedIndex``'s fan-out speaks only this protocol: ``dispatch``
    starts the shard's work NOW and returns a zero-arg harvest callable
    producing the shard's ``SearchResult`` (scores + LOCAL doc ids) --
    local ids plus kernel scores are the entire wire contract, so the
    router's merge is transport-agnostic.  ``LocalShardClient`` is the
    in-process implementation; a multi-host deployment swaps in a client
    whose ``dispatch`` ships the packed query batch over RPC and whose
    harvest blocks on the remote reply, with no change to the router.
    """

    @property
    def n(self) -> int:
        """Documents served by this shard."""
        raise NotImplementedError

    def dispatch(self, qwords, topk: int, *, mode: str = "exact",
                 query_sizes=None,
                 qkeys=None) -> Callable[[], SearchResult]:
        raise NotImplementedError


class LocalShardClient(ShardClient):
    """In-process ``ShardClient``: a direct ``IndexSearcher.dispatch``."""

    def __init__(self, searcher: IndexSearcher):
        self.searcher = searcher

    @property
    def n(self) -> int:
        return self.searcher.index.n

    def dispatch(self, qwords, topk: int, *, mode: str = "exact",
                 query_sizes=None,
                 qkeys=None) -> Callable[[], SearchResult]:
        return self.searcher.dispatch(qwords, topk, mode=mode,
                                      query_sizes=query_sizes, _qkeys=qkeys)


@dataclasses.dataclass(frozen=True)
class _RouterState:
    """One immutable, internally consistent view of the shard set.

    Mutations (``append``, ``refresh``) build a whole new state and swap
    it in with a single attribute assignment; every ``search`` snapshots
    ``self._state`` exactly once, so a racing mutation can never hand a
    query old offsets with new searchers (a torn view).  ``cache`` holds
    per-state derived device data (the mesh dispatcher's stacked
    corpus); it dies with the state, so a swapped-in corpus can never be
    served against stale offsets.
    """

    searchers: Tuple[IndexSearcher, ...]
    clients: Tuple[ShardClient, ...]
    offsets: np.ndarray            # global doc-id offset per shard
    paths: Optional[Tuple[str, ...]]
    generation: int
    cache: dict = dataclasses.field(default_factory=dict)

    @property
    def n(self) -> int:
        return int(sum(s.index.n for s in self.searchers))


def _plan_spill(last_n: int, counts: Sequence[int],
                budget: int) -> List[Tuple[bool, List[int]]]:
    """Greedy ``.sig``-file assignment for a budgeted append.

    Returns ``[(extend_last, [file indices]), ...]``: files keep landing
    in the current target shard while its doc count is below ``budget``
    (so a shard can overshoot by at most one file -- splits stay at
    ``.sig``-file granularity, like ``build_sharded``), then spill into
    a NEW shard.  The first group extends the last existing shard only
    if it still had headroom.
    """
    groups: List[Tuple[bool, List[int]]] = []
    cur: List[int] = []
    cur_n = last_n
    extend = True
    for i, c in enumerate(counts):
        if cur_n >= budget:
            if cur:
                groups.append((extend, cur))
            cur, cur_n, extend = [], 0, False
        cur.append(i)
        cur_n += c
    if cur:
        groups.append((extend, cur))
    return groups


class ShardedIndex(_BatchedAdmission):
    """One logical index over S ``.idx`` shards with contiguous doc ranges.

    Mirrors the ``IndexSearcher`` serving API (``search`` plus the
    shared ``submit``/``flush`` batched admission) and returns *global*
    doc ids.  ``searcher_kwargs`` flow to every per-shard
    ``IndexSearcher`` (backend, corpus_block, max_device_bytes, ... --
    an out-of-core device window applies per shard).

    ``mesh`` places shards round-robin on the devices of the mesh's
    ``"data"`` axis and enables the ``shard_map`` exact dispatcher;
    ``dispatch`` picks the fan-out ("auto" = mesh iff a mesh was given,
    overridable per ``search`` call).  ``max_shard_docs`` is the spill
    budget for ``append``; ``client_factory`` wraps each searcher in a
    ``ShardClient`` (default: in-process).
    """

    def __init__(self, indexes: Sequence[SigIndex], *,
                 paths: Optional[Sequence[str]] = None,
                 manifest_dir: Optional[str] = None,
                 generation: int = 0,
                 mesh: Optional[Mesh] = None,
                 dispatch: str = "auto",
                 max_shard_docs: Optional[int] = None,
                 client_factory: Optional[Callable[[IndexSearcher],
                                                   ShardClient]] = None,
                 on_shard_failure: str = "fail",
                 **searcher_kwargs):
        if not indexes:
            raise ValueError("ShardedIndex needs at least one shard")
        if dispatch not in ("auto", "sequential", "mesh"):
            raise ValueError(f"dispatch must be 'auto', 'sequential' or "
                             f"'mesh', got {dispatch!r}")
        if on_shard_failure not in ("fail", "partial"):
            raise ValueError(f"on_shard_failure must be 'fail' or "
                             f"'partial', got {on_shard_failure!r}")
        if dispatch == "mesh" and mesh is None:
            raise ValueError("dispatch='mesh' needs a mesh")
        if max_shard_docs is not None and max_shard_docs < 1:
            raise ValueError(f"max_shard_docs must be >= 1, got "
                             f"{max_shard_docs}")
        spec0 = indexes[0].spec
        for i, idx in enumerate(indexes[1:], 1):
            if idx.spec != spec0 or idx.banding != indexes[0].banding:
                raise ValueError(
                    f"shard {i} wire/banding {idx.spec}/{idx.banding} != "
                    f"shard 0 {spec0}/{indexes[0].banding}")
        self._searcher_kwargs = dict(searcher_kwargs)
        self.manifest_dir = manifest_dir
        self.mesh = mesh
        self.max_shard_docs = max_shard_docs
        self._dispatch_default = dispatch
        self._client_factory = client_factory or LocalShardClient
        self.on_shard_failure = on_shard_failure
        reg = get_registry()
        self._m_shard_failures = reg.counter(
            "index_shard_failures_total",
            "shard dispatches that failed past their client's own "
            "retry/breaker budget", labels=("shard",))
        self._m_partial = reg.counter(
            "index_partial_searches_total",
            "searches served from surviving shards only "
            "(on_shard_failure='partial')")
        # the mesh's data-parallel rank set, as its own 1-axis mesh: the
        # shard_map dispatch and the placement rule both address devices
        # along "data" only, whatever other axes the caller's mesh has
        self._data_mesh = None
        if mesh is not None:
            self._data_mesh = Mesh(np.array(data_axis_devices(mesh)),
                                   ("data",))
        self._mesh_fns: dict = {}
        self._mesh_build_lock = threading.Lock()
        # observability: collective dispatches actually taken (tests pin
        # that the LSH path really went through ONE shard_map, not the
        # per-shard sequential loop); also exported through the metrics
        # registry by the weakref collector below
        self.mesh_exact_dispatches = 0
        self.mesh_lsh_dispatches = 0
        get_registry().register_object(self, _router_samples)
        # Serializes state swaps so a refresh that read an older manifest
        # can never overwrite a concurrent append's newer state
        # (generations only move forward).
        self._swap_lock = threading.Lock()
        devices = self._shard_devices(len(indexes))
        self._state = self._build_state(
            [self._make_searcher(idx, i, devices)
             for i, idx in enumerate(indexes)], paths, generation)
        self._admission_init()

    # -- placement + state construction ----------------------------------
    def _shard_devices(self, n_shards: int):
        """Round-robin shard -> device placement (None without a mesh).

        Stable by shard position (``repro.sharding.rules.place_shards``):
        tail growth never relocates an existing shard."""
        if self._data_mesh is None:
            return None
        return place_shards(n_shards, self._data_mesh)

    def _make_searcher(self, idx: SigIndex, shard_i: int,
                       devices) -> IndexSearcher:
        dev = devices[shard_i] if devices is not None else None
        return IndexSearcher(idx, device=dev, **self._searcher_kwargs)

    def _build_state(self, searchers: Sequence[IndexSearcher],
                     paths: Optional[Sequence[str]],
                     generation: int) -> _RouterState:
        offsets = np.cumsum([0] + [s.index.n for s in searchers])[:-1]
        return _RouterState(tuple(searchers),
                            tuple(self._client_factory(s) for s in searchers),
                            offsets, tuple(paths) if paths else None,
                            generation)

    # -- snapshot accessors (each reads self._state exactly once) --------
    @property
    def searchers(self) -> Tuple[IndexSearcher, ...]:
        return self._state.searchers

    @property
    def clients(self) -> Tuple[ShardClient, ...]:
        return self._state.clients

    @property
    def offsets(self) -> np.ndarray:
        return self._state.offsets

    @property
    def paths(self) -> Optional[Tuple[str, ...]]:
        return self._state.paths

    @property
    def generation(self) -> int:
        """The manifest generation this router currently serves."""
        return self._state.generation

    @property
    def n(self) -> int:
        return self._state.n

    @property
    def n_shards(self) -> int:
        return len(self._state.searchers)

    @property
    def spec(self):
        return self._state.searchers[0].index.spec

    # -- fan-out ---------------------------------------------------------
    def _use_mesh(self, dispatch: Optional[str]) -> bool:
        d = dispatch or self._dispatch_default
        if d not in ("auto", "sequential", "mesh"):
            raise ValueError(f"dispatch must be 'auto', 'sequential' or "
                             f"'mesh', got {d!r}")
        if d == "mesh" and self._data_mesh is None:
            raise ValueError("dispatch='mesh' needs a mesh (pass mesh= to "
                             "ShardedIndex / load_sharded)")
        return d == "mesh" or (d == "auto" and self._data_mesh is not None)

    def search(self, queries: Union[PackedSignatures, jax.Array, np.ndarray],
               topk: int = 10, *, mode: str = "exact",
               query_sizes: Optional[np.ndarray] = None,
               dispatch: Optional[str] = None,
               on_shard_failure: Optional[str] = None) -> SearchResult:
        """Global top-k: fan out to every shard, merge.

        With the mesh dispatcher, both modes run as ONE ``shard_map``
        computation per call: ``mode="exact"`` scans each device's
        placed shards with an in-jit running top-k; ``mode="lsh"``
        probes every shard's bucket tables on the host (band keys
        computed once per batch), then gathers + reranks each device's
        padded candidate rows in one collective kernel dispatch.  In
        both cases the per-device ``(best_s, best_i)`` partials are
        gathered across the mesh and ``merge_topk`` folds them --
        bit-identical (ids AND scores) to the sequential fan-out and to
        a single-index search.  The shard set is snapshotted ONCE here,
        so a concurrent ``append``/``refresh`` never tears this call's
        view.

        ``on_shard_failure`` (default: the constructor's) picks what a
        shard-client exception costs on the **sequential** fan-out:
        ``"fail"`` re-raises it (whole query dies, the seed behavior);
        ``"partial"`` serves the surviving shards -- the merge is then
        bit-identical to a healthy router over just those shards, and
        the result carries ``coverage`` (surviving docs / total docs)
        and the failed shard indices.  The mesh dispatcher is a single
        in-process collective with no per-shard failure domain, so the
        policy only applies to the client fan-out.
        """
        state = self._state
        policy = on_shard_failure or self.on_shard_failure
        if policy not in ("fail", "partial"):
            raise ValueError(f"on_shard_failure must be 'fail' or "
                             f"'partial', got {policy!r}")
        qwords = _query_words(queries, state.searchers[0].index.spec)
        use_mesh = self._use_mesh(dispatch)
        if mode == "exact" and use_mesh:
            return self._mesh_exact(state, qwords, topk, query_sizes)
        qkeys = None
        if mode == "lsh":
            idx0 = state.searchers[0].index
            qkeys = np.asarray(band_keys_packed(qwords, idx0.spec,
                                                idx0.banding))
            if use_mesh:
                return self._mesh_lsh(state, qwords, topk, query_sizes,
                                      qkeys)
        tracer = get_tracer()
        if policy == "fail":
            with tracer.phase("shard_dispatch",
                              args={"mode": mode,
                                    "shards": len(state.clients)}):
                pending = [c.dispatch(qwords, topk, mode=mode,
                                      query_sizes=query_sizes, qkeys=qkeys)
                           for c in state.clients]
            with tracer.phase("harvest"):
                results = [p() for p in pending]
            with tracer.phase("merge"):
                return merge_topk(results, state.offsets, topk)
        return self._fanout_partial(state, qwords, topk, mode, query_sizes,
                                    qkeys, tracer)

    def _fanout_partial(self, state: "_RouterState", qwords, topk: int,
                        mode: str, query_sizes, qkeys,
                        tracer) -> SearchResult:
        """Sequential fan-out that survives shard-client failures.

        A shard can fail at dispatch time (e.g. its breaker is open) or
        at harvest time (transport fault past the retry budget); either
        way the shard drops out and the survivors merge **with their
        original offsets**, which is exactly what a healthy router
        restricted to the surviving shards would return
        (``merge_topk`` is a pure function of (score, global id)).
        """
        failed: dict = {}
        with tracer.phase("shard_dispatch",
                          args={"mode": mode,
                                "shards": len(state.clients)}):
            pending = []
            for si, c in enumerate(state.clients):
                try:
                    pending.append(c.dispatch(qwords, topk, mode=mode,
                                              query_sizes=query_sizes,
                                              qkeys=qkeys))
                except Exception as e:
                    pending.append(None)
                    failed[si] = e
        with tracer.phase("harvest"):
            results = []
            for si, p in enumerate(pending):
                if p is None:
                    results.append(None)
                    continue
                try:
                    results.append(p())
                except Exception as e:
                    results.append(None)
                    failed[si] = e
        if failed:
            for si in failed:
                self._m_shard_failures.labels(shard=str(si)).inc()
            if len(failed) == len(state.clients):
                raise RuntimeError(
                    f"all {len(state.clients)} shards failed "
                    f"(last: {failed[max(failed)]!r})") from failed[max(failed)]
            self._m_partial.inc()
        with tracer.phase("merge"):
            if not failed:
                return merge_topk(results, state.offsets, topk)
            keep = [si for si in range(len(results)) if si not in failed]
            merged = merge_topk([results[si] for si in keep],
                                state.offsets[keep], topk)
        n_total = state.n
        n_live = int(sum(state.searchers[si].index.n for si in keep))
        return dataclasses.replace(merged, coverage=n_live / n_total,
                                   failed_shards=tuple(sorted(failed)))

    # -- the shard_map exact dispatcher ----------------------------------
    def _mesh_layout(self, state: _RouterState) -> dict:
        """The stacked, mesh-sharded device corpus for one router state
        (built once per state, under a lock; dies with the state).

        Devices get their round-robin shards concatenated (ascending
        shard order, so rows stay in ascending global-id order per
        device -- the in-jit ``lax.top_k`` tie rule then resolves to the
        lowest global id within each device), each shard padded to a
        scan-block multiple and each device padded to the widest
        device's row count; padding rows carry id -1 and score -inf.
        """
        cached = state.cache.get("mesh_exact")
        if cached is not None:
            return cached
        with self._mesh_build_lock:
            cached = state.cache.get("mesh_exact")
            if cached is not None:
                return cached
            s0 = state.searchers[0]
            meta0 = s0.index.meta
            devs = data_axis_devices(self._data_mesh)
            D = len(devs)
            block = max(s.corpus_block for s in state.searchers)
            heights = [((s.index.n + block - 1) // block) * block
                       for s in state.searchers]
            per_dev = [[s for s in range(len(state.searchers))
                        if s % D == d] for d in range(D)]
            rows = max((sum(heights[s] for s in group) or block)
                       for group in per_dev)
            words = meta0.words
            has_sizes = (s0.index.set_sizes is not None and meta0.s > 0)
            corpus = np.zeros((D * rows, words), np.uint32)
            ids = np.full(D * rows, -1, np.int32)
            doc_sizes = np.zeros(D * rows, np.uint32) if has_sizes else None
            shard_pos = [None] * len(state.searchers)
            for d, group in enumerate(per_dev):
                pos = d * rows
                for s in group:
                    idx = state.searchers[s].index
                    n_s = idx.n
                    shard_pos[s] = (d, pos - d * rows)
                    corpus[pos:pos + n_s] = idx.words_host
                    ids[pos:pos + n_s] = (int(state.offsets[s])
                                          + np.arange(n_s, dtype=np.int32))
                    if has_sizes:
                        doc_sizes[pos:pos + n_s] = np.asarray(idx.set_sizes)
                    pos += heights[s]
            row_sh = NamedSharding(self._data_mesh, P("data"))
            layout = {
                "corpus": jax.device_put(
                    corpus, NamedSharding(self._data_mesh, P("data", None))),
                "ids": jax.device_put(ids, row_sh),
                "doc_sizes": (jax.device_put(doc_sizes, row_sh)
                              if has_sizes else None),
                # shard -> (device, row offset within the device block):
                # the LSH fan-out maps shard-local candidate ids to this
                # device-local row space
                "shard_pos": tuple(shard_pos),
                "block": block, "D": D,
                "D_univ": (1 << meta0.s) if has_sizes else 0,
                "statics": dict(k=meta0.k, b=meta0.b,
                                code_bits=meta0.code_bits,
                                sentinel=meta0.sentinel, backend=s0._be,
                                blk_q=s0._kb["blk_q"], blk_n=s0._kb["blk_n"],
                                blk_k=s0._kb["blk_k"]),
            }
            state.cache["mesh_exact"] = layout
            return layout

    def _mesh_scan_fn(self, *, block: int, kk: int, has_sizes: bool,
                      D_univ: int, statics: dict):
        """One jitted shard_map per (block, topk, statics) -- cached so
        repeated flushes reuse the compiled executable."""
        key = (block, kk, has_sizes, D_univ,
               tuple(sorted(statics.items())))
        fn = self._mesh_fns.get(key)
        if fn is not None:
            return fn
        mesh = self._data_mesh

        if has_sizes:
            def body(qwords, corpus, ids, q_sizes, doc_sizes):
                bs, bi = exact_scan_ids(qwords, corpus, ids, q_sizes,
                                        doc_sizes, block=block, topk=kk,
                                        D=D_univ, **statics)
                return bs[None], bi[None]
            in_specs = (P(None, None), P("data", None), P("data"),
                        P(None), P("data"))
        else:
            def body(qwords, corpus, ids):
                bs, bi = exact_scan_ids(qwords, corpus, ids, None, None,
                                        block=block, topk=kk, D=0,
                                        **statics)
                return bs[None], bi[None]
            in_specs = (P(None, None), P("data", None), P("data"))

        fn = jax.jit(shard_map(body, mesh=mesh, in_specs=in_specs,
                               out_specs=(P("data"), P("data")),
                               check_rep=False))
        self._mesh_fns[key] = fn
        return fn

    @staticmethod
    def _check_mesh_resident(state: _RouterState) -> None:
        streamed = [s for s in state.searchers if s.streamed]
        if streamed:
            raise ValueError(
                "mesh dispatch holds the stacked corpus device-resident "
                "and cannot honor max_device_bytes "
                f"({len(streamed)} shard(s) would stream); use "
                "dispatch='sequential' for out-of-core shards")

    def _mesh_exact(self, state: _RouterState, qwords, topk: int,
                    query_sizes) -> SearchResult:
        if topk < 1:
            raise ValueError(f"topk must be >= 1, got {topk}")
        self._check_mesh_resident(state)
        layout = self._mesh_layout(state)
        has_sizes = layout["doc_sizes"] is not None
        if has_sizes and query_sizes is None:
            raise ValueError("index stores set sizes; pass query_sizes "
                             "to search() for the exact Theorem-1 rerank")
        kk = min(topk, state.n)
        fn = self._mesh_scan_fn(block=layout["block"], kk=kk,
                                has_sizes=has_sizes,
                                D_univ=layout["D_univ"],
                                statics=layout["statics"])
        tracer = get_tracer()
        with tracer.phase("mesh_dispatch", args={"mode": "exact",
                                                 "devices": layout["D"]}):
            if has_sizes:
                out_s, out_i = fn(qwords, layout["corpus"], layout["ids"],
                                  jnp.asarray(query_sizes),
                                  layout["doc_sizes"])
            else:
                out_s, out_i = fn(qwords, layout["corpus"], layout["ids"])
            # the jit output IS the cross-device gather: (D, Q, kk) partials
            self.mesh_exact_dispatches += 1
            out_s, out_i = np.asarray(out_s), np.asarray(out_i)
        per_dev = [SearchResult(out_i[d].astype(np.int64), out_s[d])
                   for d in range(layout["D"])]
        with tracer.phase("merge"):
            return merge_topk(per_dev, [0] * layout["D"], topk)

    # -- the shard_map LSH dispatcher ------------------------------------
    def _mesh_lsh_fn(self, *, kk: int, has_sizes: bool, D_univ: int,
                     statics: dict):
        """One jitted shard_map per (topk, statics) -- candidate widths
        are shape-polymorphic under the cached callable (jax retraces
        per padded width; widths are bucketed to powers of two so
        repeated flushes reuse compiled executables)."""
        key = ("lsh", kk, has_sizes, D_univ, tuple(sorted(statics.items())))
        fn = self._mesh_fns.get(key)
        if fn is not None:
            return fn
        mesh = self._data_mesh

        if has_sizes:
            def body(qwords, corpus, ids, cand, member, q_sizes, doc_sizes):
                ts, ti = lsh_rerank_ids(qwords, corpus, ids, cand[0],
                                        member[0], q_sizes, doc_sizes,
                                        topk=kk, D=D_univ, **statics)
                return ts[None], ti[None]
            in_specs = (P(None, None), P("data", None), P("data"),
                        P("data", None), P("data", None, None),
                        P(None), P("data"))
        else:
            def body(qwords, corpus, ids, cand, member):
                ts, ti = lsh_rerank_ids(qwords, corpus, ids, cand[0],
                                        member[0], None, None,
                                        topk=kk, D=0, **statics)
                return ts[None], ti[None]
            in_specs = (P(None, None), P("data", None), P("data"),
                        P("data", None), P("data", None, None))

        fn = jax.jit(shard_map(body, mesh=mesh, in_specs=in_specs,
                               out_specs=(P("data"), P("data")),
                               check_rep=False))
        self._mesh_fns[key] = fn
        return fn

    def _mesh_lsh(self, state: _RouterState, qwords, topk: int,
                  query_sizes, qkeys: np.ndarray) -> SearchResult:
        """LSH candidate-gen + rerank as ONE collective per flush.

        Candidate generation stays a host-side bucket probe per shard
        (the sorted key arrays are mmap'd host state), but the gather +
        kernel rerank + per-device top-k run as a single ``shard_map``
        dispatch over the SAME stacked mesh corpus the exact path uses:
        each device gathers its padded/masked candidate rows (shard-
        local candidate ids mapped through the layout's per-shard row
        offsets, ascending global-id order per device), reranks them in
        one kernel launch, and the gathered per-device partials fold
        through ``merge_topk`` -- bit-identical (ids AND scores) to the
        sequential per-shard fan-out and to a single unsharded index,
        including the Theorem-1 rerank.
        """
        if topk < 1:
            raise ValueError(f"topk must be >= 1, got {topk}")
        self._check_mesh_resident(state)
        layout = self._mesh_layout(state)
        has_sizes = layout["doc_sizes"] is not None
        if has_sizes and query_sizes is None:
            raise ValueError("index stores set sizes; pass query_sizes "
                             "to search() for the exact Theorem-1 rerank")
        tracer = get_tracer()
        D, q = layout["D"], qwords.shape[0]
        cand_cols: List[List[np.ndarray]] = [[] for _ in range(D)]
        mem_cols: List[List[np.ndarray]] = [[] for _ in range(D)]
        n_cand = np.zeros(q, np.int64)
        cand_span = tracer.start_span("candidates",
                                      args={"shards": len(state.searchers)})
        for s, searcher in enumerate(state.searchers):
            d, pos = layout["shard_pos"][s]
            per_q = searcher.index.candidates_batch(qkeys)
            n_cand += np.array([c.size for c in per_q], np.int64)
            if not any(c.size for c in per_q):
                continue
            # shards are disjoint doc ranges, so per-device columns are
            # the concatenation of the per-shard candidate unions --
            # ascending global ids (ascending shard order per device,
            # np.unique-sorted local ids within a shard)
            union = np.unique(np.concatenate(per_q))
            member = np.zeros((q, union.size), bool)
            for i, c in enumerate(per_q):
                member[i, np.searchsorted(union, c)] = True
            cand_cols[d].append((pos + union).astype(np.int32))
            mem_cols[d].append(member)
        tracer.end_span(cand_span)
        widths = [sum(a.size for a in cols) for cols in cand_cols]
        if max(widths) == 0:
            return SearchResult(np.full((q, topk), -1, np.int64),
                                np.full((q, topk), -np.inf, np.float32),
                                n_cand)
        # pad every device to one bucketed width so batch-to-batch
        # candidate counts reuse compiled kernels (same rule as the
        # single-searcher LSH rerank); padding slots point at row 0
        # with membership False -> -inf score, id -1
        c_pad = max(128, 1 << int(max(widths) - 1).bit_length())
        cand = np.zeros((D, c_pad), np.int32)
        member = np.zeros((D, q, c_pad), bool)
        for d in range(D):
            if not cand_cols[d]:
                continue
            cols = np.concatenate(cand_cols[d])
            cand[d, :cols.size] = cols
            member[d, :, :cols.size] = np.concatenate(mem_cols[d], axis=1)
        kk = min(topk, c_pad)
        fn = self._mesh_lsh_fn(kk=kk, has_sizes=has_sizes,
                               D_univ=layout["D_univ"],
                               statics=layout["statics"])
        with tracer.phase("mesh_dispatch", args={"mode": "lsh",
                                                 "devices": D}):
            if has_sizes:
                out_s, out_i = fn(qwords, layout["corpus"], layout["ids"],
                                  cand, member, jnp.asarray(query_sizes),
                                  layout["doc_sizes"])
            else:
                out_s, out_i = fn(qwords, layout["corpus"], layout["ids"],
                                  cand, member)
            self.mesh_lsh_dispatches += 1
            out_s, out_i = np.asarray(out_s), np.asarray(out_i)
        per_dev = [SearchResult(out_i[d].astype(np.int64), out_s[d])
                   for d in range(D)]
        with tracer.phase("merge"):
            merged = merge_topk(per_dev, [0] * D, topk)
        return SearchResult(merged.indices, merged.scores, n_cand)

    # -- live growth -----------------------------------------------------
    def append(self, sig_paths: Sequence[str], *,
               set_sizes: Optional[np.ndarray] = None
               ) -> List[Tuple[str, object]]:
        """Append new documents, concurrently safe with readers.

        Without a ``max_shard_docs`` budget the LAST shard grows
        (``append_index``; earlier shards would shift global ids).  With
        a budget, ``.sig`` files keep extending the last shard while it
        has headroom, then *spill* into NEW tail shards at file
        granularity -- spilled shards are published atomically (temp
        write + ``os.replace``) and become visible only through the
        manifest rewrite at the end, so a crash mid-spill leaves readers
        on the old generation with no torn shard visible.

        Holds the directory lock (two appenders serialize), refreshes
        first (picking up appends other processes landed), rewrites the
        manifest atomically with a bumped generation, and swaps this
        router's state in one assignment; spilled shards pick up their
        round-robin device placement in that swap (other processes: on
        their next ``refresh``).  Existing global ids are unchanged; a
        racing ``search`` sees the pre- or post-append corpus, never a
        mix.  Returns ``[(shard_path, IndexMeta), ...]`` for every
        touched shard.  Requires shard paths (construct via
        ``load_sharded``).
        """
        if not self.paths:
            raise ValueError("append needs shard paths; load this index "
                             "via load_sharded()")
        if not self.manifest_dir:
            raise ValueError("append needs a manifest dir; load this "
                             "index via load_sharded()")
        with sharded_lock(self.manifest_dir):
            self.refresh()
            state = self._state
            meta0 = state.searchers[0].index.meta
            if set_sizes is not None:
                set_sizes = np.ascontiguousarray(set_sizes, np.uint32)
            if meta0.has_set_sizes and set_sizes is None:
                raise ValueError("index stores set sizes; append needs "
                                 "set_sizes for the new documents")
            if not meta0.has_set_sizes and set_sizes is not None:
                raise ValueError("index has no set sizes; cannot add them "
                                 "on append")
            counts = [read_sig_meta(p).n for p in sig_paths]
            if self.max_shard_docs is None:
                groups = [(True, list(range(len(sig_paths))))]
            else:
                groups = _plan_spill(state.searchers[-1].index.n, counts,
                                     self.max_shard_docs)
            paths = list(state.paths)
            searchers = list(state.searchers)
            devices = self._shard_devices(
                len(paths) + sum(1 for ext, _ in groups if not ext))
            touched: List[Tuple[str, object]] = []
            doc0 = 0
            for extend, file_idx in groups:
                files = [sig_paths[i] for i in file_idx]
                n_g = sum(counts[i] for i in file_idx)
                sizes_g = (None if set_sizes is None
                           else set_sizes[doc0:doc0 + n_g])
                if extend:
                    last = paths[-1]
                    meta = append_index(last, files, set_sizes=sizes_g)
                    searchers[-1] = self._make_searcher(
                        load_index(last), len(paths) - 1, devices)
                    touched.append((last, meta))
                else:
                    path = os.path.join(self.manifest_dir,
                                        f"shard_{len(paths):05d}.idx")
                    meta = build_index(files, path, meta0.banding,
                                       set_sizes=sizes_g, s=meta0.s,
                                       atomic=True)
                    searchers.append(self._make_searcher(
                        load_index(path), len(paths), devices))
                    paths.append(path)
                    touched.append((path, meta))
                doc0 += n_g
            write_manifest(self.manifest_dir, paths,
                           [s.index.n for s in searchers],
                           generation=state.generation + 1)
            with self._swap_lock:
                self._state = self._build_state(searchers, paths,
                                                state.generation + 1)
        return touched

    def refresh(self, *, max_attempts: int = 5) -> bool:
        """Re-read the manifest; reload shards another process changed.

        Returns True when the served state moved.  Only shards whose
        (name, doc count) differ from the current snapshot are reloaded
        (a spilled shard is a NEW name, so it loads here and gets its
        round-robin device placement -- the stable-by-position rule
        guarantees no existing shard moves); unchanged shards keep their
        device-resident corpus.  If a writer replaces a shard file
        between the manifest read and the shard load (the loaded count
        disagrees with the manifest), the whole read retries -- the
        swapped-in state is always internally consistent.
        """
        if not self.manifest_dir:
            return False
        for _ in range(max_attempts):
            manifest = read_manifest(self.manifest_dir)
            state = self._state
            if manifest["generation"] == state.generation:
                return False
            names = manifest["shards"]
            counts = [int(b) - int(a) for a, b in
                      zip(manifest["offsets"],
                          list(manifest["offsets"][1:]) + [manifest["n"]])]
            paths = [os.path.join(self.manifest_dir, nm) for nm in names]
            devices = self._shard_devices(len(paths))
            old = {}
            if state.paths:
                old = {(p, s.index.n): s
                       for p, s in zip(state.paths, state.searchers)}
            searchers = []
            consistent = True
            for i, (path, count) in enumerate(zip(paths, counts)):
                keep = old.get((path, count))
                if keep is not None:
                    searchers.append(keep)
                    continue
                loaded = self._make_searcher(load_index(path), i, devices)
                if loaded.index.n != count:
                    consistent = False     # raced a writer; re-read
                    break
                searchers.append(loaded)
            if consistent:
                with self._swap_lock:
                    if manifest["generation"] <= self._state.generation:
                        return False   # a concurrent append moved further
                    self._state = self._build_state(searchers, paths,
                                                    manifest["generation"])
                return True
        raise RuntimeError(
            f"refresh({self.manifest_dir}) kept racing a writer: shard "
            f"doc counts never matched the manifest after "
            f"{max_attempts} attempts")


def load_sharded(shard_dir: str, *, mmap: bool = True,
                 mesh: Optional[Mesh] = None, dispatch: str = "auto",
                 max_shard_docs: Optional[int] = None,
                 **searcher_kwargs) -> ShardedIndex:
    """Load a ``build_sharded`` output directory into a ``ShardedIndex``.

    ``searcher_kwargs`` flow to every per-shard ``IndexSearcher``
    (``backend=``, ``corpus_block=``, ``max_device_bytes=``, ...);
    ``mesh``/``dispatch``/``max_shard_docs`` configure the device-
    parallel fan-out and the append spill budget.
    """
    manifest = read_manifest(shard_dir)
    man_path = os.path.join(shard_dir, MANIFEST_NAME)
    paths = [os.path.join(shard_dir, name) for name in manifest["shards"]]
    indexes = [load_index(p, mmap=mmap) for p in paths]
    sharded = ShardedIndex(indexes, paths=paths, manifest_dir=shard_dir,
                           generation=manifest["generation"], mesh=mesh,
                           dispatch=dispatch, max_shard_docs=max_shard_docs,
                           **searcher_kwargs)
    if sharded.n != manifest["n"]:
        raise ValueError(f"{man_path}: manifest n={manifest['n']} != "
                         f"loaded {sharded.n}")
    return sharded
