"""LSH banding math for the similarity-search index.

This module is the canonical home of the banding calculus that
``repro.core.lsh`` introduced for offline dedup (and now delegates to):

  * ``BandingConfig``       -- n_bands x rows_per_band bands over
                               ``code_bits``-wide signature values,
  * ``band_keys_from_codes``-- pack each band's r codes into one integer
                               bucket key (pure jnp, works on device),
  * ``band_keys_packed``    -- the index-facing variant: band keys
                               straight from packed wire words, unpacked
                               *inside the jit* so the host only ever
                               sees packed words and the (n, n_bands)
                               keys,
  * ``s_curve`` / ``choose_band_config`` -- the standard LSH collision
    calculus 1 - (1 - p^r)^n_bands composed with the paper's Theorem-1
    b-bit collision probability, and a tuner that picks the most
    selective (n_bands, r) still predicted to clear a recall target at
    the resemblance threshold of interest.

Sentinel OPH wires band over the (b+1)-bit codes with EMPTY = 2^b
included: two sets whose bins are jointly empty collide in that slot,
which only adds candidates (recall can't drop); the kernel rerank then
applies the exact Li-Owen-Zhang correction.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.kernels.pack import PackSpec, unpack_device


MAX_KEY_BITS = 32


@dataclasses.dataclass(frozen=True)
class BandingConfig:
    """n_bands bands of rows_per_band ``code_bits``-wide values each.

    Band keys are computed in uint32 (``MAX_KEY_BITS`` = 32), so
    ``rows_per_band * code_bits <= 32`` -- every shift is < 32 and the
    key is the exact packed value, identical on every backend and
    independent of jax's x64 mode (an ``.idx`` built on one host must
    produce the same keys a query computes on another).
    """

    n_bands: int
    rows_per_band: int
    code_bits: int               # bits per banded value (b, or b+1 sentinel)

    def __post_init__(self):
        if self.n_bands < 1 or self.rows_per_band < 1:
            raise ValueError(f"need n_bands, rows_per_band >= 1, got "
                             f"({self.n_bands}, {self.rows_per_band})")
        if self.rows_per_band * self.code_bits > MAX_KEY_BITS:
            raise ValueError(
                f"band key needs {self.rows_per_band * self.code_bits} bits "
                f"> {MAX_KEY_BITS} (uint32 keys); reduce rows_per_band or "
                f"code_bits")

    @property
    def k(self) -> int:
        """Signature values consumed by the banding (first k of each row)."""
        return self.n_bands * self.rows_per_band


def band_keys_from_codes(codes: jax.Array, cfg: BandingConfig) -> jax.Array:
    """(n, >=cfg.k) uint32 codes -> (n, n_bands) uint32 bucket keys.

    Band i's key packs codes [i*r, (i+1)*r) little-endian at
    ``code_bits`` per value; r*code_bits <= 32 (``BandingConfig``) makes
    the packing exact with every shift well-defined.  Columns past
    ``cfg.k`` are ignored (an index may band over a prefix of the
    signature).
    """
    n, k = codes.shape
    if k < cfg.k:
        raise ValueError(f"signature width {k} < bands*rows {cfg.k}")
    z = codes[:, :cfg.k].astype(jnp.uint32).reshape(
        n, cfg.n_bands, cfg.rows_per_band)
    if cfg.code_bits < 32:
        z = z & jnp.uint32((1 << cfg.code_bits) - 1)
    shifts = jnp.arange(cfg.rows_per_band, dtype=jnp.uint32) * cfg.code_bits
    return jnp.sum(z << shifts, axis=-1, dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnums=(1, 2))
def _band_keys_packed_jit(words, spec: PackSpec, cfg: BandingConfig):
    codes = unpack_device(words, spec)
    if spec.sentinel:
        # band over the raw (b+1)-bit codes: EMPTY must key as 2^b, not
        # as the 0xFFFFFFFF marker unpack_device restores
        codes = jnp.where(codes == jnp.uint32(0xFFFFFFFF),
                          jnp.uint32(spec.empty_code), codes)
    return band_keys_from_codes(codes, cfg)


def band_keys_packed(words: jax.Array, spec: PackSpec,
                     cfg: BandingConfig) -> jax.Array:
    """Band keys straight from packed wire words (device-side unpack).

    The (n, k) signature matrix only ever exists as a traced value
    inside this jit -- the host sees packed words in, uint32 keys out.
    """
    if cfg.code_bits != spec.code_bits:
        raise ValueError(f"banding over {cfg.code_bits}-bit values, wire "
                         f"carries {spec.code_bits}-bit codes")
    return _band_keys_packed_jit(words, spec, cfg)


# ---------------------------------------------------------------------------
# S-curve calculus
# ---------------------------------------------------------------------------

def s_curve(p_collide: float, n_bands: int, rows_per_band: int) -> float:
    """P[candidate] when one banded value collides with prob p_collide."""
    return 1.0 - (1.0 - float(p_collide) ** rows_per_band) ** n_bands


def sparse_collision_prob(R: float, b: int) -> float:
    """Theorem 1 in the sparse limit r -> 0: P_b = 2^-b + (1 - 2^-b) R."""
    c = 2.0 ** -b
    return c + (1.0 - c) * R


def choose_band_config(k: int, b: int, *, code_bits: int = 0,
                       threshold: float = 0.5, target_recall: float = 0.95
                       ) -> BandingConfig:
    """Most selective banding still predicted to clear ``target_recall``.

    Sweeps rows_per_band from large (selective, steep S-curve) to small,
    keeping the first r whose predicted candidate probability at
    resemblance ``threshold`` -- Theorem-1 sparse-limit collision prob
    composed through the S-curve -- reaches the target.  ``n_bands`` is
    ``k // r`` (the banding may consume a prefix of the signature).  For
    sentinel wires pass ``code_bits=b+1``; the prediction still uses the
    b-bit collision probability, a lower bound on the code-level one
    (joint-EMPTY collisions only add candidates), so the choice stays
    conservative.
    """
    cb = code_bits or b
    pb = sparse_collision_prob(threshold, b)
    best = None
    for r in range(min(k, MAX_KEY_BITS // cb), 0, -1):
        n_bands = k // r
        cfg = BandingConfig(n_bands, r, cb)
        if s_curve(pb, n_bands, r) >= target_recall:
            best = cfg
            break
    if best is None:
        raise ValueError(
            f"no (n_bands, r) over k={k}, b={b} reaches recall "
            f"{target_recall} at threshold {threshold}; lower the target")
    return best
