"""Build and load the raw mmap-able ``.idx`` similarity-search index.

``build_index`` turns packed ``.sig`` signature shards
(``repro.data.sigshard``) into one self-contained index file without
ever unpacking a signature on the host: the packed payload is copied
through verbatim, and the banded bucket tables are computed from band
keys that a jit (``repro.index.banding.band_keys_packed``) derives from
the packed words on device.

Layout (little-endian; every section 64-byte aligned):

    0   magic   b"RIDX"
    4   u32     version (1)
    8   u32     n              documents
    12  u32     k              signature values per document
    16  u32     b              b-bit width of genuine values
    20  u32     code_bits      b, or b+1 for sentinel wires
    24  u32     words          uint32 words per packed row
    28  u32     flags          bit 0: sentinel; bit 1: set sizes present
    32  u32     n_bands
    36  u32     rows_per_band
    40  u32     n_keys         total distinct (band, key) buckets
    44  u32     s              universe bits (0 = unknown)
    48  ..64    reserved (zero)

    f32[n]                 labels (carried from the .sig shards)
    u32[n]                 set sizes            (iff flag bit 1)
    i64[n_bands + 1]       band_offsets         (into keys / bucket_offsets)
    i64[n_keys]            keys                 (sorted within each band)
    i64[n_keys + 1]        bucket_offsets       (into postings, global)
    u32[n_bands * n]       postings             (doc ids per bucket)
    u32[n * words]         packed signature payload (row-major)

Bucket lookup for (band i, key x): binary-search x in
``keys[band_offsets[i]:band_offsets[i+1]]``; slot t's posting list is
``postings[bucket_offsets[t]:bucket_offsets[t+1]]``.  Everything is a
flat array, so ``load_index(mmap=True)`` serves straight off disk; the
packed payload additionally uploads once to the device
(``SigIndex.corpus``) for kernel scoring.

Scale-out entry points: ``build_sharded`` splits a corpus into S
contiguous-doc-range ``.idx`` shards plus a ``manifest.json`` (served by
``repro.index.router.ShardedIndex``); ``append_index`` grows an existing
index in place -- new shards' band keys merge into the bucket tables and
the old payload streams through verbatim, no re-hash / re-band / re-read
of the existing corpus.
"""

from __future__ import annotations

import dataclasses
import json
import os
import struct
from typing import List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.data.lockfile import FileLock
from repro.data.sigshard import read_sig_meta, read_sig_shard
from repro.index.banding import BandingConfig, band_keys_packed
from repro.kernels.pack import PackSpec

MAGIC = b"RIDX"
VERSION = 1
HEADER_BYTES = 64
_ALIGN = 64
_FLAG_SENTINEL = 1
_FLAG_SET_SIZES = 2


@dataclasses.dataclass(frozen=True)
class IndexMeta:
    """Decoded ``.idx`` header."""

    n: int
    k: int
    b: int
    code_bits: int
    words: int
    sentinel: bool
    has_set_sizes: bool
    n_bands: int
    rows_per_band: int
    n_keys: int
    s: int = 0

    @property
    def spec(self) -> PackSpec:
        return PackSpec(self.k, self.b, self.sentinel)

    @property
    def banding(self) -> BandingConfig:
        return BandingConfig(self.n_bands, self.rows_per_band, self.code_bits)

    @property
    def payload_bytes(self) -> int:
        """Packed signature payload only -- the paper's wire accounting."""
        return 4 * self.n * self.words


def _align(offset: int) -> int:
    return ((offset + _ALIGN - 1) // _ALIGN) * _ALIGN


def _sections(meta: IndexMeta) -> List[Tuple[str, np.dtype, int]]:
    """(name, dtype, count) in file order."""
    out = [("labels", np.dtype(np.float32), meta.n)]
    if meta.has_set_sizes:
        out.append(("set_sizes", np.dtype(np.uint32), meta.n))
    out += [
        ("band_offsets", np.dtype(np.int64), meta.n_bands + 1),
        ("keys", np.dtype(np.int64), meta.n_keys),
        ("bucket_offsets", np.dtype(np.int64), meta.n_keys + 1),
        ("postings", np.dtype(np.uint32), meta.n_bands * meta.n),
        ("payload", np.dtype(np.uint32), meta.n * meta.words),
    ]
    return out


def _section_offsets(meta: IndexMeta) -> dict:
    offsets, pos = {}, HEADER_BYTES
    for name, dtype, count in _sections(meta):
        pos = _align(pos)
        offsets[name] = pos
        pos += dtype.itemsize * count
    offsets["__end__"] = pos
    return offsets


# ---------------------------------------------------------------------------
# Band bucket tables (shared with repro.core.lsh.candidate_pairs)
# ---------------------------------------------------------------------------

def build_band_tables(keys: np.ndarray
                      ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                 np.ndarray]:
    """(n, n_bands) band keys -> flat sorted bucket tables.

    Returns ``(band_offsets, sorted_keys, bucket_offsets, postings)``:
    per band, the distinct keys in sorted order plus each key's posting
    list of doc ids (ascending) -- the O(n log n) replacement for the
    old python-dict banding pass, and exactly what ``.idx`` persists.
    """
    keys = np.asarray(keys)
    n, n_bands = keys.shape
    band_offsets = np.zeros(n_bands + 1, np.int64)
    all_keys, bucket_sizes, postings = [], [], []
    for band in range(n_bands):
        col = keys[:, band]
        order = np.argsort(col, kind="stable")       # doc ids stay ascending
        uniq, counts = np.unique(col, return_counts=True)
        all_keys.append(uniq.astype(np.int64))
        bucket_sizes.append(counts.astype(np.int64))
        postings.append(order.astype(np.uint32))
        band_offsets[band + 1] = band_offsets[band] + uniq.size
    sorted_keys = (np.concatenate(all_keys) if all_keys
                   else np.zeros(0, np.int64))
    sizes = (np.concatenate(bucket_sizes) if bucket_sizes
             else np.zeros(0, np.int64))
    bucket_offsets = np.zeros(sorted_keys.size + 1, np.int64)
    np.cumsum(sizes, out=bucket_offsets[1:])
    return (band_offsets, sorted_keys, bucket_offsets,
            np.concatenate(postings) if postings else np.zeros(0, np.uint32))


# ---------------------------------------------------------------------------
# Build
# ---------------------------------------------------------------------------

def _read_sig_group(sig_paths: Sequence[str], cfg: BandingConfig,
                    expect: Optional[IndexMeta] = None):
    """Read + validate a group of ``.sig`` shards (payloads stay mmap'd).

    Returns ``(shard_words, labels, band_keys, first_shard_meta)``.
    ``expect`` (an ``IndexMeta``) pins the wire format when appending to
    an existing index.
    """
    if not sig_paths:
        raise ValueError("need at least one .sig shard")
    shard_words, label_parts, key_parts = [], [], []
    meta0 = None
    for path in sig_paths:
        words, labels, sm = read_sig_shard(path, mmap=True)
        if meta0 is None:
            meta0 = sm
            if not 1 <= meta0.b <= 16:
                raise ValueError(
                    f"index needs the packed wire format (1 <= b <= 16), "
                    f"shards carry b={meta0.b}")
            if cfg.code_bits != meta0.code_bits:
                raise ValueError(
                    f"banding over {cfg.code_bits}-bit values, shards "
                    f"carry {meta0.code_bits}-bit codes")
            if expect is not None and \
                    (sm.k, sm.b, sm.code_bits, sm.words, sm.sentinel) != \
                    (expect.k, expect.b, expect.code_bits, expect.words,
                     expect.sentinel):
                raise ValueError(f"{path}: wire format {sm} != index "
                                 f"{expect}")
        elif (sm.k, sm.b, sm.code_bits, sm.words, sm.sentinel) != \
                (meta0.k, meta0.b, meta0.code_bits, meta0.words,
                 meta0.sentinel):
            raise ValueError(f"{path}: wire format {sm} != first shard "
                             f"{meta0}")
        shard_words.append(words)
        label_parts.append(labels)
        spec = PackSpec(sm.k, sm.b, sm.sentinel)
        key_parts.append(np.asarray(
            band_keys_packed(jnp.asarray(np.ascontiguousarray(words)),
                             spec, cfg)))
    return (shard_words, np.concatenate(label_parts),
            np.concatenate(key_parts), meta0)


_WRITE_CHUNK_ROWS = 1 << 16


def _write_index(out_path: str, meta: IndexMeta, arrays: dict,
                 payload_parts) -> None:
    """Serialize one ``.idx``; ``payload_parts`` is an iterable of
    (rows, words) uint32 arrays streamed through in bounded row chunks
    -- an mmap'd part (e.g. the old corpus during ``append_index``)
    never materializes whole in host RAM."""
    flags = ((_FLAG_SENTINEL if meta.sentinel else 0)
             | (_FLAG_SET_SIZES if meta.has_set_sizes else 0))
    header = MAGIC + struct.pack(
        "<11I", VERSION, meta.n, meta.k, meta.b, meta.code_bits, meta.words,
        flags, meta.n_bands, meta.rows_per_band, meta.n_keys, meta.s)
    header = header.ljust(HEADER_BYTES, b"\0")
    offsets = _section_offsets(meta)
    with open(out_path, "wb") as f:
        f.write(header)
        pos = HEADER_BYTES
        for name, dtype, count in _sections(meta):
            f.write(b"\0" * (offsets[name] - pos))
            if name == "payload":
                written = 0
                for words in payload_parts:        # stream off the mmaps
                    for off in range(0, words.shape[0], _WRITE_CHUNK_ROWS):
                        chunk = np.ascontiguousarray(
                            words[off:off + _WRITE_CHUNK_ROWS], dtype)
                        f.write(chunk.tobytes())
                        written += chunk.size
                assert written == count, (written, count)
                pos = offsets[name] + 4 * written
                continue
            arr = np.ascontiguousarray(arrays[name], dtype)
            assert arr.size == count, (name, arr.size, count)
            f.write(arr.tobytes())
            pos = offsets[name] + arr.nbytes


def _check_set_sizes(set_sizes, n: int) -> Optional[np.ndarray]:
    if set_sizes is None:
        return None
    set_sizes = np.ascontiguousarray(set_sizes, np.uint32)
    if set_sizes.shape != (n,):
        raise ValueError(f"set_sizes shape {set_sizes.shape} != ({n},)")
    return set_sizes


def build_index(sig_paths: Sequence[str], out_path: str, cfg: BandingConfig,
                *, set_sizes: Optional[np.ndarray] = None,
                s: int = 0, atomic: bool = False) -> IndexMeta:
    """Packed ``.sig`` shards -> one ``.idx`` file.

    The corpus is never unpacked on the host: shard payloads are
    memory-mapped and written through as-is, and band keys come off the
    device (``band_keys_packed``).  ``set_sizes`` (original nonzero
    counts per document, same order as the shards) and ``s`` (universe
    bits) are optional -- when present, queries get the exact Theorem-1
    debiasing constants instead of the sparse-limit ones.  ``atomic``
    writes to a same-directory temp name and ``os.replace``s it over
    ``out_path`` only when complete, so a crash mid-build never leaves a
    torn ``.idx`` at the published name (how ``ShardedIndex.append``
    publishes spilled shards under live readers).
    """
    # shard payloads stay memory-mapped: band keys (small) are computed
    # per shard on device, and the payload section is streamed through
    # shard by shard at write time -- peak host RAM is one shard, not
    # the corpus
    shard_words, labels, keys, meta0 = _read_sig_group(sig_paths, cfg)
    n = int(labels.shape[0])
    set_sizes = _check_set_sizes(set_sizes, n)

    band_offsets, sorted_keys, bucket_offsets, postings = \
        build_band_tables(keys)
    meta = IndexMeta(n=n, k=meta0.k, b=meta0.b, code_bits=meta0.code_bits,
                     words=meta0.words, sentinel=meta0.sentinel,
                     has_set_sizes=set_sizes is not None,
                     n_bands=cfg.n_bands, rows_per_band=cfg.rows_per_band,
                     n_keys=int(sorted_keys.size), s=s)
    arrays = {"labels": labels.astype(np.float32),
              "band_offsets": band_offsets, "keys": sorted_keys,
              "bucket_offsets": bucket_offsets, "postings": postings}
    if set_sizes is not None:
        arrays["set_sizes"] = set_sizes
    dest = out_path
    if atomic:
        out_path = f"{dest}.tmp.{os.getpid()}"
    _write_index(out_path, meta, arrays, shard_words)
    if atomic:
        os.replace(out_path, dest)
    return meta


# ---------------------------------------------------------------------------
# Incremental append + sharded build
# ---------------------------------------------------------------------------

def merge_band_tables(old: Tuple[np.ndarray, np.ndarray, np.ndarray,
                                 np.ndarray],
                      new: Tuple[np.ndarray, np.ndarray, np.ndarray,
                                 np.ndarray],
                      id_offset: int
                      ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                 np.ndarray]:
    """Merge two band bucket tables; ``new``'s doc ids shift by
    ``id_offset``.

    Both operands are ``(band_offsets, keys, bucket_offsets, postings)``
    as built by ``build_band_tables``.  Per band, the postings of both
    sides are re-grouped by key with a *stable* sort, so old docs keep
    their ascending order and precede the (also ascending, larger-id)
    new docs inside every bucket -- the merged table is bit-identical to
    one built from scratch over the combined corpus, without ever
    touching the old corpus payload or re-deriving its band keys.
    """
    bo_o, k_o, off_o, p_o = old
    bo_n, k_n, off_n, p_n = new
    n_bands = len(bo_o) - 1
    if len(bo_n) - 1 != n_bands:
        raise ValueError(f"band count mismatch: {n_bands} != {len(bo_n) - 1}")
    band_offsets = np.zeros(n_bands + 1, np.int64)
    key_parts, size_parts, post_parts = [], [], []
    for band in range(n_bands):
        lo, hi = int(bo_o[band]), int(bo_o[band + 1])
        ln, hn = int(bo_n[band]), int(bo_n[band + 1])
        sizes_o = np.asarray(off_o[lo + 1:hi + 1]) - np.asarray(off_o[lo:hi])
        sizes_n = np.asarray(off_n[ln + 1:hn + 1]) - np.asarray(off_n[ln:hn])
        keys_rep = np.concatenate([np.repeat(k_o[lo:hi], sizes_o),
                                   np.repeat(k_n[ln:hn], sizes_n)])
        posts = np.concatenate([
            np.asarray(p_o[off_o[lo]:off_o[hi]], np.int64),
            np.asarray(p_n[off_n[ln]:off_n[hn]], np.int64) + id_offset])
        order = np.argsort(keys_rep, kind="stable")
        keys_m, sizes_m = np.unique(keys_rep, return_counts=True)
        key_parts.append(keys_m.astype(np.int64))
        size_parts.append(sizes_m.astype(np.int64))
        post_parts.append(posts[order].astype(np.uint32))
        band_offsets[band + 1] = band_offsets[band] + keys_m.size
    keys = (np.concatenate(key_parts) if key_parts
            else np.zeros(0, np.int64))
    sizes = (np.concatenate(size_parts) if size_parts
             else np.zeros(0, np.int64))
    bucket_offsets = np.zeros(keys.size + 1, np.int64)
    np.cumsum(sizes, out=bucket_offsets[1:])
    return (band_offsets, keys, bucket_offsets,
            np.concatenate(post_parts) if post_parts
            else np.zeros(0, np.uint32))


def append_index(idx_path: str, sig_paths: Sequence[str], *,
                 set_sizes: Optional[np.ndarray] = None,
                 out_path: Optional[str] = None) -> IndexMeta:
    """Extend an existing ``.idx`` with new documents -- no full rebuild.

    The old corpus is never re-hashed, re-banded or re-read from its
    ``.sig`` shards: only the *new* shards' band keys are computed (on
    device), the bucket tables merge via ``merge_band_tables``, and the
    old packed payload streams through verbatim from the mmap.  New docs
    get ids ``[old_n, old_n + new_n)``; the result is bit-identical to
    ``build_index`` over old + new shards.  Writes atomically (temp file
    + ``os.replace``) to ``out_path`` (default: in place), under the
    destination's lock file (``<dest>.lock``) so two appenders cannot
    interleave the read-merge-replace; readers stay lock-free -- an open
    mmap keeps the pre-append inode alive across the replace.
    """
    dest = out_path or idx_path
    with FileLock(dest + ".lock"):
        return _append_index_locked(idx_path, sig_paths,
                                    set_sizes=set_sizes, dest=dest)


def _append_index_locked(idx_path: str, sig_paths: Sequence[str], *,
                         set_sizes: Optional[np.ndarray],
                         dest: str) -> IndexMeta:
    old = load_index(idx_path, mmap=True)
    om = old.meta
    cfg = om.banding
    shard_words, new_labels, new_keys, _ = _read_sig_group(sig_paths, cfg,
                                                           expect=om)
    n_new = int(new_labels.shape[0])
    set_sizes = _check_set_sizes(set_sizes, n_new)
    if om.has_set_sizes and set_sizes is None:
        raise ValueError("index stores set sizes; append needs set_sizes "
                         "for the new documents")
    if not om.has_set_sizes and set_sizes is not None:
        raise ValueError("index has no set sizes; cannot add them on append")

    new_tables = build_band_tables(new_keys)
    band_offsets, keys, bucket_offsets, postings = merge_band_tables(
        (old.band_offsets, old.keys, old.bucket_offsets, old.postings),
        new_tables, om.n)
    meta = dataclasses.replace(om, n=om.n + n_new,
                               n_keys=int(keys.size))
    arrays = {"labels": np.concatenate([old.labels,
                                        new_labels.astype(np.float32)]),
              "band_offsets": band_offsets, "keys": keys,
              "bucket_offsets": bucket_offsets, "postings": postings}
    if om.has_set_sizes:
        arrays["set_sizes"] = np.concatenate([old.set_sizes, set_sizes])
    tmp = dest + ".tmp"
    _write_index(tmp, meta, arrays, [old.words_host] + shard_words)
    os.replace(tmp, dest)
    return meta


MANIFEST_NAME = "manifest.json"
LOCK_NAME = ".lock"


def sharded_lock(shard_dir: str, **kwargs) -> FileLock:
    """The writer lock for a sharded-index directory -- taken by every
    mutation (``ShardedIndex.append``); readers never take it (manifest
    and shard replacements are atomic)."""
    return FileLock(os.path.join(shard_dir, LOCK_NAME), **kwargs)


def write_manifest(out_dir: str, paths: Sequence[str],
                   counts: Sequence[int], *, generation: int = 0) -> None:
    """Write the shard manifest (names, doc-id offsets, total n) that
    ``repro.index.router.load_sharded`` consumes -- the ONE serializer,
    shared by ``build_sharded`` and ``ShardedIndex.append``.

    ``generation`` is a monotone mutation counter: every live append
    bumps it, and readers (``ShardedIndex.refresh``) re-read the
    manifest and reload only when it moved.  The write is atomic
    (same-directory temp + ``os.replace``), so a reader parsing the
    manifest mid-append sees the old or the new version, never a torn
    JSON.
    """
    offsets = np.cumsum([0] + list(counts))
    manifest = {"version": 1,
                "generation": int(generation),
                "shards": [os.path.basename(p) for p in paths],
                "offsets": [int(o) for o in offsets[:-1]],
                "n": int(offsets[-1])}
    dest = os.path.join(out_dir, MANIFEST_NAME)
    tmp = f"{dest}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(manifest, f, indent=2)
    os.replace(tmp, dest)


def read_manifest(shard_dir: str) -> dict:
    """Read + validate ``manifest.json`` (the reader side of
    ``write_manifest``; ``generation`` defaults to 0 for manifests
    written before live appends existed)."""
    man_path = os.path.join(shard_dir, MANIFEST_NAME)
    with open(man_path) as f:
        manifest = json.load(f)
    if manifest.get("version") != 1:
        raise ValueError(f"{man_path}: unsupported manifest version "
                         f"{manifest.get('version')}")
    manifest.setdefault("generation", 0)
    return manifest


def build_sharded(sig_paths: Sequence[str], out_dir: str, cfg: BandingConfig,
                  *, n_shards: int, set_sizes: Optional[np.ndarray] = None,
                  s: int = 0) -> List[Tuple[str, IndexMeta]]:
    """Split ``.sig`` shards into ``n_shards`` contiguous ``.idx`` files.

    Documents keep their global order: index shard i holds the doc-id
    range ``[offsets[i], offsets[i+1])``, so a router over the shards can
    translate local top-k hits back to global ids.  Writes
    ``shard_%05d.idx`` plus a ``manifest.json`` (shard names, doc-id
    offsets, total n) that ``repro.index.router.load_sharded`` consumes.
    Splits at ``.sig``-file granularity, balancing document counts.
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    if n_shards > len(sig_paths):
        raise ValueError(f"n_shards={n_shards} > {len(sig_paths)} .sig "
                         "shards (splits are at .sig-file granularity)")
    counts = [read_sig_meta(p).n for p in sig_paths]
    total = sum(counts)
    # contiguous near-even split by document count: each group takes
    # files until the cumulative count reaches its share, leaving at
    # least one file for every later group
    groups: List[List[str]] = []
    group_counts: List[int] = []
    i = cum = 0
    for g in range(n_shards):
        take_max = (len(sig_paths) - i) - (n_shards - g - 1)
        target_cum = total * (g + 1) / n_shards
        cur: List[str] = []
        cur_n = 0
        while len(cur) < take_max and (not cur or cum + cur_n < target_cum):
            cur.append(sig_paths[i])
            cur_n += counts[i]
            i += 1
        groups.append(cur)
        group_counts.append(cur_n)
        cum += cur_n
    assert i == len(sig_paths) and all(groups)

    os.makedirs(out_dir, exist_ok=True)
    out: List[Tuple[str, IndexMeta]] = []
    doc0 = 0
    for i, group in enumerate(groups):
        path = os.path.join(out_dir, f"shard_{i:05d}.idx")
        n_i = group_counts[i]
        sizes_i = (None if set_sizes is None
                   else np.asarray(set_sizes)[doc0:doc0 + n_i])
        meta = build_index(group, path, cfg, set_sizes=sizes_i, s=s)
        assert meta.n == n_i, (meta.n, n_i)
        out.append((path, meta))
        doc0 += n_i
    write_manifest(out_dir, [p for p, _ in out], group_counts)
    return out


# ---------------------------------------------------------------------------
# Load / query-side container
# ---------------------------------------------------------------------------

def read_index_meta(path: str) -> IndexMeta:
    with open(path, "rb") as f:
        head = f.read(HEADER_BYTES)
    if len(head) < HEADER_BYTES or head[:4] != MAGIC:
        raise ValueError(f"{path}: not a .idx index (bad magic)")
    (version, n, k, b, code_bits, words, flags, n_bands, rows_per_band,
     n_keys, s) = struct.unpack("<11I", head[4:48])
    if version != VERSION:
        raise ValueError(f"{path}: unsupported .idx version {version} "
                         f"(this build reads version {VERSION})")
    return IndexMeta(n=n, k=k, b=b, code_bits=code_bits, words=words,
                     sentinel=bool(flags & _FLAG_SENTINEL),
                     has_set_sizes=bool(flags & _FLAG_SET_SIZES),
                     n_bands=n_bands, rows_per_band=rows_per_band,
                     n_keys=n_keys, s=s)


@dataclasses.dataclass
class SigIndex:
    """A loaded ``.idx``: mmap'd bucket tables + packed corpus payload.

    ``words_host`` stays packed ((n, words) uint32 -- the host never
    holds an unpacked corpus); ``corpus`` uploads it to the device once,
    on first use, for kernel scoring.
    """

    meta: IndexMeta
    labels: np.ndarray
    set_sizes: Optional[np.ndarray]
    band_offsets: np.ndarray
    keys: np.ndarray
    bucket_offsets: np.ndarray
    postings: np.ndarray
    words_host: np.ndarray
    _corpus = None

    @property
    def spec(self) -> PackSpec:
        return self.meta.spec

    @property
    def banding(self) -> BandingConfig:
        return self.meta.banding

    @property
    def n(self) -> int:
        return self.meta.n

    @property
    def corpus(self):
        """Device-resident packed signature matrix (uploaded once)."""
        if self._corpus is None:
            self._corpus = jnp.asarray(np.ascontiguousarray(self.words_host))
        return self._corpus

    def bucket(self, band: int, key: int) -> np.ndarray:
        """Posting list (ascending doc ids) for one (band, key) bucket."""
        lo, hi = self.band_offsets[band], self.band_offsets[band + 1]
        t = lo + np.searchsorted(self.keys[lo:hi], key)
        if t == hi or self.keys[t] != key:
            return np.zeros(0, np.uint32)
        return self.postings[self.bucket_offsets[t]:self.bucket_offsets[t + 1]]

    def candidates(self, query_keys: np.ndarray) -> np.ndarray:
        """Union of posting lists over all bands for one query's keys."""
        return self.candidates_batch(np.asarray(query_keys)[None, :])[0]

    def candidates_batch(self, query_keys: np.ndarray) -> List[np.ndarray]:
        """Per-query candidate unions for a (Q, n_bands) key batch.

        One vectorized ``np.searchsorted`` per band over the whole query
        batch (instead of one binary search per (query, band) pair), then
        per-query posting-list unions -- the batched admission path's
        candidate generator.
        """
        query_keys = np.asarray(query_keys)
        q = query_keys.shape[0]
        hits: List[List[np.ndarray]] = [[] for _ in range(q)]
        for band in range(self.meta.n_bands):
            lo, hi = int(self.band_offsets[band]), \
                int(self.band_offsets[band + 1])
            band_keys = self.keys[lo:hi]
            if band_keys.size == 0:
                continue
            pos = np.searchsorted(band_keys, query_keys[:, band])
            found = pos < band_keys.size
            found[found] = (band_keys[pos[found]]
                            == query_keys[found, band])
            for qi in np.nonzero(found)[0]:
                t = lo + pos[qi]
                hits[qi].append(self.postings[
                    self.bucket_offsets[t]:self.bucket_offsets[t + 1]])
        return [np.unique(np.concatenate(h)).astype(np.int64) if h
                else np.zeros(0, np.int64) for h in hits]


def load_index(path: str, *, mmap: bool = True) -> SigIndex:
    """Read a ``.idx`` back; ``mmap=True`` serves straight off disk."""
    meta = read_index_meta(path)
    offsets = _section_offsets(meta)
    out = {}
    for name, dtype, count in _sections(meta):
        if mmap:
            out[name] = np.memmap(path, dtype, "r", offset=offsets[name],
                                  shape=(count,))
        else:
            with open(path, "rb") as f:
                f.seek(offsets[name])
                arr = np.fromfile(f, dtype, count)
            if arr.size != count:
                raise OSError(f"{path}: truncated .idx section {name}")
            out[name] = arr
    return SigIndex(
        meta=meta, labels=np.asarray(out["labels"]),
        set_sizes=(np.asarray(out["set_sizes"])
                   if meta.has_set_sizes else None),
        band_offsets=np.asarray(out["band_offsets"]),
        keys=np.asarray(out["keys"]),
        bucket_offsets=np.asarray(out["bucket_offsets"]),
        postings=np.asarray(out["postings"]),
        words_host=out["payload"].reshape(meta.n, meta.words))
