"""Resilient shard dispatch: deadlines, retries, hedging, breakers.

``ResilientShardClient`` wraps any ``ShardClient`` (local, socket, or
chaos-injected) and makes its ``dispatch`` survive a faulty transport:

  * **deadline** -- each attempt runs in its own thread and the
    harvest waits at most ``policy.deadline_s`` past the attempt's
    launch; a blown deadline abandons the attempt (threads cannot be
    killed, so cancellation is best-effort -- per-dispatch sockets
    make the abandoned side harmless) and counts as a failure.  With
    no deadline and no hedge (the default policy) dispatch takes a
    threadless synchronous path instead, so the healthy fast path is
    a near-zero-cost pass-through,
  * **retry** -- up to ``policy.max_retries`` relaunches on retryable
    errors (``OSError`` by default, which covers timeouts and every
    ``TransportError``), separated by exponential backoff with
    decorrelated jitter, each under a ``retry`` trace span,
  * **hedge** -- optionally a second dispatch fires when the first is
    slower than the client's EWMA latency estimate plus ``k`` absolute
    deviations (a cheap p99 proxy); first result wins, the loser is
    abandoned, and ``shard_hedges_total{outcome}`` records who won,
  * **breaker** -- consecutive attempt failures open a circuit that
    short-circuits dispatches with ``CircuitOpenError`` *without
    touching the transport*; after ``breaker_reset_s`` one probe
    dispatch half-opens it, and a success closes it.  State lives in
    the ``shard_breaker_state`` gauge (0 closed / 1 half-open /
    2 open) and every transition emits a ``breaker`` trace span.

``ChaosShardClient`` is the deterministic fault injector the chaos
tests and the degraded-mode benchmark rows drive: a seeded schedule
draws, per ``dispatch`` call in call order, one of
``latency`` (slow-but-correct), ``oserror`` (dispatch raises),
``hang`` (slower than any reasonable deadline, then returns), or
``drop`` (connection dies mid-response), and logs the draw in
``fault_log`` so two runs of the same seed are byte-for-byte
comparable.
"""

from __future__ import annotations

import dataclasses
import queue
import random
import threading
import time
from typing import Callable, Optional, Tuple

import numpy as np

from repro.index.query import SearchResult
from repro.index.router import LocalShardClient, ShardClient
from repro.obs.metrics import get_registry
from repro.obs.trace import get_tracer

__all__ = ["CircuitOpenError", "ShardDispatchTimeout", "ResiliencePolicy",
           "ResilientShardClient", "ChaosSchedule", "ChaosShardClient",
           "resilient_client_factory"]

_BREAKER_GAUGE = {"closed": 0, "half_open": 1, "open": 2}


class CircuitOpenError(RuntimeError):
    """Dispatch short-circuited: the shard's breaker is open."""


class ShardDispatchTimeout(TimeoutError):
    """An attempt outlived ``policy.deadline_s`` and was abandoned."""


@dataclasses.dataclass(frozen=True)
class ResiliencePolicy:
    """Knobs for one shard client's fault handling.

    ``deadline_s`` is **per attempt** (a dispatch with retries may take
    up to ``(max_retries + 1) * deadline_s`` plus backoff).  ``None``
    disables the deadline (and hedging's timeout arm).
    """
    deadline_s: Optional[float] = None
    max_retries: int = 2
    backoff_base_s: float = 0.01
    backoff_cap_s: float = 1.0
    hedge: bool = False
    hedge_k: float = 4.0              # delay = EWMA mean + k * EWMA |dev|
    hedge_min_s: float = 0.001
    hedge_max_s: float = 0.25
    breaker_failures: int = 5         # consecutive failures that open it
    breaker_reset_s: float = 1.0      # open -> half-open probe delay
    retryable: Tuple[type, ...] = (OSError,)


class _Breaker:
    """closed -> open -> half-open state machine, one per shard."""

    def __init__(self, policy: ResiliencePolicy, clock,
                 on_transition: Callable[[str, str], None]):
        self.policy = policy
        self._clock = clock
        self._on_transition = on_transition
        self._lock = threading.Lock()
        self.state = "closed"
        self._failures = 0
        self._opened_at = 0.0
        self._probing = False

    def _move(self, new: str) -> None:
        old, self.state = self.state, new
        if old != new:
            self._on_transition(old, new)

    def admit(self) -> None:
        """Gate one dispatch; raises ``CircuitOpenError`` when open."""
        with self._lock:
            if self.state == "closed":
                return
            if self.state == "open":
                if (self._clock() - self._opened_at
                        < self.policy.breaker_reset_s):
                    raise CircuitOpenError(
                        "circuit open; next probe in "
                        f"{self.policy.breaker_reset_s:.3f}s")
                self._move("half_open")      # this dispatch is the probe
                self._probing = True
                return
            # half-open: exactly one probe in flight
            if self._probing:
                raise CircuitOpenError("circuit half-open; probe in flight")
            self._probing = True

    def record_success(self) -> None:
        with self._lock:
            self._failures = 0
            self._probing = False
            if self.state != "closed":
                self._move("closed")

    def record_failure(self) -> None:
        with self._lock:
            self._failures += 1
            if self.state == "half_open":
                self._probing = False
                self._opened_at = self._clock()
                self._move("open")
            elif (self.state == "closed"
                    and self._failures >= self.policy.breaker_failures):
                self._opened_at = self._clock()
                self._move("open")


class ResilientShardClient(ShardClient):
    """Deadline + retry + hedge + breaker around an inner client.

    ``clock`` / ``sleep`` / ``rng`` are injectable for deterministic
    tests.  Metrics land in ``registry`` (default: the process
    registry) under the ``shard`` label; breaker transitions and
    retry/hedge activity emit spans on ``tracer`` when enabled.
    """

    def __init__(self, inner: ShardClient,
                 policy: ResiliencePolicy = ResiliencePolicy(), *,
                 shard: str = "0", registry=None, tracer=None,
                 clock=time.monotonic, sleep=time.sleep,
                 rng: Optional[random.Random] = None):
        self.inner = inner
        self.policy = policy
        self.shard = str(shard)
        self._clock = clock
        self._sleep = sleep
        self._rng = rng if rng is not None else random.Random()
        self._tracer = tracer
        self._lock = threading.Lock()
        self._ewma_m: Optional[float] = None   # mean latency
        self._ewma_d = 0.0                     # mean |deviation|
        reg = registry if registry is not None else get_registry()
        lbl = {"shard": self.shard}
        self._m_retries = reg.counter(
            "shard_dispatch_retries_total",
            "dispatch attempts relaunched after a retryable failure",
            labels=("shard",)).labels(**lbl)
        self._m_failures = reg.counter(
            "shard_dispatch_failures_total",
            "shard dispatch attempts that failed (incl. timeouts)",
            labels=("shard",)).labels(**lbl)
        self._m_timeouts = reg.counter(
            "shard_dispatch_timeouts_total",
            "attempts abandoned past the per-attempt deadline",
            labels=("shard",)).labels(**lbl)
        self._m_hedges = reg.counter(
            "shard_hedges_total",
            "hedged dispatches by outcome (win = hedge finished first)",
            labels=("shard", "outcome"))
        self._g_breaker = reg.gauge(
            "shard_breaker_state",
            "circuit state: 0 closed, 1 half-open, 2 open",
            labels=("shard",)).labels(**lbl)
        self._g_breaker.set(0.0)
        self.breaker = _Breaker(policy, clock, self._on_breaker)

    # -- observability ---------------------------------------------------
    def _tr(self):
        return self._tracer if self._tracer is not None else get_tracer()

    def _on_breaker(self, old: str, new: str) -> None:
        self._g_breaker.set(float(_BREAKER_GAUGE[new]))
        t = time.perf_counter()
        self._tr().add_span("breaker", t, t,
                            args={"shard": self.shard, "from": old,
                                  "to": new})

    def _observe_latency(self, dt: float) -> None:
        with self._lock:
            if self._ewma_m is None:
                self._ewma_m, self._ewma_d = dt, dt / 2.0
            else:
                self._ewma_m += 0.2 * (dt - self._ewma_m)
                self._ewma_d += 0.2 * (abs(dt - self._ewma_m)
                                       - self._ewma_d)

    def _hedge_delay(self) -> float:
        with self._lock:
            if self._ewma_m is None:
                return self.policy.hedge_max_s
            est = self._ewma_m + self.policy.hedge_k * self._ewma_d
        return min(self.policy.hedge_max_s,
                   max(self.policy.hedge_min_s, est))

    # -- ShardClient -----------------------------------------------------
    @property
    def n(self) -> int:
        return self.inner.n

    def _launch(self, call_q: "queue.Queue", kind: str, qwords, topk,
                mode, query_sizes, qkeys) -> None:
        def run():
            t0 = self._clock()
            try:
                res = self.inner.dispatch(qwords, topk, mode=mode,
                                          query_sizes=query_sizes,
                                          qkeys=qkeys)()
                call_q.put((kind, res, None, self._clock() - t0))
            except BaseException as e:
                call_q.put((kind, None, e, self._clock() - t0))
        threading.Thread(target=run, daemon=True,
                         name=f"shard{self.shard}-{kind}").start()

    def dispatch(self, qwords, topk: int, *, mode: str = "exact",
                 query_sizes=None,
                 qkeys=None) -> Callable[[], SearchResult]:
        self.breaker.admit()                 # CircuitOpenError when open
        args = (qwords, topk, mode, query_sizes, qkeys)
        if self.policy.deadline_s is None and not self.policy.hedge:
            # no timers to race: skip the attempt threads entirely so
            # the healthy path stays a near-zero-cost pass-through
            return self._dispatch_sync(args)
        call_q: "queue.Queue" = queue.Queue()
        self._launch(call_q, "primary", qwords, topk, mode, query_sizes,
                     qkeys)
        return lambda: self._harvest(call_q, args)

    def _dispatch_sync(self, args) -> Callable[[], SearchResult]:
        """Threadless dispatch+retry (no deadline, no hedge).  The inner
        dispatch still fires eagerly so cross-shard overlap survives;
        failures defer to the harvest, where the retry loop lives."""
        qwords, topk, mode, query_sizes, qkeys = args
        t0 = self._clock()
        pending: Optional[Callable[[], SearchResult]] = None
        err: Optional[BaseException] = None
        try:
            pending = self.inner.dispatch(qwords, topk, mode=mode,
                                          query_sizes=query_sizes,
                                          qkeys=qkeys)
        except BaseException as e:
            err = e

        def harvest() -> SearchResult:
            nonlocal t0, pending, err
            tracer = self._tr()
            retries = 0
            while True:
                if err is None:
                    try:
                        res = pending()
                        self.breaker.record_success()
                        self._observe_latency(self._clock() - t0)
                        return res
                    except BaseException as e:
                        err = e
                self._attempt_failed(err)
                if (not isinstance(err, self.policy.retryable)
                        or retries >= self.policy.max_retries):
                    raise err
                retries += 1
                self._m_retries.inc()
                with tracer.span("retry",
                                 args={"shard": self.shard,
                                       "attempt": retries,
                                       "error": type(err).__name__}):
                    self._backoff_sleep()
                t0 = self._clock()
                err = None
                try:
                    pending = self.inner.dispatch(
                        qwords, topk, mode=mode, query_sizes=query_sizes,
                        qkeys=qkeys)
                except BaseException as e:
                    err = e
        return harvest

    def _attempt_failed(self, err: BaseException) -> None:
        self._m_failures.inc()
        self.breaker.record_failure()

    def _backoff_sleep(self) -> None:
        # decorrelated jitter: sleep ~ U(base, 3 * prev), capped
        prev = getattr(self, "_last_backoff_s", self.policy.backoff_base_s)
        backoff = min(self.policy.backoff_cap_s,
                      self._rng.uniform(self.policy.backoff_base_s,
                                        prev * 3.0))
        self._last_backoff_s = backoff
        self._sleep(backoff)

    def _harvest(self, call_q: "queue.Queue", args) -> SearchResult:
        qwords, topk, mode, query_sizes, qkeys = args
        policy = self.policy
        tracer = self._tr()
        retries = 0
        inflight = 1
        hedged = False
        t_last_launch = self._clock()
        t_hedge = None
        last_err: Optional[BaseException] = None
        while True:
            # When does the wait expire?  Hedge arm first (if armed),
            # then the per-attempt deadline of the newest attempt.
            hedge_arm = (policy.hedge and not hedged and retries == 0
                         and inflight == 1)
            now = self._clock()
            deadline_left = (None if policy.deadline_s is None
                             else t_last_launch + policy.deadline_s - now)
            if hedge_arm:
                wait = self._hedge_delay()
                if deadline_left is not None:
                    wait = min(wait, deadline_left)
            else:
                wait = deadline_left
            if wait is not None and wait < 0.0:
                wait = 0.0
            try:
                kind, res, err, dt = call_q.get(timeout=wait)
            except queue.Empty:
                if hedge_arm and (deadline_left is None
                                  or self._clock() - t_last_launch
                                  < policy.deadline_s):
                    hedged = True
                    t_hedge = self._clock()
                    inflight += 1
                    t_last_launch = t_hedge
                    self._launch(call_q, "hedge", *args)
                    continue
                # per-attempt deadline blown: abandon what's in flight
                self._m_timeouts.inc()
                last_err = ShardDispatchTimeout(
                    f"shard {self.shard} dispatch exceeded "
                    f"{policy.deadline_s:.3f}s "
                    f"({inflight} attempt(s) abandoned)")
                self._attempt_failed(last_err)
                inflight = 0
            else:
                inflight -= 1
                if err is None:
                    self.breaker.record_success()
                    self._observe_latency(dt)
                    if hedged:
                        outcome = "win" if kind == "hedge" else "loss"
                        self._m_hedges.labels(shard=self.shard,
                                              outcome=outcome).inc()
                        tracer.add_span(
                            "hedge", t_hedge, self._clock(),
                            args={"shard": self.shard,
                                  "outcome": outcome})
                    return res
                self._attempt_failed(err)
                last_err = err
                if not isinstance(err, policy.retryable):
                    raise err
                if inflight > 0:
                    continue                 # the hedge twin may still win
            # no attempt left in flight: retry or give up
            if retries >= policy.max_retries:
                raise last_err
            retries += 1
            self._m_retries.inc()
            with tracer.span("retry",
                             args={"shard": self.shard,
                                   "attempt": retries,
                                   "error": type(last_err).__name__}):
                self._backoff_sleep()
            inflight = 1
            t_last_launch = self._clock()
            self._launch(call_q, f"retry{retries}", *args)


# -- deterministic fault injection --------------------------------------

@dataclasses.dataclass(frozen=True)
class ChaosSchedule:
    """Seeded per-dispatch fault plan.

    Each ``dispatch`` draws once, in call order, under a lock: with
    probability ``fault_rate`` one of ``faults`` fires, else the call
    passes through.  Same seed + same call sequence => identical
    draws, independent of wall-clock timing.
    """
    seed: int = 0
    fault_rate: float = 0.25
    faults: Tuple[str, ...] = ("latency", "oserror", "hang", "drop")
    latency_s: float = 0.01           # injected slow-but-fine delay
    hang_s: float = 0.5               # "hang": slower than any deadline


class ChaosShardClient(ShardClient):
    """Fault-injecting ``ShardClient`` wrapper (see ``ChaosSchedule``).

    ``fault_log`` records ``(call_index, kind_or_None)`` per dispatch;
    the seeded-determinism test pins it across runs.
    """

    def __init__(self, inner: ShardClient, schedule: ChaosSchedule, *,
                 sleep=time.sleep):
        self.inner = inner
        self.schedule = schedule
        self._sleep = sleep
        self._rng = np.random.default_rng(schedule.seed)
        self._lock = threading.Lock()
        self._calls = 0
        self.fault_log: list = []

    @property
    def n(self) -> int:
        return self.inner.n

    def _draw(self) -> Optional[str]:
        with self._lock:
            i = self._calls
            self._calls += 1
            kind = None
            if float(self._rng.random()) < self.schedule.fault_rate:
                kind = self.schedule.faults[
                    int(self._rng.integers(len(self.schedule.faults)))]
            self.fault_log.append((i, kind))
            return kind

    def dispatch(self, qwords, topk: int, *, mode: str = "exact",
                 query_sizes=None,
                 qkeys=None) -> Callable[[], SearchResult]:
        kind = self._draw()
        if kind == "oserror":
            raise OSError("chaos: injected I/O fault")
        inner_harvest = self.inner.dispatch(qwords, topk, mode=mode,
                                            query_sizes=query_sizes,
                                            qkeys=qkeys)
        if kind is None:
            return inner_harvest

        def harvest() -> SearchResult:
            if kind == "drop":
                inner_harvest()
                raise ConnectionResetError(
                    "chaos: connection dropped mid-response")
            # latency / hang: slow but eventually correct -- a hang is
            # just latency longer than any sane deadline.
            self._sleep(self.schedule.latency_s if kind == "latency"
                        else self.schedule.hang_s)
            return inner_harvest()
        return harvest


def resilient_client_factory(policy: ResiliencePolicy = ResiliencePolicy(),
                             *, inner_factory=None, chaos=None,
                             registry=None, tracer=None,
                             clock=time.monotonic, sleep=time.sleep,
                             seed: Optional[int] = None):
    """``client_factory=`` helper stacking resilience (and optionally
    chaos) over per-shard inner clients.

    Shard ids are assigned in construction order (the router builds
    clients in shard order).  ``chaos`` is a ``ChaosSchedule``, or a
    callable ``shard_index -> ChaosSchedule | None`` for per-shard
    schedules.  The factory keeps ``.clients`` / ``.chaos_clients``
    for inspection.
    """
    def factory(searcher) -> ResilientShardClient:
        i = len(factory.clients)
        inner = (inner_factory or LocalShardClient)(searcher)
        if chaos is not None:
            sched = chaos(i) if callable(chaos) else chaos
            if sched is not None:
                inner = ChaosShardClient(inner, sched, sleep=sleep)
                factory.chaos_clients.append(inner)
        rng = random.Random(seed + i) if seed is not None else None
        client = ResilientShardClient(inner, policy, shard=str(i),
                                      registry=registry, tracer=tracer,
                                      clock=clock, sleep=sleep, rng=rng)
        factory.clients.append(client)
        return client

    factory.clients = []
    factory.chaos_clients = []
    return factory
