"""Query paths over a loaded ``.idx``: exact top-k and LSH + rerank.

One searcher, two serving paths sharing the scoring kernel and the
estimator rerank:

  * ``mode="exact"``  -- kernel brute force, fused into ONE traced
    computation per call: a ``jax.lax.fori_loop`` over fixed-size corpus
    blocks of the device-resident packed matrix runs the packed-Hamming
    kernel (``repro.kernels.hamming.packed_match``), debiases the match
    counts into resemblance estimates (Theorem 1) and carries the running
    top-k ``(best_s, best_i)`` *inside the jit* -- one dispatch per
    ``flush()`` instead of one per block, cached on
    (query batch, corpus shape, topk, block) so repeated flushes never
    retrace.  Exact in the sense of "exact over the signatures": the
    b-bit estimator itself is still an estimator.

    Corpora larger than the configured device window
    (``max_device_bytes``) never become device-resident at all: block
    windows stream straight off the mmap'd ``.idx`` packed payload
    through a double-buffered ``device_put`` pipeline
    (``repro.data.pipeline.device_put_iter``), overlapping the H2D copy
    of window i+1 with the fused scan over window i; the top-k carry
    threads across windows, so the result is bit-identical to the
    in-core scan.

  * ``mode="lsh"``    -- candidate generation through the banded bucket
    tables (one batched ``np.searchsorted`` per band over the mmap'd
    sorted key arrays -- ``SigIndex.candidates_batch``), then one kernel
    launch over the batch's candidate union with non-candidates masked
    out, then the same estimator rerank.  With ``lsh_batch`` set, a
    flush is split into sub-batches whose kernel reranks are dispatched
    asynchronously: host candidate generation for sub-batch i+1 overlaps
    the device rerank of sub-batch i, and results are harvested once at
    the end.  The S-curve (``repro.index.banding``) predicts the
    recall/selectivity trade the band config buys.

Batched query admission: ``submit`` queues single queries, ``flush``
runs them as one batch (one traced computation / one candidate union)
and returns per-ticket results -- the serving-launcher entry point
(``repro.launch.serve --index``).

Scores are resemblance estimates: the Li-Owen-Zhang normalization for
sentinel wires (matches / (k - jointly_empty)) and the Theorem-1
debiasing -- exact per-pair constants when the index stores set sizes
and the universe size, the sparse-limit constants (C1 = C2 = 2^-b)
otherwise.  Both debiasings are strictly monotone in the collision
fraction, so rankings do not depend on which one applies.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.estimator import bbit_constants
from repro.data.pipeline import device_put_iter
from repro.index.banding import band_keys_packed
from repro.index.builder import SigIndex
from repro.kernels import PackedSignatures, packed_match
from repro.kernels.hamming import _packed_match_run
from repro.obs.metrics import get_registry
from repro.obs.trace import get_tracer

# jit-retrace accounting, read by tests: a second flush with the same
# (query batch, corpus window, topk, block) must be a jit-cache hit.
# Lives in the metrics registry (scrapeable while serving); the mapping
# below names the registry counter behind each legacy TRACE_COUNTS key.
_TRACE_METRICS = {"exact_scan": "index_exact_scan_retraces_total"}


class _TraceCounts:
    """Backward-compat, dict-like view over the registry retrace
    counters -- the old module-global mutable ``TRACE_COUNTS`` dict.

    Reads resolve the live registry counter (so ``set_registry`` /
    ``registry.reset()`` behave); writes only move forward (``+= n``
    increments the counter -- counters cannot go down; zero them via
    ``repro.obs.get_registry().reset()``).
    """

    @staticmethod
    def _family(key: str):
        return get_registry().counter(
            _TRACE_METRICS[key],
            "jit retraces of the fused exact scan (0 on a cache hit)")

    def __getitem__(self, key: str) -> int:
        return int(self._family(key).value)

    def __setitem__(self, key: str, value: int) -> None:
        fam = self._family(key)
        delta = value - fam.value
        if delta < 0:
            raise ValueError(
                f"TRACE_COUNTS[{key!r}] only goes up (registry counter); "
                f"reset via repro.obs.get_registry().reset()")
        fam.inc(delta)

    def __iter__(self):
        return iter(_TRACE_METRICS)

    def __contains__(self, key) -> bool:
        return key in _TRACE_METRICS

    def __len__(self) -> int:
        return len(_TRACE_METRICS)

    def keys(self):
        return _TRACE_METRICS.keys()

    def get(self, key, default=None):
        return self[key] if key in _TRACE_METRICS else default


TRACE_COUNTS = _TraceCounts()


def resemblance_scores(matches: jax.Array, both_empty: Optional[jax.Array],
                       k: int, b: int, *,
                       query_sizes: Optional[jax.Array] = None,
                       doc_sizes: Optional[jax.Array] = None,
                       D: int = 0) -> jax.Array:
    """(Q, N) match counts -> (Q, N) float32 resemblance estimates.

    ``both_empty`` applies the Li-Owen-Zhang denominator for sentinel
    wires; the Theorem-1 debias uses exact (C1, C2) when per-document
    set sizes and the universe size are known, the sparse-limit
    constants 2^-b otherwise.
    """
    matches = matches.astype(jnp.float32)
    if both_empty is not None:
        denom = jnp.maximum(k - both_empty.astype(jnp.float32), 1.0)
        p_hat = matches / denom
    else:
        # constant divisor: multiply by the f32 reciprocal explicitly --
        # XLA strength-reduces constant divisions to reciprocal multiplies
        # inside a jit, and the eager path must stay bit-identical to the
        # fused in-jit scan
        p_hat = matches * jnp.float32(1.0 / k)
    if query_sizes is not None and doc_sizes is not None and D:
        c = bbit_constants(jnp.asarray(query_sizes)[:, None],
                           jnp.asarray(doc_sizes)[None, :], D, b)
        return (p_hat - c.C1) / (1.0 - c.C2)
    c1 = float(2.0 ** -b)
    return (p_hat - jnp.float32(c1)) * jnp.float32(1.0 / (1.0 - c1))


@dataclasses.dataclass(frozen=True)
class StreamPlan:
    """Sizing of the out-of-core exact scan, honoring the device budget.

    ``inflight`` windows can be device-resident at once: the one being
    scanned, up to ``prefetch`` queued in the H2D pipeline, and one held
    by the producer thread while the queue is full -- so
    ``inflight * window_bytes <= max_device_bytes`` whenever the budget
    admits at least one corpus row per window (the hard floor).
    """

    window: int        # rows per streamed window (multiple of block)
    block: int         # scan block height (<= the searcher's corpus_block)
    prefetch: int      # H2D pipeline depth actually used
    row_bytes: int

    @property
    def inflight(self) -> int:
        return self.prefetch + 2

    @property
    def window_bytes(self) -> int:
        return self.window * self.row_bytes

    @property
    def resident_bytes(self) -> int:
        """Worst-case device bytes held by streamed corpus windows."""
        return self.inflight * self.window_bytes


@dataclasses.dataclass
class SearchResult:
    """Top-k per query: global doc ids (-1 past the candidate count) and
    their resemblance estimates (-inf where the id is -1).

    ``coverage`` / ``failed_shards`` carry the router's degraded-mode
    accounting (``on_shard_failure="partial"``): the fraction of corpus
    docs actually searched and the shard indices that failed.  A full
    healthy search leaves them at their defaults.
    """

    indices: np.ndarray          # (Q, topk) int64
    scores: np.ndarray           # (Q, topk) float32
    n_candidates: Optional[np.ndarray] = None    # (Q,) for the LSH path
    coverage: float = 1.0        # docs searched / docs total
    failed_shards: Tuple[int, ...] = ()

    def __len__(self) -> int:
        return self.indices.shape[0]


def _query_words(queries, spec) -> jax.Array:
    if isinstance(queries, PackedSignatures):
        if (queries.k, queries.b, queries.sentinel) != \
                (spec.k, spec.b, spec.sentinel):
            raise ValueError(
                f"query wire (k={queries.k}, b={queries.b}, "
                f"sentinel={queries.sentinel}) != index wire (k={spec.k}, "
                f"b={spec.b}, sentinel={spec.sentinel})")
        return queries.data
    words = jnp.asarray(queries)
    if words.ndim != 2 or words.shape[1] != spec.words:
        raise ValueError(f"raw queries must be (Q, {spec.words}) uint32 "
                         f"packed words, got {words.shape}")
    return words


@jax.jit
def _topk_merge(best_s, best_i, sc, ids):
    """Running top-k merge: [best so far || block scores] -> new best.

    Ties break toward the earlier concatenation position, i.e. toward
    the lowest doc id -- identical to a full-matrix ``lax.top_k``.
    """
    cat_s = jnp.concatenate([best_s, sc], axis=1)
    cat_i = jnp.concatenate(
        [best_i, jnp.broadcast_to(ids[None, :], sc.shape)], axis=1)
    new_s, sel = jax.lax.top_k(cat_s, best_s.shape[1])
    return new_s, jnp.take_along_axis(cat_i, sel, axis=1)


@functools.partial(jax.jit, static_argnames=(
    "n", "block", "k", "b", "code_bits", "sentinel", "backend",
    "blk_q", "blk_n", "blk_k", "D"))
def _exact_scan(qwords, corpus, best_s, best_i, id_start, q_sizes, doc_sizes,
                *, n, block, k, b, code_bits, sentinel, backend,
                blk_q, blk_n, blk_k, D):
    """ONE traced computation: fori_loop over ``corpus``'s blocks with the
    running top-k carried inside the jit.

    ``corpus`` is a (rows, words) device window whose row count is a
    multiple of ``block``; ``id_start`` (traced) is the window's global
    doc offset, so the same executable serves every window of a streamed
    out-of-core scan.  Rows with global id >= ``n`` are padding and are
    masked to -inf before the merge.
    """
    TRACE_COUNTS["exact_scan"] += 1
    n_blocks = corpus.shape[0] // block

    def body(t, carry):
        best_s, best_i = carry
        cblk = jax.lax.dynamic_slice_in_dim(corpus, t * block, block, axis=0)
        ids = id_start + t * block + jnp.arange(block, dtype=jnp.int32)
        out = _packed_match_run(qwords, cblk, k=k, code_bits=code_bits,
                                sentinel=sentinel, backend=backend,
                                blk_q=blk_q, blk_n=blk_n, blk_k=blk_k)
        matches, both_empty = out if sentinel else (out, None)
        if doc_sizes is not None:
            dsz = jnp.take(doc_sizes,
                           jnp.minimum(ids, doc_sizes.shape[0] - 1))
            sc = resemblance_scores(matches, both_empty, k, b,
                                    query_sizes=q_sizes, doc_sizes=dsz, D=D)
        else:
            sc = resemblance_scores(matches, both_empty, k, b)
        sc = jnp.where(ids[None, :] < n, sc, -jnp.inf)
        return _topk_merge(best_s, best_i, sc, ids)

    return jax.lax.fori_loop(0, n_blocks, body, (best_s, best_i))


def exact_scan_ids(qwords, corpus, ids, q_sizes, doc_sizes, *, block, k, b,
                   code_bits, sentinel, backend, blk_q, blk_n, blk_k, D,
                   topk):
    """Blocked exact scan over a corpus slice carrying *explicit* global
    doc ids (-1 marks a padding row) -- the per-device body of the mesh
    fan-out (``repro.index.router``).

    Unlike ``_exact_scan``, row identity comes from the ``ids`` operand
    rather than ``id_start + position``: the mesh dispatcher stacks the
    shards assigned to one device (round-robin placement interleaves
    non-adjacent global ranges) into a single padded corpus whose rows
    are in ascending-global-id order per device, so the in-jit
    ``lax.top_k`` tie rule still resolves to the lowest global id within
    the device.  Not jitted here: callers trace it inside their own
    ``shard_map``/``jit``.
    """
    q = qwords.shape[0]
    n_blocks = corpus.shape[0] // block
    best_s = jnp.full((q, topk), -jnp.inf, jnp.float32)
    best_i = jnp.full((q, topk), -1, jnp.int32)

    def body(t, carry):
        best_s, best_i = carry
        cblk = jax.lax.dynamic_slice_in_dim(corpus, t * block, block, axis=0)
        idblk = jax.lax.dynamic_slice_in_dim(ids, t * block, block, axis=0)
        out = _packed_match_run(qwords, cblk, k=k, code_bits=code_bits,
                                sentinel=sentinel, backend=backend,
                                blk_q=blk_q, blk_n=blk_n, blk_k=blk_k)
        matches, both_empty = out if sentinel else (out, None)
        if doc_sizes is not None:
            dsz = jax.lax.dynamic_slice_in_dim(doc_sizes, t * block, block,
                                               axis=0)
            sc = resemblance_scores(matches, both_empty, k, b,
                                    query_sizes=q_sizes, doc_sizes=dsz, D=D)
        else:
            sc = resemblance_scores(matches, both_empty, k, b)
        sc = jnp.where(idblk[None, :] >= 0, sc, -jnp.inf)
        return _topk_merge(best_s, best_i, sc, idblk)

    return jax.lax.fori_loop(0, n_blocks, body, (best_s, best_i))


def lsh_rerank_ids(qwords, corpus, ids, cand, member, q_sizes, doc_sizes, *,
                   k, b, code_bits, sentinel, backend, blk_q, blk_n, blk_k,
                   D, topk):
    """Candidate gather + kernel rerank over a corpus slice carrying
    explicit global doc ids -- the per-device body of the mesh LSH
    fan-out (``repro.index.router``).

    ``cand`` is a (C,) padded vector of LOCAL row indices into this
    device's stacked corpus block (ascending global-id order -- the
    ``lax.top_k`` tie rule then resolves to the lowest global id within
    the device, matching the single-index rerank over the ascending-id
    candidate union); ``member`` is the (Q, C) per-query membership
    mask.  Padding slots point at row 0 with ``member`` False, so they
    score -inf and surface id -1.  Scores go through the same kernel +
    estimator pipeline as ``IndexSearcher._lsh_dispatch`` -- elementwise
    identical, so the cross-device ``merge_topk`` fold is bit-identical
    to the per-shard sequential rerank and to a single unsharded index.
    Not jitted here: callers trace it inside their own ``shard_map``.
    """
    cwords = jnp.take(corpus, cand, axis=0)
    out = _packed_match_run(qwords, cwords, k=k, code_bits=code_bits,
                            sentinel=sentinel, backend=backend,
                            blk_q=blk_q, blk_n=blk_n, blk_k=blk_k)
    matches, both_empty = out if sentinel else (out, None)
    if doc_sizes is not None:
        dsz = jnp.take(doc_sizes, cand)
        sc = resemblance_scores(matches, both_empty, k, b,
                                query_sizes=q_sizes, doc_sizes=dsz, D=D)
    else:
        sc = resemblance_scores(matches, both_empty, k, b)
    sc = jnp.where(member, sc, -jnp.inf)
    top_s, sel = jax.lax.top_k(sc, topk)
    gids = jnp.take(ids, cand)
    top_i = jnp.take(gids, sel)
    top_i = jnp.where(jnp.isneginf(top_s), jnp.int32(-1), top_i)
    return top_s, top_i


class _BatchedAdmission:
    """The submit/flush batched-admission protocol, shared by
    ``IndexSearcher`` and the sharded router
    (``repro.index.router.ShardedIndex``).

    Hosts queue single queries with ``submit`` and run the whole queue
    as ONE batch with ``flush``.  Requires the host class to provide
    ``spec`` (the wire format) and ``search``.
    """

    def _admission_init(self) -> None:
        self._pending: List[Tuple[int, jax.Array, Optional[int]]] = []
        self._next_ticket = 0

    def submit(self, query: Union[PackedSignatures, jax.Array, np.ndarray],
               *, query_size: Optional[int] = None) -> int:
        """Queue one query (a single packed row); returns its ticket.

        ``query_size`` (the query set's original nonzero count) feeds
        the exact Theorem-1 rerank on indexes that store set sizes.
        """
        qwords = _query_words(
            query if isinstance(query, PackedSignatures)
            else jnp.asarray(query).reshape(1, -1), self.spec)
        if qwords.shape[0] != 1:
            raise ValueError("submit() takes exactly one query row")
        ticket = self._next_ticket
        self._next_ticket += 1
        self._pending.append((ticket, qwords, query_size))
        return ticket

    def flush(self, topk: int = 10, *, mode: str = "exact"
              ) -> Dict[int, SearchResult]:
        """Run all queued queries as ONE batch; per-ticket results."""
        if not self._pending:
            return {}
        tickets = [t for t, _, _ in self._pending]
        batch = jnp.concatenate([w for _, w, _ in self._pending], axis=0)
        sizes = [sz for _, _, sz in self._pending]
        self._pending = []
        if any(sz is not None for sz in sizes):
            if any(sz is None for sz in sizes):
                raise ValueError("either every submitted query carries a "
                                 "query_size or none does")
            qsizes = np.asarray(sizes, np.uint32)
        else:
            qsizes = None
        with get_tracer().span("search_dispatch",
                               args={"mode": mode, "batch": len(tickets)}):
            res = self.search(batch, topk, mode=mode, query_sizes=qsizes)
        return {t: SearchResult(res.indices[i:i + 1], res.scores[i:i + 1],
                                None if res.n_candidates is None
                                else res.n_candidates[i:i + 1],
                                coverage=res.coverage,
                                failed_shards=res.failed_shards)
                for i, t in enumerate(tickets)}


class IndexSearcher(_BatchedAdmission):
    """Serving front end over one ``SigIndex``.

    ``backend`` picks the kernel execution (SignatureEngine registry);
    ``corpus_block`` is the brute-force block height (fixed, so every
    block reuses one compiled kernel); ``blocks`` overrides the
    TuningTable kernel tile sizes.  ``max_device_bytes`` is the device
    window for the exact path: a packed corpus larger than it is never
    uploaded whole -- block windows stream off the mmap'd payload
    (double-buffered H2D) through the same fused scan.
    ``exact_impl="blockloop"`` selects the pre-fusion per-block host
    loop, kept as the reference for parity tests and the
    ``benchmarks/search_scaling.py`` baseline.  ``lsh_batch`` splits an
    LSH flush into asynchronously-dispatched sub-batches (host candidate
    generation overlaps the previous sub-batch's device rerank).
    """

    def __init__(self, index: SigIndex, *, backend: Optional[str] = None,
                 corpus_block: int = 4096, blocks: Optional[dict] = None,
                 max_device_bytes: Optional[int] = None,
                 exact_impl: str = "fused", lsh_batch: Optional[int] = None,
                 stream_prefetch: int = 2,
                 device: Optional[jax.Device] = None):
        if exact_impl not in ("fused", "blockloop"):
            raise ValueError(f"exact_impl must be 'fused' or 'blockloop', "
                             f"got {exact_impl!r}")
        self.index = index
        self.backend = backend
        # pin this searcher's corpus + kernel work to one device (the
        # sharded router's per-shard placement); None = default device
        self.device = device
        self.blocks = blocks
        self.corpus_block = min(corpus_block, max(index.n, 1))
        self.max_device_bytes = max_device_bytes
        self.exact_impl = exact_impl
        self.lsh_batch = lsh_batch
        self.stream_prefetch = stream_prefetch
        self._admission_init()
        self._corpus_padded = None
        self._doc_sizes_dev = None
        n_pad = ((index.n + self.corpus_block - 1)
                 // self.corpus_block) * self.corpus_block
        self._n_pad = n_pad
        # resolve the kernel execution + tile sizes once; the fused scan,
        # the blockloop reference and the LSH rerank all share them
        from repro.kernels.engine import (HAMMING_BLOCKS,
                                          default_tuning_table,
                                          resolve_backend)
        self._be = resolve_backend(backend).name
        spec = index.spec
        self._kb = dict(blocks or default_tuning_table().lookup(
            self._be, "hamming", spec.k, spec.words) or HAMMING_BLOCKS)

    # -- scoring ---------------------------------------------------------
    @property
    def spec(self):
        return self.index.spec

    @property
    def streamed(self) -> bool:
        """True when the exact path streams windows instead of holding the
        whole packed corpus on device."""
        return (self.max_device_bytes is not None
                and self.index.meta.payload_bytes > self.max_device_bytes)

    def _padded_corpus(self):
        """Device corpus padded to a block multiple (computed once)."""
        if self._corpus_padded is None:
            corpus = self.index.corpus
            if self._n_pad != corpus.shape[0]:
                corpus = jnp.pad(
                    corpus, ((0, self._n_pad - corpus.shape[0]), (0, 0)))
            self._corpus_padded = corpus
        return self._corpus_padded

    def _rerank_operands(self, q_sizes):
        """(query_sizes, padded device doc sizes, D) for the Theorem-1
        rerank; (None, None, 0) on sparse-limit indexes."""
        meta = self.index.meta
        sizes = self.index.set_sizes
        if sizes is None or not meta.s:
            return None, None, 0
        if q_sizes is None:
            raise ValueError("index stores set sizes; pass query_sizes "
                             "to search() for the exact Theorem-1 rerank")
        if self._doc_sizes_dev is None:
            pad = np.zeros(self._n_pad, np.uint32)
            pad[:meta.n] = np.asarray(sizes)
            self._doc_sizes_dev = jnp.asarray(pad)
        return q_sizes, self._doc_sizes_dev, 1 << meta.s

    def _score(self, qwords, cwords, doc_ids, q_sizes):
        """Kernel match counts -> resemblance estimates for given docs."""
        meta = self.index.meta
        out = packed_match(qwords, cwords, self.index.spec,
                           backend=self.backend, blocks=self._kb)
        matches, both_empty = out if meta.sentinel else (out, None)
        sizes = self.index.set_sizes
        if sizes is not None and meta.s:
            if q_sizes is None:
                raise ValueError("index stores set sizes; pass query_sizes "
                                 "to search() for the exact Theorem-1 rerank")
            doc_sizes = jnp.asarray(sizes)[doc_ids]
            return resemblance_scores(matches, both_empty, meta.k, meta.b,
                                      query_sizes=q_sizes,
                                      doc_sizes=doc_sizes, D=1 << meta.s)
        return resemblance_scores(matches, both_empty, meta.k, meta.b)

    # -- exact brute force ----------------------------------------------
    def _scan_statics(self) -> dict:
        meta = self.index.meta
        return dict(n=meta.n, block=self.corpus_block, k=meta.k, b=meta.b,
                    code_bits=meta.code_bits, sentinel=meta.sentinel,
                    backend=self._be, blk_q=self._kb["blk_q"],
                    blk_n=self._kb["blk_n"], blk_k=self._kb["blk_k"])

    def _exact_fused(self, qwords, topk: int, q_sizes):
        """One traced computation: the whole blocked scan + top-k merge.
        Returns the harvest closure (host sync deferred)."""
        n, q = self.index.n, qwords.shape[0]
        kk = min(topk, n)
        q_sizes, doc_sizes, D = self._rerank_operands(q_sizes)
        best_s = jnp.full((q, kk), -jnp.inf, jnp.float32)
        best_i = jnp.full((q, kk), -1, jnp.int32)
        best_s, best_i = _exact_scan(
            qwords, self._padded_corpus(), best_s, best_i, jnp.int32(0),
            q_sizes, doc_sizes, D=D, **self._scan_statics())
        return lambda: self._pad_result(best_i, best_s, q, topk, kk)

    def _stream_plan(self) -> StreamPlan:
        """Size the streamed windows so the budget is actually honored.

        ``inflight = prefetch + 2`` windows can be device-resident at
        once (scanned + queued + producer-held), so each window gets
        ``max_device_bytes // inflight`` bytes, floored to a ``block``
        multiple.  When that leaves less than one ``corpus_block`` of
        rows, the pipeline depth shrinks first (bigger windows beat
        deeper prefetch) and then the scan block itself shrinks below
        ``corpus_block`` -- down to the hard floor of one row per
        window, the only case where the stated budget is physically
        unsatisfiable.
        """
        row_bytes = 4 * self.index.meta.words
        budget = self.max_device_bytes or 0

        def plan(prefetch: int) -> StreamPlan:
            rows = budget // ((prefetch + 2) * row_bytes)
            block = min(self.corpus_block, max(1, rows))
            window = max(block, rows // block * block)
            return StreamPlan(window, block, prefetch, row_bytes)

        p = plan(self.stream_prefetch)
        while p.prefetch > 0 and p.block < self.corpus_block:
            p = plan(p.prefetch - 1)
        return p

    def _exact_streamed(self, qwords, topk: int, q_sizes):
        """Out-of-core exact scan: windows of the mmap'd packed payload
        stream through a double-buffered H2D pipeline; the top-k carry
        threads across windows (bit-identical to the in-core scan).
        Returns the harvest closure (host sync deferred)."""
        n, q = self.index.n, qwords.shape[0]
        kk = min(topk, n)
        words = self.index.words_host
        w = self.index.meta.words
        p = self._stream_plan()
        q_sizes, doc_sizes, D = self._rerank_operands(q_sizes)
        statics = self._scan_statics()
        statics["block"] = p.block

        def host_windows():
            for lo in range(0, n, p.window):
                hi = min(lo + p.window, n)
                if hi - lo == p.window:
                    # full window: hand the contiguous mmap slice straight
                    # to device_put (no host memset/copy on the hot path)
                    yield np.int32(lo), words[lo:hi]
                else:
                    buf = np.zeros((p.window, w), np.uint32)
                    buf[:hi - lo] = words[lo:hi]
                    yield np.int32(lo), buf

        best_s = jnp.full((q, kk), -jnp.inf, jnp.float32)
        best_i = jnp.full((q, kk), -1, jnp.int32)
        for lo, win in device_put_iter(host_windows, p.prefetch):
            best_s, best_i = _exact_scan(qwords, win, best_s, best_i, lo,
                                         q_sizes, doc_sizes, D=D, **statics)
            # backpressure: wait out window i's scan before pulling more
            # windows off the pipeline, so dispatched-but-unexecuted scans
            # never pin extra windows beyond the inflight accounting
            best_s.block_until_ready()
        return lambda: self._pad_result(best_i, best_s, q, topk, kk)

    def _exact_blockloop(self, qwords, topk: int, q_sizes):
        """The pre-fusion reference: one kernel dispatch + merge per block,
        driven from a host loop (kept for parity tests / benchmarks)."""
        n, q = self.index.n, qwords.shape[0]
        kk = min(topk, n)
        corpus = self._padded_corpus()
        best_s = jnp.full((q, kk), -jnp.inf, jnp.float32)
        best_i = jnp.full((q, kk), -1, jnp.int32)
        for start in range(0, self._n_pad, self.corpus_block):
            cblk = jax.lax.dynamic_slice_in_dim(corpus, start,
                                                self.corpus_block, axis=0)
            ids = start + jnp.arange(self.corpus_block, dtype=jnp.int32)
            sc = self._score(qwords, cblk, ids, q_sizes)
            sc = jnp.where(ids[None, :] < n, sc, -jnp.inf)
            best_s, best_i = _topk_merge(best_s, best_i, sc, ids)
        return lambda: self._pad_result(best_i, best_s, q, topk, kk)

    def _exact(self, qwords, topk: int, q_sizes):
        if self.streamed and self.device is not None:
            raise ValueError(
                "a device-pinned searcher cannot stream the exact scan "
                "(the H2D pipeline's producer thread places windows on "
                "the default device); raise max_device_bytes or drop the "
                "placement")
        if self.exact_impl == "blockloop":
            if self.streamed:
                raise ValueError(
                    "exact_impl='blockloop' keeps the whole corpus "
                    "device-resident and cannot honor max_device_bytes "
                    f"({self.max_device_bytes} < payload "
                    f"{self.index.meta.payload_bytes}); use the fused "
                    "impl for out-of-core corpora")
            return self._exact_blockloop(qwords, topk, q_sizes)
        if self.streamed:
            return self._exact_streamed(qwords, topk, q_sizes)
        return self._exact_fused(qwords, topk, q_sizes)

    @staticmethod
    def _pad_result(best_i, best_s, q: int, topk: int, kk: int,
                    n_candidates=None) -> SearchResult:
        """Pad to the requested width so every mode returns (Q, topk)."""
        out_i = np.full((q, topk), -1, np.int64)
        out_s = np.full((q, topk), -np.inf, np.float32)
        out_i[:, :kk] = np.asarray(best_i)
        out_s[:, :kk] = np.asarray(best_s)
        return SearchResult(out_i, out_s, n_candidates)

    # -- LSH candidates + rerank ----------------------------------------
    def _lsh_dispatch(self, qwords, topk: int, q_sizes, cand):
        """Dispatch one sub-batch's rerank; returns device handles (no
        host sync -- the caller harvests after the loop)."""
        q = qwords.shape[0]
        n_cand = np.array([c.size for c in cand], np.int64)
        union = (np.unique(np.concatenate(cand)) if any(c.size for c in cand)
                 else np.zeros(0, np.int64))
        if union.size == 0:
            return (np.full((q, topk), -1, np.int64),
                    np.full((q, topk), -np.inf, np.float32), n_cand, topk)
        member = np.zeros((q, union.size), bool)
        for i, c in enumerate(cand):
            member[i, np.searchsorted(union, c)] = True
        # pad the candidate union to a bucketed width so batch-to-batch
        # candidate counts reuse compiled kernels
        c_pad = max(128, 1 << int(union.size - 1).bit_length())
        ids = np.zeros(c_pad, np.int32)
        ids[:union.size] = union
        mem = np.zeros((q, c_pad), bool)
        mem[:, :union.size] = member
        ids_dev = jnp.asarray(ids)
        if self.streamed:
            # out-of-core corpus: gather ONLY the candidate rows off the
            # mmap'd payload instead of uploading the whole matrix
            cwords = jnp.asarray(
                np.ascontiguousarray(self.index.words_host[ids]))
        else:
            cwords = jnp.take(self.index.corpus, ids_dev, axis=0)
        sc = self._score(qwords, cwords, ids_dev, q_sizes)
        sc = jnp.where(jnp.asarray(mem), sc, -jnp.inf)
        kk = min(topk, c_pad)
        top_s, sel = jax.lax.top_k(sc, kk)
        top_i = jnp.take(ids_dev, sel)
        top_i = jnp.where(jnp.isneginf(top_s), -1, top_i)
        return top_i, top_s, n_cand, kk

    def _lsh(self, qwords, topk: int, q_sizes, qkeys=None):
        q = qwords.shape[0]
        if qkeys is None:
            qkeys = np.asarray(band_keys_packed(qwords, self.index.spec,
                                                self.index.banding))
        cand = self.index.candidates_batch(qkeys)
        step = self.lsh_batch or q
        # dispatch every sub-batch before harvesting anything: jax
        # dispatch is asynchronous, so generating candidates/masks for
        # sub-batch i+1 on the host overlaps the device rerank of i
        inflight = []
        for lo in range(0, q, step):
            hi = min(lo + step, q)
            sizes = None if q_sizes is None else q_sizes[lo:hi]
            inflight.append(self._lsh_dispatch(qwords[lo:hi], topk, sizes,
                                               cand[lo:hi]))

        def harvest() -> SearchResult:
            out_i = np.full((q, topk), -1, np.int64)
            out_s = np.full((q, topk), -np.inf, np.float32)
            n_cand = np.zeros(q, np.int64)
            row = 0
            for top_i, top_s, nc, kk in inflight:
                m = nc.shape[0]
                out_i[row:row + m, :kk] = np.asarray(top_i)[:, :topk]
                out_s[row:row + m, :kk] = np.asarray(top_s)[:, :topk]
                n_cand[row:row + m] = nc
                row += m
            return SearchResult(out_i, out_s, n_cand)
        return harvest

    # -- public API ------------------------------------------------------
    def dispatch(self, queries: Union[PackedSignatures, jax.Array,
                                      np.ndarray], topk: int = 10, *,
                 mode: str = "exact",
                 query_sizes: Optional[np.ndarray] = None,
                 _qkeys: Optional[np.ndarray] = None):
        """Dispatch a batch's device work NOW; defer the host sync.

        Returns a zero-arg harvest callable producing the
        ``SearchResult``.  The sharded router dispatches every shard
        before harvesting any, so shard i+1's candidate generation and
        kernel launches overlap shard i's device work.  With ``device``
        set, the dispatch runs under that device (queries are moved
        there, the corpus uploads there, and the kernel + top-k execute
        there), so searchers placed on distinct devices by the router's
        mesh placement genuinely run in parallel.  ``_qkeys``
        (router-internal) passes precomputed band keys so the fan-out
        computes them once per batch, not once per shard.
        """
        if topk < 1:
            raise ValueError(f"topk must be >= 1, got {topk}")
        qwords = _query_words(queries, self.index.spec)
        q_sizes = None if query_sizes is None else jnp.asarray(query_sizes)
        if self.device is not None:
            with jax.default_device(self.device):
                qwords = jax.device_put(qwords, self.device)
                if q_sizes is not None:
                    q_sizes = jax.device_put(q_sizes, self.device)
                return self._dispatch_mode(qwords, topk, mode, q_sizes,
                                           _qkeys)
        return self._dispatch_mode(qwords, topk, mode, q_sizes, _qkeys)

    def _dispatch_mode(self, qwords, topk: int, mode: str, q_sizes, _qkeys):
        if mode == "exact":
            return self._exact(qwords, topk, q_sizes)
        if mode == "lsh":
            return self._lsh(qwords, topk, q_sizes, _qkeys)
        raise ValueError(f"mode must be 'exact' or 'lsh', got {mode!r}")

    def search(self, queries: Union[PackedSignatures, jax.Array,
                                    np.ndarray], topk: int = 10, *,
               mode: str = "exact",
               query_sizes: Optional[np.ndarray] = None) -> SearchResult:
        """Top-k most resembling documents for a batch of packed queries.

        ``queries``: a ``PackedSignatures`` batch or a raw (Q, words)
        uint32 array in the index's wire format.  ``mode``: ``"exact"``
        (fused kernel brute force) or ``"lsh"`` (banded candidates +
        kernel rerank).  ``query_sizes`` feeds the exact Theorem-1 debias
        when the index stores set sizes.
        """
        return self.dispatch(queries, topk, mode=mode,
                             query_sizes=query_sizes)()
