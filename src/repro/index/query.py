"""Query paths over a loaded ``.idx``: exact top-k and LSH + rerank.

One searcher, two serving paths sharing the scoring kernel and the
estimator rerank:

  * ``mode="exact"``  -- kernel brute force: the packed-Hamming kernel
    (``repro.kernels.hamming.packed_match``) scores the query batch
    against fixed-size corpus blocks of the device-resident packed
    matrix, scores are debiased into resemblance estimates (Theorem 1),
    and a running top-k merge keeps the best k per query.  Exact in the
    sense of "exact over the signatures": the b-bit estimator itself is
    still an estimator.
  * ``mode="lsh"``    -- candidate generation through the banded bucket
    tables (host-side binary search over the mmap'd sorted key arrays),
    then one kernel launch over the batch's candidate union with
    non-candidates masked out, then the same estimator rerank.  The
    S-curve (``repro.index.banding``) predicts the recall/selectivity
    trade the band config buys.

Batched query admission: ``submit`` queues single queries, ``flush``
runs them as one batch (one kernel launch, one candidate union) and
returns per-ticket results -- the serving-launcher entry point
(``repro.launch.serve --index``).

Scores are resemblance estimates: the Li-Owen-Zhang normalization for
sentinel wires (matches / (k - jointly_empty)) and the Theorem-1
debiasing -- exact per-pair constants when the index stores set sizes
and the universe size, the sparse-limit constants (C1 = C2 = 2^-b)
otherwise.  Both debiasings are strictly monotone in the collision
fraction, so rankings do not depend on which one applies.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.estimator import bbit_constants
from repro.index.banding import band_keys_packed
from repro.index.builder import SigIndex
from repro.kernels import PackedSignatures, packed_match


def resemblance_scores(matches: jax.Array, both_empty: Optional[jax.Array],
                       k: int, b: int, *,
                       query_sizes: Optional[jax.Array] = None,
                       doc_sizes: Optional[jax.Array] = None,
                       D: int = 0) -> jax.Array:
    """(Q, N) match counts -> (Q, N) float32 resemblance estimates.

    ``both_empty`` applies the Li-Owen-Zhang denominator for sentinel
    wires; the Theorem-1 debias uses exact (C1, C2) when per-document
    set sizes and the universe size are known, the sparse-limit
    constants 2^-b otherwise.
    """
    matches = matches.astype(jnp.float32)
    if both_empty is not None:
        denom = jnp.maximum(k - both_empty.astype(jnp.float32), 1.0)
    else:
        denom = jnp.float32(k)
    p_hat = matches / denom
    if query_sizes is not None and doc_sizes is not None and D:
        c = bbit_constants(jnp.asarray(query_sizes)[:, None],
                           jnp.asarray(doc_sizes)[None, :], D, b)
        return (p_hat - c.C1) / (1.0 - c.C2)
    c1 = jnp.float32(2.0 ** -b)
    return (p_hat - c1) / (1.0 - c1)


@dataclasses.dataclass
class SearchResult:
    """Top-k per query: global doc ids (-1 past the candidate count) and
    their resemblance estimates (-inf where the id is -1)."""

    indices: np.ndarray          # (Q, topk) int64
    scores: np.ndarray           # (Q, topk) float32
    n_candidates: Optional[np.ndarray] = None    # (Q,) for the LSH path

    def __len__(self) -> int:
        return self.indices.shape[0]


def _query_words(queries, spec) -> jax.Array:
    if isinstance(queries, PackedSignatures):
        if (queries.k, queries.b, queries.sentinel) != \
                (spec.k, spec.b, spec.sentinel):
            raise ValueError(
                f"query wire (k={queries.k}, b={queries.b}, "
                f"sentinel={queries.sentinel}) != index wire (k={spec.k}, "
                f"b={spec.b}, sentinel={spec.sentinel})")
        return queries.data
    words = jnp.asarray(queries)
    if words.ndim != 2 or words.shape[1] != spec.words:
        raise ValueError(f"raw queries must be (Q, {spec.words}) uint32 "
                         f"packed words, got {words.shape}")
    return words


class IndexSearcher:
    """Serving front end over one ``SigIndex``.

    ``backend`` picks the kernel execution (SignatureEngine registry);
    ``corpus_block`` is the brute-force block height (fixed, so every
    block reuses one compiled kernel); ``blocks`` overrides the
    TuningTable kernel tile sizes.
    """

    def __init__(self, index: SigIndex, *, backend: Optional[str] = None,
                 corpus_block: int = 4096, blocks: Optional[dict] = None):
        self.index = index
        self.backend = backend
        self.blocks = blocks
        self.corpus_block = min(corpus_block, max(index.n, 1))
        self._pending: List[Tuple[int, jax.Array, Optional[int]]] = []
        self._next_ticket = 0
        self._query_sizes = None
        self._corpus_padded = None
        n_pad = ((index.n + self.corpus_block - 1)
                 // self.corpus_block) * self.corpus_block
        self._n_pad = n_pad

    # -- scoring ---------------------------------------------------------
    def _padded_corpus(self):
        """Device corpus padded to a block multiple (computed once)."""
        if self._corpus_padded is None:
            corpus = self.index.corpus
            if self._n_pad != corpus.shape[0]:
                corpus = jnp.pad(
                    corpus, ((0, self._n_pad - corpus.shape[0]), (0, 0)))
            self._corpus_padded = corpus
        return self._corpus_padded

    def _score(self, qwords, cwords, doc_ids):
        """Kernel match counts -> resemblance estimates for given docs."""
        meta = self.index.meta
        out = packed_match(qwords, cwords, self.index.spec,
                           backend=self.backend, blocks=self.blocks)
        matches, both_empty = out if meta.sentinel else (out, None)
        sizes = self.index.set_sizes
        if sizes is not None and meta.s:
            doc_sizes = jnp.asarray(sizes)[doc_ids]
            q_sizes = self._query_sizes
            if q_sizes is None:
                raise ValueError("index stores set sizes; pass query_sizes "
                                 "to search() for the exact Theorem-1 rerank")
            return resemblance_scores(matches, both_empty, meta.k, meta.b,
                                      query_sizes=q_sizes,
                                      doc_sizes=doc_sizes, D=1 << meta.s)
        return resemblance_scores(matches, both_empty, meta.k, meta.b)

    # -- exact brute force ----------------------------------------------
    def _exact(self, qwords, topk: int) -> SearchResult:
        n, q = self.index.n, qwords.shape[0]
        kk = min(topk, n)
        corpus = self._padded_corpus()
        best_s = jnp.full((q, kk), -jnp.inf, jnp.float32)
        best_i = jnp.full((q, kk), -1, jnp.int32)
        for start in range(0, self._n_pad, self.corpus_block):
            cblk = jax.lax.dynamic_slice_in_dim(corpus, start,
                                                self.corpus_block, axis=0)
            ids = start + jnp.arange(self.corpus_block, dtype=jnp.int32)
            sc = self._score(qwords, cblk, ids)
            sc = jnp.where(ids[None, :] < n, sc, -jnp.inf)
            cat_s = jnp.concatenate([best_s, sc], axis=1)
            cat_i = jnp.concatenate(
                [best_i, jnp.broadcast_to(ids[None, :], sc.shape)], axis=1)
            best_s, sel = jax.lax.top_k(cat_s, kk)
            best_i = jnp.take_along_axis(cat_i, sel, axis=1)
        # pad to the requested width so both modes return (Q, topk)
        out_i = np.full((q, topk), -1, np.int64)
        out_s = np.full((q, topk), -np.inf, np.float32)
        out_i[:, :kk] = np.asarray(best_i)
        out_s[:, :kk] = np.asarray(best_s)
        return SearchResult(out_i, out_s)

    # -- LSH candidates + rerank ----------------------------------------
    def _lsh(self, qwords, topk: int) -> SearchResult:
        q = qwords.shape[0]
        meta = self.index.meta
        qkeys = np.asarray(band_keys_packed(qwords, self.index.spec,
                                            self.index.banding))
        cand = [self.index.candidates(qkeys[i]) for i in range(q)]
        n_cand = np.array([c.size for c in cand], np.int64)
        union = (np.unique(np.concatenate(cand)) if any(c.size for c in cand)
                 else np.zeros(0, np.int64))
        if union.size == 0:
            return SearchResult(np.full((q, topk), -1, np.int64),
                                np.full((q, topk), -np.inf, np.float32),
                                n_cand)
        member = np.zeros((q, union.size), bool)
        for i, c in enumerate(cand):
            member[i, np.searchsorted(union, c)] = True
        # pad the candidate union to a bucketed width so batch-to-batch
        # candidate counts reuse compiled kernels
        c_pad = max(128, 1 << int(union.size - 1).bit_length())
        ids = np.zeros(c_pad, np.int32)
        ids[:union.size] = union
        mem = np.zeros((q, c_pad), bool)
        mem[:, :union.size] = member
        ids_dev = jnp.asarray(ids)
        cwords = jnp.take(self.index.corpus, ids_dev, axis=0)
        sc = self._score(qwords, cwords, ids_dev)
        sc = jnp.where(jnp.asarray(mem), sc, -jnp.inf)
        kk = min(topk, c_pad)
        top_s, sel = jax.lax.top_k(sc, kk)
        top_i = jnp.take(ids_dev, sel)
        top_i = jnp.where(jnp.isneginf(top_s), -1, top_i)
        out_i = np.full((q, topk), -1, np.int64)
        out_s = np.full((q, topk), -np.inf, np.float32)
        out_i[:, :kk] = np.asarray(top_i)
        out_s[:, :kk] = np.asarray(top_s)
        return SearchResult(out_i, out_s, n_cand)

    # -- public API ------------------------------------------------------
    def search(self, queries: Union[PackedSignatures, jax.Array,
                                    np.ndarray], topk: int = 10, *,
               mode: str = "exact",
               query_sizes: Optional[np.ndarray] = None) -> SearchResult:
        """Top-k most resembling documents for a batch of packed queries.

        ``queries``: a ``PackedSignatures`` batch or a raw (Q, words)
        uint32 array in the index's wire format.  ``mode``: ``"exact"``
        (kernel brute force) or ``"lsh"`` (banded candidates + kernel
        rerank).  ``query_sizes`` feeds the exact Theorem-1 debias when
        the index stores set sizes.
        """
        if topk < 1:
            raise ValueError(f"topk must be >= 1, got {topk}")
        qwords = _query_words(queries, self.index.spec)
        self._query_sizes = (None if query_sizes is None
                             else jnp.asarray(query_sizes))
        if mode == "exact":
            return self._exact(qwords, topk)
        if mode == "lsh":
            return self._lsh(qwords, topk)
        raise ValueError(f"mode must be 'exact' or 'lsh', got {mode!r}")

    # -- batched admission ----------------------------------------------
    def submit(self, query: Union[PackedSignatures, jax.Array, np.ndarray],
               *, query_size: Optional[int] = None) -> int:
        """Queue one query (a single packed row); returns its ticket.

        ``query_size`` (the query set's original nonzero count) feeds
        the exact Theorem-1 rerank on indexes that store set sizes.
        """
        qwords = _query_words(
            query if isinstance(query, PackedSignatures)
            else jnp.asarray(query).reshape(1, -1), self.index.spec)
        if qwords.shape[0] != 1:
            raise ValueError("submit() takes exactly one query row")
        ticket = self._next_ticket
        self._next_ticket += 1
        self._pending.append((ticket, qwords, query_size))
        return ticket

    def flush(self, topk: int = 10, *, mode: str = "exact"
              ) -> Dict[int, SearchResult]:
        """Run all queued queries as ONE batch; per-ticket results."""
        if not self._pending:
            return {}
        tickets = [t for t, _, _ in self._pending]
        batch = jnp.concatenate([w for _, w, _ in self._pending], axis=0)
        sizes = [sz for _, _, sz in self._pending]
        self._pending = []
        if any(sz is not None for sz in sizes):
            if any(sz is None for sz in sizes):
                raise ValueError("either every submitted query carries a "
                                 "query_size or none does")
            qsizes = np.asarray(sizes, np.uint32)
        else:
            qsizes = None
        res = self.search(batch, topk, mode=mode, query_sizes=qsizes)
        return {t: SearchResult(res.indices[i:i + 1], res.scores[i:i + 1],
                                None if res.n_candidates is None
                                else res.n_candidates[i:i + 1])
                for i, t in enumerate(tickets)}
