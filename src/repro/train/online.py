"""Fused streaming online learning: OPH/minhash front half -> SGD, no
host round-trip (paper §6 + One Permutation Hashing, arXiv:1208.1259).

The paper's online-learning argument is about *per-epoch data cost*:
SGD/ASGD needs 10-100 passes, the data does not fit in memory, so every
epoch pays the loading bill -- and b-bit hashing shrinks that bill by the
Table-2/§6 storage reduction.  This module makes the repo's training
entry points actually live that loop instead of round-tripping signatures
through ad-hoc ``.npz`` files:

  * ``SignatureCache`` -- wraps a ``SignatureStream``.  Epoch 0 streams
    raw shards through the hash kernel (one pass, signatures go straight
    to the SGD step on device) while writing b-bit-*packed* signature
    shards to disk; it records original-vs-hashed bytes (the Table-2/§6
    reduction).  Epochs >= 1 replay the packed shards with the same
    prefetch + straggler/IO-retry machinery as ``ChunkedLoader``
    (``read_with_retries`` / ``prefetch_iter`` are shared), unpacking the
    b-bit words *on device* -- the host only ever moves k*b bits per
    example.
  * ``OnlineTrainer`` -- consumes a ``SignatureStream`` or a
    ``SignatureCache`` (anything yielding ``(signatures, labels)``
    chunks), runs the Bottou SGD / ASGD / logistic-regression update with
    a donated state buffer, and accounts an ``EpochStats`` per epoch
    (load / kernel / train seconds, bytes, examples) -- the quantities
    behind Figures 13-16/19 and Table 4.
  * ``make_family`` -- one switch over the paper's hashing schemes:
    ``"2u"`` / ``"4u"`` (k-pass minwise) and ``"oph"`` / ``"oph-4u"``
    (single-pass one-permutation hashing, x ``densify=``).

Paper mapping:
  * §6, Eq. 11-12: the SGD/ASGD update (via ``repro.models.linear``).
  * §6.1 + Table 2: epoch-0 vs replay bytes (``CacheStats.reduction``).
  * Figs 13-15, 19: accuracy-vs-epoch curves (``OnlineTrainer.fit`` with
    ``eval_fn``); Figs 16, 18 + Table 4: ``EpochStats`` load/train split.
  * arXiv:1208.1259 (Li-Owen-Zhang): the OPH front half; empty bins under
    ``densify="sentinel"`` are zero-coded by the learning layer.
"""

from __future__ import annotations

import dataclasses
import functools
import os
import tempfile
import time
from typing import Callable, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bbit import pack_signatures, unpack_signatures
from repro.core.hashing import Hash2U, Hash4U
from repro.core.oph import EMPTY, OPH
from repro.data.pipeline import (LoaderStats, SignatureStream, prefetch_iter,
                                 read_with_retries)
from repro.models.linear import (accuracy, asgd_model, sgd_svm_init,
                                 sgd_svm_step)


def make_family(key: jax.Array, scheme: str, k: int, s: int, *,
                densify: str = "rotation", variant: str = "high"):
    """Build a hashing scheme for the online-learning front half.

    ``scheme``: ``"2u"`` / ``"4u"`` are the k-pass minwise families
    (k hash evaluations per nonzero); ``"oph"`` (2U base) / ``"oph-4u"``
    are single-pass one-permutation hashing (ONE evaluation per nonzero,
    k bins).  ``densify`` applies to the OPH schemes only: ``"rotation"``
    (Shrivastava-Li, signatures behave like minhash) or ``"sentinel"``
    (empty bins stay EMPTY; the learning layer zero-codes them).
    """
    if scheme == "2u":
        return Hash2U.create(key, k, s, variant=variant)
    if scheme == "4u":
        return Hash4U.create(key, k, s)
    if scheme in ("oph", "oph-2u"):
        return OPH.create(key, k=k, s=s, family="2u", densify=densify,
                          variant=variant)
    if scheme == "oph-4u":
        return OPH.create(key, k=k, s=s, family="4u", densify=densify)
    raise ValueError(
        f"scheme must be '2u', '4u', 'oph'/'oph-2u' or 'oph-4u', got {scheme!r}")


# ---------------------------------------------------------------------------
# SignatureCache: hash once, replay b-bit-packed shards every later epoch
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CacheStats:
    """Epoch-0 accounting: what the cache cost and what it saves."""

    bytes_original: int = 0      # raw shard bytes read to build the cache
    bytes_cached: int = 0        # packed signature shard bytes written
    shards: int = 0
    examples: int = 0
    write_s: float = 0.0

    def reduction(self) -> float:
        """Original/hashed size ratio -- the paper's Table-2/§6 number."""
        return self.bytes_original / max(self.bytes_cached, 1)


class SignatureCache:
    """Hash on epoch 0, replay b-bit-packed signature shards afterwards.

    Iterating yields ``(signatures, labels)`` chunks exactly like the
    wrapped ``SignatureStream``; the first full pass additionally writes
    each chunk as a packed shard under ``cache_dir`` (bit-exact: replayed
    signatures equal the fresh stream's output).  Replay uses the same
    prefetch and straggler/IO-retry machinery as ``ChunkedLoader``
    (``replay_stats`` is a ``LoaderStats``), and unpacks the b-bit words
    on device so host->device traffic is k*b bits per example.

    Packing: b-bit values pack into uint32 words when ``b | 32``.
    Sentinel-densified OPH signatures carry the EMPTY marker, which is
    stored as the value ``2^b`` in the smallest integer dtype that fits
    (no uint32 packing) and restored to EMPTY on replay.
    """

    def __init__(self, stream: SignatureStream, cache_dir: Optional[str] = None,
                 *, prefetch: int = 2, straggler_deadline_s: float = 30.0,
                 max_retries: int = 2):
        self.stream = stream
        self.b = stream.b
        fam = stream.family
        self.sentinel = isinstance(fam, OPH) and fam.densify == "sentinel"
        self.pack = (not self.sentinel) and 0 < self.b and 32 % self.b == 0
        self.cache_dir = cache_dir or tempfile.mkdtemp(prefix="repro_sigcache_")
        os.makedirs(self.cache_dir, exist_ok=True)
        self.prefetch = prefetch
        self.deadline = straggler_deadline_s
        self.max_retries = max_retries
        self.populated = False
        self.paths: List[str] = []
        self.stats = CacheStats()
        self.replay_stats = LoaderStats()

    # -- stats protocol (read by OnlineTrainer as per-epoch deltas) -----
    @property
    def cumulative_stats(self) -> dict:
        return {"kernel_s": self.stream.kernel_seconds,
                "bytes_read": (self.stream.loader.stats.bytes_read
                               + self.replay_stats.bytes_read),
                "source": "cache" if self.populated else "hash"}

    def __iter__(self):
        if self.populated:
            yield from self._replay()
        else:
            yield from self._populate()

    # -- epoch 0: hash + write-through ---------------------------------
    def _encode(self, sig: jax.Array) -> Tuple[np.ndarray, bool]:
        """Device signatures -> host array for storage; returns (data, packed)."""
        if self.pack:
            return np.asarray(pack_signatures(sig, self.b)), True
        host = np.asarray(sig).astype(np.uint32)
        span = (1 << self.b) + 1 if self.b > 0 else 1 << 32  # values + EMPTY code
        if self.sentinel and self.b > 0:
            host = np.where(host == np.uint32(EMPTY),
                            np.uint32(1 << self.b), host)
        dtype = (np.uint8 if span <= 1 << 8 else
                 np.uint16 if span <= 1 << 16 else np.uint32)
        return host.astype(dtype), False

    def _populate(self):
        # a partially-consumed epoch-0 pass may have written some shards
        # and read some raw bytes already; restart the accounting so
        # replay never sees duplicates and the reduction stays honest
        self.paths = []
        self.stats = CacheStats()
        raw_bytes_before = self.stream.loader.stats.bytes_read
        for i, (sig, labels) in enumerate(self.stream):
            t0 = time.perf_counter()
            data, packed = self._encode(sig)
            path = os.path.join(self.cache_dir, f"sig_{i:05d}.npz")
            np.savez(path, data=data, labels=np.asarray(labels),
                     k=np.int32(sig.shape[1]), b=np.int32(self.b),
                     packed=packed, sentinel=self.sentinel)
            self.paths.append(path)
            self.stats.bytes_cached += os.path.getsize(path)
            self.stats.shards += 1
            self.stats.examples += sig.shape[0]
            self.stats.write_s += time.perf_counter() - t0
            yield sig, labels
        self.stats.bytes_original = (self.stream.loader.stats.bytes_read
                                     - raw_bytes_before)
        self.populated = True

    # -- epochs >= 1: replay packed shards -----------------------------
    @staticmethod
    def _read_host(path: str) -> dict:
        with np.load(path) as z:
            return {k: z[k] for k in z.files}

    def _decode(self, payload: dict) -> Tuple[jax.Array, jax.Array]:
        k, b = int(payload["k"]), int(payload["b"])
        data = jnp.asarray(payload["data"])          # packed words on device
        if bool(payload["packed"]):
            sig = unpack_signatures(data, b, k)
        else:
            sig = data.astype(jnp.uint32)
            if bool(payload["sentinel"]) and b > 0:
                sig = jnp.where(sig == jnp.uint32(1 << b), EMPTY, sig)
        return sig, jnp.asarray(payload["labels"])

    def _replay(self):
        def chunks():
            for path in self.paths:
                yield read_with_retries(self._read_host, path,
                                        self.replay_stats,
                                        deadline=self.deadline,
                                        max_retries=self.max_retries)
        for payload in prefetch_iter(chunks, self.prefetch):
            yield self._decode(payload)


# ---------------------------------------------------------------------------
# OnlineTrainer: the §6 epoch loop over any (signatures, labels) source
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class EpochStats:
    """Per-epoch accounting (the split behind Figs 16/18 and Table 4).

    ``load_s`` is time the trainer waited on the source -- on a "hash"
    epoch that includes the hashing kernel (``kernel_s`` reports the
    device portion separately); on a "cache" epoch it is pure replay I/O.
    """

    epoch: int
    source: str                  # "hash" (fresh stream) | "cache" (replay)
    load_s: float = 0.0
    kernel_s: float = 0.0
    train_s: float = 0.0
    bytes_read: int = 0
    examples: int = 0


@dataclasses.dataclass
class OnlineTrainer:
    """Streaming SGD / ASGD / logistic regression on b-bit signatures.

    ``fit`` consumes chunked ``(signatures, labels)`` sources -- a
    ``SignatureStream`` (hash every epoch) or a ``SignatureCache`` (hash
    once, replay packed shards) -- and runs the Bottou update
    (Eq. 11-12) mini-batch by mini-batch with the SGD state donated to
    the jitted step, so the weights never leave the device.

    ``kind``: ``"svm"`` (Eq. 6 hinge) or ``"logistic"`` (Eq. 7);
    ``average=True`` maintains the §6.3 ASGD iterate average and makes
    ``model``/``evaluate`` use it.
    """

    k: int
    b: int
    kind: str = "svm"
    average: bool = False
    lam: float = 1e-4
    eta0: float = 0.5
    batch_size: int = 16
    avg_start: float = 0.0
    donate: bool = True

    def __post_init__(self):
        if self.kind not in ("svm", "logistic"):
            raise ValueError(f"kind must be 'svm' or 'logistic', got {self.kind!r}")
        self.dim = self.k * (1 << self.b)
        step = functools.partial(sgd_svm_step, lam=self.lam, eta0=self.eta0,
                                 b=self.b, feature_kind="hashed",
                                 kind=self.kind, average=self.average)
        self._step = (jax.jit(step, donate_argnums=(0,)) if self.donate
                      else jax.jit(step))
        self.state = sgd_svm_init(self.dim, avg_start=self.avg_start)
        self.epoch_stats: List[EpochStats] = []

    @property
    def model(self):
        return asgd_model(self.state) if self.average else self.state.model

    def evaluate(self, sig_b: jax.Array, labels: jax.Array) -> float:
        return float(accuracy(self.model, sig_b, labels,
                              feature_kind="hashed", b=self.b))

    def fit(self, source: Iterable, n_epochs: int,
            eval_fn: Optional[Callable[["OnlineTrainer"], float]] = None
            ) -> Tuple[object, List[EpochStats], List[float]]:
        """Run ``n_epochs`` passes over ``source``.

        Returns ``(final SGDState, this call's per-epoch EpochStats,
        this call's per-epoch evals)`` -- the two lists always align;
        ``eval_fn`` (if given) is called with the trainer after each
        epoch.  ``self.epoch_stats`` accumulates across ``fit`` calls so
        a warm trainer can keep training.
        """
        evals: List[float] = []
        first = len(self.epoch_stats)
        for _ in range(n_epochs):
            before = dict(getattr(source, "cumulative_stats", None) or {})
            es = EpochStats(epoch=len(self.epoch_stats),
                            source=before.get("source", "stream"))
            t_mark = time.perf_counter()
            for sig, labels in source:
                t_loaded = time.perf_counter()
                es.load_s += t_loaded - t_mark
                sig = jnp.asarray(sig)
                y = jnp.asarray(labels)
                n = sig.shape[0]
                for i in range(0, n, self.batch_size):
                    self.state = self._step(self.state,
                                            sig[i:i + self.batch_size],
                                            y[i:i + self.batch_size])
                jax.block_until_ready(self.state.model.w)
                es.examples += n
                t_mark = time.perf_counter()
                es.train_s += t_mark - t_loaded
            after = dict(getattr(source, "cumulative_stats", None) or {})
            es.kernel_s = after.get("kernel_s", 0.0) - before.get("kernel_s", 0.0)
            es.bytes_read = after.get("bytes_read", 0) - before.get("bytes_read", 0)
            self.epoch_stats.append(es)
            evals.append(float(eval_fn(self)) if eval_fn else float("nan"))
        return self.state, self.epoch_stats[first:], evals
