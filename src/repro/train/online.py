"""Fused streaming online learning: OPH/minhash front half -> SGD, no
host round-trip (paper §6 + One Permutation Hashing, arXiv:1208.1259).

The paper's online-learning argument is about *per-epoch data cost*:
SGD/ASGD needs 10-100 passes, the data does not fit in memory, so every
epoch pays the loading bill -- and b-bit hashing shrinks that bill by the
Table-2/§6 storage reduction.  This module makes the repo's training
entry points actually live that loop on the packed wire format:

  * ``SignatureCache`` -- wraps a ``SignatureStream``.  Epoch 0 streams
    raw shards through the signature engine (one pass, signatures go
    straight to the SGD step on device) while writing bit-packed ``.sig``
    shards (``repro.data.sigshard``: raw mmap-able header + payload,
    k*b bits per example -- (b+1)-bit codes for sentinel OPH); it records
    original-vs-hashed bytes (the Table-2/§6 reduction).  Epochs >= 1
    replay the shards with the same prefetch + straggler/IO-retry
    machinery as ``ChunkedLoader`` (``read_with_retries`` /
    ``prefetch_iter`` are shared); packed words go to the device as-is
    and are unpacked *inside the jitted SGD step* -- the host only ever
    moves k*b bits per example.  ``max_cache_bytes`` bounds the on-disk
    footprint (chunks past the budget are re-hashed on replay), and
    ``close()`` / context-manager use cleans up owned temp cache dirs
    (they used to leak one per run).
  * ``OnlineTrainer`` -- consumes a ``SignatureStream`` or a
    ``SignatureCache`` (anything yielding ``(signatures, labels)``
    chunks, packed or not), runs the Bottou SGD / ASGD / logistic
    update with a donated state buffer, and accounts an ``EpochStats``
    per epoch (load / kernel / train seconds, bytes, examples) -- the
    quantities behind Figures 13-16/19 and Table 4.
  * ``make_family`` -- one switch over the paper's hashing schemes:
    ``"2u"`` / ``"4u"`` (k-pass minwise) and ``"oph"`` / ``"oph-4u"``
    (single-pass one-permutation hashing, x ``densify=``).

Paper mapping:
  * §6, Eq. 11-12: the SGD/ASGD update (via ``repro.models.linear``).
  * §6.1 + Table 2: epoch-0 vs replay bytes (``CacheStats.reduction``).
  * Figs 13-15, 19: accuracy-vs-epoch curves (``OnlineTrainer.fit`` with
    ``eval_fn``); Figs 16, 18 + Table 4: ``EpochStats`` load/train split.
  * arXiv:1208.1259 (Li-Owen-Zhang): the OPH front half; empty bins under
    ``densify="sentinel"`` are zero-coded by the learning layer.
"""

from __future__ import annotations

import dataclasses
import functools
import os
import shutil
import tempfile
import time
import weakref
from typing import Callable, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hashing import Hash2U, Hash4U
from repro.core.oph import OPH
from repro.data.lockfile import FileLock
from repro.data.pipeline import (LoaderStats, SignatureStream, prefetch_iter,
                                 read_with_retries)
from repro.data.sigshard import read_sig_shard, write_sig_shard
from repro.kernels import PackedSignatures
from repro.kernels.pack import PackSpec, pack_device, unpack_device
from repro.models.linear import (accuracy, asgd_model, sgd_svm_init,
                                 sgd_svm_step)


def make_family(key: jax.Array, scheme: str, k: int, s: int, *,
                densify: str = "rotation", variant: str = "high"):
    """Build a hashing scheme for the online-learning front half.

    ``scheme``: ``"2u"`` / ``"4u"`` are the k-pass minwise families
    (k hash evaluations per nonzero); ``"oph"`` (2U base) / ``"oph-4u"``
    are single-pass one-permutation hashing (ONE evaluation per nonzero,
    k bins).  ``densify`` applies to the OPH schemes only: ``"rotation"``
    (Shrivastava-Li 2014, signatures behave like minhash), ``"optimal"``
    (Shrivastava 2017 probe-sequence densification, lower estimator
    variance), ``"fast"`` (Mai et al. 2020 donor-broadcast densification,
    O(k log k) fill work) or ``"sentinel"`` (empty bins stay EMPTY; the
    learning layer zero-codes them).
    """
    if scheme == "2u":
        return Hash2U.create(key, k, s, variant=variant)
    if scheme == "4u":
        return Hash4U.create(key, k, s)
    if scheme in ("oph", "oph-2u"):
        return OPH.create(key, k=k, s=s, family="2u", densify=densify,
                          variant=variant)
    if scheme == "oph-4u":
        return OPH.create(key, k=k, s=s, family="4u", densify=densify)
    raise ValueError(
        f"scheme must be '2u', '4u', 'oph'/'oph-2u' or 'oph-4u', got {scheme!r}")


# ---------------------------------------------------------------------------
# SignatureCache: hash once, replay packed .sig shards every later epoch
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CacheStats:
    """Epoch-0 accounting: what the cache cost and what it saves."""

    bytes_original: int = 0      # raw shard bytes read to build the cache
    bytes_cached: int = 0        # packed signature shard bytes written
    bytes_payload: int = 0       # signature payload only (k*b-bit budget)
    shards: int = 0
    uncached_chunks: int = 0     # chunks past max_cache_bytes (re-hashed)
    examples: int = 0
    write_s: float = 0.0

    def reduction(self) -> float:
        """Original/hashed size ratio -- the paper's Table-2/§6 number."""
        return self.bytes_original / max(self.bytes_cached, 1)


def _sigcache_samples(cache: "SignatureCache"):
    """Registry collector: cache footprint gauges + lifecycle counters.

    Reads ``cache.stats`` at collect time -- a repopulate (TTL eviction)
    swaps in a fresh ``CacheStats``, and the gauges must follow it.
    """
    from repro.obs.metrics import Sample
    st = cache.stats
    gauges = (
        ("sigcache_bytes_original", "raw shard bytes read to build the cache",
         st.bytes_original),
        ("sigcache_bytes_cached", "packed signature shard bytes on disk",
         st.bytes_cached),
        ("sigcache_bytes_payload", "signature payload bytes (k*b-bit budget)",
         st.bytes_payload),
        ("sigcache_shards", "signature shards tracked", st.shards),
        ("sigcache_uncached_chunks", "chunks past max_cache_bytes (re-hashed)",
         st.uncached_chunks),
        ("sigcache_examples", "examples cached", st.examples),
    )
    for name, help, value in gauges:
        yield Sample(name, "gauge", help, (), float(value))
    yield Sample("sigcache_write_seconds_total", "counter",
                 "wall clock spent writing signature shards", (),
                 float(st.write_s))
    yield Sample("sigcache_ttl_dropped_total", "counter",
                 "stale shard files removed by TTL eviction", (),
                 float(cache.ttl_dropped))


def _wire_spec(b: int, sentinel: bool) -> Tuple[int, bool]:
    """(code_bits, sentinel_flag) for storing b-bit signatures on disk.

    1 <= b <= 16 stores the bitstream wire format ((b+1)-bit codes for
    sentinel schemes); anything else falls back to raw 32-bit lanes,
    which also carry the EMPTY marker verbatim.
    """
    if 1 <= b <= 16:
        return (b + 1, True) if sentinel else (b, False)
    return 32, False


class SignatureCache:
    """Hash on epoch 0, replay packed ``.sig`` signature shards afterwards.

    Iterating yields ``(signatures, labels)`` chunks exactly like the
    wrapped ``SignatureStream`` (packed streams yield
    ``PackedSignatures``); the first full pass additionally writes each
    chunk as a bit-packed ``.sig`` shard under ``cache_dir`` (bit-exact:
    replayed signatures equal the fresh stream's output).  Replay uses
    the same prefetch and straggler/IO-retry machinery as
    ``ChunkedLoader`` (``replay_stats`` is a ``LoaderStats``), memory-maps
    the payload, and defers unpacking to the device (packed streams: to
    the jitted SGD step itself), so the host only moves k*b bits per
    example.

    Sharing: a persistent ``cache_dir`` may be shared by several
    trainers (even across processes) -- populate passes serialize on the
    directory's ``.lock`` file (``repro.data.lockfile.FileLock``,
    bounded by ``lock_timeout_s``) and every shard write is atomic, so a
    reader never maps a truncated shard and sweeps never interleave with
    another trainer's writes.

    Lifecycle: ``ttl_s`` expires shards by file mtime -- stale shard
    files are dropped on populate (leftovers in a shared ``cache_dir``)
    and on replay (a stale tracked shard invalidates the cache, which
    re-hashes on the next pass; ``ttl_dropped`` counts removals).
    ``max_cache_bytes`` caps the shard footprint -- chunks
    past the budget are not written and get re-hashed during replay
    (``stats.uncached_chunks``); the tail read resumes at the first
    uncached chunk's shard offset, recorded at populate time via
    ``ChunkedLoader.resume_point``, so the cached prefix's raw shards
    are never re-read.  ``close()`` (or context-manager exit)
    deletes the shards, and removes the cache dir entirely when this
    cache created it (``tempfile.mkdtemp``); a ``weakref.finalize``
    backstop covers caches that are garbage-collected unclosed, so temp
    dirs no longer leak one per run.
    """

    def __init__(self, stream: SignatureStream, cache_dir: Optional[str] = None,
                 *, prefetch: int = 2, straggler_deadline_s: float = 30.0,
                 max_retries: int = 2, max_cache_bytes: Optional[int] = None,
                 ttl_s: Optional[float] = None,
                 lock_timeout_s: float = 600.0):
        self.stream = stream
        self.b = stream.b
        fam = stream.family
        self.k = fam.k
        self.sentinel = isinstance(fam, OPH) and fam.densify == "sentinel"
        self.packed = stream.packed
        self._owns_dir = cache_dir is None
        self.cache_dir = cache_dir or tempfile.mkdtemp(prefix="repro_sigcache_")
        os.makedirs(self.cache_dir, exist_ok=True)
        self.prefetch = prefetch
        self.deadline = straggler_deadline_s
        self.max_retries = max_retries
        self.max_cache_bytes = max_cache_bytes
        self.ttl_s = ttl_s
        self.lock_timeout_s = lock_timeout_s
        self.ttl_dropped = 0          # stale shard files removed so far
        self.populated = False
        self.closed = False
        self.paths: List[str] = []
        self._tail_resume = None      # (shard idx, skip) past the budget
        self.stats = CacheStats()
        self.replay_stats = LoaderStats()
        self._finalizer = (weakref.finalize(self, shutil.rmtree,
                                            self.cache_dir,
                                            ignore_errors=True)
                           if self._owns_dir else None)
        from repro.data.pipeline import loader_collector
        from repro.obs.metrics import get_registry
        reg = get_registry()
        reg.register_object(self, _sigcache_samples)
        reg.register_object(self.replay_stats, loader_collector("replay"))

    # -- stats protocol (read by OnlineTrainer as per-epoch deltas) -----
    @property
    def cumulative_stats(self) -> dict:
        return {"kernel_s": self.stream.kernel_seconds,
                "bytes_read": (self.stream.loader.stats.bytes_read
                               + self.replay_stats.bytes_read),
                "source": "cache" if self.populated else "hash"}

    def __iter__(self):
        if self.closed:
            raise RuntimeError("SignatureCache is closed")
        if self.populated and self._ttl_expired():
            self.evict()
        if self.populated:
            yield from self._replay()
        else:
            yield from self._populate()

    # -- TTL eviction ---------------------------------------------------
    def _ttl_expired(self) -> bool:
        """Drop tracked shard files older than ``ttl_s`` (by mtime).

        Replay needs the full ordered shard sequence, so any stale shard
        invalidates the cache: the stale files are removed here and the
        caller evicts + re-populates on the next pass.
        """
        if self.ttl_s is None:
            return False
        cutoff = time.time() - self.ttl_s

        def is_stale(path: str) -> bool:
            try:
                return os.path.getmtime(path) <= cutoff
            except OSError:        # vanished (e.g. swept by another process)
                return True

        stale = [p for p in self.paths if is_stale(p)]
        for path in stale:
            try:
                os.remove(path)
            except OSError:
                pass
        self.ttl_dropped += len(stale)
        return bool(stale)

    def _ttl_sweep_dir(self) -> None:
        """Populate-time sweep: clear stale ``sig_*.sig`` leftovers from a
        shared/persistent ``cache_dir`` (files this instance never wrote)
        before writing fresh shards over them."""
        if self.ttl_s is None:
            return
        import glob as _glob
        cutoff = time.time() - self.ttl_s
        for path in _glob.glob(os.path.join(self.cache_dir, "sig_*.sig")):
            try:
                if os.path.getmtime(path) <= cutoff:
                    os.remove(path)
                    self.ttl_dropped += 1
            except OSError:
                pass

    # -- lifecycle ------------------------------------------------------
    def evict(self) -> None:
        """Drop all cached shards; the next pass hashes and re-populates."""
        for path in self.paths:
            try:
                os.remove(path)
            except OSError:
                pass
        self.paths = []
        self.populated = False
        self._tail_resume = None
        self.stats = CacheStats()

    def close(self) -> None:
        """Evict shards and delete the cache dir if this cache owns it."""
        if self.closed:
            return
        self.evict()
        if self._owns_dir:
            shutil.rmtree(self.cache_dir, ignore_errors=True)
            if self._finalizer is not None:
                self._finalizer.detach()
        self.closed = True

    def __enter__(self) -> "SignatureCache":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- epoch 0: hash + write-through ---------------------------------
    def _encode(self, sig) -> np.ndarray:
        """Device signatures -> host packed words for storage."""
        if isinstance(sig, PackedSignatures):
            return np.asarray(sig.data)
        if _wire_spec(self.b, self.sentinel)[0] == 32:
            return np.asarray(sig).astype(np.uint32)
        spec = PackSpec(self.k, self.b, self.sentinel)
        return np.asarray(pack_device(sig, spec))

    @property
    def code_bits(self) -> int:
        """Bits per stored signature value ((b+1) for sentinel wires).

        Packed streams always satisfy 1 <= b <= 16 (engine-enforced), so
        ``_wire_spec`` is THE definition for both stream kinds.
        """
        return _wire_spec(self.b, self.sentinel)[0]

    def _populate(self):
        # the populate pass is serialized across processes sharing this
        # cache_dir on the directory's lock file (the cross-process
        # SignatureCache coordination the serving stack relies on): two
        # trainers can point at one dir and never interleave one's TTL
        # sweep with the other's shard writes.  Shard writes themselves
        # are atomic (write_sig_shard: tmp + os.replace), so a replaying
        # reader racing a later populate only ever maps complete shards.
        # The lock releases on generator close too (abandoned epochs).
        with FileLock(os.path.join(self.cache_dir, ".lock"),
                      timeout_s=self.lock_timeout_s):
            yield from self._populate_locked()

    def _populate_locked(self):
        # a partially-consumed epoch-0 pass may have written some shards
        # and read some raw bytes already; restart the accounting so
        # replay never sees duplicates and the reduction stays honest
        self.evict()
        self._ttl_sweep_dir()
        raw_bytes_before = self.stream.loader.stats.bytes_read
        budget = self.max_cache_bytes
        for i, (sig, labels) in enumerate(self.stream):
            if budget is not None and self.stats.bytes_cached >= budget:
                self.stats.uncached_chunks += 1
                self.stats.examples += len(sig)
                yield sig, labels
                continue
            t0 = time.perf_counter()
            data = self._encode(sig)
            code_bits = self.code_bits
            path = os.path.join(self.cache_dir, f"sig_{i:05d}.sig")
            meta = write_sig_shard(path, data, np.asarray(labels), k=self.k,
                                   b=self.b, code_bits=code_bits,
                                   sentinel=self.sentinel and code_bits != 32)
            self.paths.append(path)
            self.stats.bytes_cached += os.path.getsize(path)
            self.stats.bytes_payload += meta.payload_bytes
            self.stats.shards += 1
            self.stats.examples += len(sig)
            self.stats.write_s += time.perf_counter() - t0
            yield sig, labels
        self.stats.bytes_original = (self.stream.loader.stats.bytes_read
                                     - raw_bytes_before)
        if self.stats.uncached_chunks:
            # every cached chunk is full-size (a later chunk exists), so
            # the first uncached chunk starts at this stream offset; the
            # loader maps it to (shard, in-shard skip) for the replay tail
            self._tail_resume = self.stream.loader.resume_point(
                len(self.paths) * self.stream.loader.chunk_size)
        self.populated = True

    # -- epochs >= 1: replay packed shards -----------------------------
    @staticmethod
    def _read_host(path: str):
        return read_sig_shard(path, mmap=True)

    def _decode(self, payload) -> Tuple[object, jax.Array]:
        words, labels, meta = payload
        data = jnp.asarray(np.ascontiguousarray(words))  # packed words -> device
        labels = jnp.asarray(labels)
        if self.packed:
            return PackedSignatures(data, meta.k, meta.b, meta.sentinel), labels
        if meta.code_bits == 32:
            return data, labels                          # raw uint32 lanes
        spec = PackSpec(meta.k, meta.b, meta.sentinel)
        return unpack_device(data, spec), labels         # unpack ON DEVICE

    def _replay(self):
        def chunks():
            for path in self.paths:
                yield read_with_retries(self._read_host, path,
                                        self.replay_stats,
                                        deadline=self.deadline,
                                        max_retries=self.max_retries)
        for payload in prefetch_iter(chunks, self.prefetch):
            yield self._decode(payload)
        if self.stats.uncached_chunks:
            # budget-evicted tail: re-hash only the chunks past the
            # cached prefix.  Populate recorded the first uncached
            # chunk's (shard, in-shard offset), so the tail read starts
            # there -- the cached prefix's raw shards are never re-read
            # (bytes_read counts only the tail shards).
            start_shard, skip = self._tail_resume
            for chunk in self.stream.loader.iter_from(start_shard, skip):
                yield self.stream.hash_chunk(chunk)


# ---------------------------------------------------------------------------
# OnlineTrainer: the §6 epoch loop over any (signatures, labels) source
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class EpochStats:
    """Per-epoch accounting (the split behind Figs 16/18 and Table 4).

    ``load_s`` is time the trainer waited on the source -- on a "hash"
    epoch that includes the hashing kernel (``kernel_s`` reports the
    device portion separately); on a "cache" epoch it is pure replay I/O.
    """

    epoch: int
    source: str                  # "hash" (fresh stream) | "cache" (replay)
    load_s: float = 0.0
    kernel_s: float = 0.0
    train_s: float = 0.0
    bytes_read: int = 0
    examples: int = 0


@dataclasses.dataclass
class OnlineTrainer:
    """Streaming SGD / ASGD / logistic regression on b-bit signatures.

    ``fit`` consumes chunked ``(signatures, labels)`` sources -- a
    ``SignatureStream`` (hash every epoch) or a ``SignatureCache`` (hash
    once, replay packed shards) -- and runs the Bottou update
    (Eq. 11-12) mini-batch by mini-batch with the SGD state donated to
    the jitted step, so the weights never leave the device.  Sources may
    yield unpacked (n, k) signatures or ``PackedSignatures`` wire words;
    packed chunks are fed to the step as words and unpacked *inside* the
    jitted update (``repro.models.linear`` ``feature_kind="packed"``).

    ``kind``: ``"svm"`` (Eq. 6 hinge) or ``"logistic"`` (Eq. 7);
    ``average=True`` maintains the §6.3 ASGD iterate average and makes
    ``model``/``evaluate`` use it.  ``close()`` closes every closeable
    source this trainer consumed (e.g. owned ``SignatureCache`` temp
    dirs).
    """

    k: int
    b: int
    kind: str = "svm"
    average: bool = False
    lam: float = 1e-4
    eta0: float = 0.5
    batch_size: int = 16
    avg_start: float = 0.0
    donate: bool = True

    def __post_init__(self):
        if self.kind not in ("svm", "logistic"):
            raise ValueError(f"kind must be 'svm' or 'logistic', got {self.kind!r}")
        self.dim = self.k * (1 << self.b)
        self._steps = {}
        self.state = sgd_svm_init(self.dim, avg_start=self.avg_start)
        self.epoch_stats: List[EpochStats] = []
        self._sources: List[object] = []

    def _get_step(self, feature_kind: str, sentinel: bool = False):
        key = (feature_kind, sentinel)
        if key not in self._steps:
            step = functools.partial(
                sgd_svm_step, lam=self.lam, eta0=self.eta0, b=self.b,
                feature_kind=feature_kind, kind=self.kind,
                average=self.average,
                k=self.k if feature_kind == "packed" else None,
                sentinel=sentinel)
            self._steps[key] = (jax.jit(step, donate_argnums=(0,))
                                if self.donate else jax.jit(step))
        return self._steps[key]

    @property
    def model(self):
        return asgd_model(self.state) if self.average else self.state.model

    def evaluate(self, sig_b, labels: jax.Array) -> float:
        if isinstance(sig_b, PackedSignatures):
            if (sig_b.k, sig_b.b) != (self.k, self.b):
                raise ValueError(
                    f"packed eval set has (k={sig_b.k}, b={sig_b.b}), "
                    f"trainer expects (k={self.k}, b={self.b}) -- a "
                    "mismatched wire would decode silently wrong")
            return float(accuracy(self.model, sig_b.data, labels,
                                  feature_kind="packed", b=self.b,
                                  k=sig_b.k, sentinel=sig_b.sentinel))
        return float(accuracy(self.model, sig_b, labels,
                              feature_kind="hashed", b=self.b))

    def close(self) -> None:
        """Close every closeable source consumed by ``fit`` (cache dirs)."""
        for src in self._sources:
            closer = getattr(src, "close", None)
            if callable(closer):
                closer()
        self._sources = []

    def __enter__(self) -> "OnlineTrainer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def fit(self, source: Iterable, n_epochs: int,
            eval_fn: Optional[Callable[["OnlineTrainer"], float]] = None
            ) -> Tuple[object, List[EpochStats], List[float]]:
        """Run ``n_epochs`` passes over ``source``.

        Returns ``(final SGDState, this call's per-epoch EpochStats,
        this call's per-epoch evals)`` -- the two lists always align;
        ``eval_fn`` (if given) is called with the trainer after each
        epoch.  ``self.epoch_stats`` accumulates across ``fit`` calls so
        a warm trainer can keep training.
        """
        if not any(src is source for src in self._sources):
            self._sources.append(source)
        evals: List[float] = []
        first = len(self.epoch_stats)
        for _ in range(n_epochs):
            before = dict(getattr(source, "cumulative_stats", None) or {})
            es = EpochStats(epoch=len(self.epoch_stats),
                            source=before.get("source", "stream"))
            t_mark = time.perf_counter()
            for sig, labels in source:
                t_loaded = time.perf_counter()
                es.load_s += t_loaded - t_mark
                if isinstance(sig, PackedSignatures):
                    feats = sig.data
                    step = self._get_step("packed", sig.sentinel)
                else:
                    feats = jnp.asarray(sig)
                    step = self._get_step("hashed")
                y = jnp.asarray(labels)
                n = feats.shape[0]
                for i in range(0, n, self.batch_size):
                    self.state = step(self.state,
                                      feats[i:i + self.batch_size],
                                      y[i:i + self.batch_size])
                jax.block_until_ready(self.state.model.w)
                es.examples += n
                t_mark = time.perf_counter()
                es.train_s += t_mark - t_loaded
            after = dict(getattr(source, "cumulative_stats", None) or {})
            es.kernel_s = after.get("kernel_s", 0.0) - before.get("kernel_s", 0.0)
            es.bytes_read = after.get("bytes_read", 0) - before.get("bytes_read", 0)
            self.epoch_stats.append(es)
            evals.append(float(eval_fn(self)) if eval_fn else float("nan"))
        return self.state, self.epoch_stats[first:], evals
