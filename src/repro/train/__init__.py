from repro.train.trainer import (EpochTimes, TrainState, Trainer,
                                 make_train_step, online_epochs)
from repro.train.online import (CacheStats, EpochStats, OnlineTrainer,
                                SignatureCache, make_family)
from repro.train import checkpoint
from repro.train.elastic import replicate_shardings, reshard_restore
from repro.train.fault import Heartbeat, RestartStats, run_with_restarts

__all__ = [
    "EpochTimes", "TrainState", "Trainer", "make_train_step",
    "online_epochs", "CacheStats", "EpochStats", "OnlineTrainer",
    "SignatureCache", "make_family", "checkpoint", "replicate_shardings",
    "reshard_restore", "Heartbeat", "RestartStats", "run_with_restarts",
]
