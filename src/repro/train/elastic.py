"""Elastic scaling: move a checkpoint onto a different mesh.

A checkpoint saved on an N-device mesh can be restored onto an M-device
mesh (M != N): arrays are loaded on host and ``jax.device_put`` under the
*new* shardings derived from the same logical sharding rules.  This is the
standard elastic-rescale path (grow after capacity arrives, shrink around
failed pods) -- the mesh shape is a pure runtime choice, never baked into
the checkpoint.
"""

from __future__ import annotations

from typing import Any, Callable, Optional

import jax

from repro.train import checkpoint as ckpt_lib


def reshard_restore(ckpt_dir: str, template: Any,
                    sharding_fn: Callable[[Any], Any],
                    step: Optional[int] = None) -> tuple[Any, int]:
    """Restore ``template``-shaped state with shardings from sharding_fn.

    ``sharding_fn(template) -> pytree of jax.sharding.Sharding`` evaluated
    against the *new* mesh.  Works across device counts because the npz
    checkpoint stores full (unsharded) arrays per host.
    """
    shardings = sharding_fn(template)
    return ckpt_lib.restore(ckpt_dir, template, step=step,
                            shardings=shardings)


def replicate_shardings(template: Any, mesh) -> Any:
    """All-replicated shardings (the trivially correct fallback)."""
    from jax.sharding import NamedSharding, PartitionSpec
    rep = NamedSharding(mesh, PartitionSpec())
    return jax.tree_util.tree_map(lambda _: rep, template)
