"""Fault-tolerance control plane: bounded retry, heartbeat/straggler
deadline, restart-from-checkpoint.

On a real multi-pod fleet the failure domain is a host/chip; here the same
control logic wraps the training loop so it is tested end-to-end:

  * ``run_with_restarts`` executes a step function; on exception it
    restores the latest checkpoint and replays from there, up to
    ``max_failures`` times (then re-raises).
  * ``Heartbeat`` tracks per-step wall time; a step exceeding
    ``deadline_s`` is flagged as a straggler.  Callers can react (skip the
    slow data shard, re-issue the step, or exclude the worker) -- the data
    pipeline's shard-reassignment hook consumes this signal.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, List, Optional, Tuple


@dataclasses.dataclass
class Heartbeat:
    deadline_s: float
    history: List[float] = dataclasses.field(default_factory=list)
    stragglers: int = 0

    def observe(self, step_seconds: float) -> bool:
        """Record a step time; returns True if it was a straggler."""
        self.history.append(step_seconds)
        if step_seconds > self.deadline_s:
            self.stragglers += 1
            return True
        return False

    def adaptive_deadline(self, factor: float = 3.0, min_history: int = 8
                          ) -> float:
        """Deadline = factor x median of recent steps (self-tuning)."""
        if len(self.history) < min_history:
            return self.deadline_s
        recent = sorted(self.history[-64:])
        return factor * recent[len(recent) // 2]


@dataclasses.dataclass
class RestartStats:
    failures: int = 0
    restarts_from: List[int] = dataclasses.field(default_factory=list)


def run_with_restarts(
    *,
    init_state: Any,
    init_step: int,
    run_steps: Callable[[Any, int], Tuple[Any, int]],
    restore_fn: Callable[[], Tuple[Any, int]],
    max_failures: int = 3,
) -> Tuple[Any, int, RestartStats]:
    """Drive ``run_steps(state, step) -> (state, step)`` to completion.

    ``run_steps`` raising is treated as a node failure: the latest
    checkpoint is restored via ``restore_fn`` and execution resumes.  The
    exception is re-raised once ``max_failures`` is exhausted (fail-stop
    rather than silent data corruption).
    """
    stats = RestartStats()
    state, step = init_state, init_step
    while True:
        try:
            return (*run_steps(state, step), stats)
        except KeyboardInterrupt:
            raise
        except Exception:
            stats.failures += 1
            if stats.failures > max_failures:
                raise
            state, step = restore_fn()
            stats.restarts_from.append(step)
