"""Step-atomic sharded checkpointing with keep-N GC and resume.

Layout:  <dir>/step_00001234/  arrays.npz  meta.json
Writes go to ``<dir>/.tmp_step_xxx`` then ``os.replace`` (atomic on POSIX),
so a crash mid-write never corrupts the latest checkpoint -- the
fault-tolerance contract the restart path relies on.

Arrays are flattened with their tree paths as keys, so restore works into
any pytree with the same structure, and ``restore_resharded`` can place
each array under a *different* sharding/mesh than it was saved with
(elastic scaling; see repro.train.elastic).
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d{8})$")


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save(ckpt_dir: str, step: int, tree: Any, *, keep: int = 3,
         extra_meta: Optional[dict] = None) -> str:
    """Save a pytree checkpoint. Returns the final directory path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    name = f"step_{step:08d}"
    tmp = os.path.join(ckpt_dir, f".tmp_{name}_{os.getpid()}")
    final = os.path.join(ckpt_dir, name)
    os.makedirs(tmp, exist_ok=True)

    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    arrays = {}
    dtypes = {}
    for path, leaf in flat:
        key = _path_str(path)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype == np.dtype("bfloat16"):
            dtypes[key] = "bfloat16"
            arr = arr.view(np.uint16)
        arrays[key] = arr
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    meta = {"step": step, "time": time.time(), "n_arrays": len(arrays),
            "bf16_keys": dtypes, **(extra_meta or {})}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    _gc(ckpt_dir, keep)
    return final


def save_async(ckpt_dir: str, step: int, tree: Any, *, keep: int = 3
               ) -> threading.Thread:
    """Fire-and-forget checkpoint write (device_get happens up front so the
    training loop can continue mutating device state)."""
    host_tree = jax.tree_util.tree_map(lambda x: np.asarray(jax.device_get(x)),
                                       tree)
    t = threading.Thread(target=save, args=(ckpt_dir, step, host_tree),
                         kwargs={"keep": keep}, daemon=True)
    t.start()
    return t


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        m = _STEP_RE.match(d)
        if m and os.path.exists(os.path.join(ckpt_dir, d, "meta.json")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore(ckpt_dir: str, template: Any, step: Optional[int] = None,
            shardings: Any = None) -> tuple[Any, int]:
    """Restore into the structure of ``template``.

    If ``shardings`` (a matching pytree of jax.sharding.Sharding or None) is
    given, each array is device_put under it -- this is the elastic-rescale
    entry point.
    """
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    bf16_keys = meta.get("bf16_keys", {})
    data = np.load(os.path.join(d, "arrays.npz"))

    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_flat = (jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: x is None or hasattr(x, "device_set"))
        if shardings is not None else [None] * len(flat))
    leaves = []
    for (path, leaf), shd in zip(flat, shard_flat):
        key = _path_str(path)
        arr = data[key]
        if key in bf16_keys:
            arr = arr.view(jax.numpy.bfloat16.dtype)
        if shd is not None:
            leaves.append(jax.device_put(arr, shd))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return treedef.unflatten(leaves), step


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(
        int(m.group(1)) for d in os.listdir(ckpt_dir)
        if (m := _STEP_RE.match(d)))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
