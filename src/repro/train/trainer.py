"""Generic training loop machinery.

``make_train_step`` turns (loss_fn, optimizer) into a jit-able pure step;
``Trainer`` adds the production loop around it: checkpoint/resume, async
saves, heartbeat/straggler tracking, bounded-retry restart.  The online-
learning path (paper §6) additionally accounts load-time vs train-time per
epoch, which is the quantity the paper's Table 4 reports.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.optim.base import Optimizer, apply_updates
from repro.train import checkpoint as ckpt_lib
from repro.train.fault import Heartbeat, run_with_restarts


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: jax.Array

    @staticmethod
    def create(params: Any, optimizer: Optimizer) -> "TrainState":
        return TrainState(params=params, opt_state=optimizer.init(params),
                          step=jnp.zeros((), jnp.int32))


def make_train_step(loss_fn: Callable, optimizer: Optimizer,
                    ) -> Callable[[TrainState, Any], Tuple[TrainState, Dict]]:
    """loss_fn(params, batch) -> scalar. Returns step(state, batch)."""

    def step(state: TrainState, batch: Any) -> Tuple[TrainState, Dict]:
        loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
        updates, opt_state = optimizer.update(grads, state.opt_state,
                                              state.params)
        params = apply_updates(state.params, updates)
        new_state = TrainState(params=params, opt_state=opt_state,
                               step=state.step + 1)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree_util.tree_leaves(grads)))
        return new_state, {"loss": loss, "grad_norm": gnorm}

    return step


@dataclasses.dataclass
class Trainer:
    """Production loop: jit step + checkpointing + fault handling."""

    step_fn: Callable[[TrainState, Any], Tuple[TrainState, Dict]]
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 100
    keep: int = 3
    heartbeat_deadline_s: float = 120.0
    max_failures: int = 3
    jit: bool = True

    def __post_init__(self):
        self._step = jax.jit(self.step_fn) if self.jit else self.step_fn
        self.heartbeat = Heartbeat(deadline_s=self.heartbeat_deadline_s)
        self.metrics_log: list[Dict] = []

    def maybe_resume(self, state: TrainState) -> TrainState:
        if self.ckpt_dir and ckpt_lib.latest_step(self.ckpt_dir) is not None:
            state, _ = ckpt_lib.restore(self.ckpt_dir, state)
        return state

    def fit(self, state: TrainState, batches: Callable[[], Iterable[Any]],
            n_steps: int) -> TrainState:
        """Run up to n_steps over (repeatable) batch streams with restarts."""

        def run(st: TrainState, from_step: int):
            step_no = from_step
            it = iter(batches())
            # skip batches already consumed before the restart
            for _ in range(from_step):
                next(it, None)
            for batch in it:
                if step_no >= n_steps:
                    break
                t0 = time.perf_counter()
                st, metrics = self._step(st, batch)
                jax.block_until_ready(st.params)
                self.heartbeat.observe(time.perf_counter() - t0)
                step_no += 1
                self.metrics_log.append(
                    {k: float(v) for k, v in metrics.items()})
                if self.ckpt_dir and step_no % self.ckpt_every == 0:
                    ckpt_lib.save(self.ckpt_dir, step_no, st, keep=self.keep)
            if self.ckpt_dir:
                ckpt_lib.save(self.ckpt_dir, step_no, st, keep=self.keep)
            return st, step_no

        def restore():
            step = ckpt_lib.latest_step(self.ckpt_dir) or 0
            st, step = ckpt_lib.restore(self.ckpt_dir, state, step=step)
            return st, step

        if not self.ckpt_dir:
            st, _ = run(state, 0)
            return st
        st, _, _ = run_with_restarts(
            init_state=state, init_step=0, run_steps=run,
            restore_fn=restore, max_failures=self.max_failures)
        return st


# ---------------------------------------------------------------------------
# Online-learning epoch loop with load/train accounting (paper §6)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class EpochTimes:
    load_s: float = 0.0
    train_s: float = 0.0


def online_epochs(sgd_step: Callable, state: Any,
                  epoch_batches: Callable[[], Iterable[Any]],
                  n_epochs: int,
                  eval_fn: Optional[Callable[[Any], float]] = None
                  ) -> Tuple[Any, list, list]:
    """Run SGD epochs; re-load data each epoch (paper's disk-resident setup).

    Returns (final state, per-epoch EpochTimes, per-epoch eval metrics).
    The loading cost appearing once *per epoch* is exactly why the paper's
    size reduction matters for online learning.
    """
    times, evals = [], []
    for _ in range(n_epochs):
        et = EpochTimes()
        t_iter = time.perf_counter()
        for batch in epoch_batches():
            t_loaded = time.perf_counter()
            et.load_s += t_loaded - t_iter
            state = sgd_step(state, batch)
            jax.block_until_ready(jax.tree_util.tree_leaves(state)[0])
            t_iter = time.perf_counter()
            et.train_s += t_iter - t_loaded
        times.append(et)
        evals.append(float(eval_fn(state)) if eval_fn else float("nan"))
    return state, times, evals
