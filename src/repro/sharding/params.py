"""Parameter / optimizer-state PartitionSpec trees per architecture family.

LMs use FSDP+TP: the tensor-parallel ("model") axis shards heads / d_ff /
vocab / experts; the FSDP ("data") axis shards the complementary matrix
dim (ZeRO-3 -- optimizer state shards identically since it mirrors the
param tree).  GNN params are tiny -> replicated.  RecSys embedding tables
are row-sharded over "model".

Specs are produced by *path+shape rules* against ``jax.eval_shape`` of the
init function, so they always match the real pytree structure.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
from jax.sharding import PartitionSpec as P


def _path_keys(path) -> Tuple[str, ...]:
    keys = []
    for p in path:
        if hasattr(p, "key"):
            keys.append(str(p.key))
        elif hasattr(p, "idx"):
            keys.append(f"[{p.idx}]")
        else:
            keys.append(str(p))
    return tuple(keys)


def _norm_spec(spec: P, rank: int) -> Tuple:
    t = tuple(spec) + (None,) * (rank - len(tuple(spec)))
    return t[:rank]


# -- LM rules ---------------------------------------------------------------

_COL_PARALLEL = {"wq", "wk", "wv", "wdq", "wuq", "wdkv", "wukv", "wkr",
                 "w_gate", "w_up"}          # (.., in, out): out -> model
_ROW_PARALLEL = {"wo", "w_down"}            # (.., in, out): in -> model


def lm_param_specs(shapes: Any) -> Any:
    def rule(path, leaf):
        keys = _path_keys(path)
        name = keys[-1]
        rank = len(leaf.shape)
        in_layer_stack = any(k in ("layers", "dense_layers") for k in keys)
        lead = (None,) if in_layer_stack else ()
        if name == "embed":
            # Vocab FSDP'd over the data axes only: XLA's partitioned
            # gather/scatter for vocab-sharded tables is the one robust
            # path (sharding d as well trips SPMD gather bugs for some
            # (V, d) shapes, and a d-mismatched layout forces full
            # replication of the (T, d) cotangent in the bwd scatter).
            return P("data", None)
        if name == "out":
            return P(None, "model")     # vocab-parallel logits
        if name in ("final_norm",):
            return P()
        if name == "router":
            return P()           # replicated: shard_map EP needs it whole
        in_moe_experts = rank == 4 or (rank == 3 and not in_layer_stack)
        if in_moe_experts and name in (_COL_PARALLEL | _ROW_PARALLEL):
            # EP group spans as many mesh axes as E divides into (matches
            # models.moe.ep_layout): 256-expert models cover the whole
            # ("model", "data") pod, 1 expert/chip, remaining d_ff FSDP
            # over "pod"; small-E models keep E on "model" and FSDP d_ff
            # over ("data", "pod").  Tuples are literal; the launcher
            # greedy-drops axes that don't divide.
            E = leaf.shape[1] if rank == 4 else leaf.shape[0]
            if E % 256 == 0:
                e_ax, f_ax = ("model", "data"), ("pod",)
            else:
                e_ax, f_ax = ("model",), ("data", "pod")
            if name in _COL_PARALLEL:    # (L, E, d, f)
                return P(None, e_ax, None, f_ax) if rank == 4 \
                    else P(e_ax, None, f_ax)
            return P(None, e_ax, f_ax, None) if rank == 4 \
                else P(e_ax, f_ax, None)
        if name in _COL_PARALLEL:
            return P(*lead, "data", "model")
        if name in _ROW_PARALLEL:
            return P(*lead, "model", "data")
        return P()               # norms and other vectors: replicated

    return jax.tree_util.tree_map_with_path(rule, shapes)


# -- GNN rules --------------------------------------------------------------

def gnn_param_specs(shapes: Any) -> Any:
    return jax.tree_util.tree_map(lambda _: P(), shapes)


# -- RecSys rules -----------------------------------------------------------

def recsys_param_specs(shapes: Any) -> Any:
    def rule(path, leaf):
        name = _path_keys(path)[-1]
        if name in ("tables", "wide", "minhash_table"):
            return P(None, "model", None)
        if name == "item_table":
            return P("model", None)
        return P()

    return jax.tree_util.tree_map_with_path(rule, shapes)


def param_specs_for(family: str, shapes: Any) -> Any:
    return {"lm": lm_param_specs, "gnn": gnn_param_specs,
            "recsys": recsys_param_specs}[family](shapes)


# -- optimizer-state specs (mirror the param tree) ---------------------------

def opt_state_specs(param_specs: Any, param_shapes: Any,
                    opt_shapes: Any) -> Any:
    """Derive opt-state specs: moments mirror their parameter's spec;
    Adafactor's factored stats drop the corresponding dim; scalars
    replicate."""
    spec_by_path: Dict[Tuple[str, ...], Tuple] = {}
    flat, _ = jax.tree_util.tree_flatten_with_path(param_shapes)
    spec_flat = jax.tree_util.tree_leaves(
        param_specs, is_leaf=lambda x: isinstance(x, P))
    for (path, leaf), spec in zip(flat, spec_flat):
        spec_by_path[_path_keys(path)] = _norm_spec(spec, len(leaf.shape))

    def rule(path, leaf):
        keys = _path_keys(path)
        if keys and keys[0] in ("m", "v", "mu"):
            rest = keys[1:]
            if rest in spec_by_path:
                return P(*spec_by_path[rest])
            if rest and rest[-1] == "vr" and rest[:-1] in spec_by_path:
                s = spec_by_path[rest[:-1]]
                return P(*s[:-1])
            if rest and rest[-1] == "vc" and rest[:-1] in spec_by_path:
                s = spec_by_path[rest[:-1]]
                return P(*(s[:-2] + s[-1:]))
        return P()

    return jax.tree_util.tree_map_with_path(rule, opt_shapes)
