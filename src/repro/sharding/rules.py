"""Logical sharding rules and the mesh-context constraint helper.

Models call ``constrain(x, "batch", None, "model")`` with *logical* axis
names; under an active mesh (set by ``set_mesh`` in the launcher/dry-run)
this becomes ``with_sharding_constraint``; with no mesh it is a no-op, so
the same model code runs in single-device smoke tests and 512-chip
dry-runs.

Logical -> physical:
  "batch"  -> all data-parallel axes present in the mesh ("pod", "data")
  "model"  -> the tensor/expert-parallel axis ("model")
  "data"   -> FSDP weight sharding axis ("data")
  None     -> replicated
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


def current_mesh() -> Optional[Mesh]:
    return getattr(_STATE, "mesh", None)


@contextlib.contextmanager
def set_mesh(mesh: Optional[Mesh]):
    prev = current_mesh()
    _STATE.mesh = mesh
    try:
        yield
    finally:
        _STATE.mesh = prev


def _resolve(axis, mesh: Mesh):
    if axis is None:
        return None
    if isinstance(axis, tuple):
        # tuple members are literal mesh axes ("data" does NOT expand to
        # pod+data here), except the logical names "batch" / "all"
        out = []
        for a in axis:
            if a in ("batch", "all"):
                r = _resolve(a, mesh)
                if isinstance(r, tuple):
                    out.extend(r)
                elif r is not None:
                    out.append(r)
            elif a in mesh.axis_names:
                out.append(a)
        return tuple(dict.fromkeys(out)) or None
    if axis == "all":
        return tuple(mesh.axis_names)
    if axis in ("batch", "data"):
        # "batch" (activations) and "data" (FSDP weight sharding) both
        # span every data-parallel axis: ("pod", "data") on the multi-pod
        # mesh -- ZeRO-3 over all DP ranks is what lets 671B state fit.
        axes = tuple(n for n in ("pod", "data") if n in mesh.axis_names)
        return axes if axes else None
    if axis in mesh.axis_names:
        return axis
    return None


def spec(*axes) -> P:
    mesh = current_mesh()
    if mesh is None:
        return P()
    return P(*[_resolve(a, mesh) for a in axes])


def _axis_size(mesh: Mesh, resolved) -> int:
    if resolved is None:
        return 1
    if isinstance(resolved, tuple):
        out = 1
        for r in resolved:
            out *= mesh.shape[r]
        return out
    return mesh.shape[resolved]


def constrain(x: jax.Array, *axes) -> jax.Array:
    """Sharding constraint by logical axis names; no-op without a mesh.

    Drops any axis whose mesh extent does not evenly divide the dim size
    (e.g. 56 heads over a 16-way model axis), so model code never has to
    special-case divisibility.
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    resolved = [_resolve(a, mesh) for a in axes]
    cleaned = []
    for dim, r in zip(x.shape, resolved):
        if r is not None and dim % _axis_size(mesh, r) != 0:
            r = None
        cleaned.append(r)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*cleaned)))


def named_sharding(*axes) -> Optional[NamedSharding]:
    mesh = current_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, spec(*axes))
