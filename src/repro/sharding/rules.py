"""Logical sharding rules and the mesh-context constraint helper.

Models call ``constrain(x, "batch", None, "model")`` with *logical* axis
names; under an active mesh (set by ``set_mesh`` in the launcher/dry-run)
this becomes ``with_sharding_constraint``; with no mesh it is a no-op, so
the same model code runs in single-device smoke tests and 512-chip
dry-runs.

Logical -> physical:
  "batch"  -> all data-parallel axes present in the mesh ("pod", "data")
  "model"  -> the tensor/expert-parallel axis ("model")
  "data"   -> FSDP weight sharding axis ("data")
  None     -> replicated

The retrieval stack adds a *placement* rule on top: ``place_shards``
maps S ``.idx`` shards onto the D devices of the mesh's ``"data"`` axis
round-robin (shard s -> device s mod D).  The mapping depends only on
the shard's position, so growing the tail of the shard list (a live
append or spill) never moves an already-placed shard -- the property
``ShardedIndex.refresh`` relies on to keep unchanged shards'
device-resident corpora warm.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


def current_mesh() -> Optional[Mesh]:
    return getattr(_STATE, "mesh", None)


@contextlib.contextmanager
def set_mesh(mesh: Optional[Mesh]):
    prev = current_mesh()
    _STATE.mesh = mesh
    try:
        yield
    finally:
        _STATE.mesh = prev


def _resolve(axis, mesh: Mesh):
    if axis is None:
        return None
    if isinstance(axis, tuple):
        # tuple members are literal mesh axes ("data" does NOT expand to
        # pod+data here), except the logical names "batch" / "all"
        out = []
        for a in axis:
            if a in ("batch", "all"):
                r = _resolve(a, mesh)
                if isinstance(r, tuple):
                    out.extend(r)
                elif r is not None:
                    out.append(r)
            elif a in mesh.axis_names:
                out.append(a)
        return tuple(dict.fromkeys(out)) or None
    if axis == "all":
        return tuple(mesh.axis_names)
    if axis in ("batch", "data"):
        # "batch" (activations) and "data" (FSDP weight sharding) both
        # span every data-parallel axis: ("pod", "data") on the multi-pod
        # mesh -- ZeRO-3 over all DP ranks is what lets 671B state fit.
        axes = tuple(n for n in ("pod", "data") if n in mesh.axis_names)
        return axes if axes else None
    if axis in mesh.axis_names:
        return axis
    return None


def spec(*axes) -> P:
    mesh = current_mesh()
    if mesh is None:
        return P()
    return P(*[_resolve(a, mesh) for a in axes])


def _axis_size(mesh: Mesh, resolved) -> int:
    if resolved is None:
        return 1
    if isinstance(resolved, tuple):
        out = 1
        for r in resolved:
            out *= mesh.shape[r]
        return out
    return mesh.shape[resolved]


def constrain(x: jax.Array, *axes) -> jax.Array:
    """Sharding constraint by logical axis names; no-op without a mesh.

    Drops any axis whose mesh extent does not evenly divide the dim size
    (e.g. 56 heads over a 16-way model axis), so model code never has to
    special-case divisibility.
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    resolved = [_resolve(a, mesh) for a in axes]
    cleaned = []
    for dim, r in zip(x.shape, resolved):
        if r is not None and dim % _axis_size(mesh, r) != 0:
            r = None
        cleaned.append(r)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*cleaned)))


def named_sharding(*axes) -> Optional[NamedSharding]:
    mesh = current_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, spec(*axes))


# ---------------------------------------------------------------------------
# Shard placement (the retrieval mesh)
# ---------------------------------------------------------------------------

def data_axis_devices(mesh: Mesh, axis: str = "data"
                      ) -> Tuple[jax.Device, ...]:
    """The device per position along one named mesh axis.

    Collapses every other axis to its first coordinate, so a 2-D
    ``("data", "model")`` mesh yields one representative device per
    data-parallel rank -- the device set the retrieval fan-out places
    shards on.
    """
    if axis not in mesh.axis_names:
        raise ValueError(f"mesh {mesh.axis_names} has no {axis!r} axis")
    i = mesh.axis_names.index(axis)
    devs = np.moveaxis(np.asarray(mesh.devices), i, 0)
    return tuple(devs.reshape(devs.shape[0], -1)[:, 0])


def place_shards(n_shards: int, mesh: Optional[Mesh] = None, *,
                 axis: str = "data") -> Optional[Tuple[jax.Device, ...]]:
    """Round-robin shard -> device placement along the ``"data"`` axis.

    Shard s lands on device ``s % D`` (D = the axis extent).  Returns
    one device per shard, or None with no mesh (single-device serving,
    no placement).  Because the mapping is a pure function of the shard
    position, appending or spilling NEW shards at the tail never
    relocates an existing shard -- ``refresh()`` after a tail-only
    mutation keeps every unchanged shard on its device.
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    mesh = mesh if mesh is not None else current_mesh()
    if mesh is None:
        return None
    devs = data_axis_devices(mesh, axis)
    return tuple(devs[s % len(devs)] for s in range(n_shards))
