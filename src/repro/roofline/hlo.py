"""Parse compiled (post-SPMD) HLO text for collective traffic.

``cost_analysis()`` gives FLOPs and HBM bytes but NOT collective bytes;
those are recovered by scanning the optimized HLO for all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute ops and
summing their operand sizes (per the roofline spec).
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

# shapes like  bf16[128,4096]{1,0}  or f32[] ; tuples handled by findall
_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
# an HLO instruction line:  %name = <shape(s)> opcode(...)
_INSTR_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\S+)\s+("
    + "|".join(COLLECTIVE_OPS) + r")(?:-start|-done)?\(")


def shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            if d:
                n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Tuple[int, Dict[str, int]]:
    """Total operand bytes moved by collectives (per device), by op kind.

    Operand sizes are read from the *result* shape of each collective line
    (for all-reduce in == out; for all-gather the result is the gathered
    tensor -- an upper bound on wire bytes; for reduce-scatter the operand
    side dominates, also captured since HLO prints operand shapes in the
    call args; we take max(result, operands) per line as the traffic
    proxy).
    """
    per_kind: Dict[str, int] = defaultdict(int)
    count: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        if "-done(" in line:
            continue    # avoid double counting async start/done pairs
        shapes = _SHAPE_RE.findall(line)
        if not shapes:
            continue
        head, tail = line.split("(", 1)
        result_bytes = sum(shape_bytes(d, s)
                           for d, s in _SHAPE_RE.findall(head))
        operand_bytes = sum(shape_bytes(d, s)
                            for d, s in _SHAPE_RE.findall(tail))
        per_kind[kind] += max(result_bytes, operand_bytes)
        count[kind] += 1
    total = sum(per_kind.values())
    per_kind = dict(per_kind)
    per_kind["_counts"] = dict(count)
    return total, per_kind


def count_ops(hlo_text: str, opcode: str) -> int:
    return len(re.findall(rf"=\s*\S+\s+{re.escape(opcode)}\(", hlo_text))
