"""Three-term roofline from a compiled dry-run artifact.

    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

cost_analysis() reports per-partition numbers under SPMD (the compiled
module is the per-device program), so chips is already divided out of
FLOPs/bytes; collective bytes are parsed per-device from the HLO.  The
dominant term is the projected step time; MODEL_FLOPS / HLO_FLOPs
measures how much compiled compute is 'useful'.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional

from repro.roofline import hardware as hw
from repro.roofline.hlo import collective_bytes


@dataclasses.dataclass
class Roofline:
    arch: str
    cell: str
    mesh: str
    chips: int
    hlo_flops_per_chip: float
    hlo_bytes_per_chip: float
    coll_bytes_per_chip: float
    coll_breakdown: Dict[str, Any]
    model_flops: float
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    bottleneck: str = ""
    useful_flop_frac: float = 0.0
    peak_fraction: float = 0.0
    memory_per_chip_bytes: float = 0.0

    def finalize(self) -> "Roofline":
        self.compute_s = self.hlo_flops_per_chip / hw.PEAK_FLOPS_BF16
        self.memory_s = self.hlo_bytes_per_chip / hw.HBM_BW
        self.collective_s = self.coll_bytes_per_chip / hw.ICI_BW
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        self.bottleneck = max(terms, key=terms.get)
        total_hlo = self.hlo_flops_per_chip * self.chips
        self.useful_flop_frac = (self.model_flops / total_hlo
                                 if total_hlo else 0.0)
        # roofline fraction: useful model FLOPs per chip over the time the
        # dominant term implies, normalized by peak
        step_s = max(terms.values())
        if step_s > 0:
            achieved = self.model_flops / self.chips / step_s
            self.peak_fraction = achieved / hw.PEAK_FLOPS_BF16
        return self

    def row(self) -> str:
        return (f"| {self.arch} | {self.cell} | {self.mesh} | "
                f"{self.compute_s*1e3:.2f} | {self.memory_s*1e3:.2f} | "
                f"{self.collective_s*1e3:.2f} | {self.bottleneck} | "
                f"{self.useful_flop_frac:.2f} | {self.peak_fraction:.3f} |")


def lm_model_flops(cfg, batch: int, seq: int, training: bool = True) -> float:
    """6*N_active*D (training) or 2*N_active*D (inference forward)."""
    from repro.models.transformer import count_active_params
    n_active = count_active_params(cfg)
    mult = 6.0 if training else 2.0
    return mult * n_active * batch * seq


def gnn_model_flops(cfg, n_nodes: int, n_edges: int, training: bool = True
                    ) -> float:
    """GatedGCN: 5 dense d^2 matmuls per node + 2 d-muls per edge, x layers."""
    d = cfg.d_hidden
    per_layer = 2.0 * (5 * n_nodes * d * d + 2 * n_edges * d)
    total = cfg.n_layers * per_layer
    return (3.0 if training else 1.0) * total


def recsys_model_flops(cfg, batch: int, training: bool = True) -> float:
    """Dense interaction+MLP FLOPs per example (lookup is memory-bound)."""
    d = cfg.embed_dim
    fl = 0.0
    if cfg.interaction == "self-attn":
        F = cfg.n_fields + (1 if cfg.use_minhash_frontend else 0)
        d_in = d
        for _ in range(cfg.n_attn_layers):
            h = cfg.n_attn_heads * cfg.d_attn
            fl += 2.0 * F * d_in * h * 4          # q,k,v,res projections
            fl += 2.0 * F * F * h * 2             # scores + weighted sum
            d_in = h
        fl += 2.0 * F * d_in * 1
    elif cfg.interaction == "concat":
        dims = (cfg.n_fields * d + (d if cfg.use_minhash_frontend else 0),) \
            + tuple(cfg.mlp_dims) + (1,)
        for a, b in zip(dims[:-1], dims[1:]):
            fl += 2.0 * a * b
    elif cfg.interaction == "target-attn":
        L = cfg.seq_len
        dims = (4 * d,) + tuple(cfg.attn_mlp_dims) + (1,)
        per_step = sum(2.0 * a * b for a, b in zip(dims[:-1], dims[1:]))
        fl += L * per_step + 2.0 * L * d
        head = (3 * d,) + tuple(cfg.mlp_dims) + (1,)
        fl += sum(2.0 * a * b for a, b in zip(head[:-1], head[1:]))
    else:   # multi-interest
        L, K = cfg.seq_len, cfg.n_interests
        fl += 2.0 * L * d * d                      # h @ S
        fl += cfg.capsule_iters * (2.0 * L * K * d * 2)
        fl += 2.0 * K * d * d + 2.0 * K * d
    if cfg.use_minhash_frontend:
        fl += 2.0 * cfg.minhash_k * d              # signature bag adds
    return (3.0 if training else 1.0) * fl * batch


def model_flops_for(program, smoke: bool = False) -> float:
    cfg = program.config
    av = program.input_avals
    if program.family == "lm":
        if program.kind == "lm_train":
            B, S = av["tokens"].shape
            return lm_model_flops(cfg, B, S, training=True)
        if program.kind == "lm_prefill":
            B, S = av["tokens"].shape
            return lm_model_flops(cfg, B, S, training=False)
        # decode: one token over a cache of length L (attention reads the
        # cache; matmul flops are 2*N_active*B plus attention 2*B*L*H*hd*2)
        B = av["tokens"].shape[0]
        leaf = next(iter(
            l for l in __import__("jax").tree_util.tree_leaves(av["cache"])))
        L = leaf.shape[2]
        from repro.models.transformer import count_active_params
        base = 2.0 * count_active_params(cfg) * B
        if cfg.attention == "mla":
            attn = (2.0 * B * L * cfg.n_heads * (cfg.kv_lora + cfg.qk_rope)
                    * 2 * cfg.n_layers)
        else:
            attn = (2.0 * B * L * cfg.n_kv * cfg.head_dim * 2 * cfg.n_layers)
        return base + attn
    if program.family == "gnn":
        N = av["node_feats"].shape[0]
        E = av["edge_index"].shape[1]
        return gnn_model_flops(cfg, N, E, training=True)
    # recsys
    some = av.get("field_ids", av.get("hist_ids"))
    B = some.shape[0]
    if program.kind == "recsys_retrieval":
        B = 1_000_000 if not smoke else 128
        return recsys_model_flops(cfg, B, training=False)
    return recsys_model_flops(cfg, B,
                              training=program.kind == "recsys_train")


def analyze(program, compiled, mesh, hlo_text: Optional[str] = None,
            smoke: bool = False) -> Roofline:
    """Roofline terms from analytic estimators; raw parsed HLO numbers are
    kept alongside (XLA:CPU counts while/scan bodies once -- see
    roofline.analytic docstring)."""
    from repro.roofline.analytic import estimate
    chips = math.prod(mesh.devices.shape)
    cost = compiled.cost_analysis()
    if isinstance(cost, list):      # older API returns [dict]
        cost = cost[0]
    raw_flops = float(cost.get("flops", 0.0))
    raw_bytes = float(cost.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    raw_coll, parsed_breakdown = collective_bytes(text)
    est = estimate(program, mesh)
    try:
        mem = compiled.memory_analysis()
        mem_bytes = float(mem.temp_size_in_bytes + mem.argument_size_in_bytes
                          + mem.output_size_in_bytes
                          - mem.alias_size_in_bytes)
    except Exception:
        mem_bytes = 0.0
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    breakdown = {"analytic": est["coll_breakdown"],
                 "parsed_hlo_once_per_loop": parsed_breakdown,
                 "raw_hlo": {"flops_per_chip": raw_flops,
                             "bytes_per_chip": raw_bytes,
                             "coll_bytes_per_chip": float(raw_coll)}}
    return Roofline(
        arch=program.arch_id, cell=program.cell_name, mesh=mesh_name,
        chips=chips,
        hlo_flops_per_chip=max(est["flops"], raw_flops),
        hlo_bytes_per_chip=max(est["bytes"], raw_bytes),
        coll_bytes_per_chip=max(est["coll"], float(raw_coll)),
        coll_breakdown=breakdown,
        model_flops=model_flops_for(program, smoke),
        memory_per_chip_bytes=mem_bytes,
    ).finalize()
