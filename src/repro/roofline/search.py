"""Analytic roofline terms for the packed-Hamming retrieval scan.

The serving benchmark (``benchmarks/search_serving.py``) measures wall
clock per flush; this module supplies the napkin-math counterpart --
how many HBM bytes and popcount FLOPs ONE exact-scan flush must move --
so the JSON artifact can track a *roofline gap* (measured time over
memory-bound predicted time) per offered load.  That gap is the
autotuning lane's steering signal (ROADMAP): block sizes and dispatch
changes should move it toward 1, and regressions show up as a widening
ratio even when absolute q/s still looks fine on a given host.

The exact scan is memory-bound: every flush streams the whole packed
corpus once past ``q`` resident query rows (PAPER.md §6's preprocessing
arithmetic -- b-bit codes exist precisely to shrink this stream), then
materializes a (q, n) score panel that the top-k reduction re-reads.

On the CPU dry-run host the measured bandwidth is nowhere near the TPU
constant, so the gap is large and only its TRAJECTORY is meaningful;
on real hardware the same artifact becomes an absolute utilization
number.  Pass ``bw=`` to re-anchor.

Besides the offline benchmark artifact, these terms also feed the LIVE
``serve_roofline_*`` gauges: ``SearchServer`` calls ``exact_scan_cost``
/ ``roofline_gap`` after every un-degraded exact flush and publishes
predicted bytes/seconds, measured seconds, the gap ratio, and achieved
GB/s through ``repro.obs.metrics`` (scrape via ``--metrics-port``).
"""

from __future__ import annotations

from typing import Dict

from repro.roofline.hardware import HBM_BW


def exact_scan_cost(n_docs: int, words: int, n_queries: int, *,
                    topk: int = 10, word_bytes: int = 4
                    ) -> Dict[str, float]:
    """Per-flush HBM bytes + FLOPs for one exact packed-Hamming scan.

    ``words`` is the packed signature width in ``word_bytes``-byte words
    (``IndexMeta`` stores uint32 words).  Terms, per flush of
    ``n_queries`` rows over an ``n_docs`` corpus:

      * corpus stream: ``n_docs * words * word_bytes`` -- read once,
        shared by every query row in the flush (the whole point of
        micro-batching: this dominant term amortizes over the batch),
      * query rows: ``n_queries * words * word_bytes``,
      * score panel: ``(q, n)`` float32 written by the scan and re-read
        by the top-k reduction, plus the ``(q, topk)`` result pair.

    FLOPs count xor+popcount+accumulate as 3 ops per packed word pair
    (scalar equivalent; vector ISAs fuse these, which the roofline's
    memory bound makes irrelevant).
    """
    if n_docs < 1 or words < 1 or n_queries < 1:
        raise ValueError(f"n_docs, words, n_queries must be >= 1, got "
                         f"({n_docs}, {words}, {n_queries})")
    corpus = float(n_docs) * words * word_bytes
    queries = float(n_queries) * words * word_bytes
    scores = 2.0 * n_queries * n_docs * 4.0          # write + top-k re-read
    out = float(n_queries) * topk * (8.0 + 4.0)      # int64 ids + f32 scores
    flops = 3.0 * n_queries * n_docs * words
    byts = corpus + queries + scores + out
    return {"bytes": byts, "flops": flops,
            "corpus_bytes": corpus,
            "bytes_per_query": byts / n_queries}


def roofline_gap(bytes_per_flush: float, flush_s: float, *,
                 bw: float = HBM_BW) -> Dict[str, float]:
    """Measured flush time against the memory-bound prediction.

    ``gap`` = measured / predicted (>= 1 on any real host; 1.0 means the
    scan runs at the roofline's bandwidth ``bw``).  ``achieved_gbps`` is
    the effective streaming bandwidth the flush actually sustained.
    """
    if bytes_per_flush <= 0 or flush_s <= 0:
        raise ValueError(f"bytes_per_flush and flush_s must be > 0, got "
                         f"({bytes_per_flush}, {flush_s})")
    predicted_s = bytes_per_flush / bw
    return {"predicted_s": predicted_s,
            "gap": flush_s / predicted_s,
            "achieved_gbps": bytes_per_flush / flush_s / 1e9}
