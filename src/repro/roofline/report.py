"""Render experiments/dryrun.jsonl into the EXPERIMENTS.md tables.

    PYTHONPATH=src python -m repro.roofline.report [--jsonl PATH]
"""

from __future__ import annotations

import argparse
import json
from collections import defaultdict


def load(path):
    recs = [json.loads(l) for l in open(path)]
    # keep the LAST record per (arch, cell, mesh)
    by_key = {}
    for r in recs:
        by_key[(r["arch"], r["cell"], r["mesh"])] = r
    return by_key


def dryrun_table(by_key) -> str:
    lines = [
        "| arch | cell | mesh | status | mem/chip GiB | fits 16G HBM | "
        "compile s |",
        "|---|---|---|---|---|---|---|",
    ]
    for (a, c, m), r in sorted(by_key.items()):
        if r["status"] == "skipped":
            lines.append(f"| {a} | {c} | {m} | SKIP: {r['reason'][:40]}… "
                         f"| – | – | – |")
            continue
        mem = r["memory"]["total_per_chip_bytes"] / 2**30
        lines.append(
            f"| {a} | {c} | {m} | ok | {mem:.2f} | "
            f"{'yes' if r['memory']['fits_hbm'] else 'no*'} | "
            f"{r['compile_s']:.0f} |")
    return "\n".join(lines)


def roofline_table(by_key, mesh="16x16") -> str:
    lines = [
        "| arch | cell | compute ms | memory ms | collective ms | "
        "bottleneck | useful FLOP frac | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for (a, c, m), r in sorted(by_key.items()):
        if m != mesh or r["status"] != "ok":
            continue
        rf = r["roofline"]
        lines.append(
            f"| {a} | {c} | {rf['compute_s'] * 1e3:.2f} | "
            f"{rf['memory_s'] * 1e3:.2f} | {rf['collective_s'] * 1e3:.2f} | "
            f"{rf['bottleneck']} | {rf['useful_flop_frac']:.2f} | "
            f"{rf['peak_fraction']:.3f} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jsonl", default="experiments/dryrun.jsonl")
    args = ap.parse_args()
    by_key = load(args.jsonl)
    print("## Dry-run matrix\n")
    print(dryrun_table(by_key))
    print("\n## Roofline (single-pod 16x16)\n")
    print(roofline_table(by_key, "16x16"))
    print("\n## Roofline (multi-pod 2x16x16)\n")
    print(roofline_table(by_key, "2x16x16"))


if __name__ == "__main__":
    main()
