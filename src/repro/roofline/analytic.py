"""Analytic per-chip FLOPs / HBM-bytes / collective-bytes estimators.

Why analytic: XLA:CPU's ``cost_analysis()`` (and the HLO text) counts each
``while`` (= ``lax.scan``) body ONCE, so a 61-layer scanned stack reports
~1/61 of the real compute, and per-layer collectives appear once.  On a
real TPU the trace/profile supplies the truth; in this CPU dry-run we take
the compiled HLO as the *structural* source (which collectives exist, with
what per-iteration shapes -- see roofline.hlo) and these napkin-math
estimators as the *magnitude* source.  Both are recorded; the roofline
terms use the estimators.

Conventions (per chip, per step):
  dp   = product of data-parallel axes (pod * data)
  tp   = model axis size
  AR(x)= ring all-reduce traffic  ~ 2 x bytes
  AG/RS of a tensor of full size x over an axis of size n ~ x (n-1)/n ~ x
Weights are re-gathered per microbatch (the FSDP cost of accumulation).
"""

from __future__ import annotations

import math
from typing import Dict


def _mesh_sizes(mesh):
    tp = mesh.shape.get("model", 1)
    dp = math.prod(s for n, s in mesh.shape.items() if n != "model")
    return dp, tp


def lm_train(cfg, B: int, S: int, n_params: int, n_active: int, mesh
             ) -> Dict[str, float]:
    dp, tp = _mesh_sizes(mesh)
    chips = dp * tp
    T = B * S
    m = max(1, cfg.microbatch)
    L = cfg.n_layers
    d = cfg.d_model
    pbytes = 2.0 * n_params                      # bf16
    pbytes_chip = pbytes / chips

    # -- FLOPs: 2N fwd + 4N bwd + 2N remat-refwd = 8N per token ----------
    mat = 8.0 * n_active * T / chips
    # attention: causal 0.5 factor; llama4 chunked-local: 3/4 of layers see
    # only their window
    if cfg.local_window > 0:
        frac = 0.25 + 0.75 * min(cfg.local_window, S) / S
    else:
        frac = 1.0
    attn_fwd = 2.0 * 2.0 * B * S * S * 0.5 * frac * cfg.n_heads \
        * cfg.head_dim * L
    attn = attn_fwd * 4.0 / chips                # fwd + refwd + 2x bwd
    flops = mat + attn

    # -- HBM bytes --------------------------------------------------------
    opt_bytes_chip = _opt_bytes(n_params) / chips
    weights = pbytes_chip * (3.0 * m + 2.0) + 2.0 * opt_bytes_chip
    stash = L * (T / dp / m) * (d / tp) * 2.0    # sharded stash, 1 µbatch
    acts = 6.0 * stash * m                       # write+read+transients
    kv_write = L * (T / dp) * 2 * cfg.n_kv * cfg.head_dim * 2.0 / tp
    byts = weights + acts + kv_write

    # -- collectives ------------------------------------------------------
    x_chip = (T / dp / m) * d * 2.0              # one µbatch's activations
    expert_bytes = 0.0
    n_moe = 0
    if cfg.is_moe:
        n_moe = L - cfg.n_dense_layers
        expert_bytes = 2.0 * n_moe * cfg.moe.n_experts * 3 * d * cfg.moe.d_ff
    dense_bytes = pbytes - expert_bytes
    fsdp_ag = 3.0 * m * (dense_bytes / tp)       # weight AG fwd/refwd/bwd
    grad_rs = dense_bytes / tp
    tp_ar = 12.0 * x_chip * L * m                # row-parallel AR + x AGs
    coll = fsdp_ag + grad_rs + tp_ar
    breakdown = {"fsdp_weight_allgather": fsdp_ag, "grad_reduce_scatter":
                 grad_rs, "tp_activation_allreduce": tp_ar}
    if cfg.is_moe:
        from repro.models.moe import ep_layout
        E = cfg.moe.n_experts
        ep_axes, ffn_axes, _ = ep_layout(mesh, E)
        n_ep = 1
        for nm in ep_axes:
            n_ep *= mesh.shape[nm]
        if ffn_axes:
            # d_ff FSDP'd over the leftover axes: gathered per pass
            exp_ag = 3.0 * m * (expert_bytes / max(n_ep, 1))
        else:
            exp_ag = 0.0          # whole experts resident: no gathering
        a2a = 3.0 * n_moe * 4.0 * (T / chips) * cfg.moe.top_k * d * 2.0
        coll += a2a + exp_ag
        breakdown["moe_all_to_all"] = a2a
        breakdown["moe_weight_allgather"] = exp_ag
    return {"flops": flops, "bytes": byts, "coll": coll,
            "coll_breakdown": breakdown}


def lm_prefill(cfg, B: int, S: int, n_params: int, n_active: int, mesh):
    dp, tp = _mesh_sizes(mesh)
    chips = dp * tp
    T = B * S
    L, d = cfg.n_layers, cfg.d_model
    if cfg.local_window > 0:
        frac = 0.25 + 0.75 * min(cfg.local_window, S) / S
    else:
        frac = 1.0
    attn = 2.0 * 2.0 * B * S * S * 0.5 * frac * cfg.n_heads * cfg.head_dim \
        * L / chips
    flops = 2.0 * n_active * T / chips + attn
    pbytes = 2.0 * n_params
    byts = pbytes / chips + 4.0 * L * (T / dp) * (d / tp) * 2.0
    x_chip = (T / dp) * d * 2.0
    coll = pbytes / tp + 4.0 * x_chip * L
    return {"flops": flops, "bytes": byts, "coll": coll,
            "coll_breakdown": {"fsdp_weight_allgather": pbytes / tp,
                               "tp_activation_allreduce": 4.0 * x_chip * L}}


def lm_decode(cfg, B: int, L_cache: int, n_params: int, n_active: int, mesh):
    dp, tp = _mesh_sizes(mesh)
    chips = dp * tp
    L, d = cfg.n_layers, cfg.d_model
    flops = 2.0 * n_active * B / chips
    if cfg.attention == "mla":
        row = cfg.kv_lora + cfg.qk_rope
        # absorbed decode: scores + output both against the compressed cache
        flops += 2.0 * 2.0 * B * L_cache * cfg.n_heads * cfg.kv_lora / chips
        cache_bytes = L * B * L_cache * row * 2.0
    else:
        if cfg.local_window > 0:
            eff = 0.25 * L_cache + 0.75 * min(cfg.local_window, L_cache)
        else:
            eff = L_cache
        flops += 2.0 * 2.0 * B * eff * cfg.n_heads * cfg.head_dim * L / chips
        cache_bytes = L * B * L_cache * 2 * cfg.n_kv * cfg.head_dim * 2.0
    byts = 2.0 * n_active / chips + cache_bytes / chips
    # TP ARs of the (B, d) residual per layer + cache-shard softmax stats
    x_chip = (B / dp) * d * 2.0
    coll = 4.0 * x_chip * L + 2.0 * (B / dp) * cfg.n_heads * 4.0 * L
    return {"flops": flops, "bytes": byts, "coll": coll,
            "coll_breakdown": {"tp_activation_allreduce": coll}}


def _opt_bytes(n_params: int) -> float:
    from repro.launch.steps import (ADAFACTOR_THRESHOLD,
                                    MOMENTUM_FREE_THRESHOLD)
    if n_params > MOMENTUM_FREE_THRESHOLD:
        return 0.1 * n_params            # factored stats only
    if n_params > ADAFACTOR_THRESHOLD:
        return 2.0 * n_params + 0.1 * n_params   # bf16 momentum + stats
    return 8.0 * n_params                # adamw fp32 m+v


def gnn_train(cfg, N: int, E: int, mesh, d_in: int,
              shard_nodes: bool = True):
    """Node tensors sharded over the data axes (post-§Perf iteration);
    ``shard_nodes=False`` models the replicated-node baseline where every
    chip runs the full node matmuls and psums whole node tables."""
    dp, tp = _mesh_sizes(mesh)
    chips = dp * tp
    d, L = cfg.d_hidden, cfg.n_layers
    node_div = dp if shard_nodes else 1.0
    edge_div = chips if shard_nodes else dp      # edges over ALL axes
    node_mm = 2.0 * 5 * N * d * d * L / node_div
    edge_ops = 2.0 * 2 * E * d * L / edge_div
    flops = 3.0 * (node_mm + edge_ops) + 2.0 * N * d_in * d / node_div
    byts = 3.0 * L * (8.0 * (N / node_div) * d * 4.0
                      + 6.0 * (E / edge_div) * d * 4.0) \
        + (N / node_div) * d_in * 4.0
    if shard_nodes:
        # per layer: gather h at remote edge endpoints + scatter partial
        # aggregates home: ~4 (N, d) fp32 exchanges, x3 passes
        coll = 3.0 * L * 4.0 * N * d * 4.0 / dp
        label = "node_halo_exchange"
    else:
        # gate_sum + agg psums of the full (N, d) fp32 table per layer
        coll = 3.0 * L * 2.0 * 2.0 * N * d * 4.0
        label = "node_psum_allreduce"
    return {"flops": flops, "bytes": byts, "coll": coll,
            "coll_breakdown": {label: coll}}


def recsys_step(cfg, B: int, model_flops_total: float, n_params: int, mesh,
                training: bool):
    dp, tp = _mesh_sizes(mesh)
    d = cfg.embed_dim
    flops = model_flops_total / dp               # batch sharded over dp
    n_lookups = (cfg.n_fields if cfg.n_fields else cfg.seq_len + 1)
    emb_read = (B / dp) * n_lookups * d * 4.0
    if cfg.use_minhash_frontend:
        emb_read += (B / dp) * cfg.minhash_k * d * 4.0
    table_params = n_params                      # tables dominate
    if training:
        # factored momentum-free optimizer (§Perf autoint iter 1): grads
        # read + params read/write + O(V+d) stats vs AdamW's 6 fp32-table
        # passes; rowwise-SPARSE updates (touched rows only) are the
        # documented next step (~15x further, not yet implemented)
        opt_traffic = (table_params / tp) * 4.0 * 3.0
        byts = emb_read * 3.0 + opt_traffic
        grad_ar = 2.0 * (table_params / tp) * 4.0   # AR of dense table grads
        gather = 2.0 * emb_read
        coll = grad_ar + gather
        breakdown = {"table_grad_allreduce": grad_ar,
                     "embedding_gather": gather}
    else:
        byts = emb_read + (table_params / tp) * 0.0 + emb_read
        coll = emb_read
        breakdown = {"embedding_gather": coll}
    return {"flops": max(flops, 1.0), "bytes": byts, "coll": coll,
            "coll_breakdown": breakdown}


def estimate(program, mesh) -> Dict[str, float]:
    """Dispatch on (family, kind)."""
    import jax
    cfg = program.config
    av = program.input_avals
    if program.family == "lm":
        from repro.models.transformer import (count_active_params,
                                              count_params)
        n, na = count_params(cfg), count_active_params(cfg)
        if program.kind == "lm_train":
            B, S = av["tokens"].shape
            return lm_train(cfg, B, S, n, na, mesh)
        if program.kind == "lm_prefill":
            B, S = av["tokens"].shape
            return lm_prefill(cfg, B, S, n, na, mesh)
        B = av["tokens"].shape[0]
        leaf = jax.tree_util.tree_leaves(av["cache"])[0]
        return lm_decode(cfg, B, leaf.shape[2], n, na, mesh)
    if program.family == "gnn":
        N = av["node_feats"].shape[0]
        E = av["edge_index"].shape[1]
        return gnn_train(cfg, N, E, mesh, av["node_feats"].shape[1])
    # recsys
    import math as _m
    n_params = sum(_m.prod(l.shape) for l in
                   jax.tree_util.tree_leaves(program.param_avals))
    from repro.roofline.analysis import recsys_model_flops
    if program.kind == "recsys_retrieval":
        B = 1_000_000
        fl = recsys_model_flops(cfg, B, training=False)
        return recsys_step(cfg, B, fl, n_params, mesh, training=False)
    some = av.get("field_ids", av.get("hist_ids"))
    B = some.shape[0]
    training = program.kind == "recsys_train"
    fl = recsys_model_flops(cfg, B, training=training)
    return recsys_step(cfg, B, fl, n_params, mesh, training=training)
