"""TPU v5e hardware constants for the roofline model."""

PEAK_FLOPS_BF16 = 197e12        # FLOP/s per chip, bf16
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link (approx, per direction)
HBM_BYTES = 16 * 2**30          # 16 GiB HBM per chip
VMEM_BYTES = 128 * 2**20
