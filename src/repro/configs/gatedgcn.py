"""gatedgcn: 16-layer GatedGCN, d_hidden=70 [arXiv:2003.00982].

d_in / n_classes / readout are per-shape-cell (cora-, reddit-,
ogbn-products- and molecule-scale); see configs/base.py GNN_CELLS.
"""

import jax.numpy as jnp

from repro.configs.base import ArchSpec, register
from repro.models.gnn import GNNConfig

CONFIG = GNNConfig(
    arch_id="gatedgcn", n_layers=16, d_hidden=70, d_in=100, n_classes=47,
    aggregator="gated", param_dtype=jnp.float32, remat=True)

SMOKE = GNNConfig(
    arch_id="gatedgcn-smoke", n_layers=2, d_hidden=16, d_in=16, n_classes=4,
    aggregator="gated", param_dtype=jnp.float32)

register(ArchSpec(arch_id="gatedgcn", family="gnn", config=CONFIG,
                  smoke=SMOKE, source="arXiv:2003.00982; paper"))
