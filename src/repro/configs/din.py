"""din: Deep Interest Network (target attention) [arXiv:1706.06978].

embed_dim=18, behavior seq_len=100, attention MLP 80-40, head MLP 200-80.
"""

import jax.numpy as jnp

from repro.configs.base import ArchSpec, register
from repro.models.recsys import RecsysConfig

CONFIG = RecsysConfig(
    arch_id="din", interaction="target-attn", n_fields=0, vocab=0,
    embed_dim=18, seq_len=100, attn_mlp_dims=(80, 40), mlp_dims=(200, 80),
    item_vocab=1_000_000)

SMOKE = RecsysConfig(
    arch_id="din-smoke", interaction="target-attn", n_fields=0, vocab=0,
    embed_dim=8, seq_len=12, attn_mlp_dims=(16, 8), mlp_dims=(16, 8),
    item_vocab=1000)

register(ArchSpec(arch_id="din", family="recsys", config=CONFIG,
                  smoke=SMOKE, source="arXiv:1706.06978; paper"))
