"""mistral-large-123b: dense GQA LM
[hf:mistralai/Mistral-Large-Instruct-2407; unverified].

88L, d_model=12288, 96 heads, GQA kv=8, d_ff=28672, vocab=32768.
"""

import jax.numpy as jnp

from repro.configs.base import ArchSpec, register
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    arch_id="mistral-large-123b", n_layers=88, d_model=12288, n_heads=96,
    n_kv=8, d_ff=28672, vocab=32768, head_dim=128, rope_theta=1_000_000.0,
    param_dtype=jnp.bfloat16, microbatch=8)

SMOKE = TransformerConfig(
    arch_id="mistral-large-123b-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv=2, d_ff=160, vocab=512, head_dim=16, param_dtype=jnp.float32,
    remat=False, ce_chunk=32, attn_blk=32)

register(ArchSpec(
    arch_id="mistral-large-123b", family="lm", config=CONFIG, smoke=SMOKE,
    source="hf:mistralai/Mistral-Large-Instruct-2407; unverified",
    skip_cells={"long_500k": "pure full-attention arch (no sub-quadratic "
                             "path); skip per assignment rules"}))
