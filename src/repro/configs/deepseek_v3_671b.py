"""deepseek-v3-671b: MLA + MoE LM [arXiv:2412.19437].

61L, d_model=7168, 128 heads (MLA), vocab=129280.  MoE: 256 routed experts
(d_ff=2048) top-8 + 1 shared expert; first 3 layers dense (d_ff=18432).
MLA: q_lora=1536, kv_lora=512, nope=128, rope=64, v=128.
MTP (multi-token prediction) is a training-objective add-on in the paper;
the backbone modeled here is the deployed architecture.
"""

import jax.numpy as jnp

from repro.configs.base import ArchSpec, register
from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    arch_id="deepseek-v3-671b", n_layers=61, d_model=7168, n_heads=128,
    n_kv=128, d_ff=2048, vocab=129280, head_dim=128, attention="mla",
    rope_theta=10000.0, n_dense_layers=3, d_ff_dense=18432,
    moe=MoEConfig(n_experts=256, top_k=8, d_ff=2048, n_shared=1,
                  capacity_factor=1.25, router="sigmoid"),
    q_lora=1536, kv_lora=512, qk_nope=128, qk_rope=64, v_head=128,
    param_dtype=jnp.bfloat16, microbatch=8)

SMOKE = TransformerConfig(
    arch_id="deepseek-v3-671b-smoke", n_layers=3, d_model=64, n_heads=4,
    n_kv=4, d_ff=32, vocab=512, head_dim=16, attention="mla",
    n_dense_layers=1, d_ff_dense=128,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff=32, n_shared=1,
                  router="sigmoid"),
    q_lora=32, kv_lora=16, qk_nope=16, qk_rope=8, v_head=16,
    param_dtype=jnp.float32, remat=False, ce_chunk=32, attn_blk=32)

register(ArchSpec(
    arch_id="deepseek-v3-671b", family="lm", config=CONFIG, smoke=SMOKE,
    source="arXiv:2412.19437; hf",
    skip_cells={"long_500k": "MLA is full softmax attention over all keys "
                             "(quadratic prefill); skip per assignment "
                             "rules"}))
