"""autoint: self-attention feature interaction [arXiv:1810.11921].

39 sparse fields, embed_dim=16, 3 attention layers x 2 heads, d_attn=32.
Carries the paper's minhash frontend (set-valued feature -> k b-bit
signatures -> signature embedding-bag) as the 40th field.
"""

import jax.numpy as jnp

from repro.configs.base import ArchSpec, register
from repro.models.recsys import RecsysConfig

CONFIG = RecsysConfig(
    arch_id="autoint", interaction="self-attn", n_fields=39,
    vocab=1_000_000, embed_dim=16, n_attn_layers=3, n_attn_heads=2,
    d_attn=32, use_minhash_frontend=True, minhash_k=64, minhash_b=8,
    minhash_s=24, set_nnz=128)

SMOKE = RecsysConfig(
    arch_id="autoint-smoke", interaction="self-attn", n_fields=6,
    vocab=1000, embed_dim=8, n_attn_layers=2, n_attn_heads=2, d_attn=8,
    use_minhash_frontend=True, minhash_k=16, minhash_b=4, minhash_s=16,
    set_nnz=32)

register(ArchSpec(arch_id="autoint", family="recsys", config=CONFIG,
                  smoke=SMOKE, source="arXiv:1810.11921; paper"))
