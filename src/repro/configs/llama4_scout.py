"""llama4-scout-17b-a16e: MoE LM with chunked-local attention
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

48L, d_model=5120, 40 heads, GQA kv=8, vocab=202048.  MoE: 16 experts
top-1 (d_ff=8192) + 1 shared expert.  iRoPE: chunked local attention
(window 8192) with every 4th layer global -> sub-quadratic prefill, so
``long_500k`` RUNS for this arch.  Early-fusion multimodality is a
frontend stub per the assignment (text backbone modeled).
"""

import jax.numpy as jnp

from repro.configs.base import ArchSpec, register
from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    arch_id="llama4-scout-17b-a16e", n_layers=48, d_model=5120, n_heads=40,
    n_kv=8, d_ff=8192, vocab=202048, head_dim=128, rope_theta=500000.0,
    local_window=8192, global_every=4,
    moe=MoEConfig(n_experts=16, top_k=1, d_ff=8192, n_shared=1,
                  capacity_factor=1.25, router="sigmoid"),
    param_dtype=jnp.bfloat16, microbatch=4)

SMOKE = TransformerConfig(
    arch_id="llama4-scout-smoke", n_layers=4, d_model=64, n_heads=4, n_kv=2,
    d_ff=64, vocab=512, head_dim=16, local_window=16, global_every=4,
    moe=MoEConfig(n_experts=4, top_k=1, d_ff=64, n_shared=1,
                  router="sigmoid"),
    param_dtype=jnp.float32, remat=False, ce_chunk=32, attn_blk=16)

register(ArchSpec(
    arch_id="llama4-scout-17b-a16e", family="lm", config=CONFIG, smoke=SMOKE,
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified"))
