"""Architecture/shape registry: every assigned (arch x shape) cell.

Each arch module registers an ``ArchSpec``:
  * ``family``    -- "lm" | "gnn" | "recsys"
  * ``config``    -- the full published configuration (dry-run only),
  * ``smoke``     -- reduced same-family config for CPU smoke tests,
  * per-family shape cells come from the family tables below; an arch can
    mark cells skipped (with a reason recorded into EXPERIMENTS.md).

``input_specs(arch, cell, smoke)`` returns ShapeDtypeStruct stand-ins for
every model input -- shardable, weak-type-correct, no allocation.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.gnn import GNNConfig, subgraph_sizes
from repro.models.recsys import RecsysConfig
from repro.models.transformer import TransformerConfig


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str                       # step kind, see launch/steps.py
    dims: Dict[str, int]
    note: str = ""


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str
    config: Any
    smoke: Any
    source: str
    skip_cells: Dict[str, str] = dataclasses.field(default_factory=dict)


_REGISTRY: Dict[str, ArchSpec] = {}


def register(spec: ArchSpec) -> ArchSpec:
    _REGISTRY[spec.arch_id] = spec
    return spec


def get_arch(arch_id: str) -> ArchSpec:
    _ensure_loaded()
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]


def all_archs() -> Dict[str, ArchSpec]:
    _ensure_loaded()
    return dict(_REGISTRY)


def _ensure_loaded():
    if _REGISTRY:
        return
    from repro.configs import (autoint, deepseek_7b, deepseek_v3_671b, din,
                               gatedgcn, llama4_scout, mind,
                               mistral_large_123b, wide_deep, yi_34b)  # noqa: F401


# ---------------------------------------------------------------------------
# Family shape tables (the assigned input-shape sets)
# ---------------------------------------------------------------------------

LM_CELLS = [
    ShapeCell("train_4k", "lm_train", {"batch": 256, "seq": 4096}),
    ShapeCell("prefill_32k", "lm_prefill", {"batch": 32, "seq": 32768}),
    ShapeCell("decode_32k", "lm_decode", {"batch": 128, "seq": 32768}),
    ShapeCell("long_500k", "lm_decode", {"batch": 1, "seq": 524288},
              note="sub-quadratic attention required"),
]

GNN_CELLS = [
    ShapeCell("full_graph_sm", "gnn_train_full",
              {"n_nodes": 2708, "n_edges": 10556, "d_feat": 1433,
               "n_classes": 7}),
    ShapeCell("minibatch_lg", "gnn_train_sampled",
              {"n_nodes": 232965, "n_edges": 114615892, "batch_nodes": 1024,
               "fanout1": 15, "fanout2": 10, "d_feat": 602, "n_classes": 41}),
    ShapeCell("ogb_products", "gnn_train_full",
              {"n_nodes": 2449029, "n_edges": 61859140, "d_feat": 100,
               "n_classes": 47}),
    ShapeCell("molecule", "gnn_train_graphs",
              {"n_nodes": 30, "n_edges": 64, "batch": 128, "d_feat": 28,
               "n_classes": 2}),
]

RECSYS_CELLS = [
    ShapeCell("train_batch", "recsys_train", {"batch": 65536}),
    ShapeCell("serve_p99", "recsys_serve", {"batch": 512}),
    ShapeCell("serve_bulk", "recsys_serve", {"batch": 262144}),
    ShapeCell("retrieval_cand", "recsys_retrieval",
              {"batch": 1, "n_candidates": 1_000_000}),
]

FAMILY_CELLS = {"lm": LM_CELLS, "gnn": GNN_CELLS, "recsys": RECSYS_CELLS}


def cells_for(arch_id: str):
    spec = get_arch(arch_id)
    return FAMILY_CELLS[spec.family]


def get_cell(arch_id: str, cell_name: str) -> ShapeCell:
    for c in cells_for(arch_id):
        if c.name == cell_name:
            return c
    raise KeyError(f"{arch_id} has no cell {cell_name!r}")


# ---------------------------------------------------------------------------
# Per-cell model config + input specs
# ---------------------------------------------------------------------------

SMOKE_LM = {"batch": 2, "seq": 64, "decode_len": 64}
SMOKE_GNN = {"n_nodes": 64, "n_edges": 256, "d_feat": 16, "n_classes": 4,
             "batch_nodes": 8, "fanout1": 3, "fanout2": 2, "batch": 4}
SMOKE_RECSYS = {"batch": 32, "n_candidates": 128}


def config_for_cell(arch_id: str, cell: ShapeCell, smoke: bool = False):
    """Model config adjusted for this cell (GNN dims are per-cell)."""
    spec = get_arch(arch_id)
    cfg = spec.smoke if smoke else spec.config
    if spec.family == "gnn":
        dims = SMOKE_GNN if smoke else cell.dims
        cfg = dataclasses.replace(
            cfg, d_in=dims["d_feat"], n_classes=dims["n_classes"],
            readout="graph" if cell.kind == "gnn_train_graphs" else "node")
    return cfg


def _sd(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _pad512(n: int) -> int:
    """Edge arrays are padded (mask-valid) to a 512 multiple so they tile
    and shard evenly; the data loader pads identically."""
    return ((n + 511) // 512) * 512


def input_specs(arch_id: str, cell_name: str, smoke: bool = False) -> dict:
    """ShapeDtypeStruct stand-ins for the cell's step inputs."""
    spec = get_arch(arch_id)
    cell = get_cell(arch_id, cell_name)
    cfg = config_for_cell(arch_id, cell, smoke)
    d = dict(cell.dims)
    i32 = jnp.int32

    if spec.family == "lm":
        B = SMOKE_LM["batch"] if smoke else d["batch"]
        S = SMOKE_LM["seq"] if smoke else d["seq"]
        if cell.kind == "lm_train":
            return {"tokens": _sd((B, S), i32), "labels": _sd((B, S), i32)}
        if cell.kind == "lm_prefill":
            return {"tokens": _sd((B, S), i32)}
        if cell.kind == "lm_decode":
            from repro.models.transformer import cache_shapes
            cache = cache_shapes(cfg, B, S)
            return {"cache": cache, "tokens": _sd((B,), i32),
                    "pos": _sd((), i32)}

    if spec.family == "gnn":
        dims = SMOKE_GNN if smoke else d
        if cell.kind == "gnn_train_full":
            N, E = _pad512(dims["n_nodes"]), _pad512(dims["n_edges"])
            return {
                "node_feats": _sd((N, dims["d_feat"]), jnp.float32),
                "edge_index": _sd((2, E), i32),
                "edge_mask": _sd((E,), jnp.float32),
                "labels": _sd((N,), i32),
                "node_mask": _sd((N,), jnp.float32),
            }
        if cell.kind == "gnn_train_sampled":
            n_sub, e_sub = subgraph_sizes(
                dims["batch_nodes"], (dims["fanout1"], dims["fanout2"]))
            n_sub, e_sub = _pad512(n_sub), _pad512(e_sub)
            return {
                "node_feats": _sd((n_sub, dims["d_feat"]), jnp.float32),
                "edge_index": _sd((2, e_sub), i32),
                "edge_mask": _sd((e_sub,), jnp.float32),
                "labels": _sd((n_sub,), i32),
                "node_mask": _sd((n_sub,), jnp.float32),
            }
        if cell.kind == "gnn_train_graphs":
            Bg = dims["batch"]
            N = Bg * dims["n_nodes"]
            E = _pad512(Bg * dims["n_edges"])
            return {
                "node_feats": _sd((N, dims["d_feat"]), jnp.float32),
                "edge_index": _sd((2, E), i32),
                "edge_mask": _sd((E,), jnp.float32),
                "labels": _sd((Bg,), i32),
                "node_mask": _sd((N,), jnp.float32),
                "graph_ids": _sd((N,), i32),
            }

    if spec.family == "recsys":
        if cell.kind == "recsys_retrieval":
            B = d["batch"]                     # always 1 query
        else:
            B = (SMOKE_RECSYS["batch"] if smoke else d["batch"])
        out: Dict[str, Any] = {}
        if cfg.interaction in ("concat", "self-attn"):
            out["field_ids"] = _sd((B, cfg.n_fields), i32)
        else:
            out["hist_ids"] = _sd((B, cfg.seq_len), i32)
            out["hist_mask"] = _sd((B, cfg.seq_len), jnp.float32)
            out["target_id"] = _sd((B,), i32)
        if cfg.use_minhash_frontend:
            out["set_ids"] = _sd((B, cfg.set_nnz), i32)
            out["set_counts"] = _sd((B,), i32)
        if cell.kind == "recsys_train":
            out["labels"] = _sd((B,), jnp.float32)
        if cell.kind == "recsys_retrieval":
            out["n_candidates"] = (SMOKE_RECSYS["n_candidates"] if smoke
                                   else d["n_candidates"])
        return out

    raise ValueError(f"no input specs for {arch_id}/{cell_name}")


def is_skipped(arch_id: str, cell_name: str) -> Optional[str]:
    """Returns the skip reason, or None if the cell runs."""
    spec = get_arch(arch_id)
    if cell_name in spec.skip_cells:
        return spec.skip_cells[cell_name]
    return None
