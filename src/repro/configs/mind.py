"""mind: Multi-Interest Network with Dynamic routing [arXiv:1904.08030].

embed_dim=64, 4 interest capsules, 3 routing iterations.
"""

import jax.numpy as jnp

from repro.configs.base import ArchSpec, register
from repro.models.recsys import RecsysConfig

CONFIG = RecsysConfig(
    arch_id="mind", interaction="multi-interest", n_fields=0, vocab=0,
    embed_dim=64, seq_len=100, n_interests=4, capsule_iters=3,
    item_vocab=1_000_000)

SMOKE = RecsysConfig(
    arch_id="mind-smoke", interaction="multi-interest", n_fields=0, vocab=0,
    embed_dim=16, seq_len=12, n_interests=2, capsule_iters=2,
    item_vocab=1000)

register(ArchSpec(arch_id="mind", family="recsys", config=CONFIG,
                  smoke=SMOKE, source="arXiv:1904.08030; unverified"))
