"""wide-deep: Wide & Deep [arXiv:1606.07792].

40 sparse fields, embed_dim=32, deep MLP 1024-512-256.  Carries the
minhash frontend as an extra deep input (the paper's technique applied to
the wide&deep user-behavior set).
"""

import jax.numpy as jnp

from repro.configs.base import ArchSpec, register
from repro.models.recsys import RecsysConfig

CONFIG = RecsysConfig(
    arch_id="wide-deep", interaction="concat", n_fields=40, vocab=1_000_000,
    embed_dim=32, mlp_dims=(1024, 512, 256), use_minhash_frontend=True,
    minhash_k=64, minhash_b=8, minhash_s=24, set_nnz=128)

SMOKE = RecsysConfig(
    arch_id="wide-deep-smoke", interaction="concat", n_fields=6, vocab=1000,
    embed_dim=8, mlp_dims=(32, 16), use_minhash_frontend=True, minhash_k=16,
    minhash_b=4, minhash_s=16, set_nnz=32)

register(ArchSpec(arch_id="wide-deep", family="recsys", config=CONFIG,
                  smoke=SMOKE, source="arXiv:1606.07792; paper"))
