"""yi-34b: dense llama-arch GQA LM [arXiv:2403.04652].

60L, d_model=7168, 56 heads, GQA kv=8, d_ff=20480, vocab=64000.
"""

import jax.numpy as jnp

from repro.configs.base import ArchSpec, register
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    arch_id="yi-34b", n_layers=60, d_model=7168, n_heads=56, n_kv=8,
    d_ff=20480, vocab=64000, head_dim=128, rope_theta=5_000_000.0,
    param_dtype=jnp.bfloat16, microbatch=4)

SMOKE = TransformerConfig(
    arch_id="yi-34b-smoke", n_layers=2, d_model=56, n_heads=4, n_kv=2,
    d_ff=112, vocab=512, head_dim=16, param_dtype=jnp.float32, remat=False,
    ce_chunk=32, attn_blk=32)

register(ArchSpec(
    arch_id="yi-34b", family="lm", config=CONFIG, smoke=SMOKE,
    source="arXiv:2403.04652; hf",
    skip_cells={"long_500k": "pure full-attention arch (no sub-quadratic "
                             "path); skip per assignment rules"}))
