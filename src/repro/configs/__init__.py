"""Architecture configs: 10 assigned archs + the paper's own datasets."""

from repro.configs.base import (ArchSpec, ShapeCell, all_archs, cells_for,
                                config_for_cell, get_arch, get_cell,
                                input_specs, is_skipped)

__all__ = ["ArchSpec", "ShapeCell", "all_archs", "cells_for",
           "config_for_cell", "get_arch", "get_cell", "input_specs",
           "is_skipped"]
