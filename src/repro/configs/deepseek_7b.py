"""deepseek-7b: dense llama-arch LM [arXiv:2401.02954].

30L, d_model=4096, 32 heads (MHA: kv=32), d_ff=11008, vocab=102400.
Pure full attention -> long_500k skipped (see DESIGN.md §7.5).
"""

import jax.numpy as jnp

from repro.configs.base import ArchSpec, register
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    arch_id="deepseek-7b", n_layers=30, d_model=4096, n_heads=32, n_kv=32,
    d_ff=11008, vocab=102400, head_dim=128, rope_theta=10000.0,
    param_dtype=jnp.bfloat16, microbatch=2)

SMOKE = TransformerConfig(
    arch_id="deepseek-7b-smoke", n_layers=2, d_model=64, n_heads=4, n_kv=4,
    d_ff=128, vocab=512, head_dim=16, param_dtype=jnp.float32, remat=False,
    ce_chunk=32, attn_blk=32)

register(ArchSpec(
    arch_id="deepseek-7b", family="lm", config=CONFIG, smoke=SMOKE,
    source="arXiv:2401.02954; hf",
    skip_cells={"long_500k": "pure full-attention arch (no sub-quadratic "
                             "path); skip per assignment rules"}))
