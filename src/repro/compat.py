"""Version compatibility shims for the jax API surface this repo uses.

``shard_map`` has moved twice across jax releases:

  * old:  ``jax.experimental.shard_map.shard_map`` with a ``check_rep``
    keyword,
  * new:  ``jax.shard_map`` with ``check_rep`` renamed to ``check_vma``.

``repro.compat.shard_map`` resolves whichever exists at import time and
accepts either keyword spelling, so callers (the expert-parallel MoE,
the compressed-allreduce optimizer wrappers, tests) write one form and
run on both.  Add future jax API moves here rather than try/except-ing
at call sites.
"""

from __future__ import annotations

import jax

__all__ = ["shard_map"]


def _resolve_shard_map():
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        return fn, "check_vma"
    from jax.experimental.shard_map import shard_map as fn
    return fn, "check_rep"


_SHARD_MAP, _CHECK_KW = _resolve_shard_map()


def shard_map(f, *, mesh, in_specs, out_specs, **kwargs):
    """Version-agnostic ``shard_map``.

    Accepts ``check_rep`` or ``check_vma`` (synonyms for the replication
    check) and forwards whichever spelling the installed jax expects;
    other keywords pass through untouched.
    """
    for alias in ("check_rep", "check_vma"):
        if alias in kwargs and alias != _CHECK_KW:
            kwargs[_CHECK_KW] = kwargs.pop(alias)
    return _SHARD_MAP(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)
