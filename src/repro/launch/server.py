"""Continuous-batching search server over the similarity-search index.

The paper's endgame is serving b-bit signatures under real traffic
(PAPER.md §1, §3: retrieval at 200GB scale); this module is the serving
spine on top of ``repro.index``: a thread-safe admission queue in front
of any ``submit``/``flush`` searcher (``IndexSearcher`` or the sharded
``ShardedIndex`` router), flushed by a background dispatch thread with
deadline-aware micro-batching -- the queue + worker-thread design of
production inference servers (cf. MLPerf offline-inference harnesses).

  client threads                     dispatch thread
  --------------                     ---------------------------------
  submit(q) ──> admission queue ──>  wait until: batch full
  (returns a PendingResult)             OR oldest request aged max_delay
                                        OR a deadline is about to miss
                                     pop <= max_batch requests
                                     [router.refresh(): pick up live
                                      appends via the versioned manifest]
                                     searcher.submit() x batch; flush()
                                     resolve PendingResults + stats

Because a flush drains the queue through the *existing* batched
admission protocol (one fused scan / one candidate union per flush),
micro-batched results are **bit-identical** to calling ``search()``
directly on the same queries -- and since every per-query row of the
exact scan and the LSH rerank is independent of its co-batched rows,
they are also bit-identical to a single-query ``search`` per request
(``tests/test_server.py`` pins both).

Live index updates ride the ``repro.index`` lock-file + atomic-manifest
machinery: a crawler process calls ``ShardedIndex.append`` (directory
lock, atomic ``.idx`` replace -- or, past the ``max_shard_docs`` budget,
a spill into atomically published NEW tail shards -- manifest generation
bump) while this server keeps flushing; with ``refresh=True`` the
dispatch thread re-reads the versioned manifest before each flush and
swaps in grown/spilled shards between batches, so every flush serves
one consistent corpus snapshot.  A router constructed with a device
mesh keeps its shard_map exact dispatch across refreshes: spilled
shards pick up their round-robin device placement in the same swap.

``ZipfianTraffic`` is the synthetic load model (Zipf-popular query ids,
Poisson arrivals) behind ``benchmarks/search_serving.py`` and
``repro.launch.serve --index --serve``.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Deque, Dict, List, Optional

import numpy as np


def _percentile(samples, q: float) -> float:
    if not samples:
        return float("nan")
    return float(np.percentile(np.asarray(samples, np.float64), q))


class PendingResult:
    """Handle for one admitted request; resolved by the dispatch thread."""

    __slots__ = ("t_submit", "deadline", "query", "query_size",
                 "_event", "_result", "_error", "queue_wait_s", "latency_s")

    def __init__(self, query, query_size, deadline: Optional[float]):
        self.query = query
        self.query_size = query_size
        self.t_submit = time.monotonic()
        self.deadline = deadline          # absolute monotonic time, or None
        self.queue_wait_s: Optional[float] = None
        self.latency_s: Optional[float] = None
        self._event = threading.Event()
        self._result = None
        self._error: Optional[BaseException] = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None):
        """Block until resolved; returns the per-request ``SearchResult``
        (one row) or re-raises the batch's failure."""
        if not self._event.wait(timeout):
            raise TimeoutError("request not served within timeout")
        if self._error is not None:
            raise self._error
        return self._result

    def _resolve(self, result, error: Optional[BaseException]) -> None:
        self._result = result
        self._error = error
        self.latency_s = time.monotonic() - self.t_submit
        self._event.set()


@dataclasses.dataclass
class ServerStats:
    """Serving counters; bounded reservoirs feed the percentile snapshot.

    ``queue_wait_s`` is admission -> batch pop, ``flush_s`` is one
    batch's dispatch+harvest wall clock, ``latency_s`` is admission ->
    result resolution (what a client observes).
    """

    requests: int = 0
    batches: int = 0
    errors: int = 0
    deadline_misses: int = 0
    refreshes: int = 0            # manifest refreshes that changed state
    flush_full: int = 0           # trigger: queue reached max_batch
    flush_aged: int = 0           # trigger: oldest request aged max_delay
    flush_deadline: int = 0       # trigger: a deadline was about to miss
    flush_drain: int = 0          # trigger: server stopping
    window: int = 65536
    queue_wait_s: Deque[float] = dataclasses.field(default=None)  # type: ignore[assignment]
    flush_s: Deque[float] = dataclasses.field(default=None)       # type: ignore[assignment]
    latency_s: Deque[float] = dataclasses.field(default=None)     # type: ignore[assignment]
    batch_sizes: Deque[int] = dataclasses.field(default=None)     # type: ignore[assignment]

    def __post_init__(self):
        for name in ("queue_wait_s", "flush_s", "latency_s", "batch_sizes"):
            if getattr(self, name) is None:
                setattr(self, name, collections.deque(maxlen=self.window))

    def snapshot(self) -> Dict[str, float]:
        """One consistent dict of counters + p50/p99s (ms)."""
        out = {"requests": self.requests, "batches": self.batches,
               "errors": self.errors, "deadline_misses": self.deadline_misses,
               "refreshes": self.refreshes, "flush_full": self.flush_full,
               "flush_aged": self.flush_aged,
               "flush_deadline": self.flush_deadline,
               "flush_drain": self.flush_drain,
               "mean_batch": (float(np.mean(self.batch_sizes))
                              if self.batch_sizes else float("nan"))}
        for name, samples in (("queue_wait", self.queue_wait_s),
                              ("flush", self.flush_s),
                              ("latency", self.latency_s)):
            out[f"{name}_p50_ms"] = _percentile(samples, 50) * 1e3
            out[f"{name}_p99_ms"] = _percentile(samples, 99) * 1e3
        return out


class SearchServer:
    """Deadline-aware micro-batching front end over a searcher.

    ``searcher`` is anything speaking the batched-admission protocol
    (``IndexSearcher`` or ``ShardedIndex``); all searcher calls happen on
    the single dispatch thread, so the underlying jax state is never
    raced.  A flush fires when the queue holds ``max_batch`` requests,
    when the oldest request has waited ``max_delay_s``, or when a
    request's deadline minus the estimated flush latency (EWMA of recent
    flushes) is about to pass.  ``refresh=True`` (default) calls
    ``searcher.refresh()`` -- when it has one -- before each flush, so a
    served ``ShardedIndex`` picks up concurrent appends batch by batch.
    """

    def __init__(self, searcher, *, max_batch: int = 64,
                 max_delay_s: float = 0.005, topk: int = 10,
                 mode: str = "exact", refresh: bool = True,
                 deadline_safety: float = 1.5):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if mode not in ("exact", "lsh"):
            raise ValueError(f"mode must be 'exact' or 'lsh', got {mode!r}")
        self.searcher = searcher
        self.max_batch = max_batch
        self.max_delay_s = max_delay_s
        self.topk = topk
        self.mode = mode
        self.refresh = refresh and hasattr(searcher, "refresh")
        self.deadline_safety = deadline_safety
        self.stats = ServerStats()
        self._queue: Deque[PendingResult] = collections.deque()
        self._cond = threading.Condition()
        self._stopping = False
        self._thread: Optional[threading.Thread] = None
        self._est_flush_s = max(max_delay_s, 1e-3)   # EWMA, pre-warm guess

    # -- lifecycle -------------------------------------------------------
    def start(self) -> "SearchServer":
        if self._thread is not None:
            raise RuntimeError("server already started")
        self._thread = threading.Thread(target=self._dispatch_loop,
                                        daemon=True, name="search-dispatch")
        self._thread.start()
        return self

    def stop(self) -> None:
        """Drain the queue (remaining requests are flushed) and join."""
        if self._thread is None:
            return
        with self._cond:
            self._stopping = True
            self._cond.notify_all()
        self._thread.join()
        self._thread = None

    def __enter__(self) -> "SearchServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- admission (any thread) -----------------------------------------
    def submit(self, query, *, query_size: Optional[int] = None,
               deadline_s: Optional[float] = None) -> PendingResult:
        """Admit one query row; returns immediately with a handle.

        ``deadline_s`` is relative (seconds from now): the dispatcher
        tries to flush early enough that the result lands before it.
        """
        if self._thread is None:
            raise RuntimeError("server not started (use `with server:` "
                               "or call start())")
        deadline = (time.monotonic() + deadline_s
                    if deadline_s is not None else None)
        req = PendingResult(query, query_size, deadline)
        with self._cond:
            if self._stopping:
                raise RuntimeError("server is stopping")
            self._queue.append(req)
            self._cond.notify_all()
        return req

    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    @property
    def generation(self) -> Optional[int]:
        """Manifest generation the served searcher is on (None when the
        searcher has no notion of one, e.g. a single ``IndexSearcher``)
        -- lets operators confirm a live append/spill was picked up."""
        return getattr(self.searcher, "generation", None)

    # -- dispatch (the one searcher thread) ------------------------------
    def _next_due(self, now: float) -> float:
        """Earliest time the current queue must flush."""
        oldest = self._queue[0]
        due = oldest.t_submit + self.max_delay_s
        margin = self._est_flush_s * self.deadline_safety
        for r in self._queue:
            if r.deadline is not None:
                due = min(due, r.deadline - margin)
        return due

    def _dispatch_loop(self) -> None:
        while True:
            with self._cond:
                while not self._queue and not self._stopping:
                    self._cond.wait()
                if not self._queue and self._stopping:
                    return
                trigger = "drain" if self._stopping else None
                while trigger is None:
                    now = time.monotonic()
                    if len(self._queue) >= self.max_batch:
                        trigger = "full"
                        break
                    due = self._next_due(now)
                    if now >= due:
                        oldest_due = (self._queue[0].t_submit
                                      + self.max_delay_s)
                        trigger = "aged" if due >= oldest_due else "deadline"
                        break
                    self._cond.wait(timeout=due - now)
                    if self._stopping:
                        trigger = "drain"
                batch = [self._queue.popleft()
                         for _ in range(min(self.max_batch,
                                            len(self._queue)))]
            if batch:
                self._flush_batch(batch, trigger)

    def _flush_batch(self, batch: List[PendingResult], trigger: str) -> None:
        t0 = time.monotonic()
        stats = self.stats
        setattr(stats, f"flush_{trigger}",
                getattr(stats, f"flush_{trigger}") + 1)
        if self.refresh:
            try:
                if self.searcher.refresh():
                    stats.refreshes += 1
            except Exception:           # keep serving on a failed refresh
                stats.errors += 1
        tickets: Dict[int, PendingResult] = {}
        for r in batch:
            r.queue_wait_s = t0 - r.t_submit
            stats.queue_wait_s.append(r.queue_wait_s)
            try:
                tickets[self.searcher.submit(
                    r.query, query_size=r.query_size)] = r
            except Exception as e:       # a malformed query fails only itself
                stats.errors += 1
                r._resolve(None, e)
        error: Optional[BaseException] = None
        out: Dict[int, object] = {}
        if tickets:
            try:
                out = self.searcher.flush(self.topk, mode=self.mode)
            except Exception as e:
                error = e
                stats.errors += 1
        dt = time.monotonic() - t0
        self._est_flush_s = 0.7 * self._est_flush_s + 0.3 * dt
        stats.batches += 1
        stats.flush_s.append(dt)
        stats.batch_sizes.append(len(batch))
        now = time.monotonic()
        for ticket, r in tickets.items():
            r._resolve(out.get(ticket), error)
            stats.requests += 1
            stats.latency_s.append(r.latency_s)
            if r.deadline is not None and now > r.deadline:
                stats.deadline_misses += 1


# ---------------------------------------------------------------------------
# Synthetic traffic: Zipf-popular queries, Poisson arrivals
# ---------------------------------------------------------------------------

class ZipfianTraffic:
    """Synthetic serving load over an ``n_docs`` corpus.

    Query popularity follows a Zipf law with exponent ``alpha`` over a
    random permutation of the doc ids (so popular docs are scattered,
    not clustered at low ids); arrivals are a Poisson process at
    ``rate_qps``.  Deterministic per seed.
    """

    def __init__(self, n_docs: int, *, alpha: float = 1.1, seed: int = 0):
        if n_docs < 1:
            raise ValueError(f"n_docs must be >= 1, got {n_docs}")
        self.n_docs = n_docs
        self.alpha = alpha
        self._rng = np.random.default_rng(seed)
        weights = 1.0 / np.arange(1, n_docs + 1, dtype=np.float64) ** alpha
        self._probs = weights / weights.sum()
        self._perm = self._rng.permutation(n_docs)

    def ids(self, m: int) -> np.ndarray:
        """``m`` query doc ids, Zipf-popular."""
        ranks = self._rng.choice(self.n_docs, size=m, p=self._probs)
        return self._perm[ranks]

    def arrival_offsets(self, m: int, rate_qps: float) -> np.ndarray:
        """``m`` monotone arrival times (seconds from start) at the
        offered load ``rate_qps``."""
        if rate_qps <= 0:
            raise ValueError(f"rate_qps must be > 0, got {rate_qps}")
        gaps = self._rng.exponential(1.0 / rate_qps, size=m)
        return np.cumsum(gaps)
