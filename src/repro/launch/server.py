"""Continuous-batching search server over the similarity-search index.

The paper's endgame is serving b-bit signatures under real traffic
(PAPER.md §1, §3: retrieval at 200GB scale); this module is the serving
spine on top of ``repro.index``: a thread-safe admission queue in front
of any ``search``-speaking searcher (``IndexSearcher`` or the sharded
``ShardedIndex`` router), drained by a POOL of dispatch workers with
deadline-aware micro-batching -- the queue + worker-pool design of
production inference servers (cf. MLPerf server-scenario harnesses).

  client threads                     dispatch workers (num_workers)
  --------------                     ---------------------------------
  submit(q) ──> admission queue ──>  each worker waits until: batch full
  (returns a PendingResult;             OR oldest request aged max_delay
   overload: shed / degrade             OR a deadline is about to miss
   per the admission policy)         pop <= max_batch requests
                                     [searcher.refresh(): pick up live
                                      appends via the versioned manifest]
                                     per-worker handle: submit x batch;
                                     flush -> ONE batched search
                                     resolve PendingResults + stats

Each worker owns a private batched-admission handle over the SHARED
searcher, so concurrent flushes overlap: while worker A blocks on its
device harvest, worker B's flush is already dispatched -- on a device
mesh (``ShardedIndex(mesh=...)``) the default worker count is one per
data-axis device, so per-device flushes genuinely run in parallel
instead of serializing behind one thread.  Because a flush drains the
queue through the *existing* batched admission protocol (one fused scan
/ one candidate union per flush), micro-batched results are
**bit-identical** to calling ``search()`` directly on the same queries
-- per request and regardless of the worker count or which worker
flushed which batch, since every per-query row of the exact scan and
the LSH rerank is independent of its co-batched rows
(``tests/test_server.py`` pins both).

Admission control (``admission=`` + ``max_queue`` / a deadline budget)
keeps the server inside its latency budget under overload instead of
silently blowing it:

  * ``"reject"``      -- an arriving request is shed immediately when
    the queue is full or its EWMA-projected wait exceeds the budget,
  * ``"shed-oldest"`` -- the arriving request is admitted and the
    OLDEST queued requests are shed until the projection fits (the
    freshest traffic survives -- right for Zipf-popular reads),
  * ``"degrade-to-lsh"`` -- nothing is shed: over-budget requests are
    marked and their batches serve ``mode="lsh"`` (candidate probe +
    rerank over a sliver of the corpus) instead of the exact scan --
    graceful quality degradation instead of latency collapse.  Batches
    never mix degraded and exact requests.

A shed request's ``result()`` raises ``RequestShed``; every handle
surfaces what happened via ``PendingResult.outcome``
(``"served"`` / ``"shed"`` / ``"degraded"`` / ``"partial"`` /
``"error"``).  With a fault-tolerant router underneath
(``SearchServer(on_shard_failure="partial")``), a shard failure past
its client's retry/breaker budget degrades the affected flushes to the
surviving shards instead of poisoning the whole batch: those requests
resolve as ``"partial"`` with per-row ``coverage`` / ``failed_shards``
annotations, counted in ``ServerStats.partial`` and the coverage
reservoir.  Dispatch workers themselves are crash-proof: an exception
that escapes a flush fails only the requests that worker held, bumps
``worker_restarts`` (exported as ``serve_worker_restarts_total``), and
the loop keeps draining with a fresh handle -- requests queued behind
a crashed worker are never stranded.  ``ServerStats`` counts
shed/degraded/partial traffic and per-worker flush counts + busy-time
occupancy; all mutation happens under one lock, and ``snapshot()``
copies before computing percentiles, so concurrent submit storms can
never tear a reading.

Live index updates ride the ``repro.index`` lock-file + atomic-manifest
machinery exactly as before: with ``refresh=True`` one worker per flush
wave re-reads the versioned manifest (a non-blocking try-lock keeps
redundant refreshes off the hot path) and swaps in grown/spilled shards
between batches, so every flush serves one consistent corpus snapshot.

``ZipfianTraffic`` is the synthetic load model (Zipf-popular query ids,
Poisson arrivals) behind ``benchmarks/search_serving.py`` and
``repro.launch.serve --index --serve``.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.index.query import _BatchedAdmission
from repro.obs.metrics import Sample, get_registry
from repro.obs.trace import get_tracer
from repro.roofline.search import exact_scan_cost, roofline_gap


def _percentile(samples, q: float) -> float:
    if not samples:
        return float("nan")
    return float(np.percentile(np.asarray(samples, np.float64), q))


class RequestShed(RuntimeError):
    """The admission policy dropped this request under overload."""


class PendingResult:
    """Handle for one admitted request; resolved by a dispatch worker.

    ``outcome`` is ``"pending"`` until resolution, then ``"served"``,
    ``"shed"`` (the admission policy dropped it -- ``result()`` raises
    ``RequestShed``), ``"degraded"`` (served, but through the cheaper
    LSH path under the ``degrade-to-lsh`` overload policy),
    ``"partial"`` (served from the surviving shards only under
    ``on_shard_failure="partial"`` -- the result row carries
    ``coverage`` / ``failed_shards``), or ``"error"`` (the flush, or
    the worker around it, raised -- ``result()`` re-raises).
    """

    __slots__ = ("t_submit", "deadline", "query", "query_size",
                 "_event", "_result", "_error", "queue_wait_s", "latency_s",
                 "outcome", "degrade", "t_admit", "trace")

    def __init__(self, query, query_size, deadline: Optional[float]):
        self.query = query
        self.query_size = query_size
        self.t_submit = time.monotonic()
        self.deadline = deadline          # absolute monotonic time, or None
        self.queue_wait_s: Optional[float] = None
        self.latency_s: Optional[float] = None
        self.outcome = "pending"
        self.degrade = False              # admission marked: serve via LSH
        self.t_admit = self.t_submit      # end of admission (set if traced)
        self.trace = None                 # per-request root Span, or None
        self._event = threading.Event()
        self._result = None
        self._error: Optional[BaseException] = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None):
        """Block until resolved; returns the per-request ``SearchResult``
        (one row) or re-raises the batch's failure (``RequestShed`` when
        the admission policy dropped this request)."""
        if not self._event.wait(timeout):
            raise TimeoutError("request not served within timeout")
        if self._error is not None:
            raise self._error
        return self._result

    def _resolve(self, result, error: Optional[BaseException],
                 outcome: str = "served") -> None:
        self._result = result
        self._error = error
        self.outcome = outcome
        self.latency_s = time.monotonic() - self.t_submit
        self._event.set()


@dataclasses.dataclass
class ServerStats:
    """Serving counters; bounded reservoirs feed the percentile snapshot.

    ``queue_wait_s`` is admission -> batch pop, ``flush_s`` is one
    batch's dispatch+harvest wall clock, ``latency_s`` is admission ->
    result resolution (what a client observes).  ``worker_flushes`` /
    ``worker_busy_s`` split the flush histogram per dispatch worker;
    occupancy (busy / wall time) lands in ``snapshot()``.

    Every mutation happens under ``lock`` (the dispatch workers and the
    admission path share these fields), and ``snapshot()`` copies the
    reservoirs under the same lock before computing percentiles -- a
    concurrent submit storm can never hand ``np.percentile`` a deque
    that mutates mid-read.
    """

    requests: int = 0
    batches: int = 0
    errors: int = 0
    deadline_misses: int = 0
    shed: int = 0                 # requests dropped by the admission policy
    degraded: int = 0             # requests served via degrade-to-lsh
    partial: int = 0              # requests served with coverage < 1
    worker_restarts: int = 0      # dispatch loops revived after a crash
    refreshes: int = 0            # manifest refreshes that changed state
    flush_full: int = 0           # trigger: queue reached max_batch
    flush_aged: int = 0           # trigger: oldest request aged max_delay
    flush_deadline: int = 0       # trigger: a deadline was about to miss
    flush_drain: int = 0          # trigger: server stopping
    workers: int = 1
    window: int = 65536
    t_start: Optional[float] = None    # set by SearchServer.start()
    queue_wait_s: Deque[float] = dataclasses.field(default=None)  # type: ignore[assignment]
    flush_s: Deque[float] = dataclasses.field(default=None)       # type: ignore[assignment]
    latency_s: Deque[float] = dataclasses.field(default=None)     # type: ignore[assignment]
    batch_sizes: Deque[int] = dataclasses.field(default=None)     # type: ignore[assignment]
    coverage: Deque[float] = dataclasses.field(default=None)      # type: ignore[assignment]
    worker_flushes: List[int] = dataclasses.field(default=None)   # type: ignore[assignment]
    worker_busy_s: List[float] = dataclasses.field(default=None)  # type: ignore[assignment]

    def __post_init__(self):
        for name in ("queue_wait_s", "flush_s", "latency_s", "batch_sizes",
                     "coverage"):
            if getattr(self, name) is None:
                setattr(self, name, collections.deque(maxlen=self.window))
        if self.worker_flushes is None:
            self.worker_flushes = [0] * self.workers
        if self.worker_busy_s is None:
            self.worker_busy_s = [0.0] * self.workers
        self.lock = threading.Lock()

    def snapshot(self) -> Dict[str, object]:
        """One consistent dict of counters + p50/p99s (ms) + per-worker
        occupancy, copied under the lock (no torn reads)."""
        with self.lock:
            out = {"requests": self.requests, "batches": self.batches,
                   "errors": self.errors,
                   "deadline_misses": self.deadline_misses,
                   "shed": self.shed, "degraded": self.degraded,
                   "partial": self.partial,
                   "worker_restarts": self.worker_restarts,
                   "refreshes": self.refreshes,
                   "flush_full": self.flush_full,
                   "flush_aged": self.flush_aged,
                   "flush_deadline": self.flush_deadline,
                   "flush_drain": self.flush_drain,
                   "workers": self.workers}
            batch_sizes = list(self.batch_sizes)
            coverage = list(self.coverage)
            samples = {"queue_wait": list(self.queue_wait_s),
                       "flush": list(self.flush_s),
                       "latency": list(self.latency_s)}
            flushes = list(self.worker_flushes)
            busy = list(self.worker_busy_s)
            t_start = self.t_start
        out["mean_batch"] = (float(np.mean(batch_sizes)) if batch_sizes
                             else float("nan"))
        admitted = out["requests"] + out["shed"]
        out["shed_rate"] = out["shed"] / max(admitted, 1)
        out["degraded_rate"] = out["degraded"] / max(out["requests"], 1)
        out["partial_rate"] = out["partial"] / max(out["requests"], 1)
        out["mean_coverage"] = (float(np.mean(coverage)) if coverage
                                else float("nan"))
        out["deadline_miss_rate"] = (out["deadline_misses"]
                                     / max(out["requests"], 1))
        for name, vals in samples.items():
            out[f"{name}_p50_ms"] = _percentile(vals, 50) * 1e3
            out[f"{name}_p99_ms"] = _percentile(vals, 99) * 1e3
        out["worker_flushes"] = flushes
        elapsed = (time.monotonic() - t_start) if t_start else None
        out["worker_occupancy"] = [
            (b / elapsed if elapsed and elapsed > 0 else float("nan"))
            for b in busy]
        return out


def _summary_samples(name: str, help: str, vals: List[float],
                     labels: Tuple = ()):
    """Reservoir -> Prometheus summary samples (windowed, like the
    ``ServerStats`` percentile snapshot: count/sum cover the retained
    window, not all time)."""
    vals = sorted(vals)
    for q in (0.5, 0.99):
        v = (vals[min(len(vals) - 1, int(q * len(vals)))] if vals
             else float("nan"))
        yield Sample(name, "summary", help,
                     labels + (("quantile", f"{q:g}"),), float(v))
    yield Sample(name, "summary", help, labels, float(sum(vals)),
                 suffix="_sum")
    yield Sample(name, "summary", help, labels, float(len(vals)),
                 suffix="_count")


def _server_samples(server: "SearchServer"):
    """Registry collector over one live ``SearchServer`` (weakref'd by
    ``MetricsRegistry.register_object``): ``ServerStats`` counters, the
    live queue depth, per-worker flushes/busy-time/occupancy, and the
    latency reservoirs as windowed summaries.  Several live servers
    sharing a registry sum their counters (one process-wide total)."""
    st = server.stats
    with st.lock:
        counters = {
            "serve_requests_total": (st.requests, "requests served"),
            "serve_shed_total": (st.shed,
                                 "requests dropped by admission control"),
            "serve_degraded_total": (st.degraded,
                                     "requests served via degrade-to-lsh"),
            "serve_partial_total": (st.partial,
                                    "requests served from surviving shards "
                                    "only (coverage < 1)"),
            "serve_worker_restarts_total": (st.worker_restarts,
                                            "dispatch loops revived after "
                                            "an unexpected crash"),
            "serve_errors_total": (st.errors, "failed flushes/submits"),
            "serve_deadline_misses_total": (st.deadline_misses,
                                            "results landed past deadline"),
            "serve_refreshes_total": (st.refreshes,
                                      "manifest refreshes that moved state"),
            "serve_batches_total": (st.batches, "micro-batches flushed"),
        }
        triggers = {"full": st.flush_full, "aged": st.flush_aged,
                    "deadline": st.flush_deadline, "drain": st.flush_drain}
        flushes = list(st.worker_flushes)
        busy = list(st.worker_busy_s)
        t_start = st.t_start
        reservoirs = {
            "serve_queue_wait_seconds": ("admission -> batch pop",
                                         list(st.queue_wait_s)),
            "serve_flush_seconds": ("one batch dispatch+harvest",
                                    list(st.flush_s)),
            "serve_latency_seconds": ("admission -> resolution",
                                      list(st.latency_s)),
            "serve_batch_size": ("requests per flushed batch",
                                 [float(v) for v in st.batch_sizes]),
            "serve_coverage": ("fraction of corpus docs searched per "
                               "flush (1.0 = full coverage)",
                               list(st.coverage)),
        }
    for name, (v, help) in counters.items():
        yield Sample(name, "counter", help, (), float(v))
    for trig, v in triggers.items():
        yield Sample("serve_flushes_total", "counter",
                     "flushes by trigger", (("trigger", trig),), float(v))
    yield Sample("serve_queue_depth", "gauge",
                 "requests waiting in the admission queue", (),
                 float(len(server._queue)))
    yield Sample("serve_workers", "gauge", "dispatch workers", (),
                 float(st.workers))
    elapsed = (time.monotonic() - t_start) if t_start else None
    for i in range(len(flushes)):
        lbl = (("worker", str(i)),)
        yield Sample("serve_worker_flushes_total", "counter",
                     "flushes per dispatch worker", lbl, float(flushes[i]))
        yield Sample("serve_worker_busy_seconds_total", "counter",
                     "flush wall-clock per dispatch worker", lbl,
                     float(busy[i]))
        occ = busy[i] / elapsed if elapsed and elapsed > 0 else float("nan")
        yield Sample("serve_worker_occupancy", "gauge",
                     "busy time / wall time per dispatch worker", lbl, occ)
    for name, (help, vals) in reservoirs.items():
        yield from _summary_samples(name, help, vals)


class _WorkerHandle(_BatchedAdmission):
    """One dispatch worker's private batched-admission state over the
    SHARED searcher.

    ``submit`` validates/queues rows against the shared wire spec;
    ``flush`` runs the worker's batch as ONE ``searcher.search`` call --
    the underlying searcher snapshots its state per search, so
    concurrent flushes from different workers are safe and bit-identical
    to direct calls, while each worker's pending queue stays private
    (the shared searcher's own submit/flush state is never raced).
    """

    def __init__(self, searcher, on_shard_failure: Optional[str] = None):
        self._searcher = searcher
        self._on_shard_failure = on_shard_failure
        self._admission_init()

    @property
    def spec(self):
        return self._searcher.spec

    def search(self, queries, topk: int = 10, *, mode: str = "exact",
               query_sizes=None):
        kwargs = {}
        if self._on_shard_failure is not None:
            # only a sharded router understands the policy; a plain
            # IndexSearcher server leaves it unset
            kwargs["on_shard_failure"] = self._on_shard_failure
        return self._searcher.search(queries, topk, mode=mode,
                                     query_sizes=query_sizes, **kwargs)


ADMISSION_POLICIES = ("none", "reject", "shed-oldest", "degrade-to-lsh")


class SearchServer:
    """Deadline-aware micro-batching front end over a searcher.

    ``searcher`` is anything with a ``search`` batch API and a wire
    ``spec`` (``IndexSearcher`` or ``ShardedIndex``); ``num_workers``
    dispatch workers drain the shared admission queue, each through its
    own private admission handle, so flushes overlap (default: one per
    device on the searcher's mesh ``"data"`` axis, else 1).  A flush
    fires when the queue holds ``max_batch`` requests, when the oldest
    request has waited ``max_delay_s``, or when a request's deadline
    minus the estimated flush latency (EWMA of recent flushes) is about
    to pass.  ``refresh=True`` (default) calls ``searcher.refresh()``
    -- when it has one -- before each flush wave (one worker at a time,
    via a try-lock), so a served ``ShardedIndex`` picks up concurrent
    appends batch by batch.

    Overload: ``admission`` picks the policy (see the module docstring),
    triggered when the queue holds ``max_queue`` requests or when the
    EWMA-projected queue wait exceeds the request's deadline budget
    (its ``deadline_s``, else ``deadline_budget_s``).
    """

    def __init__(self, searcher, *, max_batch: int = 64,
                 max_delay_s: float = 0.005, topk: int = 10,
                 mode: str = "exact", refresh: bool = True,
                 deadline_safety: float = 1.5,
                 num_workers: Optional[int] = None,
                 admission: str = "none",
                 max_queue: Optional[int] = None,
                 deadline_budget_s: Optional[float] = None,
                 on_shard_failure: Optional[str] = None,
                 registry=None, tracer=None):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if mode not in ("exact", "lsh"):
            raise ValueError(f"mode must be 'exact' or 'lsh', got {mode!r}")
        if on_shard_failure not in (None, "fail", "partial"):
            raise ValueError(f"on_shard_failure must be None, 'fail' or "
                             f"'partial', got {on_shard_failure!r}")
        if admission not in ADMISSION_POLICIES:
            raise ValueError(f"admission must be one of "
                             f"{ADMISSION_POLICIES}, got {admission!r}")
        if admission == "degrade-to-lsh" and mode != "exact":
            raise ValueError("admission='degrade-to-lsh' needs mode='exact' "
                             "(there is nothing cheaper to degrade to)")
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        if num_workers is None:
            num_workers = self._default_workers(searcher)
        if num_workers < 1:
            raise ValueError(f"num_workers must be >= 1, got {num_workers}")
        self.searcher = searcher
        self.max_batch = max_batch
        self.max_delay_s = max_delay_s
        self.topk = topk
        self.mode = mode
        self.refresh = refresh and hasattr(searcher, "refresh")
        self.deadline_safety = deadline_safety
        self.num_workers = num_workers
        self.admission = admission
        self.max_queue = max_queue
        self.deadline_budget_s = deadline_budget_s
        self.on_shard_failure = on_shard_failure
        self.stats = ServerStats(workers=num_workers)
        # observability: this server's counters/reservoirs export through
        # the (default: process-wide) registry -- a weakref collector, so
        # registration never outlives the server -- and per-request span
        # trees go to the tracer (disabled by default: off the hot path).
        # Tests needing totals in isolation pass private instances.
        self.registry = registry if registry is not None else get_registry()
        self.tracer = tracer if tracer is not None else get_tracer()
        self.registry.register_object(self, _server_samples)
        # live roofline gauges, updated per exact flush: the autotuning
        # signal (predicted-vs-measured flush bytes/time) at serve time
        g = self.registry.gauge
        self._g_roofline = {
            "bytes": g("serve_roofline_predicted_bytes",
                       "exact_scan_cost HBM bytes for the last flush"),
            "predicted_s": g("serve_roofline_predicted_seconds",
                             "memory-bound time prediction, last flush"),
            "measured_s": g("serve_roofline_measured_seconds",
                            "measured wall clock of the last exact flush"),
            "gap": g("serve_roofline_gap",
                     "measured / predicted flush time (1.0 = at roofline)"),
            "gbps": g("serve_roofline_achieved_gbps",
                      "effective streaming bandwidth of the last flush"),
        }
        self._queue: Deque[PendingResult] = collections.deque()
        self._cond = threading.Condition()
        self._refresh_lock = threading.Lock()
        self._stopping = False
        self._threads: List[threading.Thread] = []
        self._handles: List[_WorkerHandle] = []
        self._est_flush_s = max(max_delay_s, 1e-3)   # EWMA, pre-warm guess

    @staticmethod
    def _default_workers(searcher) -> int:
        """One worker per device on the searcher's mesh ``"data"`` axis
        (overlapping flushes keep every placed device busy), else 1."""
        mesh = getattr(searcher, "mesh", None)
        if mesh is None:
            return 1
        try:
            from repro.sharding.rules import data_axis_devices
            return max(1, len(data_axis_devices(mesh)))
        except (ImportError, ValueError):
            return 1

    # -- lifecycle -------------------------------------------------------
    def start(self) -> "SearchServer":
        if self._threads:
            raise RuntimeError("server already started")
        self._stopping = False
        self.stats.t_start = time.monotonic()
        self._handles = [_WorkerHandle(self.searcher, self.on_shard_failure)
                         for _ in range(self.num_workers)]
        self._threads = [
            threading.Thread(target=self._dispatch_loop, args=(i,),
                             daemon=True, name=f"search-dispatch-{i}")
            for i in range(self.num_workers)]
        for t in self._threads:
            t.start()
        return self

    def stop(self) -> None:
        """Drain the queue (remaining requests are flushed) and join."""
        if not self._threads:
            return
        with self._cond:
            self._stopping = True
            self._cond.notify_all()
        for t in self._threads:
            t.join()
        self._threads = []
        self._handles = []

    def __enter__(self) -> "SearchServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- admission (any thread) -----------------------------------------
    def submit(self, query, *, query_size: Optional[int] = None,
               deadline_s: Optional[float] = None) -> PendingResult:
        """Admit one query row; returns immediately with a handle.

        ``deadline_s`` is relative (seconds from now): the dispatchers
        try to flush early enough that the result lands before it, and
        the admission policy (when one is set) uses it as the overload
        budget.  Under overload the returned handle may already be
        resolved as shed (``result()`` raises ``RequestShed``) or marked
        for LSH degradation -- check ``PendingResult.outcome``.
        """
        if not self._threads:
            raise RuntimeError("server not started (use `with server:` "
                               "or call start())")
        deadline = (time.monotonic() + deadline_s
                    if deadline_s is not None else None)
        req = PendingResult(query, query_size, deadline)
        with self._cond:
            if self._stopping:
                raise RuntimeError("server is stopping")
            budget = (deadline_s if deadline_s is not None
                      else self.deadline_budget_s)
            if self.admission == "none":
                self._queue.append(req)
            else:
                self._admit(req, budget)
            self._cond.notify_all()
        tracer = self.tracer
        if tracer.enabled:
            # root async span: [t_submit, resolution]; "admission" is its
            # first child, so the per-request children partition the
            # request's recorded end-to-end latency exactly
            root = tracer.start_span("request", t0=req.t_submit,
                                     kind="async",
                                     args={"deadline_s": deadline_s})
            root.trace_id = root.span_id
            req.trace = root
            req.t_admit = time.monotonic()
            tracer.add_span("admission", req.t_submit, req.t_admit,
                            parent=root, kind="async",
                            args={"policy": self.admission,
                                  "degrade": req.degrade})
            if req.outcome == "shed":      # rejected on arrival
                tracer.end_span(root, t1=req.t_admit,
                                args={"outcome": "shed"})
                req.trace = None
        return req

    def _projected_wait_s(self, depth: int) -> float:
        """EWMA-projected queue wait for a request behind ``depth``
        others: full batches ahead of it, divided over the workers."""
        batches = (depth + self.max_batch) // self.max_batch
        return batches * self._est_flush_s / self.num_workers

    def _overloaded(self, depth: int, budget: Optional[float]) -> bool:
        if self.max_queue is not None and depth >= self.max_queue:
            return True
        return (budget is not None
                and self._projected_wait_s(depth) > budget)

    def _shed(self, req: PendingResult, why: str) -> None:
        with self.stats.lock:
            self.stats.shed += 1
        req._resolve(None, RequestShed(why), outcome="shed")
        if req.trace is not None:          # shed-oldest: already traced
            self.tracer.end_span(req.trace,
                                 t1=req.t_submit + req.latency_s,
                                 args={"outcome": "shed"})
            req.trace = None

    def _admit(self, req: PendingResult, budget: Optional[float]) -> None:
        """Apply the admission policy (caller holds ``_cond``)."""
        depth = len(self._queue)
        if not self._overloaded(depth, budget):
            self._queue.append(req)
            return
        if self.admission == "reject":
            self._shed(req, f"admission rejected: queue depth {depth}, "
                            f"projected wait "
                            f"{self._projected_wait_s(depth) * 1e3:.1f}ms "
                            f"over budget")
            return
        if self.admission == "shed-oldest":
            self._queue.append(req)
            while len(self._queue) > 1 and self._overloaded(
                    len(self._queue) - 1, budget):
                self._shed(self._queue.popleft(),
                           "admission overload: shed oldest queued request")
            return
        # degrade-to-lsh: admit, but the batch serves the cheap path
        req.degrade = True
        self._queue.append(req)

    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    @property
    def generation(self) -> Optional[int]:
        """Manifest generation the served searcher is on (None when the
        searcher has no notion of one, e.g. a single ``IndexSearcher``)
        -- lets operators confirm a live append/spill was picked up."""
        return getattr(self.searcher, "generation", None)

    # -- dispatch (the worker pool) --------------------------------------
    def _next_due(self, now: float) -> float:
        """Earliest time the current queue must flush."""
        oldest = self._queue[0]
        due = oldest.t_submit + self.max_delay_s
        margin = self._est_flush_s * self.deadline_safety
        for r in self._queue:
            if r.deadline is not None:
                due = min(due, r.deadline - margin)
        return due

    def _take_batch(self):
        """Wait for a flush trigger, pop one batch (caller holds
        ``_cond``).  Returns ``(None, "")`` when stopping and drained.
        Batches never mix degraded and non-degraded requests (the
        degrade-to-lsh policy switches the whole batch's mode)."""
        while True:
            if not self._queue:
                if self._stopping:
                    return None, ""
                self._cond.wait()
                continue
            if self._stopping:
                trigger = "drain"
                break
            now = time.monotonic()
            if len(self._queue) >= self.max_batch:
                trigger = "full"
                break
            due = self._next_due(now)
            if now >= due:
                oldest_due = self._queue[0].t_submit + self.max_delay_s
                trigger = "aged" if due >= oldest_due else "deadline"
                break
            self._cond.wait(timeout=due - now)
        flag = self._queue[0].degrade
        batch: List[PendingResult] = []
        while (self._queue and len(batch) < self.max_batch
               and self._queue[0].degrade == flag):
            batch.append(self._queue.popleft())
        if self._queue:
            self._cond.notify_all()       # leftover work for other workers
        return batch, trigger

    def _dispatch_loop(self, wi: int) -> None:
        handle = self._handles[wi]
        while True:
            batch = None
            try:
                with self._cond:
                    batch, trigger = self._take_batch()
                if batch is None:
                    return
                if batch:
                    self._flush_batch(batch, trigger, wi, handle)
            except Exception as e:
                # _flush_batch already contains the expected failure
                # domains (bad query -> per request, flush error -> per
                # batch); anything that still escapes must not silently
                # kill the worker with requests queued behind it.  Fail
                # whatever this worker was holding, swap in a fresh
                # handle (the crashed one may hold torn admission
                # state), and keep draining.
                stats = self.stats
                with stats.lock:
                    stats.worker_restarts += 1
                    stats.errors += 1
                for r in (batch or ()):
                    if r.done():
                        continue
                    r._resolve(None, e, outcome="error")
                    if r.trace is not None:
                        self.tracer.end_span(r.trace,
                                             t1=r.t_submit + r.latency_s,
                                             args={"outcome": "error"})
                        r.trace = None
                handle = _WorkerHandle(self.searcher, self.on_shard_failure)
                self._handles[wi] = handle

    def _flush_batch(self, batch: List[PendingResult], trigger: str,
                     wi: int, handle: _WorkerHandle) -> None:
        t0 = time.monotonic()
        stats = self.stats
        tracer = self.tracer
        degraded = bool(batch[0].degrade and self.mode == "exact")
        mode = "lsh" if degraded else self.mode
        outcome = "degraded" if degraded else "served"
        with stats.lock:
            setattr(stats, f"flush_{trigger}",
                    getattr(stats, f"flush_{trigger}") + 1)
        if tracer.enabled:
            tracer.take_phases()         # drop a prior flush's stale notes
        wf = tracer.start_span("worker_flush", t0=t0,
                               args={"worker": wi, "trigger": trigger,
                                     "mode": mode, "batch": len(batch)})
        if self.refresh and self._refresh_lock.acquire(blocking=False):
            # one worker refreshes per flush wave; the rest serve the
            # snapshot they'd have gotten anyway (keep serving on a
            # failed refresh, too)
            try:
                try:
                    with tracer.span("refresh", parent=wf):
                        if self.searcher.refresh():
                            with stats.lock:
                                stats.refreshes += 1
                except Exception:
                    with stats.lock:
                        stats.errors += 1
            finally:
                self._refresh_lock.release()
        tickets: Dict[int, PendingResult] = {}
        for r in batch:
            r.queue_wait_s = t0 - r.t_submit
            with stats.lock:
                stats.queue_wait_s.append(r.queue_wait_s)
            if r.trace is not None:
                tracer.add_span("queue", r.t_admit, t0, parent=r.trace,
                                kind="async", args={"worker": wi})
            try:
                tickets[handle.submit(
                    r.query, query_size=r.query_size)] = r
            except Exception as e:       # a malformed query fails only itself
                with stats.lock:
                    stats.errors += 1
                r._resolve(None, e)
                if r.trace is not None:
                    tracer.end_span(r.trace,
                                    t1=r.t_submit + r.latency_s,
                                    args={"outcome": "error"})
                    r.trace = None
        error: Optional[BaseException] = None
        out: Dict[int, object] = {}
        if tickets:
            try:
                with tracer.jax_annotation(f"flush:w{wi}"):
                    out = handle.flush(self.topk, mode=mode)
            except Exception as e:
                error = e
                with stats.lock:
                    stats.errors += 1
        # batch-level phases the searcher noted on THIS thread (mesh
        # dispatch, top-k merge, ...): replayed below as children of every
        # co-batched request's span tree
        phases = tracer.take_phases() if tracer.enabled else []
        dt = time.monotonic() - t0
        tracer.end_span(wf, t1=t0 + dt)
        now = time.monotonic()
        # on_shard_failure="partial": the searcher annotated every row of
        # this flush with the same coverage; < 1 means shards dropped out
        cov = 1.0
        if tickets and error is None:
            first = next(iter(out.values()), None)
            cov = float(getattr(first, "coverage", 1.0))
        with stats.lock:
            self._est_flush_s = 0.7 * self._est_flush_s + 0.3 * dt
            stats.batches += 1
            stats.flush_s.append(dt)
            stats.batch_sizes.append(len(batch))
            stats.worker_flushes[wi] += 1
            stats.worker_busy_s[wi] += dt
            if degraded:
                stats.degraded += len(tickets)
            if tickets and error is None:
                stats.coverage.append(cov)
                if cov < 1.0:
                    stats.partial += len(tickets)
        if cov < 1.0:
            outcome = "partial"
        if (tickets and not degraded and mode == "exact" and error is None
                and cov == 1.0):   # a partial flush scanned fewer bytes
            self._update_roofline(len(tickets), dt)
        for ticket, r in tickets.items():
            r._resolve(out.get(ticket), error, outcome=outcome)
            with stats.lock:
                stats.requests += 1
                stats.latency_s.append(r.latency_s)
                if r.deadline is not None and now > r.deadline:
                    stats.deadline_misses += 1
            if r.trace is not None:
                t_res = r.t_submit + r.latency_s
                fl = tracer.start_span("flush", parent=r.trace, t0=t0,
                                       kind="async",
                                       args={"worker": wi,
                                             "trigger": trigger,
                                             "mode": mode})
                for name, p0, p1 in phases:
                    tracer.add_span(name, p0, p1, parent=fl, kind="async")
                tracer.end_span(fl, t1=t_res)
                tracer.end_span(r.trace, t1=t_res,
                                args={"outcome": r.outcome})
                r.trace = None

    def _update_roofline(self, n_queries: int, flush_s: float) -> None:
        """Refresh the live roofline gauges from one measured exact flush
        (``repro.roofline.search``): predicted HBM bytes for this corpus
        + batch, the memory-bound time prediction, and the gap."""
        try:
            n = getattr(self.searcher, "n", None)
            if n is None:
                n = self.searcher.index.n
            cost = exact_scan_cost(int(n), int(self.searcher.spec.words),
                                   n_queries, topk=self.topk)
            gap = roofline_gap(cost["bytes"], flush_s)
        except (AttributeError, ValueError):
            return                       # searcher without n/words, dt=0
        g = self._g_roofline
        g["bytes"].set(cost["bytes"])
        g["predicted_s"].set(gap["predicted_s"])
        g["measured_s"].set(flush_s)
        g["gap"].set(gap["gap"])
        g["gbps"].set(gap["achieved_gbps"])


# ---------------------------------------------------------------------------
# Synthetic traffic: Zipf-popular queries, Poisson arrivals
# ---------------------------------------------------------------------------

class ZipfianTraffic:
    """Synthetic serving load over an ``n_docs`` corpus.

    Query popularity follows a Zipf law with exponent ``alpha`` over a
    random permutation of the doc ids (so popular docs are scattered,
    not clustered at low ids); arrivals are a Poisson process at
    ``rate_qps``.  Deterministic per seed -- and independent of the
    serving side entirely (worker counts, admission policies), so load
    replays compare servers on identical traffic.
    """

    def __init__(self, n_docs: int, *, alpha: float = 1.1, seed: int = 0):
        if n_docs < 1:
            raise ValueError(f"n_docs must be >= 1, got {n_docs}")
        self.n_docs = n_docs
        self.alpha = alpha
        self._rng = np.random.default_rng(seed)
        weights = 1.0 / np.arange(1, n_docs + 1, dtype=np.float64) ** alpha
        self._probs = weights / weights.sum()
        self._perm = self._rng.permutation(n_docs)

    def ids(self, m: int) -> np.ndarray:
        """``m`` query doc ids, Zipf-popular."""
        ranks = self._rng.choice(self.n_docs, size=m, p=self._probs)
        return self._perm[ranks]

    def arrival_offsets(self, m: int, rate_qps: float) -> np.ndarray:
        """``m`` monotone arrival times (seconds from start) at the
        offered load ``rate_qps``."""
        if rate_qps <= 0:
            raise ValueError(f"rate_qps must be > 0, got {rate_qps}")
        gaps = self._rng.exponential(1.0 / rate_qps, size=m)
        return np.cumsum(gaps)
