"""Step-function builders per (architecture family x step kind).

One factory, ``build_cell(arch_id, cell_name, smoke)``, returns a
``CellProgram``: the step callable, shape-only input avals, and the
PartitionSpec trees for inputs/params/opt-state -- everything the smoke
tests, the dry-run and the roofline harness need.  Smoke tests call
``program.init_inputs(key)`` to materialize small real inputs.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import (cells_for, config_for_cell, get_arch,
                                get_cell, input_specs)
from repro.models import gnn as gnn_lib
from repro.models import recsys as recsys_lib
from repro.models import transformer as tfm
from repro.optim import adamw, warmup_cosine
from repro.optim.base import Optimizer, apply_updates
from repro.optim.optimizers import adafactor_fused
from repro.sharding.params import opt_state_specs, param_specs_for

ADAFACTOR_THRESHOLD = 50e9      # params above this use factored optimizer


@dataclasses.dataclass
class CellProgram:
    arch_id: str
    cell_name: str
    kind: str
    family: str
    config: Any
    step: Callable                      # step(params, [opt_state,] **inputs)
    param_avals: Any
    opt_avals: Any                      # None for inference kinds
    input_avals: Dict[str, Any]
    param_specs: Any
    opt_specs: Any
    input_specs_tree: Dict[str, Any]
    optimizer: Optional[Optimizer]
    init_params: Callable[[jax.Array], Any]

    def abstract_args(self) -> Tuple:
        if self.opt_avals is not None:
            return (self.param_avals, self.opt_avals, self.input_avals)
        return (self.param_avals, self.input_avals)

    def arg_specs(self) -> Tuple:
        if self.opt_avals is not None:
            return (self.param_specs, self.opt_specs, self.input_specs_tree)
        return (self.param_specs, self.input_specs_tree)


MOMENTUM_FREE_THRESHOLD = 300e9   # T5-style beta1=0 adafactor above this


def _pick_optimizer(n_params: int, steps: int = 10000, family: str = "lm"
                    ) -> Tuple[Optimizer, bool]:
    """Returns (optimizer, fused) -- fused optimizers apply updates
    in-place per layer slice (see optim.adafactor_fused)."""
    lr = warmup_cosine(3e-4, 200, steps)
    if family == "recsys":
        # embedding tables dominate: factored second moment (O(V + d)
        # state per table, rowwise-adagrad-like) instead of AdamW's
        # 2x-fp32-table state+traffic -- §Perf autoint iteration 1
        return adafactor_fused(lr, momentum=None), True
    if n_params > MOMENTUM_FREE_THRESHOLD:
        # 671B-class: even bf16 momentum (~5 GB/chip at 256 chips) would
        # blow the 16 GB HBM budget; classic momentum-free Adafactor.
        return adafactor_fused(lr, momentum=None), True
    if n_params > ADAFACTOR_THRESHOLD:
        return adafactor_fused(lr, momentum=0.9), True
    return adamw(lr, weight_decay=0.01), False


def _make_train_step(loss_fn, optimizer, microbatch: int = 1,
                     fused: bool = False):
    """Train step with optional gradient accumulation over microbatches.

    Microbatching bounds the remat activation stash: each scan iteration
    runs fwd+bwd on 1/m of the batch, so only that slice's stash is live.
    Gradients accumulate in the parameter dtype (bf16 for the large LMs --
    one extra param-sized buffer per chip).  ``fused`` optimizers apply
    updates themselves (update(g, s, p) -> (new_params, new_state)).
    """
    def apply_opt(grads, opt_state, params):
        if fused:
            return optimizer.update(grads, opt_state, params)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state

    if microbatch <= 1:
        def step(params, opt_state, inputs):
            loss, grads = jax.value_and_grad(loss_fn)(params, inputs)
            params, opt_state = apply_opt(grads, opt_state, params)
            return params, opt_state, loss

        return step

    def step(params, opt_state, inputs):
        m = microbatch
        mbs = jax.tree_util.tree_map(
            lambda x: x.reshape((m, x.shape[0] // m) + x.shape[1:]), inputs)

        def body(carry, mb):
            gacc, lacc = carry
            loss, grads = jax.value_and_grad(loss_fn)(params, mb)
            gacc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(a.dtype), gacc, grads)
            return (gacc, lacc + loss), None

        g0 = jax.tree_util.tree_map(jnp.zeros_like, params)
        (grads, loss), _ = jax.lax.scan(body, (g0, jnp.zeros((), jnp.float32)),
                                        mbs)
        # keep the param dtype: bf16 / python-int silently promotes to f32,
        # which would drag a full fp32 grad tree through the optimizer
        grads = jax.tree_util.tree_map(
            lambda g: (g / m).astype(g.dtype), grads)
        params, opt_state = apply_opt(grads, opt_state, params)
        return params, opt_state, loss / m

    return step


# -- input sharding specs per kind ------------------------------------------

def _lm_input_spec_tree(kind: str, cfg, avals) -> Dict[str, Any]:
    if kind in ("lm_train", "lm_prefill"):
        return {k: P("batch", None) for k in avals}
    # decode: cache entries (L, B, len, ...) -- shard cache length over
    # "model" (decode sequence parallelism), batch over "batch".
    def cache_spec(leaf):
        if leaf.ndim == 5:      # (L, B, len, n_kv, hd)
            return P(None, "batch", "model", None, None)
        return P(None, "batch", "model", None)  # (L, B, len, lora/rope)

    return {
        "cache": jax.tree_util.tree_map(cache_spec, avals["cache"]),
        "tokens": P("batch"),
        "pos": P(),
    }


def _gnn_input_spec_tree(avals) -> Dict[str, Any]:
    spec = {
        "node_feats": P("batch", None),
        "edge_index": P(None, ("batch", "model")),
        "edge_mask": P(("batch", "model")),
        "labels": P("batch"),
        "node_mask": P("batch"),
    }
    if "graph_ids" in avals:
        spec["graph_ids"] = P("batch")
    return spec


def _recsys_input_spec_tree(avals) -> Dict[str, Any]:
    out = {}
    for k, v in avals.items():
        if k == "n_candidates":
            continue
        rank = len(v.shape)
        out[k] = P("batch", *([None] * (rank - 1))) if rank else P()
    return out


# -- cell builder -------------------------------------------------------------

def build_cell(arch_id: str, cell_name: str, smoke: bool = False
               ) -> CellProgram:
    spec = get_arch(arch_id)
    cell = get_cell(arch_id, cell_name)
    cfg = config_for_cell(arch_id, cell, smoke)
    avals = input_specs(arch_id, cell_name, smoke)
    family = spec.family

    if family == "lm":
        init = functools.partial(tfm.init_params, cfg)
        loss = functools.partial(_lm_loss, cfg=cfg)
    elif family == "gnn":
        init = functools.partial(_gnn_init, cfg)
        loss = functools.partial(_gnn_loss, cfg=cfg)
    else:
        init = functools.partial(_recsys_init, cfg)
        loss = functools.partial(_recsys_loss, cfg=cfg)

    import math
    param_avals = jax.eval_shape(init, jax.random.PRNGKey(0))
    n_params = sum(math.prod(l.shape)
                   for l in jax.tree_util.tree_leaves(param_avals))
    p_specs = param_specs_for(family, param_avals)

    kind = cell.kind
    optimizer = None
    opt_avals = None
    o_specs = None

    if kind in ("lm_train", "gnn_train_full", "gnn_train_sampled",
                "gnn_train_graphs", "recsys_train"):
        optimizer, fused = _pick_optimizer(n_params, family=family)
        opt_avals = jax.eval_shape(optimizer.init, param_avals)
        o_specs = opt_state_specs(p_specs, param_avals, opt_avals)
        micro = getattr(cfg, "microbatch", 1) if not smoke else 1
        step = _make_train_step(loss, optimizer, microbatch=micro,
                                fused=fused)
    elif kind == "lm_prefill":
        def step(params, inputs):
            return tfm.forward(params, inputs["tokens"], cfg)
    elif kind == "lm_decode":
        def step(params, inputs):
            return tfm.serve_step(params, inputs["cache"], inputs["tokens"],
                                  inputs["pos"], cfg)
    elif kind == "recsys_serve":
        def step(params, inputs):
            return recsys_lib.serve_scores(params, inputs, cfg)
    elif kind == "recsys_retrieval":
        n_cand = avals.pop("n_candidates")

        def step(params, inputs):
            return recsys_lib.retrieval_scores(params, inputs, cfg, n_cand)
    else:
        raise ValueError(kind)

    if family == "lm":
        in_spec_tree = _lm_input_spec_tree(kind, cfg, avals)
    elif family == "gnn":
        in_spec_tree = _gnn_input_spec_tree(avals)
    else:
        in_spec_tree = _recsys_input_spec_tree(avals)

    return CellProgram(
        arch_id=arch_id, cell_name=cell_name, kind=kind, family=family,
        config=cfg, step=step, param_avals=param_avals, opt_avals=opt_avals,
        input_avals=avals, param_specs=p_specs, opt_specs=o_specs,
        input_specs_tree=in_spec_tree, optimizer=optimizer,
        init_params=init)


def _lm_loss(params, inputs, cfg):
    return tfm.train_loss(params, inputs, cfg)


def _gnn_init(cfg, key):
    return gnn_lib.init_gnn_params(cfg, key)


def _gnn_loss(params, inputs, cfg):
    return gnn_lib.gnn_loss(params, inputs, cfg)


def _recsys_init(cfg, key):
    return recsys_lib.init_recsys_params(cfg, key)


def _recsys_loss(params, inputs, cfg):
    return recsys_lib.recsys_loss(params, inputs, cfg)


# -- concrete input materialization (smoke tests / examples) -----------------

def init_inputs(program: CellProgram, key: jax.Array) -> Dict[str, Any]:
    """Random small inputs matching the cell's avals (smoke scale)."""
    out = {}
    cfg = program.config
    for name, aval in program.input_avals.items():
        k, key = jax.random.split(key)
        out[name] = _random_like(k, name, aval, program)
    if program.kind == "gnn_train_graphs":
        # consistent block-diagonal graph ids
        n_nodes = program.input_avals["node_feats"].shape[0]
        bg = program.input_avals["labels"].shape[0]
        per = n_nodes // bg
        out["graph_ids"] = jnp.repeat(jnp.arange(bg, dtype=jnp.int32), per)
    return out


def _random_like(key, name: str, aval, program: CellProgram):
    cfg = program.config
    shape, dtype = aval.shape if hasattr(aval, "shape") else (), None
    if isinstance(aval, dict) or not hasattr(aval, "dtype"):
        # cache pytree
        return jax.tree_util.tree_map(
            lambda l: jnp.zeros(l.shape, l.dtype), aval)
    dtype = aval.dtype
    if name in ("tokens", "labels") and program.family == "lm":
        hi = cfg.vocab
        return jax.random.randint(key, shape, 0, hi, dtype=jnp.int32)
    if name == "pos":
        return jnp.asarray(2, jnp.int32)
    if name == "edge_index":
        n_nodes = program.input_avals["node_feats"].shape[0]
        return jax.random.randint(key, shape, 0, n_nodes, dtype=jnp.int32)
    if name == "labels":
        if dtype == jnp.float32:
            return jax.random.bernoulli(key, 0.5, shape).astype(jnp.float32)
        n_classes = getattr(cfg, "n_classes", 2)
        return jax.random.randint(key, shape, 0, n_classes, dtype=jnp.int32)
    if name in ("edge_mask", "node_mask", "hist_mask"):
        return jnp.ones(shape, jnp.float32)
    if name == "field_ids":
        return jax.random.randint(key, shape, 0, cfg.vocab, dtype=jnp.int32)
    if name in ("hist_ids", "target_id"):
        return jax.random.randint(key, shape, 0, cfg.item_vocab,
                                  dtype=jnp.int32)
    if name == "set_ids":
        return jax.random.randint(key, shape, 0, 1 << cfg.minhash_s,
                                  dtype=jnp.int32)
    if name == "set_counts":
        return jax.random.randint(key, shape, 1, cfg.set_nnz, dtype=jnp.int32)
    if jnp.issubdtype(dtype, jnp.floating):
        return jax.random.normal(key, shape, dtype)
    return jnp.zeros(shape, dtype)
