"""Production mesh construction.

Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model") -- the
"pod" axis is an outer data-parallel axis whose gradient all-reduce
crosses the inter-pod links once per step.

Defined as functions (never module-level constants) so importing this
module does not touch jax device state.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_devices: int = 1, *,
                    axes: Sequence[str] = ("data", "model"),
                    shape: Optional[Tuple[int, ...]] = None):
    """A small mesh over the real local devices (tests).

    ``axes`` names the mesh axes; ``shape`` optionally fixes the extent
    per axis (must multiply to ``n_devices``).  Defaults keep the
    historical model-major layout -- all devices along the LAST axis,
    e.g. ``(1, n)`` over ("data", "model") -- while
    ``make_debug_mesh(8, axes=("data",))`` builds the data-parallel
    ``(8,)`` mesh the retrieval fan-out tests place shards on.
    """
    devs = jax.devices()[:n_devices]
    if shape is None:
        shape = (1,) * (len(axes) - 1) + (len(devs),)
    if int(np.prod(shape)) != len(devs):
        raise ValueError(f"mesh shape {shape} needs {int(np.prod(shape))} "
                         f"devices, have {len(devs)}")
    return jax.sharding.Mesh(np.array(devs).reshape(shape), tuple(axes))
