"""Production mesh construction.

Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model") -- the
"pod" axis is an outer data-parallel axis whose gradient all-reduce
crosses the inter-pod links once per step.

Defined as functions (never module-level constants) so importing this
module does not touch jax device state.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_devices: int = 1):
    """A small mesh over the real local devices (tests)."""
    devs = jax.devices()[:n_devices]
    import numpy as np
    return jax.sharding.Mesh(np.array(devs).reshape(1, len(devs)),
                             ("data", "model"))
