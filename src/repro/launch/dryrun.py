import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "")
    + " --xla_force_host_platform_device_count=512"
    # XLA:CPU's while-loop invariant code motion hoists fp32 converts of
    # scanned (layer-stacked) tensors out of loops, materializing
    # whole-stack fp32 copies (2x params!).  XLA:TPU schedules these
    # memory-aware; on the CPU dry-run we disable the passes so
    # memory_analysis() reflects the TPU-realistic footprint.
    + " --xla_disable_hlo_passes=while-loop-expensive-invariant-code-motion"
    ",while-loop-invariant-code-motion")
# The lines above MUST run before any other import (jax locks the device
# count at first init).  Everything below is ordinary code.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the full-config step program, places params /
optimizer state / inputs under the production shardings, and runs

    with mesh:
        lowered  = jax.jit(step, in_shardings=..., donate...).lower(*avals)
        compiled = lowered.compile()
        compiled.memory_analysis()    # proves it fits 16 GB/chip
        compiled.cost_analysis()      # FLOPs/bytes for the roofline

for the 16x16 single-pod mesh and the 2x16x16 multi-pod mesh.  Results
(bytes/chip, FLOPs, collective schedule, roofline terms) are appended to
experiments/dryrun.jsonl, which EXPERIMENTS.md reads.

Usage:
    python -m repro.launch.dryrun --arch deepseek-7b --cell train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--out FILE]
"""

import argparse
import dataclasses
import json
import math
import time
import traceback

import jax
from jax.sharding import NamedSharding

from repro.configs import all_archs, cells_for, is_skipped
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell
from repro.roofline import hardware as hw
from repro.roofline.analysis import analyze
from repro.sharding.rules import set_mesh


def _to_named(mesh, spec_tree, aval_tree):
    """Attach NamedShardings; drop axes that don't divide the dim."""
    def fix(spec, aval):
        from jax.sharding import PartitionSpec as P
        dims = aval.shape
        parts = tuple(spec) + (None,) * (len(dims) - len(tuple(spec)))
        clean = []
        for dim, ax in zip(dims, parts):
            if ax is None:
                clean.append(None)
                continue
            names = (ax,) if isinstance(ax, str) else tuple(ax)
            # resolve "batch" -> data axes present in this mesh
            is_literal_tuple = not isinstance(ax, str)
            resolved = []
            for nm in names:
                if nm == "batch" or (nm == "data" and not is_literal_tuple):
                    # logical axes span all data-parallel mesh axes;
                    # "data" inside a literal tuple stays literal
                    resolved.extend(n for n in ("pod", "data")
                                    if n in mesh.axis_names)
                elif nm == "all":
                    resolved.extend(mesh.axis_names)
                elif nm in mesh.axis_names:
                    resolved.append(nm)
            resolved = list(dict.fromkeys(resolved))
            # greedy right-drop until the dim divides (e.g. 16 experts on
            # a ("model", "data") spec keep only "model")
            while resolved and dim % math.prod(
                    mesh.shape[n] for n in resolved) != 0:
                resolved.pop()
            if resolved:
                clean.append(tuple(resolved) if len(resolved) > 1
                             else resolved[0])
            else:
                clean.append(None)
        return NamedSharding(mesh, P(*clean))

    return jax.tree_util.tree_map(
        fix, spec_tree, aval_tree,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))


def run_cell(arch_id: str, cell_name: str, multi_pod: bool = False,
             smoke: bool = False, keep_artifacts: bool = False):
    """Lower+compile one cell; returns a result dict (and artifacts)."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    program = build_cell(arch_id, cell_name, smoke=smoke)

    with_shard = lambda avals, specs: jax.tree_util.tree_map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        avals, _to_named(mesh, specs, avals))

    t0 = time.time()
    with set_mesh(mesh):
        p_avals = with_shard(program.param_avals, program.param_specs)
        in_avals = with_shard(program.input_avals, program.input_specs_tree)
        if program.opt_avals is not None:
            o_avals = with_shard(program.opt_avals, program.opt_specs)
            jitted = jax.jit(program.step, donate_argnums=(0, 1))
            lowered = jitted.lower(p_avals, o_avals, in_avals)
        else:
            donate = (1,) if program.kind == "lm_decode" else ()
            jitted = jax.jit(program.step, donate_argnums=donate)
            lowered = jitted.lower(p_avals, in_avals)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        hlo_text = compiled.as_text()
        roof = analyze(program, compiled, mesh, hlo_text=hlo_text,
                       smoke=smoke)

    mem_total = (mem.temp_size_in_bytes + mem.argument_size_in_bytes
                 + mem.output_size_in_bytes - mem.alias_size_in_bytes)
    result = {
        "arch": arch_id, "cell": cell_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": 512 if multi_pod else 256,
        "status": "ok",
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "args_bytes": int(mem.argument_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
            "total_per_chip_bytes": int(mem_total),
            "fits_hbm": bool(mem_total <= hw.HBM_BYTES),
        },
        "cost": {
            "hlo_flops_per_chip": roof.hlo_flops_per_chip,
            "hlo_bytes_per_chip": roof.hlo_bytes_per_chip,
            "collective_bytes_per_chip": roof.coll_bytes_per_chip,
            "collective_breakdown": roof.coll_breakdown,
        },
        "roofline": {
            "compute_s": roof.compute_s, "memory_s": roof.memory_s,
            "collective_s": roof.collective_s,
            "bottleneck": roof.bottleneck,
            "model_flops": roof.model_flops,
            "useful_flop_frac": roof.useful_flop_frac,
            "peak_fraction": roof.peak_fraction,
        },
    }
    if keep_artifacts:
        return result, compiled, lowered, program, mesh
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--cell", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced configs (debug only)")
    ap.add_argument("--out", default="experiments/dryrun.jsonl")
    args = ap.parse_args()

    cells = []
    archs = sorted(all_archs()) if (args.all or not args.arch) \
        else [args.arch]
    for a in archs:
        for c in cells_for(a):
            if args.cell and c.name != args.cell:
                continue
            cells.append((a, c.name))

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "a") as f:
        for arch_id, cell_name in cells:
            for mp in meshes:
                tag = f"{arch_id}/{cell_name}/{'2x16x16' if mp else '16x16'}"
                reason = is_skipped(arch_id, cell_name)
                if reason:
                    rec = {"arch": arch_id, "cell": cell_name,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "skipped", "reason": reason}
                    print(f"SKIP {tag}: {reason}")
                    f.write(json.dumps(rec) + "\n")
                    f.flush()
                    continue
                try:
                    rec = run_cell(arch_id, cell_name, multi_pod=mp,
                                   smoke=args.smoke)
                    r = rec["roofline"]
                    print(f"OK   {tag}: mem/chip="
                          f"{rec['memory']['total_per_chip_bytes']/2**30:.2f}"
                          f"GiB fits={rec['memory']['fits_hbm']} "
                          f"bottleneck={r['bottleneck']} "
                          f"peak_frac={r['peak_fraction']:.3f} "
                          f"(compile {rec['compile_s']:.0f}s)")
                except Exception as e:
                    rec = {"arch": arch_id, "cell": cell_name,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "error",
                           "error": f"{type(e).__name__}: {e}"}
                    print(f"FAIL {tag}: {type(e).__name__}: {e}")
                    traceback.print_exc(limit=5)
                f.write(json.dumps(rec) + "\n")
                f.flush()


if __name__ == "__main__":
    main()
