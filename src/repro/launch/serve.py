"""Serving launcher: batched decode (LMs), batched scoring (recsys), or
similarity-search serving over a packed signature index.

    PYTHONPATH=src python -m repro.launch.serve --arch <id>
        [--smoke | --no-smoke] [--tokens N | --requests N]
    PYTHONPATH=src python -m repro.launch.serve --index [--mode exact|lsh]
        [--docs N] [--queries N] [--topk K] [--densify d]
        [--shards S] [--device-window BYTES]
        [--serve --rate QPS --max-delay-ms MS --workers N
         --admission none|reject|shed-oldest|degrade-to-lsh
         --max-queue Q --deadline-budget-ms MS]

LMs run the KV-cache serve_step autoregressively for --tokens steps on a
batch of prompts; recsys archs score --requests synthetic requests through
``serve_scores`` (including the minhash-frontend featurization, i.e. the
paper's online-preprocessing path).  ``--index`` drives the retrieval
workload (``repro.index``): shard a synthetic corpus, hash it to packed
``.sig`` shards, build the banded ``.idx``, then serve batched top-k
queries through the packed-Hamming kernel, reporting p50/p99 latency.
``--shards S`` builds S ``.idx`` shards and serves them through the
``ShardedIndex`` router (bit-identical merge); ``--device-window`` caps
the device-resident packed corpus bytes -- beyond it the exact path
streams mmap windows (out-of-core serving).  ``--serve`` puts the
continuous-batching ``SearchServer`` in front of the searcher and
replays Zipf-popular queries at a Poisson ``--rate`` offered load,
reporting the server's queue-wait / flush / end-to-end latency
percentiles instead of closed-loop batch latency.  ``--workers`` sizes
the dispatch pool (default: one per mesh data-axis device), and
``--admission``/``--max-queue``/``--deadline-budget-ms`` pick the
overload policy -- reject, shed-oldest, or degrade-to-lsh.
"""

from __future__ import annotations

import argparse
import glob
import os
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.sharding.rules import set_mesh


def serve_index(args) -> None:
    """The retrieval workload: build a .idx, serve batched queries."""
    import numpy as np

    from repro.data.pipeline import make_sharded_dataset
    from repro.data.preprocess import preprocess_shards
    from repro.data.synthetic import DatasetSpec
    from repro.index import (IndexSearcher, build_index, build_sharded,
                             choose_band_config, load_index, load_sharded)
    from repro.train.online import make_family

    k, b, s = args.k, args.b, 16
    spec = DatasetSpec("serve_index", n=args.docs, D=1 << s,
                       avg_nnz=64, n_prototypes=8, overlap=0.8, seed=0)
    with tempfile.TemporaryDirectory(prefix="repro_serve_index_") as tmp:
        raw = make_sharded_dataset(spec, os.path.join(tmp, "raw"),
                                   n_shards=4)
        fam = make_family(jax.random.PRNGKey(0), args.scheme, k, s,
                          densify=args.densify)
        t0 = time.perf_counter()
        preprocess_shards(raw, os.path.join(tmp, "sig"), fam, b=b,
                          chunk_size=max(64, args.docs // 4),
                          loader_kwargs={"lane_multiple": 8})
        t_hash = time.perf_counter() - t0
        sig_paths = sorted(glob.glob(os.path.join(tmp, "sig", "*.sig")))
        cfg = choose_band_config(
            k, b, code_bits=(b + 1 if args.densify == "sentinel" else b),
            threshold=args.threshold)
        t0 = time.perf_counter()
        if args.shards > 1:
            shard_dir = os.path.join(tmp, "shards")
            built = build_sharded(sig_paths, shard_dir, cfg,
                                  n_shards=args.shards)
            t_build = time.perf_counter() - t0
            n_total = sum(m.n for _, m in built)
            payload = sum(m.payload_bytes for _, m in built)
            mesh = None
            if args.mesh:
                from repro.launch.mesh import make_debug_mesh
                n_dev = min(args.mesh, len(jax.devices()))
                mesh = make_debug_mesh(n_dev, axes=("data",))
            searcher = load_sharded(shard_dir, mesh=mesh,
                                    max_device_bytes=args.device_window)
            words_of = _sharded_row_reader(searcher)
            what = f"{args.shards} shards"
            if mesh is not None:
                what += (f" on {n_dev} device(s) "
                         f"(shard_map exact dispatch)")
        else:
            meta = build_index(sig_paths, os.path.join(tmp, "corpus.idx"),
                               cfg)
            t_build = time.perf_counter() - t0
            n_total, payload = meta.n, meta.payload_bytes
            index = load_index(os.path.join(tmp, "corpus.idx"))
            searcher = IndexSearcher(index,
                                     max_device_bytes=args.device_window)
            words_of = lambda i: np.asarray(index.words_host[i])
            what = "1 index"
        streamed = (any(s.streamed for s in searcher.searchers)
                    if args.shards > 1 else searcher.streamed)
        print(f"indexed {n_total} docs into {what} (k={k} b={b} "
              f"bands={cfg.n_bands}x{cfg.rows_per_band}): "
              f"hash {t_hash:.2f}s, build {t_build:.2f}s, "
              f"payload {payload:,} B"
              + (f", streamed (window {args.device_window:,} B)"
                 if streamed else ""))
        if args.serve:
            _serve_traffic(searcher, words_of, n_total, args)
            return
        rng = np.random.default_rng(1)
        lat = []
        hits0 = None
        for r in range(args.requests):
            picks = rng.integers(0, n_total, args.queries)
            for i in picks:
                searcher.submit(words_of(int(i)))
            t0 = time.perf_counter()
            out = searcher.flush(args.topk, mode=args.mode)
            lat.append((time.perf_counter() - t0) * 1e3)
            if hits0 is None:
                hits0 = np.mean([float(res.indices[0, 0] == q)
                                 for res, q in zip(out.values(), picks)])
        lat = sorted(lat)
        qps = args.queries * args.requests / (sum(lat) / 1e3)
        print(f"{args.requests} batches x {args.queries} queries "
              f"({args.mode}): p50={lat[len(lat) // 2]:.1f}ms "
              f"max={lat[-1]:.1f}ms {qps:.0f} q/s "
              f"self-hit@1={hits0:.2f}")


def _serve_traffic(searcher, words_of, n_total: int, args) -> None:
    """Open-loop serving: SearchServer under Zipf/Poisson traffic."""
    from repro.launch.server import RequestShed, SearchServer, ZipfianTraffic
    from repro.obs.trace import get_tracer

    exporter = None
    if args.metrics_port is not None:
        from repro.obs.export import start_http_exporter
        exporter = start_http_exporter(port=args.metrics_port)
        print(f"metrics: {exporter.url}/metrics "
              f"(JSON {exporter.url}/metrics.json, "
              f"trace {exporter.url}/trace)")
    tracer = get_tracer()
    if args.trace_out:
        tracer.reset(enabled=True)

    traffic = ZipfianTraffic(n_total, alpha=args.zipf_alpha, seed=1)
    m = args.requests * args.queries
    ids = traffic.ids(m)
    arrivals = traffic.arrival_offsets(m, args.rate)
    budget = (args.deadline_budget_ms / 1e3
              if args.deadline_budget_ms is not None else None)
    server = SearchServer(searcher, max_batch=args.queries,
                          max_delay_s=args.max_delay_ms / 1e3,
                          topk=args.topk, mode=args.mode,
                          num_workers=args.workers,
                          admission=args.admission,
                          max_queue=args.max_queue,
                          deadline_budget_s=budget,
                          on_shard_failure=args.on_shard_failure)
    try:
        with server:
            t_start = time.monotonic()
            handles = []
            for doc, at in zip(ids, arrivals):
                lag = at - (time.monotonic() - t_start)
                if lag > 0:
                    time.sleep(lag)
                handles.append(server.submit(words_of(int(doc)),
                                             deadline_s=budget))
            for h in handles:
                try:
                    h.result(timeout=120.0)
                except RequestShed:
                    pass                # accounted in stats.shed
            elapsed = time.monotonic() - t_start
    finally:
        if args.trace_out:
            n_ev = tracer.export(args.trace_out)
            print(f"trace: wrote {n_ev} events to {args.trace_out} "
                  "(open in https://ui.perfetto.dev)")
        if exporter is not None:
            exporter.close()
    snap = server.stats.snapshot()
    print(f"served {snap['requests']} requests in {snap['batches']} "
          f"micro-batches over {snap['workers']} worker(s) "
          f"(mean {snap['mean_batch']:.1f}/batch, "
          f"offered {args.rate:.0f} q/s, achieved "
          f"{snap['requests'] / elapsed:.0f} q/s)")
    print(f"latency p50={snap['latency_p50_ms']:.1f}ms "
          f"p99={snap['latency_p99_ms']:.1f}ms  queue-wait "
          f"p50={snap['queue_wait_p50_ms']:.1f}ms  flush "
          f"p50={snap['flush_p50_ms']:.1f}ms  triggers: "
          f"full={snap['flush_full']} aged={snap['flush_aged']} "
          f"deadline={snap['flush_deadline']} drain={snap['flush_drain']}")
    occ = " ".join(f"{o:.2f}" for o in snap["worker_occupancy"])
    print(f"admission={args.admission}: shed={snap['shed']} "
          f"(rate {snap['shed_rate']:.3f}) degraded={snap['degraded']} "
          f"deadline-miss rate {snap['deadline_miss_rate']:.3f}  "
          f"worker occupancy [{occ}]")
    if args.on_shard_failure == "partial" or snap["partial"]:
        print(f"fault tolerance: partial={snap['partial']} "
              f"(rate {snap['partial_rate']:.3f}) "
              f"mean coverage {snap['mean_coverage']:.3f} "
              f"worker restarts {snap['worker_restarts']}")


def _sharded_row_reader(sharded):
    """Global doc id -> packed query row, off the shards' mmaps."""
    import numpy as np
    offsets = list(sharded.offsets) + [sharded.n]

    def words_of(i: int) -> np.ndarray:
        shard = int(np.searchsorted(offsets, i, side="right")) - 1
        local = i - int(offsets[shard])
        return np.asarray(
            sharded.searchers[shard].index.words_host[local])
    return words_of


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    # BooleanOptionalAction so --no-smoke can actually turn full-size
    # builds back on (a bare store_true with default=True could not be
    # disabled from the command line at all).
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="shrink the arch for a fast smoke run "
                         "(--no-smoke serves the full-size config)")
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--index", action="store_true",
                    help="serve the similarity-search index workload")
    ap.add_argument("--mode", choices=("exact", "lsh"), default="lsh")
    ap.add_argument("--docs", type=int, default=2048)
    ap.add_argument("--queries", type=int, default=16,
                    help="queries admitted per batch (--index); the "
                         "server's max_batch under --serve")
    ap.add_argument("--topk", type=int, default=10)
    ap.add_argument("--k", type=int, default=128)
    ap.add_argument("--b", type=int, default=8)
    ap.add_argument("--scheme", default="oph")
    ap.add_argument("--densify", default="rotation")
    ap.add_argument("--threshold", type=float, default=0.5)
    ap.add_argument("--shards", type=int, default=1,
                    help="serve through a ShardedIndex router over S "
                         ".idx shards (--index)")
    ap.add_argument("--device-window", type=int, default=None,
                    help="max device-resident packed-corpus bytes; larger "
                         "corpora stream mmap windows (--index)")
    ap.add_argument("--mesh", type=int, default=0,
                    help="place shards round-robin on a D-device "
                         '("data",) mesh and run the exact scan as one '
                         "shard_map dispatch (--index --shards; clamped "
                         "to the available devices; 0 = single-device "
                         "sequential fan-out)")
    ap.add_argument("--serve", action="store_true",
                    help="drive the continuous-batching SearchServer "
                         "under open-loop Zipf/Poisson traffic (--index)")
    ap.add_argument("--rate", type=float, default=500.0,
                    help="offered load in queries/s (--serve)")
    ap.add_argument("--zipf-alpha", type=float, default=1.1,
                    help="query-popularity Zipf exponent (--serve)")
    ap.add_argument("--max-delay-ms", type=float, default=5.0,
                    help="micro-batching window: max time the oldest "
                         "queued request waits before a flush (--serve)")
    ap.add_argument("--workers", type=int, default=None,
                    help="dispatch workers draining the admission queue "
                         "(--serve; default: one per data-axis mesh "
                         "device, else 1)")
    ap.add_argument("--admission", default="none",
                    choices=("none", "reject", "shed-oldest",
                             "degrade-to-lsh"),
                    help="overload policy when the queue is full or the "
                         "projected wait blows the deadline budget "
                         "(--serve)")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bounded admission-queue depth; beyond it the "
                         "--admission policy fires (--serve)")
    ap.add_argument("--on-shard-failure", default=None,
                    choices=("fail", "partial"),
                    help="shard-failure policy threaded to the sharded "
                         "router: 'partial' serves surviving shards with "
                         "coverage accounting instead of failing the "
                         "whole batch (--serve --shards)")
    ap.add_argument("--deadline-budget-ms", type=float, default=None,
                    help="per-request latency budget the admission "
                         "policy defends (--serve)")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve live Prometheus metrics on this port "
                         "(/metrics, /metrics.json, /trace; 0 = "
                         "ephemeral; --serve)")
    ap.add_argument("--trace-out", default=None,
                    help="enable request tracing and write the "
                         "Perfetto-loadable trace-event JSON here on "
                         "exit (--serve)")
    return ap


def main():
    ap = build_parser()
    args = ap.parse_args()

    if args.index:
        serve_index(args)
        return
    if not args.arch:
        ap.error("--arch is required unless --index is given")
    from repro.configs import get_arch
    from repro.launch.steps import build_cell, init_inputs
    spec = get_arch(args.arch)
    if spec.family == "lm":
        prog = build_cell(args.arch, "decode_32k", smoke=args.smoke)
        key = jax.random.PRNGKey(0)
        params = prog.init_params(key)
        inputs = init_inputs(prog, key)
        cache, tokens = inputs["cache"], inputs["tokens"]
        step = jax.jit(prog.step)
        t0 = time.perf_counter()
        out_tokens = [tokens]
        for pos in range(1, args.tokens + 1):
            tokens, cache = step(params, {"cache": cache, "tokens": tokens,
                                          "pos": jnp.int32(pos)})
            out_tokens.append(tokens)
        jax.block_until_ready(tokens)
        dt = time.perf_counter() - t0
        print(f"decoded {args.tokens} tokens x batch {tokens.shape[0]} "
              f"in {dt:.2f}s ({args.tokens * tokens.shape[0] / dt:.1f} "
              f"tok/s); first sequence: "
              f"{[int(t[0]) for t in out_tokens[:8]]}")
    else:
        cell = "serve_p99" if spec.family == "recsys" else None
        prog = build_cell(args.arch, cell, smoke=args.smoke)
        key = jax.random.PRNGKey(0)
        params = prog.init_params(key)
        step = jax.jit(prog.step)
        lat = []
        for r in range(args.requests):
            inputs = init_inputs(prog, jax.random.PRNGKey(r))
            t0 = time.perf_counter()
            scores = step(params, inputs)
            jax.block_until_ready(scores)
            lat.append((time.perf_counter() - t0) * 1e3)
        lat = sorted(lat)
        print(f"{args.requests} requests, batch "
              f"{scores.shape[0]}: p50={lat[len(lat) // 2]:.1f}ms "
              f"p99={lat[-1]:.1f}ms")


if __name__ == "__main__":
    main()
