"""Serving launcher: batched decode (LMs) or batched scoring (recsys).

    PYTHONPATH=src python -m repro.launch.serve --arch <id> [--smoke]
        [--tokens N | --requests N]

LMs run the KV-cache serve_step autoregressively for --tokens steps on a
batch of prompts; recsys archs score --requests synthetic requests through
``serve_scores`` (including the minhash-frontend featurization, i.e. the
paper's online-preprocessing path).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.launch.steps import build_cell, init_inputs
from repro.sharding.rules import set_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--requests", type=int, default=4)
    args = ap.parse_args()

    spec = get_arch(args.arch)
    if spec.family == "lm":
        prog = build_cell(args.arch, "decode_32k", smoke=args.smoke)
        key = jax.random.PRNGKey(0)
        params = prog.init_params(key)
        inputs = init_inputs(prog, key)
        cache, tokens = inputs["cache"], inputs["tokens"]
        step = jax.jit(prog.step)
        t0 = time.perf_counter()
        out_tokens = [tokens]
        for pos in range(1, args.tokens + 1):
            tokens, cache = step(params, {"cache": cache, "tokens": tokens,
                                          "pos": jnp.int32(pos)})
            out_tokens.append(tokens)
        jax.block_until_ready(tokens)
        dt = time.perf_counter() - t0
        print(f"decoded {args.tokens} tokens x batch {tokens.shape[0]} "
              f"in {dt:.2f}s ({args.tokens * tokens.shape[0] / dt:.1f} "
              f"tok/s); first sequence: "
              f"{[int(t[0]) for t in out_tokens[:8]]}")
    else:
        cell = "serve_p99" if spec.family == "recsys" else None
        prog = build_cell(args.arch, cell, smoke=args.smoke)
        key = jax.random.PRNGKey(0)
        params = prog.init_params(key)
        step = jax.jit(prog.step)
        lat = []
        for r in range(args.requests):
            inputs = init_inputs(prog, jax.random.PRNGKey(r))
            t0 = time.perf_counter()
            scores = step(params, inputs)
            jax.block_until_ready(scores)
            lat.append((time.perf_counter() - t0) * 1e3)
        lat = sorted(lat)
        print(f"{args.requests} requests, batch "
              f"{scores.shape[0]}: p50={lat[len(lat) // 2]:.1f}ms "
              f"p99={lat[-1]:.1f}ms")


if __name__ == "__main__":
    main()
