"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch <id> [--smoke]
        [--steps N] [--ckpt-dir DIR] [--mesh debug|single-pod|multi-pod]

Builds the arch's train cell, places it on the requested mesh, and runs
the fault-tolerant Trainer (checkpoint/resume, heartbeat, bounded-retry
restart).  On this CPU container use --smoke (reduced config, synthetic
batches); on a real TPU fleet the same entry point runs the full config
with the production mesh.
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import cells_for, get_arch
from repro.launch.steps import build_cell, init_inputs
from repro.sharding.rules import set_mesh
from repro.train import Trainer, checkpoint


def _train_cell_name(arch_id: str) -> str:
    for c in cells_for(arch_id):
        if "train" in c.kind:
            return c.name
    raise ValueError(f"{arch_id} has no train cell")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--cell", default=None)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", default="none",
                    choices=["none", "debug", "single-pod", "multi-pod"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    mesh = None
    if args.mesh == "debug":
        from repro.launch.mesh import make_debug_mesh
        mesh = make_debug_mesh(len(jax.devices()))
    elif args.mesh != "none":
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=args.mesh == "multi-pod")

    cell = args.cell or _train_cell_name(args.arch)
    prog = build_cell(args.arch, cell, smoke=args.smoke)
    key = jax.random.PRNGKey(args.seed)

    with set_mesh(mesh):
        params = prog.init_params(key)
        opt_state = prog.optimizer.init(params)
        n = sum(int(np.prod(l.shape))
                for l in jax.tree_util.tree_leaves(params))
        print(f"{args.arch}/{cell}: {n:,} params, optimizer="
              f"{'fused-adafactor' if prog.opt_avals else ''}")

        def step(state, batch):
            p, o, loss = prog.step(state.params, state.opt_state, batch)
            from repro.train.trainer import TrainState
            return (TrainState(params=p, opt_state=o, step=state.step + 1),
                    {"loss": loss})

        from repro.train.trainer import TrainState
        state = TrainState(params=params, opt_state=opt_state,
                           step=jax.numpy.zeros((), jax.numpy.int32))
        tr = Trainer(step, ckpt_dir=args.ckpt_dir,
                     ckpt_every=args.ckpt_every)
        state = tr.maybe_resume(state)

        keys = jax.random.split(jax.random.PRNGKey(args.seed + 1),
                                args.steps)
        batches = lambda: (init_inputs(prog, k) for k in keys)
        state = tr.fit(state, batches, args.steps)
        losses = [m["loss"] for m in tr.metrics_log]
        if losses:
            print(f"loss: first={losses[0]:.4f} last={losses[-1]:.4f} "
                  f"({len(losses)} steps, "
                  f"{tr.heartbeat.stragglers} stragglers)")


if __name__ == "__main__":
    main()
