"""Process-wide metrics registry for the serving stack.

PRs 2-8 accumulated excellent but siloed counters (``ServerStats``,
``EpochStats``, ``LoaderStats``, the ``TRACE_COUNTS`` dict, the router's
mesh-dispatch ints, ``ttl_dropped``, ``io_errors``) -- each with its own
ad-hoc read path, none scrapeable while the server is live.  This module
is the one place they all land: a thread-safe ``MetricsRegistry`` of
named counters, gauges and bounded-reservoir histograms (with Prometheus
label support), plus a *collector* seam so the existing stat holders
keep their in-object storage (and their locks, and their tests) while
still exporting through ONE snapshot API.

Two registration styles, by ownership:

  * **registry-owned metrics** -- ``registry.counter(name)`` /
    ``.gauge`` / ``.histogram`` return live metric objects the caller
    mutates (``inc`` / ``set`` / ``observe``).  Creation is idempotent:
    asking for an existing name returns the same family (a type or
    label-name mismatch raises).  This replaces module-global mutable
    dicts like ``repro.index.query.TRACE_COUNTS``.
  * **collectors** -- ``registry.register_object(holder, fn)`` keeps a
    ``weakref`` to an existing stat holder (``ServerStats``,
    ``ShardedIndex``, ``LoaderStats``, ``SignatureCache``) and calls
    ``fn(holder)`` at snapshot time to yield ``Sample``s read from the
    holder's own fields under the holder's own lock.  Dead holders are
    pruned automatically -- registering never extends a lifetime.

``snapshot()`` merges both sources into one dict (samples with the same
name + labels sum -- right for counters, and documented behaviour for
gauges when several holders share a name); ``prometheus_text()`` renders
the Prometheus text exposition served by ``repro.obs.export``.

The default process registry is reached with ``get_registry()``;
``reset()`` zeroes every registry-owned metric and prunes dead
collectors (the test-isolation hook -- live holders keep reporting).
Tests that need totals unpolluted by other components pass a private
``MetricsRegistry`` instead.
"""

from __future__ import annotations

import collections
import dataclasses
import math
import re
import threading
import weakref
from typing import Callable, Dict, Iterable, List, Optional, Tuple

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")

_TYPES = ("counter", "gauge", "summary")


@dataclasses.dataclass(frozen=True)
class Sample:
    """One exported measurement.

    ``suffix`` distinguishes summary components (``""`` for the
    quantile samples, ``"_sum"`` / ``"_count"`` for the aggregates) --
    the exposition name is ``name + suffix``.
    """

    name: str
    mtype: str                        # "counter" | "gauge" | "summary"
    help: str
    labels: Tuple[Tuple[str, str], ...]     # sorted (key, value) pairs
    value: float
    suffix: str = ""


def _label_items(labels: Optional[Dict[str, object]]
                 ) -> Tuple[Tuple[str, str], ...]:
    if not labels:
        return ()
    for k in labels:
        if not _LABEL_RE.match(k):
            raise ValueError(f"illegal label name {k!r}")
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class Counter:
    """Monotonic float counter (one labeled child)."""

    __slots__ = ("_lock", "_value")

    def __init__(self, lock: threading.Lock):
        self._lock = lock
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counters only go up, got inc({n})")
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        return self._value


class Gauge:
    """Settable instantaneous value (one labeled child)."""

    __slots__ = ("_lock", "_value")

    def __init__(self, lock: threading.Lock):
        self._lock = lock
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    def dec(self, n: float = 1.0) -> None:
        self.inc(-n)

    @property
    def value(self) -> float:
        return self._value


class Histogram:
    """Bounded-reservoir histogram (one labeled child).

    Keeps exact ``count`` / ``sum`` plus a bounded deque of recent
    observations for the quantile snapshot -- the same reservoir
    discipline ``ServerStats`` already uses, so a long-running server
    never grows without bound.
    """

    __slots__ = ("_lock", "count", "total", "_reservoir")

    def __init__(self, lock: threading.Lock, reservoir: int):
        self._lock = lock
        self.count = 0
        self.total = 0.0
        self._reservoir: collections.deque = collections.deque(
            maxlen=reservoir)

    def observe(self, v: float) -> None:
        with self._lock:
            self.count += 1
            self.total += v
            self._reservoir.append(float(v))

    def quantiles(self, qs=(0.5, 0.99)) -> Dict[float, float]:
        with self._lock:
            vals = sorted(self._reservoir)
        if not vals:
            return {q: float("nan") for q in qs}
        return {q: vals[min(len(vals) - 1, int(q * len(vals)))] for q in qs}


class _Family:
    """One named metric family: type, help, label names, children."""

    def __init__(self, name: str, mtype: str, help: str,
                 labelnames: Tuple[str, ...], lock: threading.Lock,
                 reservoir: int):
        self.name = name
        self.mtype = mtype
        self.help = help
        self.labelnames = labelnames
        self._lock = lock
        self._reservoir = reservoir
        self._children: Dict[Tuple[str, ...], object] = {}

    def _make_child(self):
        if self.mtype == "counter":
            return Counter(self._lock)
        if self.mtype == "gauge":
            return Gauge(self._lock)
        return Histogram(self._lock, self._reservoir)

    def labels(self, **labelvalues):
        """The child bound to one label-value set (created on demand)."""
        if tuple(sorted(labelvalues)) != tuple(sorted(self.labelnames)):
            raise ValueError(
                f"{self.name}: expected labels {self.labelnames}, got "
                f"{tuple(sorted(labelvalues))}")
        key = tuple(str(labelvalues[k]) for k in self.labelnames)
        with self._lock:
            child = self._children.get(key)
            if child is None:
                child = self._make_child()
                self._children[key] = child
        return child

    # unlabeled families proxy straight to their single child
    def _default(self):
        if self.labelnames:
            raise ValueError(f"{self.name} is labeled {self.labelnames}; "
                             f"use .labels(...)")
        return self.labels()

    def inc(self, n: float = 1.0) -> None:
        self._default().inc(n)

    def set(self, v: float) -> None:
        self._default().set(v)

    def dec(self, n: float = 1.0) -> None:
        self._default().dec(n)

    def observe(self, v: float) -> None:
        self._default().observe(v)

    @property
    def value(self) -> float:
        return self._default().value

    def samples(self) -> Iterable[Sample]:
        with self._lock:
            items = list(self._children.items())
        for key, child in items:
            labels = tuple(zip(self.labelnames, key))
            if isinstance(child, Histogram):
                qs = child.quantiles()
                for q, v in qs.items():
                    yield Sample(self.name, "summary", self.help,
                                 labels + (("quantile", f"{q:g}"),), v)
                yield Sample(self.name, "summary", self.help, labels,
                             float(child.total), suffix="_sum")
                yield Sample(self.name, "summary", self.help, labels,
                             float(child.count), suffix="_count")
            else:
                yield Sample(self.name, self.mtype, self.help, labels,
                             child.value)


class MetricsRegistry:
    """Thread-safe registry of metric families + stat-holder collectors."""

    def __init__(self):
        self._lock = threading.Lock()
        self._families: Dict[str, _Family] = {}
        self._collectors: List[Tuple[weakref.ref, Callable]] = []

    # -- registry-owned metrics ------------------------------------------
    def _family(self, name: str, mtype: str, help: str,
                labels: Tuple[str, ...], reservoir: int = 4096) -> _Family:
        if not _NAME_RE.match(name):
            raise ValueError(f"illegal metric name {name!r}")
        labels = tuple(labels)
        for ln in labels:
            if not _LABEL_RE.match(ln):
                raise ValueError(f"illegal label name {ln!r}")
        with self._lock:
            fam = self._families.get(name)
            if fam is not None:
                if fam.mtype != mtype or tuple(sorted(fam.labelnames)) != \
                        tuple(sorted(labels)):
                    raise ValueError(
                        f"metric {name!r} already registered as "
                        f"{fam.mtype}{fam.labelnames}, not "
                        f"{mtype}{labels}")
                return fam
            fam = _Family(name, mtype, help or name, labels,
                          threading.Lock(), reservoir)
            self._families[name] = fam
            return fam

    def counter(self, name: str, help: str = "",
                labels: Tuple[str, ...] = ()) -> _Family:
        return self._family(name, "counter", help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Tuple[str, ...] = ()) -> _Family:
        return self._family(name, "gauge", help, labels)

    def histogram(self, name: str, help: str = "",
                  labels: Tuple[str, ...] = (),
                  reservoir: int = 4096) -> _Family:
        return self._family(name, "summary", help, labels, reservoir)

    # -- collectors over existing stat holders ---------------------------
    def register_object(self, holder: object,
                        fn: Callable[[object], Iterable[Sample]]) -> None:
        """Snapshot-time collector over ``holder`` (kept by weakref:
        registration never extends the holder's lifetime, and a dead
        holder's samples simply stop appearing)."""
        with self._lock:
            self._collectors.append((weakref.ref(holder), fn))

    def _collect(self) -> List[Sample]:
        with self._lock:
            families = list(self._families.values())
            collectors = list(self._collectors)
        out: List[Sample] = []
        for fam in families:
            out.extend(fam.samples())
        dead = []
        for ref, fn in collectors:
            holder = ref()
            if holder is None:
                dead.append((ref, fn))
                continue
            out.extend(fn(holder))
        if dead:
            with self._lock:
                self._collectors = [c for c in self._collectors
                                    if c not in dead]
        return out

    # -- the one snapshot API --------------------------------------------
    def snapshot(self) -> Dict[str, dict]:
        """Merged view of every metric: ``{name: {type, help, samples}}``.

        Samples with identical (name, suffix, labels) -- e.g. the same
        counter exported by two live servers -- are summed.
        """
        merged: Dict[str, dict] = {}
        order: Dict[Tuple, int] = {}
        for s in self._collect():
            fam = merged.setdefault(
                s.name, {"type": s.mtype, "help": s.help, "samples": []})
            key = (s.name, s.suffix, s.labels)
            i = order.get(key)
            if i is None:
                order[key] = len(fam["samples"])
                fam["samples"].append({"suffix": s.suffix,
                                       "labels": dict(s.labels),
                                       "value": s.value})
            else:
                fam["samples"][i]["value"] += s.value
        return merged

    def values(self) -> Dict[str, float]:
        """Flat ``{"name{k=v,...}": value}`` convenience view."""
        out: Dict[str, float] = {}
        for name, fam in self.snapshot().items():
            for s in fam["samples"]:
                lbl = ",".join(f'{k}="{v}"'
                               for k, v in sorted(s["labels"].items()))
                key = name + s["suffix"] + (f"{{{lbl}}}" if lbl else "")
                out[key] = s["value"]
        return out

    def prometheus_text(self) -> str:
        """Prometheus text exposition (format 0.0.4) of ``snapshot()``."""
        lines: List[str] = []
        for name, fam in sorted(self.snapshot().items()):
            lines.append(f"# HELP {name} {_escape_help(fam['help'])}")
            lines.append(f"# TYPE {name} {fam['type']}")
            for s in fam["samples"]:
                lbl = ",".join(
                    f'{k}="{_escape_label(v)}"'
                    for k, v in sorted(s["labels"].items()))
                label_part = f"{{{lbl}}}" if lbl else ""
                lines.append(f"{name}{s['suffix']}{label_part} "
                             f"{_fmt_value(s['value'])}")
        return "\n".join(lines) + "\n"

    def reset(self) -> None:
        """Zero every registry-owned metric; prune dead collectors.

        Live stat holders keep reporting (their collectors survive) --
        tests needing totals in full isolation use a private registry.
        """
        with self._lock:
            for fam in self._families.values():
                fam._children.clear()
            self._collectors = [(ref, fn) for ref, fn in self._collectors
                                if ref() is not None]


def _escape_help(s: str) -> str:
    return s.replace("\\", r"\\").replace("\n", r"\n")


def _escape_label(s: str) -> str:
    return (s.replace("\\", r"\\").replace('"', r'\"')
             .replace("\n", r"\n"))


def _fmt_value(v: float) -> str:
    if isinstance(v, float) and math.isnan(v):
        return "NaN"
    if v == float("inf"):
        return "+Inf"
    if v == float("-inf"):
        return "-Inf"
    return repr(float(v))


_default_registry = MetricsRegistry()
_default_lock = threading.Lock()


def get_registry() -> MetricsRegistry:
    """The process-wide default registry (what the serving stack's stat
    holders register into, and what ``repro.obs.export`` serves)."""
    return _default_registry


def set_registry(registry: MetricsRegistry) -> MetricsRegistry:
    """Swap the process default (returns the previous one)."""
    global _default_registry
    with _default_lock:
        prev, _default_registry = _default_registry, registry
    return prev
