"""Live export of the metrics registry + trace buffer.

A stdlib ``ThreadingHTTPServer`` on a daemon thread -- no third-party
dependency -- serving:

  * ``GET /metrics``       Prometheus text exposition (format 0.0.4)
  * ``GET /metrics.json``  the registry ``snapshot()`` as JSON
  * ``GET /trace``         the tracer buffer as Chrome trace-event JSON
                           (load in Perfetto / ``chrome://tracing``)
  * ``GET /healthz``       liveness probe (``ok``)

``launch/serve.py --metrics-port N`` starts one of these next to the
search server; ``--metrics-port 0`` binds an ephemeral port (printed on
startup, readable from ``exporter.port`` -- what CI uses to scrape the
serving benchmark).  Request handling never touches the serving hot
path: scrapes read the registry under its own locks.
"""

from __future__ import annotations

import http.server
import json
import threading
from typing import Optional

from .metrics import MetricsRegistry, get_registry
from .trace import Tracer, get_tracer


class MetricsExporter:
    """Owns the HTTP server thread; ``close()`` (or context exit) stops it."""

    def __init__(self, *, port: int = 0, host: str = "127.0.0.1",
                 registry: Optional[MetricsRegistry] = None,
                 tracer: Optional[Tracer] = None):
        self.registry = registry if registry is not None else get_registry()
        self.tracer = tracer if tracer is not None else get_tracer()
        exporter = self

        class _Handler(http.server.BaseHTTPRequestHandler):
            def log_message(self, fmt, *args):      # silence per-request spam
                pass

            def _send(self, body: bytes, ctype: str, code: int = 200):
                self.send_response(code)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def do_GET(self):
                path = self.path.split("?", 1)[0]
                try:
                    if path == "/metrics":
                        self._send(exporter.registry.prometheus_text()
                                   .encode(),
                                   "text/plain; version=0.0.4; "
                                   "charset=utf-8")
                    elif path == "/metrics.json":
                        self._send(json.dumps(exporter.registry.snapshot())
                                   .encode(), "application/json")
                    elif path == "/trace":
                        self._send(json.dumps(exporter.tracer.to_json())
                                   .encode(), "application/json")
                    elif path == "/healthz":
                        self._send(b"ok", "text/plain")
                    else:
                        self._send(b"not found", "text/plain", 404)
                except (BrokenPipeError, ConnectionResetError):
                    pass        # scraper went away mid-response
                except Exception as e:      # never kill the server thread
                    try:
                        self._send(f"error: {e}".encode(),
                                   "text/plain", 500)
                    except OSError:
                        pass

        self._httpd = http.server.ThreadingHTTPServer((host, port), _Handler)
        self._httpd.daemon_threads = True
        self.host = host
        self.port = self._httpd.server_address[1]   # resolved if port=0
        self._thread = threading.Thread(
            target=self._httpd.serve_forever,
            name=f"metrics-exporter:{self.port}", daemon=True)
        self._thread.start()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5.0)

    def __enter__(self) -> "MetricsExporter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def start_http_exporter(port: int = 0, host: str = "127.0.0.1", *,
                        registry: Optional[MetricsRegistry] = None,
                        tracer: Optional[Tracer] = None) -> MetricsExporter:
    """Start the exporter thread; returns the handle (``.port``,
    ``.url``, ``.close()``)."""
    return MetricsExporter(port=port, host=host, registry=registry,
                           tracer=tracer)
