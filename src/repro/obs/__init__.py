"""Unified observability: metrics registry, span tracing, live export.

See ``docs/observability.md`` for the metric catalog, the request span
tree, and the Prometheus/Perfetto quickstart.
"""

from .metrics import (MetricsRegistry, Sample, get_registry,  # noqa: F401
                      set_registry)
from .trace import (Span, Tracer, get_tracer, request_tree,   # noqa: F401
                    set_tracer)
from .export import MetricsExporter, start_http_exporter      # noqa: F401

__all__ = [
    "MetricsRegistry", "Sample", "get_registry", "set_registry",
    "Span", "Tracer", "get_tracer", "set_tracer", "request_tree",
    "MetricsExporter", "start_http_exporter",
]
