"""Lightweight span tracing for the serving request path.

Answers "where did this request's 40 ms go": every admitted request
grows a span tree -- admission -> queue wait -> worker flush -> the
mesh ``shard_map`` dispatch -> ``merge_topk`` -> resolution -- and the
whole buffer exports as Chrome trace-event JSON, loadable directly in
Perfetto (https://ui.perfetto.dev) or ``chrome://tracing``.

Design constraints, in order:

  1. **Off the hot path when disabled.**  The tracer ships disabled;
     every entry point checks ``enabled`` first and returns a shared
     no-op span, so an untraced server pays one attribute read per
     would-be span (the serving benchmark pins total instrumentation
     overhead < 2%).
  2. **Tear-free under concurrent workers.**  Span ids come from one
     atomic counter; parent linkage is explicit (``parent=``) or via a
     *thread-local* span stack (``span()`` context manager), so two
     dispatch workers flushing concurrently can never adopt each
     other's children.  Per-span clocks are monotonic
     (``time.monotonic``), and completed spans append to the bounded
     buffer under one lock.
  3. **Cross-thread request trees.**  A request's root span opens on
     the client thread and closes on whichever worker resolved it;
     retroactive children (queue wait is only known at batch pop) are
     recorded with explicit ``t0``/``t1`` via ``add_span``.

Export: spans marked ``kind="async"`` (the per-request tree) become
``ph: "b"``/``"e"`` async event pairs keyed on the request's trace id
-- Perfetto renders each request as its own nested async track --
while worker-side spans become ``ph: "X"`` complete events on their
thread's track.  Every event carries ``span_id`` / ``parent_id`` /
``trace_id`` in ``args``, so the tree is machine-checkable
(``tools/check_obs.py``) independent of the rendering.

``jax_annotation()`` optionally brackets a region with
``jax.profiler.TraceAnnotation`` so server flushes line up with device
ops inside a captured ``jax.profiler`` trace; it is a no-op unless
``jax_annotations=True`` AND the profiler import succeeds.
"""

from __future__ import annotations

import contextlib
import itertools
import json
import os
import threading
import time
from typing import Dict, Iterator, List, Optional, Tuple


class Span:
    """One interval: ``[t0, t1]`` monotonic seconds + tree linkage."""

    __slots__ = ("name", "span_id", "parent_id", "trace_id", "t0", "t1",
                 "tid", "args", "kind")

    def __init__(self, name: str, span_id: int, parent_id: int,
                 trace_id: int, t0: float, tid: int,
                 args: Optional[dict], kind: str):
        self.name = name
        self.span_id = span_id
        self.parent_id = parent_id
        self.trace_id = trace_id
        self.t0 = t0
        self.t1: Optional[float] = None
        self.tid = tid
        self.args = args
        self.kind = kind          # "thread" (ph X) | "async" (ph b/e)


class _NullSpan(Span):
    """Shared no-op span handed out while tracing is disabled."""

    def __init__(self):
        super().__init__("", 0, 0, 0, 0.0, 0, None, "thread")


_NULL_SPAN = _NullSpan()


class Tracer:
    """Bounded buffer of completed spans + the span-construction API."""

    def __init__(self, *, enabled: bool = False, max_events: int = 65536,
                 jax_annotations: bool = False):
        self.enabled = enabled
        self.jax_annotations = jax_annotations
        self.max_events = max_events
        self._lock = threading.Lock()
        self._events: List[dict] = []
        self.dropped = 0              # spans lost to the buffer bound
        self._ids = itertools.count(1)
        self._tls = threading.local()
        self._epoch = time.monotonic()

    # -- span construction ----------------------------------------------
    def _stack(self) -> List[Span]:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        return stack

    def current(self) -> Optional[Span]:
        """Innermost context-manager span on THIS thread (or None)."""
        stack = getattr(self._tls, "stack", None)
        return stack[-1] if stack else None

    def start_span(self, name: str, *, parent: Optional[Span] = None,
                   trace_id: Optional[int] = None,
                   args: Optional[dict] = None, t0: Optional[float] = None,
                   kind: str = "thread") -> Span:
        """Open a span NOT tied to this thread's stack (close it with
        ``end_span``; may happen on another thread)."""
        if not self.enabled:
            return _NULL_SPAN
        if parent is None:
            parent = self.current()
        pid = parent.span_id if parent is not None else 0
        if trace_id is None:
            trace_id = parent.trace_id if parent is not None else 0
        return Span(name, next(self._ids), pid, trace_id,
                    time.monotonic() if t0 is None else t0,
                    threading.get_ident(), args, kind)

    def end_span(self, span: Span, *, t1: Optional[float] = None,
                 args: Optional[dict] = None) -> None:
        if span is _NULL_SPAN or not isinstance(span, Span):
            return
        span.t1 = time.monotonic() if t1 is None else t1
        if args:
            span.args = {**(span.args or {}), **args}
        self._emit(span)

    @contextlib.contextmanager
    def span(self, name: str, *, args: Optional[dict] = None,
             parent: Optional[Span] = None,
             kind: str = "thread") -> Iterator[Span]:
        """Context-managed span, nested via this thread's span stack."""
        if not self.enabled:
            yield _NULL_SPAN
            return
        sp = self.start_span(name, parent=parent, args=args, kind=kind)
        stack = self._stack()
        stack.append(sp)
        try:
            yield sp
        finally:
            stack.pop()
            self.end_span(sp)

    def add_span(self, name: str, t0: float, t1: float, *,
                 parent: Optional[Span] = None,
                 trace_id: Optional[int] = None,
                 args: Optional[dict] = None,
                 kind: str = "thread") -> None:
        """Record an already-elapsed interval (e.g. a request's queue
        wait, only known when its batch pops)."""
        if not self.enabled:
            return
        sp = self.start_span(name, parent=parent, trace_id=trace_id,
                             args=args, t0=t0, kind=kind)
        self.end_span(sp, t1=t1)

    @contextlib.contextmanager
    def phase(self, name: str, *, args: Optional[dict] = None
              ) -> Iterator[Span]:
        """A ``span()`` that ALSO notes its interval on this thread's
        phase list -- the channel through which batch-level phases
        (mesh dispatch, top-k merge) deep inside the searcher reach the
        server, which replays them as children of every co-batched
        request's span tree.  Bounded per thread; ``take_phases``
        drains."""
        with self.span(name, args=args) as sp:
            yield sp
        if sp is not _NULL_SPAN and sp.t1 is not None:
            phases = getattr(self._tls, "phases", None)
            if phases is None:
                phases = self._tls.phases = []
            if len(phases) < 64:        # a flush records a handful; cap
                phases.append((name, sp.t0, sp.t1))

    def take_phases(self) -> List[Tuple[str, float, float]]:
        """Drain this thread's noted phase intervals (see ``phase``)."""
        phases = getattr(self._tls, "phases", None)
        self._tls.phases = []
        return phases or []

    @contextlib.contextmanager
    def jax_annotation(self, name: str):
        """``jax.profiler.TraceAnnotation`` bracket (opt-in no-op)."""
        if not (self.enabled and self.jax_annotations):
            yield
            return
        try:
            from jax.profiler import TraceAnnotation
        except ImportError:
            yield
            return
        with TraceAnnotation(name):
            yield

    # -- the Chrome trace-event buffer ------------------------------------
    def _us(self, t: float) -> float:
        return round((t - self._epoch) * 1e6, 3)

    def _emit(self, span: Span) -> None:
        t1 = span.t1 if span.t1 is not None else span.t0
        dur = max(0.0, t1 - span.t0)         # monotonic per span, clamped
        args = {"span_id": span.span_id, "parent_id": span.parent_id,
                "trace_id": span.trace_id, **(span.args or {})}
        base = {"name": span.name, "pid": os.getpid(), "tid": span.tid,
                "args": args}
        if span.kind == "async":
            events = [
                {**base, "ph": "b", "cat": "request",
                 "id": span.trace_id, "ts": self._us(span.t0)},
                {**base, "ph": "e", "cat": "request",
                 "id": span.trace_id, "ts": self._us(span.t0 + dur)},
            ]
        else:
            events = [{**base, "ph": "X", "cat": "serve",
                       "ts": self._us(span.t0),
                       "dur": round(dur * 1e6, 3)}]
        with self._lock:
            room = self.max_events - len(self._events)
            if room < len(events):
                self.dropped += 1
                return
            self._events.extend(events)

    def to_json(self) -> dict:
        """The buffered events as a Chrome trace-event document."""
        with self._lock:
            events = list(self._events)
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def export(self, path: str) -> int:
        """Write the buffer as trace-event JSON; returns event count."""
        doc = self.to_json()
        with open(path, "w") as f:
            json.dump(doc, f)
        return len(doc["traceEvents"])

    def events(self) -> List[dict]:
        with self._lock:
            return list(self._events)

    def reset(self, *, enabled: Optional[bool] = None) -> None:
        with self._lock:
            self._events = []
            self.dropped = 0
            self._epoch = time.monotonic()
        if enabled is not None:
            self.enabled = enabled


def request_tree(events: List[dict]) -> Dict[int, List[dict]]:
    """Group events by ``args.trace_id`` (0 = untraced/batch-level) --
    the per-request span-tree view the tests and the validator check."""
    out: Dict[int, List[dict]] = {}
    for ev in events:
        tid = int((ev.get("args") or {}).get("trace_id", 0))
        out.setdefault(tid, []).append(ev)
    return out


_default_tracer = Tracer()


def get_tracer() -> Tracer:
    """The process-wide default tracer (disabled until something --
    ``--trace-out``, a test, an exporter -- enables it)."""
    return _default_tracer


def set_tracer(tracer: Tracer) -> Tracer:
    global _default_tracer
    prev, _default_tracer = _default_tracer, tracer
    return prev
