"""Minwise-hash signature computation (the paper's preprocessing step).

Given a batch of binary feature *sets* (padded-CSR layout, see
``repro.data.sparse``), compute for each set the k minima

    z_j = min_{t in S} h_j(t),     j = 1..k

under one of three hash families (permutation / 2U / 4U).  This is the
expensive preprocessing the paper accelerates with GPUs; here the jnp path
is the reference oracle and ``repro.kernels.minhash`` holds the Pallas TPU
kernels.  (``repro.core.oph`` implements the One Permutation Hashing
alternative: the same (n, k) signature from ONE hash pass per vector.)  The jnp path is written with a k-chunked scan so the
``(n, nnz, k)`` intermediate never exceeds ``chunk_k`` lanes -- the same
blocking idea as the kernel, expressed at the XLA level.
"""

from __future__ import annotations

from functools import partial
from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.core.hashing import (Hash2U, Hash4U, PermutationFamily,
                                hash2u_apply, hash4u_apply)

Family = Union[Hash2U, Hash4U, PermutationFamily]

# Sentinel for masked (padding) slots: larger than any hash output.
_PAD_MAX = jnp.uint32(0xFFFFFFFF)


def minhash_signatures(indices: jax.Array, mask: jax.Array, family: Family,
                       chunk_k: int = 64) -> jax.Array:
    """Compute (n, k) uint32 signatures for a padded sparse batch.

    Args:
      indices: (n, max_nnz) int32 feature ids in [0, D).
      mask:    (n, max_nnz) bool, True for real entries.
      family:  hash family (Hash2U / Hash4U / PermutationFamily).
      chunk_k: number of hash functions evaluated per scan step.

    Returns:
      (n, k) uint32 minima.
    """
    if isinstance(family, PermutationFamily):
        return _minhash_perm(indices, mask, family)
    if isinstance(family, Hash2U):
        return _minhash_2u(indices, mask, family.a1, family.a2, family.s,
                           family.variant, chunk_k)
    if isinstance(family, Hash4U):
        return _minhash_4u(indices, mask, family.a, family.s,
                           family.use_bitmod, chunk_k)
    raise TypeError(type(family))


def _chunked_min(indices: jax.Array, mask: jax.Array, k: int, chunk_k: int,
                 hash_chunk) -> jax.Array:
    """Scan over k-chunks; ``hash_chunk(t, j0)`` -> (n, nnz, chunk_k)."""
    n = indices.shape[0]
    if k % chunk_k != 0:
        # pad k up; extra lanes discarded at the end
        k_pad = ((k + chunk_k - 1) // chunk_k) * chunk_k
    else:
        k_pad = k
    n_chunks = k_pad // chunk_k

    def body(carry, j0):
        h = hash_chunk(indices, j0)                       # (n, nnz, chunk_k)
        h = jnp.where(mask[..., None], h, _PAD_MAX)
        return carry, jnp.min(h, axis=1)                  # (n, chunk_k)

    _, mins = jax.lax.scan(body, None, jnp.arange(n_chunks) * chunk_k)
    out = jnp.moveaxis(mins, 0, 1).reshape(n, k_pad)      # (n, k_pad)
    return out[:, :k]


def _minhash_2u(indices, mask, a1, a2, s, variant, chunk_k):
    k = a1.shape[0]
    chunk_k = min(chunk_k, k)
    a1p, a2p = _pad_coeffs(chunk_k, a1, a2)

    def hash_chunk(t, j0):
        c1 = jax.lax.dynamic_slice_in_dim(a1p, j0, chunk_k)
        c2 = jax.lax.dynamic_slice_in_dim(a2p, j0, chunk_k)
        return hash2u_apply(t[..., None], c1, c2, s, variant)

    return _chunked_min(indices, mask, k, chunk_k, hash_chunk)


def _minhash_4u(indices, mask, a, s, use_bitmod, chunk_k):
    k = a.shape[1]
    chunk_k = min(chunk_k, k)
    coeffs = _pad_coeffs(chunk_k, a[0], a[1], a[2], a[3])

    def hash_chunk(t, j0):
        c = [jax.lax.dynamic_slice_in_dim(ci, j0, chunk_k) for ci in coeffs]
        return hash4u_apply(t[..., None], c[0], c[1], c[2], c[3], s,
                            use_bitmod)

    return _chunked_min(indices, mask, k, chunk_k, hash_chunk)


def _minhash_perm(indices, mask, family: PermutationFamily):
    # (k, D) gathered at (n, nnz) -> (n, nnz, k); D is small by construction.
    vals = family(indices)
    vals = jnp.where(mask[..., None], vals, _PAD_MAX)
    return jnp.min(vals, axis=1)


def _pad_coeffs(chunk_k, *arrs):
    """Pad coefficient vectors so dynamic_slice never reads out of range."""
    k = arrs[0].shape[0]
    k_pad = ((k + chunk_k - 1) // chunk_k) * chunk_k
    if k_pad == k:
        return arrs if len(arrs) > 1 else arrs[0]
    out = tuple(jnp.pad(a, (0, k_pad - k)) for a in arrs)
    return out if len(out) > 1 else out[0]


# ---------------------------------------------------------------------------
# Collision-probability utilities (used in tests / Appendix-A benchmarks)
# ---------------------------------------------------------------------------

def signature_matches(sig1: jax.Array, sig2: jax.Array) -> jax.Array:
    """Fraction of matching minima -- the Eq. (2) estimator R̂_M."""
    return jnp.mean((sig1 == sig2).astype(jnp.float32), axis=-1)


def resemblance(set1_mask_onehot: jax.Array, set2_mask_onehot: jax.Array) -> jax.Array:
    """Exact resemblance |S1 ∩ S2| / |S1 ∪ S2| from dense 0/1 vectors."""
    inter = jnp.sum(set1_mask_onehot * set2_mask_onehot, axis=-1)
    union = jnp.sum(jnp.maximum(set1_mask_onehot, set2_mask_onehot), axis=-1)
    return inter / jnp.maximum(union, 1)
