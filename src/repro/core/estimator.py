"""Theorem-1 estimator for b-bit minwise hashing (Li & König [26]).

    P_b = Pr[z1^(b) == z2^(b)] = C_{1,b} + (1 - C_{2,b}) R

with, for r1 = f1/D, r2 = f2/D (f = set size):

    A_{i,b} = r_i (1 - r_i)^(2^b - 1) / (1 - (1 - r_i)^(2^b))
    C_{1,b} = A_{1,b} r2/(r1+r2) + A_{2,b} r1/(r1+r2)
    C_{2,b} = A_{1,b} r1/(r1+r2) + A_{2,b} r2/(r1+r2)

Unbiased estimator and its theoretical variance (Eq. 11 of [26]):

    R̂_b = (P̂_b - C_{1,b}) / (1 - C_{2,b})
    Var(R̂_b) = P_b (1 - P_b) / (k (1 - C_{2,b})^2)

In the sparse limit r -> 0: A -> 2^-b and P_b -> 2^-b + (1 - 2^-b) R.
These formulas power the Appendix-A MSE-vs-theory benchmarks.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class BBitConstants(NamedTuple):
    C1: jax.Array
    C2: jax.Array


def bbit_constants(f1, f2, D, b) -> BBitConstants:
    """C_{1,b}, C_{2,b} from set sizes f1, f2 and universe size D."""
    r1 = jnp.asarray(f1, jnp.float64 if jax.config.jax_enable_x64 else jnp.float32) / D
    r2 = jnp.asarray(f2, r1.dtype) / D
    two_b = 2.0 ** b

    def A(r):
        # Numerically stable via log1p/expm1 (r can be ~1e-9 in fp32):
        #   A = r (1-r)^(2^b - 1) / (1 - (1-r)^(2^b))
        r = jnp.clip(r, 1e-35, 1.0 - 1e-7)
        log1m = jnp.log1p(-r)
        num = r * jnp.exp((two_b - 1.0) * log1m)
        denom = -jnp.expm1(two_b * log1m)
        return num / jnp.maximum(denom, 1e-35)

    A1, A2 = A(r1), A(r2)
    rs = jnp.maximum(r1 + r2, 1e-30)
    C1 = A1 * r2 / rs + A2 * r1 / rs
    C2 = A1 * r1 / rs + A2 * r2 / rs
    return BBitConstants(C1=C1, C2=C2)


def collision_prob(R, f1, f2, D, b):
    """Theorem 1 forward direction: P_b from resemblance R."""
    c = bbit_constants(f1, f2, D, b)
    return c.C1 + (1.0 - c.C2) * R


def estimate_resemblance(p_hat, f1, f2, D, b):
    """Unbiased R̂_b from the empirical collision fraction P̂_b (Eq. 4)."""
    c = bbit_constants(f1, f2, D, b)
    return (p_hat - c.C1) / (1.0 - c.C2)


def theoretical_variance(R, f1, f2, D, b, k):
    """Var(R̂_b), Eq. (11) of [26], assuming perfectly random permutations."""
    c = bbit_constants(f1, f2, D, b)
    Pb = c.C1 + (1.0 - c.C2) * R
    return Pb * (1.0 - Pb) / (k * (1.0 - c.C2) ** 2)


def theoretical_variance_minwise(R, k):
    """Var of the original (full-value) minwise estimator R̂_M = R(1-R)/k."""
    return R * (1.0 - R) / k


def empirical_p_hat(sig1_b: jax.Array, sig2_b: jax.Array) -> jax.Array:
    """P̂_b: fraction of matching b-bit values across the k signatures."""
    return jnp.mean((sig1_b == sig2_b).astype(jnp.float32), axis=-1)


# ---------------------------------------------------------------------------
# One Permutation Hashing variants (scheme="oph")
# ---------------------------------------------------------------------------

def empirical_p_hat_oph(sig1_b: jax.Array, sig2_b: jax.Array) -> jax.Array:
    """P̂_b over jointly non-empty bins (sentinel-coded OPH signatures).

    Rotation-densified signatures have no EMPTY bins, so this reduces to
    ``empirical_p_hat`` there; for ``densify="sentinel"`` it is the
    Li-Owen-Zhang normalization N_match / (k - N_jointly_empty).
    """
    from repro.core.oph import oph_match_fraction
    return oph_match_fraction(sig1_b, sig2_b)


def estimate_resemblance_oph(sig1_b, sig2_b, f1, f2, D, b):
    """R̂_b from b-bit OPH signatures via the Theorem-1 correction.

    Uses the OPH-aware collision fraction, then the same (C1, C2)
    debiasing as the k-permutation estimator -- the bin process is a
    without-replacement sample of one permutation, whose collision
    probability matches Theorem 1 up to O(1/k) terms.
    """
    return estimate_resemblance(empirical_p_hat_oph(sig1_b, sig2_b),
                                f1, f2, D, b)
