"""LSH banding over b-bit minhash signatures: near-duplicate detection.

The paper's §1 motivates minwise hashing through the Web-crawling dedup
pipeline ("minwise hashing is a major step in the crawling pipeline").
This module provides that application on top of the same signatures the
learning stack uses:

  * signatures are split into ``n_bands`` bands of ``r`` values each,
  * each band is hashed to a bucket key; documents sharing any bucket
    become candidate pairs,
  * candidates are verified with the unbiased Theorem-1 estimator
    (``estimate_resemblance``) against a threshold.

Collision calculus (standard LSH S-curve): a pair with resemblance R
matches one band with prob ~ P_b(R)^r and any band with
1 - (1 - P_b^r)^n, where P_b = C1 + (1 - C2) R is the paper's b-bit
collision probability -- so banding composes exactly with Theorem 1.

The banding *machinery* now lives with the search subsystem
(``repro.index``): key packing and the S-curve are
``repro.index.banding``, and the bucket grouping is the same sorted
posting-table construction the ``.idx`` index persists
(``repro.index.builder.build_band_tables``) -- this module is the thin
offline-dedup entry point on top of it.  Imports are function-local so
the core layer carries no import-time dependency on the subsystem.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import jax
import numpy as np

from repro.core.estimator import bbit_constants, estimate_resemblance


@dataclasses.dataclass(frozen=True)
class LSHConfig:
    n_bands: int
    rows_per_band: int           # r signatures per band
    b: int                       # bits kept per signature

    @property
    def k(self) -> int:
        return self.n_bands * self.rows_per_band


def band_keys(sig_b: jax.Array, cfg: LSHConfig) -> jax.Array:
    """Pack each band's r b-bit values into one integer bucket key.

    sig_b: (n, k) uint32 b-bit signatures (k = n_bands * r).
    Returns (n, n_bands) uint32 keys (r*b <= 32 required).  Delegates
    to ``repro.index.banding.band_keys_from_codes`` -- the same key the
    search index computes from packed wire words on device.
    """
    from repro.index.banding import BandingConfig, band_keys_from_codes
    n, k = sig_b.shape
    if k != cfg.k:
        raise ValueError(f"signature width {k} != bands*rows {cfg.k}")
    return band_keys_from_codes(
        sig_b, BandingConfig(cfg.n_bands, cfg.rows_per_band, cfg.b))


def match_probability(R: float, f1: int, f2: int, D: int,
                      cfg: LSHConfig) -> float:
    """Analytic S-curve: P[candidate] for a pair with resemblance R."""
    from repro.index.banding import s_curve
    c = bbit_constants(f1, f2, D, cfg.b)
    pb = float(c.C1 + (1.0 - c.C2) * R)
    return s_curve(pb, cfg.n_bands, cfg.rows_per_band)


def candidate_pairs(keys: np.ndarray) -> List[Tuple[int, int]]:
    """All document pairs sharing at least one band bucket.

    Built on the index subsystem's sorted posting tables (the structure
    the ``.idx`` file persists) instead of the old python-dict pass.
    """
    from repro.index.builder import build_band_tables
    band_offsets, _, bucket_offsets, postings = \
        build_band_tables(np.asarray(keys))
    pairs = set()
    n_bands = band_offsets.size - 1
    for band in range(n_bands):
        for t in range(band_offsets[band], band_offsets[band + 1]):
            members = postings[bucket_offsets[t]:bucket_offsets[t + 1]]
            for a in range(members.size):
                for b_ in range(a + 1, members.size):
                    pairs.add((int(members[a]), int(members[b_])))
    return sorted(pairs)


def dedup(sig_b: jax.Array, set_sizes: Sequence[int], D: int,
          cfg: LSHConfig, threshold: float = 0.8
          ) -> List[Tuple[int, int, float]]:
    """Find near-duplicate pairs: LSH candidates + Theorem-1 verification.

    Returns (i, j, estimated_resemblance) for pairs with R_hat >= threshold.
    """
    keys = np.asarray(band_keys(sig_b, cfg))
    sig_np = np.asarray(sig_b)
    out = []
    for i, j in candidate_pairs(keys):
        p_hat = float(np.mean(sig_np[i] == sig_np[j]))
        r_hat = float(estimate_resemblance(p_hat, set_sizes[i], set_sizes[j],
                                           D, cfg.b))
        if r_hat >= threshold:
            out.append((i, j, r_hat))
    return out
