"""LSH banding over b-bit minhash signatures: near-duplicate detection.

The paper's §1 motivates minwise hashing through the Web-crawling dedup
pipeline ("minwise hashing is a major step in the crawling pipeline").
This module provides that application on top of the same signatures the
learning stack uses:

  * signatures are split into ``n_bands`` bands of ``r`` values each,
  * each band is hashed to a bucket key; documents sharing any bucket
    become candidate pairs,
  * candidates are verified with the unbiased Theorem-1 estimator
    (``estimate_resemblance``) against a threshold.

Collision calculus (standard LSH S-curve): a pair with resemblance R
matches one band with prob ~ P_b(R)^r and any band with
1 - (1 - P_b^r)^n, where P_b = C1 + (1 - C2) R is the paper's b-bit
collision probability -- so banding composes exactly with Theorem 1.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.estimator import bbit_constants, estimate_resemblance


@dataclasses.dataclass(frozen=True)
class LSHConfig:
    n_bands: int
    rows_per_band: int           # r signatures per band
    b: int                       # bits kept per signature

    @property
    def k(self) -> int:
        return self.n_bands * self.rows_per_band


def band_keys(sig_b: jax.Array, cfg: LSHConfig) -> jax.Array:
    """Pack each band's r b-bit values into one integer bucket key.

    sig_b: (n, k) uint32 b-bit signatures (k = n_bands * r).
    Returns (n, n_bands) uint64-safe int64 keys (r*b <= 60 required).
    """
    n, k = sig_b.shape
    if k != cfg.k:
        raise ValueError(f"signature width {k} != bands*rows {cfg.k}")
    if cfg.rows_per_band * cfg.b > 60:
        raise ValueError("band key exceeds 60 bits; reduce r or b")
    z = sig_b.astype(jnp.int64).reshape(n, cfg.n_bands, cfg.rows_per_band)
    shifts = (jnp.arange(cfg.rows_per_band, dtype=jnp.int64) * cfg.b)
    return jnp.sum(z << shifts, axis=-1)


def match_probability(R: float, f1: int, f2: int, D: int,
                      cfg: LSHConfig) -> float:
    """Analytic S-curve: P[candidate] for a pair with resemblance R."""
    c = bbit_constants(f1, f2, D, cfg.b)
    pb = float(c.C1 + (1.0 - c.C2) * R)
    return 1.0 - (1.0 - pb ** cfg.rows_per_band) ** cfg.n_bands


def candidate_pairs(keys: np.ndarray) -> List[Tuple[int, int]]:
    """All document pairs sharing at least one band bucket."""
    buckets: Dict[Tuple[int, int], List[int]] = defaultdict(list)
    n, n_bands = keys.shape
    for band in range(n_bands):
        for i in range(n):
            buckets[(band, int(keys[i, band]))].append(i)
    pairs = set()
    for members in buckets.values():
        for a in range(len(members)):
            for b_ in range(a + 1, len(members)):
                pairs.add((members[a], members[b_]))
    return sorted(pairs)


def dedup(sig_b: jax.Array, set_sizes: Sequence[int], D: int,
          cfg: LSHConfig, threshold: float = 0.8
          ) -> List[Tuple[int, int, float]]:
    """Find near-duplicate pairs: LSH candidates + Theorem-1 verification.

    Returns (i, j, estimated_resemblance) for pairs with R_hat >= threshold.
    """
    keys = np.asarray(band_keys(sig_b, cfg))
    sig_np = np.asarray(sig_b)
    out = []
    for i, j in candidate_pairs(keys):
        p_hat = float(np.mean(sig_np[i] == sig_np[j]))
        r_hat = float(estimate_resemblance(p_hat, set_sizes[i], set_sizes[j],
                                           D, cfg.b))
        if r_hat >= threshold:
            out.append((i, j, r_hat))
    return out
