"""Universal hash families used by (b-bit) minwise hashing.

This module implements the paper's three hashing schemes:

  * full random permutations (the "gold standard" -- storable only for
    small D; used to validate the simple hash families),
  * 2-universal (2U) multiply-shift hashing without modulo ops (Eq. 10),
  * 4-universal (4U) polynomial hashing over the Mersenne prime
    p = 2^31 - 1, with the modulo replaced by the paper's §3.4 ``BitMod``
    shift/mask/conditional-subtract sequence.

All arithmetic is 32-bit (TPU-native).  64-bit intermediates needed by the
4U polynomial are emulated with 16-bit-limb long multiplication
(``umul32_wide``) so the exact same code path runs inside Pallas TPU
kernels, where 64-bit integers do not exist.  This is the TPU adaptation of
the paper's "avoid modulo operations" GPU tricks.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

MERSENNE_P = np.uint32(2**31 - 1)  # p = 2^31 - 1, the paper's §3.4 prime
_U32 = jnp.uint32


# ---------------------------------------------------------------------------
# 32-bit building blocks (shared by jnp reference paths and Pallas kernels)
# ---------------------------------------------------------------------------

def umul32_wide(a: jax.Array, b: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Full 32x32 -> 64 bit product as a ``(hi, lo)`` pair of uint32.

    Emulated with 16-bit limbs so it lowers to plain uint32 ops (TPU has no
    64-bit integer unit; this is the standard ``umulhi`` emulation).
    """
    a = a.astype(_U32)
    b = b.astype(_U32)
    mask16 = _U32(0xFFFF)
    a_lo, a_hi = a & mask16, a >> 16
    b_lo, b_hi = b & mask16, b >> 16
    ll = a_lo * b_lo
    lh = a_lo * b_hi
    hl = a_hi * b_lo
    hh = a_hi * b_hi
    mid1 = lh + (ll >> 16)          # <= 2^32 - 2^17 + 2^16, no overflow
    mid2 = hl + (mid1 & mask16)     # no overflow
    hi = hh + (mid1 >> 16) + (mid2 >> 16)
    lo = (mid2 << 16) | (ll & mask16)
    return hi, lo


def add64(hi: jax.Array, lo: jax.Array, c: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """``(hi, lo) + c`` with carry, all uint32."""
    c = c.astype(_U32)
    new_lo = lo + c
    carry = (new_lo < c).astype(_U32)
    return hi + carry, new_lo


def mod_mersenne31(hi: jax.Array, lo: jax.Array) -> jax.Array:
    """``(hi * 2^32 + lo) mod (2^31 - 1)`` for values < 2^62.

    Branch-free transliteration of the paper's §3.4 ``BitMod``:
    two fold steps ``v = (v >> 31) + (v & p)`` followed by one conditional
    subtract.  The first fold is done directly on the (hi, lo) pair:
    ``v >> 31 == (hi << 1) | (lo >> 31)`` and ``v & p == lo & p``.
    """
    p = _U32(MERSENNE_P)
    # fold 1: requires hi < 2^30, guaranteed for products of values < 2^31.
    v1 = ((hi << 1) | (lo >> 31)) + (lo & p)      # < 2^32
    # fold 2
    v2 = (v1 >> 31) + (v1 & p)                    # <= 2^31
    return jnp.where(v2 >= p, v2 - p, v2)


def mulmod_mersenne31(a: jax.Array, b: jax.Array) -> jax.Array:
    """``a * b mod (2^31 - 1)`` for a, b < 2^31, all in uint32."""
    hi, lo = umul32_wide(a, b)
    return mod_mersenne31(hi, lo)


# ---------------------------------------------------------------------------
# Hash families
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Hash2U:
    """2-universal multiply-shift family (paper Eq. 10, Dietzfelbinger).

    ``h_j(t) = ((a1_j + a2_j * t) mod 2^32) >> (32 - s)`` with ``a2`` odd.

    We default to the *high-bits* variant (the form proven 2U in [14]);
    ``variant="low"`` gives the paper's literal ``mod 2^s`` form.  Output
    range is ``[0, 2^s) == [0, D)``.
    """

    a1: jax.Array   # (k,) uint32
    a2: jax.Array   # (k,) uint32, odd
    s: int          # D = 2^s
    variant: str = "high"

    @property
    def k(self) -> int:
        return self.a1.shape[0]

    @property
    def D(self) -> int:
        return 1 << self.s

    @staticmethod
    def create(key: jax.Array, k: int, s: int, variant: str = "high") -> "Hash2U":
        if not (1 <= s <= 32):
            raise ValueError(f"need 1 <= s <= 32, got {s}")
        k1, k2 = jax.random.split(key)
        a1 = jax.random.bits(k1, (k,), jnp.uint32)
        a2 = jax.random.bits(k2, (k,), jnp.uint32) | _U32(1)
        return Hash2U(a1=a1, a2=a2, s=s, variant=variant)

    def __call__(self, t: jax.Array) -> jax.Array:
        """Hash indices ``t`` (any shape, int) with all k functions.

        Returns shape ``t.shape + (k,)`` uint32 in ``[0, 2^s)``.
        """
        t = t.astype(_U32)[..., None]
        v = self.a1 + self.a2 * t           # wraps mod 2^32
        if self.variant == "high":
            return v >> _U32(32 - self.s) if self.s < 32 else v
        return v & _U32((1 << self.s) - 1) if self.s < 32 else v

    def apply_one(self, t: jax.Array, j_a1: jax.Array, j_a2: jax.Array) -> jax.Array:
        """Single-function form used inside kernels: coefficients passed in."""
        v = j_a1 + j_a2 * t.astype(_U32)
        if self.variant == "high":
            return v >> _U32(32 - self.s) if self.s < 32 else v
        return v & _U32((1 << self.s) - 1) if self.s < 32 else v


def hash2u_apply(t: jax.Array, a1: jax.Array, a2: jax.Array, s: int,
                 variant: str = "high") -> jax.Array:
    """Functional 2U hash: broadcast ``a1``/``a2`` against ``t``."""
    v = a1.astype(_U32) + a2.astype(_U32) * t.astype(_U32)
    if s >= 32:
        return v
    if variant == "high":
        return v >> _U32(32 - s)
    return v & _U32((1 << s) - 1)


@dataclasses.dataclass(frozen=True)
class Hash4U:
    """4-universal polynomial family over p = 2^31 - 1 (paper Eq. 9 + §3.4).

    ``h_j(t) = ((sum_i a_{i,j} t^{i-1}) mod p) mod D`` evaluated by Horner's
    rule; every ``mod p`` uses the Mersenne ``BitMod`` trick, and the final
    ``mod D`` is a mask when D is a power of two (``use_bitmod=True``), or a
    true modulo for the reference/validation path (``use_bitmod=False``,
    the paper's "4U (Mod)" row in Table 2).
    """

    a: jax.Array    # (4, k) uint32, coefficients < p
    s: int          # D = 2^s, s <= 31
    use_bitmod: bool = True

    @property
    def k(self) -> int:
        return self.a.shape[1]

    @property
    def D(self) -> int:
        return 1 << self.s

    @staticmethod
    def create(key: jax.Array, k: int, s: int, use_bitmod: bool = True) -> "Hash4U":
        if not (1 <= s <= 31):
            raise ValueError(f"4U over p=2^31-1 needs s <= 31, got {s}")
        a = jax.random.bits(key, (4, k), jnp.uint32) % _U32(MERSENNE_P)
        return Hash4U(a=a, s=s, use_bitmod=use_bitmod)

    def __call__(self, t: jax.Array) -> jax.Array:
        """Hash indices ``t``; returns ``t.shape + (k,)`` uint32 in [0, 2^s)."""
        return hash4u_apply(t[..., None], self.a[0], self.a[1], self.a[2],
                            self.a[3], self.s, self.use_bitmod)


def hash4u_apply(t: jax.Array, a1: jax.Array, a2: jax.Array, a3: jax.Array,
                 a4: jax.Array, s: int, use_bitmod: bool = True) -> jax.Array:
    """Horner evaluation of the 4U polynomial, all uint32.

    ``h = ((a4 t^3 + a3 t^2 + a2 t + a1) mod p) mod 2^s``.
    Inputs must satisfy ``t < 2^31`` and coefficients ``< p``.
    """
    t = t.astype(_U32)
    acc = jnp.broadcast_to(a4.astype(_U32), jnp.broadcast_shapes(t.shape, a4.shape))
    for coef in (a3, a2, a1):
        hi, lo = umul32_wide(acc, t)             # acc * t < 2^62
        hi, lo = add64(hi, lo, coef.astype(_U32))
        if use_bitmod:
            acc = mod_mersenne31(hi, lo)
        else:
            # Reference "Mod" path: same mathematical value, computed with
            # the double-fold as well (there is no 64-bit % on TPU); kept
            # separate so benchmarks can cost the two variants differently.
            acc = _slow_mod_mersenne31(hi, lo)
    mask = _U32((1 << s) - 1) if s < 31 else _U32(MERSENNE_P)
    return acc & mask if s < 31 else acc % _U32(MERSENNE_P)


def _slow_mod_mersenne31(hi: jax.Array, lo: jax.Array) -> jax.Array:
    """Generic (hi,lo) mod p via remainder chains -- the 'Mod' baseline.

    Emulates a true 64-bit modulo using 32-bit ops only:
    v mod p = ((hi mod p) * (2^32 mod p) + lo mod p) mod p.
    """
    p = _U32(MERSENNE_P)
    two32_mod_p = _U32((2**32) % int(MERSENNE_P))  # == 2
    hi_m = hi % p
    term = mulmod_mersenne31(hi_m, two32_mod_p)
    lo_m = lo % p
    v = term + lo_m                     # < 2p < 2^32
    return jnp.where(v >= p, v - p, v)


# ---------------------------------------------------------------------------
# Full random permutations (gold standard, small D only)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PermutationFamily:
    """k independent uniformly random permutations of [0, D).

    Storage is O(k * D) -- exactly the paper's Issue 3.  Only usable for
    small D (tests / the webspam-scale validation of §4).
    """

    perms: jax.Array   # (k, D) int32; perms[j, t] = pi_j(t)

    @property
    def k(self) -> int:
        return self.perms.shape[0]

    @property
    def D(self) -> int:
        return self.perms.shape[1]

    @staticmethod
    def create(key: jax.Array, k: int, D: int) -> "PermutationFamily":
        keys = jax.random.split(key, k)
        perms = jax.vmap(lambda kk: jax.random.permutation(kk, D))(keys)
        return PermutationFamily(perms=perms.astype(jnp.int32))

    def __call__(self, t: jax.Array) -> jax.Array:
        """Returns ``t.shape + (k,)`` permuted values."""
        # perms: (k, D); t: (...,) -> out (..., k)
        out = self.perms[:, t]                       # (k, ...)
        return jnp.moveaxis(out, 0, -1).astype(jnp.uint32)

    def storage_bytes(self) -> int:
        return int(self.k) * int(self.D) * 4


def family_storage_bytes(family) -> int:
    """Coefficient storage -- the paper's Issue-3 comparison."""
    if isinstance(family, PermutationFamily):
        return family.storage_bytes()
    if isinstance(family, Hash2U):
        return 2 * family.k * 4
    if isinstance(family, Hash4U):
        return 4 * family.k * 4
    base = getattr(family, "base", None)   # OPH: ONE function's coefficients
    if base is not None:
        return family_storage_bytes(base)
    raise TypeError(type(family))
