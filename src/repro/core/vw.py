"""Vowpal-Wabbit-style feature hashing (Weinberger et al. [37], Shi et al.
[33]) -- the paper's §4.2/§5.3 baseline.

Each original feature index t is mapped to bin ``h(t) in [0, m)`` and sign
``xi(t) in {-1, +1}``; the hashed vector is ``x'_i = sum_{t: h(t)=i}
xi(t) x_t``.  For the paper's binary data ``x_t in {0, 1}`` this is a
signed count per bin.  Two randomness modes, matching Figure 5:

  * ``full``  -- h and xi are uniformly random tables of size D (small D),
  * ``u2``    -- h is the 2U multiply-shift scheme; xi is one extra 2U bit.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.hashing import Hash2U, hash2u_apply


@dataclasses.dataclass(frozen=True)
class VWHasher:
    mode: str                     # "full" | "u2"
    m_bits: int                   # m = 2^m_bits bins
    # full-random tables (mode == "full")
    bin_table: Optional[jax.Array] = None    # (D,) int32
    sign_table: Optional[jax.Array] = None   # (D,) int8 in {-1, +1}
    # 2U coefficients (mode == "u2")
    a1: Optional[jax.Array] = None
    a2: Optional[jax.Array] = None
    s1: Optional[jax.Array] = None
    s2: Optional[jax.Array] = None

    @property
    def m(self) -> int:
        return 1 << self.m_bits

    @staticmethod
    def create(key: jax.Array, m_bits: int, mode: str = "u2",
               D: Optional[int] = None) -> "VWHasher":
        if mode == "full":
            if D is None:
                raise ValueError("full-random VW needs explicit D")
            kb, ks = jax.random.split(key)
            bins = jax.random.randint(kb, (D,), 0, 1 << m_bits, dtype=jnp.int32)
            signs = (jax.random.bernoulli(ks, 0.5, (D,)).astype(jnp.int8) * 2 - 1)
            return VWHasher(mode=mode, m_bits=m_bits, bin_table=bins,
                            sign_table=signs)
        if mode == "u2":
            k1, k2, k3, k4 = jax.random.split(key, 4)
            mk = lambda kk: jax.random.bits(kk, (), jnp.uint32)
            return VWHasher(mode=mode, m_bits=m_bits,
                            a1=mk(k1), a2=mk(k2) | jnp.uint32(1),
                            s1=mk(k3), s2=mk(k4) | jnp.uint32(1))
        raise ValueError(mode)

    def bins_and_signs(self, t: jax.Array):
        if self.mode == "full":
            return (self.bin_table[t].astype(jnp.int32),
                    self.sign_table[t].astype(jnp.float32))
        bins = hash2u_apply(t, self.a1, self.a2, self.m_bits).astype(jnp.int32)
        sign_bit = hash2u_apply(t, self.s1, self.s2, 1)
        return bins, (sign_bit.astype(jnp.float32) * 2.0 - 1.0)

    def __call__(self, indices: jax.Array, mask: jax.Array,
                 values: Optional[jax.Array] = None) -> jax.Array:
        """Hash a padded sparse batch into dense (n, m) float vectors.

        Args:
          indices: (n, max_nnz) int32, mask: (n, max_nnz) bool.
          values:  optional (n, max_nnz) float; default all-ones (binary).
        """
        n, nnz = indices.shape
        bins, signs = self.bins_and_signs(indices)
        vals = signs if values is None else signs * values
        vals = jnp.where(mask, vals, 0.0)
        # scatter-add each row's contributions into its m-bin vector
        row = jnp.broadcast_to(jnp.arange(n)[:, None], (n, nnz))
        flat_bin = (row * self.m + bins).reshape(-1)
        out = jnp.zeros((n * self.m,), jnp.float32).at[flat_bin].add(
            vals.reshape(-1), mode="drop")
        return out.reshape(n, self.m)
