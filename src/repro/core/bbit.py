"""b-bit minwise hashing: lowest-b-bit extraction, packing, and the Eq. (5)
expansion that turns signatures into learnable features.

The learning construction: each example's k b-bit values ``z^(b)_1..k``
expand into a ``2^b * k``-dimensional binary vector with exactly k ones
(Eq. 5).  A linear model on that expansion approximates resemblance-kernel
learning.  We provide:

  * ``lowest_bits``      -- z & (2^b - 1)
  * ``pack_signatures``  -- bit-pack b-bit values into uint32 words (the
                            storage the paper counts: k*b bits per example)
  * ``pack_codes`` / ``unpack_codes`` -- general bitstream packing of
                            ``code_bits``-wide codes (codes may straddle
                            word boundaries), used for the wire format:
                            plain signatures pack b-bit codes, sentinel
                            OPH packs (b+1)-bit codes with EMPTY as 2^b
  * ``expand_tokens``    -- the *implicit* expansion: token ids
                            ``j * 2^b + z_j`` (a gather into a (k*2^b, ...)
                            weight table == the one-hot dot of Eq. 5)
  * ``expand_onehot``    -- the explicit dense 0/1 expansion (tests/small)
  * ``storage_bits``     -- the paper's storage accounting for comparisons
                            against VW / raw data.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def lowest_bits(sig: jax.Array, b: int) -> jax.Array:
    """Keep the lowest b bits of each minhash value. Output uint32 in [0, 2^b)."""
    if b >= 32:
        return sig.astype(jnp.uint32)
    return sig.astype(jnp.uint32) & jnp.uint32((1 << b) - 1)


def expand_tokens(sig_b: jax.Array, b: int) -> jax.Array:
    """Token ids for the implicit Eq.(5) expansion.

    ``tok[i, j] = j * 2^b + z^(b)_{i,j}`` in ``[0, k * 2^b)``.  A linear
    model is then ``sum_j w[tok[i, j]]`` -- identical to the inner product
    with the explicit one-hot expansion, without materializing it.
    """
    k = sig_b.shape[-1]
    offs = (jnp.arange(k, dtype=jnp.uint32) << b)
    return (sig_b.astype(jnp.uint32) + offs).astype(jnp.int32)


def expand_onehot(sig_b: jax.Array, b: int, dtype=jnp.float32) -> jax.Array:
    """Explicit (n, k * 2^b) 0/1 expansion of Eq. (5).  For tests/small n."""
    n, k = sig_b.shape
    tok = expand_tokens(sig_b, b)
    # one_hot over the k tokens then sum: exactly k ones per row, one in
    # each length-2^b block (the tokens of different j never collide).
    return jnp.sum(jax.nn.one_hot(tok, k * (1 << b), dtype=dtype), axis=1)


def pack_signatures(sig_b: jax.Array, b: int) -> jax.Array:
    """Bit-pack (n, k) b-bit values into (n, ceil(k*b/32)) uint32 words.

    This is the wire/storage format (k*b bits per example).  b must divide
    32 for lane-aligned packing (b in {1, 2, 4, 8, 16}); other b are stored
    one-per-lane unpacked by callers.
    """
    if 32 % b != 0:
        raise ValueError(f"pack_signatures needs b | 32, got b={b}")
    per_word = 32 // b
    n, k = sig_b.shape
    k_pad = ((k + per_word - 1) // per_word) * per_word
    z = jnp.pad(sig_b.astype(jnp.uint32), ((0, 0), (0, k_pad - k)))
    z = z.reshape(n, k_pad // per_word, per_word)
    shifts = (jnp.arange(per_word, dtype=jnp.uint32) * b).astype(jnp.uint32)
    return jnp.sum(z << shifts, axis=-1, dtype=jnp.uint32)


def unpack_signatures(packed: jax.Array, b: int, k: int) -> jax.Array:
    """Inverse of ``pack_signatures``; returns (n, k) uint32."""
    per_word = 32 // b
    shifts = (jnp.arange(per_word, dtype=jnp.uint32) * b).astype(jnp.uint32)
    z = (packed[..., None] >> shifts) & jnp.uint32((1 << b) - 1)
    return z.reshape(packed.shape[0], -1)[:, :k]


def packed_words(k: int, code_bits: int) -> int:
    """uint32 words per example for k ``code_bits``-wide codes (bitstream)."""
    if not 1 <= code_bits <= 32:
        raise ValueError(f"code_bits must be in [1, 32], got {code_bits}")
    return (k * code_bits + 31) // 32


def _code_geometry(k: int, code_bits: int):
    """Per-code (low word index, bit shift) for the bitstream layout: code
    j occupies bits [j*code_bits, (j+1)*code_bits) of the row's stream."""
    j = jnp.arange(k, dtype=jnp.uint32)
    bit0 = j * jnp.uint32(code_bits)
    return (bit0 >> 5).astype(jnp.int32), bit0 & jnp.uint32(31)


def pack_codes(values: jax.Array, code_bits: int) -> jax.Array:
    """Bitstream-pack (n, k) codes (< 2^code_bits) into uint32 words.

    Unlike ``pack_signatures`` this supports *any* ``code_bits`` in
    [1, 32] (codes may straddle word boundaries) and any k, so it can
    carry sentinel OPH signatures as (b+1)-bit codes and non-word-aligned
    k.  Output is (n, ceil(k*code_bits/32)) -- exactly k*code_bits bits
    per example, the paper's wire accounting.  Pure uint32 arithmetic
    (TPU-safe, no 64-bit intermediates); jit-compatible.
    """
    n, k = values.shape
    words = packed_words(k, code_bits)
    v = values.astype(jnp.uint32)
    if code_bits < 32:
        v = v & jnp.uint32((1 << code_bits) - 1)
    wlo, sh = _code_geometry(k, code_bits)
    lo = v << sh                                # uint32 wrap: high bits drop
    # v >> (32 - sh) without the undefined shift-by-32 at sh == 0: codes
    # are <= 32 bits wide so two single shifts compose exactly.
    hi = (v >> (jnp.uint32(31) - sh)) >> jnp.uint32(1)
    out = jnp.zeros((n, words), jnp.uint32)
    # contributions to one word occupy disjoint bit ranges, so add == or
    out = out.at[:, wlo].add(lo)
    out = out.at[:, jnp.minimum(wlo + 1, words - 1)].add(hi)
    return out


def unpack_codes(packed: jax.Array, code_bits: int, k: int) -> jax.Array:
    """Inverse of ``pack_codes``; returns (n, k) uint32 codes."""
    words = packed.shape[-1]
    if words < packed_words(k, code_bits):
        raise ValueError(
            f"packed has {words} words, need {packed_words(k, code_bits)} "
            f"for k={k}, code_bits={code_bits}")
    wlo, sh = _code_geometry(k, code_bits)
    lo = packed[:, wlo] >> sh
    hi = (packed[:, jnp.minimum(wlo + 1, words - 1)]
          << (jnp.uint32(31) - sh)) << jnp.uint32(1)
    out = lo | hi
    if code_bits < 32:
        out = out & jnp.uint32((1 << code_bits) - 1)
    return out


def storage_bits(k: int, b: int) -> int:
    """Per-example storage of the hashed representation: k*b bits."""
    return k * b


def vw_storage_bits(m_bins: int, bits_per_counter: int = 32) -> int:
    """Per-example storage for VW feature hashing with m bins (dense)."""
    return m_bins * bits_per_counter


def raw_storage_bits(avg_nnz: float, index_bits: int = 32) -> float:
    """Per-example storage of the original sparse binary data."""
    return avg_nnz * index_bits
