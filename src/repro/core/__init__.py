"""Core b-bit minwise hashing library (the paper's contribution).

Public API:
  hashing:   Hash2U, Hash4U, PermutationFamily, mod_mersenne31, umul32_wide
  minhash:   minhash_signatures, signature_matches
  oph:       OPH, oph_signatures, densify_rotation (one-permutation hashing:
             k bins from ONE hash pass, sentinel or rotation densification)
  bbit:      lowest_bits, expand_tokens, expand_onehot, pack/unpack, storage
  estimator: bbit_constants, estimate_resemblance, theoretical_variance
  vw:        VWHasher (feature-hashing baseline)
"""

from repro.core.hashing import (Hash2U, Hash4U, PermutationFamily, MERSENNE_P,
                                add64, family_storage_bytes, hash2u_apply,
                                hash4u_apply, mod_mersenne31,
                                mulmod_mersenne31, umul32_wide)
from repro.core.minhash import (minhash_signatures, resemblance,
                                signature_matches)
from repro.core.oph import (EMPTY, OPH, densify_fast, densify_optimal,
                            densify_rotation, hash_evaluations,
                            oph_match_fraction, oph_signatures)
from repro.core.bbit import (expand_onehot, expand_tokens, lowest_bits,
                             pack_signatures, raw_storage_bits, storage_bits,
                             unpack_signatures, vw_storage_bits)
from repro.core.estimator import (bbit_constants, collision_prob,
                                  empirical_p_hat, empirical_p_hat_oph,
                                  estimate_resemblance,
                                  estimate_resemblance_oph,
                                  theoretical_variance,
                                  theoretical_variance_minwise)
from repro.core.vw import VWHasher

__all__ = [
    "EMPTY", "OPH", "densify_fast", "densify_optimal", "densify_rotation",
    "hash_evaluations",
    "oph_match_fraction", "oph_signatures",
    "Hash2U", "Hash4U", "PermutationFamily", "MERSENNE_P", "add64",
    "family_storage_bytes", "hash2u_apply", "hash4u_apply", "mod_mersenne31",
    "mulmod_mersenne31", "umul32_wide", "minhash_signatures", "resemblance",
    "signature_matches", "expand_onehot", "expand_tokens", "lowest_bits",
    "pack_signatures", "raw_storage_bits", "storage_bits",
    "unpack_signatures", "vw_storage_bits", "bbit_constants",
    "collision_prob", "empirical_p_hat", "empirical_p_hat_oph",
    "estimate_resemblance", "estimate_resemblance_oph",
    "theoretical_variance", "theoretical_variance_minwise", "VWHasher",
]
