"""One Permutation Hashing (OPH): k-bin signatures from ONE hash pass.

The paper's §3 preprocessing evaluates k independent hash functions per
nonzero (k ~ 500).  One Permutation Hashing (Li, Owen, Zhang, NIPS 2012)
instead applies a *single* hash function h: [0, D) -> [0, D), splits the
hashed universe into k equal bins of width D/k, and keeps the minimum
in-bin offset per bin:

    bin(t)    = h(t) >> (s - log2 k)            (high bits)
    offset(t) = h(t) &  (D/k - 1)               (low bits)
    z_j       = min { offset(t) : t in S, bin(t) == j }

This is ~k x less hashing work for the same signature length.  Bins that
receive no element of S are *empty*; two strategies are implemented:

  * ``densify="sentinel"``: keep the 0xFFFFFFFF sentinel and use the
    Li-Owen-Zhang estimator  R^ = N_match / (k - N_jointly_empty) --
    unbiased, but signatures are not directly usable as fixed-length
    b-bit features,
  * ``densify="rotation"``: Shrivastava & Li (ICML 2014) densification --
    an empty bin borrows the value of the nearest non-empty bin to its
    right (circularly), shifted by ``distance * C`` with C = D/k + 1 so
    borrowed values never collide with genuine ones.  The densified
    signature behaves like a standard minhash signature (same-bin
    collision probability R), so the whole b-bit / learning stack applies
    unchanged.
  * ``densify="optimal"``: Shrivastava (ICML 2017) optimal densification
    -- each empty bin draws donor bins from its own 2-universal probe
    sequence (shared across sets, so matched empties stay comparable)
    until it hits a non-empty bin, and copies that bin's value.  Breaks
    the rotation scheme's donor correlation between neighbouring empty
    bins, reducing estimator variance; signatures remain minhash-like.
  * ``densify="fast"``: Mai et al. (UAI 2020) fast densification -- the
    probing direction is reversed: in each round every originally
    NON-empty bin hashes to one target bin (the probe sequence depends
    only on (bin, round, k), so it is shared across sets) and fills it
    if still empty; ties inside a round resolve to the lowest donor bin
    id.  Expected O(k log k) fill work versus the empty-bin-probing
    schemes' O(k^2 / m), with the same copied-value semantics.

The single hash function is any of the existing families from
``repro.core.hashing`` instantiated with ``k == 1`` (2U / 4U /
a true random permutation); ``family_storage_bytes`` then shows the
paper's Issue-3 win at its extreme: 8-16 bytes of coefficients total.

Paper mapping:
  * §3 (cost model): ``hash_evaluations`` -- k-pass minhash does
    ``n * nnz * k`` evaluations, OPH does ``n * nnz`` (ratio exactly k),
  * arXiv:1208.1259 (Li-Owen-Zhang) §3: ``oph_signatures`` (binned
    minima) and the unbiased ``oph_match_fraction`` estimator
    R^ = N_match / (k - N_jointly_empty),
  * Shrivastava-Li ICML 2014, Eq. (7)-(9): ``densify_rotation``
    (circular borrow, offset by distance * C so borrows never alias),
  * main paper Eq. (2): after rotation densification the same-bin
    collision probability is R, so §4-§6 (b-bit + learning) apply
    unchanged.

This module is the jnp reference; ``repro.kernels.oph`` holds the Pallas
TPU kernels validated bit-exactly against it.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.hashing import Hash2U, Hash4U, PermutationFamily

_U32 = jnp.uint32

# Sentinel for empty bins (and padded rows): larger than any in-bin offset,
# which is < D/k <= 2^31.
EMPTY = jnp.uint32(0xFFFFFFFF)

BaseFamily = Union[Hash2U, Hash4U, PermutationFamily]


@dataclasses.dataclass(frozen=True)
class OPH:
    """An OPH scheme: ONE base hash function + k bins + densification.

    ``base`` must hold exactly one hash function (``base.k == 1``) over a
    power-of-two universe D = 2^s with s <= 31; ``k`` (the number of bins
    == signature length) must be a power of two dividing D.
    """

    base: BaseFamily
    k: int                      # number of bins == signature length
    densify: str = "rotation"   # "rotation"|"sentinel"|"optimal"|"fast"

    def __post_init__(self):
        if self.base.k != 1:
            raise ValueError(f"OPH uses ONE hash function, got base.k={self.base.k}")
        s = self.s
        if s > 31:
            raise ValueError(f"OPH needs s <= 31 (rotation offsets overflow), got {s}")
        if self.k & (self.k - 1) or not (1 <= self.k <= (1 << s)):
            raise ValueError(f"k must be a power of two in [1, 2^{s}], got {self.k}")
        if self.densify not in ("rotation", "sentinel", "optimal", "fast"):
            raise ValueError("densify must be 'rotation', 'sentinel', "
                             f"'optimal' or 'fast', got {self.densify!r}")

    @property
    def s(self) -> int:
        if isinstance(self.base, PermutationFamily):
            D = self.base.D
            if D & (D - 1):
                raise ValueError(f"OPH over a permutation needs power-of-two D, got {D}")
            return D.bit_length() - 1
        return self.base.s

    @property
    def D(self) -> int:
        return 1 << self.s

    @property
    def bin_bits(self) -> int:
        return self.k.bit_length() - 1

    @property
    def bin_width(self) -> int:
        return 1 << (self.s - self.bin_bits)

    @staticmethod
    def create(key: jax.Array, k: int, s: int, family: str = "2u",
               densify: str = "rotation", **family_kwargs) -> "OPH":
        """Build an OPH scheme with a fresh single-function base family."""
        if family == "2u":
            base = Hash2U.create(key, 1, s, **family_kwargs)
        elif family == "4u":
            base = Hash4U.create(key, 1, s, **family_kwargs)
        elif family == "perm":
            base = PermutationFamily.create(key, 1, 1 << s)
        else:
            raise ValueError(f"family must be '2u', '4u' or 'perm', got {family!r}")
        return OPH(base=base, k=k, densify=densify)


def split_hash(h: jax.Array, s: int, bin_bits: int) -> Tuple[jax.Array, jax.Array]:
    """Split a hash value in [0, 2^s) into (bin id, in-bin offset)."""
    h = h.astype(_U32)
    off_bits = s - bin_bits
    bins = (h >> _U32(off_bits)) if bin_bits > 0 else jnp.zeros_like(h)
    offs = h & _U32((1 << off_bits) - 1)
    return bins, offs


def oph_signatures(indices: jax.Array, mask: jax.Array, oph: OPH,
                   b: int = 0) -> jax.Array:
    """Reference (jnp) OPH signatures for a padded sparse batch.

    Args:
      indices: (n, max_nnz) int32 feature ids in [0, D).
      mask:    (n, max_nnz) bool, True for real entries.
      oph:     the OPH scheme (base family, k bins, densification).
      b:       if > 0, keep only the lowest b bits of each (densified)
               value.  Under ``densify="sentinel"`` empty bins stay EMPTY
               so the estimator can still exclude them; under
               ``densify="rotation"`` the only possible EMPTYs are
               all-empty rows (empty input sets), which fold to the
               all-ones b-bit code -- the same defined value the k-pass
               minhash path assigns empty sets -- so signatures are
               always bit-packable.

    Returns:
      (n, k) uint32: in-bin minima (EMPTY where a bin got no element and
      ``densify="sentinel"``).
    """
    n = indices.shape[0]
    h = oph.base(indices)[..., 0]                     # ONE hash: (n, nnz)
    bins, offs = split_hash(h, oph.s, oph.bin_bits)
    offs = jnp.where(mask, offs, EMPTY)
    # segment-min per (row, bin) via scatter-min; masked lanes carry EMPTY
    # and bin 0, so they can never beat a genuine offset (offset < D/k).
    bins = jnp.where(mask, bins, 0).astype(jnp.int32)
    sig = jnp.full((n, oph.k), EMPTY).at[
        jnp.arange(n)[:, None], bins].min(offs)
    return densify_and_bbit(sig, oph.bin_width, oph.densify, b)


def densify_and_bbit(sig: jax.Array, bin_width: int, densify: str,
                     b: int) -> jax.Array:
    """Shared epilogue: densify sentinel-coded bin minima, extract b bits.

    This is THE semantics both the jnp reference above and the kernel
    path (``repro.kernels.engine``) apply after the raw binned minima, so
    the two stay bit-exact by construction.  Under ``sentinel`` the EMPTY
    marker survives the b-bit mask (the estimator / learning layer handle
    it); under ``rotation``/``optimal``/``fast`` every bin is defined except in
    all-empty rows, which fold to the all-ones b-bit code -- the same
    value the k-pass minhash path assigns empty sets.
    """
    if densify == "rotation":
        sig = densify_rotation(sig, bin_width)
    elif densify == "optimal":
        sig = densify_optimal(sig)
    elif densify == "fast":
        sig = densify_fast(sig)
    if b > 0:
        mask_b = _U32((1 << b) - 1)
        if densify in ("rotation", "optimal", "fast"):
            sig = sig & mask_b        # EMPTY (all-empty rows) -> 2^b - 1
        else:
            sig = jnp.where(sig != EMPTY, sig & mask_b, sig)
    return sig


def densify_rotation(sig: jax.Array, bin_width: int) -> jax.Array:
    """Shrivastava-Li rotation densification of sentinel-coded signatures.

    Each empty bin j takes the value of the nearest non-empty bin to its
    right (circularly), plus ``distance * C`` with C = bin_width + 1, so a
    borrowed value can never equal a genuine offset and two borrows
    collide iff they borrow the same value over the same distance --
    exactly the LSH-preserving scheme of the densification paper.

    Rows that are entirely empty (empty input sets) stay all-EMPTY.
    Vectorized O(k) per row: a reversed cummin gives every bin the index
    of its nearest non-empty successor; the circular wrap reuses the
    row-wide first non-empty index.
    """
    n, k = sig.shape
    nonempty = sig != EMPTY
    idx = jnp.arange(k, dtype=jnp.int32)
    # index of each non-empty bin, 2k for empty ones (any value > k works)
    cand = jnp.where(nonempty, idx, jnp.int32(2 * k))
    # nearest non-empty at position >= j (non-circular part)
    suffix = jax.lax.cummin(cand[:, ::-1], axis=1)[:, ::-1]
    first = jnp.min(cand, axis=1, keepdims=True)      # row's first non-empty
    donor_pos = jnp.where(suffix < 2 * k, suffix, first + k)   # circular
    dist = (donor_pos - idx).astype(_U32)
    donor = jnp.take_along_axis(sig, (donor_pos % k).astype(jnp.int32), axis=1)
    C = _U32(bin_width + 1)
    borrowed = donor + C * dist
    dense = jnp.where(nonempty, sig, borrowed)
    # all-empty rows: first == 2k, donor values are EMPTY-garbage -> keep EMPTY
    return jnp.where(first < 2 * k, dense, EMPTY)


def _optimal_probe(j: jax.Array, t: jax.Array, k: int) -> jax.Array:
    """Donor bin for (bin j, probe attempt t): a multiply-mix universal
    hash of the unique key t*k + j.  Depends only on (j, t, k) -- the same
    probe sequence for every set, as the optimal-densification estimator
    requires (matched empty bins must walk the same donors)."""
    x = t.astype(_U32) * _U32(k) + j.astype(_U32)
    h = x * _U32(2654435761) + _U32(0x9E3779B9)       # wraps mod 2^32
    h = h ^ (h >> _U32(16))
    return (h % _U32(k)).astype(jnp.int32)


def densify_optimal(sig: jax.Array, max_probes: int = 0) -> jax.Array:
    """Shrivastava (ICML 2017) optimal densification.

    Each empty bin j copies the value of the first NON-empty bin in its
    own probe sequence ``_optimal_probe(j, t)`` for t = 0, 1, ... --
    i.i.d. donor choices instead of the rotation scheme's shared
    nearest-right donor, which is what removes the correlated-borrow
    variance.  Rows that are entirely empty stay all-EMPTY.  Probing is a
    bounded ``while_loop`` (it exits as soon as every empty bin found a
    donor); the deterministic fallback after ``max_probes`` attempts --
    the row's first non-empty bin -- keeps the function total and
    identical between the reference and kernel epilogues.
    """
    n, k = sig.shape
    if max_probes <= 0:
        max_probes = 8 * k + 64
    nonempty = sig != EMPTY
    any_ne = jnp.any(nonempty, axis=1, keepdims=True)
    j = jnp.arange(k, dtype=jnp.int32)

    def cond(state):
        t, _, resolved = state
        return (t < max_probes) & ~jnp.all(resolved)

    def body(state):
        t, out, resolved = state
        donor = _optimal_probe(j, t, k)                            # (k,)
        donor_val = jnp.take(sig, donor, axis=1)                   # (n, k)
        donor_ok = jnp.take(nonempty, donor, axis=1)
        newly = ~resolved & donor_ok
        return t + 1, jnp.where(newly, donor_val, out), resolved | donor_ok

    init = (jnp.zeros((), jnp.int32), sig, nonempty | ~any_ne)
    _, out, resolved = jax.lax.while_loop(cond, body, init)
    # pathological unresolved bins: deterministic first-non-empty fallback
    cand = jnp.where(nonempty, j[None, :], jnp.int32(2 * k))
    first = jnp.min(cand, axis=1, keepdims=True)
    fallback = jnp.take_along_axis(sig, first % k, axis=1)
    return jnp.where(resolved, out, jnp.broadcast_to(fallback, out.shape))


def densify_fast(sig: jax.Array, max_rounds: int = 0) -> jax.Array:
    """Mai et al. (UAI 2020) fast densification: donors broadcast.

    The probing direction of ``densify_optimal`` reversed: on round t,
    every originally NON-empty bin j targets bin ``_optimal_probe(j, t)``
    (the same (j, t, k)-only probe hash, so the walk is shared across
    sets -- matched empty bins receive matched donors) and fills it if
    it is still empty.  Multiple donors landing on one empty bin in the
    same round resolve deterministically to the lowest donor bin id.
    Expected O(k log k) total fill work instead of the empty-bin-probing
    schemes' O(k^2 / m) when most bins are empty.

    Rows that are entirely empty stay all-EMPTY.  The bounded
    ``while_loop`` exits once every empty bin is filled; the
    deterministic fallback after ``max_rounds`` (the row's first
    non-empty bin) keeps the function total, mirroring
    ``densify_optimal``.
    """
    n, k = sig.shape
    if max_rounds <= 0:
        max_rounds = 8 * k + 64
    nonempty = sig != EMPTY
    any_ne = jnp.any(nonempty, axis=1, keepdims=True)
    j = jnp.arange(k, dtype=jnp.int32)

    def cond(state):
        t, _, filled = state
        return (t < max_rounds) & ~jnp.all(filled)

    def body(state):
        t, out, filled = state
        tgt = _optimal_probe(j, t, k)                              # (k,)
        # scatter-min of the donor bin id into its target: per row, the
        # winning donor for a bin is the lowest-id non-empty bin that
        # targeted it this round (2k = "no donor")
        donor_id = jnp.where(nonempty, j[None, :], jnp.int32(2 * k))
        donor_at = jnp.full((n, k), jnp.int32(2 * k)).at[
            :, tgt].min(donor_id)
        newly = ~filled & (donor_at < 2 * k)
        donor_val = jnp.take_along_axis(sig, donor_at % k, axis=1)
        return (t + 1, jnp.where(newly, donor_val, out),
                filled | (donor_at < 2 * k))

    init = (jnp.zeros((), jnp.int32), sig, nonempty | ~any_ne)
    _, out, filled = jax.lax.while_loop(cond, body, init)
    # pathological unfilled bins: deterministic first-non-empty fallback
    cand = jnp.where(nonempty, j[None, :], jnp.int32(2 * k))
    first = jnp.min(cand, axis=1, keepdims=True)
    fallback = jnp.take_along_axis(sig, first % k, axis=1)
    return jnp.where(filled, out, jnp.broadcast_to(fallback, out.shape))


# ---------------------------------------------------------------------------
# Estimators
# ---------------------------------------------------------------------------

def oph_match_fraction(sig1: jax.Array, sig2: jax.Array) -> jax.Array:
    """Li-Owen-Zhang estimator R^ = N_match / (k - N_jointly_empty).

    Works on sentinel-coded signatures; on densified signatures there are
    no EMPTY bins and this reduces to the plain Eq.(2) match fraction.
    """
    both_empty = (sig1 == EMPTY) & (sig2 == EMPTY)
    match = (sig1 == sig2) & ~both_empty
    n_match = jnp.sum(match.astype(jnp.float32), axis=-1)
    denom = sig1.shape[-1] - jnp.sum(both_empty.astype(jnp.float32), axis=-1)
    return n_match / jnp.maximum(denom, 1.0)


def hash_evaluations(n: int, avg_nnz: float, k: int, scheme: str) -> float:
    """Analytic hash-evaluation count of preprocessing (the §3 cost model).

    k-pass minwise hashing evaluates one of k functions per (set, nonzero)
    pair; OPH evaluates its single function once per nonzero regardless
    of k.  The ratio is exactly k -- the tentpole speedup this subsystem
    exists for.
    """
    if scheme == "minhash":
        return n * avg_nnz * k
    if scheme == "oph":
        return n * avg_nnz
    raise ValueError(f"scheme must be 'minhash' or 'oph', got {scheme!r}")
