"""Validate observability artifacts: Prometheus exposition + trace JSON.

    python tools/check_obs.py --prom PATH [--require NAME[,NAME...]]
    python tools/check_obs.py --trace PATH [--require-spans NAME[,...]]

CI runs this over the artifacts the serving benchmark writes
(``--prom-out`` / ``--trace-out``) so a malformed exposition or a
truncated trace fails the job instead of shipping as a green artifact.

Prometheus checks (text format 0.0.4):
  * every sample line parses (``name{labels} value`` with legal label
    syntax), every metric name matches ``[a-zA-Z_:][a-zA-Z0-9_:]*``,
  * ``# TYPE`` appears at most once per family, with a known type,
  * no duplicate series (same name + label set twice),
  * sample values parse as floats (NaN/+Inf/-Inf allowed),
  * ``--require`` names must be present as families.

Trace checks (Chrome trace-event JSON):
  * the document is ``{"traceEvents": [...]}`` with at least one event,
  * every event has name/ph/ts/pid/tid; "X" events also carry ``dur``,
  * async "b"/"e" pairs balance per (id, name),
  * span ids referenced as ``parent_id`` exist within the same trace
    tree (0 = root),
  * ``--require-spans`` names must appear.

Exit code 0 = valid, 1 = any check failed (every failure is printed).
"""

from __future__ import annotations

import argparse
import json
import math
import re
import sys

NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
LABEL_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')
SAMPLE_RE = re.compile(
    r"^([a-zA-Z_:][a-zA-Z0-9_:]*)(\{([^}]*)\})?\s+(\S+)(\s+\d+)?$")
KNOWN_TYPES = {"counter", "gauge", "summary", "histogram", "untyped"}
# suffixes Prometheus clients attach to a summary/histogram family
FAMILY_SUFFIXES = ("_sum", "_count", "_bucket")


def _family_of(sample_name: str, typed: dict) -> str:
    if sample_name in typed:
        return sample_name
    for suf in FAMILY_SUFFIXES:
        if sample_name.endswith(suf) and sample_name[:-len(suf)] in typed:
            return sample_name[:-len(suf)]
    return sample_name


def check_prom(path: str, require: list) -> list:
    errors = []
    typed = {}
    seen_series = set()
    families = set()
    with open(path) as f:
        lines = f.read().splitlines()
    if not lines:
        return [f"{path}: empty exposition"]
    for i, line in enumerate(lines, 1):
        if not line.strip():
            continue
        if line.startswith("# TYPE "):
            parts = line.split()
            if len(parts) != 4:
                errors.append(f"{path}:{i}: malformed TYPE line: {line!r}")
                continue
            _, _, name, mtype = parts
            if not NAME_RE.match(name):
                errors.append(f"{path}:{i}: illegal metric name {name!r}")
            if mtype not in KNOWN_TYPES:
                errors.append(f"{path}:{i}: unknown type {mtype!r}")
            if name in typed:
                errors.append(f"{path}:{i}: duplicate TYPE for {name!r}")
            typed[name] = mtype
            continue
        if line.startswith("#"):
            continue
        m = SAMPLE_RE.match(line)
        if not m:
            errors.append(f"{path}:{i}: unparsable sample line: {line!r}")
            continue
        name, _, labelstr, value = m.group(1), m.group(2), m.group(3), \
            m.group(4)
        if not NAME_RE.match(name):
            errors.append(f"{path}:{i}: illegal metric name {name!r}")
        labels = ()
        if labelstr:
            stripped = LABEL_RE.sub("", labelstr)
            if stripped.strip(", "):
                errors.append(f"{path}:{i}: malformed labels {labelstr!r}")
            labels = tuple(sorted(LABEL_RE.findall(labelstr)))
        series = (name, labels)
        if series in seen_series:
            errors.append(f"{path}:{i}: duplicate series {name}"
                          f"{dict(labels)}")
        seen_series.add(series)
        families.add(_family_of(name, typed))
        try:
            v = float(value)
            if not (math.isfinite(v) or math.isnan(v) or math.isinf(v)):
                raise ValueError
        except ValueError:
            errors.append(f"{path}:{i}: bad sample value {value!r}")
    for name in require:
        if name not in families:
            errors.append(f"{path}: required metric {name!r} missing")
    if not errors:
        print(f"{path}: OK ({len(seen_series)} series, "
              f"{len(families)} families)")
    return errors


def check_trace(path: str, require_spans: list) -> list:
    errors = []
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: not valid JSON: {e}"]
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        return [f"{path}: no traceEvents array (or empty)"]
    async_depth = {}
    names = set()
    span_ids = set()
    parents = []           # (trace_id, parent_id) refs to re-check
    for j, ev in enumerate(events):
        missing = {"name", "ph", "ts", "pid", "tid"} - set(ev)
        if missing:
            errors.append(f"{path}[{j}]: missing fields {sorted(missing)}")
            continue
        names.add(ev["name"])
        ph = ev["ph"]
        if ph == "X" and "dur" not in ev:
            errors.append(f"{path}[{j}]: X event without dur")
        if ph in ("b", "e"):
            key = (ev.get("id"), ev["name"])
            async_depth[key] = async_depth.get(key, 0) + (1 if ph == "b"
                                                          else -1)
            if async_depth[key] < 0:
                errors.append(f"{path}[{j}]: 'e' before 'b' for {key}")
        args = ev.get("args") or {}
        if "span_id" in args:
            span_ids.add(args["span_id"])
            if args.get("parent_id", 0):
                parents.append((j, args["parent_id"]))
    for key, depth in async_depth.items():
        if depth != 0:
            errors.append(f"{path}: unbalanced async pair {key} "
                          f"(depth {depth})")
    for j, pid in parents:
        if pid not in span_ids:
            errors.append(f"{path}[{j}]: parent_id {pid} references no "
                          f"recorded span")
    for name in require_spans:
        if name not in names:
            errors.append(f"{path}: required span {name!r} missing")
    if not errors:
        print(f"{path}: OK ({len(events)} events, {len(names)} span names)")
    return errors


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--prom", default=None,
                    help="Prometheus text exposition to validate")
    ap.add_argument("--require", default="",
                    help="comma-separated metric families that must be "
                         "present in --prom")
    ap.add_argument("--trace", default=None,
                    help="Chrome trace-event JSON to validate")
    ap.add_argument("--require-spans", default="",
                    help="comma-separated span names that must appear "
                         "in --trace")
    args = ap.parse_args()
    if not args.prom and not args.trace:
        ap.error("nothing to check: pass --prom and/or --trace")
    errors = []
    if args.prom:
        errors += check_prom(
            args.prom, [t for t in args.require.split(",") if t])
    if args.trace:
        errors += check_trace(
            args.trace, [t for t in args.require_spans.split(",") if t])
    for e in errors:
        print(f"ERROR: {e}", file=sys.stderr)
    sys.exit(1 if errors else 0)


if __name__ == "__main__":
    main()
