"""Check that all relative markdown links in README.md and docs/ resolve.

Usage:  python tools/check_links.py [files...]
No dependencies; exits 1 listing any link whose target does not exist.
External links (http/https/mailto) and pure in-page anchors are skipped.
"""

from __future__ import annotations

import glob
import os
import re
import sys

# [text](target) -- target captured up to the closing paren (no nesting)
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE_RE = re.compile(r"^```.*?^```", re.MULTILINE | re.DOTALL)


def check_file(path: str) -> list[str]:
    errors = []
    with open(path) as f:
        text = FENCE_RE.sub("", f.read())   # link syntax in code blocks
                                            # is illustrative, not a link
    base = os.path.dirname(os.path.abspath(path))
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]        # drop in-page anchor
        if not rel:
            continue
        if not os.path.exists(os.path.join(base, rel)):
            errors.append(f"{path}: broken link -> {target}")
    return errors


def main(argv: list[str]) -> int:
    files = argv or sorted({"README.md", *glob.glob("docs/*.md")})
    all_errors = []
    for path in files:
        all_errors.extend(check_file(path))
    for err in all_errors:
        print(err, file=sys.stderr)
    print(f"checked {len(files)} files: "
          f"{'OK' if not all_errors else f'{len(all_errors)} broken links'}")
    return 1 if all_errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
