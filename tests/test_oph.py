"""One Permutation Hashing: kernel parity, estimator statistics, invariants.

Three layers, mirroring what the subsystem promises:

  * Pallas-kernel-vs-jnp-reference bit-exactness across the full
    (b, family, densification, k) grid (interpret mode),
  * statistical tests that OPH resemblance estimates are unbiased within
    tolerance on synthetic pairs of known Jaccard similarity,
  * seeded property-style tests (numpy RNG + parametrize, no hypothesis)
    for the bin-split and densification invariants, checked against
    brute-force python references.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.estimator import estimate_resemblance_oph
from repro.core.hashing import Hash2U, Hash4U, PermutationFamily, \
    family_storage_bytes
from repro.core.oph import (EMPTY, OPH, densify_fast, densify_optimal,
                            densify_rotation, hash_evaluations,
                            oph_match_fraction, oph_signatures, split_hash)
from repro.data import word_pair_sets
from repro.data.sparse import from_lists
from repro.kernels import batch_signatures, oph2u, oph4u

RNG = np.random.default_rng(11)
_E = np.uint32(0xFFFFFFFF)


def _random_batch(n, max_set, s, seed, max_nnz=256):
    """Fixed max_nnz so every case shares one padded shape (jit cache)."""
    rng = np.random.default_rng(seed)
    sets = [rng.choice(1 << s, rng.integers(1, max_set + 1), replace=False)
            for _ in range(n)]
    return from_lists(sets, max_nnz=max_nnz)


@pytest.fixture(scope="module")
def batch16():
    return _random_batch(5, 250, 16, seed=101)


@pytest.fixture(scope="module")
def batch18():
    return _random_batch(3, 137, 18, seed=77)


# ---------------------------------------------------------------------------
# Kernel vs jnp reference: bit-exact across the acceptance grid
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b", [1, 2, 4, 8])
@pytest.mark.parametrize("densify", ["sentinel", "rotation"])
@pytest.mark.parametrize("family", ["2u", "4u"])
def test_oph_kernel_bit_exact(b, densify, family, batch16):
    s, k = 16, 128
    batch = batch16
    oph = OPH.create(jax.random.PRNGKey(b), k, s, family, densify)
    want = oph_signatures(batch.indices, batch.mask, oph, b=b)
    got = batch_signatures(batch, oph, b=b)
    assert got.dtype == jnp.uint32
    assert np.array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("k,family", [
    (16, "2u"), (64, "4u"), (512, "2u"),
    pytest.param(64, "2u", marks=pytest.mark.slow),
    pytest.param(128, "4u", marks=pytest.mark.slow),
    pytest.param(512, "4u", marks=pytest.mark.slow),
])
def test_oph_kernel_bit_exact_k_sweep(k, family, batch18):
    """k below / at / above the lane block; odd nnz counts per row."""
    s = 18
    batch = batch18
    oph = OPH.create(jax.random.PRNGKey(k), k, s, family, "rotation")
    want = oph_signatures(batch.indices, batch.mask, oph, b=0)
    got = batch_signatures(batch, oph, b=0)
    assert got.shape == (3, k)
    assert np.array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("family,b", [
    ("2u", 0), ("2u", 4),
    pytest.param("4u", 8, marks=pytest.mark.slow),
    pytest.param("4u", 1, marks=pytest.mark.slow),
])
def test_oph_optimal_densify_kernel_parity(family, b, batch16):
    """Shrivastava-2017 optimal densification: engine epilogue == reference."""
    s, k = 16, 128
    oph = OPH.create(jax.random.PRNGKey(b + 17), k, s, family, "optimal")
    want = oph_signatures(batch16.indices, batch16.mask, oph, b=b)
    got = batch_signatures(batch16, oph, b=b)
    assert np.array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("family,b", [
    ("2u", 0), ("2u", 8),
    pytest.param("4u", 4, marks=pytest.mark.slow),
    pytest.param("4u", 1, marks=pytest.mark.slow),
])
def test_oph_fast_densify_kernel_parity(family, b, batch16):
    """Mai-et-al fast densification: engine epilogue == reference."""
    s, k = 16, 128
    oph = OPH.create(jax.random.PRNGKey(b + 29), k, s, family, "fast")
    want = oph_signatures(batch16.indices, batch16.mask, oph, b=b)
    got = batch_signatures(batch16, oph, b=b)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_densify_fast_properties():
    """Genuine bins untouched; empty bins copy a genuine same-row donor;
    all-empty rows stay EMPTY; matched holes receive matched donors
    (the probe walk depends only on (bin, round, k))."""
    s, k = 12, 64
    oph = OPH.create(jax.random.PRNGKey(5), k, s, "2u", "sentinel")
    batch = _random_batch(6, 40, s, seed=9)      # sparse: many empty bins
    sent = np.asarray(oph_signatures(batch.indices, batch.mask, oph))
    dense = np.asarray(densify_fast(jnp.asarray(sent)))
    holes = sent == _E
    assert holes.any() and not (dense == _E).any()
    assert np.array_equal(dense[~holes], sent[~holes])
    for i in range(sent.shape[0]):
        genuine = set(sent[i][~holes[i]].tolist())
        assert all(v in genuine for v in dense[i][holes[i]].tolist())
    all_empty = np.full((2, k), _E, np.uint32)
    assert (np.asarray(densify_fast(jnp.asarray(all_empty))) == _E).all()
    # two rows with identical occupancy patterns walk identical donors
    row = sent[0:1]
    twin = np.concatenate([row, row])
    out = np.asarray(densify_fast(jnp.asarray(twin)))
    assert np.array_equal(out[0], out[1])


def test_densify_optimal_properties():
    """Genuine bins untouched; empty bins copy a genuine same-row donor;
    all-empty rows stay EMPTY."""
    s, k = 12, 64
    oph = OPH.create(jax.random.PRNGKey(5), k, s, "2u", "sentinel")
    batch = _random_batch(6, 40, s, seed=9)      # sparse: many empty bins
    sent = np.asarray(oph_signatures(batch.indices, batch.mask, oph))
    dense = np.asarray(densify_optimal(jnp.asarray(sent)))
    holes = sent == _E
    assert holes.any() and not (dense == _E).any()
    assert np.array_equal(dense[~holes], sent[~holes])
    for i in range(sent.shape[0]):
        genuine = set(sent[i][~holes[i]].tolist())
        assert all(v in genuine for v in dense[i][holes[i]].tolist())
    all_empty = np.full((2, k), _E, np.uint32)
    assert (np.asarray(densify_optimal(jnp.asarray(all_empty))) == _E).all()


def test_oph_kernel_multi_lane_block(batch18):
    """k spanning several BLK_K blocks (forces the j-grid loop)."""
    s, k = 18, 512
    batch = batch18
    oph = OPH.create(jax.random.PRNGKey(7), k, s, "2u", "sentinel")
    counts = jnp.sum(batch.mask.astype(jnp.int32), axis=1)
    got = oph2u(batch.indices, counts, oph.base.a1, oph.base.a2, s=s, k=k,
                densify="sentinel", blk_k=128)
    want = oph_signatures(batch.indices, batch.mask, oph)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_oph_pallas_matches_ref_path(batch16):
    """use_pallas=True == use_pallas=False (the kernels/ref.py oracle)."""
    s, k = 16, 256
    batch = batch16
    counts = jnp.sum(batch.mask.astype(jnp.int32), axis=1)
    o2 = OPH.create(jax.random.PRNGKey(1), k, s, "2u", "sentinel")
    a = oph2u(batch.indices, counts, o2.base.a1, o2.base.a2, s=s, k=k,
              densify="sentinel", use_pallas=True)
    b = oph2u(batch.indices, counts, o2.base.a1, o2.base.a2, s=s, k=k,
              densify="sentinel", use_pallas=False)
    assert np.array_equal(np.asarray(a), np.asarray(b))
    o4 = OPH.create(jax.random.PRNGKey(2), k, s, "4u", "rotation")
    a = oph4u(batch.indices, counts, o4.base.a, s=s, k=k, b=4,
              use_pallas=True)
    b = oph4u(batch.indices, counts, o4.base.a, s=s, k=k, b=4,
              use_pallas=False)
    assert np.array_equal(np.asarray(a), np.asarray(b))


def test_oph_padding_invariance():
    """Extra padding lanes must not change OPH signatures."""
    s = 16
    s1, _ = word_pair_sets(1 << s, 400, 400, 0.5, seed=3)
    oph = OPH.create(jax.random.PRNGKey(0), 128, s, "2u", "rotation")
    small = from_lists([s1], lane_multiple=128)
    big = from_lists([s1], max_nnz=2048, lane_multiple=128)
    sig_small = batch_signatures(small, oph)
    sig_big = batch_signatures(big, oph)
    assert np.array_equal(np.asarray(sig_small), np.asarray(sig_big))


# ---------------------------------------------------------------------------
# Brute-force semantic references (seeded property-style)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed,family", [
    (0, "2u"), (1, "4u"), (2, "perm"),
    pytest.param(1, "2u", marks=pytest.mark.slow),
    pytest.param(2, "4u", marks=pytest.mark.slow),
    pytest.param(0, "perm", marks=pytest.mark.slow),
])
def test_oph_sentinel_matches_bruteforce(seed, family):
    """Sentinel signatures == per-bin minima computed by a python loop."""
    s, k = 10, 16
    oph = OPH.create(jax.random.PRNGKey(seed), k, s, family, "sentinel")
    rng = np.random.default_rng(seed)
    sets = [rng.choice(1 << s, rng.integers(1, 60), replace=False)
            for _ in range(3)]
    batch = from_lists(sets, lane_multiple=8)
    got = np.asarray(oph_signatures(batch.indices, batch.mask, oph))
    h_all = np.asarray(oph.base(batch.indices))[..., 0]
    bw = oph.bin_width
    for i, st in enumerate(sets):
        want = np.full(k, _E, np.uint32)
        for j, t in enumerate(st):
            h = int(h_all[i, j])
            bin_id, off = h // bw, h % bw
            want[bin_id] = min(want[bin_id], np.uint32(off))
        assert np.array_equal(got[i], want), (i, family)


@pytest.mark.parametrize("seed,k", [
    (0, 8), (1, 32), (2, 128),
    pytest.param(3, 8, marks=pytest.mark.slow),
    pytest.param(4, 32, marks=pytest.mark.slow),
    pytest.param(3, 128, marks=pytest.mark.slow),
])
def test_densify_rotation_matches_bruteforce(seed, k):
    """Rotation == nearest-right-donor python loop on random holes."""
    rng = np.random.default_rng(seed)
    bin_width = 1 << 10
    n = 4
    sig = rng.integers(0, bin_width, (n, k)).astype(np.uint32)
    holes = rng.random((n, k)) < rng.uniform(0.1, 0.9)
    sig[holes] = _E
    sig[2, :] = _E                         # one all-empty row
    got = np.asarray(densify_rotation(jnp.asarray(sig), bin_width))
    C = bin_width + 1
    for i in range(n):
        if (sig[i] == _E).all():
            assert (got[i] == _E).all()
            continue
        for j in range(k):
            if sig[i, j] != _E:
                assert got[i, j] == sig[i, j]
                continue
            d = next(t for t in range(1, k + 1) if sig[i, (j + t) % k] != _E)
            want = np.uint32(int(sig[i, (j + d) % k]) + C * d)
            assert got[i, j] == want, (i, j)


def test_rotation_borrows_never_collide_with_genuine():
    """Borrowed values live above bin_width, so a borrowed bin can only
    match another bin that borrowed the same value over the same distance
    -- the densification paper's collision-preserving property."""
    s, k = 12, 64
    oph = OPH.create(jax.random.PRNGKey(5), k, s, "2u", "sentinel")
    batch = _random_batch(6, 40, s, seed=9)      # sparse: many empty bins
    sent = oph_signatures(batch.indices, batch.mask, oph)
    dense = densify_rotation(sent, oph.bin_width)
    borrowed = (np.asarray(sent) == _E) & (np.asarray(dense) != _E)
    assert borrowed.any()                        # the test is non-vacuous
    assert (np.asarray(dense)[borrowed] >= oph.bin_width).all()
    genuine = np.asarray(sent) != _E
    assert (np.asarray(dense)[genuine] < oph.bin_width).all()


def test_oph_split_hash_partition():
    """(bin << off_bits) | offset reconstructs the hash: a true partition."""
    s, k = 16, 32
    h = jnp.asarray(RNG.integers(0, 1 << s, 500), jnp.uint32)
    bins, offs = split_hash(h, s, 5)
    assert int(jnp.max(bins)) < k
    assert int(jnp.max(offs)) < (1 << (s - 5))
    recon = (bins.astype(jnp.uint32) << (s - 5)) | offs
    assert np.array_equal(np.asarray(recon), np.asarray(h))


def test_oph_bbit_preserves_sentinel():
    s, k, b = 14, 64, 4
    oph = OPH.create(jax.random.PRNGKey(1), k, s, "2u", "sentinel")
    batch = _random_batch(4, 30, s, seed=2)      # sparse -> empty bins
    sig = np.asarray(oph_signatures(batch.indices, batch.mask, oph, b=b))
    assert (sig == _E).any()
    nonempty = sig != _E
    assert sig[nonempty].max() < (1 << b)


def test_oph_empty_set_stays_empty():
    oph = OPH.create(jax.random.PRNGKey(0), 32, 12, "2u", "rotation")
    batch = from_lists([np.array([], np.int64)], lane_multiple=8)
    sig = oph_signatures(batch.indices, batch.mask, oph)
    assert (np.asarray(sig) == _E).all()
    # with b > 0 the rotation path folds EMPTY to the all-ones code (the
    # minhash path's empty-set value), so bit-packing never sees EMPTY
    sig_b = oph_signatures(batch.indices, batch.mask, oph, b=4)
    assert (np.asarray(sig_b) == 15).all()
    got = batch_signatures(batch, oph, b=4)
    assert (np.asarray(got) == 15).all()


def test_oph_create_validation():
    key = jax.random.PRNGKey(0)
    with pytest.raises(ValueError):
        OPH.create(key, 48, 16)                  # k not a power of two
    with pytest.raises(ValueError):
        OPH.create(key, 1 << 17, 16)             # k > D
    with pytest.raises(ValueError):
        OPH(base=Hash2U.create(key, 4, 16), k=16)   # base.k != 1
    with pytest.raises(ValueError):
        OPH.create(key, 16, 16, densify="bogus")
    assert OPH.create(key, 16, 16, densify="fast").densify == "fast"


def test_oph_storage_and_cost_accounting():
    """Issue 3 taken to its extreme: ONE function's coefficients, and the
    analytic hash-evaluation model shows exactly the k x reduction."""
    oph2 = OPH.create(jax.random.PRNGKey(0), 512, 16, "2u")
    oph4 = OPH.create(jax.random.PRNGKey(0), 512, 16, "4u")
    assert family_storage_bytes(oph2) == 2 * 4
    assert family_storage_bytes(oph4) == 4 * 4
    assert family_storage_bytes(Hash2U.create(jax.random.PRNGKey(0), 512, 16)) \
        == 512 * family_storage_bytes(oph2)
    k = 512
    ratio = (hash_evaluations(100, 256, k, "minhash")
             / hash_evaluations(100, 256, k, "oph"))
    assert ratio == k


# ---------------------------------------------------------------------------
# Statistical correctness: unbiased resemblance estimates
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("densify,R", [
    ("sentinel", 0.2), ("rotation", 0.7), ("optimal", 0.2), ("fast", 0.7),
    pytest.param("sentinel", 0.7, marks=pytest.mark.slow),
    pytest.param("rotation", 0.2, marks=pytest.mark.slow),
    pytest.param("optimal", 0.7, marks=pytest.mark.slow),
    pytest.param("fast", 0.2, marks=pytest.mark.slow),
])
def test_oph_estimator_unbiased(densify, R):
    """Mean OPH estimate over seeds within 4 s.e. of the true Jaccard.

    One jit of the whole per-seed pipeline (fresh single hash function ->
    bins -> densify -> estimate) keeps 24 replications cheap.
    """
    s, k, n_seeds = 14, 256, 24
    s1, s2 = word_pair_sets(1 << s, 500, 550, R, seed=17)
    true_r = len(np.intersect1d(s1, s2)) / len(np.union1d(s1, s2))
    batch = from_lists([s1, s2])

    @jax.jit
    def one_seed(key):
        oph = OPH.create(key, k, s, "2u", densify)
        sig = oph_signatures(batch.indices, batch.mask, oph)
        return oph_match_fraction(sig[0], sig[1])

    ests = [float(one_seed(jax.random.PRNGKey(seed)))
            for seed in range(n_seeds)]
    se = np.sqrt(true_r * (1 - true_r) / (k * n_seeds))
    assert abs(np.mean(ests) - true_r) < 4 * se + 0.015, \
        (np.mean(ests), true_r)


@pytest.mark.slow
def test_oph_matches_minwise_estimates():
    """OPH and k-pass minwise hashing agree at the estimator level."""
    from repro.core import Hash2U as H2, minhash_signatures, signature_matches
    s, k = 14, 512
    s1, s2 = word_pair_sets(1 << s, 600, 620, 0.8, seed=23)
    batch = from_lists([s1, s2])
    fam = H2.create(jax.random.PRNGKey(1), k, s)
    sig_mh = minhash_signatures(batch.indices, batch.mask, fam)
    r_mh = float(signature_matches(sig_mh[0], sig_mh[1]))
    oph = OPH.create(jax.random.PRNGKey(2), k, s, "2u", "rotation")
    sig_oph = oph_signatures(batch.indices, batch.mask, oph)
    r_oph = float(oph_match_fraction(sig_oph[0], sig_oph[1]))
    assert abs(r_mh - r_oph) < 0.08, (r_mh, r_oph)


def test_oph_bbit_theorem1_estimate():
    """b-bit OPH signatures + Theorem-1 debiasing recover R."""
    s, b, k = 14, 4, 512
    D = 1 << s
    s1, s2 = word_pair_sets(D, 500, 520, 0.6, seed=31)
    true_r = len(np.intersect1d(s1, s2)) / len(np.union1d(s1, s2))
    batch = from_lists([s1, s2])

    @jax.jit
    def one_seed(key):
        oph = OPH.create(key, k, s, "2u", "sentinel")
        sig = oph_signatures(batch.indices, batch.mask, oph, b=b)
        return estimate_resemblance_oph(sig[0], sig[1], len(s1), len(s2),
                                        D, b)

    ests = [float(one_seed(jax.random.PRNGKey(seed))) for seed in range(8)]
    assert abs(np.mean(ests) - true_r) < 0.05, (np.mean(ests), true_r)


@pytest.mark.slow
def test_oph_identical_and_disjoint_sets():
    s, k = 14, 128
    rng = np.random.default_rng(0)
    univ = rng.choice(1 << s, 800, replace=False)
    a, bdis = univ[:400], univ[400:]
    batch = from_lists([a, a, bdis])
    oph = OPH.create(jax.random.PRNGKey(0), k, s, "4u", "rotation")
    sig = oph_signatures(batch.indices, batch.mask, oph)
    assert float(oph_match_fraction(sig[0], sig[1])) == 1.0
    assert float(oph_match_fraction(sig[0], sig[2])) < 0.1


# ---------------------------------------------------------------------------
# Pipeline integration
# ---------------------------------------------------------------------------

def test_oph_preprocess_shards_roundtrip(tmp_path):
    from repro.core.bbit import unpack_signatures
    from repro.data.pipeline import make_sharded_dataset
    from repro.data.preprocess import preprocess_shards, read_signature_shard
    from repro.data.synthetic import DatasetSpec
    spec = DatasetSpec("ophpre", n=96, D=2**14, avg_nnz=40, n_prototypes=2,
                       overlap=0.5, seed=0)
    paths = make_sharded_dataset(spec, str(tmp_path / "raw"), n_shards=2)
    from repro.data.pipeline import read_shard_binary
    n_total = sum(len(read_shard_binary(p)[1]) for p in paths)
    oph = OPH.create(jax.random.PRNGKey(0), 128, 14, "2u", "rotation")
    stats = preprocess_shards(paths, str(tmp_path / "sig"), oph, b=8,
                              chunk_size=64,
                              loader_kwargs={"lane_multiple": 8})
    assert stats.examples == n_total >= 64
    packed, labels, k, b = read_signature_shard(
        str(tmp_path / "sig" / "sig_00000.sig"))
    assert (k, b) == (128, 8)
    sig = np.asarray(unpack_signatures(jnp.asarray(packed), b, k))
    assert sig.shape == (64, 128) and sig.max() < 256

    # sentinel OPH now packs too: (b+1)-bit codes, EMPTY stored as 2^b
    from repro.data.sigshard import read_sig_shard
    sent = OPH.create(jax.random.PRNGKey(0), 128, 14, "2u", "sentinel")
    preprocess_shards(paths, str(tmp_path / "sig_sent"), sent, b=8,
                      chunk_size=64, loader_kwargs={"lane_multiple": 8})
    words, _, meta = read_sig_shard(str(tmp_path / "sig_sent" /
                                        "sig_00000.sig"))
    assert meta.sentinel and meta.code_bits == 9
    assert meta.words == (128 * 9 + 31) // 32          # k*(b+1) bits/example
    from repro.core.bbit import unpack_codes
    codes = np.asarray(unpack_codes(jnp.asarray(words), 9, 128))
    assert codes.max() <= 256                          # values + EMPTY code
    with pytest.raises(ValueError):                    # legacy 4-tuple reader
        read_signature_shard(str(tmp_path / "sig_sent" /  # refuses (b+1)-bit
                                 "sig_00000.sig"))        # codes

    with pytest.raises(TypeError):
        preprocess_shards(paths, str(tmp_path / "bad2"),
                          OPH.create(jax.random.PRNGKey(0), 32, 10, "perm"))


def test_oph_signature_stream(tmp_path):
    from repro.data.pipeline import SignatureStream, make_sharded_dataset
    from repro.data.synthetic import DatasetSpec
    spec = DatasetSpec("ophstream", n=64, D=2**12, avg_nnz=30,
                       n_prototypes=2, overlap=0.5, seed=1)
    paths = make_sharded_dataset(spec, str(tmp_path / "raw"), n_shards=2)
    from repro.data.pipeline import read_shard_binary
    n_total = sum(len(read_shard_binary(p)[1]) for p in paths)
    oph = OPH.create(jax.random.PRNGKey(0), 64, 12, "2u", "rotation")
    stream = SignatureStream(paths, oph, b=4, chunk_size=32,
                             loader_kwargs={"lane_multiple": 8})
    chunks = list(stream)
    assert stream.examples == n_total > 0
    assert sum(sig.shape[0] for sig, _ in chunks) == n_total
    assert all(sig.shape[1] == 64 for sig, _ in chunks)
    assert all(int(jnp.max(sig)) < 16 for sig, _ in chunks)
    assert stream.kernel_seconds > 0
