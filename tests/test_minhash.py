"""Minhash signature semantics: collision probability == resemblance."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Hash2U, Hash4U, PermutationFamily,
                        minhash_signatures, signature_matches)
from repro.data import word_pair_sets
from repro.data.sparse import from_lists


# perm-family cases materialize k full permutations (the paper's Issue 3)
# and cost ~15s each; they run under -m slow, the 2U/4U cases stay fast.
_COLLISION_CASES = [
    pytest.param(f, R, marks=[pytest.mark.slow] if f == "perm" else [])
    for f in ("perm", "2u", "4u") for R in (0.2, 0.7, 0.9)]


@pytest.mark.parametrize("family_kind,R", _COLLISION_CASES)
def test_collision_probability_estimates_resemblance(family_kind, R):
    D, k = 2**16, 1024
    s1, s2 = word_pair_sets(D, 800, 900, R, seed=42)
    batch = from_lists([s1, s2])
    key = jax.random.PRNGKey(3)
    if family_kind == "perm":
        fam = PermutationFamily.create(key, 256, D)
    elif family_kind == "2u":
        fam = Hash2U.create(key, k, 16)
    else:
        fam = Hash4U.create(key, k, 16)
    sig = minhash_signatures(batch.indices, batch.mask, fam)
    r_hat = float(signature_matches(sig[0], sig[1]))
    true_r = len(np.intersect1d(s1, s2)) / len(np.union1d(s1, s2))
    k_eff = fam.k
    tol = 4.0 * np.sqrt(true_r * (1 - true_r) / k_eff) + 0.02
    assert abs(r_hat - true_r) < tol, (r_hat, true_r, tol)


def test_padding_invariance():
    """Extra padding lanes must not change signatures."""
    D = 2**16
    s1, _ = word_pair_sets(D, 500, 500, 0.5)
    fam = Hash2U.create(jax.random.PRNGKey(0), 64, 16)
    b_small = from_lists([s1], lane_multiple=128)
    b_big = from_lists([s1], max_nnz=2048, lane_multiple=128)
    sig_small = minhash_signatures(b_small.indices, b_small.mask, fam)
    sig_big = minhash_signatures(b_big.indices, b_big.mask, fam)
    assert np.array_equal(np.asarray(sig_small), np.asarray(sig_big))


def test_chunked_scan_matches_direct():
    """chunk_k blocking must not change results."""
    D = 2**18
    s1, s2 = word_pair_sets(D, 300, 400, 0.3, seed=5)
    batch = from_lists([s1, s2])
    fam = Hash2U.create(jax.random.PRNGKey(1), 96, 18)
    a = minhash_signatures(batch.indices, batch.mask, fam, chunk_k=8)
    b = minhash_signatures(batch.indices, batch.mask, fam, chunk_k=96)
    assert np.array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
def test_2u_and_4u_agree_statistically():
    """The paper's §4 claim at estimator level: 2U ~ 4U ~ random.

    Slow tier (creates k full permutations); the per-family collision
    tests above keep estimator-level coverage in the fast tier.
    """
    _check_families_agree(D=2**14, s=14, k=128, tol=0.10)


@pytest.mark.slow
def test_2u_and_4u_agree_statistically_full():
    _check_families_agree(D=2**16, s=16, k=512, tol=0.06)


def _check_families_agree(D, s, k, tol):
    s1, s2 = word_pair_sets(D, 948, 940, 0.925, seed=7)  # KONG-HONG
    batch = from_lists([s1, s2])
    ests = {}
    for name, fam in [
        ("2u", Hash2U.create(jax.random.PRNGKey(11), k, s)),
        ("4u", Hash4U.create(jax.random.PRNGKey(12), k, s)),
        ("perm", PermutationFamily.create(jax.random.PRNGKey(13), k, D)),
    ]:
        sig = minhash_signatures(batch.indices, batch.mask, fam)
        ests[name] = float(signature_matches(sig[0], sig[1]))
    for a in ests.values():
        for b in ests.values():
            assert abs(a - b) < tol, ests
