"""repro.sharding.rules: the logical-axis constraint helper and the
retrieval mesh's shard placement rule."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import make_debug_mesh
from repro.sharding.rules import (constrain, current_mesh, data_axis_devices,
                                  place_shards, set_mesh)


def test_constrain_is_noop_without_mesh():
    x = jnp.arange(16.0).reshape(4, 4)
    assert current_mesh() is None
    y = constrain(x, "batch", "model")
    assert y is x                                 # literally untouched


def test_constrain_applies_named_sharding_under_set_mesh():
    n = min(2, len(jax.devices()))
    mesh = make_debug_mesh(n, axes=("data", "model"),
                           shape=(n, 1))
    x = jnp.arange(4.0 * n * 3).reshape(2 * n, 6)

    @jax.jit
    def f(x):
        return constrain(x, "batch", "model") * 2.0

    with set_mesh(mesh):
        out = f(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x) * 2.0)
    # the constraint actually shaped the output sharding: rows split
    # over the data axis
    assert out.sharding.is_equivalent_to(
        NamedSharding(mesh, P(("data",), None)), out.ndim)


def test_constrain_drops_non_divisible_axes():
    n = min(2, len(jax.devices()))
    mesh = make_debug_mesh(n, axes=("data", "model"), shape=(n, 1))
    x = jnp.arange(float(3 * n + 1)).reshape(3 * n + 1, 1)  # indivisible
    with set_mesh(mesh):
        y = constrain(x, "batch", None)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


def test_data_axis_devices_orders_and_validates():
    devs = jax.devices()
    n = len(devs)
    mesh = make_debug_mesh(n, axes=("data",))
    assert data_axis_devices(mesh) == tuple(devs[:n])
    # multi-axis mesh: one representative device per data rank
    if n >= 2:
        half = n // 2
        mesh2 = make_debug_mesh(2 * half, axes=("data", "model"),
                                shape=(half, 2))
        picked = data_axis_devices(mesh2)
        assert len(picked) == half
        assert picked == tuple(np.asarray(mesh2.devices)[:, 0])
    with pytest.raises(ValueError, match="no 'data' axis"):
        data_axis_devices(make_debug_mesh(1, axes=("model",)))


def test_place_shards_round_robin_and_tail_stable():
    devs = jax.devices()
    D = len(devs)
    mesh = make_debug_mesh(D, axes=("data",))
    for s_count in (1, D, D + 3, 3 * D):
        placed = place_shards(s_count, mesh)
        assert len(placed) == s_count
        assert all(placed[s] == devs[s % D] for s in range(s_count))
        # tail growth never relocates an existing shard -- the property
        # ShardedIndex.refresh relies on after a spill-append
        assert place_shards(s_count + 1, mesh)[:s_count] == placed
    with pytest.raises(ValueError, match="n_shards"):
        place_shards(0, mesh)


def test_place_shards_uses_ambient_mesh_or_none():
    assert place_shards(3) is None               # no mesh anywhere
    mesh = make_debug_mesh(1, axes=("data",))
    with set_mesh(mesh):
        placed = place_shards(3)
    assert placed == (jax.devices()[0],) * 3


def test_make_debug_mesh_axes_and_shape_validation():
    # legacy default: model-major (1, n) over ("data", "model")
    n = min(2, len(jax.devices()))
    legacy = make_debug_mesh(n)
    assert legacy.axis_names == ("data", "model")
    assert legacy.shape["data"] == 1 and legacy.shape["model"] == n
    # the retrieval fan-out's data-major form
    data = make_debug_mesh(n, axes=("data",))
    assert data.axis_names == ("data",) and data.shape["data"] == n
    with pytest.raises(ValueError, match="devices"):
        make_debug_mesh(n, axes=("data", "model"), shape=(n, 7))
