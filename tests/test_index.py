"""Similarity-search subsystem: kernel parity, LSH recall, .idx format.

Four layers, mirroring the subsystem's promises:

  * packed-Hamming kernel vs an unpacked numpy/jnp reference: match
    counts bit-exact across (scheme, b, densify) including sentinel-OPH
    EMPTY bins, and exact brute-force top-k identical to a full-matrix
    reference top-k (same scores, same tie-breaking),
  * LSH candidate generation + rerank: recall@10 >= 0.9 vs exact on a
    synthetic corpus with the S-curve-predicted band config,
  * index build -> mmap load -> query round trip with ZERO host-side
    unpacking of the corpus (guards on the unpack entry points),
  * the ``.idx`` header: version byte round trip + clear mismatch error,
    banding math, batched query admission.
"""

import glob
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hashing import Hash2U, Hash4U
from repro.core.oph import EMPTY, OPH
from repro.data.pipeline import make_sharded_dataset
from repro.data.preprocess import preprocess_shards
from repro.data.sparse import from_lists
from repro.data.synthetic import DatasetSpec
from repro.index import (BandingConfig, IndexSearcher, band_keys_from_codes,
                         band_keys_packed, build_band_tables, build_index,
                         choose_band_config, load_index, read_index_meta,
                         resemblance_scores, s_curve)
from repro.kernels import SignatureEngine, packed_match
from repro.kernels.pack import PackSpec

K, S = 128, 16
_E = np.uint32(0xFFFFFFFF)


def _batch(n=40, max_set=60, s=S, seed=5, max_nnz=128):
    rng = np.random.default_rng(seed)
    sets = [rng.choice(1 << s, rng.integers(1, max_set + 1), replace=False)
            for _ in range(n)]
    return from_lists(sets, max_nnz=max_nnz)


def _family(scheme, fam, densify, k=K, s=S):
    import zlib
    key = jax.random.PRNGKey(
        zlib.crc32(repr((scheme, fam, densify)).encode()) % (2**31))
    if scheme == "minhash":
        return (Hash2U.create(key, k, s) if fam == "2u"
                else Hash4U.create(key, k, s))
    return OPH.create(key, k, s, fam, densify)


def _ref_counts(sig_q: np.ndarray, sig_c: np.ndarray, sentinel: bool):
    """Unpacked reference: per-pair match counts (and joint-EMPTY)."""
    eq = sig_q[:, None, :] == sig_c[None, :, :]
    if sentinel:
        both = (sig_q == _E)[:, None, :] & (sig_c == _E)[None, :, :]
        return (eq & ~both).sum(-1), both.sum(-1)
    return eq.sum(-1), None


# ---------------------------------------------------------------------------
# Kernel vs unpacked reference: the acceptance grid
# ---------------------------------------------------------------------------

_GRID = [
    ("minhash", "2u", None, 8),
    ("oph", "2u", "sentinel", 8),        # EMPTY bins in play
    ("oph", "2u", "rotation", 4),
    ("oph", "2u", "fast", 8),
    pytest.param("oph", "2u", "optimal", 8, marks=pytest.mark.slow),
    pytest.param("minhash", "4u", None, 16, marks=pytest.mark.slow),
    pytest.param("oph", "4u", "sentinel", 1, marks=pytest.mark.slow),
]


@pytest.mark.parametrize("scheme,fam,densify,b", _GRID)
def test_packed_match_bit_exact_vs_unpacked_reference(scheme, fam, densify,
                                                      b):
    """Kernel match counts over packed wires == numpy counts over the
    unpacked signatures, EMPTY-aware for sentinel OPH."""
    family = _family(scheme, fam, densify)
    batch = _batch(seed=b)
    eng = SignatureEngine(family, b=b, packed=True)
    wire = eng.packed_signatures(batch)
    sig = np.asarray(wire.unpack())
    if densify == "sentinel":
        assert (sig == _E).any(), "grid case must exercise EMPTY bins"
    spec = wire.spec
    qwords, cwords = wire.data[:7], wire.data
    out = packed_match(qwords, cwords, spec, backend="interpret")
    want_m, want_e = _ref_counts(sig[:7], sig, spec.sentinel)
    if spec.sentinel:
        got_m, got_e = out
        assert np.array_equal(np.asarray(got_e), want_e)
    else:
        got_m = out
    assert np.array_equal(np.asarray(got_m), want_m)
    # the gpu/ref backends (jnp oracle) agree too
    out_ref = packed_match(qwords, cwords, spec, backend="ref")
    ref_m = out_ref[0] if spec.sentinel else out_ref
    assert np.array_equal(np.asarray(ref_m), want_m)


@pytest.mark.parametrize("scheme,fam,densify,b", [
    ("oph", "2u", "sentinel", 8),
    ("oph", "2u", "rotation", 8),
    pytest.param("minhash", "2u", None, 8, marks=pytest.mark.slow),
])
def test_exact_topk_matches_full_matrix_reference(tmp_path, scheme, fam,
                                                  densify, b):
    """Blocked brute-force top-k == one-shot full-matrix reference top-k
    (identical scores AND indices, i.e. identical tie-breaking)."""
    family = _family(scheme, fam, densify)
    batch = _batch(n=90, seed=17)
    wire = SignatureEngine(family, b=b, packed=True).packed_signatures(batch)
    sig = np.asarray(wire.unpack())
    cfg = BandingConfig(16, 2, wire.spec.code_bits)
    from repro.data.sigshard import write_sig_shard
    path = str(tmp_path / "c.sig")
    write_sig_shard(path, np.asarray(wire.data),
                    np.zeros(len(sig), np.float32), k=K, b=b,
                    code_bits=wire.spec.code_bits,
                    sentinel=wire.spec.sentinel)
    build_index([path], str(tmp_path / "c.idx"), cfg)
    index = load_index(str(tmp_path / "c.idx"))
    # corpus_block smaller than n forces the running top-k merge
    searcher = IndexSearcher(index, backend="interpret", corpus_block=32)
    topk = 10
    res = searcher.search(wire[:6], topk, mode="exact")

    want_m, want_e = _ref_counts(sig[:6], sig, wire.spec.sentinel)
    want_sc = resemblance_scores(
        jnp.asarray(want_m),
        None if want_e is None else jnp.asarray(want_e), K, b)
    ref_s, ref_i = jax.lax.top_k(want_sc, topk)
    assert np.array_equal(res.indices, np.asarray(ref_i).astype(np.int64))
    assert np.array_equal(res.scores, np.asarray(ref_s))
    # self-queries rank themselves first with resemblance estimate 1
    assert np.array_equal(res.indices[:, 0], np.arange(6))
    np.testing.assert_allclose(res.scores[:, 0], 1.0, atol=1e-6)


# ---------------------------------------------------------------------------
# Build -> mmap load -> query: the subsystem round trip
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def corpus_idx(tmp_path_factory):
    """A .sig-sharded synthetic corpus built into a .idx (rotation OPH)."""
    tmp = str(tmp_path_factory.mktemp("corpus"))
    spec = DatasetSpec("idxtest", n=512, D=1 << S, avg_nnz=48,
                       n_prototypes=8, overlap=0.8, seed=2)
    raw = make_sharded_dataset(spec, os.path.join(tmp, "raw"), n_shards=3)
    fam = OPH.create(jax.random.PRNGKey(0), K, S, "2u", "rotation")
    preprocess_shards(raw, os.path.join(tmp, "sig"), fam, b=8,
                      chunk_size=128, loader_kwargs={"lane_multiple": 8})
    sig_paths = sorted(glob.glob(os.path.join(tmp, "sig", "*.sig")))
    assert len(sig_paths) > 1
    cfg = choose_band_config(K, 8, threshold=0.5, target_recall=0.95)
    idx_path = os.path.join(tmp, "corpus.idx")
    meta = build_index(sig_paths, idx_path, cfg)
    return idx_path, meta, cfg


def test_index_roundtrip_zero_host_unpack(corpus_idx, monkeypatch):
    """mmap-load + both query paths while every unpack entry point is
    guarded against concrete host (numpy) corpus input."""
    idx_path, meta, cfg = corpus_idx

    def _guard(fn, what):
        def wrapped(arr, *a, **kw):
            assert not isinstance(arr, np.ndarray), \
                f"host-side {what} of packed data"
            return fn(arr, *a, **kw)
        return wrapped

    import repro.core.bbit as bbit
    import repro.index.banding as banding
    import repro.kernels.pack as pack
    monkeypatch.setattr(pack, "unpack_codes",
                        _guard(pack.unpack_codes, "unpack_codes"))
    monkeypatch.setattr(banding, "unpack_device",
                        _guard(banding.unpack_device, "unpack_device"))
    monkeypatch.setattr(bbit, "unpack_codes",
                        _guard(bbit.unpack_codes, "unpack_codes"))

    index = load_index(idx_path, mmap=True)
    assert isinstance(index.words_host, np.memmap)      # packed, off disk
    assert index.words_host.shape == (meta.n, meta.words)
    searcher = IndexSearcher(index, backend="interpret", corpus_block=128)
    q = jnp.asarray(np.ascontiguousarray(index.words_host[:5]))
    exact = searcher.search(q, 10, mode="exact")
    lsh = searcher.search(q, 10, mode="lsh")
    assert np.array_equal(exact.indices[:, 0], np.arange(5))
    assert np.array_equal(lsh.indices[:, 0], np.arange(5))
    # rebuild through the guarded entry points too: keys stay device-side
    build_index(sorted(glob.glob(os.path.join(
        os.path.dirname(idx_path), "sig", "*.sig"))),
        idx_path + ".re", cfg)
    assert read_index_meta(idx_path + ".re").n == meta.n


def test_lsh_recall_at_10(corpus_idx):
    """LSH candidates + kernel rerank reach recall@10 >= 0.9 vs exact
    with the S-curve-predicted band config."""
    idx_path, meta, cfg = corpus_idx
    # the chooser's own prediction clears the target at the threshold
    from repro.index.banding import sparse_collision_prob
    pb = sparse_collision_prob(0.5, 8)
    assert s_curve(pb, cfg.n_bands, cfg.rows_per_band) >= 0.95
    index = load_index(idx_path)
    searcher = IndexSearcher(index, backend="interpret", corpus_block=256)
    rng = np.random.default_rng(3)
    picks = rng.integers(0, meta.n, 16)
    q = jnp.asarray(np.ascontiguousarray(index.words_host[picks]))
    exact = searcher.search(q, 10, mode="exact")
    lsh = searcher.search(q, 10, mode="lsh")
    hits = [len(set(l.tolist()) & set(e.tolist())) / 10
            for l, e in zip(lsh.indices, exact.indices)]
    assert float(np.mean(hits)) >= 0.9, hits
    # candidate generation is genuinely selective, not a full scan
    assert float(np.mean(lsh.n_candidates)) < 0.5 * meta.n


def test_batched_admission_matches_search(corpus_idx):
    idx_path, meta, _ = corpus_idx
    index = load_index(idx_path)
    searcher = IndexSearcher(index, backend="interpret", corpus_block=128)
    rows = [np.ascontiguousarray(index.words_host[i]) for i in (3, 11, 40)]
    tickets = [searcher.submit(r) for r in rows]
    out = searcher.flush(5, mode="exact")
    batch = searcher.search(jnp.asarray(np.stack(rows)), 5, mode="exact")
    assert set(out) == set(tickets)
    for i, t in enumerate(tickets):
        assert np.array_equal(out[t].indices[0], batch.indices[i])
        assert np.array_equal(out[t].scores[0], batch.scores[i])
    assert searcher.flush() == {}                        # queue drained


def test_theorem1_rerank_with_set_sizes(tmp_path):
    """An index carrying set sizes + universe bits reranks with the exact
    Theorem-1 constants; self-queries still estimate R = 1."""
    rng = np.random.default_rng(4)
    sets = [rng.choice(1 << S, rng.integers(30, 90), replace=False)
            for _ in range(64)]
    batch = from_lists(sets, max_nnz=128)
    fam = _family("oph", "2u", "rotation")
    wire = SignatureEngine(fam, b=8, packed=True).packed_signatures(batch)
    from repro.data.sigshard import write_sig_shard
    path = str(tmp_path / "c.sig")
    write_sig_shard(path, np.asarray(wire.data),
                    np.zeros(len(sets), np.float32), k=K, b=8, code_bits=8)
    sizes = np.array([len(s) for s in sets], np.uint32)
    build_index([path], str(tmp_path / "c.idx"),
                BandingConfig(16, 2, 8), set_sizes=sizes, s=S)
    index = load_index(str(tmp_path / "c.idx"))
    assert index.meta.has_set_sizes and index.meta.s == S
    assert np.array_equal(index.set_sizes, sizes)
    searcher = IndexSearcher(index, backend="interpret", corpus_block=64)
    res = searcher.search(wire[:4], 5, mode="exact", query_sizes=sizes[:4])
    assert np.array_equal(res.indices[:, 0], np.arange(4))
    np.testing.assert_allclose(res.scores[:, 0], 1.0, atol=1e-5)
    with pytest.raises(ValueError):                      # sizes required
        searcher.search(wire[:4], 5, mode="exact")
    # batched admission carries per-ticket sizes through to the rerank
    t0 = searcher.submit(wire[0:1], query_size=int(sizes[0]))
    t1 = searcher.submit(wire[1:2], query_size=int(sizes[1]))
    out = searcher.flush(5, mode="exact")
    assert np.array_equal(out[t0].indices[0], res.indices[0])
    assert np.array_equal(out[t1].indices[0], res.indices[1])
    searcher.submit(wire[0:1], query_size=int(sizes[0]))
    searcher.submit(wire[1:2])                           # mixed sizes
    with pytest.raises(ValueError, match="every submitted query"):
        searcher.flush(5, mode="exact")


# ---------------------------------------------------------------------------
# .idx format: versioning + structure
# ---------------------------------------------------------------------------

def test_idx_version_byte_roundtrip_and_mismatch(corpus_idx, tmp_path):
    idx_path, meta, _ = corpus_idx
    assert read_index_meta(idx_path) == meta             # header round trip
    bad = str(tmp_path / "bad.idx")
    with open(idx_path, "rb") as f:
        blob = bytearray(f.read())
    blob[4] = 99                                         # bump version byte
    with open(bad, "wb") as f:
        f.write(blob)
    with pytest.raises(ValueError, match="version 99"):
        read_index_meta(bad)
    blob[:4] = b"NOPE"
    with open(bad, "wb") as f:
        f.write(blob)
    with pytest.raises(ValueError, match="bad magic"):
        read_index_meta(bad)


def test_build_index_rejects_mismatched_shards(tmp_path):
    from repro.data.sigshard import write_sig_shard
    rng = np.random.default_rng(0)
    w8 = rng.integers(0, 2**32, (4, 32), dtype=np.uint64).astype(np.uint32)
    write_sig_shard(str(tmp_path / "a.sig"), w8, np.zeros(4, np.float32),
                    k=128, b=8, code_bits=8)
    write_sig_shard(str(tmp_path / "b.sig"), w8[:, :16],
                    np.zeros(4, np.float32), k=128, b=4, code_bits=4)
    with pytest.raises(ValueError, match="wire format"):
        build_index([str(tmp_path / "a.sig"), str(tmp_path / "b.sig")],
                    str(tmp_path / "c.idx"), BandingConfig(16, 2, 8))
    with pytest.raises(ValueError):                      # cb mismatch
        build_index([str(tmp_path / "a.sig")], str(tmp_path / "c.idx"),
                    BandingConfig(16, 2, 9))


# ---------------------------------------------------------------------------
# Banding math
# ---------------------------------------------------------------------------

def test_band_keys_packed_matches_unpacked_keys():
    fam = _family("oph", "2u", "sentinel")
    wire = SignatureEngine(fam, b=8, packed=True).packed_signatures(_batch())
    cfg = BandingConfig(14, 3, 9)
    keys = np.asarray(band_keys_packed(wire.data, wire.spec, cfg))
    codes = np.asarray(wire.unpack())
    codes = np.where(codes == _E, np.uint32(1 << 8), codes)  # EMPTY -> 2^b
    want = np.asarray(band_keys_from_codes(jnp.asarray(codes), cfg))
    assert np.array_equal(keys, want)
    with pytest.raises(ValueError):                      # wire mismatch
        band_keys_packed(wire.data, wire.spec, BandingConfig(14, 3, 8))


def test_choose_band_config_s_curve():
    cfg = choose_band_config(128, 8, threshold=0.5, target_recall=0.95)
    assert cfg.k <= 128 and cfg.rows_per_band * cfg.code_bits <= 60
    from repro.index.banding import sparse_collision_prob
    pb = sparse_collision_prob(0.5, 8)
    assert s_curve(pb, cfg.n_bands, cfg.rows_per_band) >= 0.95
    # one row more per band would miss the target (maximally selective)
    r2 = cfg.rows_per_band + 1
    assert s_curve(pb, 128 // r2, r2) < 0.95
    with pytest.raises(ValueError):
        choose_band_config(4, 1, threshold=0.05, target_recall=0.999)


def test_build_band_tables_structure():
    keys = np.array([[1, 5], [1, 7], [2, 5], [1, 5]])
    band_offsets, skeys, bucket_offsets, postings = build_band_tables(keys)
    assert band_offsets.tolist() == [0, 2, 4]            # {1,2}, {5,7}
    assert skeys.tolist() == [1, 2, 5, 7]
    # bucket for band 0 key 1 -> docs 0,1,3 (ascending)
    assert postings[bucket_offsets[0]:bucket_offsets[1]].tolist() == [0, 1, 3]
    assert postings[bucket_offsets[2]:bucket_offsets[3]].tolist() == [0, 2, 3]


# ---------------------------------------------------------------------------
# Fused exact scan: one traced computation, bit-identical, out-of-core
# ---------------------------------------------------------------------------

def test_fused_scan_bit_identical_to_blockloop_reference(corpus_idx):
    """The fused in-jit scan returns exactly (ids AND scores) what the
    PR-4 per-block host loop returned."""
    idx_path, meta, _ = corpus_idx
    index = load_index(idx_path)
    q = jnp.asarray(np.ascontiguousarray(index.words_host[10:30]))
    fused = IndexSearcher(index, backend="interpret", corpus_block=128)
    ref = IndexSearcher(index, backend="interpret", corpus_block=128,
                        exact_impl="blockloop")
    r_f = fused.search(q, 10, mode="exact")
    r_b = ref.search(q, 10, mode="exact")
    assert np.array_equal(r_f.indices, r_b.indices)
    assert np.array_equal(r_f.scores, r_b.scores)
    with pytest.raises(ValueError, match="exact_impl"):
        IndexSearcher(index, exact_impl="nope")


def test_exact_flush_is_one_traced_computation(corpus_idx, monkeypatch):
    """flush() dispatches the fused scan exactly once, and a repeat flush
    with the same (batch, corpus, topk, block) is a jit-cache hit -- no
    per-block host round trips, no retrace."""
    import repro.index.query as query

    idx_path, meta, _ = corpus_idx
    index = load_index(idx_path)
    searcher = IndexSearcher(index, backend="interpret", corpus_block=64)
    assert meta.n // 64 > 2                      # genuinely multi-block
    calls = []
    real_scan = query._exact_scan

    def counting_scan(*args, **kwargs):
        calls.append(1)
        return real_scan(*args, **kwargs)

    monkeypatch.setattr(query, "_exact_scan", counting_scan)
    for i in (3, 4, 5):
        searcher.submit(np.asarray(index.words_host[i]))
    searcher.flush(10, mode="exact")
    assert len(calls) == 1                       # ONE dispatch per flush
    traces = query.TRACE_COUNTS["exact_scan"]
    for i in (6, 7, 8):
        searcher.submit(np.asarray(index.words_host[i]))
    searcher.flush(10, mode="exact")
    assert len(calls) == 2
    assert query.TRACE_COUNTS["exact_scan"] == traces   # cache hit


def test_streamed_out_of_core_bit_identical(corpus_idx):
    """A device window smaller than the corpus forces the mmap-window
    streaming path; results are bit-identical to the in-core scan."""
    idx_path, meta, _ = corpus_idx
    index = load_index(idx_path)
    q = jnp.asarray(np.ascontiguousarray(index.words_host[:12]))
    incore = IndexSearcher(index, backend="interpret", corpus_block=128)
    window = meta.payload_bytes // 3
    streamed = IndexSearcher(index, backend="interpret", corpus_block=128,
                             max_device_bytes=window)
    assert streamed.streamed and meta.payload_bytes > window
    assert not incore.streamed
    r_i = incore.search(q, 10, mode="exact")
    r_s = streamed.search(q, 10, mode="exact")
    assert np.array_equal(r_i.indices, r_s.indices)
    assert np.array_equal(r_i.scores, r_s.scores)
    # LSH on a streamed searcher gathers candidates off the mmap instead
    # of uploading the corpus; results match the in-core LSH path
    l_i = incore.search(q, 10, mode="lsh")
    l_s = streamed.search(q, 10, mode="lsh")
    assert np.array_equal(l_i.indices, l_s.indices)
    assert np.array_equal(l_i.scores, l_s.scores)


def test_lsh_subbatch_pipeline_matches_single_batch(corpus_idx):
    """lsh_batch pipelining (async dispatch per sub-batch) returns the
    same results as one monolithic batch."""
    idx_path, meta, _ = corpus_idx
    index = load_index(idx_path)
    q = jnp.asarray(np.ascontiguousarray(index.words_host[5:18]))
    mono = IndexSearcher(index, backend="interpret", corpus_block=128)
    piped = IndexSearcher(index, backend="interpret", corpus_block=128,
                          lsh_batch=4)
    r_m = mono.search(q, 10, mode="lsh")
    r_p = piped.search(q, 10, mode="lsh")
    assert np.array_equal(r_m.indices, r_p.indices)
    assert np.array_equal(r_m.scores, r_p.scores)
    assert np.array_equal(r_m.n_candidates, r_p.n_candidates)


def test_candidates_batch_matches_per_query_buckets(corpus_idx):
    """The batched searchsorted candidate lookup equals a per-(query,
    band) bucket walk."""
    idx_path, meta, _ = corpus_idx
    index = load_index(idx_path)
    wire = jnp.asarray(np.ascontiguousarray(index.words_host[:8]))
    qkeys = np.asarray(band_keys_packed(wire, index.spec, index.banding))
    batch = index.candidates_batch(qkeys)
    for i in range(qkeys.shape[0]):
        per_band = [index.bucket(band, int(qkeys[i, band]))
                    for band in range(meta.n_bands)]
        want = (np.unique(np.concatenate(per_band)).astype(np.int64)
                if per_band else np.zeros(0, np.int64))
        np.testing.assert_array_equal(batch[i], want)


def test_blockloop_refuses_out_of_core_corpus(corpus_idx):
    """blockloop keeps the corpus device-resident, so combining it with
    a device window smaller than the payload must fail loudly instead of
    silently uploading past the cap."""
    idx_path, meta, _ = corpus_idx
    index = load_index(idx_path)
    searcher = IndexSearcher(index, backend="interpret", corpus_block=128,
                             exact_impl="blockloop",
                             max_device_bytes=meta.payload_bytes // 2)
    q = jnp.asarray(np.ascontiguousarray(index.words_host[:2]))
    with pytest.raises(ValueError, match="max_device_bytes"):
        searcher.search(q, 5, mode="exact")


def test_stream_plan_resident_bytes_within_budget(corpus_idx):
    """The out-of-core window plan must keep worst-case device-resident
    corpus bytes (inflight windows x window bytes) within the configured
    budget -- the old plan floored the window at corpus_block and could
    hold prefetch+1 windows over budget.  Below two rows' worth the
    budget is physically unsatisfiable; the plan floors at one row per
    window and that is the only excused case."""
    idx_path, meta, _ = corpus_idx
    index = load_index(idx_path)
    row_bytes = 4 * meta.words
    budgets = [2 * row_bytes, 5 * row_bytes, 64 * row_bytes,
               200 * row_bytes, meta.payload_bytes // 3,
               meta.payload_bytes // 2]
    for budget in budgets:
        s = IndexSearcher(index, backend="interpret", corpus_block=128,
                          max_device_bytes=budget)
        assert s.streamed
        p = s._stream_plan()
        assert p.resident_bytes <= budget, (
            f"budget {budget}: {p.inflight} x {p.window_bytes} B resident")
        assert p.window % p.block == 0 and p.block <= 128
    # hard floor: less than two rows of budget still yields a legal
    # (one-row-per-window) plan rather than dividing to zero
    tiny = IndexSearcher(index, backend="interpret", corpus_block=128,
                         max_device_bytes=row_bytes)
    assert tiny._stream_plan().window == 1


def test_streamed_tiny_budget_bit_identical(corpus_idx):
    """Even a budget that shrinks the scan block below corpus_block (the
    case the old plan violated) returns bit-identical results."""
    idx_path, meta, _ = corpus_idx
    index = load_index(idx_path)
    q = jnp.asarray(np.ascontiguousarray(index.words_host[20:26]))
    want = IndexSearcher(index, backend="interpret",
                         corpus_block=128).search(q, 10, mode="exact")
    row_bytes = 4 * meta.words
    tight = IndexSearcher(index, backend="interpret", corpus_block=128,
                          max_device_bytes=40 * row_bytes)
    plan = tight._stream_plan()
    assert plan.block < 128                      # budget forced a small block
    got = tight.search(q, 10, mode="exact")
    assert np.array_equal(got.indices, want.indices)
    assert np.array_equal(got.scores, want.scores)
