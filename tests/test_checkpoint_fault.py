"""Checkpointing, restart, elastic resharding, straggler heartbeat."""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import (Heartbeat, TrainState, Trainer, checkpoint,
                         make_train_step, run_with_restarts,
                         reshard_restore)
from repro.optim import adamw, constant


def _toy_state():
    params = {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
              "b": jnp.ones((4,), jnp.bfloat16)}
    opt = adamw(constant(0.1))
    return TrainState.create(params, opt), opt


def test_save_restore_roundtrip(tmp_path):
    state, _ = _toy_state()
    d = str(tmp_path / "ckpt")
    checkpoint.save(d, 7, state)
    template = jax.tree_util.tree_map(jnp.zeros_like, state)
    restored, step = checkpoint.restore(d, template)
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_keep_n_gc_and_latest(tmp_path):
    state, _ = _toy_state()
    d = str(tmp_path / "ckpt")
    for s in (1, 2, 3, 4, 5):
        checkpoint.save(d, s, state, keep=2)
    assert checkpoint.latest_step(d) == 5
    kept = sorted(os.listdir(d))
    assert kept == ["step_00000004", "step_00000005"]


def test_atomicity_no_tmp_left(tmp_path):
    state, _ = _toy_state()
    d = str(tmp_path / "ckpt")
    checkpoint.save(d, 1, state)
    assert not [f for f in os.listdir(d) if f.startswith(".tmp")]


def test_trainer_restarts_after_failure(tmp_path):
    """A step that raises once mid-run resumes from checkpoint."""
    opt = adamw(constant(0.1))
    params = {"w": jnp.zeros((4,))}
    state = TrainState.create(params, opt)
    loss = lambda p, batch: jnp.sum((p["w"] - batch) ** 2)
    base_step = make_train_step(loss, opt)
    boom = {"armed": True}

    def flaky_step(st, batch):
        if boom["armed"] and int(st.step) == 7:
            boom["armed"] = False
            raise RuntimeError("injected node failure")
        return base_step(st, batch)

    tr = Trainer(flaky_step, ckpt_dir=str(tmp_path / "ck"), ckpt_every=5,
                 jit=False, max_failures=2)
    batches = lambda: iter([jnp.ones((4,))] * 20)
    final = tr.fit(state, batches, 20)
    assert int(final.step) == 20
    assert not boom["armed"]  # failure actually happened


def test_run_with_restarts_exhausts():
    def always_fails(state, step):
        raise ValueError("dead")

    with pytest.raises(ValueError):
        run_with_restarts(init_state=0, init_step=0, run_steps=always_fails,
                          restore_fn=lambda: (0, 0), max_failures=2)


def test_elastic_reshard_roundtrip(tmp_path):
    """Restore a checkpoint under explicit (new-mesh) shardings."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    state, _ = _toy_state()
    d = str(tmp_path / "ckpt")
    checkpoint.save(d, 3, state)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))

    def sharding_fn(template):
        s = NamedSharding(mesh, P())
        return jax.tree_util.tree_map(lambda _: s, template)

    template = jax.tree_util.tree_map(jnp.zeros_like, state)
    restored, step = reshard_restore(d, template, sharding_fn)
    assert step == 3
    leaf = jax.tree_util.tree_leaves(restored)[0]
    assert leaf.sharding.mesh.axis_names == ("data",)


def test_heartbeat_straggler_detection():
    hb = Heartbeat(deadline_s=0.1)
    assert not hb.observe(0.05)
    assert hb.observe(0.5)
    assert hb.stragglers == 1
    for _ in range(10):
        hb.observe(0.01)
    assert hb.adaptive_deadline(factor=3.0) == pytest.approx(0.03, rel=0.5)
