"""Fault-tolerant fan-out (``repro.index.resilience``):

  * ``ResilientShardClient`` healthy path is a bit-identical
    pass-through; retries recover from transient faults with the
    documented backoff + metrics + ``retry`` spans,
  * per-attempt deadlines abandon hung dispatches; hedged dispatch
    races a second attempt and records win/loss,
  * the circuit breaker opens after consecutive failures,
    short-circuits without touching the transport, half-opens a probe,
    and closes on success -- every transition visible in the
    ``shard_breaker_state`` gauge and ``breaker`` spans,
  * ``on_shard_failure="partial"`` serves survivors bit-identically to
    a healthy router restricted to those shards, with exact
    ``coverage``; every query resolves under seeded 25% mixed chaos
    through a live ``SearchServer``,
  * the seeded ``ChaosShardClient`` is deterministic: same schedule =>
    identical fault sequences and identical partial results.
"""

import glob
import os
import time

import jax
import numpy as np
import pytest

from repro.core.oph import OPH
from repro.data.pipeline import make_sharded_dataset
from repro.data.preprocess import preprocess_shards
from repro.data.synthetic import DatasetSpec
from repro.index import (ChaosSchedule, ChaosShardClient, CircuitOpenError,
                         IndexSearcher, LocalShardClient, ResiliencePolicy,
                         ResilientShardClient, ShardDispatchTimeout,
                         build_index, build_sharded, choose_band_config,
                         load_index, load_sharded, merge_topk,
                         resilient_client_factory)
from repro.launch.server import SearchServer
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Tracer

K, S, B = 128, 16, 8


@pytest.fixture(scope="module")
def corpus(tmp_path_factory):
    tmp = str(tmp_path_factory.mktemp("chaos_corpus"))
    spec = DatasetSpec("chaostest", n=300, D=1 << S, avg_nnz=48,
                       n_prototypes=8, overlap=0.8, seed=31)
    raw = make_sharded_dataset(spec, os.path.join(tmp, "raw"), n_shards=4)
    fam = OPH.create(jax.random.PRNGKey(6), K, S, "2u", "rotation")
    preprocess_shards(raw, os.path.join(tmp, "sig"), fam, b=B,
                      chunk_size=64, loader_kwargs={"lane_multiple": 8})
    sig_paths = sorted(glob.glob(os.path.join(tmp, "sig", "*.sig")))
    cfg = choose_band_config(K, B, threshold=0.5)
    idx_path = os.path.join(tmp, "single.idx")
    build_index(sig_paths, idx_path, cfg)
    shard_dir = os.path.join(tmp, "shards")
    build_sharded(sig_paths, shard_dir, cfg, n_shards=3)
    return tmp, shard_dir, idx_path


@pytest.fixture(scope="module")
def single(corpus):
    _, _, idx_path = corpus
    return IndexSearcher(load_index(idx_path), backend="interpret",
                         corpus_block=64)


def _queries(single, m=4):
    n = single.index.n
    ids = [0, n // 3, n // 2, n - 1][:m]
    return np.ascontiguousarray(single.index.words_host[ids])


class ScriptedClient:
    """``ShardClient`` whose calls follow a plan.

    Plan entries: ``"err"`` -> OSError at dispatch; a float -> the
    harvest sleeps that long then returns the real result; ``0`` ->
    plain pass-through.  Past the end of the plan, every call is ok.
    """

    def __init__(self, searcher, plan=()):
        self.inner = LocalShardClient(searcher)
        self.plan = list(plan)
        self.calls = 0

    @property
    def n(self):
        return self.inner.n

    def dispatch(self, qwords, topk, *, mode="exact", query_sizes=None,
                 qkeys=None):
        step = self.plan[self.calls] if self.calls < len(self.plan) else 0
        self.calls += 1
        if step == "err":
            raise OSError("scripted dispatch failure")
        inner = self.inner.dispatch(qwords, topk, mode=mode,
                                    query_sizes=query_sizes, qkeys=qkeys)

        def harvest():
            if step:
                time.sleep(step)
            return inner()
        return harvest


# ---------------------------------------------------------------------------
# ResilientShardClient
# ---------------------------------------------------------------------------

def test_resilient_healthy_path_is_passthrough(corpus, single):
    """No faults: resilient fan-out == plain local fan-out, zero
    retries/hedges/breaker movement, coverage 1.0."""
    _, shard_dir, _ = corpus
    reg = MetricsRegistry()
    fac = resilient_client_factory(ResiliencePolicy(), registry=reg)
    router = load_sharded(shard_dir, backend="interpret", corpus_block=64,
                          dispatch="sequential", client_factory=fac)
    plain = load_sharded(shard_dir, backend="interpret", corpus_block=64,
                         dispatch="sequential")
    q = _queries(single)
    for mode in ("exact", "lsh"):
        got = router.search(q, 10, mode=mode)
        want = plain.search(q, 10, mode=mode)
        assert np.array_equal(got.indices, want.indices), mode
        assert np.array_equal(got.scores, want.scores), mode
        assert got.coverage == 1.0 and got.failed_shards == ()
    vals = reg.values()
    for i in range(3):
        assert vals[f'shard_dispatch_retries_total{{shard="{i}"}}'] == 0.0
        assert vals[f'shard_breaker_state{{shard="{i}"}}'] == 0.0


def test_retry_recovers_with_backoff_metrics_and_spans(single):
    reg, tr = MetricsRegistry(), Tracer(enabled=True)
    sleeps = []
    inner = ScriptedClient(single, ["err", "err", 0])
    client = ResilientShardClient(
        inner, ResiliencePolicy(max_retries=2, backoff_base_s=0.001,
                                backoff_cap_s=0.01),
        registry=reg, tracer=tr, sleep=sleeps.append)
    q = _queries(single, 2)
    got = client.dispatch(q, 5)()
    want = single.dispatch(q, 5)()
    assert np.array_equal(got.indices, want.indices)
    assert np.array_equal(got.scores, want.scores)
    assert inner.calls == 3
    vals = reg.values()
    assert vals['shard_dispatch_retries_total{shard="0"}'] == 2.0
    assert vals['shard_dispatch_failures_total{shard="0"}'] == 2.0
    # decorrelated-jitter backoff: bounded by [base, cap], one per retry
    assert len(sleeps) == 2
    assert all(0.001 <= s <= 0.01 for s in sleeps)
    retry_spans = [e for e in tr.events() if e.get("name") == "retry"]
    assert [s["args"]["attempt"] for s in retry_spans] == [1, 2]
    assert all(s["args"]["error"] == "OSError" for s in retry_spans)


def test_retry_budget_exhausted_raises_last_error(single):
    inner = ScriptedClient(single, ["err", "err", "err"])
    client = ResilientShardClient(
        inner, ResiliencePolicy(max_retries=2, backoff_base_s=0.0,
                                backoff_cap_s=0.0),
        registry=MetricsRegistry())
    with pytest.raises(OSError, match="scripted"):
        client.dispatch(_queries(single, 1), 5)()
    assert inner.calls == 3


def test_deadline_abandons_hung_dispatch(single):
    reg = MetricsRegistry()
    client = ResilientShardClient(
        ScriptedClient(single, [0.5, 0.5]),
        ResiliencePolicy(deadline_s=0.05, max_retries=0),
        registry=reg)
    t0 = time.monotonic()
    with pytest.raises(ShardDispatchTimeout):
        client.dispatch(_queries(single, 1), 5)()
    assert time.monotonic() - t0 < 0.4          # did not wait out the hang
    assert reg.values()['shard_dispatch_timeouts_total{shard="0"}'] == 1.0


def test_hedge_wins_against_slow_primary(single):
    reg, tr = MetricsRegistry(), Tracer(enabled=True)
    inner = ScriptedClient(single, [0.5, 0])     # primary slow, hedge fast
    client = ResilientShardClient(
        inner, ResiliencePolicy(hedge=True, hedge_min_s=0.01,
                                hedge_max_s=0.01),
        registry=reg, tracer=tr)
    q = _queries(single, 2)
    t0 = time.monotonic()
    got = client.dispatch(q, 5)()
    assert time.monotonic() - t0 < 0.4           # hedge, not the primary
    want = single.dispatch(q, 5)()
    assert np.array_equal(got.indices, want.indices)
    assert inner.calls == 2
    key = 'shard_hedges_total{outcome="win",shard="0"}'
    assert reg.values()[key] == 1.0
    spans = [e for e in tr.events() if e.get("name") == "hedge"]
    assert len(spans) == 1 and spans[0]["args"]["outcome"] == "win"


def test_breaker_lifecycle_short_circuits_and_recovers(single):
    reg, tr = MetricsRegistry(), Tracer(enabled=True)
    inner = ScriptedClient(single, ["err", "err", 0])
    client = ResilientShardClient(
        inner, ResiliencePolicy(max_retries=0, breaker_failures=2,
                                breaker_reset_s=0.05),
        registry=reg, tracer=tr)
    q = _queries(single, 1)
    key = 'shard_breaker_state{shard="0"}'

    for _ in range(2):                           # two consecutive failures
        with pytest.raises(OSError):
            client.dispatch(q, 5)()
    assert reg.values()[key] == 2.0              # open

    calls_before = inner.calls
    with pytest.raises(CircuitOpenError):        # short-circuit: no
        client.dispatch(q, 5)                    # transport touched
    assert inner.calls == calls_before

    time.sleep(0.06)                             # reset window elapses
    got = client.dispatch(q, 5)()                # the half-open probe
    want = single.dispatch(q, 5)()
    assert np.array_equal(got.indices, want.indices)
    assert reg.values()[key] == 0.0              # closed again

    trans = [(e["args"]["from"], e["args"]["to"])
             for e in tr.events() if e.get("name") == "breaker"]
    assert trans == [("closed", "open"), ("open", "half_open"),
                     ("half_open", "closed")]


# ---------------------------------------------------------------------------
# partial fan-out + chaos
# ---------------------------------------------------------------------------

def _dead_shard_router(shard_dir, dead, **kw):
    fac = resilient_client_factory(
        ResiliencePolicy(max_retries=0, backoff_base_s=0.0),
        chaos=lambda i: (ChaosSchedule(seed=7, fault_rate=1.0,
                                       faults=("oserror",))
                         if i == dead else None))
    return load_sharded(shard_dir, backend="interpret", corpus_block=64,
                        dispatch="sequential", client_factory=fac, **kw)


def test_partial_serves_survivors_bit_identically(corpus, single):
    """Dead shard under "partial": results == healthy router restricted
    to the survivors, coverage == surviving doc fraction exactly."""
    _, shard_dir, _ = corpus
    router = _dead_shard_router(shard_dir, dead=2,
                                on_shard_failure="partial")
    healthy = load_sharded(shard_dir, backend="interpret", corpus_block=64,
                           dispatch="sequential")
    q = _queries(single)
    got = router.search(q, 10)
    assert got.failed_shards == (2,)
    keep = [0, 1]
    want = merge_topk(
        [healthy.searchers[i].dispatch(q, 10)() for i in keep],
        healthy.offsets[keep], 10)
    assert np.array_equal(got.indices, want.indices)
    assert np.array_equal(got.scores, want.scores)
    n_live = sum(healthy.searchers[i].index.n for i in keep)
    assert got.coverage == n_live / single.index.n


def test_partial_not_requested_still_fails(corpus):
    _, shard_dir, _ = corpus
    router = _dead_shard_router(shard_dir, dead=0)     # default "fail"
    with pytest.raises(OSError):
        router.search(np.zeros((1, router.searchers[0].index.words_host
                                .shape[1]), np.uint32), 5)


def test_all_shards_failed_raises(corpus):
    _, shard_dir, _ = corpus
    fac = resilient_client_factory(
        ResiliencePolicy(max_retries=0),
        chaos=ChaosSchedule(seed=1, fault_rate=1.0, faults=("oserror",)))
    router = load_sharded(shard_dir, backend="interpret", corpus_block=64,
                          dispatch="sequential", client_factory=fac,
                          on_shard_failure="partial")
    q = np.zeros((1, router.searchers[0].index.words_host.shape[1]),
                 np.uint32)
    with pytest.raises(RuntimeError, match="all 3 shards failed"):
        router.search(q, 5)


def test_chaos_survival_through_server(corpus, single):
    """Seeded 25% mixed faults (latency/oserror/hang/drop) through a
    live 2-worker SearchServer in partial mode: every request resolves,
    nothing hangs, coverage is accounted."""
    _, shard_dir, _ = corpus
    fac = resilient_client_factory(
        ResiliencePolicy(deadline_s=0.25, max_retries=1,
                         backoff_base_s=0.001, backoff_cap_s=0.005),
        chaos=lambda i: ChaosSchedule(seed=100 + i, fault_rate=0.25,
                                      latency_s=0.002, hang_s=1.0),
        seed=9)
    router = load_sharded(shard_dir, backend="interpret", corpus_block=64,
                          dispatch="sequential", client_factory=fac,
                          on_shard_failure="partial")
    rows = [np.asarray(r) for r in _queries(single)] * 6
    with SearchServer(router, max_batch=4, max_delay_s=0.002, topk=5,
                      num_workers=2, on_shard_failure="partial") as srv:
        handles = [srv.submit(r) for r in rows]
        results = [h.result(timeout=120.0) for h in handles]
    assert len(results) == len(rows)             # every query resolved
    assert all(h.outcome in ("served", "partial") for h in handles)
    for res in results:
        assert res.indices.shape == (1, 5)
        assert 0.0 < res.coverage <= 1.0
        if res.failed_shards:
            n_live = sum(s.index.n for i, s in enumerate(router.searchers)
                         if i not in res.failed_shards)
            assert res.coverage == n_live / single.index.n
    snap = srv.stats.snapshot()
    assert snap["requests"] == len(rows)
    if any(h.outcome == "partial" for h in handles):
        assert snap["partial"] > 0
        assert snap["mean_coverage"] < 1.0


def test_chaos_is_seed_deterministic(corpus, single):
    """Same ChaosSchedule seeds => identical fault sequences AND
    identical (partial) results, run to run."""
    _, shard_dir, _ = corpus
    q = _queries(single)

    def run():
        fac = resilient_client_factory(
            ResiliencePolicy(max_retries=1, backoff_base_s=0.0,
                             backoff_cap_s=0.0),
            chaos=lambda i: ChaosSchedule(seed=40 + i, fault_rate=0.5,
                                          faults=("oserror", "drop",
                                                  "latency"),
                                          latency_s=0.0),
            seed=3)
        router = load_sharded(shard_dir, backend="interpret",
                              corpus_block=64, dispatch="sequential",
                              client_factory=fac,
                              on_shard_failure="partial")
        out = [router.search(q, 10) for _ in range(6)]
        logs = [tuple(c.fault_log) for c in fac.chaos_clients]
        return out, logs

    out_a, logs_a = run()
    out_b, logs_b = run()
    assert logs_a == logs_b                      # identical fault sequences
    assert any(k is not None for log in logs_a for _, k in log)
    for ra, rb in zip(out_a, out_b):
        assert np.array_equal(ra.indices, rb.indices)
        assert np.array_equal(ra.scores, rb.scores)
        assert ra.coverage == rb.coverage
        assert ra.failed_shards == rb.failed_shards


def test_chaos_client_draw_log_matches_schedule(single):
    """fault_log replays the schedule's seeded draw stream exactly."""
    sched = ChaosSchedule(seed=11, fault_rate=0.5, faults=("latency",),
                          latency_s=0.0)
    client = ChaosShardClient(LocalShardClient(single), sched)
    q = _queries(single, 1)
    for _ in range(8):
        client.dispatch(q, 3)()
    rng = np.random.default_rng(11)
    want = []
    for i in range(8):
        kind = None
        if float(rng.random()) < 0.5:
            rng.integers(1)
            kind = "latency"
        want.append((i, kind))
    assert client.fault_log == want
