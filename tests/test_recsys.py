"""RecSys: EmbeddingBag semantics + minhash frontend == paper's Eq. (5)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models.recsys import (embedding_bag, embedding_lookup,
                                 init_recsys_params, minhash_frontend,
                                 recsys_logits, _minhash_coeffs)
from repro.kernels import sigbag, minhash2u
from repro.kernels import ref as kref


def test_embedding_lookup_matches_onehot():
    rng = np.random.default_rng(0)
    F, V, d, B = 3, 50, 4, 7
    table = jnp.asarray(rng.normal(size=(F, V, d)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, V, (B, F)), jnp.int32)
    got = embedding_lookup(table, ids)
    want = np.stack([
        np.asarray(table)[f][np.asarray(ids)[:, f]] for f in range(F)], axis=1)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-6)


def test_embedding_bag_sum_and_mean():
    rng = np.random.default_rng(1)
    V, d, B, L = 30, 5, 4, 6
    table = jnp.asarray(rng.normal(size=(V, d)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, V, (B, L)), jnp.int32)
    mask = jnp.asarray(rng.random((B, L)) < 0.7, jnp.float32)
    got = embedding_bag(table, ids, mask, "sum")
    want = np.einsum("bl,bld->bd", np.asarray(mask),
                     np.asarray(table)[np.asarray(ids)])
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-6)
    got_m = embedding_bag(table, ids, mask, "mean")
    cnt = np.maximum(np.asarray(mask).sum(1, keepdims=True), 1)
    np.testing.assert_allclose(np.asarray(got_m), want / cnt, rtol=1e-5,
                               atol=1e-6)


def test_minhash_frontend_equals_kernel_path():
    """In-graph jnp frontend == Pallas preprocessing kernel + sigbag."""
    spec = get_arch("autoint")
    cfg = spec.smoke
    params = init_recsys_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    B = 9
    set_ids = jnp.asarray(rng.integers(0, 1 << cfg.minhash_s,
                                       (B, cfg.set_nnz)), jnp.int32)
    counts = jnp.asarray(rng.integers(1, cfg.set_nnz, (B,)), jnp.int32)
    in_graph = minhash_frontend(params, set_ids, counts, cfg)

    a1, a2 = _minhash_coeffs(cfg.arch_id, cfg.minhash_k)
    sig = minhash2u(set_ids, counts, jnp.asarray(a1), jnp.asarray(a2),
                    s=cfg.minhash_s, b=cfg.minhash_b)       # Pallas kernel
    via_kernel = sigbag(sig.astype(jnp.int32), params["minhash_table"])
    np.testing.assert_allclose(np.asarray(in_graph), np.asarray(via_kernel),
                               rtol=1e-4, atol=1e-5)


def test_minhash_frontend_reduces_storage():
    """The paper's data-reduction claim for embeddings: table is O(k 2^b d),
    independent of the raw universe D = 2^s."""
    spec = get_arch("wide-deep")
    cfg = spec.config
    table_rows = cfg.minhash_k * (1 << cfg.minhash_b)
    assert table_rows < (1 << cfg.minhash_s) / 100


def test_frontend_changes_logits():
    """The hashed feature must actually contribute to predictions."""
    spec = get_arch("wide-deep")
    cfg = spec.smoke
    params = init_recsys_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    B = 4
    batch = {
        "field_ids": jnp.asarray(rng.integers(0, cfg.vocab, (B, cfg.n_fields)),
                                 jnp.int32),
        "set_ids": jnp.asarray(rng.integers(0, 1 << cfg.minhash_s,
                                            (B, cfg.set_nnz)), jnp.int32),
        "set_counts": jnp.asarray([5, 10, 20, 30], jnp.int32),
    }
    l1 = recsys_logits(params, batch, cfg)
    batch2 = dict(batch, set_ids=(batch["set_ids"] + 7) % (1 << cfg.minhash_s))
    l2 = recsys_logits(params, batch2, cfg)
    assert not np.allclose(np.asarray(l1), np.asarray(l2))


@pytest.mark.parametrize(
    "arch", [pytest.param("din", marks=pytest.mark.slow), "mind"])
def test_sequence_models_attend_to_history(arch):
    spec = get_arch(arch)
    cfg = spec.smoke
    params = init_recsys_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(6)
    B = 5
    batch = {
        "hist_ids": jnp.asarray(rng.integers(0, cfg.item_vocab,
                                             (B, cfg.seq_len)), jnp.int32),
        "hist_mask": jnp.ones((B, cfg.seq_len), jnp.float32),
        "target_id": jnp.asarray(rng.integers(0, cfg.item_vocab, (B,)),
                                 jnp.int32),
    }
    l1 = recsys_logits(params, batch, cfg)
    batch2 = dict(batch, hist_ids=(batch["hist_ids"] + 13) % cfg.item_vocab)
    l2 = recsys_logits(params, batch2, cfg)
    assert l1.shape == (B,)
    assert not np.allclose(np.asarray(l1), np.asarray(l2))
