"""Socket shard transport (``repro.index.transport``):

  * ``SocketShardClient`` fan-out is bit-identical (ids AND scores) to
    the in-process ``LocalShardClient`` router and to a single
    unsharded index, exact + LSH + the Theorem-1 set-sizes rerank,
  * a truncated frame, a corrupt frame, and a mid-response connection
    drop each surface as a clean per-dispatch ``TransportError`` /
    timeout -- never a hang, never a torn ``SearchResult``,
  * the service itself survives garbage input and keeps serving.
"""

import glob
import os
import socket
import struct
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.oph import OPH
from repro.data.pipeline import make_sharded_dataset
from repro.data.preprocess import preprocess_shards
from repro.data.sigshard import write_sig_shard
from repro.data.sparse import from_lists
from repro.data.synthetic import DatasetSpec
from repro.index import (BandingConfig, IndexSearcher, ShardService,
                         SocketShardClient, TransportError, build_index,
                         build_sharded, choose_band_config, load_index,
                         load_sharded, loopback_client_factory)
from repro.index.transport import _MAGIC, RemoteShardError, _pack_msg
from repro.kernels import SignatureEngine

K, S, B = 128, 16, 8


@pytest.fixture(scope="module")
def corpus(tmp_path_factory):
    """Synthetic corpus: .sig shards, a 3-shard dir, one reference .idx."""
    tmp = str(tmp_path_factory.mktemp("transport_corpus"))
    spec = DatasetSpec("transport", n=300, D=1 << S, avg_nnz=48,
                       n_prototypes=8, overlap=0.8, seed=21)
    raw = make_sharded_dataset(spec, os.path.join(tmp, "raw"), n_shards=4)
    fam = OPH.create(jax.random.PRNGKey(4), K, S, "2u", "rotation")
    preprocess_shards(raw, os.path.join(tmp, "sig"), fam, b=B,
                      chunk_size=64, loader_kwargs={"lane_multiple": 8})
    sig_paths = sorted(glob.glob(os.path.join(tmp, "sig", "*.sig")))
    cfg = choose_band_config(K, B, threshold=0.5)
    idx_path = os.path.join(tmp, "single.idx")
    build_index(sig_paths, idx_path, cfg)
    shard_dir = os.path.join(tmp, "shards")
    build_sharded(sig_paths, shard_dir, cfg, n_shards=3)
    return tmp, shard_dir, idx_path


def test_socket_fanout_bit_identical(corpus):
    """Socket transport == local clients == single index, both modes."""
    _, shard_dir, idx_path = corpus
    single = IndexSearcher(load_index(idx_path), backend="interpret",
                           corpus_block=64)
    local = load_sharded(shard_dir, backend="interpret", corpus_block=64,
                         dispatch="sequential")
    fac = loopback_client_factory(timeout_s=30.0)
    try:
        sock_router = load_sharded(shard_dir, backend="interpret",
                                   corpus_block=64, dispatch="sequential",
                                   client_factory=fac)
        n = single.index.n
        q = jnp.asarray(np.ascontiguousarray(
            single.index.words_host[[0, 3, n // 3, n // 2, n - 1]]))
        for mode in ("exact", "lsh"):
            want = single.search(q, 10, mode=mode)
            via_local = local.search(q, 10, mode=mode)
            got = sock_router.search(q, 10, mode=mode)
            for ref in (want, via_local):
                assert np.array_equal(got.indices, ref.indices), mode
                assert np.array_equal(got.scores, ref.scores), mode
            if mode == "lsh":
                assert np.array_equal(got.n_candidates, want.n_candidates)
        # the hello roundtrip reports per-shard doc counts
        assert [c.n for c in fac.clients] == \
            [s.index.n for s in sock_router.searchers]
    finally:
        fac.close()


def test_socket_set_sizes_rerank(tmp_path):
    """Theorem-1 rerank crosses the wire: query_sizes serialize too."""
    rng = np.random.default_rng(5)
    sets = [rng.choice(1 << S, rng.integers(30, 90), replace=False)
            for _ in range(96)]
    batch = from_lists(sets, max_nnz=128)
    fam = OPH.create(jax.random.PRNGKey(2), K, S, "2u", "rotation")
    wire = SignatureEngine(fam, b=B, packed=True).packed_signatures(batch)
    sizes = np.array([len(s) for s in sets], np.uint32)
    paths = []
    for i in range(3):
        p = str(tmp_path / f"c{i}.sig")
        write_sig_shard(p, np.asarray(wire.data[i * 32:(i + 1) * 32]),
                        np.zeros(32, np.float32), k=K, b=B, code_bits=B)
        paths.append(p)
    cfg = BandingConfig(16, 2, B)
    build_index(paths, str(tmp_path / "one.idx"), cfg, set_sizes=sizes, s=S)
    build_sharded(paths, str(tmp_path / "sh"), cfg, n_shards=3,
                  set_sizes=sizes, s=S)
    single = IndexSearcher(load_index(str(tmp_path / "one.idx")),
                           backend="interpret", corpus_block=32)
    fac = loopback_client_factory()
    try:
        router = load_sharded(str(tmp_path / "sh"), backend="interpret",
                              corpus_block=32, client_factory=fac)
        want = single.search(wire[:5], 5, mode="exact",
                             query_sizes=sizes[:5])
        got = router.search(wire[:5], 5, mode="exact",
                            query_sizes=sizes[:5])
        assert np.array_equal(got.indices, want.indices)
        assert np.array_equal(got.scores, want.scores)
    finally:
        fac.close()


def test_service_survives_garbage_and_remote_errors(corpus):
    """Garbage bytes and failing requests never kill the service."""
    _, shard_dir, idx_path = corpus
    searcher = IndexSearcher(load_index(idx_path), backend="interpret",
                             corpus_block=64)
    svc = ShardService(searcher)
    try:
        # raw garbage: connection is dropped, service stays up
        with socket.create_connection(svc.address, timeout=5.0) as s:
            s.sendall(b"\x00" * 64)
        # a framed-but-invalid request gets an error frame
        client = SocketShardClient(svc.address, timeout_s=5.0)
        q = np.ascontiguousarray(searcher.index.words_host[:2])
        with pytest.raises(RemoteShardError):
            client.dispatch(q, 5, mode="nonsense")()
        # and a valid request still round-trips afterwards
        got = client.dispatch(q, 5)()
        want = searcher.dispatch(q, 5)()
        assert np.array_equal(got.indices, want.indices)
        assert np.array_equal(got.scores, want.scores)
        assert client.n == searcher.index.n
    finally:
        svc.close()


def _fake_server(handler):
    """One-connection fake shard server running ``handler(conn)``."""
    srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    srv.bind(("127.0.0.1", 0))
    srv.listen(1)

    def run():
        try:
            conn, _ = srv.accept()
        except OSError:
            return
        with conn:
            handler(conn)
        srv.close()
    threading.Thread(target=run, daemon=True).start()
    return srv.getsockname()


def _drain_request(conn):
    # read until the client has sent its (single) request frame; the
    # fake servers don't parse it, they just misbehave afterwards
    conn.settimeout(5.0)
    try:
        conn.recv(1 << 20)
    except OSError:
        pass


def test_truncated_response_is_clean_error():
    """A response cut mid-frame raises TransportError -- no hang, and no
    torn SearchResult can ever escape."""
    full = _pack_msg({"kind": "result"},
                     [("indices", np.zeros((1, 5), np.int64)),
                      ("scores", np.zeros((1, 5), np.float32))])

    def handler(conn):
        _drain_request(conn)
        conn.sendall(full[:len(full) // 2])   # then close: torn frame

    addr = _fake_server(handler)
    client = SocketShardClient(addr, timeout_s=5.0)
    harvest = client.dispatch(np.zeros((1, 4), np.uint32), 5)
    with pytest.raises(TransportError, match="mid-frame"):
        harvest()


def test_corrupt_frame_surfaces_as_transport_error():
    """Bad magic and an undecodable header are both clean errors."""
    def bad_magic(conn):
        _drain_request(conn)
        conn.sendall(b"XXXX" + struct.pack("<I", 4) + b"junk")

    def bad_header(conn):
        _drain_request(conn)
        payload = struct.pack("<I", 8) + b"\xff" * 8
        conn.sendall(_MAGIC + struct.pack("<I", len(payload)) + payload)

    for handler, match in ((bad_magic, "magic"), (bad_header, "corrupt")):
        client = SocketShardClient(_fake_server(handler), timeout_s=5.0)
        harvest = client.dispatch(np.zeros((1, 4), np.uint32), 5)
        with pytest.raises(TransportError, match=match):
            harvest()


def test_short_array_buffer_is_clean_error():
    """A result frame whose declared arrays outrun the payload is torn --
    the client must reject it, not hand back a short-read ndarray."""
    def handler(conn):
        _drain_request(conn)
        hdr = (b'{"kind": "result", "arrays": '
               b'[["indices", "<i8", [4, 10]]]}')
        payload = struct.pack("<I", len(hdr)) + hdr + b"\x00" * 16
        conn.sendall(_MAGIC + struct.pack("<I", len(payload)) + payload)

    client = SocketShardClient(_fake_server(handler), timeout_s=5.0)
    harvest = client.dispatch(np.zeros((1, 4), np.uint32), 5)
    with pytest.raises(TransportError, match="truncated"):
        harvest()


def test_unresponsive_server_times_out():
    """A server that accepts and goes silent trips the socket timeout
    (an OSError, so retry policies treat it like any transport fault)."""
    def handler(conn):
        _drain_request(conn)
        threading.Event().wait(2.0)           # say nothing

    client = SocketShardClient(_fake_server(handler), timeout_s=0.2)
    harvest = client.dispatch(np.zeros((1, 4), np.uint32), 5)
    with pytest.raises(OSError):
        harvest()
