"""Sharded-index router + incremental append: the scale-out promises.

  * router top-k merge bit-identical (ids AND scores) to a single-index
    search over the same corpus, exact and LSH, including the Theorem-1
    set-sizes rerank,
  * ``append_index`` produces byte-equivalent tables/payload to a full
    rebuild over old + new shards (and appending through the router
    keeps global ids stable),
  * ``build_sharded`` manifest round trip + error paths.
"""

import glob
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.oph import OPH
from repro.data.pipeline import make_sharded_dataset
from repro.data.preprocess import preprocess_shards
from repro.data.sigshard import write_sig_shard
from repro.data.sparse import from_lists
from repro.data.synthetic import DatasetSpec
from repro.index import (BandingConfig, IndexSearcher, ShardedIndex,
                         append_index, build_index, build_sharded,
                         choose_band_config, load_index, load_sharded,
                         merge_topk)
from repro.index.query import SearchResult
from repro.kernels import SignatureEngine

K, S, B = 128, 16, 8


@pytest.fixture(scope="module")
def corpus(tmp_path_factory):
    """Synthetic corpus as .sig shards + one reference .idx."""
    tmp = str(tmp_path_factory.mktemp("router_corpus"))
    spec = DatasetSpec("routertest", n=420, D=1 << S, avg_nnz=48,
                       n_prototypes=8, overlap=0.8, seed=11)
    raw = make_sharded_dataset(spec, os.path.join(tmp, "raw"), n_shards=5)
    fam = OPH.create(jax.random.PRNGKey(1), K, S, "2u", "rotation")
    preprocess_shards(raw, os.path.join(tmp, "sig"), fam, b=B,
                      chunk_size=64, loader_kwargs={"lane_multiple": 8})
    sig_paths = sorted(glob.glob(os.path.join(tmp, "sig", "*.sig")))
    assert len(sig_paths) >= 4
    cfg = choose_band_config(K, B, threshold=0.5)
    idx_path = os.path.join(tmp, "single.idx")
    build_index(sig_paths, idx_path, cfg)
    return tmp, sig_paths, cfg, idx_path


@pytest.mark.parametrize("n_shards", [2, 3])
def test_router_topk_bit_identical_to_single_index(corpus, tmp_path,
                                                   n_shards):
    """Fan-out + merge == single-index search: same ids, same scores,
    exact and LSH, search() and submit()/flush()."""
    tmp, sig_paths, cfg, idx_path = corpus
    single = IndexSearcher(load_index(idx_path), backend="interpret",
                           corpus_block=128)
    shard_dir = str(tmp_path / f"shards{n_shards}")
    built = build_sharded(sig_paths, shard_dir, cfg, n_shards=n_shards)
    assert len(built) == n_shards
    router = load_sharded(shard_dir, backend="interpret", corpus_block=128)
    assert router.n == single.index.n
    n = single.index.n
    picks = [0, 7, n // 3, n // 2, n - 2, n - 1]
    q = jnp.asarray(np.ascontiguousarray(single.index.words_host[picks]))
    for mode in ("exact", "lsh"):
        want = single.search(q, 10, mode=mode)
        got = router.search(q, 10, mode=mode)
        assert np.array_equal(got.indices, want.indices), mode
        assert np.array_equal(got.scores, want.scores), mode
        if mode == "lsh":
            assert np.array_equal(got.n_candidates, want.n_candidates)
    # batched admission returns the same per-ticket rows
    rows = [np.asarray(single.index.words_host[i])
            for i in (3, n // 2 + 1, n - 5)]
    tickets = [router.submit(r) for r in rows]
    out = router.flush(5, mode="exact")
    want = single.search(jnp.asarray(np.stack(rows)), 5, mode="exact")
    for i, t in enumerate(tickets):
        assert np.array_equal(out[t].indices[0], want.indices[i])
        assert np.array_equal(out[t].scores[0], want.scores[i])
    assert router.flush() == {}


def test_router_with_set_sizes_rerank(tmp_path):
    """Theorem-1 rerank flows through the router: per-shard doc sizes,
    merged results equal the single index's."""
    rng = np.random.default_rng(9)
    sets = [rng.choice(1 << S, rng.integers(30, 90), replace=False)
            for _ in range(96)]
    batch = from_lists(sets, max_nnz=128)
    fam = OPH.create(jax.random.PRNGKey(2), K, S, "2u", "rotation")
    wire = SignatureEngine(fam, b=B, packed=True).packed_signatures(batch)
    sizes = np.array([len(s) for s in sets], np.uint32)
    paths = []
    for i in range(3):
        p = str(tmp_path / f"c{i}.sig")
        write_sig_shard(p, np.asarray(wire.data[i * 32:(i + 1) * 32]),
                        np.zeros(32, np.float32), k=K, b=B, code_bits=B)
        paths.append(p)
    cfg = BandingConfig(16, 2, B)
    build_index(paths, str(tmp_path / "one.idx"), cfg, set_sizes=sizes, s=S)
    build_sharded(paths, str(tmp_path / "sh"), cfg, n_shards=3,
                  set_sizes=sizes, s=S)
    single = IndexSearcher(load_index(str(tmp_path / "one.idx")),
                           backend="interpret", corpus_block=32)
    router = load_sharded(str(tmp_path / "sh"), backend="interpret",
                          corpus_block=32)
    want = single.search(wire[:5], 5, mode="exact", query_sizes=sizes[:5])
    got = router.search(wire[:5], 5, mode="exact", query_sizes=sizes[:5])
    assert np.array_equal(got.indices, want.indices)
    assert np.array_equal(got.scores, want.scores)
    with pytest.raises(ValueError):              # sizes still required
        router.search(wire[:5], 5, mode="exact")


def test_append_equals_full_rebuild(corpus, tmp_path):
    """append_index over the tail shards == build_index over everything:
    identical header, tables, labels, payload -- and identical queries."""
    tmp, sig_paths, cfg, idx_path = corpus
    full = load_index(idx_path)
    grown_path = str(tmp_path / "grown.idx")
    build_index(sig_paths[:2], grown_path, cfg)
    meta = append_index(grown_path, sig_paths[2:])
    grown = load_index(grown_path)
    assert meta == full.meta
    np.testing.assert_array_equal(grown.labels, full.labels)
    np.testing.assert_array_equal(grown.band_offsets, full.band_offsets)
    np.testing.assert_array_equal(grown.keys, full.keys)
    np.testing.assert_array_equal(grown.bucket_offsets, full.bucket_offsets)
    np.testing.assert_array_equal(grown.postings, full.postings)
    np.testing.assert_array_equal(grown.words_host, full.words_host)
    q = jnp.asarray(np.ascontiguousarray(full.words_host[50:60]))
    want = IndexSearcher(full, backend="interpret",
                         corpus_block=128).search(q, 10)
    got = IndexSearcher(grown, backend="interpret",
                        corpus_block=128).search(q, 10)
    assert np.array_equal(got.indices, want.indices)
    assert np.array_equal(got.scores, want.scores)


def test_append_wire_and_set_size_validation(corpus, tmp_path):
    tmp, sig_paths, cfg, idx_path = corpus
    target = str(tmp_path / "t.idx")
    build_index(sig_paths[:1], target, cfg)
    bad = str(tmp_path / "bad.sig")
    rng = np.random.default_rng(0)
    w4 = rng.integers(0, 2**32, (4, 16), dtype=np.uint64).astype(np.uint32)
    write_sig_shard(bad, w4, np.zeros(4, np.float32), k=64, b=B, code_bits=B)
    with pytest.raises(ValueError, match="wire format"):
        append_index(target, [bad])
    with pytest.raises(ValueError, match="no set sizes"):
        append_index(target, sig_paths[1:2],
                     set_sizes=np.ones(64, np.uint32))


def test_router_append_grows_last_shard(corpus, tmp_path):
    """ShardedIndex.append: existing global ids stay put, the grown
    router matches a single index over all shards."""
    tmp, sig_paths, cfg, idx_path = corpus
    shard_dir = str(tmp_path / "growing")
    build_sharded(sig_paths[:3], shard_dir, cfg, n_shards=2)
    router = load_sharded(shard_dir, backend="interpret", corpus_block=128)
    n_before = router.n
    router.append(sig_paths[3:])
    assert router.n > n_before
    assert router.n_shards == 2                  # grew in place
    full = IndexSearcher(load_index(idx_path), backend="interpret",
                         corpus_block=128)
    assert router.n == full.index.n
    q = jnp.asarray(np.ascontiguousarray(
        full.index.words_host[[1, n_before - 1, n_before, router.n - 1]]))
    want = full.search(q, 10, mode="exact")
    got = router.search(q, 10, mode="exact")
    assert np.array_equal(got.indices, want.indices)
    assert np.array_equal(got.scores, want.scores)
    # the updated manifest reloads to the same state
    reloaded = load_sharded(shard_dir, backend="interpret", corpus_block=128)
    got2 = reloaded.search(q, 10, mode="exact")
    assert np.array_equal(got2.indices, want.indices)


def test_build_sharded_manifest_and_errors(corpus, tmp_path):
    tmp, sig_paths, cfg, idx_path = corpus
    import json
    shard_dir = str(tmp_path / "m")
    built = build_sharded(sig_paths, shard_dir, cfg, n_shards=3)
    with open(os.path.join(shard_dir, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["version"] == 1 and len(manifest["shards"]) == 3
    counts = [m.n for _, m in built]
    assert manifest["n"] == sum(counts)
    assert manifest["offsets"] == [0, counts[0], counts[0] + counts[1]]
    assert all(c > 0 for c in counts)            # no empty shard
    with pytest.raises(ValueError, match="n_shards"):
        build_sharded(sig_paths, shard_dir, cfg,
                      n_shards=len(sig_paths) + 1)
    with pytest.raises(OSError):
        load_sharded(str(tmp_path))              # no manifest.json here


def test_merge_topk_tie_break_and_padding():
    """merge_topk reproduces lax.top_k's lowest-id tie rule across shard
    boundaries and pads short corpora like a single index does."""
    r0 = SearchResult(np.array([[1, 0, -1]]),
                      np.array([[0.5, 0.5, -np.inf]], np.float32))
    r1 = SearchResult(np.array([[0, 2, -1]]),
                      np.array([[0.7, 0.5, -np.inf]], np.float32))
    out = merge_topk([r0, r1], [0, 10], 3)
    # 0.7 first, then the tied 0.5s in ascending GLOBAL-id order (0 then
    # 1) -- even though shard 0 reported them in the opposite order: the
    # merge rule is a pure function of (score, global id), never of the
    # arrival position, so any partition / dispatch order converges
    np.testing.assert_array_equal(out.indices, [[10, 0, 1]])
    np.testing.assert_array_equal(out.scores,
                                  np.array([[0.7, 0.5, 0.5]], np.float32))
    out = merge_topk([r0], [0], 5)               # fewer docs than topk
    np.testing.assert_array_equal(out.indices, [[0, 1, -1, -1, -1]])
    with pytest.raises(ValueError):
        merge_topk([], [], 3)


def test_merge_topk_all_empty_shards():
    """Every shard empty (e.g. LSH with zero candidates anywhere): the
    merge yields pure padding, not garbage ids."""
    empty = SearchResult(np.full((2, 3), -1),
                         np.full((2, 3), -np.inf, np.float32))
    out = merge_topk([empty, empty, empty], [0, 10, 20], 3)
    np.testing.assert_array_equal(out.indices, np.full((2, 3), -1))
    assert np.all(np.isneginf(out.scores))


def test_merge_topk_topk_exceeds_total_docs():
    """topk larger than ALL shards' real docs combined: valid docs first
    (score order), then -1/-inf padding out to topk."""
    r0 = SearchResult(np.array([[1, 0, -1]]),
                      np.array([[0.9, 0.4, -np.inf]], np.float32))
    r1 = SearchResult(np.array([[0, -1, -1]]),
                      np.array([[0.6, -np.inf, -np.inf]], np.float32))
    out = merge_topk([r0, r1], [0, 10], 8)
    np.testing.assert_array_equal(out.indices,
                                  [[1, 10, 0, -1, -1, -1, -1, -1]])
    np.testing.assert_array_equal(
        out.scores[0, :3], np.array([0.9, 0.6, 0.4], np.float32))
    assert np.all(np.isneginf(out.scores[0, 3:]))


def test_merge_topk_tie_run_spans_three_shards():
    """A tie run crossing every shard boundary resolves in ascending
    global-id order -- lax.top_k's rule over the concatenated corpus."""
    tie = np.float32(0.5)
    r0 = SearchResult(np.array([[0, 2]]), np.array([[tie, tie]], np.float32))
    r1 = SearchResult(np.array([[1, 3]]), np.array([[tie, tie]], np.float32))
    r2 = SearchResult(np.array([[0, 4]]), np.array([[tie, tie]], np.float32))
    out = merge_topk([r0, r1, r2], [0, 10, 20], 6)
    # per-shard results keep ascending local id inside the tie run, so
    # the merge must produce ascending GLOBAL ids across all shards
    np.testing.assert_array_equal(out.indices, [[0, 2, 11, 13, 20, 24]])
    assert np.all(out.scores == tie)


@pytest.mark.parametrize("seed", range(6))
def test_merge_topk_any_partition_matches_lax_topk(seed):
    """Property test: partition a scored corpus into 1..8 shards at
    random cut points, run a real per-shard lax.top_k, merge in a
    SHUFFLED shard order -- ids and scores must be bit-identical to
    lax.top_k over the unpartitioned corpus.  Scores are quantized so
    duplicate values and cross-shard tie runs are everywhere."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(20, 200))
    topk = int(rng.integers(1, 13))
    nq = 3
    scores = (rng.integers(0, 6, (nq, n)) / 4.0).astype(np.float32)
    kk = min(topk, n)
    want_s, want_i = jax.lax.top_k(jnp.asarray(scores), kk)
    n_shards = int(rng.integers(1, 9))
    cuts = np.sort(rng.choice(np.arange(1, n),
                              size=min(n_shards - 1, n - 1),
                              replace=False)) if n_shards > 1 else []
    bounds = [0, *np.asarray(cuts, int).tolist(), n]
    results, offsets = [], []
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        s_i, i_i = jax.lax.top_k(jnp.asarray(scores[:, lo:hi]),
                                 min(topk, hi - lo))
        results.append(SearchResult(np.asarray(i_i).astype(np.int64),
                                    np.asarray(s_i)))
        offsets.append(lo)
    perm = rng.permutation(len(results))         # arrival-order-blind
    out = merge_topk([results[p] for p in perm],
                     [offsets[p] for p in perm], topk)
    np.testing.assert_array_equal(out.indices[:, :kk], np.asarray(want_i))
    np.testing.assert_array_equal(out.scores[:, :kk], np.asarray(want_s))
    assert np.all(out.indices[:, kk:] == -1)     # padding past the corpus
    assert np.all(np.isneginf(out.scores[:, kk:]))


def test_router_append_spills_into_new_shards(corpus, tmp_path):
    """With a max_shard_docs budget, append extends the last shard only
    while it has headroom, then spills into NEW tail shards; global ids
    stay put and the grown router matches a single index over all docs.
    A second process (fresh load_sharded) picks the spill up via the
    manifest."""
    tmp, sig_paths, cfg, idx_path = corpus
    shard_dir = str(tmp_path / "spilling")
    build_sharded(sig_paths[:3], shard_dir, cfg, n_shards=2)
    router = load_sharded(shard_dir, backend="interpret", corpus_block=128,
                          max_shard_docs=1)      # every file spills
    n_before, shards_before = router.n, router.n_shards
    n_files = len(sig_paths) - 3
    touched = router.append(sig_paths[3:])
    # budget below every file size: each appended file becomes its own
    # NEW shard, the original shards never grow
    assert router.n_shards == shards_before + n_files
    assert all(os.path.basename(p).startswith("shard_")
               for p, _ in touched)
    assert [p for p, _ in touched] == list(router.paths[-n_files:])
    full = IndexSearcher(load_index(idx_path), backend="interpret",
                         corpus_block=128)
    assert router.n == full.index.n
    q = jnp.asarray(np.ascontiguousarray(
        full.index.words_host[[1, n_before - 1, n_before, router.n - 1]]))
    want = full.search(q, 10, mode="exact")
    got = router.search(q, 10, mode="exact")
    assert np.array_equal(got.indices, want.indices)
    assert np.array_equal(got.scores, want.scores)
    # reader-side pickup: an independently loaded router refreshes into
    # the spilled shard set
    reader = load_sharded(shard_dir, backend="interpret", corpus_block=128)
    assert reader.n_shards == router.n_shards
    got2 = reader.search(q, 10, mode="exact")
    assert np.array_equal(got2.indices, want.indices)
    assert np.array_equal(got2.scores, want.scores)


def test_router_append_spill_respects_budget_granularity(corpus, tmp_path):
    """Spill planning is at .sig-file granularity: a shard may overshoot
    the budget by at most one file, and each spilled shard is refilled
    up to the budget before the next one starts."""
    tmp, sig_paths, cfg, idx_path = corpus
    from repro.data.sigshard import read_sig_meta
    counts = [read_sig_meta(p).n for p in sig_paths]
    shard_dir = str(tmp_path / "granular")
    build_sharded(sig_paths[:2], shard_dir, cfg, n_shards=2)
    # budget below the last shard's size -> the append is a pure spill
    budget = min(counts) // 2
    router = load_sharded(shard_dir, backend="interpret", corpus_block=128,
                          max_shard_docs=budget)
    router.append(sig_paths[2:])
    # pure spill: the two original shards never grew
    from repro.index.builder import read_manifest
    man = read_manifest(shard_dir)
    assert man["offsets"][:2] == [0, counts[0]]
    assert router.n == sum(counts)
    # every spilled shard holds >= 1 file and started below the budget
    spilled = [b - a for a, b in zip(man["offsets"][2:],
                                     man["offsets"][3:] + [man["n"]])]
    assert spilled and all(s > 0 for s in spilled)
    assert len(spilled) == len(sig_paths) - 2    # budget < every file size


def test_router_append_spill_crash_before_manifest_is_invisible(
        corpus, tmp_path, monkeypatch):
    """Fault injection at the spill-append commit point: the new shard
    is fully written but the process dies BEFORE the manifest rewrite.
    Readers must stay on the old generation with no torn shard visible,
    and a clean retry + refresh() must converge."""
    tmp, sig_paths, cfg, idx_path = corpus
    import repro.index.router as router_mod
    shard_dir = str(tmp_path / "crashy")
    build_sharded(sig_paths[:3], shard_dir, cfg, n_shards=2)
    writer = load_sharded(shard_dir, backend="interpret", corpus_block=128,
                          max_shard_docs=1)      # pure spill, no grow
    reader = load_sharded(shard_dir, backend="interpret", corpus_block=128)
    gen0, n0, paths0 = reader.generation, reader.n, reader.paths
    q = jnp.asarray(np.ascontiguousarray(
        reader.searchers[0].index.words_host[[0, 3]]))
    want = reader.search(q, 5)

    def boom(*a, **kw):
        raise RuntimeError("injected crash before manifest publish")

    monkeypatch.setattr(router_mod, "write_manifest", boom)
    with pytest.raises(RuntimeError, match="injected crash"):
        writer.append(sig_paths[3:4])
    monkeypatch.undo()

    # reader side: manifest untouched -> refresh is a no-op, same corpus,
    # same results; no temp files leak, no lock is left held
    assert reader.refresh() is False
    assert reader.generation == gen0 and reader.n == n0
    assert reader.paths == paths0
    got = reader.search(q, 5)
    assert np.array_equal(got.indices, want.indices)
    assert np.array_equal(got.scores, want.scores)
    assert not [f for f in os.listdir(shard_dir) if ".tmp" in f]
    # ... and a fresh load (new process) sees only the old generation
    fresh = load_sharded(shard_dir, backend="interpret", corpus_block=128)
    assert fresh.generation == gen0 and fresh.n == n0

    # clean retry: the orphaned shard file from the crash is atomically
    # overwritten, the manifest lands, readers converge via refresh()
    writer2 = load_sharded(shard_dir, backend="interpret",
                           corpus_block=128, max_shard_docs=1)
    writer2.append(sig_paths[3:4])
    assert reader.refresh() is True
    assert reader.generation > gen0
    assert reader.n_shards == 3 and reader.n > n0
    full_idx = str(tmp_path / "full.idx")
    build_index(sig_paths[:4], full_idx, cfg)
    single = IndexSearcher(load_index(full_idx), backend="interpret",
                           corpus_block=128)
    want2 = single.search(q, 5)
    got2 = reader.search(q, 5)
    assert np.array_equal(got2.indices, want2.indices)
    assert np.array_equal(got2.scores, want2.scores)
