"""End-to-end system tests: the paper's full pipeline on synthetic data.

disk shards -> chunked loader -> Pallas minhash preprocessing -> b-bit
signatures -> batch SVM + online SGD training -> accuracy; plus the
online-learning load-time accounting the paper's Table 4 reports.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Hash2U, lowest_bits
from repro.data import TINY, generate
from repro.data.pipeline import ChunkedLoader, make_sharded_dataset
from repro.kernels import batch_signatures
from repro.models.linear import (LinearModel, accuracy, make_loss_fn,
                                 sgd_svm_init, sgd_svm_step)
from repro.optim import adamw, constant
from repro.train import TrainState, Trainer, make_train_step, online_epochs


@pytest.fixture(scope="module")
def sharded(tmp_path_factory):
    d = str(tmp_path_factory.mktemp("shards"))
    paths = make_sharded_dataset(TINY, d, n_shards=3, n=320)
    return paths


def test_full_pipeline_batch_learning(sharded):
    k, b, s = 128, 8, 16
    fam = Hash2U.create(jax.random.PRNGKey(0), k, s)
    loader = ChunkedLoader(sharded, chunk_size=64, lane_multiple=8)

    sigs, labels = [], []
    for chunk in loader:                       # Pallas kernel preprocessing
        sigs.append(np.asarray(batch_signatures(chunk, fam, b=b)))
        labels.append(np.asarray(chunk.labels))
    sig = jnp.asarray(np.concatenate(sigs)).astype(jnp.uint32)
    y = jnp.asarray(np.concatenate(labels))
    n_train = int(sig.shape[0] * 0.75)

    loss = make_loss_fn("svm", "hashed", b, C=1.0)
    opt = adamw(constant(0.05))
    state = TrainState.create(LinearModel.create(k * 2**b), opt)
    step = make_train_step(lambda p, batch: loss(p, *batch), opt)
    state = Trainer(step).fit(
        state, lambda: iter([(sig[:n_train], y[:n_train])] * 100), 100)
    acc = float(accuracy(state.params, sig[n_train:], y[n_train:],
                         feature_kind="hashed", b=b))
    assert acc > 0.85, acc


def test_online_learning_with_load_accounting(sharded):
    """Online SGD over epochs re-loading from disk; hashed data loads
    faster than raw data (the paper's §6 claim, directionally)."""
    k, b, s = 64, 8, 16
    fam = Hash2U.create(jax.random.PRNGKey(1), k, s)

    # Preprocess once; "hashed dataset" is the signatures on disk (here:
    # in memory as a small array -- the size ratio is what matters).
    loader = ChunkedLoader(sharded, chunk_size=64, lane_multiple=8)
    chunks = list(loader)
    sig_chunks = [(jnp.asarray(batch_signatures(c, fam, b=b)), c.labels)
                  for c in chunks]
    raw_bytes = sum(c.nbytes() for c in chunks)
    hashed_bytes = sum(int(s_.size) * (b // 8 or 1) for s_, _ in sig_chunks)
    assert hashed_bytes < raw_bytes / 4   # data reduction

    sgd_state = sgd_svm_init(k * 2**b)
    step = jax.jit(functools.partial(sgd_svm_step, lam=1e-4, eta0=0.5, b=b))

    def epoch_batches():
        for s_, y in sig_chunks:
            yield (s_, y)

    def sgd_wrap(state, batch):
        return step(state, batch[0], batch[1])

    final, times, _ = online_epochs(sgd_wrap, sgd_state, epoch_batches, 3)
    assert len(times) == 3
    assert all(t.train_s > 0 for t in times)


def test_preprocessing_deterministic_across_chunk_sizes(sharded):
    """Chunk size must not change signatures (paper Figs 1-3 sweep)."""
    fam = Hash2U.create(jax.random.PRNGKey(2), 32, 16)
    outs = []
    for cs in (32, 64, 256):
        loader = ChunkedLoader(sharded, chunk_size=cs, lane_multiple=8)
        sigs = np.concatenate(
            [np.asarray(batch_signatures(c, fam, b=4)) for c in loader])
        outs.append(sigs)
    assert np.array_equal(outs[0], outs[1])
    assert np.array_equal(outs[1], outs[2])
