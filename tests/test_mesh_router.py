"""Device-parallel retrieval mesh (multidevice tier: 8 forced host
devices, selected with ``-m multidevice``).

The PR's acceptance bar: under ``shard_map`` dispatch with round-robin
shard placement on the mesh's ``"data"`` axis, ``ShardedIndex.search``
is **bit-identical** -- ids AND scores -- to the sequential host-merge
fan-out and to a single-index search, for the exact scan and the LSH
rerank, including under a concurrent spill-append.
"""

import glob
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.oph import OPH
from repro.data.pipeline import make_sharded_dataset
from repro.data.preprocess import preprocess_shards
from repro.data.sigshard import write_sig_shard
from repro.data.sparse import from_lists
from repro.data.synthetic import DatasetSpec
from repro.index import (BandingConfig, IndexSearcher, build_index,
                         build_sharded, choose_band_config, load_index,
                         load_sharded)
from repro.kernels import SignatureEngine
from repro.launch.mesh import make_debug_mesh

pytestmark = pytest.mark.multidevice

K, S, B = 128, 16, 8


@pytest.fixture(scope="module")
def corpus(tmp_path_factory):
    """Synthetic corpus as .sig shards + one reference .idx."""
    tmp = str(tmp_path_factory.mktemp("mesh_corpus"))
    spec = DatasetSpec("meshtest", n=420, D=1 << S, avg_nnz=48,
                       n_prototypes=8, overlap=0.8, seed=11)
    raw = make_sharded_dataset(spec, os.path.join(tmp, "raw"), n_shards=5)
    fam = OPH.create(jax.random.PRNGKey(1), K, S, "2u", "rotation")
    preprocess_shards(raw, os.path.join(tmp, "sig"), fam, b=B,
                      chunk_size=64, loader_kwargs={"lane_multiple": 8})
    sig_paths = sorted(glob.glob(os.path.join(tmp, "sig", "*.sig")))
    cfg = choose_band_config(K, B, threshold=0.5)
    idx_path = os.path.join(tmp, "single.idx")
    build_index(sig_paths, idx_path, cfg)
    return tmp, sig_paths, cfg, idx_path


def _queries(index, picks):
    return jnp.asarray(np.ascontiguousarray(index.words_host[picks]))


@pytest.mark.parametrize("n_shards,n_dev", [(2, 2), (3, 8), (5, 4), (6, 8)])
def test_mesh_dispatch_bit_identical(corpus, tmp_path, host_devices,
                                     n_shards, n_dev):
    """shard_map fan-out == sequential fan-out == single index, exact
    and LSH, including shard counts above the device count (round-robin
    wrap: 5 shards on 4 devices stacks two shards on device 0)."""
    tmp, sig_paths, cfg, idx_path = corpus
    single = IndexSearcher(load_index(idx_path), backend="interpret",
                           corpus_block=128)
    shard_dir = str(tmp_path / "shards")
    build_sharded(sig_paths, shard_dir, cfg, n_shards=n_shards)
    mesh = make_debug_mesh(n_dev, axes=("data",))
    router = load_sharded(shard_dir, mesh=mesh, backend="interpret",
                          corpus_block=128)
    n = single.index.n
    q = _queries(single.index, [0, 7, n // 3, n // 2, n - 2, n - 1])
    for mode in ("exact", "lsh"):
        want = single.search(q, 10, mode=mode)
        got = router.search(q, 10, mode=mode)            # auto -> mesh
        assert np.array_equal(got.indices, want.indices), mode
        assert np.array_equal(got.scores, want.scores), mode
        seq = router.search(q, 10, mode=mode, dispatch="sequential")
        assert np.array_equal(seq.indices, want.indices), mode
        assert np.array_equal(seq.scores, want.scores), mode
        # LSH candidate accounting survives the collective: the summed
        # per-shard union sizes equal the single index's unions
        # (disjoint shards), on both dispatch paths
        if mode == "lsh":
            assert np.array_equal(got.n_candidates, want.n_candidates)
            assert np.array_equal(seq.n_candidates, want.n_candidates)
    # the collective path (not the sequential loop) served the auto
    # dispatches above -- one shard_map LSH flush, one exact
    assert router.mesh_lsh_dispatches == 1
    assert router.mesh_exact_dispatches == 1


def test_mesh_placement_lands_on_distinct_devices(corpus, tmp_path,
                                                  host_devices):
    """Round-robin placement: with S <= D each shard searcher is pinned
    to its own data-axis device, and the searcher honors the pin."""
    tmp, sig_paths, cfg, _ = corpus
    shard_dir = str(tmp_path / "shards")
    build_sharded(sig_paths, shard_dir, cfg, n_shards=4)
    mesh = make_debug_mesh(8, axes=("data",))
    router = load_sharded(shard_dir, mesh=mesh, backend="interpret",
                          corpus_block=128)
    devs = [s.device for s in router.searchers]
    assert devs == list(host_devices[:4])
    # the pinned device actually holds each shard's corpus after a
    # sequential per-shard dispatch (every searcher uploads its corpus
    # inside its jax.default_device context)
    q = _queries(router.searchers[0].index, [0, 1])
    router.search(q, 5, mode="exact", dispatch="sequential")
    for s in router.searchers:
        assert s.index.corpus.devices() == {s.device}


def test_mesh_with_set_sizes_rerank(tmp_path, host_devices):
    """The exact Theorem-1 rerank (stored set sizes + query_sizes) flows
    through the shard_map dispatch bit-identically."""
    rng = np.random.default_rng(9)
    sets = [rng.choice(1 << S, rng.integers(30, 90), replace=False)
            for _ in range(96)]
    batch = from_lists(sets, max_nnz=128)
    fam = OPH.create(jax.random.PRNGKey(2), K, S, "2u", "rotation")
    wire = SignatureEngine(fam, b=B, packed=True).packed_signatures(batch)
    sizes = np.array([len(s) for s in sets], np.uint32)
    paths = []
    for i in range(3):
        p = str(tmp_path / f"c{i}.sig")
        write_sig_shard(p, np.asarray(wire.data[i * 32:(i + 1) * 32]),
                        np.zeros(32, np.float32), k=K, b=B, code_bits=B)
        paths.append(p)
    cfg = BandingConfig(16, 2, B)
    build_index(paths, str(tmp_path / "one.idx"), cfg, set_sizes=sizes, s=S)
    build_sharded(paths, str(tmp_path / "sh"), cfg, n_shards=3,
                  set_sizes=sizes, s=S)
    single = IndexSearcher(load_index(str(tmp_path / "one.idx")),
                           backend="interpret", corpus_block=32)
    mesh = make_debug_mesh(8, axes=("data",))
    router = load_sharded(str(tmp_path / "sh"), mesh=mesh,
                          backend="interpret", corpus_block=32)
    q = jnp.asarray(np.asarray(wire.data[:5]))
    qs = sizes[:5]
    for mode in ("exact", "lsh"):
        want = single.search(q, 8, mode=mode, query_sizes=qs)
        got = router.search(q, 8, mode=mode, query_sizes=qs)
        assert np.array_equal(got.indices, want.indices), mode
        assert np.array_equal(got.scores, want.scores), mode
    assert router.mesh_lsh_dispatches == 1
    # forgetting query_sizes fails loudly on the mesh path too
    with pytest.raises(ValueError, match="query_sizes"):
        router.search(q, 8)
    with pytest.raises(ValueError, match="query_sizes"):
        router.search(q, 8, mode="lsh")


def test_mesh_submit_flush_admission(corpus, tmp_path, host_devices):
    """Batched admission drains through the mesh dispatcher: per-ticket
    rows equal the single index's batch rows."""
    tmp, sig_paths, cfg, idx_path = corpus
    single = IndexSearcher(load_index(idx_path), backend="interpret",
                           corpus_block=128)
    shard_dir = str(tmp_path / "shards")
    build_sharded(sig_paths, shard_dir, cfg, n_shards=3)
    router = load_sharded(shard_dir,
                          mesh=make_debug_mesh(8, axes=("data",)),
                          backend="interpret", corpus_block=128)
    n = single.index.n
    rows = [np.asarray(single.index.words_host[i])
            for i in (3, n // 2 + 1, n - 5)]
    tickets = [router.submit(r) for r in rows]
    out = router.flush(5, mode="exact")
    want = single.search(jnp.asarray(np.stack(rows)), 5, mode="exact")
    for i, t in enumerate(tickets):
        assert np.array_equal(out[t].indices[0], want.indices[i])
        assert np.array_equal(out[t].scores[0], want.scores[i])


def test_mesh_streamed_shards_rejected(corpus, tmp_path, host_devices):
    """An out-of-core (device-window) shard cannot be mesh-dispatched:
    fail loudly instead of silently falling back."""
    tmp, sig_paths, cfg, _ = corpus
    shard_dir = str(tmp_path / "shards")
    build_sharded(sig_paths, shard_dir, cfg, n_shards=2)
    mesh = make_debug_mesh(4, axes=("data",))
    router = load_sharded(shard_dir, mesh=mesh, backend="interpret",
                          corpus_block=64, max_device_bytes=4096)
    assert any(s.streamed for s in router.searchers)
    q = _queries(router.searchers[0].index, [0, 1])
    with pytest.raises(ValueError, match="max_device_bytes"):
        router.search(q, 5)
    # the sequential fan-out still streams fine -- but not through a
    # device pin, so build it without the mesh
    plain = load_sharded(shard_dir, backend="interpret", corpus_block=64,
                         max_device_bytes=4096)
    out = plain.search(q, 5, dispatch="sequential")
    assert out.indices.shape == (2, 5)


def test_mesh_search_racing_spill_append_never_torn(corpus, tmp_path,
                                                    host_devices):
    """Concurrent spill-appends (new shards materialize mid-run) while
    the mesh dispatcher serves: every result is bit-identical to a
    sequential search against the SAME generation's corpus -- never a
    torn mix, and the stacked mesh corpus never outlives its state."""
    tmp, sig_paths, cfg, _ = corpus
    shard_dir = str(tmp_path / "shards")
    build_sharded(sig_paths[:3], shard_dir, cfg, n_shards=2)
    mesh = make_debug_mesh(8, axes=("data",))
    writer = load_sharded(shard_dir, backend="interpret", corpus_block=128,
                          max_shard_docs=80)
    reader = load_sharded(shard_dir, mesh=mesh, backend="interpret",
                          corpus_block=128)
    q = _queries(reader.searchers[0].index, [0, 5, 11])

    stop = threading.Event()
    failures = []

    def appender():
        try:
            for sig in sig_paths[3:]:
                writer.append([sig])
        except Exception as e:                     # pragma: no cover
            failures.append(e)
        finally:
            stop.set()

    t = threading.Thread(target=appender)
    t.start()
    try:
        while not stop.is_set():
            reader.refresh()
            got = reader.search(q, 10)                       # mesh
            want = reader.search(q, 10, dispatch="sequential")
            assert np.array_equal(got.indices, want.indices)
            assert np.array_equal(got.scores, want.scores)
    finally:
        t.join()
    assert not failures
    # final converged state: spilled shards exist, placed, and the mesh
    # result matches a from-scratch single index over everything
    reader.refresh()
    assert reader.n_shards > 2
    assert [s.device for s in reader.searchers] == \
        [host_devices[i % 8] for i in range(reader.n_shards)]
    full_idx = str(tmp_path / "full.idx")
    build_index(sig_paths, full_idx, cfg)
    single = IndexSearcher(load_index(full_idx), backend="interpret",
                           corpus_block=128)
    want = single.search(q, 10)
    got = reader.search(q, 10)
    assert np.array_equal(got.indices, want.indices)
    assert np.array_equal(got.scores, want.scores)
