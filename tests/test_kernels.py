"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hashing import Hash2U, Hash4U
from repro.kernels import batch_signatures, minhash2u, minhash4u, sigbag
from repro.kernels import ref as kref
from repro.data.sparse import from_lists

RNG = np.random.default_rng(7)


def _case(n, nnz, k, s):
    indices = jnp.asarray(RNG.integers(0, 2**s, (n, nnz)), jnp.int32)
    counts = jnp.asarray(RNG.integers(1, nnz + 1, (n,)), jnp.int32)
    return indices, counts


# full (shape x s) product in the slow tier; fast tier keeps the s=24 row
# (all padding paths) plus the aligned shape at the s extremes
_2U_CASES = [
    pytest.param(n, nnz, k, s,
                 marks=[] if (s == 24 or (n, nnz, k) == (8, 128, 128))
                 else [pytest.mark.slow])
    for n, nnz, k in [(3, 100, 20), (8, 128, 128), (17, 300, 70),
                      (5, 513, 33)]
    for s in (12, 24, 32)]


@pytest.mark.parametrize("n,nnz,k,s", _2U_CASES)
def test_minhash2u_kernel_matches_ref(n, nnz, k, s):
    indices, counts = _case(n, nnz, k, s)
    fam = Hash2U.create(jax.random.PRNGKey(n * 1000 + k), k, s)
    got = minhash2u(indices, counts, fam.a1, fam.a2, s=s)
    want = kref.minhash2u_ref(indices, counts.reshape(-1, 1), fam.a1, fam.a2,
                              s=s)
    assert np.array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("b", [1, 2, 4, 8, 12])
def test_minhash2u_fused_bbit(b):
    indices, counts = _case(6, 200, 50, 20)
    fam = Hash2U.create(jax.random.PRNGKey(b), 50, 20)
    got = minhash2u(indices, counts, fam.a1, fam.a2, s=20, b=b)
    full = kref.minhash2u_ref(indices, counts.reshape(-1, 1), fam.a1, fam.a2,
                              s=20)
    assert np.array_equal(np.asarray(got),
                          np.asarray(full) & ((1 << b) - 1))
    assert int(jnp.max(got)) < (1 << b)


@pytest.mark.parametrize("n,nnz,k,s", [
    (4, 100, 16, 16),
    pytest.param(9, 257, 40, 24, marks=pytest.mark.slow),
    (8, 128, 128, 30)])
def test_minhash4u_kernel_matches_ref(n, nnz, k, s):
    indices, counts = _case(n, nnz, k, s)
    fam = Hash4U.create(jax.random.PRNGKey(k), k, s)
    got = minhash4u(indices, counts, fam.a, s=s)
    want = kref.minhash4u_ref(indices, counts.reshape(-1, 1), fam.a, s=s)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_kernel_vs_minhash_module():
    """Pallas path == the core library path on a real SparseBatch."""
    from repro.core.minhash import minhash_signatures
    from repro.data import word_pair_sets
    D = 2**20
    s1, s2 = word_pair_sets(D, 700, 600, 0.4, seed=2)
    batch = from_lists([s1, s2])
    fam = Hash2U.create(jax.random.PRNGKey(0), 64, 20)
    via_kernel = batch_signatures(batch, fam)
    via_module = minhash_signatures(batch.indices, batch.mask, fam)
    assert np.array_equal(np.asarray(via_kernel), np.asarray(via_module))


# fast tier: fp32 small + one bf16 case; the rest of the product is slow
_SIGBAG_FAST = {(jnp.float32, 10, 16, 4, 8), (jnp.float32, 64, 500, 8, 1),
                (jnp.bfloat16, 130, 32, 6, 32)}
_SIGBAG_CASES = [
    pytest.param(dtype, n, k, b, d,
                 marks=[] if (dtype, n, k, b, d) in _SIGBAG_FAST
                 else [pytest.mark.slow])
    for dtype in (jnp.float32, jnp.bfloat16)
    for n, k, b, d in ((10, 16, 4, 8), (130, 32, 6, 32), (64, 500, 8, 1))]


@pytest.mark.parametrize("dtype,n,k,b,d", _SIGBAG_CASES)
def test_sigbag_kernel_matches_ref(dtype, n, k, b, d):
    tok = jnp.asarray(RNG.integers(0, 2**b, (n, k)), jnp.int32)
    table = jnp.asarray(RNG.normal(size=(k, 2**b, d)), dtype)
    got = sigbag(tok, table)
    want = kref.sigbag_ref(tok, table)
    rtol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=rtol,
                               atol=1e-4 if dtype == jnp.float32 else 0.3)


def test_sigbag_is_eq5_inner_product():
    """sigbag with d=1 equals the Eq.(5) one-hot expansion dot product."""
    from repro.core.bbit import expand_onehot
    k, b, n = 24, 3, 12
    tok = jnp.asarray(RNG.integers(0, 2**b, (n, k)), jnp.int32)
    w = jnp.asarray(RNG.normal(size=(k * 2**b,)), jnp.float32)
    via_kernel = np.asarray(sigbag(tok, w.reshape(k, 2**b, 1)))[:, 0]
    oh = expand_onehot(tok.astype(jnp.uint32), b)
    via_onehot = np.asarray(oh @ w)
    np.testing.assert_allclose(via_kernel, via_onehot, rtol=1e-5, atol=1e-5)
